#!/usr/bin/env python3
"""Oracle fixture generator for the native `rust/src/nn/` stack.

Transliterates the reference model math of `python/compile/model.py`
(itself the `kernels/ref.py` composition) under the *native numeric
contract* and emits bit-exact fixtures consumed by
`rust/tests/nn_kernels.rs`:

  * dot products accumulate in f64 sequentially over the contraction
    index and round to f32 once;
  * elementwise +,-,*,/ are single-rounded f32 (evaluated in f64 —
    exact for f32 operands — then rounded once, which IEEE-754
    guarantees equals the directly-rounded f32 op);
  * transcendentals (exp, tanh, log, sigmoid) evaluate in f64 via the
    platform libm on the widened input and round to f32 once — both
    CPython's `math` module and Rust's `f64::{exp,tanh,ln}` resolve to
    the system libm on linux-gnu, so the bit patterns agree;
  * batch reductions (loss means, adv normalization) accumulate in f64
    in flat `[T, B]` order (t-major), rounding to f32 once at the end.

The same functions are re-run with rounding disabled (pure f64) to
validate every analytic gradient against central finite differences to
~1e-8 relative error before anything is emitted, so the committed
fixtures carry both the forward bit patterns and a machine-checked
derivation of the BPTT backward used in `rust/src/nn/train.rs`.

Regenerate with:  python3 python/tools/gen_nn_fixtures.py
Output:           rust/tests/data/nn_fixtures.txt
"""

import math
import os
import struct

NUM_TILES = 15
NUM_COLORS = 14

MASK = (1 << 64) - 1

# ---------------------------------------------------------------------------
# util::rng mirror (xoshiro256++ seeded by splitmix64)
# ---------------------------------------------------------------------------


def _rotl(x, k):
    return ((x << k) | (x >> (64 - k))) & MASK


class XRng:
    """Bit-exact mirror of `rust/src/util/rng.rs`."""

    def __init__(self, seed=None, state=None):
        if state is not None:
            self.s = list(state)
            return
        x = seed & MASK
        s = []
        for _ in range(4):
            x = (x + 0x9E37_79B9_7F4A_7C15) & MASK
            z = x
            z = ((z ^ (z >> 30)) * 0xBF58_476D_1CE4_E5B9) & MASK
            z = ((z ^ (z >> 27)) * 0x94D0_49BB_1331_11EB) & MASK
            s.append(z ^ (z >> 31))
        self.s = s

    def next_u64(self):
        s = self.s
        r = (_rotl((s[0] + s[3]) & MASK, 23) + s[0]) & MASK
        t = (s[1] << 17) & MASK
        s[2] ^= s[0]
        s[3] ^= s[1]
        s[1] ^= s[2]
        s[0] ^= s[3]
        s[2] ^= t
        s[3] = _rotl(s[3], 45)
        return r

    def below(self, n):
        return self.next_u64() % n

    def f64(self):
        return (self.next_u64() >> 11) * (1.0 / float(1 << 53))

    def split(self):
        return XRng(seed=self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)

    def shuffle(self, items):
        for i in range(len(items) - 1, 0, -1):
            j = self.below(i + 1)
            items[i], items[j] = items[j], items[i]


# ---------------------------------------------------------------------------
# numeric contract ops (MODE32 toggles f32 rounding; False = pure f64,
# used only for the finite-difference validation of the backward)
# ---------------------------------------------------------------------------

MODE32 = True


def f32(x):
    return struct.unpack("<f", struct.pack("<f", float(x)))[0]


def rnd(x):
    return f32(x) if MODE32 else float(x)


def f32_bits(x):
    return struct.unpack("<I", struct.pack("<f", float(x)))[0]


def exp_c(x):
    return rnd(math.exp(x))


def tanh_c(x):
    return rnd(math.tanh(x))


def sigmoid_c(x):
    return rnd(1.0 / (1.0 + math.exp(-x)))


def matvec(x, w, n_in, n_out, bias=None):
    """out[j] = f32(sum_k f64(x[k] * w[k*n_out + j])) (+ bias, f32 add).

    Row-major `w` of shape [n_in, n_out], mirroring `x @ w` in the
    reference. The f64 accumulator runs over k ascending.
    """
    out = []
    for j in range(n_out):
        acc = 0.0
        for k in range(n_in):
            acc += x[k] * w[k * n_out + j]
        v = rnd(acc)
        if bias is not None:
            v = rnd(v + bias[j])
        out.append(v)
    return out


def log_softmax(logits):
    """Contract: m = max (f32 compare); d_i = f32(x_i - m); s = f64
    sequential sum of exp(d_i); logp_i = f32(d_i - ln s)."""
    m = max(logits)
    d = [rnd(x - m) for x in logits]
    s = 0.0
    for di in d:
        s += math.exp(di)
    ls = math.log(s)
    return [rnd(di - ls) for di in d]


def categorical(rng, logits):
    """One action draw: softmax probs in f64 from the contract
    log-probs, one rng.f64() per draw, CDF walk in action order."""
    logp = log_softmax(logits)
    probs = [math.exp(lp) for lp in logp]
    total = sum(probs)  # ~1.0; normalizes away rounding
    u = rng.f64() * total
    acc = 0.0
    for a, p in enumerate(probs):
        acc += p
        if u < acc:
            return a
    return len(probs) - 1


# ---------------------------------------------------------------------------
# model forward (transliteration of python/compile/model.py)
# ---------------------------------------------------------------------------


class Dims:
    def __init__(self, v, e, ae, d, h, a, extra):
        self.v, self.e, self.ae, self.d = v, e, ae, d
        self.h, self.a, self.extra = h, a, extra

    @property
    def obs_len(self):
        return self.v * self.v * 2 + self.extra

    @property
    def in1(self):
        return self.v * self.v * 2 * self.e + self.extra

    @property
    def rl2_in(self):
        return self.d + self.ae + 1


PARAM_NAMES = (
    "tile_emb", "col_emb", "act_emb", "w1", "b1",
    "wi", "wh", "bi", "bh", "whead", "bhead",
)


def param_shapes(dm):
    return {
        "tile_emb": (NUM_TILES, dm.e),
        "col_emb": (NUM_COLORS, dm.e),
        "act_emb": (dm.a + 1, dm.ae),
        "w1": (dm.in1, dm.d),
        "b1": (dm.d,),
        "wi": (dm.rl2_in, 3 * dm.h),
        "wh": (dm.h, 3 * dm.h),
        "bi": (3 * dm.h,),
        "bh": (3 * dm.h,),
        "whead": (dm.h, dm.a + 1),
        "bhead": (dm.a + 1,),
    }


def embed_obs(params, dm, obs_row):
    """The [V*V*2 (+extra)] i32 row -> f32 input of w1: per cell, E
    tile-embedding dims then E color dims; extra wrapper values appended
    raw as f32."""
    flat = []
    cells = dm.v * dm.v
    for c in range(cells):
        t = min(max(obs_row[c * 2], 0), NUM_TILES - 1)
        k = min(max(obs_row[c * 2 + 1], 0), NUM_COLORS - 1)
        flat.extend(params["tile_emb"][t * dm.e:(t + 1) * dm.e])
        flat.extend(params["col_emb"][k * dm.e:(k + 1) * dm.e])
    for i in range(dm.extra):
        flat.append(float(obs_row[cells * 2 + i]))
    return flat


def network_step(params, dm, obs_row, prev_a, prev_r, done, h):
    """One env, one step: returns (logits, value, h_out, cache)."""
    flat = embed_obs(params, dm, obs_row)
    trunk = matvec(flat, params["w1"], dm.in1, dm.d, params["b1"])
    trunk = [x if x > 0.0 else 0.0 for x in trunk]
    pa = dm.a if done else min(max(prev_a, 0), dm.a)
    ae = params["act_emb"][pa * dm.ae:(pa + 1) * dm.ae]
    nd = rnd(1.0 - (1.0 if done else 0.0))
    pr = rnd(prev_r * nd)
    x = trunk + list(ae) + [pr]
    h_in = [rnd(hj * nd) for hj in h]
    gi = matvec(x, params["wi"], dm.rl2_in, 3 * dm.h, params["bi"])
    gh = matvec(h_in, params["wh"], dm.h, 3 * dm.h, params["bh"])
    H = dm.h
    r = [sigmoid_c(rnd(gi[j] + gh[j])) for j in range(H)]
    z = [sigmoid_c(rnd(gi[H + j] + gh[H + j])) for j in range(H)]
    n = [tanh_c(rnd(gi[2 * H + j] + rnd(r[j] * gh[2 * H + j])))
         for j in range(H)]
    h_out = [rnd(rnd(rnd(1.0 - z[j]) * n[j]) + rnd(z[j] * h_in[j]))
             for j in range(H)]
    out = matvec(h_out, params["whead"], dm.h, dm.a + 1, params["bhead"])
    logits, value = out[: dm.a], out[dm.a]
    cache = {
        "x": x, "h_in": h_in, "r": r, "z": z, "n": n,
        "ghn": gh[2 * H:], "pa": pa, "nd": nd, "trunk": trunk,
        "obs_row": obs_row, "h_out": h_out,
    }
    return logits, value, h_out, cache


def gae(rewards, values, dones, last_value, gamma, lam, T, B):
    """Reverse-scan GAE in contract f32; arrays flat [T, B]. Returns
    (adv, targets) flat [T, B]."""
    g, l = rnd(gamma), rnd(lam)
    gl = rnd(g * l)
    adv = [0.0] * (T * B)
    targets = [0.0] * (T * B)
    for b in range(B):
        a_next = 0.0
        v_next = last_value[b]
        for t in range(T - 1, -1, -1):
            i = t * B + b
            nonterm = rnd(1.0 - (1.0 if dones[i] else 0.0))
            t1 = rnd(g * v_next)
            t2 = rnd(t1 * nonterm)
            t3 = rnd(rewards[i] + t2)
            delta = rnd(t3 - values[i])
            u1 = rnd(gl * nonterm)
            u2 = rnd(u1 * a_next)
            a_next = rnd(delta + u2)
            adv[i] = a_next
            targets[i] = rnd(a_next + values[i])
            v_next = values[i]
    return adv, targets


# ---------------------------------------------------------------------------
# PPO loss + analytic backward (BPTT)
# ---------------------------------------------------------------------------


def forward_sequence(params, dm, mb):
    """Run the policy over the minibatch's T-step window. `mb` holds
    flat [T, Bm] arrays plus h0 [Bm, H]. Returns per-step caches and
    (logits, values) flat [T, Bm, A] / [T, Bm]."""
    T, Bm = mb["T"], mb["Bm"]
    h = [list(mb["h0"][b * dm.h:(b + 1) * dm.h]) for b in range(Bm)]
    logits = [[0.0] * dm.a for _ in range(T * Bm)]
    values = [0.0] * (T * Bm)
    caches = [None] * (T * Bm)
    ol = dm.obs_len
    for t in range(T):
        for b in range(Bm):
            i = t * Bm + b
            obs_row = mb["obs"][i * ol:(i + 1) * ol]
            lg, v, h_new, cache = network_step(
                params, dm, obs_row, mb["prev_a"][i], mb["prev_r"][i],
                mb["done"][i], h[b])
            logits[i], values[i], caches[i] = lg, v, cache
            h[b] = h_new
    return logits, values, caches


def ppo_loss_and_grads(params, dm, mb, hp):
    """Full loss forward + analytic BPTT backward over the minibatch.

    Returns (metrics6, grads) where metrics6 = [total, pi_loss, v_loss,
    entropy, approx_kl, clip_frac] (contract f32) and grads maps param
    name -> f64 list. Loss means accumulate f64 in flat [T, Bm] order.
    """
    T, Bm = mb["T"], mb["Bm"]
    N = T * Bm
    # hyperparameters live as f32 on the Rust side: round them first so
    # every f64 expression below sees the identical operand bits
    clip_eps = float(f32(hp[1]))
    ent_coef = float(f32(hp[4]))
    vf_coef = float(f32(hp[5]))
    logits, values, caches = forward_sequence(params, dm, mb)

    # adv normalization over the minibatch, f64 mean/std (population)
    s = 0.0
    for i in range(N):
        s += mb["adv"][i]
    mean = s / N
    s2 = 0.0
    for i in range(N):
        d = mb["adv"][i] - mean
        s2 += d * d
    std = math.sqrt(s2 / N)
    adv_n = [rnd((mb["adv"][i] - mean) / (std + 1e-8)) for i in range(N)]

    lo, hi = rnd(1.0 - clip_eps), rnd(1.0 + clip_eps)
    logp_all = [log_softmax(logits[i]) for i in range(N)]
    sum_pi, sum_v, sum_ent, sum_kl, n_clip = 0.0, 0.0, 0.0, 0.0, 0
    dlogits = [[0.0] * dm.a for _ in range(N)]
    dvalues = [0.0] * N
    for i in range(N):
        act = mb["actions"][i]
        lp = logp_all[i][act]
        dl = rnd(lp - mb["old_logp"][i])
        ratio = exp_c(dl)
        a = adv_n[i]
        pg1 = rnd(ratio * a)
        rc = min(max(ratio, lo), hi)
        pg2 = rnd(rc * a)
        sum_pi += min(pg1, pg2)
        rf = ratio
        sum_kl += (rf - 1.0) - math.log(rf)
        if abs(rnd(ratio - 1.0)) > clip_eps:
            n_clip += 1
        # d min(pg1, pg2) / d logp  (ratio' = ratio)
        if pg1 <= pg2:
            dmin_dlogp = a * ratio
        else:
            dmin_dlogp = a * ratio if lo <= ratio <= hi else 0.0
        dlp = -(1.0 / N) * dmin_dlogp  # pi_loss = -mean(min(...))
        probs = [math.exp(lp_a) for lp_a in logp_all[i]]
        ent_i = 0.0
        for p_a, lp_a in zip(probs, logp_all[i]):
            ent_i -= p_a * lp_a
        sum_ent += ent_i
        for j in range(dm.a):
            d_z = dlp * ((1.0 if j == act else 0.0) - probs[j])
            # total has -ent_coef * entropy; dH/dz_a = -p_a (logp_a + H)
            d_z += ent_coef / N * probs[j] * (logp_all[i][j] + ent_i)
            dlogits[i][j] = d_z
        e = rnd(values[i] - mb["targets"][i])
        sum_v += e * e
        dvalues[i] = vf_coef / N * e

    pi_loss = rnd(-(sum_pi / N))
    v_loss = rnd(0.5 * sum_v / N)
    entropy = rnd(sum_ent / N)
    approx_kl = rnd(sum_kl / N)
    clip_frac = rnd(n_clip / N)
    total = rnd(pi_loss + vf_coef * float(v_loss)
                - ent_coef * float(entropy))
    # recompute in f64 from the unrounded sums when rounding is off
    if not MODE32:
        total = (-(sum_pi / N) + vf_coef * (0.5 * sum_v / N)
                 - ent_coef * (sum_ent / N))

    grads = {nm: [0.0] * (sh[0] * (sh[1] if len(sh) > 1 else 1))
             for nm, sh in param_shapes(dm).items()}
    backward_sequence(params, dm, mb, caches, dlogits, dvalues, grads)
    metrics = [total, pi_loss, v_loss, entropy, approx_kl, clip_frac]
    return metrics, grads, std


def backward_sequence(params, dm, mb, caches, dlogits, dvalues, grads):
    """BPTT: iterate t descending, envs ascending; f64 grad buffers."""
    T, Bm, H, A = mb["T"], mb["Bm"], dm.h, dm.a
    dh_carry = [[0.0] * H for _ in range(Bm)]
    for t in range(T - 1, -1, -1):
        for b in range(Bm):
            i = t * Bm + b
            c = caches[i]
            # head backward: out = h_out @ whead + bhead
            dout = dlogits[i] + [dvalues[i]]
            dh = list(dh_carry[b])
            for j in range(H):
                hj = c["h_out"][j]
                base = j * (A + 1)
                for o in range(A + 1):
                    grads["whead"][base + o] += hj * dout[o]
                    dh[j] += dout[o] * params["whead"][base + o]
            for o in range(A + 1):
                grads["bhead"][o] += dout[o]
            # GRU backward
            dgi = [0.0] * (3 * H)
            dgh = [0.0] * (3 * H)
            dh_in = [0.0] * H
            for j in range(H):
                r, z, n = c["r"][j], c["z"][j], c["n"][j]
                dn = dh[j] * (1.0 - z)
                dz = dh[j] * (c["h_in"][j] - n)
                dh_in[j] += dh[j] * z
                da_n = dn * (1.0 - n * n)
                dr = da_n * c["ghn"][j]
                da_r = dr * r * (1.0 - r)
                da_z = dz * z * (1.0 - z)
                dgi[j], dgi[H + j], dgi[2 * H + j] = da_r, da_z, da_n
                dgh[j], dgh[H + j] = da_r, da_z
                dgh[2 * H + j] = da_n * r
            dx = [0.0] * dm.rl2_in
            for k in range(dm.rl2_in):
                xk = c["x"][k]
                base = k * 3 * H
                acc = 0.0
                for j in range(3 * H):
                    grads["wi"][base + j] += xk * dgi[j]
                    acc += dgi[j] * params["wi"][base + j]
                dx[k] = acc
            for k in range(H):
                hk = c["h_in"][k]
                base = k * 3 * H
                acc = 0.0
                for j in range(3 * H):
                    grads["wh"][base + j] += hk * dgh[j]
                    acc += dgh[j] * params["wh"][base + j]
                dh_in[k] += acc
            for j in range(3 * H):
                grads["bi"][j] += dgi[j]
                grads["bh"][j] += dgh[j]
            # input-mask backward: h_in = h_prev * (1 - done)
            dh_carry[b] = [dh_in[k] * c["nd"] for k in range(H)]
            # trunk / embeddings backward
            dtrunk = dx[: dm.d]
            dae = dx[dm.d: dm.d + dm.ae]
            ab = c["pa"] * dm.ae
            for j in range(dm.ae):
                grads["act_emb"][ab + j] += dae[j]
            dpre = [dtrunk[j] if c["trunk"][j] > 0.0 else 0.0
                    for j in range(dm.d)]
            flat = embed_obs(params, dm, c["obs_row"])
            dflat = [0.0] * dm.in1
            for k in range(dm.in1):
                fk = flat[k]
                base = k * dm.d
                acc = 0.0
                for j in range(dm.d):
                    grads["w1"][base + j] += fk * dpre[j]
                    acc += dpre[j] * params["w1"][base + j]
                dflat[k] = acc
            for j in range(dm.d):
                grads["b1"][j] += dpre[j]
            cells = dm.v * dm.v
            for cc in range(cells):
                ti = min(max(c["obs_row"][cc * 2], 0), NUM_TILES - 1)
                ci = min(max(c["obs_row"][cc * 2 + 1], 0), NUM_COLORS - 1)
                for j in range(dm.e):
                    grads["tile_emb"][ti * dm.e + j] += \
                        dflat[cc * 2 * dm.e + j]
                    grads["col_emb"][ci * dm.e + j] += \
                        dflat[cc * 2 * dm.e + dm.e + j]


def global_norm(grads):
    acc = 0.0
    for nm in PARAM_NAMES:
        for g in grads[nm]:
            acc += g * g
    return math.sqrt(acc)


def adam_step(params, grads, mstate, vstate, t, lr, max_norm):
    """Contract Adam: f64 math per element, states/params rounded to
    f32 on store. `t` is the post-increment step count (>= 1)."""
    lr = float(f32(lr))
    max_norm = float(f32(max_norm))
    gn = global_norm(grads)
    scale = min(1.0, max_norm / (gn + 1e-8))
    bc1 = 1.0 - 0.9 ** t
    bc2 = 1.0 - 0.999 ** t
    for nm in PARAM_NAMES:
        p, g = params[nm], grads[nm]
        m, v = mstate[nm], vstate[nm]
        for k in range(len(p)):
            gk = g[k] * scale
            mk = rnd(0.9 * m[k] + 0.1 * gk)
            vk = rnd(0.999 * v[k] + 0.001 * gk * gk)
            m[k], v[k] = mk, vk
            mh = mk / bc1
            vh = vk / bc2
            p[k] = rnd(p[k] - lr * mh / (math.sqrt(vh) + 1e-8))
    return gn


# ---------------------------------------------------------------------------
# finite-difference validation (pure f64 mode)
# ---------------------------------------------------------------------------


def fin_diff_check(dm, mb, hp, params):
    global MODE32
    MODE32 = False
    try:
        _, grads, _ = ppo_loss_and_grads(params, dm, mb, hp)

        def loss_of(ps):
            m, _, _ = ppo_loss_and_grads(ps, dm, mb, hp)
            return m[0]

        eps = 1e-6
        worst = 0.0
        for nm in PARAM_NAMES:
            n = len(params[nm])
            stride = max(1, n // 7)  # probe a spread of elements
            for k in range(0, n, stride):
                pp = {q: list(params[q]) for q in PARAM_NAMES}
                pp[nm][k] += eps
                up = loss_of(pp)
                pp[nm][k] -= 2 * eps
                dn = loss_of(pp)
                num = (up - dn) / (2 * eps)
                ana = grads[nm][k]
                rel = abs(num - ana) / max(abs(num), abs(ana), 1e-6)
                worst = max(worst, rel)
                assert rel < 1e-4, (
                    f"grad mismatch {nm}[{k}]: fin-diff {num:.9g} "
                    f"analytic {ana:.9g} rel {rel:.3g}")
        print(f"fin-diff ok: worst rel err {worst:.3g}")
    finally:
        MODE32 = True


# ---------------------------------------------------------------------------
# fixture emission
# ---------------------------------------------------------------------------


class Emit:
    def __init__(self):
        self.lines = [
            "# generated by python/tools/gen_nn_fixtures.py -- do not edit",
        ]

    def case(self, name):
        self.lines.append(f"case {name}")

    def i32(self, name, vals):
        self.lines.append(
            f"i32 {name} {len(vals)} " + " ".join(str(int(v)) for v in vals))

    def fl(self, name, vals):
        self.lines.append(
            f"f32 {name} {len(vals)} "
            + " ".join(f"{f32_bits(v):08x}" for v in vals))

    def u64(self, name, vals):
        self.lines.append(
            f"u64 {name} {len(vals)} " + " ".join(f"{v:016x}" for v in vals))

    def end(self):
        self.lines.append("end")

    def write(self, path):
        with open(path, "w") as f:
            f.write("\n".join(self.lines) + "\n")


def rand_f32s(rng, n, scale=1.0, shift=-0.5):
    return [f32((rng.f64() + shift) * scale) for _ in range(n)]


def rand_params(rng, dm, scale=0.6):
    params = {}
    for nm, sh in param_shapes(dm).items():
        n = sh[0] * (sh[1] if len(sh) > 1 else 1)
        params[nm] = rand_f32s(rng, n, scale=scale)
    return params


def make_minibatch(rng, dm, T, Bm):
    """Synthetic rollout minibatch with realistic structure."""
    N = T * Bm
    ol = dm.obs_len
    obs = []
    cells = dm.v * dm.v
    for _ in range(N):
        row = []
        for _ in range(cells):
            row.append(rng.below(NUM_TILES + 2) - 1)  # includes clamping
            row.append(rng.below(NUM_COLORS + 2) - 1)
        for _ in range(dm.extra):
            row.append(rng.below(3))
        obs.extend(row)
    prev_a = [rng.below(dm.a + 1) for _ in range(N)]
    prev_r = rand_f32s(rng, N, scale=0.5, shift=0.0)
    done = [1 if rng.f64() < 0.2 else 0 for _ in range(N)]
    actions = [rng.below(dm.a) for _ in range(N)]
    old_logp = [f32(-(rng.f64() * 2.0 + 0.1)) for _ in range(N)]
    old_value = rand_f32s(rng, N, scale=1.0)
    rewards = [f32(rng.f64() * 0.5) if rng.f64() < 0.3 else 0.0
               for _ in range(N)]
    done_after = [1 if rng.f64() < 0.2 else 0 for _ in range(N)]
    last_value = rand_f32s(rng, Bm, scale=1.0)
    h0 = rand_f32s(rng, Bm * dm.h, scale=0.8)
    return {
        "T": T, "Bm": Bm, "obs": obs, "prev_a": prev_a, "prev_r": prev_r,
        "done": done, "actions": actions, "old_logp": old_logp,
        "old_value": old_value, "rewards": rewards,
        "done_after": done_after, "last_value": last_value, "h0": h0,
    }


def main():
    out = Emit()

    # --- rng parity ------------------------------------------------------
    rng = XRng(seed=123)
    u = [rng.next_u64() for _ in range(6)]
    f = [XRng(seed=123)]
    fr = f[0]
    fvals = [fr.f64() for _ in range(6)]
    sp = XRng(seed=123)
    child = sp.split()
    out.case("rng")
    out.u64("seed", [123])
    out.u64("u64s", u)
    out.u64("f64_bits",
            [struct.unpack("<Q", struct.pack("<d", x))[0] for x in fvals])
    out.u64("split_first", [child.next_u64()])
    out.end()

    # --- gru cell --------------------------------------------------------
    rng = XRng(seed=7)
    B, I, H = 3, 7, 4
    x = rand_f32s(rng, B * I)
    h = rand_f32s(rng, B * H)
    wi = rand_f32s(rng, I * 3 * H)
    wh = rand_f32s(rng, H * 3 * H)
    bi = rand_f32s(rng, 3 * H, scale=0.2)
    bh = rand_f32s(rng, 3 * H, scale=0.2)
    h_out = []
    for b in range(B):
        xb, hb = x[b * I:(b + 1) * I], h[b * H:(b + 1) * H]
        gi = matvec(xb, wi, I, 3 * H, bi)
        gh = matvec(hb, wh, H, 3 * H, bh)
        r = [sigmoid_c(rnd(gi[j] + gh[j])) for j in range(H)]
        z = [sigmoid_c(rnd(gi[H + j] + gh[H + j])) for j in range(H)]
        n = [tanh_c(rnd(gi[2 * H + j] + rnd(r[j] * gh[2 * H + j])))
             for j in range(H)]
        h_out.extend(
            rnd(rnd(rnd(1.0 - z[j]) * n[j]) + rnd(z[j] * hb[j]))
            for j in range(H))
    out.case("gru_forward")
    out.i32("dims", [B, I, H])
    out.fl("x", x)
    out.fl("h", h)
    out.fl("wi", wi)
    out.fl("wh", wh)
    out.fl("bi", bi)
    out.fl("bh", bh)
    out.fl("h_out", h_out)
    out.end()

    # --- actor-critic head ----------------------------------------------
    rng = XRng(seed=8)
    B, H, A = 3, 4, 6
    hv = rand_f32s(rng, B * H)
    w = rand_f32s(rng, H * (A + 1))
    bb = rand_f32s(rng, A + 1, scale=0.3)
    logits, value = [], []
    for b in range(B):
        o = matvec(hv[b * H:(b + 1) * H], w, H, A + 1, bb)
        logits.extend(o[:A])
        value.append(o[A])
    out.case("head_forward")
    out.i32("dims", [B, H, A])
    out.fl("h", hv)
    out.fl("w", w)
    out.fl("b", bb)
    out.fl("logits", logits)
    out.fl("value", value)
    out.end()

    # --- log-softmax -----------------------------------------------------
    rng = XRng(seed=9)
    B, A = 4, 6
    lg = rand_f32s(rng, B * A, scale=4.0)
    lp = []
    for b in range(B):
        lp.extend(log_softmax(lg[b * A:(b + 1) * A]))
    out.case("log_softmax")
    out.i32("dims", [B, A])
    out.fl("logits", lg)
    out.fl("logp", lp)
    out.end()

    # --- categorical sampling -------------------------------------------
    rng = XRng(seed=10)
    B, A = 5, 6
    lg = rand_f32s(rng, B * A, scale=3.0)
    act_rng = XRng(seed=77)
    acts = [categorical(act_rng, lg[b * A:(b + 1) * A]) for b in range(B)]
    out.case("categorical")
    out.u64("seed", [77])
    out.i32("dims", [B, A])
    out.fl("logits", lg)
    out.i32("actions", acts)
    out.end()

    # --- network_step (symbolic, and with wrapper extras) ---------------
    for name, extra in (("network_step", 0), ("network_step_ext", 4)):
        rng = XRng(seed=11 + extra)
        dm = Dims(v=5, e=2, ae=3, d=6, h=4, a=6, extra=extra)
        B = 4
        params = rand_params(rng, dm)
        mb_obs = []
        for _ in range(B):
            row = []
            for _ in range(dm.v * dm.v):
                row.append(rng.below(NUM_TILES + 2) - 1)
                row.append(rng.below(NUM_COLORS + 2) - 1)
            for _ in range(extra):
                row.append(rng.below(3))
            mb_obs.append(row)
        prev_a = [0, 3, 6, 2]
        prev_r = [f32(0.25), 0.0, f32(0.5), f32(-0.125)]
        done = [0, 1, 0, 1]
        h0 = rand_f32s(rng, B * dm.h)
        lgs, vals, houts = [], [], []
        for b in range(B):
            lg, v, ho, _ = network_step(
                params, dm, mb_obs[b], prev_a[b], prev_r[b], done[b],
                h0[b * dm.h:(b + 1) * dm.h])
            lgs.extend(lg)
            vals.append(v)
            houts.extend(ho)
        out.case(name)
        out.i32("dims", [B, dm.v, dm.e, dm.ae, dm.d, dm.h, dm.a, extra])
        for nm in PARAM_NAMES:
            out.fl(nm, params[nm])
        out.i32("obs", [v for row in mb_obs for v in row])
        out.i32("prev_a", prev_a)
        out.fl("prev_r", prev_r)
        out.i32("done", done)
        out.fl("h", h0)
        out.fl("logits", lgs)
        out.fl("value", vals)
        out.fl("h_out", houts)
        out.end()

    # --- GAE -------------------------------------------------------------
    rng = XRng(seed=21)
    T, B = 5, 3
    rewards = rand_f32s(rng, T * B, scale=1.0, shift=0.0)
    values = rand_f32s(rng, T * B, scale=1.0)
    dones = [1 if rng.f64() < 0.3 else 0 for _ in range(T * B)]
    last_value = rand_f32s(rng, B)
    adv, targets = gae(rewards, values, dones, last_value,
                       0.99, 0.95, T, B)
    out.case("gae")
    out.i32("dims", [T, B])
    out.fl("gamma", [0.99])
    out.fl("lam", [0.95])
    out.fl("rewards", rewards)
    out.fl("values", values)
    out.i32("dones", dones)
    out.fl("last_value", last_value)
    out.fl("adv", adv)
    out.fl("targets", targets)
    out.end()

    # --- adam ------------------------------------------------------------
    rng = XRng(seed=31)
    n = 13
    p = rand_f32s(rng, n)
    m = rand_f32s(rng, n, scale=0.1)
    v = [f32(abs(x)) for x in rand_f32s(rng, n, scale=0.05)]
    g = [float(x) for x in rand_f32s(rng, n, scale=2.0)]
    # exercise both clip regimes with the same tensors
    for name, max_norm in (("adam", 10.0), ("adam_clipped", 0.5)):
        ps = {"p": list(p)}
        ms, vs = {"p": list(m)}, {"p": list(v)}
        names_save = PARAM_NAMES
        globals()["PARAM_NAMES"] = ("p",)
        gn = adam_step(ps, {"p": list(g)}, ms, vs, t=3, lr=1e-3,
                       max_norm=max_norm)
        globals()["PARAM_NAMES"] = names_save
        out.case(name)
        out.i32("dims", [n, 3])  # n, t
        out.fl("lr", [1e-3])
        out.fl("max_norm", [max_norm])
        out.fl("p", p)
        out.fl("m", m)
        out.fl("v", v)
        out.fl("g", g)
        out.fl("gn", [gn])
        out.fl("p_out", ps["p"])
        out.fl("m_out", ms["p"])
        out.fl("v_out", vs["p"])
        out.end()

    # --- full PPO update (loss metrics + post-Adam params) ---------------
    rng = XRng(seed=41)
    dm = Dims(v=5, e=2, ae=3, d=6, h=4, a=6, extra=0)
    T, Bm = 3, 4
    params = rand_params(rng, dm)
    mb = make_minibatch(rng, dm, T, Bm)
    hp = [1e-3, 0.2, 0.99, 0.95, 0.01, 0.5, 0.5, 0.0]
    adv, targets = gae(mb["rewards"], mb["old_value"], mb["done_after"],
                       mb["last_value"], hp[2], hp[3], T, Bm)
    mb["adv"], mb["targets"] = adv, targets

    # validate the analytic backward before emitting anything
    fin_diff_check(dm, mb, hp, params)

    metrics, grads, std = ppo_loss_and_grads(params, dm, mb, hp)
    new_params = {nm: list(params[nm]) for nm in PARAM_NAMES}
    mstate = {nm: [0.0] * len(params[nm]) for nm in PARAM_NAMES}
    vstate = {nm: [0.0] * len(params[nm]) for nm in PARAM_NAMES}
    gn = adam_step(new_params, grads, mstate, vstate, t=1, lr=hp[0],
                   max_norm=hp[6])
    out.case("ppo_update")
    out.i32("dims", [T, Bm, dm.v, dm.e, dm.ae, dm.d, dm.h, dm.a, 0])
    out.fl("hp", hp)
    for nm in PARAM_NAMES:
        out.fl(nm, params[nm])
    out.i32("obs", mb["obs"])
    out.i32("prev_a", mb["prev_a"])
    out.fl("prev_r", mb["prev_r"])
    out.i32("done", mb["done"])
    out.i32("actions", mb["actions"])
    out.fl("old_logp", mb["old_logp"])
    out.fl("old_value", mb["old_value"])
    out.fl("rewards", mb["rewards"])
    out.i32("done_after", mb["done_after"])
    out.fl("last_value", mb["last_value"])
    out.fl("h0", mb["h0"])
    out.fl("adv", adv)
    out.fl("targets", targets)
    out.fl("metrics", metrics + [f32(gn), f32(std)])
    for nm in PARAM_NAMES:
        out.fl(nm + "_new", new_params[nm])
        out.fl(nm + "_m", mstate[nm])
        out.fl(nm + "_v", vstate[nm])
    out.end()

    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    path = os.path.join(root, "rust", "tests", "data", "nn_fixtures.txt")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    out.write(path)
    print(f"wrote {path} ({len(out.lines)} lines)")


if __name__ == "__main__":
    main()
