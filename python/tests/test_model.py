"""L2 model tests: RL² network shapes, PPO update math (GAE, Adam,
clipping) and learning on a synthetic bandit-like task."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig(trunk_dim=32, hidden_dim=16, emb_dim=4, act_emb_dim=4)


@pytest.fixture(scope="module")
def params():
    return M.init_params(jax.random.PRNGKey(0), CFG)


def test_param_shapes_and_order(params):
    assert len(params) == M.NUM_PARAMS == len(M.PARAM_NAMES)
    shapes = {n: p.shape for n, p in zip(M.PARAM_NAMES, params)}
    assert shapes["wi"] == (M.rl2_input_dim(CFG), 3 * CFG.hidden_dim)
    assert shapes["whead"] == (CFG.hidden_dim, CFG.num_actions + 1)


def test_policy_step_outputs(params):
    b = 8
    key = jax.random.PRNGKey(1)
    obs = jax.random.randint(key, (b, 5, 5, 2), 0, 10)
    a, logp, v, h = M.policy_step(
        params, obs, jnp.zeros(b, jnp.int32), jnp.zeros(b),
        jnp.zeros(b, jnp.int32), jnp.zeros((b, CFG.hidden_dim)), key, CFG)
    assert a.shape == (b,) and a.dtype == jnp.int32
    assert np.all((np.asarray(a) >= 0) & (np.asarray(a) < 6))
    assert np.all(np.asarray(logp) <= 0)
    assert v.shape == (b,)
    assert h.shape == (b, CFG.hidden_dim)


def test_done_resets_hidden_state(params):
    b = 4
    key = jax.random.PRNGKey(2)
    obs = jnp.zeros((b, 5, 5, 2), jnp.int32)
    h = jax.random.normal(key, (b, CFG.hidden_dim))
    # with done=1 the carried h must be ignored: outputs identical for any h
    _, v1, h1 = M.network_step(params, obs, jnp.zeros(b, jnp.int32),
                               jnp.zeros(b), jnp.ones(b, jnp.int32), h,
                               CFG)
    _, v2, h2 = M.network_step(params, obs, jnp.zeros(b, jnp.int32),
                               jnp.zeros(b), jnp.ones(b, jnp.int32),
                               h * 5.0, CFG)
    np.testing.assert_allclose(v1, v2, rtol=1e-6)
    np.testing.assert_allclose(h1, h2, rtol=1e-6)


def test_gae_matches_manual():
    # single env, 3 steps, no terminations
    r = jnp.array([[1.0], [0.0], [1.0]])
    v = jnp.array([[0.5], [0.5], [0.5]])
    d = jnp.zeros((3, 1), jnp.int32)
    last_v = jnp.array([0.5])
    gamma, lam = 0.9, 0.8
    adv = M.gae(r, v, d, last_v, gamma, lam)
    # manual backward recursion
    deltas = [1.0 + 0.9 * 0.5 - 0.5, 0.0 + 0.9 * 0.5 - 0.5,
              1.0 + 0.9 * 0.5 - 0.5]
    a2 = deltas[2]
    a1 = deltas[1] + gamma * lam * a2
    a0 = deltas[0] + gamma * lam * a1
    np.testing.assert_allclose(np.asarray(adv)[:, 0], [a0, a1, a2],
                               rtol=1e-6)


def test_gae_cuts_at_episode_end():
    r = jnp.zeros((3, 1))
    v = jnp.ones((3, 1))
    last_v = jnp.array([100.0])  # must not leak across the done at t=2
    d = jnp.array([[0], [0], [1]], jnp.int32)
    adv = M.gae(r, v, d, last_v, 0.99, 0.95)
    # at t=2: delta = 0 + 0 - 1 = -1 (bootstrap suppressed)
    np.testing.assert_allclose(float(adv[2, 0]), -1.0, rtol=1e-6)


def test_adam_step_moves_toward_gradient():
    params = [jnp.ones((3,))]
    grads = [jnp.array([1.0, -1.0, 0.0])]
    m = [jnp.zeros((3,))]
    v = [jnp.zeros((3,))]
    hp = jnp.array([0.1, 0.2, 0.99, 0.95, 0.01, 0.5, 0.5, 0.0])
    new_p, _, _, t = M.adam_update(params, grads, m, v,
                                   jnp.asarray(0, jnp.int32), hp)
    assert int(t) == 1
    p = np.asarray(new_p[0])
    assert p[0] < 1.0 and p[1] > 1.0 and p[2] == 1.0


def test_global_norm_clip():
    grads = [jnp.array([3.0, 4.0])]  # norm 5
    clipped, gn = M.global_norm_clip(grads, 1.0)
    np.testing.assert_allclose(float(gn), 5.0, rtol=1e-6)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(clipped[0])), 1.0, rtol=1e-4)
    # below the max norm: untouched
    same, _ = M.global_norm_clip(grads, 10.0)
    np.testing.assert_allclose(same[0], grads[0])


def _synthetic_rollout(key, t, b, good_action=2):
    """Bandit-ish data: reward when action==good_action was taken."""
    ks = jax.random.split(key, 4)
    obs = jax.random.randint(ks[0], (t, b, 5, 5, 2), 0, 10)
    actions = jax.random.randint(ks[1], (t, b), 0, 6)
    reward = (actions == good_action).astype(jnp.float32)
    old_logp = jnp.full((t, b), -np.log(6.0))
    old_value = jnp.zeros((t, b))
    return (obs, jnp.zeros((t, b), jnp.int32), jnp.zeros((t, b)),
            jnp.zeros((t, b), jnp.int32), actions, old_logp, old_value,
            reward, jnp.zeros((t, b), jnp.int32), jnp.zeros((b,)),
            jnp.zeros((b, CFG.hidden_dim)))


def test_train_update_learns_synthetic_bandit(params):
    t, b = 8, 16
    hp = M.default_hp()
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    tcount = jnp.asarray(0, jnp.int32)
    p = [jnp.asarray(x) for x in params]

    upd = jax.jit(lambda p, m, v, t_, roll, hp: M.train_update(
        p, m, v, t_, roll, hp, CFG))

    def mean_good_prob(p):
        obs = jnp.zeros((4, 5, 5, 2), jnp.int32)
        logits, _, _ = M.network_step(
            p, obs, jnp.zeros(4, jnp.int32), jnp.zeros(4),
            jnp.ones(4, jnp.int32), jnp.zeros((4, CFG.hidden_dim)), CFG)
        return float(jax.nn.softmax(logits, -1)[:, 2].mean())

    before = mean_good_prob(p)
    for i in range(30):
        roll = _synthetic_rollout(jax.random.PRNGKey(i), t, b)
        p, m, v, tcount, metrics = upd(p, m, v, tcount, roll, hp)
    after = mean_good_prob(p)
    assert int(tcount) == 30
    assert after > before + 0.05, (
        f"policy should move toward the rewarded action ({before:.3f} -> "
        f"{after:.3f})")
    assert np.all(np.isfinite(np.asarray(metrics)))


def test_metrics_vector_semantics(params):
    t, b = 4, 8
    hp = M.default_hp()
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    roll = _synthetic_rollout(jax.random.PRNGKey(0), t, b)
    _, _, _, _, metrics = M.train_update(
        list(params), m, v, jnp.asarray(0, jnp.int32), roll, hp, CFG)
    ms = np.asarray(metrics)
    assert ms.shape == (8,)
    entropy = ms[3]
    assert 0.0 < entropy <= np.log(6.0) + 1e-5
    clip_frac = ms[5]
    assert 0.0 <= clip_frac <= 1.0


if __name__ == "__main__":
    pytest.main([__file__, "-q"])


def test_goal_conditioning_features():
    # Fig. 11 mechanism: goal/rule encodings -> conditioning vector
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    b, mr = 6, 3
    goal = jnp.tile(jnp.array([[3, 5, 3, 0, 0]], jnp.int32), (b, 1))
    rules = jnp.zeros((b, mr, 7), jnp.int32)
    feat = M.goal_conditioning(params, goal, rules, CFG)
    assert feat.shape == (b, 15 + 6 * CFG.emb_dim)
    # one-hot on the goal id
    np.testing.assert_allclose(np.asarray(feat[:, 3]), 1.0)
    # different goals give different features
    goal2 = goal.at[:, 0].set(4)
    feat2 = M.goal_conditioning(params, goal2, rules, CFG)
    assert not np.allclose(np.asarray(feat), np.asarray(feat2))
