"""Fused rollout graphs (Anakin loops): shape/semantic tests at tiny sizes
for env_rollout, train_iter and eval_rollout before they are AOT-lowered."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import rollout as R
from compile.aot import state_specs, STATE_FIELDS, _DTYPES
from compile.xmg import types as T
from compile.xmg.grid import empty_room

jax.config.update("jax_platform_name", "cpu")

CFG = M.ModelConfig()
H = W = 9
MR, MI, B = 3, 6, 8


def batched_state(seed=0):
    base = jnp.stack([empty_room(H, W)] * B)
    rules = jnp.zeros((B, MR, T.RULE_ENC), jnp.int32)
    goal = jnp.tile(
        jnp.array([[T.GOAL_AGENT_NEAR, T.TILE_BALL, T.COLOR_RED, 0, 0]],
                  jnp.int32), (B, 1))
    init = jnp.zeros((B, MI, 2), jnp.int32)
    init = init.at[:, 0].set(
        jnp.array([T.TILE_BALL, T.COLOR_RED], jnp.int32))
    keys = jax.random.split(jax.random.PRNGKey(seed), B)
    from compile.xmg import env
    reset_b = jax.vmap(lambda bg, r, g, it, k: env.reset(
        bg, r, g, it, 243, k))
    state, obs = reset_b(base, rules, goal, init, keys)
    return state, obs


def test_env_rollout_shapes_and_accounting():
    t_len = 16
    fn = R.make_env_rollout(5, t_len)
    state, _ = batched_state()
    flat = R.state_to_flat(state)
    out = jax.jit(fn)(*flat, jax.random.PRNGKey(7))
    assert len(out) == 11 + 4
    reward_sum, done_sum, trial_sum, chk = out[11:]
    assert reward_sum.shape == (B,)
    assert np.all(np.asarray(done_sum) >= 0)
    assert np.all(np.asarray(trial_sum) >= np.asarray(done_sum)), \
        "every episode end is a trial end"
    # step counters advanced
    step_counts = np.asarray(out[8])
    assert np.all(step_counts == t_len), "no terminations in 16 < 243 steps"
    assert int(chk) != 0, "obs checksum keeps the observation path live"


def test_env_rollout_deterministic_given_key():
    fn = jax.jit(R.make_env_rollout(5, 8))
    state, _ = batched_state()
    flat = R.state_to_flat(state)
    o1 = fn(*flat, jax.random.PRNGKey(3))
    o2 = fn(*flat, jax.random.PRNGKey(3))
    for a, b in zip(o1, o2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_train_iter_runs_and_updates():
    t_len, mb = 8, 4
    fn = R.make_train_iter(CFG, 5, t_len, B, mb)
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    state, obs = batched_state()
    flat = R.state_to_flat(state)
    args = (list(params) + m + v + [jnp.asarray(0, jnp.int32)]
            + list(flat)
            + [obs, jnp.zeros(B, jnp.int32), jnp.zeros(B),
               jnp.ones(B, jnp.int32),
               jnp.zeros((B, CFG.hidden_dim)), jax.random.PRNGKey(5),
               M.default_hp()])
    out = jax.jit(fn)(*args)
    np_ = M.NUM_PARAMS
    new_params = out[:np_]
    t_after = out[3 * np_]
    assert int(t_after) == B // mb, "one Adam step per minibatch"
    changed = any(
        not np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(new_params, params))
    assert changed, "training must update parameters"
    metrics = np.asarray(out[3 * np_ + 1 + 11 + 5])
    assert metrics.shape == (8,)
    assert np.all(np.isfinite(metrics))
    reward_sum = out[3 * np_ + 1 + 11 + 5 + 1]
    assert float(reward_sum) >= 0.0


def test_eval_rollout_accumulates():
    t_len = 12
    fn = R.make_eval_rollout(CFG, 5, t_len)
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    state, obs = batched_state()
    flat = R.state_to_flat(state)
    args = (list(params) + list(flat)
            + [obs, jnp.zeros(B, jnp.int32), jnp.zeros(B),
               jnp.ones(B, jnp.int32),
               jnp.zeros((B, CFG.hidden_dim)), jax.random.PRNGKey(9)])
    out = jax.jit(fn)(*args)
    acc_r, acc_g, acc_e = out[-3], out[-2], out[-1]
    assert acc_r.shape == (B,)
    assert np.all(np.asarray(acc_r) >= 0.0)
    assert np.all(np.asarray(acc_g) >= 0)
    assert np.all(np.asarray(acc_e) == 0), "12 steps < max_steps"


def test_state_specs_match_flat_state():
    specs = state_specs(H, W, MR, MI, batch=B)
    state, _ = batched_state()
    flat = R.state_to_flat(state)
    assert len(specs) == len(flat) == len(STATE_FIELDS)
    for spec, arr, (name, dtype) in zip(specs, flat, STATE_FIELDS):
        assert spec.shape == arr.shape, name
        assert spec.dtype == _DTYPES[dtype], name


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
