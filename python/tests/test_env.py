"""L2 environment semantics tests — the JAX twin of the Rust oracle's unit
suite (both implementations are additionally cross-validated transition-
for-transition in rust/tests/cross_validation.rs)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.xmg import env, types as T
from compile.xmg.goals import check_goal
from compile.xmg.grid import empty_room, place_objects
from compile.xmg.observation import observe
from compile.xmg.rules import check_rule, check_rules

jax.config.update("jax_platform_name", "cpu")


def cell(t, c):
    return jnp.array([t, c], dtype=jnp.int32)


def mk_state(h=9, w=9, rules=None, goal=None, init=None, max_steps=243,
             seed=0):
    base = empty_room(h, w)
    mr = 3
    r = jnp.zeros((mr, T.RULE_ENC), jnp.int32)
    for i, enc in enumerate(rules or []):
        r = r.at[i].set(jnp.array(enc, jnp.int32))
    g = jnp.array(goal or [0] * T.GOAL_ENC, jnp.int32)
    it = jnp.zeros((4, 2), jnp.int32)
    for i, obj in enumerate(init or []):
        it = it.at[i].set(jnp.array(obj, jnp.int32))
    state, obs = env.reset(base, r, g, it, max_steps,
                           jax.random.PRNGKey(seed))
    return state, obs


def put(state, r, c, tile, color):
    return state._replace(
        grid=state.grid.at[r, c].set(jnp.array([tile, color], jnp.int32)))


def teleport(state, pos, d):
    return state._replace(
        agent_pos=jnp.array(pos, jnp.int32),
        agent_dir=jnp.asarray(d, jnp.int32))


class TestActions:
    def test_forward_blocked_by_wall(self):
        s, _ = mk_state()
        s = teleport(s, (1, 1), 0)  # face up into wall
        out = env.step(s, jnp.asarray(T.ACTION_FORWARD))
        assert tuple(out.state.agent_pos.tolist()) == (1, 1)

    def test_forward_moves_on_floor(self):
        s, _ = mk_state()
        s = teleport(s, (1, 1), 2)  # face down
        out = env.step(s, jnp.asarray(T.ACTION_FORWARD))
        assert tuple(out.state.agent_pos.tolist()) == (2, 1)

    def test_turns(self):
        s, _ = mk_state()
        s = teleport(s, (4, 4), 0)
        out = env.step(s, jnp.asarray(T.ACTION_TURN_RIGHT))
        assert int(out.state.agent_dir) == 1
        out = env.step(out.state, jnp.asarray(T.ACTION_TURN_LEFT))
        out = env.step(out.state, jnp.asarray(T.ACTION_TURN_LEFT))
        assert int(out.state.agent_dir) == 3

    def test_pickup_putdown(self):
        s, _ = mk_state()
        s = teleport(s, (4, 4), 1)
        s = put(s, 4, 5, T.TILE_BALL, T.COLOR_RED)
        out = env.step(s, jnp.asarray(T.ACTION_PICK_UP))
        assert out.state.pocket.tolist() == [T.TILE_BALL, T.COLOR_RED]
        assert out.state.grid[4, 5].tolist() == list(T.FLOOR_CELL)
        # single-slot pocket
        s2 = put(out.state, 4, 5, T.TILE_KEY, T.COLOR_BLUE)
        out2 = env.step(s2, jnp.asarray(T.ACTION_PICK_UP))
        assert out2.state.pocket.tolist() == [T.TILE_BALL, T.COLOR_RED]
        # put down on floor
        s3 = teleport(out2.state, (4, 4), 2)
        out3 = env.step(s3, jnp.asarray(T.ACTION_PUT_DOWN))
        assert out3.state.pocket.tolist() == list(T.POCKET_EMPTY)
        assert out3.state.grid[5, 4].tolist() == [T.TILE_BALL, T.COLOR_RED]

    def test_toggle_door_with_key(self):
        s, _ = mk_state()
        s = teleport(s, (4, 4), 1)
        s = put(s, 4, 5, T.TILE_DOOR_LOCKED, T.COLOR_BLUE)
        out = env.step(s, jnp.asarray(T.ACTION_TOGGLE))
        assert int(out.state.grid[4, 5, 0]) == T.TILE_DOOR_LOCKED
        s2 = out.state._replace(
            pocket=jnp.array([T.TILE_KEY, T.COLOR_BLUE], jnp.int32))
        out2 = env.step(s2, jnp.asarray(T.ACTION_TOGGLE))
        assert int(out2.state.grid[4, 5, 0]) == T.TILE_DOOR_OPEN


class TestRules:
    def test_tile_near_rule_fires(self):
        g = empty_room(7, 7)
        g = g.at[3, 3].set(cell(T.TILE_BALL, T.COLOR_RED))
        g = g.at[3, 4].set(cell(T.TILE_SQUARE, T.COLOR_BLUE))
        rule = jnp.array([T.RULE_TILE_NEAR, T.TILE_BALL, T.COLOR_RED,
                          T.TILE_SQUARE, T.COLOR_BLUE, T.TILE_HEX,
                          T.COLOR_PINK], jnp.int32)
        pocket = jnp.array(T.POCKET_EMPTY, jnp.int32)
        g2, _ = check_rule(g, jnp.array([1, 1]), pocket, rule)
        assert g2[3, 3].tolist() == [T.TILE_HEX, T.COLOR_PINK]
        assert g2[3, 4].tolist() == list(T.FLOOR_CELL)

    def test_direction_priority_up_first(self):
        g = empty_room(7, 7)
        g = g.at[3, 3].set(cell(T.TILE_BALL, T.COLOR_RED))
        g = g.at[2, 3].set(cell(T.TILE_SQUARE, T.COLOR_BLUE))  # above
        g = g.at[3, 4].set(cell(T.TILE_SQUARE, T.COLOR_BLUE))  # right
        rule = jnp.array([T.RULE_TILE_NEAR, T.TILE_BALL, T.COLOR_RED,
                          T.TILE_SQUARE, T.COLOR_BLUE, T.TILE_HEX,
                          T.COLOR_PINK], jnp.int32)
        pocket = jnp.array(T.POCKET_EMPTY, jnp.int32)
        g2, _ = check_rule(g, jnp.array([1, 1]), pocket, rule)
        assert g2[2, 3].tolist() == list(T.FLOOR_CELL), "up consumed"
        assert g2[3, 4].tolist() == [T.TILE_SQUARE, T.COLOR_BLUE]

    def test_agent_hold_rule(self):
        g = empty_room(5, 5)
        rule = jnp.array([T.RULE_AGENT_HOLD, T.TILE_BALL, T.COLOR_RED,
                          0, 0, T.TILE_KEY, T.COLOR_YELLOW], jnp.int32)
        pocket = cell(T.TILE_BALL, T.COLOR_RED)
        _, p2 = check_rule(g, jnp.array([2, 2]), pocket, rule)
        assert p2.tolist() == [T.TILE_KEY, T.COLOR_YELLOW]

    def test_rules_chain_sequentially(self):
        g = empty_room(7, 7)
        g = g.at[3, 3].set(cell(T.TILE_BALL, T.COLOR_RED))
        g = g.at[3, 4].set(cell(T.TILE_SQUARE, T.COLOR_BLUE))
        g = g.at[2, 3].set(cell(T.TILE_PYRAMID, T.COLOR_GREEN))
        rules = jnp.array([
            [T.RULE_TILE_NEAR, T.TILE_BALL, T.COLOR_RED, T.TILE_SQUARE,
             T.COLOR_BLUE, T.TILE_STAR, T.COLOR_YELLOW],
            [T.RULE_TILE_NEAR, T.TILE_STAR, T.COLOR_YELLOW,
             T.TILE_PYRAMID, T.COLOR_GREEN, T.TILE_HEX, T.COLOR_PINK],
        ], jnp.int32)
        pocket = jnp.array(T.POCKET_EMPTY, jnp.int32)
        g2, _ = check_rules(g, jnp.array([5, 5]), pocket, rules)
        assert g2[3, 3].tolist() == [T.TILE_HEX, T.COLOR_PINK]


class TestGoals:
    def test_agent_near_goal(self):
        g = empty_room(5, 5)
        g = g.at[1, 2].set(cell(T.TILE_BALL, T.COLOR_RED))
        goal = jnp.array([T.GOAL_AGENT_NEAR, T.TILE_BALL, T.COLOR_RED, 0,
                          0], jnp.int32)
        pocket = jnp.array(T.POCKET_EMPTY, jnp.int32)
        assert bool(check_goal(g, jnp.array([2, 2]), pocket, goal))
        assert not bool(check_goal(g, jnp.array([3, 3]), pocket, goal))

    def test_tile_near_goal_symmetric(self):
        g = empty_room(6, 6)
        g = g.at[2, 2].set(cell(T.TILE_BALL, T.COLOR_RED))
        g = g.at[2, 3].set(cell(T.TILE_SQUARE, T.COLOR_BLUE))
        pocket = jnp.array(T.POCKET_EMPTY, jnp.int32)
        fwd = jnp.array([T.GOAL_TILE_NEAR, T.TILE_BALL, T.COLOR_RED,
                         T.TILE_SQUARE, T.COLOR_BLUE], jnp.int32)
        rev = jnp.array([T.GOAL_TILE_NEAR, T.TILE_SQUARE, T.COLOR_BLUE,
                         T.TILE_BALL, T.COLOR_RED], jnp.int32)
        assert bool(check_goal(g, jnp.array([4, 4]), pocket, fwd))
        assert bool(check_goal(g, jnp.array([4, 4]), pocket, rev))

    def test_empty_goal_false(self):
        g = empty_room(5, 5)
        pocket = jnp.array(T.POCKET_EMPTY, jnp.int32)
        goal = jnp.zeros(T.GOAL_ENC, jnp.int32)
        assert not bool(check_goal(g, jnp.array([2, 2]), pocket, goal))


class TestObservation:
    def test_rotation_consistency(self):
        g = empty_room(11, 11)
        for r, c in [(3, 5), (5, 7), (7, 5), (5, 3)]:
            g = g.at[r, c].set(cell(T.TILE_BALL, T.COLOR_RED))
        for d in range(4):
            obs = observe(g, jnp.array([5, 5]), jnp.asarray(d), 5, True)
            assert obs[2, 2].tolist() == [T.TILE_BALL, T.COLOR_RED]

    def test_out_of_map(self):
        g = empty_room(9, 9)
        obs = observe(g, jnp.array([1, 1]), jnp.asarray(0), 5, True)
        assert obs[0, 0].tolist() == [T.TILE_END_OF_MAP,
                                      T.COLOR_END_OF_MAP]

    def test_occlusion(self):
        g = empty_room(11, 11)
        wall = cell(T.TILE_WALL, T.COLOR_GREY)
        for c in range(11):
            g = g.at[4, c].set(wall)
        g = g.at[2, 5].set(cell(T.TILE_BALL, T.COLOR_RED))
        seen = observe(g, jnp.array([5, 5]), jnp.asarray(0), 5, True)
        hidden = observe(g, jnp.array([5, 5]), jnp.asarray(0), 5, False)
        assert seen[1, 2].tolist() == [T.TILE_BALL, T.COLOR_RED]
        assert hidden[1, 2].tolist() == [T.TILE_UNSEEN, T.COLOR_UNSEEN]
        assert hidden[3, 2].tolist() == [T.TILE_WALL, T.COLOR_GREY]


class TestEpisodeMechanics:
    def test_goal_gives_scaled_reward(self):
        goal = [T.GOAL_AGENT_NEAR, T.TILE_BALL, T.COLOR_RED, 0, 0]
        s, _ = mk_state(goal=goal, init=[(T.TILE_BALL, T.COLOR_RED)])
        s = teleport(s, (4, 4), 0)
        # clear any randomly placed ball, then place next to agent
        grid = jnp.where(
            (s.grid[..., 0] == T.TILE_BALL)[..., None],
            jnp.array(T.FLOOR_CELL, jnp.int32), s.grid)
        s = s._replace(grid=grid)
        s = put(s, 3, 4, T.TILE_BALL, T.COLOR_RED)
        out = env.step(s, jnp.asarray(T.ACTION_TURN_LEFT))
        assert bool(out.trial_done)
        expected = 1.0 - 0.9 * 1.0 / float(s.max_steps)
        np.testing.assert_allclose(out.reward, expected, rtol=1e-6)
        # trial reset: ball somewhere, pocket empty, step continues
        assert int((out.state.grid[..., 0] == T.TILE_BALL).sum()) == 1
        assert int(out.state.step_count) == 1

    def test_episode_auto_reset(self):
        s, _ = mk_state(init=[(T.TILE_BALL, T.COLOR_RED)], max_steps=3)
        for i in range(3):
            out = env.step(s, jnp.asarray(T.ACTION_TURN_LEFT))
            s = out.state
        assert bool(out.done)
        assert int(s.step_count) == 0
        assert int((s.grid[..., 0] == T.TILE_BALL).sum()) == 1

    def test_default_max_steps(self):
        assert env.default_max_steps(9, 9) == 243
        assert env.default_max_steps(13, 13) == 507


class TestPlacement:
    def test_objects_placed_once_on_floor(self):
        base = empty_room(9, 9)
        init = jnp.array([[T.TILE_BALL, T.COLOR_RED],
                          [T.TILE_KEY, T.COLOR_YELLOW],
                          [0, 0]], jnp.int32)
        for seed in range(10):
            grid, pos, d = place_objects(jax.random.PRNGKey(seed), base,
                                         init)
            assert int((grid[..., 0] == T.TILE_BALL).sum()) == 1
            assert int((grid[..., 0] == T.TILE_KEY).sum()) == 1
            assert int(grid[pos[0], pos[1], 0]) == T.TILE_FLOOR
            assert 0 <= int(d) < 4

    def test_placement_randomizes(self):
        base = empty_room(9, 9)
        init = jnp.array([[T.TILE_BALL, T.COLOR_RED]], jnp.int32)
        g1, p1, _ = place_objects(jax.random.PRNGKey(1), base, init)
        g2, p2, _ = place_objects(jax.random.PRNGKey(2), base, init)
        assert (not np.array_equal(np.asarray(g1), np.asarray(g2))
                or not np.array_equal(np.asarray(p1), np.asarray(p2)))


class TestVmap:
    def test_batched_step_and_reset(self):
        b = 4
        base = jnp.stack([empty_room(9, 9)] * b)
        rules = jnp.zeros((b, 3, T.RULE_ENC), jnp.int32)
        goal = jnp.zeros((b, T.GOAL_ENC), jnp.int32)
        init = jnp.tile(jnp.array([[[T.TILE_BALL, T.COLOR_RED]]],
                                  jnp.int32), (b, 1, 1))
        keys = jax.random.split(jax.random.PRNGKey(0), b)
        reset_b = jax.vmap(
            lambda bg, r, g, it, k: env.reset(bg, r, g, it, 243, k))
        state, obs = jit_once(reset_b)(base, rules, goal, init, keys)
        assert obs.shape == (b, 5, 5, 2)
        step_b = jax.vmap(lambda s, a: env.step(s, a))
        out = jit_once(step_b)(state,
                               jnp.zeros((b,), jnp.int32))
        assert out.obs.shape == (b, 5, 5, 2)
        assert out.reward.shape == (b,)


def jit_once(fn):
    return jax.jit(fn)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
