"""RGB rendering wrapper (App. H) tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.xmg import types as T
from compile.xmg.render import render_obs

jax.config.update("jax_platform_name", "cpu")


def test_render_shape_and_range():
    obs = jnp.zeros((5, 5, 2), jnp.int32).at[..., 0].set(T.TILE_FLOOR)
    img = render_obs(obs, patch=8)
    assert img.shape == (40, 40, 3)
    assert img.dtype == jnp.float32
    assert float(img.min()) >= 0.0 and float(img.max()) <= 1.0


def test_different_tiles_render_differently():
    base = jnp.zeros((5, 5, 2), jnp.int32).at[..., 0].set(T.TILE_FLOOR)
    ball = base.at[2, 2].set(
        jnp.array([T.TILE_BALL, T.COLOR_RED], jnp.int32))
    wall = base.at[2, 2].set(
        jnp.array([T.TILE_WALL, T.COLOR_GREY], jnp.int32))
    img_b = np.asarray(render_obs(ball))
    img_w = np.asarray(render_obs(wall))
    assert not np.array_equal(img_b, img_w)
    # the ball patch contains red pixels
    patch = img_b[16:24, 16:24]
    assert patch[..., 0].max() > 0.9
    assert patch[..., 1].max() < 0.5


def test_color_is_respected():
    base = jnp.zeros((5, 5, 2), jnp.int32).at[..., 0].set(T.TILE_FLOOR)
    red = base.at[1, 1].set(
        jnp.array([T.TILE_BALL, T.COLOR_RED], jnp.int32))
    blue = base.at[1, 1].set(
        jnp.array([T.TILE_BALL, T.COLOR_BLUE], jnp.int32))
    img_r = np.asarray(render_obs(red))[8:16, 8:16]
    img_b = np.asarray(render_obs(blue))[8:16, 8:16]
    assert img_r[..., 0].max() > img_r[..., 2].max()
    assert img_b[..., 2].max() > img_b[..., 0].max()


def test_render_is_jit_and_vmap_compatible():
    obs = jnp.zeros((3, 5, 5, 2), jnp.int32).at[..., 0].set(T.TILE_FLOOR)
    imgs = jax.jit(jax.vmap(lambda o: render_obs(o, patch=4)))(obs)
    assert imgs.shape == (3, 20, 20, 3)


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
