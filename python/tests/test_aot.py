"""AOT pipeline tests: HLO-text lowering and the manifest contract that the
Rust loader (runtime/manifest.rs) depends on."""

import os
import tempfile

import jax
import jax.numpy as jnp
import pytest

from compile import aot
from compile import model as M
from compile import rollout as R

jax.config.update("jax_platform_name", "cpu")


def test_to_hlo_text_emits_parseable_module():
    fn = lambda x, y: (x @ y + 1.0,)
    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = aot.to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert text.startswith("HloModule")
    assert "f32[2,2]" in text


def test_env_step_lowering_has_expected_signature():
    fn = aot.make_env_step(5)
    specs = aot.state_specs(9, 9, 3, 6, batch=4)
    specs.append(jax.ShapeDtypeStruct((4,), jnp.int32))
    out = jax.eval_shape(fn, *specs)
    flat = jax.tree_util.tree_leaves(out)
    # 11 state fields + obs + reward + done + trial_done
    assert len(flat) == 15
    assert flat[11].shape == (4, 5, 5, 2)
    assert flat[12].shape == (4,)


def test_manifest_writer_format():
    with tempfile.TemporaryDirectory() as d:
        mw = aot.ManifestWriter(d)
        fn = jax.vmap(lambda x: (x * 2.0,))
        mw.emit("double_b4", fn,
                [jax.ShapeDtypeStruct((4, 3), jnp.float32)],
                dict(kind="test", B=4))
        mw.save()
        text = open(os.path.join(d, "manifest.txt")).read()
        lines = text.strip().splitlines()
        assert lines[0] == "artifact double_b4 double_b4.hlo.txt"
        assert "meta kind test" in lines
        assert "meta B 4" in lines
        assert "in 0 f32 4,3" in lines
        assert "out 0 f32 4,3" in lines
        assert lines[-1] == "end"
        assert os.path.exists(os.path.join(d, "double_b4.hlo.txt"))


def test_quick_artifact_set_covers_all_kinds():
    # the quick set must exercise every artifact kind so rust integration
    # tests can run against it
    kinds = {"env_step", "env_reset", "env_rollout", "policy_step",
             "train_iter", "eval_rollout", "render_rgb"}
    assert len(aot.QUICK_STEP_VARIANTS) >= 1
    assert len(aot.QUICK_ROLLOUT_VARIANTS) >= 1
    assert len(aot.QUICK_TRAIN_VARIANTS) >= 1
    assert len(aot.QUICK_EVAL_VARIANTS) >= 1
    assert len(aot.QUICK_POLICY_BATCHES) >= 1
    assert len(aot.QUICK_RENDER_BATCHES) >= 1
    assert kinds  # documented contract


def test_full_variants_cover_paper_sweeps():
    # Fig 5a: batch sweep on one grid size
    fig5a = [v for v in aot.FULL_ROLLOUT_VARIANTS if v[0] == 13]
    assert any(len(v[4]) >= 5 for v in fig5a), "needs a wide batch sweep"
    # Fig 5b: at least 4 grid sizes
    sizes = {v[0] for v in aot.FULL_ROLLOUT_VARIANTS}
    assert len(sizes) >= 4
    # Fig 5c: rule sweep at 16x16
    rules16 = sorted(v[2] for v in aot.FULL_ROLLOUT_VARIANTS if v[0] == 16)
    assert rules16 == [1, 3, 6, 12, 24]
    # Fig 5f: training batch sweep
    train_b = sorted(v[4] for v in aot.FULL_TRAIN_VARIANTS if v[0] == 9)
    assert len(train_b) >= 3


def test_train_iter_io_arity():
    cfg = M.ModelConfig()
    t_len, b, mb = 4, 8, 4
    fn = R.make_train_iter(cfg, 5, t_len, b, mb)
    params = M.init_params(jax.random.PRNGKey(0), cfg)
    pspecs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params]
    sspecs = aot.state_specs(9, 9, 3, 6, batch=b)
    hd = cfg.hidden_dim
    rl2 = [
        jax.ShapeDtypeStruct((b, 5, 5, 2), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b,), jnp.float32),
        jax.ShapeDtypeStruct((b,), jnp.int32),
        jax.ShapeDtypeStruct((b, hd), jnp.float32),
    ]
    in_specs = (pspecs * 3 + [jax.ShapeDtypeStruct((), jnp.int32)]
                + sspecs + rl2
                + [jax.ShapeDtypeStruct((2,), jnp.uint32),
                   jax.ShapeDtypeStruct((M.HP_LEN,), jnp.float32)])
    out = jax.eval_shape(fn, *in_specs)
    flat = jax.tree_util.tree_leaves(out)
    # 33 learner tensors + t + 11 state + 5 carry + metrics + 3 stats
    assert len(flat) == 3 * M.NUM_PARAMS + 1 + 11 + 5 + 1 + 3


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
