"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes and dtypes; assert_allclose against ref — the CORE
correctness signal for the kernels that end up inside every policy/train
artifact.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.gru import fused_gru_cell
from compile.kernels.heads import fused_actor_critic_head
from compile.kernels.ref import actor_critic_head_ref, gru_cell_ref

jax.config.update("jax_platform_name", "cpu")


def _gru_inputs(key, b, i, h, dtype):
    ks = jax.random.split(key, 6)
    scale = 0.3
    return (
        jax.random.normal(ks[0], (b, i), dtype) * scale,
        jax.random.normal(ks[1], (b, h), dtype) * scale,
        jax.random.normal(ks[2], (i, 3 * h), dtype) * scale,
        jax.random.normal(ks[3], (h, 3 * h), dtype) * scale,
        jax.random.normal(ks[4], (3 * h,), dtype) * scale,
        jax.random.normal(ks[5], (3 * h,), dtype) * scale,
    )


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 3, 8, 17, 64]),
    i=st.sampled_from([1, 7, 32, 273]),
    h=st.sampled_from([4, 16, 256]),
    seed=st.integers(0, 2**31 - 1),
)
def test_gru_matches_ref_shapes(b, i, h, seed):
    args = _gru_inputs(jax.random.PRNGKey(seed), b, i, h, jnp.float32)
    out = fused_gru_cell(*args)
    ref = gru_cell_ref(*args)
    assert out.shape == (b, h)
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_gru_bf16(seed):
    args = _gru_inputs(jax.random.PRNGKey(seed), 8, 16, 32, jnp.bfloat16)
    out = fused_gru_cell(*args)
    ref = gru_cell_ref(*args)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32),
        rtol=2e-2, atol=2e-2)


def test_gru_output_bounded():
    # GRU output is a convex combination of tanh output and previous h
    args = _gru_inputs(jax.random.PRNGKey(0), 16, 8, 8, jnp.float32)
    x, h, wi, wh, bi, bh = args
    h = jnp.clip(h, -1.0, 1.0)
    out = fused_gru_cell(x, h, wi, wh, bi, bh)
    assert jnp.all(jnp.abs(out) <= 1.0 + 1e-6)


def test_gru_gradients_match_ref():
    args = _gru_inputs(jax.random.PRNGKey(3), 4, 6, 8, jnp.float32)

    def loss_kernel(*a):
        return jnp.sum(fused_gru_cell(*a) ** 2)

    def loss_ref(*a):
        return jnp.sum(gru_cell_ref(*a) ** 2)

    gk = jax.grad(loss_kernel, argnums=tuple(range(6)))(*args)
    gr = jax.grad(loss_ref, argnums=tuple(range(6)))(*args)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_gru_under_jit_and_scan():
    args = _gru_inputs(jax.random.PRNGKey(1), 8, 8, 16, jnp.float32)
    x, h, wi, wh, bi, bh = args

    @jax.jit
    def roll(h):
        def body(h, _):
            return fused_gru_cell(x, h, wi, wh, bi, bh), None
        h, _ = jax.lax.scan(body, h, None, length=5)
        return h

    out = roll(h)
    ref = h
    for _ in range(5):
        ref = gru_cell_ref(x, ref, wi, wh, bi, bh)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 5, 8, 64, 100]),
    h=st.sampled_from([4, 16, 256]),
    a=st.sampled_from([2, 6, 17]),
    seed=st.integers(0, 2**31 - 1),
)
def test_head_matches_ref(b, h, a, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    hid = jax.random.normal(ks[0], (b, h))
    w = jax.random.normal(ks[1], (h, a + 1)) * 0.1
    bias = jax.random.normal(ks[2], (a + 1,))
    logits, value = fused_actor_critic_head(hid, w, bias)
    rl, rv = actor_critic_head_ref(hid, w, bias)
    assert logits.shape == (b, a)
    assert value.shape == (b,)
    np.testing.assert_allclose(logits, rl, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(value, rv, rtol=1e-5, atol=1e-6)


def test_head_gradients_match_ref():
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    hid = jax.random.normal(ks[0], (4, 8))
    w = jax.random.normal(ks[1], (8, 7)) * 0.1
    bias = jax.random.normal(ks[2], (7,))

    def lk(h, w, b):
        lo, v = fused_actor_critic_head(h, w, b)
        return jnp.sum(lo ** 2) + jnp.sum(v ** 2)

    def lr(h, w, b):
        lo, v = actor_critic_head_ref(h, w, b)
        return jnp.sum(lo ** 2) + jnp.sum(v ** 2)

    gk = jax.grad(lk, argnums=(0, 1, 2))(hid, w, bias)
    gr = jax.grad(lr, argnums=(0, 1, 2))(hid, w, bias)
    for a, b in zip(gk, gr):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_kernels_lower_to_hlo_text():
    # the AOT path must accept the kernels (interpret=True lowering)
    from compile.aot import to_hlo_text

    spec = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    wspec = jax.ShapeDtypeStruct((16, 48), jnp.float32)
    bspec = jax.ShapeDtypeStruct((48,), jnp.float32)
    lowered = jax.jit(fused_gru_cell).lower(
        spec, spec.update(shape=(8, 16)), wspec,
        jax.ShapeDtypeStruct((16, 48), jnp.float32), bspec, bspec)
    text = to_hlo_text(lowered)
    assert "HloModule" in text


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
