"""Table 1-3 constants pinned to the paper, and python<->rust contract
checks (the Rust side pins the same values in env/types.rs)."""

import jax.numpy as jnp
import pytest

from compile.xmg import types as T


def test_tile_ids_match_table_1a():
    assert T.TILE_END_OF_MAP == 0
    assert T.TILE_UNSEEN == 1
    assert T.TILE_EMPTY == 2
    assert T.TILE_FLOOR == 3
    assert T.TILE_WALL == 4
    assert T.TILE_BALL == 5
    assert T.TILE_SQUARE == 6
    assert T.TILE_PYRAMID == 7
    assert T.TILE_GOAL == 8
    assert T.TILE_KEY == 9
    assert T.TILE_DOOR_LOCKED == 10
    assert T.TILE_DOOR_CLOSED == 11
    assert T.TILE_DOOR_OPEN == 12
    assert T.TILE_HEX == 13
    assert T.TILE_STAR == 14
    assert T.NUM_TILES == 15


def test_color_ids_match_table_1b():
    assert T.COLOR_RED == 3
    assert T.COLOR_GREEN == 4
    assert T.COLOR_BLUE == 5
    assert T.COLOR_PURPLE == 6
    assert T.COLOR_YELLOW == 7
    assert T.COLOR_GREY == 8
    assert T.COLOR_BLACK == 9
    assert T.COLOR_ORANGE == 10
    assert T.COLOR_WHITE == 11
    assert T.COLOR_BROWN == 12
    assert T.COLOR_PINK == 13
    assert T.NUM_COLORS == 14


def test_goal_ids_match_table_2():
    assert T.GOAL_EMPTY == 0
    assert T.GOAL_AGENT_HOLD == 1
    assert T.GOAL_AGENT_ON_TILE == 2
    assert T.GOAL_AGENT_NEAR == 3
    assert T.GOAL_TILE_NEAR == 4
    assert T.GOAL_AGENT_ON_POSITION == 5
    assert T.GOAL_TILE_ON_POSITION == 6
    assert T.GOAL_TILE_NEAR_UP == 7
    assert T.GOAL_AGENT_NEAR_LEFT == 14
    assert T.NUM_GOALS == 15


def test_rule_ids_match_table_3():
    assert T.RULE_EMPTY == 0
    assert T.RULE_AGENT_HOLD == 1
    assert T.RULE_AGENT_NEAR == 2
    assert T.RULE_TILE_NEAR == 3
    assert T.RULE_TILE_NEAR_UP == 4
    assert T.RULE_AGENT_NEAR_LEFT == 11
    assert T.NUM_RULES == 12


def test_generator_palettes():
    # App. J: 10 colors, 7 tile types => 70 unique objects
    assert len(T.GEN_COLORS) == 10
    assert len(T.GEN_TILES) == 7
    assert len(set(T.GEN_COLORS)) == 10
    assert len(set(T.GEN_TILES)) == 7


def test_predicates():
    assert bool(T.is_pickable(jnp.asarray(T.TILE_KEY)))
    assert not bool(T.is_pickable(jnp.asarray(T.TILE_WALL)))
    assert bool(T.is_walkable(jnp.asarray(T.TILE_DOOR_OPEN)))
    assert not bool(T.is_walkable(jnp.asarray(T.TILE_DOOR_LOCKED)))
    assert bool(T.blocks_sight(jnp.asarray(T.TILE_DOOR_CLOSED)))
    assert not bool(T.blocks_sight(jnp.asarray(T.TILE_FLOOR)))


def test_action_space():
    assert T.NUM_ACTIONS == 6
    assert T.ACTION_FORWARD == 0
    assert T.ACTION_TOGGLE == 5


def test_encoding_widths():
    assert T.RULE_ENC == 7
    assert T.GOAL_ENC == 5


if __name__ == "__main__":
    pytest.main([__file__, "-q"])
