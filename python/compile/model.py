"""L2 — the RL² recurrent-PPO baseline (paper §4.2) as pure-jnp functions.

Architecture (Table 6 lineage, CleanRL/PureJaxRL style, scaled for the CPU
testbed): symbolic obs -> (tile, color) embeddings -> MLP trunk -> RL² input
(trunk ⊕ prev-action embedding ⊕ prev-reward) -> GRU (Pallas kernel, L1) ->
fused actor-critic head (Pallas kernel, L1).

``train_update`` is the full PPO minibatch update — forward scan over the
rollout, GAE, clipped surrogate + value + entropy loss, global-norm clip,
Adam — lowered to a single HLO artifact. Hyperparameters arrive as a runtime
``hp[8]`` vector so the Rust coordinator can sweep them without recompiling:
``[lr, clip_eps, gamma, gae_lambda, ent_coef, vf_coef, max_grad_norm, pad]``.

Parameters cross the PJRT boundary as a flat list in PARAM_NAMES order.
"""

from typing import NamedTuple

import jax
import jax.numpy as jnp

from .kernels.gru import fused_gru_cell
from .kernels.heads import fused_actor_critic_head
from .xmg import types as T


class ModelConfig(NamedTuple):
    view_size: int = 5
    emb_dim: int = 8
    act_emb_dim: int = 16
    trunk_dim: int = 256
    hidden_dim: int = 256
    num_actions: int = T.NUM_ACTIONS


PARAM_NAMES = ("tile_emb", "col_emb", "act_emb", "w1", "b1",
               "wi", "wh", "bi", "bh", "whead", "bhead")
NUM_PARAMS = len(PARAM_NAMES)
HP_LEN = 8  # lr, clip_eps, gamma, gae_lambda, ent_coef, vf_coef, max_gn, pad


def rl2_input_dim(cfg: ModelConfig) -> int:
    return cfg.trunk_dim + cfg.act_emb_dim + 1


def init_params(key, cfg: ModelConfig):
    """Scaled-normal init; returns params in PARAM_NAMES order."""
    ks = jax.random.split(key, NUM_PARAMS)
    v, e = cfg.view_size, cfg.emb_dim
    d, h = cfg.trunk_dim, cfg.hidden_dim
    i = rl2_input_dim(cfg)
    a = cfg.num_actions

    def dense(k, fan_in, shape):
        return (jax.random.normal(k, shape) / jnp.sqrt(fan_in)
                ).astype(jnp.float32)

    return [
        dense(ks[0], e, (T.NUM_TILES, e)),
        dense(ks[1], e, (T.NUM_COLORS, e)),
        dense(ks[2], cfg.act_emb_dim, (a + 1, cfg.act_emb_dim)),
        dense(ks[3], v * v * 2 * e, (v * v * 2 * e, d)),
        jnp.zeros((d,), jnp.float32),
        dense(ks[5], i, (i, 3 * h)),
        dense(ks[6], h, (h, 3 * h)),
        jnp.zeros((3 * h,), jnp.float32),
        jnp.zeros((3 * h,), jnp.float32),
        dense(ks[9], h, (h, a + 1)) * 0.01,  # small policy/value head init
        jnp.zeros((a + 1,), jnp.float32),
    ]


def network_step(params, obs, prev_action, prev_reward, done, h,
                 cfg: ModelConfig):
    """One recurrent forward step over a batch.

    obs i32[B,V,V,2], prev_action i32[B], prev_reward f32[B], done i32[B]
    (episode boundary BEFORE this obs: resets hidden state and RL² inputs),
    h f32[B,H] -> (logits [B,A], value [B], h' [B,H]).
    """
    (tile_emb, col_emb, act_emb, w1, b1, wi, wh, bi, bh, whead,
     bhead) = params
    b = obs.shape[0]
    donef = done.astype(jnp.float32)[:, None]

    te = tile_emb[jnp.clip(obs[..., 0], 0, T.NUM_TILES - 1)]
    ce = col_emb[jnp.clip(obs[..., 1], 0, T.NUM_COLORS - 1)]
    flat = jnp.concatenate([te, ce], axis=-1).reshape(b, -1)
    trunk = jax.nn.relu(flat @ w1 + b1)

    # RL² conditioning; neutralized at episode starts
    pa = jnp.where(done > 0, cfg.num_actions,
                   jnp.clip(prev_action, 0, cfg.num_actions))
    ae = act_emb[pa]
    pr = (prev_reward * (1.0 - donef[:, 0]))[:, None]
    x = jnp.concatenate([trunk, ae, pr], axis=-1)

    h = h * (1.0 - donef)
    h_new = fused_gru_cell(x, h, wi, wh, bi, bh)
    logits, value = fused_actor_critic_head(h_new, whead, bhead)
    return logits, value, h_new


def goal_conditioning(params, ruleset_goal, rules, cfg: ModelConfig):
    """Fig. 11 (App. G) mechanism: pre-embed the goal and rule encodings and
    concatenate into a conditioning vector.

    Reuses the tile/color embedding tables so the parameter list stays in
    PARAM_NAMES order. ruleset_goal i32[B, 5], rules i32[B, MR, 7] ->
    f32[B, (1+NUM_GOALS') features]: goal id one-hot ⊕ goal object
    embeddings ⊕ mean rule-object embedding.
    """
    tile_emb, col_emb = params[0], params[1]
    e = cfg.emb_dim
    gid = jax.nn.one_hot(jnp.clip(ruleset_goal[:, 0], 0, T.NUM_GOALS - 1),
                         T.NUM_GOALS)
    a_t = tile_emb[jnp.clip(ruleset_goal[:, 1], 0, T.NUM_TILES - 1)]
    a_c = col_emb[jnp.clip(ruleset_goal[:, 2], 0, T.NUM_COLORS - 1)]
    b_t = tile_emb[jnp.clip(ruleset_goal[:, 3], 0, T.NUM_TILES - 1)]
    b_c = col_emb[jnp.clip(ruleset_goal[:, 4], 0, T.NUM_COLORS - 1)]
    rule_t = tile_emb[jnp.clip(rules[..., 1], 0, T.NUM_TILES - 1)]
    rule_c = col_emb[jnp.clip(rules[..., 2], 0, T.NUM_COLORS - 1)]
    rule_feat = jnp.concatenate([rule_t, rule_c], -1).mean(axis=1)
    out = jnp.concatenate([gid, a_t, a_c, b_t, b_c, rule_feat], -1)
    assert out.shape[-1] == T.NUM_GOALS + 6 * e
    return out


def policy_step(params, obs, prev_action, prev_reward, done, h, key,
                cfg: ModelConfig):
    """Forward + categorical sample. Returns (action, logp, value, h')."""
    logits, value, h_new = network_step(params, obs, prev_action,
                                        prev_reward, done, h, cfg)
    logp_all = jax.nn.log_softmax(logits)
    action = jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)
    logp = jnp.take_along_axis(logp_all, action[:, None], axis=1)[:, 0]
    return action, logp, value, h_new


def _forward_sequence(params, obs, prev_action, prev_reward, done, h0, cfg):
    """Scan network_step over time. obs [T,B,...]; returns logits [T,B,A],
    values [T,B]."""
    def body(h, xs):
        o, pa, pr, d = xs
        logits, value, h = network_step(params, o, pa, pr, d, h, cfg)
        return h, (logits, value)

    _, (logits, values) = jax.lax.scan(
        body, h0, (obs, prev_action, prev_reward, done))
    return logits, values


def gae(rewards, values, dones_after, last_value, gamma, lam):
    """Generalized advantage estimation over [T, B] arrays.

    ``dones_after[t]`` marks *episode* termination after step t (trial ends
    within an episode do NOT cut the value function — the RL² objective
    maximizes return across trials, §4.2).
    """
    def body(carry, xs):
        adv_next, v_next = carry
        r, v, d = xs
        nonterm = 1.0 - d
        delta = r + gamma * v_next * nonterm - v
        adv = delta + gamma * lam * nonterm * adv_next
        return (adv, v), adv

    (_, _), advs = jax.lax.scan(
        body, (jnp.zeros_like(last_value), last_value),
        (rewards, values, dones_after.astype(jnp.float32)), reverse=True)
    return advs


def ppo_loss(params, batch, hp, cfg: ModelConfig):
    (obs, prev_action, prev_reward, done_before, actions, old_logp,
     advantages, returns, h0) = batch
    clip_eps, ent_coef, vf_coef = hp[1], hp[4], hp[5]

    logits, values = _forward_sequence(params, obs, prev_action, prev_reward,
                                       done_before, h0, cfg)
    logp_all = jax.nn.log_softmax(logits)
    logp = jnp.take_along_axis(logp_all, actions[..., None], axis=-1)[..., 0]
    ratio = jnp.exp(logp - old_logp)

    adv = (advantages - advantages.mean()) / (advantages.std() + 1e-8)
    pg1 = ratio * adv
    pg2 = jnp.clip(ratio, 1.0 - clip_eps, 1.0 + clip_eps) * adv
    pi_loss = -jnp.minimum(pg1, pg2).mean()

    v_loss = 0.5 * jnp.square(values - returns).mean()
    entropy = -(jnp.exp(logp_all) * logp_all).sum(-1).mean()

    total = pi_loss + vf_coef * v_loss - ent_coef * entropy
    approx_kl = ((ratio - 1.0) - jnp.log(ratio)).mean()
    clip_frac = (jnp.abs(ratio - 1.0) > clip_eps).mean()
    return total, (pi_loss, v_loss, entropy, approx_kl, clip_frac)


def adam_update(params, grads, m, v, t, hp):
    lr, b1, b2, eps = hp[0], 0.9, 0.999, 1e-8
    t = t + 1
    tf = t.astype(jnp.float32)
    new_params, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * jnp.square(g)
        mhat = mi / (1 - b1 ** tf)
        vhat = vi / (1 - b2 ** tf)
        new_params.append(p - lr * mhat / (jnp.sqrt(vhat) + eps))
        new_m.append(mi)
        new_v.append(vi)
    return new_params, new_m, new_v, t


def global_norm_clip(grads, max_norm):
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads))
    scale = jnp.minimum(1.0, max_norm / (gn + 1e-8))
    return [g * scale for g in grads], gn


def train_update(params, m, v, t, rollout, hp, cfg: ModelConfig):
    """One PPO minibatch update; everything fused into a single HLO.

    rollout = (obs [T,B,V,V,2] i32, prev_action [T,B] i32, prev_reward
    [T,B] f32, done_before [T,B] i32, actions [T,B] i32, old_logp [T,B] f32,
    old_value [T,B] f32, reward [T,B] f32, done_after [T,B] i32,
    last_value [B] f32, h0 [B,H] f32).
    Returns (params, m, v, t, metrics[8]).
    """
    (obs, prev_action, prev_reward, done_before, actions, old_logp,
     old_value, reward, done_after, last_value, h0) = rollout
    gamma, lam, max_gn = hp[2], hp[3], hp[6]

    advantages = gae(reward, old_value, done_after, last_value, gamma, lam)
    returns = advantages + old_value

    batch = (obs, prev_action, prev_reward, done_before, actions, old_logp,
             advantages, returns, h0)
    (total, aux), grads = jax.value_and_grad(ppo_loss, has_aux=True)(
        params, batch, hp, cfg)
    pi_loss, v_loss, entropy, approx_kl, clip_frac = aux

    grads, grad_norm = global_norm_clip(grads, max_gn)
    params, m, v, t = adam_update(params, grads, m, v, t, hp)

    metrics = jnp.stack([total, pi_loss, v_loss, entropy, approx_kl,
                         clip_frac, grad_norm, advantages.std()])
    return params, m, v, t, metrics.astype(jnp.float32)


def default_hp():
    """Table 6 values (lr, clip_eps, gamma, gae_lambda, ent_coef, vf_coef,
    max_grad_norm, pad)."""
    return jnp.array([1e-3, 0.2, 0.99, 0.95, 0.01, 0.5, 0.5, 0.0],
                     dtype=jnp.float32)
