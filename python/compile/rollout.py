"""L2 — fused rollout graphs (the Anakin architecture, paper §2/§4.2).

The paper's throughput comes from jit-compiling *entire loops*, not single
steps (Listing 3 + PureJaxRL lineage). We lower three loop artifacts:

- ``env_rollout``: T random-policy steps over a batch of envs — the §4.1
  simulation-throughput workload (auto-reset enabled, obs forced via a
  checksum so XLA cannot dead-code the observation path).
- ``train_iter``: collect T steps with the RL² policy, then PPO updates
  over minibatch slices — one fused HLO per training iteration (Fig. 5f,
  Fig. 6/7/8 harness).
- ``eval_rollout``: policy rollout without learning, returning per-env
  return/trial counts for the 25-trials / 20th-percentile protocol.

The Rust coordinator feeds state in, gets state back, and swaps rulesets /
keys between calls; Python never runs at that point.
"""

import jax
import jax.numpy as jnp

from . import model as M
from .xmg import env


def batched_step(view_size):
    return jax.vmap(lambda s, a: env.step(s, a, view_size=view_size))


def state_from_flat(args):
    """Rebuild env.State from the 11 flat arrays (aot.STATE_FIELDS order)."""
    return env.State(*args)


def state_to_flat(s):
    return (s.base_grid, s.grid, s.agent_pos, s.agent_dir, s.pocket,
            s.rules, s.goal, s.init_tiles, s.step_count, s.key, s.max_steps)


def make_env_rollout(view_size, t_len):
    """Random-policy rollout: (state..., key) -> (state'..., reward_sum[B],
    done_sum[B], trial_sum[B], obs_checksum[])."""
    step = batched_step(view_size)

    def fn(*args):
        state = state_from_flat(args[:11])
        key = args[11]
        batch = state.agent_dir.shape[0]

        def body(carry, k):
            state, acc_r, acc_d, acc_t, chk = carry
            action = jax.random.randint(k, (batch,), 0, 6, dtype=jnp.int32)
            out = step(state, action)
            # checksum keeps the observation computation live under DCE —
            # the paper's rollouts materialize obs for the agent, ours must
            # pay the same cost even with a random policy
            chk = chk + jnp.sum(out.obs.astype(jnp.int32) % 7)
            return (out.state, acc_r + out.reward,
                    acc_d + out.done, acc_t + out.trial_done, chk), None

        keys = jax.random.split(key, t_len)
        zero_f = jnp.zeros((batch,), jnp.float32)
        zero_i = jnp.zeros((batch,), jnp.int32)
        (state, acc_r, acc_d, acc_t, chk), _ = jax.lax.scan(
            body, (state, zero_f, zero_i, zero_i,
                   jnp.asarray(0, jnp.int32)), keys)
        return state_to_flat(state) + (acc_r, acc_d, acc_t, chk)

    return fn


def _collect(params, cfg, step, state, obs, prev_a, prev_r, done_prev, h,
             key, t_len):
    """Scan the policy+env loop for t_len steps, recording the PPO rollout."""
    def body(carry, k):
        state, obs, prev_a, prev_r, done_prev, h = carry
        action, logp, value, h2 = M.policy_step(
            params, obs, prev_a, prev_r.astype(jnp.float32), done_prev, h,
            k, cfg)
        out = step(state, action)
        rec = (obs, prev_a, prev_r, done_prev, action, logp, value,
               out.reward, out.done)
        carry = (out.state, out.obs, action, out.reward, out.done, h2)
        return carry, rec

    keys = jax.random.split(key, t_len)
    carry, recs = jax.lax.scan(
        body, (state, obs, prev_a, prev_r, done_prev, h), keys)
    return carry, recs


def make_train_iter(cfg, view_size, t_len, batch, minibatch):
    """One full PPO iteration: collect T×B, then B/minibatch sequential
    minibatch updates (update_epochs=1, Table 6).

    Inputs:  params(NP), m(NP), v(NP), t,
             state(11, batched B), obs[B,V,V,2], prev_action[B],
             prev_reward[B], done_prev[B], h[B,H], key[2], hp[8]
    Outputs: params(NP), m(NP), v(NP), t,
             state(11), obs, prev_action, prev_reward, done_prev, h,
             metrics[8], reward_sum[], trials[], episodes[]
    """
    assert batch % minibatch == 0
    n_mb = batch // minibatch
    np_ = M.NUM_PARAMS
    step = batched_step(view_size)

    def fn(*args):
        params = list(args[:np_])
        m = list(args[np_:2 * np_])
        v = list(args[2 * np_:3 * np_])
        t = args[3 * np_]
        s = 3 * np_ + 1
        state = state_from_flat(args[s:s + 11])
        obs, prev_a, prev_r, done_prev, h = args[s + 11:s + 16]
        key, hp = args[s + 16], args[s + 17]

        k_collect, k_rest = jax.random.split(key)
        h0 = h  # hidden state at collection start, for minibatch replays
        carry, recs = _collect(params, cfg, step, state, obs, prev_a,
                               prev_r, done_prev, h, k_collect, t_len)
        (state, obs, prev_a, prev_r, done_prev, h) = carry
        (r_obs, r_pa, r_pr, r_db, r_act, r_logp, r_val, r_rew,
         r_da) = recs

        # bootstrap value for GAE from the post-rollout observation
        _, last_value, _ = M.network_step(
            params, obs, prev_a, prev_r.astype(jnp.float32), done_prev, h,
            cfg)

        def to_mb(x):  # [T, B, ...] -> [n_mb, T, MB, ...]
            return jnp.moveaxis(
                x.reshape(x.shape[0], n_mb, minibatch, *x.shape[2:]), 1, 0)

        mb_rolls = jax.tree_util.tree_map(
            to_mb, (r_obs, r_pa, r_pr.astype(jnp.float32), r_db, r_act,
                    r_logp, r_val, r_rew, r_da))
        mb_last_v = last_value.reshape(n_mb, minibatch)
        mb_h0 = h0.reshape(n_mb, minibatch, -1)

        def mb_body(carry, xs):
            params, m, v, t = carry
            rolls, lv, h0s = xs
            rollout = tuple(rolls) + (lv, h0s)
            params, m, v, t, metrics = M.train_update(
                list(params), list(m), list(v), t, rollout, hp, cfg)
            return (tuple(params), tuple(m), tuple(v), t), metrics

        (params, m, v, t), metrics = jax.lax.scan(
            mb_body, (tuple(params), tuple(m), tuple(v), t),
            (mb_rolls, mb_last_v, mb_h0))
        metrics = metrics.mean(axis=0)

        reward_sum = r_rew.sum()
        trials = (r_rew > 0).astype(jnp.int32).sum()
        episodes = r_da.sum()
        del k_rest
        return (tuple(params) + tuple(m) + tuple(v) + (t,)
                + state_to_flat(state)
                + (obs, prev_a, prev_r, done_prev, h, metrics,
                   reward_sum, trials, episodes))

    return fn


def make_eval_rollout(cfg, view_size, t_len):
    """Policy rollout without learning. Outputs per-env totals for the
    evaluation protocol of §4.2: return_sum[B], goals_reached[B] (trials
    solved), episodes_done[B], plus the carried RL² state so evaluation can
    span multiple calls."""
    np_ = M.NUM_PARAMS
    step = batched_step(view_size)

    def fn(*args):
        params = list(args[:np_])
        state = state_from_flat(args[np_:np_ + 11])
        obs, prev_a, prev_r, done_prev, h = args[np_ + 11:np_ + 16]
        key = args[np_ + 16]

        def body(carry, k):
            state, obs, prev_a, prev_r, done_prev, h, acc_r, acc_g, acc_e \
                = carry
            action, _, _, h2 = M.policy_step(
                params, obs, prev_a, prev_r.astype(jnp.float32), done_prev,
                h, k, cfg)
            out = step(state, action)
            acc_r = acc_r + out.reward
            acc_g = acc_g + (out.reward > 0).astype(jnp.int32)
            acc_e = acc_e + out.done
            carry = (out.state, out.obs, action, out.reward, out.done, h2,
                     acc_r, acc_g, acc_e)
            return carry, None

        batch = obs.shape[0]
        zf = jnp.zeros((batch,), jnp.float32)
        zi = jnp.zeros((batch,), jnp.int32)
        keys = jax.random.split(key, t_len)
        carry, _ = jax.lax.scan(
            body, (state, obs, prev_a, prev_r, done_prev, h, zf, zi, zi),
            keys)
        (state, obs, prev_a, prev_r, done_prev, h, acc_r, acc_g,
         acc_e) = carry
        return (state_to_flat(state)
                + (obs, prev_a, prev_r, done_prev, h, acc_r, acc_g, acc_e))

    return fn
