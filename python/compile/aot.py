"""AOT lowering: JAX/Pallas (L1+L2) -> HLO text artifacts + manifest.

Emits HLO *text*, not serialized HloModuleProto — the image's xla_extension
0.5.1 rejects jax>=0.5's 64-bit-instruction-id protos; the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (one executable per variant — shapes are static in XLA):
  env_step_g{H}x{W}_r{MR}_b{B}    batched environment step (+auto-reset)
  env_reset_g{H}x{W}_r{MR}_b{B}   batched episode reset
  policy_step_b{B}                RL² actor-critic forward + sampling
  train_update_t{T}_mb{B}         PPO minibatch update (fwd+bwd+GAE+Adam)
  render_rgb_b{B}                 symbolic obs -> RGB (Fig. 13 wrapper)

The manifest (artifacts/manifest.txt) is line-oriented so the Rust loader
needs no JSON dependency:

  artifact <name> <file>
  meta <key> <value>
  in <idx> <dtype> <comma-dims>
  out <idx> <dtype> <comma-dims>
  end

Run: ``cd python && python -m compile.aot --out-dir ../artifacts [--quick]``
Python never runs again after this: the Rust binary is self-contained.
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import rollout as R
from .xmg import env
from .xmg.render import render_obs

VIEW_SIZE = 5

# State field order across the PJRT boundary — mirrored by
# rust/src/runtime/state.rs. (name, dtype, per-env shape builder)
STATE_FIELDS = (
    ("base_grid", "i32"), ("grid", "i32"), ("agent_pos", "i32"),
    ("agent_dir", "i32"), ("pocket", "i32"), ("rules", "i32"),
    ("goal", "i32"), ("init_tiles", "i32"), ("step_count", "i32"),
    ("key", "u32"), ("max_steps", "i32"),
)

_DTYPES = {"i32": jnp.int32, "u32": jnp.uint32, "f32": jnp.float32}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def _spec(dtype, shape):
    return jax.ShapeDtypeStruct(shape, _DTYPES[dtype])


def state_specs(h, w, mr, mi, batch=None):
    """ShapeDtypeStructs for the state tuple, optionally batched."""
    per_env = {
        "base_grid": (h, w, 2), "grid": (h, w, 2), "agent_pos": (2,),
        "agent_dir": (), "pocket": (2,), "rules": (mr, 5 + 2),
        "goal": (5,), "init_tiles": (mi, 2), "step_count": (),
        "key": (2,), "max_steps": (),
    }
    specs = []
    for name, dtype in STATE_FIELDS:
        shape = per_env[name]
        if batch is not None:
            shape = (batch,) + shape
        specs.append(_spec(dtype, shape))
    return specs


def make_env_step(view_size):
    def step_flat(base_grid, grid, agent_pos, agent_dir, pocket, rules,
                  goal, init_tiles, step_count, key, max_steps, action):
        state = env.State(base_grid, grid, agent_pos, agent_dir, pocket,
                          rules, goal, init_tiles, step_count, key,
                          max_steps)
        out = env.step(state, action, view_size=view_size)
        s = out.state
        return (s.base_grid, s.grid, s.agent_pos, s.agent_dir, s.pocket,
                s.rules, s.goal, s.init_tiles, s.step_count, s.key,
                s.max_steps, out.obs, out.reward, out.done, out.trial_done)
    return jax.vmap(step_flat)


def make_env_reset(view_size):
    def reset_flat(key, base_grid, rules, goal, init_tiles, max_steps):
        state, obs = env.reset(base_grid, rules, goal, init_tiles,
                               max_steps, key, view_size=view_size)
        return (state.base_grid, state.grid, state.agent_pos,
                state.agent_dir, state.pocket, state.rules, state.goal,
                state.init_tiles, state.step_count, state.key,
                state.max_steps, obs)
    return jax.vmap(reset_flat)


def make_policy_step(cfg):
    def fn(*args):
        params = list(args[:M.NUM_PARAMS])
        obs, prev_action, prev_reward, done, h, key = args[M.NUM_PARAMS:]
        return M.policy_step(params, obs, prev_action, prev_reward, done,
                             h, key, cfg)
    return fn


def make_train_update(cfg):
    np_ = M.NUM_PARAMS

    def fn(*args):
        params = list(args[:np_])
        m = list(args[np_:2 * np_])
        v = list(args[2 * np_:3 * np_])
        t = args[3 * np_]
        rollout = args[3 * np_ + 1:3 * np_ + 12]
        hp = args[3 * np_ + 12]
        params, m, v, t, metrics = M.train_update(params, m, v, t, rollout,
                                                  hp, cfg)
        return tuple(params) + tuple(m) + tuple(v) + (t, metrics)
    return fn


def _dtype_name(dt):
    return {"int32": "i32", "uint32": "u32", "float32": "f32",
            "bool": "i32"}[str(dt)]


class ManifestWriter:
    def __init__(self, out_dir):
        self.out_dir = out_dir
        self.lines = []

    def emit(self, name, fn, in_specs, meta):
        """Lower fn at in_specs, write HLO text, append manifest entry."""
        lowered = jax.jit(fn).lower(*in_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(self.out_dir, fname), "w") as f:
            f.write(text)
        out_specs = jax.eval_shape(fn, *in_specs)
        out_flat = jax.tree_util.tree_leaves(out_specs)
        self.lines.append(f"artifact {name} {fname}")
        for k, val in meta.items():
            self.lines.append(f"meta {k} {val}")
        for i, s in enumerate(in_specs):
            dims = ",".join(str(d) for d in s.shape)
            self.lines.append(f"in {i} {_dtype_name(s.dtype)} {dims}")
        for i, s in enumerate(out_flat):
            dims = ",".join(str(d) for d in s.shape)
            self.lines.append(f"out {i} {_dtype_name(s.dtype)} {dims}")
        self.lines.append("end")
        print(f"  lowered {name} ({len(text) / 1024:.0f} KiB)")

    def save(self):
        path = os.path.join(self.out_dir, "manifest.txt")
        with open(path, "w") as f:
            f.write("\n".join(self.lines) + "\n")
        print(f"wrote {path}")


# --- variant tables ---------------------------------------------------------
# Single-step env artifacts: used for Rust<->JAX cross-validation and as the
# per-step-dispatch baseline in §Perf. (H, W, MR, MI, batches)
FULL_STEP_VARIANTS = [
    (9, 9, 3, 6, [8]),
    (13, 13, 9, 12, [8]),
]
# Fused random-policy rollouts (T steps per call): the §4.1 workload.
# (H, W, MR, MI, batches, T)
FULL_ROLLOUT_VARIANTS = [
    # Fig 5a: throughput vs parallel envs (13x13, the paper's mid size)
    (13, 13, 9, 12, [1, 16, 256, 1024, 4096, 8192], 256),
    # Fig 5b: grid-size sweep at fixed batches
    (9, 9, 9, 6, [1024, 4096], 256),
    (17, 17, 9, 12, [1024, 4096], 256),
    (25, 25, 9, 16, [1024, 4096], 256),
    # Fig 5c: rule-count sweep at 16x16 (paper's setup)
    (16, 16, 1, 12, [1024], 256),
    (16, 16, 3, 12, [1024], 256),
    (16, 16, 6, 12, [1024], 256),
    (16, 16, 12, 12, [1024], 256),
    (16, 16, 24, 12, [1024], 256),
]
# Training iterations (Anakin): (H, W, MR, MI, B, T, MB)
FULL_TRAIN_VARIANTS = [
    # Fig 5f: training-throughput sweep on 9x9 / trivial
    (9, 9, 3, 6, 64, 32, 16),
    (9, 9, 3, 6, 256, 32, 64),
    (9, 9, 3, 6, 1024, 32, 256),
    # Fig 6/7/8: training on 13x13 R4
    (13, 13, 9, 12, 256, 64, 64),
]
# Evaluation rollouts: (H, W, MR, MI, B, T)
FULL_EVAL_VARIANTS = [
    (9, 9, 3, 6, 256, 128),
    (13, 13, 9, 12, 256, 256),
]
FULL_POLICY_BATCHES = [256]
FULL_RENDER_BATCHES = [256, 1024]

QUICK_STEP_VARIANTS = [(9, 9, 3, 6, [8])]
QUICK_ROLLOUT_VARIANTS = [(9, 9, 3, 6, [8], 8)]
QUICK_TRAIN_VARIANTS = [(9, 9, 3, 6, 8, 8, 4)]
QUICK_EVAL_VARIANTS = [(9, 9, 3, 6, 8, 8)]
QUICK_POLICY_BATCHES = [8]
QUICK_RENDER_BATCHES = [8]


def main():
    parser = argparse.ArgumentParser()
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--quick", action="store_true",
                        help="small variants only (CI / pytest)")
    args = parser.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    cfg = M.ModelConfig(view_size=VIEW_SIZE)
    mw = ManifestWriter(args.out_dir)

    def emit_reset(h, w, mr, mi, b):
        name = f"env_reset_g{h}x{w}_r{mr}_b{b}"
        if any(line.endswith(f" {name}.hlo.txt") for line in mw.lines):
            return
        reset_in = [
            _spec("u32", (b, 2)), _spec("i32", (b, h, w, 2)),
            _spec("i32", (b, mr, 7)), _spec("i32", (b, 5)),
            _spec("i32", (b, mi, 2)), _spec("i32", (b,)),
        ]
        mw.emit(name, make_env_reset(VIEW_SIZE), reset_in,
                dict(kind="env_reset", H=h, W=w, V=VIEW_SIZE, MR=mr, MI=mi,
                     B=b))

    # --- single-step env artifacts (cross-validation + dispatch baseline)
    step_variants = QUICK_STEP_VARIANTS if args.quick else FULL_STEP_VARIANTS
    for h, w, mr, mi, batches in step_variants:
        for b in batches:
            sspecs = state_specs(h, w, mr, mi, batch=b)
            mw.emit(f"env_step_g{h}x{w}_r{mr}_b{b}", make_env_step(VIEW_SIZE),
                    sspecs + [_spec("i32", (b,))],
                    dict(kind="env_step", H=h, W=w, V=VIEW_SIZE, MR=mr,
                         MI=mi, B=b))
            emit_reset(h, w, mr, mi, b)

    # --- fused random-policy rollouts (Fig 5a-e workload) ------------------
    roll_variants = (QUICK_ROLLOUT_VARIANTS if args.quick
                     else FULL_ROLLOUT_VARIANTS)
    for h, w, mr, mi, batches, t_len in roll_variants:
        for b in batches:
            sspecs = state_specs(h, w, mr, mi, batch=b)
            mw.emit(f"env_rollout_g{h}x{w}_r{mr}_b{b}_t{t_len}",
                    R.make_env_rollout(VIEW_SIZE, t_len),
                    sspecs + [_spec("u32", (2,))],
                    dict(kind="env_rollout", H=h, W=w, V=VIEW_SIZE, MR=mr,
                         MI=mi, B=b, T=t_len))
            emit_reset(h, w, mr, mi, b)

    # --- policy / training / eval artifacts --------------------------------
    key = jax.random.PRNGKey(0)
    params = M.init_params(key, cfg)
    param_specs = [jax.ShapeDtypeStruct(p.shape, p.dtype) for p in params]
    v, hd = cfg.view_size, cfg.hidden_dim

    def rl2_carry_specs(b):
        return [
            _spec("i32", (b, v, v, 2)),  # obs
            _spec("i32", (b,)),          # prev_action
            _spec("f32", (b,)),          # prev_reward
            _spec("i32", (b,)),          # done_prev
            _spec("f32", (b, hd)),       # h
        ]

    pol_batches = (QUICK_POLICY_BATCHES if args.quick
                   else FULL_POLICY_BATCHES)
    for b in pol_batches:
        in_specs = param_specs + rl2_carry_specs(b) + [_spec("u32", (2,))]
        mw.emit(f"policy_step_b{b}", make_policy_step(cfg), in_specs,
                dict(kind="policy_step", B=b, V=v, H_DIM=hd,
                     NP=M.NUM_PARAMS))

    train_variants = (QUICK_TRAIN_VARIANTS if args.quick
                      else FULL_TRAIN_VARIANTS)
    for h, w, mr, mi, b, t_len, mb in train_variants:
        sspecs = state_specs(h, w, mr, mi, batch=b)
        in_specs = (param_specs * 3 + [_spec("i32", ())] + sspecs
                    + rl2_carry_specs(b)
                    + [_spec("u32", (2,)), _spec("f32", (M.HP_LEN,))])
        mw.emit(
            f"train_iter_g{h}x{w}_r{mr}_b{b}_t{t_len}_mb{mb}",
            R.make_train_iter(cfg, VIEW_SIZE, t_len, b, mb), in_specs,
            dict(kind="train_iter", H=h, W=w, V=v, MR=mr, MI=mi, B=b,
                 T=t_len, MB=mb, H_DIM=hd, NP=M.NUM_PARAMS,
                 HP_LEN=M.HP_LEN))
        emit_reset(h, w, mr, mi, b)

    eval_variants = (QUICK_EVAL_VARIANTS if args.quick
                     else FULL_EVAL_VARIANTS)
    for h, w, mr, mi, b, t_len in eval_variants:
        sspecs = state_specs(h, w, mr, mi, batch=b)
        in_specs = (param_specs + sspecs + rl2_carry_specs(b)
                    + [_spec("u32", (2,))])
        mw.emit(f"eval_rollout_g{h}x{w}_r{mr}_b{b}_t{t_len}",
                R.make_eval_rollout(cfg, VIEW_SIZE, t_len), in_specs,
                dict(kind="eval_rollout", H=h, W=w, V=v, MR=mr, MI=mi,
                     B=b, T=t_len, H_DIM=hd, NP=M.NUM_PARAMS))
        emit_reset(h, w, mr, mi, b)

    # --- image-observation wrapper (Fig. 13) -------------------------------
    render_batches = (QUICK_RENDER_BATCHES if args.quick
                      else FULL_RENDER_BATCHES)
    for b in render_batches:
        fn = jax.vmap(lambda o: render_obs(o, patch=8))
        mw.emit(f"render_rgb_b{b}", fn, [_spec("i32", (b, v, v, 2))],
                dict(kind="render_rgb", B=b, V=v, P=8))

    # persist model init values so rust can bootstrap training
    params_path = os.path.join(args.out_dir, "params_init.bin")
    with open(params_path, "wb") as f:
        for p in params:
            f.write(bytes(jnp.asarray(p, jnp.float32).tobytes()))
    shapes = ";".join(
        f"{n}:{','.join(str(d) for d in p.shape)}"
        for n, p in zip(M.PARAM_NAMES, params))
    mw.lines.insert(0, f"paramshapes {shapes}")
    mw.save()


if __name__ == "__main__":
    main()
