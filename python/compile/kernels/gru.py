"""Fused GRU cell as a Pallas kernel (L1).

The RL² baseline's recurrent hot spot: three gate matmuls against the input
and three against the hidden state, plus gating, fused into one kernel so
gate activations never round-trip to HBM between matmuls.

TPU mapping (docs/ARCHITECTURE.md, "Pallas kernels"): the grid tiles the batch; each program holds
an x-tile (bB×I), the full weight panels (I×3H, H×3H — MXU-aligned when H is
a multiple of 128) and the h-tile in VMEM, issues the six MXU matmuls
back-to-back, applies the sigmoid/tanh gating in-register and writes one
bB×H output tile. The GPU analogue in the paper's lineage would be a
threadblock-per-batch-tile persistent kernel; on TPU the HBM↔VMEM schedule
is expressed with BlockSpec index maps instead.

``interpret=True`` always: CPU PJRT cannot execute Mosaic custom-calls.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gru_kernel(x_ref, h_ref, wi_ref, wh_ref, bi_ref, bh_ref, out_ref,
                *, hidden):
    x = x_ref[...]
    h = h_ref[...]
    gi = x @ wi_ref[...] + bi_ref[...]
    gh = h @ wh_ref[...] + bh_ref[...]
    i_r, i_z, i_n = (gi[:, :hidden], gi[:, hidden:2 * hidden],
                     gi[:, 2 * hidden:])
    h_r, h_z, h_n = (gh[:, :hidden], gh[:, hidden:2 * hidden],
                     gh[:, 2 * hidden:])
    r = jax.nn.sigmoid(i_r + h_r)
    z = jax.nn.sigmoid(i_z + h_z)
    n = jnp.tanh(i_n + r * h_n)
    out_ref[...] = (1.0 - z) * n + z * h


def _gru_pallas(x, h, wi, wh, bi, bh, block_b=64):
    b, _ = x.shape
    hidden = h.shape[-1]
    bb = min(block_b, b)
    while b % bb != 0:  # batch tile must divide B (batches are powers of 2)
        bb //= 2
    grid = (b // bb,)
    kernel = functools.partial(_gru_kernel, hidden=hidden)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bb, x.shape[1]), lambda i: (i, 0)),
            pl.BlockSpec((bb, hidden), lambda i: (i, 0)),
            pl.BlockSpec((wi.shape[0], 3 * hidden), lambda i: (0, 0)),
            pl.BlockSpec((hidden, 3 * hidden), lambda i: (0, 0)),
            pl.BlockSpec((3 * hidden,), lambda i: (0,)),
            pl.BlockSpec((3 * hidden,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, hidden), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hidden), x.dtype),
        interpret=True,
    )(x, h, wi, wh, bi, bh)


# Reverse-mode AD cannot flow through a pallas_call; the backward pass uses
# the analytic gradient of the reference computation (same math, pure jnp),
# which XLA fuses into the same train_update HLO.
@jax.custom_vjp
def fused_gru_cell(x, h, wi, wh, bi, bh):
    """h' = GRU(x, h). Shapes: x [B, I], h [B, H], wi [I, 3H], wh [H, 3H],
    bi/bh [3H] -> [B, H]."""
    return _gru_pallas(x, h, wi, wh, bi, bh)


def _gru_fwd(x, h, wi, wh, bi, bh):
    return _gru_pallas(x, h, wi, wh, bi, bh), (x, h, wi, wh, bi, bh)


def _gru_bwd(res, g):
    from .ref import gru_cell_ref
    _, vjp = jax.vjp(gru_cell_ref, *res)
    return vjp(g)


fused_gru_cell.defvjp(_gru_fwd, _gru_bwd)
