"""Fused actor-critic head as a Pallas kernel (L1).

Policy logits and value share the GRU output tile: one [H, A+1] weight panel
(last column = value head) means the hidden-state tile is read from VMEM
once for both heads instead of twice — the fusion the paper's baselines get
implicitly from XLA, made explicit here.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _head_kernel(h_ref, w_ref, b_ref, out_ref):
    out_ref[...] = h_ref[...] @ w_ref[...] + b_ref[...]


def _head_pallas(h, w, b, block_b=128):
    batch, hidden = h.shape
    na1 = w.shape[1]
    bb = min(block_b, batch)
    while batch % bb != 0:
        bb //= 2
    out = pl.pallas_call(
        _head_kernel,
        grid=(batch // bb,),
        in_specs=[
            pl.BlockSpec((bb, hidden), lambda i: (i, 0)),
            pl.BlockSpec((hidden, na1), lambda i: (0, 0)),
            pl.BlockSpec((na1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bb, na1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, na1), h.dtype),
        interpret=True,
    )(h, w, b)
    return out[:, :-1], out[:, -1]


# custom_vjp: Pallas forward, analytic (ref-math) backward — see gru.py.
@jax.custom_vjp
def fused_actor_critic_head(h, w, b):
    """(logits [B, A], value [B]) = h @ w + b with w [H, A+1]."""
    return _head_pallas(h, w, b)


def _head_fwd(h, w, b):
    return _head_pallas(h, w, b), (h, w, b)


def _head_bwd(res, g):
    from .ref import actor_critic_head_ref
    _, vjp = jax.vjp(actor_critic_head_ref, *res)
    return vjp(g)


fused_actor_critic_head.defvjp(_head_fwd, _head_bwd)
