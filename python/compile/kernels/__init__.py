"""L1 Pallas kernels: the baseline network's hot spots.

Lowered with ``interpret=True`` so the resulting HLO runs on the CPU PJRT
plugin (real-TPU lowering emits Mosaic custom-calls the CPU client cannot
execute). Correctness is pinned against ``ref.py`` by
``python/tests/test_kernels.py`` (hypothesis shape/dtype sweeps).
"""

from .gru import fused_gru_cell  # noqa: F401
from .heads import fused_actor_critic_head  # noqa: F401
from . import ref  # noqa: F401
