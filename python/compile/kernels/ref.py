"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

The Pallas kernels in ``gru.py`` / ``heads.py`` must reproduce these
reference computations to float tolerance for every shape/dtype the
hypothesis sweep in ``python/tests/test_kernels.py`` generates.
"""

import jax.numpy as jnp


def gru_cell_ref(x, h, wi, wh, bi, bh):
    """Standard GRU cell (r, z, n gate layout along the 3H axis).

    x: [B, I], h: [B, H], wi: [I, 3H], wh: [H, 3H], bi/bh: [3H].
    Returns h': [B, H].
    """
    hidden = h.shape[-1]
    gi = x @ wi + bi
    gh = h @ wh + bh
    i_r, i_z, i_n = (gi[..., :hidden], gi[..., hidden:2 * hidden],
                     gi[..., 2 * hidden:])
    h_r, h_z, h_n = (gh[..., :hidden], gh[..., hidden:2 * hidden],
                     gh[..., 2 * hidden:])
    r = jnp.reciprocal(1.0 + jnp.exp(-(i_r + h_r)))
    z = jnp.reciprocal(1.0 + jnp.exp(-(i_z + h_z)))
    n = jnp.tanh(i_n + r * h_n)
    return (1.0 - z) * n + z * h


def actor_critic_head_ref(h, w, b):
    """Fused policy/value projection.

    h: [B, H], w: [H, A+1], b: [A+1]. Returns (logits [B, A], value [B]).
    """
    out = h @ w + b
    return out[..., :-1], out[..., -1]
