"""Grid helpers: masks, neighbor shifts, random placement.

A grid is an ``int32[H, W, 2]`` array; ``grid[..., 0]`` is the tile id and
``grid[..., 1]`` the color id (paper §2.2). The agent is *not* part of the
grid — it lives in separate state fields.
"""

import jax
import jax.numpy as jnp

from . import types as T


def object_mask(grid, tile, color):
    """Boolean [H, W] mask of cells equal to object (tile, color)."""
    return (grid[..., 0] == tile) & (grid[..., 1] == color)


def shift_mask(mask, direction):
    """shift_mask(m, d)[r, c] == m[r - dr, c - dc]: the mask moved one cell
    *in* direction d (0=up,1=right,2=down,3=left), zero-filled at borders.

    With ``A & shift_mask(B, DIR_DOWN)`` a cell holds ``a`` with ``b``
    directly above it (b moved down lands on a).
    """
    if direction == T.DIR_UP:
        return jnp.pad(mask[1:, :], ((0, 1), (0, 0)))
    if direction == T.DIR_RIGHT:
        return jnp.pad(mask[:, :-1], ((0, 0), (1, 0)))
    if direction == T.DIR_DOWN:
        return jnp.pad(mask[:-1, :], ((1, 0), (0, 0)))
    if direction == T.DIR_LEFT:
        return jnp.pad(mask[:, 1:], ((0, 0), (0, 1)))
    raise ValueError(direction)


def first_true_flat(flags):
    """Index of the first True in flattened ``flags`` (0 if none) and whether
    any is True. Deterministic tie-break = row-major order, mirrored by the
    Rust oracle."""
    flat = flags.reshape(-1)
    any_ = jnp.any(flat)
    idx = jnp.argmax(flat)  # first max = first True
    return idx, any_


def neighbor_cell(grid, pos, direction):
    """(tile, color) of the neighbor of ``pos`` in ``direction``; END_OF_MAP
    outside the grid."""
    h, w = grid.shape[0], grid.shape[1]
    r = pos[0] + T.DIR_DR[direction]
    c = pos[1] + T.DIR_DC[direction]
    inside = (r >= 0) & (r < h) & (c >= 0) & (c < w)
    rc = jnp.clip(r, 0, h - 1)
    cc = jnp.clip(c, 0, w - 1)
    cell = grid[rc, cc]
    off = jnp.array([T.TILE_END_OF_MAP, T.COLOR_END_OF_MAP], dtype=jnp.int32)
    return jnp.where(inside, cell, off), (r, c), inside


def place_objects(key, base_grid, init_tiles):
    """Place ``init_tiles`` (padded with tile==0 rows) and the agent on
    uniformly random distinct FLOOR cells of ``base_grid``.

    Returns (grid, agent_pos[2] i32, agent_dir i32). Padded object rows write
    a FLOOR_CELL onto a floor cell (a no-op), keeping the computation
    branch-free — the trick that makes trial auto-reset inside ``step``
    jit/vmap friendly (paper §2.2 auto-reset wrapper, App. C on branching).
    """
    h, w = base_grid.shape[0], base_grid.shape[1]
    mi = init_tiles.shape[0]
    k_pos, k_dir = jax.random.split(key)

    free = base_grid[..., 0] == T.TILE_FLOOR
    scores = jax.random.uniform(k_pos, (h, w))
    scores = jnp.where(free, scores, -1.0)  # non-free cells sort last
    # §Perf: unrolled argmax top-(MI+1) instead of a full argsort —
    # placement runs on every step (branch-free trial auto-reset), so it is
    # on the hot path; the distribution is identical (first k of a uniform
    # random order). Written with plain reduce ops because xla_extension
    # 0.5.1's HLO parser rejects lax.top_k's `largest` attribute.
    flat_scores = scores.reshape(-1)
    picks = []
    for _ in range(mi + 1):
        i = jnp.argmax(flat_scores)
        picks.append(i)
        flat_scores = flat_scores.at[i].set(-2.0)
    order = jnp.stack(picks)

    valid = (init_tiles[:, 0] > 0)[:, None]
    floor = jnp.array(T.FLOOR_CELL, dtype=jnp.int32)
    vals = jnp.where(valid, init_tiles, floor[None, :]).astype(jnp.int32)

    flat = base_grid.reshape(h * w, 2)
    flat = flat.at[order[:mi]].set(vals)
    grid = flat.reshape(h, w, 2)

    agent_flat = order[mi]
    agent_pos = jnp.stack([agent_flat // w, agent_flat % w]).astype(jnp.int32)
    agent_dir = jax.random.randint(k_dir, (), 0, 4, dtype=jnp.int32)
    return grid, agent_pos, agent_dir


def empty_room(h, w):
    """Base grid for a single room: WALL border, FLOOR interior (numpy-side
    helper used by python tests; the Rust layout library is authoritative
    for registered environments)."""
    grid = jnp.zeros((h, w, 2), dtype=jnp.int32)
    grid = grid.at[..., 0].set(T.TILE_FLOOR)
    grid = grid.at[..., 1].set(T.COLOR_BLACK)
    wall = jnp.array(T.WALL_CELL, dtype=jnp.int32)
    grid = grid.at[0, :].set(wall)
    grid = grid.at[h - 1, :].set(wall)
    grid = grid.at[:, 0].set(wall)
    grid = grid.at[:, w - 1].set(wall)
    return grid
