"""xmg — XLand-MiniGrid environment semantics in JAX (build-time, L2).

This package implements the paper's grid-world engine: tiles/colors
(Table 1), the rules & goals system (Tables 2-3), partial egocentric
observations, trial auto-reset, and the reset/step functions that get
vmapped and AOT-lowered to HLO by ``compile/aot.py``.

Nothing here runs at serving/training time — the Rust coordinator executes
the lowered artifacts through PJRT.
"""

from . import types, grid, rules, goals, observation, env, render  # noqa: F401
