"""Production rules (paper Table 3, §2.1).

Rules are *data*: ``int32[RULE_ENC]`` arrays ``[id, a_tile, a_col, b_tile,
b_col, c_tile, c_col]``. Dispatch is a single ``jax.lax.switch`` over the 12
rule functions, exactly the structure the paper describes for
``xminigrid.core.rules.check_rule`` (App. I). Because the encodings are
runtime inputs, one compiled executable serves arbitrarily many tasks.

Disappearance is encoded by producing ``(TILE_FLOOR, COLOR_BLACK)`` (App. J).

Determinism contract (mirrored bit-exactly by ``rust/src/env/rules.rs``):
when a rule has several candidate positions, directions are scanned in the
fixed order up, right, down, left and cells in row-major order; the first
match fires. Each rule fires at most once per check; rules are applied
sequentially in ruleset order, later rules seeing earlier rules' effects.
"""

import jax
import jax.numpy as jnp

from . import types as T
from .grid import first_true_flat, object_mask, shift_mask

_OPP = {T.DIR_UP: T.DIR_DOWN, T.DIR_RIGHT: T.DIR_LEFT,
        T.DIR_DOWN: T.DIR_UP, T.DIR_LEFT: T.DIR_RIGHT}


def _floor_cell():
    return jnp.array(T.FLOOR_CELL, dtype=jnp.int32)


def _rule_empty(grid, agent_pos, pocket, args):
    return grid, pocket


def _rule_agent_hold(grid, agent_pos, pocket, args):
    a_t, a_c, c_t, c_c = args[0], args[1], args[4], args[5]
    hit = (pocket[0] == a_t) & (pocket[1] == a_c)
    # producing a floor tile empties the pocket (disappearance)
    empty = jnp.array(T.POCKET_EMPTY, dtype=jnp.int32)
    prod = jnp.where(c_t == T.TILE_FLOOR, empty,
                     jnp.stack([c_t, c_c]).astype(jnp.int32))
    pocket = jnp.where(hit, prod, pocket)
    return grid, pocket


def _agent_neighbor_replace(grid, agent_pos, a_t, a_c, c_t, c_c, directions):
    """Replace the first neighbor of the agent (scanning ``directions`` in
    order) that holds object a with object c."""
    h, w = grid.shape[0], grid.shape[1]
    hits, rows, cols = [], [], []
    for d in directions:
        r = agent_pos[0] + T.DIR_DR[d]
        c = agent_pos[1] + T.DIR_DC[d]
        inside = (r >= 0) & (r < h) & (c >= 0) & (c < w)
        rc, cc = jnp.clip(r, 0, h - 1), jnp.clip(c, 0, w - 1)
        cell = grid[rc, cc]
        hits.append(inside & (cell[0] == a_t) & (cell[1] == a_c))
        rows.append(rc)
        cols.append(cc)
    hits = jnp.stack(hits)
    idx, any_ = first_true_flat(hits)
    rr = jnp.stack(rows)[idx]
    cc = jnp.stack(cols)[idx]
    prod = jnp.stack([c_t, c_c]).astype(jnp.int32)
    new = jnp.where(any_, prod, grid[rr, cc])
    grid = grid.at[rr, cc].set(new)
    return grid


def _rule_agent_near(grid, agent_pos, pocket, args):
    grid = _agent_neighbor_replace(
        grid, agent_pos, args[0], args[1], args[4], args[5],
        (T.DIR_UP, T.DIR_RIGHT, T.DIR_DOWN, T.DIR_LEFT))
    return grid, pocket


def _make_rule_agent_near_dir(direction):
    def rule(grid, agent_pos, pocket, args):
        g = _agent_neighbor_replace(grid, agent_pos, args[0], args[1],
                                    args[4], args[5], (direction,))
        return g, pocket
    return rule


def _tile_near_apply(grid, a_t, a_c, b_t, b_c, c_t, c_c, directions):
    """Fire TileNear*: find the first (direction, cell) where object b sits
    in ``direction`` relative to object a; a's cell becomes c, b's becomes
    floor."""
    h, w = grid.shape[0], grid.shape[1]
    mask_a = object_mask(grid, a_t, a_c)
    mask_b = object_mask(grid, b_t, b_c)
    flags = jnp.stack(
        [mask_a & shift_mask(mask_b, _OPP[d]) for d in directions])
    idx, any_ = first_true_flat(flags)
    hw = h * w
    d_idx = idx // hw
    cell = idx % hw
    ar, ac = cell // w, cell % w
    dirs = jnp.array(directions, dtype=jnp.int32)
    d = dirs[d_idx]
    br = jnp.clip(ar + T.DIR_DR[d], 0, h - 1)
    bc = jnp.clip(ac + T.DIR_DC[d], 0, w - 1)
    prod = jnp.stack([c_t, c_c]).astype(jnp.int32)
    grid = grid.at[br, bc].set(jnp.where(any_, _floor_cell(), grid[br, bc]))
    grid = grid.at[ar, ac].set(jnp.where(any_, prod, grid[ar, ac]))
    return grid


def _rule_tile_near(grid, agent_pos, pocket, args):
    g = _tile_near_apply(grid, args[0], args[1], args[2], args[3], args[4],
                         args[5],
                         (T.DIR_UP, T.DIR_RIGHT, T.DIR_DOWN, T.DIR_LEFT))
    return g, pocket


def _make_rule_tile_near_dir(direction):
    def rule(grid, agent_pos, pocket, args):
        g = _tile_near_apply(grid, args[0], args[1], args[2], args[3],
                             args[4], args[5], (direction,))
        return g, pocket
    return rule


_RULE_FNS = [
    _rule_empty,                              # 0
    _rule_agent_hold,                         # 1
    _rule_agent_near,                         # 2
    _rule_tile_near,                          # 3
    _make_rule_tile_near_dir(T.DIR_UP),       # 4  b one tile above a
    _make_rule_tile_near_dir(T.DIR_RIGHT),    # 5
    _make_rule_tile_near_dir(T.DIR_DOWN),     # 6
    _make_rule_tile_near_dir(T.DIR_LEFT),     # 7
    _make_rule_agent_near_dir(T.DIR_UP),      # 8  a one tile above agent
    _make_rule_agent_near_dir(T.DIR_RIGHT),   # 9
    _make_rule_agent_near_dir(T.DIR_DOWN),    # 10
    _make_rule_agent_near_dir(T.DIR_LEFT),    # 11
]


def check_rule(grid, agent_pos, pocket, rule):
    """Apply a single encoded rule; returns (grid, pocket)."""
    rid = jnp.clip(rule[0], 0, T.NUM_RULES - 1)
    return jax.lax.switch(rid, _RULE_FNS, grid, agent_pos, pocket, rule[1:])


def check_rules(grid, agent_pos, pocket, rules):
    """Apply all rules of a ruleset sequentially (scan keeps HLO compact so
    the rule count sweep of Fig. 5c measures per-rule marginal cost)."""
    def body(carry, rule):
        grid, pocket = carry
        grid, pocket = check_rule(grid, agent_pos, pocket, rule)
        return (grid, pocket), None

    (grid, pocket), _ = jax.lax.scan(body, (grid, pocket), rules)
    return grid, pocket
