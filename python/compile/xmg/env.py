"""The XLand-MiniGrid environment: ``reset`` / ``step`` (paper §2.2).

The environment is completely stateless: all dynamics live in the ``State``
tuple of fixed-shape arrays, so ``jax.vmap`` batches over envs *and* over
rulesets (the paper's core trick — tasks are data). ``step`` implements:

- the 6 discrete actions (move_forward, turn_left, turn_right, pick_up,
  put_down, toggle);
- rule evaluation after the acting actions only (§2.1 "for efficiency
  reasons, the rules are evaluated only after some actions");
- goal checking with reward ``1 - 0.9 * step/max_steps`` on success;
- trial auto-reset *inside* step (the agent "can get more trials if it
  manages to solve tasks faster", §4.2) and episode auto-reset at
  ``max_steps`` (GymAutoResetWrapper semantics, enabled for all throughput
  measurements as in §4.1).

The PRNG key is state-carried (paper §2.2: State contains "a key for the
random number generator that can be used during resets").
"""

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from . import types as T
from .goals import check_goal
from .grid import place_objects
from .observation import observe
from .rules import check_rules


class State(NamedTuple):
    """Full environment state; every leaf is a fixed-shape array."""
    base_grid: jnp.ndarray   # i32[H, W, 2] walls/doors only
    grid: jnp.ndarray        # i32[H, W, 2] current grid
    agent_pos: jnp.ndarray   # i32[2] (row, col)
    agent_dir: jnp.ndarray   # i32[] 0=up 1=right 2=down 3=left
    pocket: jnp.ndarray      # i32[2] (tile, color), EMPTY sentinel if empty
    rules: jnp.ndarray       # i32[MAX_RULES, RULE_ENC]
    goal: jnp.ndarray        # i32[GOAL_ENC]
    init_tiles: jnp.ndarray  # i32[MAX_INIT, 2] objects placed at trial start
    step_count: jnp.ndarray  # i32[]
    key: jnp.ndarray         # u32[2] PRNG key
    max_steps: jnp.ndarray   # i32[]


class StepOutput(NamedTuple):
    state: State
    obs: jnp.ndarray         # i32[V, V, 2]
    reward: jnp.ndarray      # f32[]
    done: jnp.ndarray        # i32[] episode ended (max_steps reached)
    trial_done: jnp.ndarray  # i32[] trial ended (goal or episode end)


def reset(base_grid, rules, goal, init_tiles, max_steps, key,
          view_size=5, see_through_walls=True):
    """Start a fresh episode: place init objects + agent on random floor
    cells of ``base_grid``."""
    key, sub = jax.random.split(key)
    grid, agent_pos, agent_dir = place_objects(sub, base_grid, init_tiles)
    state = State(
        base_grid=base_grid,
        grid=grid,
        agent_pos=agent_pos,
        agent_dir=agent_dir,
        pocket=jnp.array(T.POCKET_EMPTY, dtype=jnp.int32),
        rules=rules,
        goal=goal,
        init_tiles=init_tiles,
        step_count=jnp.asarray(0, dtype=jnp.int32),
        key=key,
        max_steps=jnp.asarray(max_steps, dtype=jnp.int32),
    )
    obs = observe(grid, agent_pos, agent_dir, view_size, see_through_walls)
    return state, obs


# --- action branches (identical signatures for lax.switch) ------------------

def _front(grid, pos, direction):
    h, w = grid.shape[0], grid.shape[1]
    r = pos[0] + T.DIR_DR[direction]
    c = pos[1] + T.DIR_DC[direction]
    inside = (r >= 0) & (r < h) & (c >= 0) & (c < w)
    rc = jnp.clip(r, 0, h - 1)
    cc = jnp.clip(c, 0, w - 1)
    return rc, cc, inside


def _act_forward(grid, pos, direction, pocket):
    rc, cc, inside = _front(grid, pos, direction)
    ok = inside & T.is_walkable(grid[rc, cc, 0])
    pos = jnp.where(ok, jnp.stack([rc, cc]), pos)
    return grid, pos, direction, pocket


def _act_turn_left(grid, pos, direction, pocket):
    return grid, pos, (direction + 3) % 4, pocket


def _act_turn_right(grid, pos, direction, pocket):
    return grid, pos, (direction + 1) % 4, pocket


def _act_pick_up(grid, pos, direction, pocket):
    rc, cc, inside = _front(grid, pos, direction)
    cell = grid[rc, cc]
    empty = (pocket[0] == T.TILE_EMPTY)
    ok = inside & empty & T.is_pickable(cell[0])
    floor = jnp.array(T.FLOOR_CELL, dtype=jnp.int32)
    grid = grid.at[rc, cc].set(jnp.where(ok, floor, cell))
    pocket = jnp.where(ok, cell, pocket)
    return grid, pos, direction, pocket


def _act_put_down(grid, pos, direction, pocket):
    rc, cc, inside = _front(grid, pos, direction)
    cell = grid[rc, cc]
    holding = pocket[0] != T.TILE_EMPTY
    ok = inside & holding & (cell[0] == T.TILE_FLOOR)
    grid = grid.at[rc, cc].set(jnp.where(ok, pocket, cell))
    empty = jnp.array(T.POCKET_EMPTY, dtype=jnp.int32)
    pocket = jnp.where(ok, empty, pocket)
    return grid, pos, direction, pocket


def _act_toggle(grid, pos, direction, pocket):
    rc, cc, inside = _front(grid, pos, direction)
    cell = grid[rc, cc]
    tile, color = cell[0], cell[1]
    has_key = (pocket[0] == T.TILE_KEY) & (pocket[1] == color)
    new_tile = jnp.where(
        tile == T.TILE_DOOR_CLOSED, T.TILE_DOOR_OPEN,
        jnp.where(tile == T.TILE_DOOR_OPEN, T.TILE_DOOR_CLOSED,
                  jnp.where((tile == T.TILE_DOOR_LOCKED) & has_key,
                            T.TILE_DOOR_OPEN, tile)))
    new_tile = jnp.where(inside, new_tile, tile)
    grid = grid.at[rc, cc, 0].set(new_tile)
    return grid, pos, direction, pocket


_ACTION_FNS = [_act_forward, _act_turn_left, _act_turn_right,
               _act_pick_up, _act_put_down, _act_toggle]


def step(state: State, action, view_size=5, see_through_walls=True):
    """One environment transition with trial/episode auto-reset."""
    action = jnp.clip(action, 0, T.NUM_ACTIONS - 1)
    grid, pos, direction, pocket = jax.lax.switch(
        action, _ACTION_FNS, state.grid, state.agent_pos, state.agent_dir,
        state.pocket)

    # rules fire only after acting actions (not after turns)
    triggering = ((action == T.ACTION_FORWARD) | (action == T.ACTION_PICK_UP)
                  | (action == T.ACTION_PUT_DOWN)
                  | (action == T.ACTION_TOGGLE))
    r_grid, r_pocket = check_rules(grid, pos, pocket, state.rules)
    grid = jnp.where(triggering, r_grid, grid)
    pocket = jnp.where(triggering, r_pocket, pocket)

    achieved = check_goal(grid, pos, pocket, state.goal)
    new_step = state.step_count + 1
    done = new_step >= state.max_steps
    reward = jnp.where(
        achieved,
        1.0 - 0.9 * new_step.astype(jnp.float32)
        / jnp.maximum(state.max_steps, 1).astype(jnp.float32),
        0.0).astype(jnp.float32)

    # trial auto-reset on goal, full episode auto-reset at max_steps;
    # branch-free (both vmap-friendly and matching lax.select cost model)
    trial_done = achieved | done
    key, sub = jax.random.split(state.key)
    f_grid, f_pos, f_dir = place_objects(sub, state.base_grid,
                                         state.init_tiles)
    grid = jnp.where(trial_done, f_grid, grid)
    pos = jnp.where(trial_done, f_pos, pos)
    direction = jnp.where(trial_done, f_dir, direction)
    empty = jnp.array(T.POCKET_EMPTY, dtype=jnp.int32)
    pocket = jnp.where(trial_done, empty, pocket)
    key = jnp.where(trial_done, key, state.key)
    step_count = jnp.where(done, 0, new_step).astype(jnp.int32)

    new_state = State(
        base_grid=state.base_grid, grid=grid, agent_pos=pos,
        agent_dir=direction, pocket=pocket, rules=state.rules,
        goal=state.goal, init_tiles=state.init_tiles,
        step_count=step_count, key=key, max_steps=state.max_steps)
    obs = observe(grid, pos, direction, view_size, see_through_walls)
    return StepOutput(state=new_state, obs=obs, reward=reward,
                      done=done.astype(jnp.int32),
                      trial_done=trial_done.astype(jnp.int32))


def default_max_steps(h, w):
    """Paper §2.3 heuristic: 3 × grid height × grid width."""
    return 3 * h * w


@functools.partial(jax.jit, static_argnums=(5, 6))
def reset_jit(base_grid, rules, goal, init_tiles, key, view_size,
              see_through_walls, max_steps):
    return reset(base_grid, rules, goal, init_tiles, max_steps, key,
                 view_size, see_through_walls)
