"""Shared constants: tiles, colors, actions, rule/goal IDs (paper Tables 1-3).

The Rust substrate (``rust/src/env/types.rs``) mirrors these values exactly;
``rust/tests/id_tables.rs`` and ``python/tests/test_types.py`` pin them.
"""

import jax.numpy as jnp

# --- Table 1a: tiles -------------------------------------------------------
TILE_END_OF_MAP = 0
TILE_UNSEEN = 1
TILE_EMPTY = 2
TILE_FLOOR = 3
TILE_WALL = 4
TILE_BALL = 5
TILE_SQUARE = 6
TILE_PYRAMID = 7
TILE_GOAL = 8
TILE_KEY = 9
TILE_DOOR_LOCKED = 10
TILE_DOOR_CLOSED = 11
TILE_DOOR_OPEN = 12
TILE_HEX = 13
TILE_STAR = 14
NUM_TILES = 15

# --- Table 1b: colors ------------------------------------------------------
COLOR_END_OF_MAP = 0
COLOR_UNSEEN = 1
COLOR_EMPTY = 2
COLOR_RED = 3
COLOR_GREEN = 4
COLOR_BLUE = 5
COLOR_PURPLE = 6
COLOR_YELLOW = 7
COLOR_GREY = 8
COLOR_BLACK = 9
COLOR_ORANGE = 10
COLOR_WHITE = 11
COLOR_BROWN = 12
COLOR_PINK = 13
NUM_COLORS = 14

# Colors used by the benchmark generator for objects (App. J: 10 colors).
GEN_COLORS = (
    COLOR_RED, COLOR_GREEN, COLOR_BLUE, COLOR_PURPLE, COLOR_YELLOW,
    COLOR_GREY, COLOR_WHITE, COLOR_BROWN, COLOR_PINK, COLOR_ORANGE,
)
# Object tiles used by the generator (App. J: 7 tile types).
GEN_TILES = (
    TILE_BALL, TILE_SQUARE, TILE_PYRAMID, TILE_KEY, TILE_STAR, TILE_HEX,
    TILE_GOAL,
)

# --- actions ---------------------------------------------------------------
ACTION_FORWARD = 0
ACTION_TURN_LEFT = 1
ACTION_TURN_RIGHT = 2
ACTION_PICK_UP = 3
ACTION_PUT_DOWN = 4
ACTION_TOGGLE = 5
NUM_ACTIONS = 6

# --- directions: 0=up, 1=right, 2=down, 3=left -----------------------------
DIR_UP, DIR_RIGHT, DIR_DOWN, DIR_LEFT = 0, 1, 2, 3
# row/col deltas indexed by direction
DIR_DR = jnp.array([-1, 0, 1, 0], dtype=jnp.int32)
DIR_DC = jnp.array([0, 1, 0, -1], dtype=jnp.int32)

# --- Table 2: goals --------------------------------------------------------
GOAL_EMPTY = 0
GOAL_AGENT_HOLD = 1
GOAL_AGENT_ON_TILE = 2
GOAL_AGENT_NEAR = 3
GOAL_TILE_NEAR = 4
GOAL_AGENT_ON_POSITION = 5
GOAL_TILE_ON_POSITION = 6
GOAL_TILE_NEAR_UP = 7
GOAL_TILE_NEAR_RIGHT = 8
GOAL_TILE_NEAR_DOWN = 9
GOAL_TILE_NEAR_LEFT = 10
GOAL_AGENT_NEAR_UP = 11
GOAL_AGENT_NEAR_RIGHT = 12
GOAL_AGENT_NEAR_DOWN = 13
GOAL_AGENT_NEAR_LEFT = 14
NUM_GOALS = 15

# --- Table 3: rules --------------------------------------------------------
RULE_EMPTY = 0
RULE_AGENT_HOLD = 1
RULE_AGENT_NEAR = 2
RULE_TILE_NEAR = 3
RULE_TILE_NEAR_UP = 4
RULE_TILE_NEAR_RIGHT = 5
RULE_TILE_NEAR_DOWN = 6
RULE_TILE_NEAR_LEFT = 7
RULE_AGENT_NEAR_UP = 8
RULE_AGENT_NEAR_RIGHT = 9
RULE_AGENT_NEAR_DOWN = 10
RULE_AGENT_NEAR_LEFT = 11
NUM_RULES = 12

# Encoding widths (paper §2.1: id followed by padded arguments).
RULE_ENC = 7   # [id, a_tile, a_col, b_tile, b_col, c_tile, c_col]
GOAL_ENC = 5   # [id, a0, a1, a2, a3]

# Tile sets
PICKABLE_TILES = (TILE_BALL, TILE_SQUARE, TILE_PYRAMID, TILE_KEY, TILE_HEX,
                  TILE_STAR)
WALKABLE_TILES = (TILE_FLOOR, TILE_GOAL, TILE_DOOR_OPEN)
# Tiles light passes through (for the optional occlusion mode)
TRANSPARENT_BLOCKERS = (TILE_WALL, TILE_DOOR_CLOSED, TILE_DOOR_LOCKED,
                        TILE_END_OF_MAP)

# Pocket sentinel: empty pocket is (TILE_EMPTY, COLOR_EMPTY)
POCKET_EMPTY = (TILE_EMPTY, COLOR_EMPTY)

# Grid cell constants
FLOOR_CELL = (TILE_FLOOR, COLOR_BLACK)
WALL_CELL = (TILE_WALL, COLOR_GREY)


def is_pickable(tile):
    t = jnp.asarray(tile)
    out = jnp.zeros_like(t, dtype=jnp.bool_)
    for p in PICKABLE_TILES:
        out = out | (t == p)
    return out


def is_walkable(tile):
    t = jnp.asarray(tile)
    out = jnp.zeros_like(t, dtype=jnp.bool_)
    for w in WALKABLE_TILES:
        out = out | (t == w)
    return out


def blocks_sight(tile):
    t = jnp.asarray(tile)
    out = jnp.zeros_like(t, dtype=jnp.bool_)
    for b in TRANSPARENT_BLOCKERS:
        out = out | (t == b)
    return out
