"""RGB rendering of symbolic observations (paper App. H).

``render_obs`` maps an ``i32[V, V, 2]`` symbolic observation to a
``f32[V*P, V*P, 3]`` image with P pixels per tile, entirely in jnp so it can
be AOT-lowered (``render_rgb_*`` artifacts) and benchmarked for Fig. 13. The
paper renders 224×224; we render at tile-patch resolution (the upscale is a
constant factor, not a semantic difference — docs/ARCHITECTURE.md, "Hardware adaptation").
"""

import jax.numpy as jnp

from . import types as T

# RGB per color id (rows index COLOR_*)
_PALETTE = jnp.array([
    [0, 0, 0],        # END_OF_MAP
    [40, 40, 40],     # UNSEEN
    [0, 0, 0],        # EMPTY
    [255, 0, 0],      # RED
    [0, 255, 0],      # GREEN
    [0, 0, 255],      # BLUE
    [112, 39, 195],   # PURPLE
    [255, 255, 0],    # YELLOW
    [100, 100, 100],  # GREY
    [20, 20, 20],     # BLACK
    [255, 140, 0],    # ORANGE
    [255, 255, 255],  # WHITE
    [139, 69, 19],    # BROWN
    [255, 105, 180],  # PINK
], dtype=jnp.float32) / 255.0


def _tile_patches(patch):
    """Binary P×P stencils per tile id (shape [NUM_TILES, P, P])."""
    p = patch
    y, x = jnp.meshgrid(jnp.arange(p), jnp.arange(p), indexing="ij")
    yc = (y - (p - 1) / 2.0) / (p / 2.0)
    xc = (x - (p - 1) / 2.0) / (p / 2.0)
    full = jnp.ones((p, p))
    empty = jnp.zeros((p, p))
    circle = (yc**2 + xc**2 <= 0.64).astype(jnp.float32)
    square = ((jnp.abs(yc) <= 0.7) & (jnp.abs(xc) <= 0.7)).astype(jnp.float32)
    pyramid = ((yc >= -0.7) & (jnp.abs(xc) <= 0.7 * (yc + 0.7) / 1.4)
               ).astype(jnp.float32)
    key = (((yc**2 + xc**2 <= 0.3) & (yc < 0))
           | ((jnp.abs(xc) < 0.18) & (yc >= -0.2) & (yc <= 0.8))
           ).astype(jnp.float32)
    door = ((jnp.abs(yc) > 0.75) | (jnp.abs(xc) > 0.75)).astype(jnp.float32)
    door_open = ((jnp.abs(xc) > 0.75)).astype(jnp.float32)
    hexa = ((jnp.abs(yc) + jnp.abs(xc) * 0.6) <= 0.8).astype(jnp.float32)
    star = (((jnp.abs(yc) <= 0.25) | (jnp.abs(xc) <= 0.25))
            & (jnp.abs(yc) <= 0.8) & (jnp.abs(xc) <= 0.8)).astype(jnp.float32)
    goal = full * 0.6
    stencils = [
        empty,      # END_OF_MAP
        full,       # UNSEEN (dim overlay via palette)
        empty,      # EMPTY
        empty,      # FLOOR (background only)
        full,       # WALL
        circle,     # BALL
        square,     # SQUARE
        pyramid,    # PYRAMID
        goal,       # GOAL
        key,        # KEY
        door,       # DOOR_LOCKED
        door,       # DOOR_CLOSED
        door_open,  # DOOR_OPEN
        hexa,       # HEX
        star,       # STAR
    ]
    return jnp.stack(stencils)


def render_obs(obs, patch=8):
    """Render symbolic obs [V, V, 2] -> image [V*P, V*P, 3] float32 in
    [0, 1]."""
    v = obs.shape[0]
    stencils = _tile_patches(patch)            # [NT, P, P]
    tile = jnp.clip(obs[..., 0], 0, T.NUM_TILES - 1)
    color = jnp.clip(obs[..., 1], 0, T.NUM_COLORS - 1)
    fg = stencils[tile]                        # [V, V, P, P]
    rgb = _PALETTE[color]                      # [V, V, 3]
    floor_bg = jnp.array([0.12, 0.12, 0.12], dtype=jnp.float32)
    img = (fg[..., None] * rgb[:, :, None, None, :]
           + (1.0 - fg[..., None]) * floor_bg)
    img = img.transpose(0, 2, 1, 3, 4).reshape(v * patch, v * patch, 3)
    return img.astype(jnp.float32)
