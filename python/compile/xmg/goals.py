"""Goals (paper Table 2, §2.1): pure condition checks, never mutate state.

Encoding: ``int32[GOAL_ENC] = [id, a0, a1, a2, a3]``; argument meaning is
per-goal (object = (tile, color) pair, position = (row, col)). Dispatch is a
``jax.lax.switch`` over the 15 goal functions, mirroring
``xminigrid.core.goals.check_goal`` (App. I).
"""

import jax
import jax.numpy as jnp

from . import types as T
from .grid import object_mask, shift_mask

_OPP = {T.DIR_UP: T.DIR_DOWN, T.DIR_RIGHT: T.DIR_LEFT,
        T.DIR_DOWN: T.DIR_UP, T.DIR_LEFT: T.DIR_RIGHT}


def _goal_empty(grid, agent_pos, pocket, args):
    return jnp.asarray(False)


def _goal_agent_hold(grid, agent_pos, pocket, args):
    return (pocket[0] == args[0]) & (pocket[1] == args[1])


def _goal_agent_on_tile(grid, agent_pos, pocket, args):
    cell = grid[agent_pos[0], agent_pos[1]]
    return (cell[0] == args[0]) & (cell[1] == args[1])


def _agent_near_any(grid, agent_pos, a_t, a_c, directions):
    h, w = grid.shape[0], grid.shape[1]
    hit = jnp.asarray(False)
    for d in directions:
        r = agent_pos[0] + T.DIR_DR[d]
        c = agent_pos[1] + T.DIR_DC[d]
        inside = (r >= 0) & (r < h) & (c >= 0) & (c < w)
        cell = grid[jnp.clip(r, 0, h - 1), jnp.clip(c, 0, w - 1)]
        hit = hit | (inside & (cell[0] == a_t) & (cell[1] == a_c))
    return hit


def _goal_agent_near(grid, agent_pos, pocket, args):
    return _agent_near_any(grid, agent_pos, args[0], args[1],
                           (T.DIR_UP, T.DIR_RIGHT, T.DIR_DOWN, T.DIR_LEFT))


def _tile_near_any(grid, a_t, a_c, b_t, b_c, directions):
    mask_a = object_mask(grid, a_t, a_c)
    mask_b = object_mask(grid, b_t, b_c)
    hit = jnp.asarray(False)
    for d in directions:
        hit = hit | jnp.any(mask_a & shift_mask(mask_b, _OPP[d]))
    return hit


def _goal_tile_near(grid, agent_pos, pocket, args):
    return _tile_near_any(grid, args[0], args[1], args[2], args[3],
                          (T.DIR_UP, T.DIR_RIGHT, T.DIR_DOWN, T.DIR_LEFT))


def _goal_agent_on_position(grid, agent_pos, pocket, args):
    return (agent_pos[0] == args[0]) & (agent_pos[1] == args[1])


def _goal_tile_on_position(grid, agent_pos, pocket, args):
    h, w = grid.shape[0], grid.shape[1]
    r = jnp.clip(args[2], 0, h - 1)
    c = jnp.clip(args[3], 0, w - 1)
    cell = grid[r, c]
    return (cell[0] == args[0]) & (cell[1] == args[1])


def _make_goal_tile_near_dir(direction):
    def goal(grid, agent_pos, pocket, args):
        return _tile_near_any(grid, args[0], args[1], args[2], args[3],
                              (direction,))
    return goal


def _make_goal_agent_near_dir(direction):
    def goal(grid, agent_pos, pocket, args):
        return _agent_near_any(grid, agent_pos, args[0], args[1],
                               (direction,))
    return goal


_GOAL_FNS = [
    _goal_empty,                               # 0
    _goal_agent_hold,                          # 1
    _goal_agent_on_tile,                       # 2
    _goal_agent_near,                          # 3
    _goal_tile_near,                           # 4
    _goal_agent_on_position,                   # 5
    _goal_tile_on_position,                    # 6
    _make_goal_tile_near_dir(T.DIR_UP),        # 7  b one tile above a
    _make_goal_tile_near_dir(T.DIR_RIGHT),     # 8
    _make_goal_tile_near_dir(T.DIR_DOWN),      # 9
    _make_goal_tile_near_dir(T.DIR_LEFT),      # 10
    _make_goal_agent_near_dir(T.DIR_UP),       # 11 a one tile above agent
    _make_goal_agent_near_dir(T.DIR_RIGHT),    # 12
    _make_goal_agent_near_dir(T.DIR_DOWN),     # 13
    _make_goal_agent_near_dir(T.DIR_LEFT),     # 14
]


def check_goal(grid, agent_pos, pocket, goal):
    """Evaluate an encoded goal; returns a scalar bool."""
    gid = jnp.clip(goal[0], 0, T.NUM_GOALS - 1)
    return jax.lax.switch(gid, _GOAL_FNS, grid, agent_pos, pocket, goal[1:])
