"""Partial egocentric observations (paper §2.2).

The agent sees a V×V window, rotated so it faces "up" with the agent at the
bottom-center cell ``(V-1, V//2)``. Each cell is ``(tile_id, color_id)`` —
symbolic, not pixels. Cells outside the grid read END_OF_MAP. With
``see_through_walls=False`` a flood-fill visibility pass marks occluded
cells UNSEEN (light spreads outward from the agent through transparent
cells; order-independent fixed point, mirrored by the Rust oracle).
"""

import jax.numpy as jnp

from . import types as T


def view_coords(view_size):
    """Static (forward, lateral) offsets for each view cell; agent at
    (V-1, V//2) facing up."""
    v = view_size
    rows = jnp.arange(v)
    cols = jnp.arange(v)
    fwd = (v - 1) - rows  # forward distance
    lat = cols - (v // 2)  # lateral offset (right positive)
    return jnp.meshgrid(fwd, lat, indexing="ij")


def observe(grid, agent_pos, agent_dir, view_size, see_through_walls=True):
    h, w = grid.shape[0], grid.shape[1]
    v = view_size
    fwd, lat = view_coords(v)

    # world deltas per direction: facing up=(-f, l), right=(l, f),
    # down=(f, -l), left=(-l, -f)
    drs = jnp.stack([-fwd, lat, fwd, -lat])
    dcs = jnp.stack([lat, fwd, -lat, -fwd])
    dr = drs[agent_dir]
    dc = dcs[agent_dir]

    r = agent_pos[0] + dr
    c = agent_pos[1] + dc
    inside = (r >= 0) & (r < h) & (c >= 0) & (c < w)
    rc = jnp.clip(r, 0, h - 1)
    cc = jnp.clip(c, 0, w - 1)
    obs = grid[rc, cc]
    off = jnp.array([T.TILE_END_OF_MAP, T.COLOR_END_OF_MAP], dtype=jnp.int32)
    obs = jnp.where(inside[..., None], obs, off[None, None, :])

    if not see_through_walls:
        transparent = ~T.blocks_sight(obs[..., 0])
        vis = jnp.zeros((v, v), dtype=jnp.bool_)
        vis = vis.at[v - 1, v // 2].set(True)
        # light spreads from visible transparent cells to 4-neighbors;
        # fixed point reached after <= 2*V sweeps
        for _ in range(2 * v):
            src = vis & transparent
            spread = (
                jnp.pad(src[1:, :], ((0, 1), (0, 0)))
                | jnp.pad(src[:-1, :], ((1, 0), (0, 0)))
                | jnp.pad(src[:, 1:], ((0, 0), (0, 1)))
                | jnp.pad(src[:, :-1], ((0, 0), (1, 0)))
            )
            vis = vis | spread
        unseen = jnp.array([T.TILE_UNSEEN, T.COLOR_UNSEEN], dtype=jnp.int32)
        obs = jnp.where(vis[..., None], obs, unseen[None, None, :])

    return obs.astype(jnp.int32)
