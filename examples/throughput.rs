//! Throughput sweep (the Fig. 5a experience as a runnable example):
//! random-policy simulation throughput vs number of parallel environments,
//! comparing the native vectorized SoA engine and the fused AOT rollout
//! against the pure-Rust CPU loop (the EnvPool-style baseline every
//! JAX-env paper compares against). The native and scalar sections need
//! no artifacts; the XLA section is skipped without them.
//!
//! Run: `cargo run --release --example throughput -- [--chunks N]`

use anyhow::Result;
use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use xmgrid::benchgen::{generate_benchmark, Benchmark, Preset};
use xmgrid::coordinator::metrics::fmt_sps;
use xmgrid::coordinator::pool::EnvFamily;
use xmgrid::coordinator::{EnvPool, NativeEnvConfig, NativePool};
use xmgrid::env::api::{rollout_batch, BatchEnvironment, ObsMode,
                       RolloutBufs};
use xmgrid::env::state::{reset, step, EnvOptions};
use xmgrid::env::Grid;
use xmgrid::util::args::Args;
use xmgrid::util::rng::Rng;

fn main() -> Result<()> {
    let args = Args::from_env();
    let chunks = args.usize_or("chunks", 2);
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");

    let (rulesets, _) = generate_benchmark(&Preset::Trivial.config(), 256)?;
    let bench = Arc::new(Benchmark { name: "trivial".into(), rulesets });
    let mut rng = Rng::new(0);

    // --- native vectorized SoA engine (no artifacts) ---------------------
    println!("== native vectorized rollout (VecEnv SoA kernels, 13x13)");
    for batch in [16usize, 256, 1024] {
        let t = 128usize;
        let ncfg = NativeEnvConfig::for_env("XLand-MiniGrid-R1-13x13",
                                            batch, t, &bench)?;
        let mut pool = NativePool::new(ncfg);
        pool.reset(&bench, &mut rng)?;
        pool.rollout(t, &mut rng)?; // warmup (buffer first-touch)
        let t0 = Instant::now();
        for _ in 0..chunks {
            pool.rollout(t, &mut rng)?;
        }
        let sps = (batch * t * chunks) as f64
            / t0.elapsed().as_secs_f64();
        println!("  native-vec 13x13              envs={batch:<6} sps={}",
                 fmt_sps(sps));
    }

    // --- threads axis: same batch chunked over the worker pool ----------
    let cores = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(1);
    println!("\n== native rollout threads scaling (B=1024, \
              host cores: {cores})");
    let mut sweep = vec![1usize, 2, cores.min(8)];
    sweep.sort_unstable();
    sweep.dedup();
    for threads in sweep {
        let t = 128usize;
        let ncfg = NativeEnvConfig::for_env("XLand-MiniGrid-R1-13x13",
                                            1024, t, &bench)?
            .with_threads(threads);
        let mut pool = NativePool::new(ncfg);
        pool.reset(&bench, &mut rng)?;
        pool.rollout(t, &mut rng)?; // warmup
        let t0 = Instant::now();
        for _ in 0..chunks {
            pool.rollout(t, &mut rng)?;
        }
        let sps = (1024 * t * chunks) as f64
            / t0.elapsed().as_secs_f64();
        println!("  native-vec threads={threads:<3}       envs=1024   \
                  sps={}", fmt_sps(sps));
    }

    // --- observation wrapper stacks (`--obs` cost model) -----------------
    println!("\n== native rollout through obs wrapper stacks (B=256)");
    for mode in [ObsMode::Symbolic, ObsMode::Rgb] {
        let t = 64usize;
        let ncfg = NativeEnvConfig::for_env("XLand-MiniGrid-R1-13x13",
                                            256, t, &bench)?;
        let pool = NativePool::with_tasks(ncfg, bench.clone());
        let mut env = mode.wrap(pool);
        let mut obs0 = vec![0i32; env.obs_len()];
        env.reset(&mut rng, &mut obs0)?;
        let mut bufs = RolloutBufs::for_env(env.as_ref());
        rollout_batch(env.as_mut(), t, &mut rng, &mut bufs)?; // warmup
        let t0 = Instant::now();
        for _ in 0..chunks {
            rollout_batch(env.as_mut(), t, &mut rng, &mut bufs)?;
        }
        let sps =
            (256 * t * chunks) as f64 / t0.elapsed().as_secs_f64();
        println!("  native obs={mode:<12} envs=256    sps={}",
                 fmt_sps(sps));
    }

    // --- AOT fused rollouts, every compiled batch size -------------------
    match xmgrid::runtime::Runtime::new(&dir) {
        Ok(rt) => {
            println!("\n== XLA batched rollout (auto-reset on, random \
                      policy)");
            let mut rolls = rt.manifest.of_kind("env_rollout");
            rolls.sort_by_key(|s| {
                (s.meta_usize("H").unwrap(), s.meta_usize("B").unwrap())
            });
            for spec in rolls {
                let fam = EnvFamily::from_spec(spec)?;
                let t = spec.meta_usize("T")?;
                let mut pool = EnvPool::new(&rt, fam, 1)?;
                let rs = pool.sample_rulesets(&bench, &mut rng);
                pool.reset(&rs, &mut rng)?;
                pool.rollout(&rt, t, &mut rng)?; // warmup
                let t0 = Instant::now();
                for _ in 0..chunks {
                    pool.rollout(&rt, t, &mut rng)?;
                }
                let sps = (fam.b * t * chunks) as f64
                    / t0.elapsed().as_secs_f64();
                println!("  {:<38} envs={:<6} sps={}", spec.name, fam.b,
                         fmt_sps(sps));
            }
        }
        Err(e) => {
            println!("\n== XLA section skipped (no artifacts/PJRT): {e}");
        }
    }

    // --- pure-Rust sequential loop (CPU baseline) -------------------------
    println!("\n== pure-Rust loop baseline (single thread)");
    for batch in [1usize, 16, 256, 1024] {
        let opts = EnvOptions::default();
        let mut states: Vec<_> = (0..batch)
            .map(|i| {
                let rs = bench.rulesets[i % bench.num_rulesets()].clone();
                reset(Grid::empty_room(13, 13), rs, 507,
                      Rng::new(i as u64), opts).0
            })
            .collect();
        let steps_per_env = 256usize;
        let t0 = Instant::now();
        for s in states.iter_mut() {
            for _ in 0..steps_per_env {
                step(s, rng.below(6) as i32, opts);
            }
        }
        let sps = (batch * steps_per_env) as f64
            / t0.elapsed().as_secs_f64();
        println!("  rust-loop 13x13               envs={batch:<6} sps={}",
                 fmt_sps(sps));
    }
    Ok(())
}
