//! Generalization protocol (paper Fig. 8): train on rulesets whose goal
//! types are in {AgentHold=1, AgentNear=3, TileNear=4}, evaluate on tasks
//! sampled from the *held-out* goal types, and report the train/test gap.
//!
//! Run: `cargo run --release --example generalization -- [--iters N]`

use anyhow::{Context, Result};
use std::path::Path;

use xmgrid::benchgen::{generate_benchmark, Benchmark, Preset};
use xmgrid::coordinator::{TrainConfig, Trainer};
use xmgrid::runtime::Runtime;
use xmgrid::util::args::Args;

/// Goal ids kept for training (App. K: "only goals with IDs 1, 3, 4 were
/// retained").
const TRAIN_GOALS: [i32; 3] = [1, 3, 4];

fn main() -> Result<()> {
    let args = Args::from_env();
    let iters = args.usize_or("iters", 100);
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Runtime::new(&dir).context("run `make artifacts` first")?;

    let artifact = rt
        .manifest
        .of_kind("train_iter")
        .iter()
        .max_by_key(|s| s.meta_usize("B").unwrap())
        .context("no train_iter artifacts")?
        .name
        .clone();
    let eval_artifact = rt
        .manifest
        .of_kind("eval_rollout")
        .iter()
        .map(|s| s.name.clone())
        .next()
        .context("no eval_rollout artifact")?;

    let mut trainer =
        Trainer::new(&rt, &artifact, 1, TrainConfig::default())?;

    // benchmark split by goal type — the Fig. 8 protocol
    let mut gen_cfg = Preset::Small.config();
    gen_cfg.max_rules = trainer.family.mr;
    gen_cfg.max_objects = trainer.family.mi;
    let (rulesets, _) = generate_benchmark(&gen_cfg, 8192)?;
    let all = Benchmark { name: "small-8k".into(), rulesets };
    let (train_bench, test_bench) = all.split_by_goal(&TRAIN_GOALS);
    println!(
        "goal-type split: {} train tasks (goals {:?}), {} held-out tasks",
        train_bench.num_rulesets(), TRAIN_GOALS,
        test_bench.num_rulesets()
    );
    // train and eval share one observation contract (shared EnvParams)
    let params = xmgrid::env::api::EnvParams::new(
        trainer.family.h, trainer.family.w, trainer.family.mr,
        trainer.family.mi);
    println!("obs spec: {} | action spec: {}",
             params.obs_spec().to_json(),
             params.action_spec().to_json());

    trainer.resample_tasks(&train_bench)?;
    for i in 1..=iters {
        if i > 1 && (i - 1) % trainer.cfg.task_resample_iters == 0 {
            trainer.resample_tasks(&train_bench)?;
        }
        let m = trainer.train_iter()?;
        if i % 20 == 0 {
            println!("iter {i:>4} loss {:+.3} r/step {:.4}",
                     m.total_loss, m.reward_sum / m.env_steps as f32);
        }
    }

    let on_train =
        trainer.evaluate(&rt, &eval_artifact, &train_bench, 1)?;
    let on_test = trainer.evaluate(&rt, &eval_artifact, &test_bench, 1)?;
    println!("\n== Fig. 8 readout (return over eval tasks)");
    println!("  train goals: mean {:.3}  P20 {:.3}", on_train.return_mean,
             on_train.return_p20);
    println!("  held-out:    mean {:.3}  P20 {:.3}", on_test.return_mean,
             on_test.return_p20);
    println!("  generalization gap (mean): {:.3}",
             on_train.return_mean - on_test.return_mean);
    Ok(())
}
