//! Quickstart — the Listing 1/2 experience of the paper, in Rust.
//!
//! Creates an XLand environment, samples a ruleset from a benchmark,
//! resets and steps it (both the pure-Rust engine and the AOT-compiled
//! JAX executable), and renders the grid.
//!
//! Run: `cargo run --release --example quickstart`

use anyhow::Result;
use std::path::Path;

use xmgrid::benchgen::{generate_benchmark, Preset};
use xmgrid::coordinator::pool::EnvFamily;
use xmgrid::coordinator::EnvPool;
use xmgrid::env::api::{BatchEnvironment, DirectionObs, EnvParams,
                       Environment, ScalarEnv, SingleEnv};
use xmgrid::env::registry;
use xmgrid::env::state::{reset, step, EnvOptions};
use xmgrid::env::Grid;
use xmgrid::render::{render_grid, render_obs};
use xmgrid::runtime::Runtime;
use xmgrid::util::rng::Rng;

fn main() -> Result<()> {
    // --- list available environments (xminigrid.registered_environments)
    let envs = registry::registered_environments();
    println!("{} registered environments, e.g. {} / {}", envs.len(),
             envs[0], envs[20]);

    // --- create an env instance + sample a task -------------------------
    let mut rng = Rng::new(0);
    let bp = registry::make("XLand-MiniGrid-R1-9x9", &mut rng);
    let (mut tasks, _) =
        generate_benchmark(&Preset::Trivial.config(), 16)?;
    let ruleset = tasks.swap_remove(3);
    println!("\ntask goal id {} | {} rules | {} initial objects",
             ruleset.goal.id(), ruleset.rules.len(),
             ruleset.init_tiles.len());

    // --- reset + step the pure-Rust engine ------------------------------
    let opts = EnvOptions::default();
    let (mut state, obs) =
        reset(bp.base_grid, ruleset, bp.max_steps, rng.split(), opts);
    println!("\ninitial grid:\n{}",
             render_grid(&state.grid,
                         Some((state.agent_pos, state.agent_dir)), true));
    println!("agent's egocentric view:\n{}", render_obs(&obs, true));

    let mut total = 0.0;
    for _ in 0..100 {
        let out = step(&mut state, rng.below(6) as i32, opts);
        total += out.reward as f64;
    }
    println!("100 random steps -> total reward {total:.3}");

    // --- the unified TimeStep API + a wrapper stack ---------------------
    // ScalarEnv speaks the dm_env-style Environment trait; SingleEnv
    // lifts it into the batch API so the same wrappers that extend
    // VecEnv/NativePool observations compose over it.
    let (mut tasks2, _) =
        generate_benchmark(&Preset::Trivial.config(), 4)?;
    let mut env = ScalarEnv::new(EnvParams::new(9, 9, 1, 2),
                                 Grid::empty_room(9, 9),
                                 tasks2.pop().unwrap(), 243,
                                 rng.split());
    let first = env.reset(rng.split());
    println!("\nTimeStep API: step_type {:?}, obs spec {} (len {})",
             first.step_type,
             env.obs_spec().to_json(), env.obs_spec().len());
    let ts = env.step(rng.below(6) as i32);
    println!("one step -> reward {:.3}, discount {}, trial_done {}",
             ts.reward, ts.discount, ts.trial_done);

    let mut wrapped = DirectionObs::new(SingleEnv::new(env));
    let mut obs_buf = vec![0i32; wrapped.obs_len()];
    let (mut rw, mut dn, mut tr) = ([0f32], [false], [false]);
    wrapped.step(&[0], &mut obs_buf, &mut rw, &mut dn, &mut tr)?;
    println!("DirectionObs wrapper: spec {} -> last 4 values {:?}",
             wrapped.obs_spec().to_json(),
             &obs_buf[obs_buf.len() - 4..]);

    // --- same thing through the AOT JAX executable ----------------------
    let artifacts = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match Runtime::new(&artifacts) {
        Ok(rt) => {
            let spec = rt.manifest.of_kind("env_rollout");
            if let Some(s) = spec.first() {
                let fam = EnvFamily::from_spec(s)?;
                let t = s.meta_usize("T")?;
                let mut pool = EnvPool::new(&rt, fam, 1)?;
                let bench = xmgrid::benchgen::Benchmark {
                    name: "demo".into(),
                    rulesets: generate_benchmark(
                        &Preset::Trivial.config(), 64)?.0,
                };
                let rulesets = pool.sample_rulesets(&bench, &mut rng);
                pool.reset(&rulesets, &mut rng)?;
                let (reward, episodes, trials) =
                    pool.rollout(&rt, t, &mut rng)?;
                println!(
                    "\nAOT executable {}: {} envs x {t} steps -> \
                     reward {reward:.1}, {episodes} episodes, {trials} \
                     trials",
                    s.name, fam.b
                );
            }
        }
        Err(e) => println!("\n(skipping AOT demo: {e}; run `make artifacts`)"),
    }
    Ok(())
}
