//! End-to-end driver (docs/ARCHITECTURE.md, "End-to-end validation"): meta-train the
//! RL² recurrent-PPO baseline on a freshly generated trivial benchmark,
//! log the learning curve, and run the §4.2 evaluation protocol before and
//! after — proving all three layers (Pallas kernels inside the JAX policy,
//! the vmapped env, the Rust coordinator) compose on a real workload.
//!
//! Run: `cargo run --release --example train_rl2 -- [--iters N]`
//! (Results recorded in EXPERIMENTS.md.)

use anyhow::{Context, Result};
use std::path::Path;

use xmgrid::benchgen::{generate_benchmark, Benchmark, Preset};
use xmgrid::coordinator::metrics::{fmt_sps, CsvLog};
use xmgrid::coordinator::{TrainConfig, Trainer};
use xmgrid::runtime::Runtime;
use xmgrid::util::args::Args;

fn main() -> Result<()> {
    let args = Args::from_env();
    let iters = args.usize_or("iters", 150);
    let eval_every = args.usize_or("eval-every", 25);

    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let rt = Runtime::new(&dir).context("run `make artifacts` first")?;

    // largest train artifact = the most realistic workload available
    let artifact = rt
        .manifest
        .of_kind("train_iter")
        .iter()
        .max_by_key(|s| s.meta_usize("B").unwrap())
        .context("no train_iter artifacts")?
        .name
        .clone();
    let eval_artifact = rt
        .manifest
        .of_kind("eval_rollout")
        .iter()
        .map(|s| s.name.clone())
        .next();

    let cfg = TrainConfig::default();
    let mut trainer = Trainer::new(&rt, &artifact, 1, cfg)?;

    // benchmark sized to the artifact capacity
    let mut gen_cfg = Preset::Trivial.config();
    gen_cfg.max_rules = trainer.family.mr;
    gen_cfg.max_objects = trainer.family.mi;
    let (rulesets, _) = generate_benchmark(&gen_cfg, 4096)?;
    let bench = Benchmark { name: "trivial-4k".into(), rulesets };

    println!("== train_rl2: {} on {} ({}x{} grid, {} envs, T={})",
             artifact, bench.name, trainer.family.h, trainer.family.w,
             trainer.family.b, trainer.t_len);
    // the compiled policy consumes the family's symbolic ObsSpec —
    // derived from the same shared EnvParams the native engines use
    let params = xmgrid::env::api::EnvParams::new(
        trainer.family.h, trainer.family.w, trainer.family.mr,
        trainer.family.mi);
    println!("   policy input spec: {}", params.obs_spec().to_json());

    trainer.resample_tasks(&bench)?;
    if let Some(ea) = &eval_artifact {
        let st = trainer.evaluate(&rt, ea, &bench, 1)?;
        println!("before training: return mean {:.3} P20 {:.3}",
                 st.return_mean, st.return_p20);
    }

    let log_path = dir.join("train_rl2_curve.csv");
    let mut log = CsvLog::create(&log_path, &[
        "iter", "env_steps", "loss", "entropy", "reward_per_step",
        "trials", "sps",
    ])?;

    let t0 = std::time::Instant::now();
    let mut env_steps = 0u64;
    let mut first_r = None;
    let mut last_r = 0.0f32;
    for i in 1..=iters {
        if i > 1 && (i - 1) % trainer.cfg.task_resample_iters == 0 {
            trainer.resample_tasks(&bench)?;
        }
        let m = trainer.train_iter()?;
        env_steps += m.env_steps;
        let r_per_step = m.reward_sum / m.env_steps as f32;
        first_r.get_or_insert(r_per_step);
        last_r = r_per_step;
        log.row(&[
            i.to_string(), env_steps.to_string(),
            format!("{:.4}", m.total_loss), format!("{:.4}", m.entropy),
            format!("{r_per_step:.5}"), m.trials.to_string(),
            format!("{:.0}",
                    env_steps as f64 / t0.elapsed().as_secs_f64()),
        ])?;
        if i % 10 == 0 || i == iters {
            println!(
                "iter {i:>4} | steps {env_steps:>8} | loss {:+.3} | \
                 ent {:.3} | r/step {:.4} | trials {:>5} | sps {}",
                m.total_loss, m.entropy, r_per_step, m.trials,
                fmt_sps(env_steps as f64 / t0.elapsed().as_secs_f64())
            );
        }
        if eval_every > 0 && i % eval_every == 0 {
            if let Some(ea) = &eval_artifact {
                let st = trainer.evaluate(&rt, ea, &bench, 1)?;
                println!("  eval @ {i}: return mean {:.3} P20 {:.3} \
                          per-trial {:.3}",
                         st.return_mean, st.return_p20, st.per_trial_mean);
            }
        }
    }

    if let Some(ea) = &eval_artifact {
        let st = trainer.evaluate(&rt, ea, &bench, 1)?;
        println!("after training: return mean {:.3} P20 {:.3}",
                 st.return_mean, st.return_p20);
    }
    println!(
        "\nreward/step first->last: {:.4} -> {:.4} | total env steps {} \
         in {:.1}s | curve: {:?}",
        first_r.unwrap_or(0.0), last_r, env_steps,
        t0.elapsed().as_secs_f64(), log_path
    );
    Ok(())
}
