#!/usr/bin/env python3
"""Advisory throughput diff between a freshly regenerated bench JSON and
the committed baseline.

Usage: compare_bench.py NEW_JSON BASELINE_JSON [--threshold 0.10]

Matches rows by label and compares `steps_per_sec` (falling back to the
older `sps` key for pre-rename baselines). Regressions beyond the
threshold are printed as GitHub Actions `::warning::` annotations;
improvements and small moves are listed informationally. Exits 0 always
— this step is advisory (CI marks it continue-on-error anyway): absolute
throughput on shared runners is noisy, so regressions flag for a human
rather than gate the merge.
"""
import json
import sys


def rows_by_label(path):
    """label -> (steps_per_sec, envs, steps). A throughput only means
    anything relative to its batch size, so envs rides along and rows
    measured at different batch sizes are never compared."""
    with open(path) as f:
        doc = json.load(f)
    out = {}
    for row in doc.get("rows", []):
        sps = row.get("steps_per_sec", row.get("sps"))
        if isinstance(sps, (int, float)) and sps > 0:
            out[row["label"]] = (float(sps), row.get("envs"),
                                 row.get("steps"))
    return out


def main(argv):
    if len(argv) < 3:
        print(__doc__)
        return 0
    new_path, base_path = argv[1], argv[2]
    threshold = 0.10
    if "--threshold" in argv:
        threshold = float(argv[argv.index("--threshold") + 1])

    new = rows_by_label(new_path)
    base = rows_by_label(base_path)
    if not base:
        print(f"baseline {base_path} has no measured rows; "
              "skipping the throughput diff (first measured run "
              "should be committed as the new baseline)")
        return 0
    if not new:
        print(f"::warning::{new_path} has no measured rows to compare")
        return 0

    regressions = 0
    compared = 0
    for label in sorted(new):
        n_sps, n_envs, n_steps = new[label]
        if label not in base:
            print(f"  {label:<34} new row ({n_sps:,.0f} steps/s)")
            continue
        b_sps, b_envs, b_steps = base[label]
        if n_envs != b_envs:
            # different batch size (e.g. CI smoke XMG_MAX_B vs a full
            # local run): throughputs are not comparable — skip, loudly
            print(f"  {label:<34} skipped: envs {b_envs} -> {n_envs} "
                  "(different benchmark config, not comparable)")
            continue
        compared += 1
        ratio = n_sps / b_sps
        note = ""
        if n_steps != b_steps:
            note = f"  [steps/chunk {b_steps} -> {n_steps}]"
        if ratio < 1.0 - threshold:
            regressions += 1
            print(f"::warning title=throughput regression::{label}: "
                  f"{b_sps:,.0f} -> {n_sps:,.0f} steps/s "
                  f"({(1.0 - ratio) * 100.0:.1f}% slower than the "
                  f"committed baseline)")
        print(f"  {label:<34} {b_sps:>14,.0f} -> "
              f"{n_sps:>14,.0f} steps/s  ({ratio:5.2f}x){note}")
    dropped = sorted(set(base) - set(new))
    for label in dropped:
        print(f"  {label:<34} missing from the new run")
    print(f"compared {compared} rows; "
          f"{regressions} regression(s) beyond "
          f"{threshold * 100:.0f}% (advisory)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
