//! PJRT runtime: loads AOT artifacts (HLO text), compiles them once on the
//! CPU PJRT client, and executes them from the L3 hot path.
//!
//! Interchange format is HLO *text* (see docs/ARCHITECTURE.md, "HLO-text interchange"): the
//! image's xla_extension 0.5.1 rejects jax>=0.5 serialized protos, while the
//! text parser reassigns instruction ids and round-trips cleanly.

pub mod manifest;
pub mod state;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

pub use manifest::{ArtifactSpec, DType, Manifest, TensorSpec};

/// Host-side tensor crossing the PJRT boundary.
#[derive(Clone, Debug, PartialEq)]
pub enum Tensor {
    I32(Vec<i32>),
    U32(Vec<u32>),
    F32(Vec<f32>),
}

impl Tensor {
    pub fn dtype(&self) -> DType {
        match self {
            Tensor::I32(_) => DType::I32,
            Tensor::U32(_) => DType::U32,
            Tensor::F32(_) => DType::F32,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            Tensor::I32(v) => v.len(),
            Tensor::U32(v) => v.len(),
            Tensor::F32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_i32(&self) -> &[i32] {
        match self {
            Tensor::I32(v) => v,
            _ => panic!("tensor is not i32"),
        }
    }

    /// Mutable i32 view — the host-side re-encode path (the xla
    /// backend's between-chunk task resampling rewrites ruleset rows
    /// in the resident state tensors).
    pub fn as_i32_mut(&mut self) -> &mut [i32] {
        match self {
            Tensor::I32(v) => v,
            _ => panic!("tensor is not i32"),
        }
    }

    pub fn as_u32(&self) -> &[u32] {
        match self {
            Tensor::U32(v) => v,
            _ => panic!("tensor is not u32"),
        }
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            Tensor::F32(v) => v,
            _ => panic!("tensor is not f32"),
        }
    }

    pub fn scalar_i32(&self) -> i32 {
        self.as_i32()[0]
    }

    pub fn scalar_f32(&self) -> f32 {
        self.as_f32()[0]
    }

    fn bytes(&self) -> &[u8] {
        unsafe {
            match self {
                Tensor::I32(v) => std::slice::from_raw_parts(
                    v.as_ptr() as *const u8, v.len() * 4),
                Tensor::U32(v) => std::slice::from_raw_parts(
                    v.as_ptr() as *const u8, v.len() * 4),
                Tensor::F32(v) => std::slice::from_raw_parts(
                    v.as_ptr() as *const u8, v.len() * 4),
            }
        }
    }

    fn to_literal(&self, dims: &[usize]) -> Result<xla::Literal> {
        let ty = match self {
            Tensor::I32(_) => xla::ElementType::S32,
            Tensor::U32(_) => xla::ElementType::U32,
            Tensor::F32(_) => xla::ElementType::F32,
        };
        let expect: usize = dims.iter().product();
        if expect != self.len() {
            bail!("tensor has {} elements, dims {:?} want {expect}",
                  self.len(), dims);
        }
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            ty, dims, self.bytes())?)
    }

    fn from_literal(lit: &xla::Literal, dtype: DType) -> Result<Tensor> {
        Ok(match dtype {
            DType::I32 => Tensor::I32(lit.to_vec::<i32>()?),
            DType::U32 => Tensor::U32(lit.to_vec::<u32>()?),
            DType::F32 => Tensor::F32(lit.to_vec::<f32>()?),
        })
    }
}

/// A compiled artifact ready to execute.
pub struct Artifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with host tensors; returns host tensors (aot.py lowers with
    /// `return_tuple=True`, so the single result buffer is untupled here).
    pub fn execute(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.spec.inputs.len() {
            bail!("{}: got {} inputs, want {}", self.spec.name,
                  inputs.len(), self.spec.inputs.len());
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (i, (t, spec)) in
            inputs.iter().zip(&self.spec.inputs).enumerate()
        {
            if t.dtype() != spec.dtype {
                bail!("{}: input {i} dtype {:?} want {:?}", self.spec.name,
                      t.dtype(), spec.dtype);
            }
            literals.push(t.to_literal(&spec.dims).with_context(|| {
                format!("{}: input {i}", self.spec.name)
            })?);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        if parts.len() != self.spec.outputs.len() {
            bail!("{}: got {} outputs, want {}", self.spec.name,
                  parts.len(), self.spec.outputs.len());
        }
        parts
            .iter()
            .zip(&self.spec.outputs)
            .map(|(lit, spec)| Tensor::from_literal(lit, spec.dtype))
            .collect()
    }
}

/// Artifact loader + compile cache around one PJRT CPU client.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<Artifact>>>,
}

impl Runtime {
    pub fn new(artifacts_dir: &Path) -> Result<Runtime> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Runtime {
            client,
            dir: artifacts_dir.to_path_buf(),
            manifest,
            cache: Mutex::new(HashMap::new()),
        })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact by manifest name (cached).
    pub fn load(&self, name: &str) -> Result<Arc<Artifact>> {
        if let Some(a) = self.cache.lock().unwrap().get(name) {
            return Ok(a.clone());
        }
        let spec = self.manifest.find(name)?.clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {name}"))?;
        let artifact = Arc::new(Artifact { spec, exe });
        self.cache
            .lock()
            .unwrap()
            .insert(name.to_string(), artifact.clone());
        Ok(artifact)
    }

    /// Warm the compile cache: load + compile every named artifact now,
    /// so the engines' first timed chunk measures stepping rather than
    /// HLO compilation.
    pub fn preload(&self, names: &[&str]) -> Result<()> {
        for name in names {
            self.load(name)?;
        }
        Ok(())
    }

    /// Initial network parameters written by aot.py (`params_init.bin`,
    /// f32, concatenated in `paramshapes` order).
    pub fn load_params_init(&self) -> Result<Vec<Tensor>> {
        load_params_init_from(&self.dir, &self.manifest)
    }
}

/// [`Runtime::load_params_init`] without a `Runtime`: reads
/// `params_init.bin` given the artifacts dir and a parsed manifest. The
/// sharded trainer's host thread uses this for its master copy — the
/// host coordinates but never owns a PJRT client; clients live one per
/// shard thread.
pub fn load_params_init_from(dir: &Path, manifest: &Manifest)
                             -> Result<Vec<Tensor>> {
    let path = dir.join("params_init.bin");
    let bytes = std::fs::read(&path)
        .with_context(|| format!("reading {path:?}"))?;
    let mut params = Vec::new();
    let mut off = 0usize;
    for (name, dims) in &manifest.param_shapes {
        let n: usize = dims.iter().product();
        let end = off + n * 4;
        if end > bytes.len() {
            bail!("params_init.bin truncated at {name}");
        }
        let vals: Vec<f32> = bytes[off..end]
            .chunks_exact(4)
            .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
            .collect();
        params.push(Tensor::F32(vals));
        off = end;
    }
    if off != bytes.len() {
        bail!("params_init.bin has trailing bytes");
    }
    Ok(params)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_accessors_and_bytes() {
        let t = Tensor::I32(vec![1, 2, 3]);
        assert_eq!(t.len(), 3);
        assert_eq!(t.dtype(), DType::I32);
        assert_eq!(t.as_i32(), &[1, 2, 3]);
        assert_eq!(t.bytes().len(), 12);
        let f = Tensor::F32(vec![1.5]);
        assert_eq!(f.scalar_f32(), 1.5);
    }

    #[test]
    fn literal_roundtrip() {
        let t = Tensor::F32(vec![1.0, 2.0, 3.0, 4.0]);
        let lit = t.to_literal(&[2, 2]).unwrap();
        let back = Tensor::from_literal(&lit, DType::F32).unwrap();
        assert_eq!(t, back);
    }

    #[test]
    fn literal_dims_must_match() {
        let t = Tensor::I32(vec![1, 2, 3]);
        assert!(t.to_literal(&[2, 2]).is_err());
    }

}
