//! Batched env-state packing: Rust structs <-> the 11 flat state tensors of
//! the AOT boundary (`aot.STATE_FIELDS` order).
//!
//! Field order: base_grid, grid, agent_pos, agent_dir, pocket, rules, goal,
//! init_tiles, step_count, key, max_steps.

use anyhow::{ensure, Result};

use crate::env::goals::Goal;
use crate::env::grid::Grid;
use crate::env::rules::Rule;
use crate::env::state::{Ruleset, State};
use crate::env::types::{GOAL_ENC, RULE_ENC};

use super::Tensor;

pub const NUM_STATE_FIELDS: usize = 11;

/// Encode a ruleset into padded arrays (rules [MR,7], goal [5],
/// init [MI,2]).
pub fn encode_ruleset(rs: &Ruleset, mr: usize, mi: usize)
                      -> Result<(Vec<i32>, Vec<i32>, Vec<i32>)> {
    ensure!(rs.rules.len() <= mr,
            "ruleset has {} rules > artifact capacity {mr}",
            rs.rules.len());
    ensure!(rs.init_tiles.len() <= mi,
            "ruleset has {} init objects > artifact capacity {mi}",
            rs.init_tiles.len());
    let mut rules = vec![0i32; mr * RULE_ENC];
    for (i, r) in rs.rules.iter().enumerate() {
        rules[i * RULE_ENC..(i + 1) * RULE_ENC].copy_from_slice(&r.0);
    }
    let goal = rs.goal.0.to_vec();
    let mut init = vec![0i32; mi * 2];
    for (i, c) in rs.init_tiles.iter().enumerate() {
        init[i * 2] = c.tile;
        init[i * 2 + 1] = c.color;
    }
    Ok((rules, goal, init))
}

/// Decode padded arrays back into a ruleset (zero rows are padding).
pub fn decode_ruleset(rules: &[i32], goal: &[i32], init: &[i32]) -> Ruleset {
    let rules = rules
        .chunks_exact(RULE_ENC)
        .filter(|c| c[0] != 0)
        .map(|c| Rule(c.try_into().unwrap()))
        .collect();
    let mut g = [0i32; GOAL_ENC];
    g.copy_from_slice(&goal[..GOAL_ENC]);
    let init = init
        .chunks_exact(2)
        .filter(|c| c[0] != 0)
        .map(|c| crate::env::Cell::new(c[0], c[1]))
        .collect();
    Ruleset { goal: Goal(g), rules, init_tiles: init }
}

/// Inputs for an `env_reset` artifact: one (base grid, ruleset, max_steps)
/// triple per env slot, plus PRNG key material.
pub fn reset_inputs(grids: &[Grid], rulesets: &[&Ruleset],
                    max_steps: &[i32], seeds: &[[u32; 2]], mr: usize,
                    mi: usize) -> Result<Vec<Tensor>> {
    let b = grids.len();
    ensure!(rulesets.len() == b && max_steps.len() == b && seeds.len() == b,
            "batch size mismatch");
    let mut key = Vec::with_capacity(b * 2);
    let mut base = Vec::new();
    let mut rules = Vec::new();
    let mut goal = Vec::new();
    let mut init = Vec::new();
    for i in 0..b {
        key.extend_from_slice(&seeds[i]);
        base.extend_from_slice(&grids[i].to_flat());
        let (r, g, it) = encode_ruleset(rulesets[i], mr, mi)?;
        rules.extend_from_slice(&r);
        goal.extend_from_slice(&g);
        init.extend_from_slice(&it);
    }
    Ok(vec![
        Tensor::U32(key),
        Tensor::I32(base),
        Tensor::I32(rules),
        Tensor::I32(goal),
        Tensor::I32(init),
        Tensor::I32(max_steps.to_vec()),
    ])
}

/// Pack a batch of pure-Rust env states into the 11 state tensors (used by
/// the cross-validation tests; `keys` supplies the JAX-side PRNG state).
pub fn pack_states(states: &[State], mr: usize, mi: usize,
                   keys: &[[u32; 2]]) -> Result<Vec<Tensor>> {
    let b = states.len();
    ensure!(keys.len() == b, "need one key per env");
    let mut base = Vec::new();
    let mut grid = Vec::new();
    let mut pos = Vec::with_capacity(b * 2);
    let mut dir = Vec::with_capacity(b);
    let mut pocket = Vec::with_capacity(b * 2);
    let mut rules = Vec::new();
    let mut goal = Vec::new();
    let mut init = Vec::new();
    let mut step_count = Vec::with_capacity(b);
    let mut key = Vec::with_capacity(b * 2);
    let mut max_steps = Vec::with_capacity(b);
    for (s, k) in states.iter().zip(keys) {
        base.extend_from_slice(&s.base_grid.to_flat());
        grid.extend_from_slice(&s.grid.to_flat());
        pos.push(s.agent_pos.0);
        pos.push(s.agent_pos.1);
        dir.push(s.agent_dir);
        pocket.push(s.pocket.tile);
        pocket.push(s.pocket.color);
        let (r, g, it) = encode_ruleset(&s.ruleset, mr, mi)?;
        rules.extend_from_slice(&r);
        goal.extend_from_slice(&g);
        init.extend_from_slice(&it);
        step_count.push(s.step_count);
        key.extend_from_slice(k);
        max_steps.push(s.max_steps);
    }
    Ok(vec![
        Tensor::I32(base),
        Tensor::I32(grid),
        Tensor::I32(pos),
        Tensor::I32(dir),
        Tensor::I32(pocket),
        Tensor::I32(rules),
        Tensor::I32(goal),
        Tensor::I32(init),
        Tensor::I32(step_count),
        Tensor::U32(key),
        Tensor::I32(max_steps),
    ])
}

/// View of one env's slice of unpacked state tensors.
pub struct StateView {
    pub grid: Grid,
    pub agent_pos: (i32, i32),
    pub agent_dir: i32,
    pub pocket: crate::env::Cell,
    pub step_count: i32,
}

/// Extract env `i`'s state from the 11 state tensors.
pub fn state_view(tensors: &[Tensor], i: usize, h: usize, w: usize)
                  -> StateView {
    let cells = h * w * 2;
    let grid = Grid::from_flat(
        h, w, &tensors[1].as_i32()[i * cells..(i + 1) * cells]);
    let pos = &tensors[2].as_i32()[i * 2..(i + 1) * 2];
    let dir = tensors[3].as_i32()[i];
    let pocket = &tensors[4].as_i32()[i * 2..(i + 1) * 2];
    StateView {
        grid,
        agent_pos: (pos[0], pos[1]),
        agent_dir: dir,
        pocket: crate::env::Cell::new(pocket[0], pocket[1]),
        step_count: tensors[8].as_i32()[i],
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::types::*;
    use crate::env::Cell;

    fn sample_ruleset() -> Ruleset {
        Ruleset {
            goal: Goal::agent_hold(Cell::new(TILE_BALL, COLOR_RED)),
            rules: vec![Rule::tile_near(
                Cell::new(TILE_BALL, COLOR_RED),
                Cell::new(TILE_SQUARE, COLOR_BLUE),
                Cell::new(TILE_HEX, COLOR_PINK),
            )],
            init_tiles: vec![Cell::new(TILE_BALL, COLOR_RED),
                             Cell::new(TILE_SQUARE, COLOR_BLUE)],
        }
    }

    #[test]
    fn ruleset_encode_decode_roundtrip() {
        let rs = sample_ruleset();
        let (r, g, i) = encode_ruleset(&rs, 4, 6).unwrap();
        assert_eq!(r.len(), 4 * RULE_ENC);
        assert_eq!(i.len(), 12);
        let back = decode_ruleset(&r, &g, &i);
        assert_eq!(back, rs);
    }

    #[test]
    fn capacity_overflow_rejected() {
        let rs = sample_ruleset();
        assert!(encode_ruleset(&rs, 0, 6).is_err());
        assert!(encode_ruleset(&rs, 4, 1).is_err());
    }

    #[test]
    fn reset_inputs_shapes() {
        let g = Grid::empty_room(9, 9);
        let rs = sample_ruleset();
        let inputs = reset_inputs(&[g.clone(), g], &[&rs, &rs],
                                  &[243, 243], &[[0, 1], [2, 3]], 3, 6)
            .unwrap();
        assert_eq!(inputs.len(), 6);
        assert_eq!(inputs[0].len(), 4); // keys 2x2
        assert_eq!(inputs[1].len(), 2 * 9 * 9 * 2);
        assert_eq!(inputs[2].len(), 2 * 3 * RULE_ENC);
        assert_eq!(inputs[5].as_i32(), &[243, 243]);
    }
}
