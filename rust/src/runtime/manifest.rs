//! Manifest parser for `artifacts/manifest.txt` — the line-oriented
//! contract written by `python/compile/aot.py` (no JSON dependency).

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Element type crossing the PJRT boundary.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum DType {
    I32,
    U32,
    F32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "i32" => DType::I32,
            "u32" => DType::U32,
            "f32" => DType::F32,
            other => bail!("unknown dtype {other}"),
        })
    }

    pub fn size_bytes(&self) -> usize {
        4
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl TensorSpec {
    pub fn num_elements(&self) -> usize {
        self.dims.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub meta: HashMap<String, String>,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

impl ArtifactSpec {
    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .with_context(|| format!("artifact {} missing meta {key}",
                                     self.name))?
            .parse()
            .with_context(|| format!("bad meta {key}"))
    }

    pub fn kind(&self) -> &str {
        self.meta.get("kind").map(|s| s.as_str()).unwrap_or("")
    }
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactSpec>,
    /// `name:d0,d1;...` parameter shape table from `paramshapes`.
    pub param_shapes: Vec<(String, Vec<usize>)>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let mut m = Manifest::default();
        let mut cur: Option<ArtifactSpec> = None;
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() {
                continue;
            }
            let mut it = line.splitn(2, ' ');
            let tag = it.next().unwrap();
            let rest = it.next().unwrap_or("");
            match tag {
                "paramshapes" => {
                    for part in rest.split(';') {
                        let (name, dims) = part
                            .split_once(':')
                            .with_context(|| format!("bad paramshapes: {part}"))?;
                        let dims = if dims.is_empty() {
                            vec![]
                        } else {
                            dims.split(',')
                                .map(|d| d.parse().context("bad dim"))
                                .collect::<Result<Vec<usize>>>()?
                        };
                        m.param_shapes.push((name.to_string(), dims));
                    }
                }
                "artifact" => {
                    if cur.is_some() {
                        bail!("line {lineno}: nested artifact");
                    }
                    let (name, file) = rest
                        .split_once(' ')
                        .with_context(|| format!("line {lineno}: bad artifact"))?;
                    cur = Some(ArtifactSpec {
                        name: name.to_string(),
                        file: file.to_string(),
                        meta: HashMap::new(),
                        inputs: vec![],
                        outputs: vec![],
                    });
                }
                "meta" => {
                    let a = cur.as_mut().context("meta outside artifact")?;
                    let (k, v) = rest.split_once(' ')
                        .with_context(|| format!("line {lineno}: bad meta"))?;
                    a.meta.insert(k.to_string(), v.to_string());
                }
                "in" | "out" => {
                    let a = cur.as_mut().context("io outside artifact")?;
                    let mut parts = rest.split(' ');
                    let _idx = parts.next().context("missing idx")?;
                    let dtype = DType::parse(parts.next().context("dtype")?)?;
                    let dims_s = parts.next().unwrap_or("");
                    let dims = if dims_s.is_empty() {
                        vec![]
                    } else {
                        dims_s
                            .split(',')
                            .map(|d| d.parse().context("bad dim"))
                            .collect::<Result<Vec<usize>>>()?
                    };
                    let spec = TensorSpec { dtype, dims };
                    if tag == "in" {
                        a.inputs.push(spec);
                    } else {
                        a.outputs.push(spec);
                    }
                }
                "end" => {
                    let a = cur.take().context("end outside artifact")?;
                    m.artifacts.push(a);
                }
                other => bail!("line {lineno}: unknown tag {other}"),
            }
        }
        if cur.is_some() {
            bail!("unterminated artifact entry");
        }
        Ok(m)
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path).with_context(|| {
            format!("reading {path:?}; run `make artifacts` first")
        })?;
        Self::parse(&text)
    }

    pub fn find(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts
            .iter()
            .find(|a| a.name == name)
            .with_context(|| {
                let names: Vec<_> =
                    self.artifacts.iter().map(|a| a.name.as_str()).collect();
                format!("artifact {name} not in manifest; have: {names:?}")
            })
    }

    /// All artifacts of a given kind (e.g. "env_rollout").
    pub fn of_kind(&self, kind: &str) -> Vec<&ArtifactSpec> {
        self.artifacts.iter().filter(|a| a.kind() == kind).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
paramshapes w1:4,8;b1:8
artifact env_step_g9x9_r3_b8 env_step_g9x9_r3_b8.hlo.txt
meta kind env_step
meta H 9
meta B 8
in 0 i32 8,9,9,2
in 1 u32 8,2
out 0 i32 8
out 1 f32 8
end
artifact policy_step_b8 policy_step_b8.hlo.txt
meta kind policy_step
in 0 f32 15,8
out 0 i32 8
end
";

    #[test]
    fn parses_artifacts() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.artifacts.len(), 2);
        let a = m.find("env_step_g9x9_r3_b8").unwrap();
        assert_eq!(a.kind(), "env_step");
        assert_eq!(a.meta_usize("H").unwrap(), 9);
        assert_eq!(a.inputs.len(), 2);
        assert_eq!(a.inputs[0].dims, vec![8, 9, 9, 2]);
        assert_eq!(a.inputs[1].dtype, DType::U32);
        assert_eq!(a.outputs[1].dtype, DType::F32);
    }

    #[test]
    fn parses_param_shapes() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.param_shapes.len(), 2);
        assert_eq!(m.param_shapes[0], ("w1".to_string(), vec![4, 8]));
    }

    #[test]
    fn of_kind_filters() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.of_kind("policy_step").len(), 1);
        assert_eq!(m.of_kind("nope").len(), 0);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("bogus line").is_err());
        assert!(Manifest::parse("artifact x").is_err());
        assert!(Manifest::parse("artifact a b.hlo\nmeta kind k").is_err());
    }

    #[test]
    fn missing_artifact_error_lists_names() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let err = format!("{:#}", m.find("missing").unwrap_err());
        assert!(err.contains("env_step_g9x9_r3_b8"));
    }

    #[test]
    fn tensor_spec_elements() {
        let t = TensorSpec { dtype: DType::I32, dims: vec![8, 9, 9, 2] };
        assert_eq!(t.num_elements(), 8 * 9 * 9 * 2);
        let s = TensorSpec { dtype: DType::F32, dims: vec![] };
        assert_eq!(s.num_elements(), 1);
    }
}
