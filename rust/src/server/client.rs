//! Client side of the rollout service: a [`Connection`] speaking the
//! framed protocol with per-request deadlines, and [`ServerClient`] —
//! a [`BatchEnvironment`] whose reset/step run on a remote server.
//!
//! Bitwise parity with the in-process native backend is carried by the
//! RNG state: `reset` ships the caller's `Rng` state in the request,
//! the server runs the *same* trait-surface reset the in-process pool
//! would, and the reply carries the post-reset state back, which the
//! client adopts. Action draws then happen client-side (in
//! `rollout_batch`), in exactly the order the fused native rollout
//! draws them — so `--backend server:ADDR` reproduces `--backend
//! native` totals and observations bit for bit.

use std::cell::RefCell;
use std::net::TcpStream;
#[cfg(unix)]
use std::os::unix::net::UnixStream;
use std::time::Duration;

use crate::env::api::{ActionSpec, BatchEnvironment, EnvParams,
                      ObsSpec};
use anyhow::{bail, Context, Result};
use crate::util::rng::Rng;

use super::protocol::{
    code, decode_error_body, read_frame, write_frame, BodyReader,
    BodyWriter, Frame, Kind,
};
use super::Stream;

/// Where a server lives. `server:` backend strings parse as: a path
/// (contains `/` or ends in `.sock`) is a unix socket; anything else
/// is a TCP `host:port`. Explicit `unix:PATH` / `tcp:HOST:PORT`
/// prefixes are also accepted.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum ServerAddr {
    Tcp(String),
    Unix(String),
}

impl ServerAddr {
    pub fn parse(s: &str) -> Result<ServerAddr> {
        if s.is_empty() {
            bail!(
                "empty server address — use server:HOST:PORT or \
                 server:/path/to.sock"
            );
        }
        if let Some(p) = s.strip_prefix("unix:") {
            return Ok(ServerAddr::Unix(p.to_string()));
        }
        if let Some(hp) = s.strip_prefix("tcp:") {
            return Ok(ServerAddr::Tcp(hp.to_string()));
        }
        if s.contains('/') || s.ends_with(".sock") {
            return Ok(ServerAddr::Unix(s.to_string()));
        }
        if !s.contains(':') {
            bail!(
                "server address `{s}` is neither HOST:PORT nor a \
                 socket path (paths contain `/`)"
            );
        }
        Ok(ServerAddr::Tcp(s.to_string()))
    }
}

impl std::fmt::Display for ServerAddr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>)
           -> std::fmt::Result {
        match self {
            ServerAddr::Tcp(a) => write!(f, "tcp:{a}"),
            ServerAddr::Unix(p) => write!(f, "unix:{p}"),
        }
    }
}

/// The environment a `Hello` asks the server to build.
#[derive(Clone, Debug)]
pub struct SessionSpec {
    pub env: String,
    pub benchmark: String,
    pub b: usize,
    pub t: usize,
    /// Server-side stepping threads for this session's pool.
    pub threads: usize,
}

/// One framed connection with request/reply bookkeeping. Every read
/// and write carries `deadline_ms`; a late reply is a structured
/// `deadline` error naming the request, never a hung caller.
pub struct Connection {
    stream: Stream,
    session: u64,
    next_req: u64,
    deadline_ms: u64,
}

impl Connection {
    pub fn connect(addr: &ServerAddr, deadline_ms: u64)
                   -> Result<Connection> {
        let stream = match addr {
            ServerAddr::Tcp(a) => Stream::Tcp(
                TcpStream::connect(a)
                    .with_context(|| format!("connecting {addr}"))?,
            ),
            #[cfg(unix)]
            ServerAddr::Unix(p) => Stream::Unix(
                UnixStream::connect(p)
                    .with_context(|| format!("connecting {addr}"))?,
            ),
            #[cfg(not(unix))]
            ServerAddr::Unix(_) => bail!(
                "unix sockets are unavailable on this platform — use \
                 server:HOST:PORT"
            ),
        };
        let d = Duration::from_millis(deadline_ms.max(1));
        stream.set_read_timeout(Some(d))?;
        stream.set_write_timeout(Some(d))?;
        Ok(Connection { stream, session: 0, next_req: 0, deadline_ms })
    }

    /// The server-assigned session id (0 until `hello`).
    pub fn session(&self) -> u64 {
        self.session
    }

    /// Fire a request frame without awaiting the reply — the raw
    /// surface backpressure tests use to overfill a session queue.
    /// Returns the request id.
    pub fn send_raw(&mut self, kind: Kind, body: Vec<u8>)
                    -> Result<u64> {
        let req = self.next_req;
        self.next_req += 1;
        let f = Frame::new(kind, self.session, req, body);
        write_frame(&mut self.stream, &f)
            .with_context(|| format!("sending req {req}"))?;
        Ok(req)
    }

    /// Await one frame (any kind), honoring the connection deadline.
    pub fn recv_raw(&mut self) -> Result<Frame> {
        read_frame(&mut self.stream).map_err(|e| {
            let msg = format!("{e:#}");
            if msg.contains(super::protocol::ERR_DEADLINE) {
                e.context(format!(
                    "deadline: no reply within {} ms",
                    self.deadline_ms
                ))
            } else {
                e
            }
        })
    }

    /// Send `kind` and await its reply. `Error` frames become
    /// structured errors naming the server's error code; an unexpected
    /// reply kind is a protocol error.
    pub fn request(&mut self, kind: Kind, body: Vec<u8>, expect: Kind)
                   -> Result<Vec<u8>> {
        let req = self.send_raw(kind, body)?;
        let reply = self
            .recv_raw()
            .with_context(|| format!("awaiting reply to req {req}"))?;
        if reply.kind == Kind::Error {
            let (c, msg) = decode_error_body(&reply.body);
            bail!("server error ({}): {msg}", code::name(c));
        }
        if reply.kind != expect {
            bail!(
                "protocol error: expected {expect:?} for req {req}, \
                 got {:?}",
                reply.kind
            );
        }
        Ok(reply.body)
    }

    /// Open a session: the server builds this session's private pool
    /// and replies with the family geometry.
    pub fn hello(&mut self, spec: &SessionSpec) -> Result<EnvParams> {
        let mut w = BodyWriter::new();
        w.str(&spec.env)
            .str(&spec.benchmark)
            .u32(spec.b as u32)
            .u32(spec.t as u32)
            .u32(spec.threads as u32);
        let req = self.send_raw(Kind::Hello, w.finish())?;
        let reply = self
            .recv_raw()
            .with_context(|| format!("awaiting HelloOk (req {req})"))?;
        if reply.kind == Kind::Error {
            let (c, msg) = decode_error_body(&reply.body);
            bail!("server error ({}): {msg}", code::name(c));
        }
        if reply.kind != Kind::HelloOk {
            bail!("protocol error: expected HelloOk, got {:?}",
                  reply.kind);
        }
        self.session = reply.session;
        let mut r = BodyReader::new(&reply.body);
        let h = r.u32("h")? as usize;
        let w_ = r.u32("w")? as usize;
        let mr = r.u32("max_rules")? as usize;
        let mi = r.u32("max_init")? as usize;
        Ok(EnvParams::new(h, w_, mr, mi))
    }

    /// Polite close: the server tears the session down immediately
    /// instead of waiting for the idle deadline.
    pub fn bye(mut self) {
        if self.send_raw(Kind::Bye, Vec::new()).is_ok() {
            let _ = self.recv_raw();
        }
    }
}

/// Ask the server at `addr` to drain gracefully (the wire-level
/// equivalent of SIGTERM): in-flight work completes, new requests are
/// refused, `serve` returns.
pub fn request_shutdown(addr: &ServerAddr, deadline_ms: u64)
                        -> Result<()> {
    let mut conn = Connection::connect(addr, deadline_ms)?;
    let req = conn.send_raw(Kind::Shutdown, Vec::new())?;
    let reply = conn
        .recv_raw()
        .with_context(|| format!("awaiting ShutdownOk (req {req})"))?;
    if reply.kind != Kind::ShutdownOk {
        bail!("protocol error: expected ShutdownOk, got {:?}",
              reply.kind);
    }
    Ok(())
}

/// A remote session as a [`BatchEnvironment`]. Wrap it in the usual
/// observation wrappers (`ObsMode::wrap`) and drive it with
/// `rollout_batch` — the obs pipeline runs client-side, only raw
/// reset/step cross the wire.
pub struct ServerClient {
    conn: RefCell<Connection>,
    params: EnvParams,
    b: usize,
    /// First error from a `&self` RPC (`agent_dirs_into` /
    /// `task_rows_into` cannot return one); the next fallible call
    /// surfaces it instead of silently continuing on a desynced
    /// connection.
    deferred_err: RefCell<Option<String>>,
}

impl ServerClient {
    /// Connect and open a session in one move.
    pub fn connect_session(addr: &ServerAddr, spec: &SessionSpec,
                           deadline_ms: u64) -> Result<ServerClient> {
        let mut conn = Connection::connect(addr, deadline_ms)?;
        let params = conn.hello(spec)?;
        Ok(ServerClient {
            conn: RefCell::new(conn),
            params,
            b: spec.b,
            deferred_err: RefCell::new(None),
        })
    }

    /// The server-assigned session id.
    pub fn session(&self) -> u64 {
        self.conn.borrow().session()
    }

    fn take_deferred(&self) -> Result<()> {
        if let Some(msg) = self.deferred_err.borrow_mut().take() {
            bail!("deferred client error: {msg}");
        }
        Ok(())
    }

    fn rpc(&self, kind: Kind, body: Vec<u8>, expect: Kind)
           -> Result<Vec<u8>> {
        self.conn.borrow_mut().request(kind, body, expect)
    }
}

impl BatchEnvironment for ServerClient {
    fn batch(&self) -> usize {
        self.b
    }

    fn obs_spec(&self) -> ObsSpec {
        self.params.obs_spec()
    }

    fn action_spec(&self) -> ActionSpec {
        self.params.action_spec()
    }

    fn max_rules(&self) -> usize {
        self.params.max_rules
    }

    fn reset(&mut self, rng: &mut Rng, obs_out: &mut [i32])
             -> Result<()> {
        self.take_deferred()?;
        let mut w = BodyWriter::new();
        for s in rng.state() {
            w.u64(s);
        }
        let body = self.rpc(Kind::Reset, w.finish(), Kind::ResetOk)?;
        let mut r = BodyReader::new(&body);
        let state = [
            r.u64("rng[0]")?,
            r.u64("rng[1]")?,
            r.u64("rng[2]")?,
            r.u64("rng[3]")?,
        ];
        let obs = r.i32s("obs")?;
        if obs.len() != obs_out.len() {
            bail!(
                "reset reply carries {} obs values, caller buffer \
                 holds {}",
                obs.len(),
                obs_out.len()
            );
        }
        obs_out.copy_from_slice(&obs);
        *rng = Rng::from_state(state);
        Ok(())
    }

    fn step(&mut self, actions: &[i32], obs_out: &mut [i32],
            rewards: &mut [f32], dones: &mut [bool],
            trial_dones: &mut [bool]) -> Result<()> {
        self.take_deferred()?;
        let mut w = BodyWriter::new();
        w.i32s(actions);
        let body = self.rpc(Kind::Step, w.finish(), Kind::StepOk)?;
        let mut r = BodyReader::new(&body);
        let obs = r.i32s("obs")?;
        let rew = r.f32s("rewards")?;
        let dn = r.bools("dones")?;
        let td = r.bools("trial_dones")?;
        if obs.len() != obs_out.len()
            || rew.len() != rewards.len()
            || dn.len() != dones.len()
            || td.len() != trial_dones.len()
        {
            bail!(
                "step reply sizes (obs {}, rewards {}, dones {}, \
                 trial_dones {}) do not match caller buffers",
                obs.len(),
                rew.len(),
                dn.len(),
                td.len()
            );
        }
        obs_out.copy_from_slice(&obs);
        rewards.copy_from_slice(&rew);
        dones.copy_from_slice(&dn);
        trial_dones.copy_from_slice(&td);
        Ok(())
    }

    fn agent_dirs_into(&self, out: &mut [i32]) {
        match self
            .rpc(Kind::AgentDirs, Vec::new(), Kind::AgentDirsOk)
            .and_then(|body| {
                BodyReader::new(&body).i32s("agent dirs")
            }) {
            Ok(dirs) if dirs.len() == out.len() => {
                out.copy_from_slice(&dirs)
            }
            Ok(dirs) => {
                out.fill(0);
                *self.deferred_err.borrow_mut() = Some(format!(
                    "agent_dirs reply carried {} values for a batch \
                     of {}",
                    dirs.len(),
                    out.len()
                ));
            }
            Err(e) => {
                out.fill(0);
                *self.deferred_err.borrow_mut() =
                    Some(format!("agent_dirs rpc failed: {e:#}"));
            }
        }
    }

    fn task_rows_into(&self, out: &mut [i32]) {
        match self
            .rpc(Kind::TaskRows, Vec::new(), Kind::TaskRowsOk)
            .and_then(|body| {
                BodyReader::new(&body).i32s("task rows")
            }) {
            Ok(rows) if rows.len() == out.len() => {
                out.copy_from_slice(&rows)
            }
            Ok(rows) => {
                out.fill(0);
                *self.deferred_err.borrow_mut() = Some(format!(
                    "task_rows reply carried {} values, expected {}",
                    rows.len(),
                    out.len()
                ));
            }
            Err(e) => {
                out.fill(0);
                *self.deferred_err.borrow_mut() =
                    Some(format!("task_rows rpc failed: {e:#}"));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_parse_shapes() {
        assert_eq!(
            ServerAddr::parse("127.0.0.1:7777").unwrap(),
            ServerAddr::Tcp("127.0.0.1:7777".into())
        );
        assert_eq!(
            ServerAddr::parse("/tmp/xmgrid.sock").unwrap(),
            ServerAddr::Unix("/tmp/xmgrid.sock".into())
        );
        assert_eq!(
            ServerAddr::parse("run.sock").unwrap(),
            ServerAddr::Unix("run.sock".into())
        );
        assert_eq!(
            ServerAddr::parse("tcp:localhost:9").unwrap(),
            ServerAddr::Tcp("localhost:9".into())
        );
        assert_eq!(
            ServerAddr::parse("unix:x/y").unwrap(),
            ServerAddr::Unix("x/y".into())
        );
        assert!(ServerAddr::parse("").is_err());
        assert!(ServerAddr::parse("localhost").is_err());
    }
}
