//! One client session: a reader thread that frames requests off the
//! socket into a *bounded* queue, and a worker thread that owns this
//! session's private [`NativePool`] replica and serves them. The two
//! threads and the pool are the session's entire blast radius — a
//! panic, stall, or vanished peer here cannot touch any other session.
//!
//! Robustness contracts (pinned by `tests/server_faults.rs`):
//!
//! - **Isolation.** Each session allocates its own pool on `Hello`
//!   (own envs, own task table, own stepping threads). Worker panics
//!   are caught per-request; the session replies a structured
//!   `internal` error and tears itself down. Nothing is shared with
//!   other sessions but the immutable benchmark registry.
//! - **Deadlines.** The socket read runs on a short poll tick; a
//!   mid-frame stall or an idle gap past `idle_timeout_ms` surfaces as
//!   a structured `timeout` error, then teardown. Writes carry
//!   `io_deadline_ms`. No blocking read or write is unbounded.
//! - **Backpressure.** The request queue holds `queue_depth` frames.
//!   When it is full the reader *replies immediately* with a
//!   `backpressure` error naming the refused request — never an
//!   unbounded buffer, never a silent drop.
//! - **Drain.** When the server-wide drain flag rises, queued and
//!   in-flight requests complete with normal replies; frames read
//!   after that get a `draining` error; the reader exits at the next
//!   idle tick and both threads join.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, TrySendError};
use std::sync::{Arc, Mutex, PoisonError};
use std::time::Duration;

use crate::benchgen::store::load_benchmark_with;
use crate::benchgen::Benchmark;
use crate::coordinator::metrics::WallTimer;
use crate::coordinator::{NativeEnvConfig, NativePool};
use crate::env::api::BatchEnvironment;
use anyhow::{bail, Result};
use crate::util::rng::Rng;

use super::protocol::{
    code, error_body, read_frame_opt, write_frame, BodyReader,
    BodyWriter, Frame, Kind, ERR_DEADLINE, ERR_IDLE,
};
use super::{ServeConfig, Stream};

/// Read-poll tick: the granularity at which an otherwise-blocked
/// reader notices the drain flag and accumulates idle time.
const POLL_TICK_MS: u64 = 100;

/// State shared between the server accept loop and every session.
#[derive(Clone)]
pub(crate) struct SessionShared {
    pub cfg: Arc<ServeConfig>,
    pub drain: Arc<AtomicBool>,
    /// name -> preloaded benchmark (tests preload; the CLI path loads
    /// through the store on first use).
    pub benchmarks: Arc<Mutex<Vec<(String, Arc<Benchmark>)>>>,
    pub requests_served: Arc<AtomicU64>,
}

/// Recover a mutex guard even if another session thread panicked while
/// holding it — poisoning must not cascade across sessions.
fn lock_unpoisoned<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

fn send_error(writer: &Mutex<Stream>, session: u64, req: u64,
              code_: u32, msg: &str) {
    let f = Frame::new(Kind::Error, session, req,
                       error_body(code_, msg));
    // Best-effort: the peer may already be gone.
    let mut w = lock_unpoisoned(writer);
    let _ = write_frame(&mut *w, &f);
}

/// Run one session to completion. Called on the session's own thread;
/// spawns the worker internally and joins it before returning.
pub(crate) fn run_session(id: u64, mut stream: Stream,
                          shared: SessionShared) {
    let writer = match stream.try_clone() {
        Ok(w) => Arc::new(Mutex::new(w)),
        Err(_) => return, // socket already dead; nothing to clean up
    };
    let _ = stream
        .set_read_timeout(Some(Duration::from_millis(POLL_TICK_MS)));
    {
        let w = lock_unpoisoned(&writer);
        let _ = w.set_write_timeout(Some(Duration::from_millis(
            shared.cfg.io_deadline_ms.max(1),
        )));
    }

    let (tx, rx) =
        std::sync::mpsc::sync_channel::<Frame>(shared.cfg.queue_depth);
    let worker = {
        let writer = Arc::clone(&writer);
        let shared = shared.clone();
        std::thread::spawn(move || worker_loop(id, rx, writer, shared))
    };

    let mut idle_ms = 0u64;
    let mut draining = false;
    loop {
        if shared.drain.load(Ordering::SeqCst) {
            draining = true;
        }
        match read_frame_opt(&mut stream) {
            Ok(None) => break, // peer closed cleanly
            Ok(Some(f)) => {
                idle_ms = 0;
                match f.kind {
                    Kind::Bye => {
                        let bye = Frame::new(Kind::ByeOk, id, f.req,
                                             Vec::new());
                        let mut w = lock_unpoisoned(&writer);
                        let _ = write_frame(&mut *w, &bye);
                        break;
                    }
                    Kind::Shutdown => {
                        // Graceful drain request: acknowledge, raise
                        // the server-wide flag. In-flight work still
                        // completes below.
                        shared.drain.store(true, Ordering::SeqCst);
                        draining = true;
                        let okf = Frame::new(Kind::ShutdownOk, id,
                                             f.req, Vec::new());
                        let mut w = lock_unpoisoned(&writer);
                        let _ = write_frame(&mut *w, &okf);
                    }
                    Kind::Hello | Kind::Reset | Kind::Step
                    | Kind::AgentDirs | Kind::TaskRows => {
                        if draining {
                            send_error(
                                &writer, id, f.req, code::DRAINING,
                                &format!(
                                    "server is draining — req {} \
                                     refused, no new work accepted",
                                    f.req
                                ),
                            );
                            continue;
                        }
                        let req = f.req;
                        match tx.try_send(f) {
                            Ok(()) => {}
                            Err(TrySendError::Full(_)) => send_error(
                                &writer, id, req, code::BACKPRESSURE,
                                &format!(
                                    "session {id} queue full (depth \
                                     {}) — req {req} refused, resend \
                                     after a reply arrives",
                                    shared.cfg.queue_depth
                                ),
                            ),
                            Err(TrySendError::Disconnected(_)) => break,
                        }
                    }
                    other => {
                        send_error(
                            &writer, id, f.req, code::BAD_REQUEST,
                            &format!(
                                "frame kind {other:?} is a reply kind \
                                 — clients send requests only"
                            ),
                        );
                    }
                }
            }
            Err(e) => {
                let msg = format!("{e:#}");
                if msg.contains(ERR_IDLE) {
                    // poll tick between frames: not an error yet
                    idle_ms += POLL_TICK_MS;
                    if draining {
                        break; // drained and idle: session is done
                    }
                    if idle_ms >= shared.cfg.idle_timeout_ms {
                        send_error(
                            &writer, id, 0, code::TIMEOUT,
                            &format!(
                                "session {id} idle deadline \
                                 ({} ms) exceeded",
                                shared.cfg.idle_timeout_ms
                            ),
                        );
                        break;
                    }
                } else if msg.contains(ERR_DEADLINE) {
                    // stalled mid-frame: a per-request deadline breach
                    send_error(
                        &writer, id, 0, code::TIMEOUT,
                        &format!("session {id}: {msg}"),
                    );
                    break;
                } else {
                    // malformed frame or transport error; the stream
                    // position is unknown, so reply and resync by
                    // closing.
                    send_error(
                        &writer, id, 0, code::MALFORMED,
                        &format!("session {id}: {msg}"),
                    );
                    break;
                }
            }
        }
    }
    drop(tx); // closes the queue; the worker finishes what's in flight
    let _ = worker.join();
    let _ = stream.shutdown();
}

/// Per-session environment state, created by `Hello`.
struct PoolState {
    pool: NativePool,
    obs: Vec<i32>,
    rewards: Vec<f32>,
    dones: Vec<bool>,
    trial_dones: Vec<bool>,
    b: usize,
    row_len: usize,
}

fn worker_loop(id: u64, rx: Receiver<Frame>,
               writer: Arc<Mutex<Stream>>, shared: SessionShared) {
    let mut st: Option<PoolState> = None;
    let timer = WallTimer::start();
    let mut served = 0u64;
    for f in rx.iter() {
        // Fault hooks (XMG_FAULTS): deterministic stand-ins for a
        // stalled worker, a kill-9'd connection, and a torn reply.
        if let Some(ms) = shared.cfg.faults.server_stall_ms(id) {
            std::thread::sleep(Duration::from_millis(ms));
        }
        if shared.cfg.faults.server_drop_conn(id, f.req) {
            let w = lock_unpoisoned(&writer);
            let _ = w.shutdown(); // both halves: the hard-kill shape
            break;
        }
        let torn = shared.cfg.faults.server_torn_frame(id);
        let req = f.req;
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            handle_request(id, &mut st, &f, &shared)
        }));
        match outcome {
            Ok(Ok(reply)) => {
                served += 1;
                shared.requests_served.fetch_add(1, Ordering::Relaxed);
                let mut w = lock_unpoisoned(&writer);
                if torn {
                    // write half the encoded reply, then cut the
                    // stream — the client must see a structured
                    // truncation error, never hang or desync.
                    let bytes =
                        super::protocol::encode_frame(&reply);
                    use std::io::Write;
                    let half = bytes.len() / 2;
                    let _ = w.write_all(&bytes[..half]);
                    let _ = w.flush();
                    let _ = w.shutdown();
                    break;
                }
                let _ = write_frame(&mut *w, &reply);
            }
            Ok(Err(e)) => {
                // Structured failure (bad request, unknown benchmark,
                // step error): reply and keep serving — handle() fails
                // before mutating state.
                send_error(&writer, id, req, code::BAD_REQUEST,
                           &format!("{e:#}"));
            }
            Err(panic) => {
                let what = panic_msg(&panic);
                send_error(
                    &writer, id, req, code::INTERNAL,
                    &format!(
                        "session {id} worker panicked serving req \
                         {req}: {what} — session torn down, other \
                         sessions unaffected"
                    ),
                );
                break;
            }
        }
    }
    if served > 0 {
        eprintln!(
            "[serve] session {id}: {served} requests in {:.3}s",
            timer.elapsed_secs()
        );
    }
}

fn panic_msg(p: &Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Decode, execute, and encode one request against this session's
/// pool. Errors are structured and *pre-mutation*: a failed request
/// leaves the pool exactly as it was.
fn handle_request(id: u64, st: &mut Option<PoolState>, f: &Frame,
                  shared: &SessionShared) -> Result<Frame> {
    match f.kind {
        Kind::Hello => {
            let mut r = BodyReader::new(&f.body);
            let env = r.str("env name")?;
            let bench_name = r.str("benchmark name")?;
            let b = r.u32("batch")? as usize;
            let t = r.u32("steps")? as usize;
            let threads = (r.u32("threads")? as usize).max(1);
            let bench = resolve_benchmark(&bench_name, threads,
                                          shared)?;
            let ncfg = NativeEnvConfig::for_env(&env, b, t, &bench)?
                .with_threads(threads);
            let params = ncfg.params;
            let pool = NativePool::with_tasks(ncfg, bench);
            let obs_len = pool.obs_len();
            *st = Some(PoolState {
                pool,
                obs: vec![0; obs_len],
                rewards: vec![0.0; b],
                dones: vec![false; b],
                trial_dones: vec![false; b],
                b,
                row_len: params.task_row_len(),
            });
            let mut w = BodyWriter::new();
            w.u32(params.h as u32)
                .u32(params.w as u32)
                .u32(params.max_rules as u32)
                .u32(params.max_init as u32);
            Ok(Frame::new(Kind::HelloOk, id, f.req, w.finish()))
        }
        Kind::Reset => {
            let st = need_pool(st)?;
            let mut r = BodyReader::new(&f.body);
            let state = [
                r.u64("rng[0]")?,
                r.u64("rng[1]")?,
                r.u64("rng[2]")?,
                r.u64("rng[3]")?,
            ];
            let mut rng = Rng::from_state(state);
            // Trait-surface reset (qualified — the inherent
            // `NativePool::reset(bench, rng)` would shadow it):
            // bitwise-identical to the in-process pool, pinned by
            // trait_surface_matches_inherent_pool.
            BatchEnvironment::reset(&mut st.pool, &mut rng,
                                    &mut st.obs)?;
            let mut w = BodyWriter::new();
            for s in rng.state() {
                w.u64(s);
            }
            w.i32s(&st.obs);
            Ok(Frame::new(Kind::ResetOk, id, f.req, w.finish()))
        }
        Kind::Step => {
            let st = need_pool(st)?;
            let mut r = BodyReader::new(&f.body);
            let actions = r.i32s("actions")?;
            if actions.len() != st.b {
                bail!(
                    "req {}: {} actions for a batch of {}",
                    f.req,
                    actions.len(),
                    st.b
                );
            }
            st.pool.step(&actions, &mut st.obs, &mut st.rewards,
                         &mut st.dones, &mut st.trial_dones)?;
            let mut w = BodyWriter::new();
            w.i32s(&st.obs)
                .f32s(&st.rewards)
                .bools(&st.dones)
                .bools(&st.trial_dones);
            Ok(Frame::new(Kind::StepOk, id, f.req, w.finish()))
        }
        Kind::AgentDirs => {
            let st = need_pool(st)?;
            let mut dirs = vec![0i32; st.b];
            st.pool.agent_dirs_into(&mut dirs);
            let mut w = BodyWriter::new();
            w.i32s(&dirs);
            Ok(Frame::new(Kind::AgentDirsOk, id, f.req, w.finish()))
        }
        Kind::TaskRows => {
            let st = need_pool(st)?;
            let mut rows = vec![0i32; st.b * st.row_len];
            st.pool.task_rows_into(&mut rows);
            let mut w = BodyWriter::new();
            w.i32s(&rows);
            Ok(Frame::new(Kind::TaskRowsOk, id, f.req, w.finish()))
        }
        other => bail!("kind {other:?} reached the worker (bug)"),
    }
}

fn need_pool(st: &mut Option<PoolState>) -> Result<&mut PoolState> {
    match st {
        Some(p) => Ok(p),
        None => bail!("no session environment — send Hello first"),
    }
}

fn resolve_benchmark(name: &str, threads: usize,
                     shared: &SessionShared) -> Result<Arc<Benchmark>> {
    {
        let reg = lock_unpoisoned(&shared.benchmarks);
        if let Some((_, b)) = reg.iter().find(|(n, _)| n == name) {
            return Ok(Arc::clone(b));
        }
    }
    let loaded = Arc::new(load_benchmark_with(name, threads)?);
    let mut reg = lock_unpoisoned(&shared.benchmarks);
    if let Some((_, b)) = reg.iter().find(|(n, _)| n == name) {
        return Ok(Arc::clone(b)); // another session raced the load
    }
    reg.push((name.to_string(), Arc::clone(&loaded)));
    Ok(loaded)
}
