//! Framed wire protocol for the rollout service — the same codec
//! discipline as the checkpoint format (magic + version +
//! length-prefix + FNV-1a checksum, bounded reads), applied to a
//! socket: a peer that sends garbage gets a structured error naming
//! the byte offset, never a panic, a desync, or an unbounded
//! allocation.
//!
//! Frame layout (all integers little-endian):
//!
//! ```text
//! offset  0  magic      b"XMGS"            (4 bytes)
//! offset  4  version    u32                (4 bytes)
//! offset  8  kind       u8                 (1 byte)
//! offset  9  session    u64                (8 bytes)
//! offset 17  req        u64                (8 bytes)
//! offset 25  body_len   u64                (8 bytes, <= MAX_BODY)
//! offset 33  body       body_len bytes
//! offset 33+body_len    checksum u64       FNV-1a over bytes [0, 33+len)
//! ```
//!
//! `body_len` is validated against [`MAX_BODY`] *before* any
//! allocation, so an adversarial length prefix (`u64::MAX`) costs
//! nothing. Body decoding goes through [`BodyReader`], which caps
//! every count field by the bytes actually remaining — the reader can
//! reject, but it can never over-allocate or read past the frame.

use std::io::{ErrorKind, Read, Write};

use anyhow::{bail, Context, Result};

pub const MAGIC: [u8; 4] = *b"XMGS";
pub const VERSION: u32 = 1;
/// Fixed header bytes before the body: magic(4) version(4) kind(1)
/// session(8) req(8) body_len(8).
pub const HEADER_LEN: usize = 33;
/// Hard cap on a frame body. Checked before allocation; a Step frame
/// for B=65536 envs at view 5 is ~13 MiB, so 64 MiB clears every real
/// workload with headroom.
pub const MAX_BODY: u64 = 64 << 20;

/// Byte offsets of the header fields (named so decode errors and the
/// docs agree by construction).
pub const OFF_MAGIC: usize = 0;
pub const OFF_VERSION: usize = 4;
pub const OFF_KIND: usize = 8;
pub const OFF_SESSION: usize = 9;
pub const OFF_REQ: usize = 17;
pub const OFF_LEN: usize = 25;
pub const OFF_BODY: usize = 33;

/// Stable marker in mid-frame deadline errors (a socket read timeout
/// fired while a frame was partially read) — sessions use it to tell
/// a stalled peer apart from a malformed one.
pub const ERR_DEADLINE: &str = "deadline exceeded";
/// Stable marker for the benign between-frames poll timeout (zero
/// bytes of the next frame read yet).
pub const ERR_IDLE: &str = "deadline exceeded waiting for frame";

/// Frame kinds. Requests are odd-ball client->server, `*Ok` replies
/// echo the request's `req` id; `Error` replies carry a [`code`] and a
/// message.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u8)]
pub enum Kind {
    Hello = 1,
    HelloOk = 2,
    Reset = 3,
    ResetOk = 4,
    Step = 5,
    StepOk = 6,
    AgentDirs = 7,
    AgentDirsOk = 8,
    TaskRows = 9,
    TaskRowsOk = 10,
    Bye = 11,
    ByeOk = 12,
    Shutdown = 13,
    ShutdownOk = 14,
    Error = 15,
}

impl Kind {
    pub fn from_u8(v: u8) -> Option<Kind> {
        Some(match v {
            1 => Kind::Hello,
            2 => Kind::HelloOk,
            3 => Kind::Reset,
            4 => Kind::ResetOk,
            5 => Kind::Step,
            6 => Kind::StepOk,
            7 => Kind::AgentDirs,
            8 => Kind::AgentDirsOk,
            9 => Kind::TaskRows,
            10 => Kind::TaskRowsOk,
            11 => Kind::Bye,
            12 => Kind::ByeOk,
            13 => Kind::Shutdown,
            14 => Kind::ShutdownOk,
            15 => Kind::Error,
            _ => return None,
        })
    }
}

/// Stable error codes carried by `Kind::Error` bodies (u32 + message).
/// Clients surface these as structured errors whose text names the
/// code, so tests and operators can match on them.
pub mod code {
    pub const MALFORMED: u32 = 1;
    pub const TIMEOUT: u32 = 2;
    pub const BACKPRESSURE: u32 = 3;
    pub const DRAINING: u32 = 4;
    pub const INTERNAL: u32 = 5;
    pub const BAD_REQUEST: u32 = 6;

    /// Human name for a code — the stable token error text carries.
    pub fn name(c: u32) -> &'static str {
        match c {
            MALFORMED => "malformed",
            TIMEOUT => "timeout",
            BACKPRESSURE => "backpressure",
            DRAINING => "draining",
            INTERNAL => "internal",
            BAD_REQUEST => "bad-request",
            _ => "unknown",
        }
    }
}

#[derive(Clone, Debug)]
pub struct Frame {
    pub kind: Kind,
    pub session: u64,
    pub req: u64,
    pub body: Vec<u8>,
}

impl Frame {
    pub fn new(kind: Kind, session: u64, req: u64, body: Vec<u8>)
               -> Frame {
        Frame { kind, session, req, body }
    }
}

/// FNV-1a 64 — same function the checkpoint codec uses (kept local so
/// the wire format has no dependency on the checkpoint module's
/// layout).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

/// Serialize a frame to its wire image (header + body + checksum).
pub fn encode_frame(f: &Frame) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + f.body.len() + 8);
    out.extend_from_slice(&MAGIC);
    out.extend_from_slice(&VERSION.to_le_bytes());
    out.push(f.kind as u8);
    out.extend_from_slice(&f.session.to_le_bytes());
    out.extend_from_slice(&f.req.to_le_bytes());
    out.extend_from_slice(&(f.body.len() as u64).to_le_bytes());
    out.extend_from_slice(&f.body);
    let sum = fnv1a(&out);
    out.extend_from_slice(&sum.to_le_bytes());
    out
}

/// Write a full frame to `w` (single `write_all` so a frame is never
/// interleaved mid-frame by a concurrent writer holding the same lock).
pub fn write_frame(w: &mut dyn Write, f: &Frame) -> Result<()> {
    let bytes = encode_frame(f);
    w.write_all(&bytes).context("writing frame")?;
    w.flush().context("flushing frame")?;
    Ok(())
}

/// Read exactly `buf.len()` bytes, distinguishing the three failure
/// shapes a socket read has: clean truncation (peer closed mid-frame),
/// deadline expiry (`WouldBlock`/`TimedOut` from a socket read
/// timeout), and transport errors. `base` is the byte offset of
/// `buf[0]` within the frame, so every error names where the stream
/// died.
fn read_exact_at(r: &mut dyn Read, buf: &mut [u8], base: usize)
                 -> Result<()> {
    let mut got = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) => bail!(
                "truncated frame: stream closed at byte offset {} \
                 (needed {} more bytes)",
                base + got,
                buf.len() - got
            ),
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => {}
            Err(e) if e.kind() == ErrorKind::WouldBlock
                || e.kind() == ErrorKind::TimedOut =>
            {
                bail!(
                    "{ERR_DEADLINE} reading frame at byte offset {}",
                    base + got
                )
            }
            Err(e) => {
                return Err(e).with_context(|| format!(
                    "reading frame at byte offset {}",
                    base + got
                ))
            }
        }
    }
    Ok(())
}

/// Read one frame. `Ok(None)` means the stream ended cleanly *between*
/// frames (zero header bytes read) — any other shortfall is an error
/// naming the offset. Validates magic, version, kind, the body-length
/// cap (before allocating), and the trailing checksum.
pub fn read_frame_opt(r: &mut dyn Read) -> Result<Option<Frame>> {
    let mut header = [0u8; HEADER_LEN];
    // First byte separately: EOF here is a clean close, not an error.
    match r.read(&mut header[..1]) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        Err(e) if e.kind() == ErrorKind::Interrupted => {
            return read_frame_opt(r)
        }
        Err(e) if e.kind() == ErrorKind::WouldBlock
            || e.kind() == ErrorKind::TimedOut =>
        {
            bail!("{ERR_IDLE}")
        }
        Err(e) => return Err(e).context("reading frame header"),
    }
    read_exact_at(r, &mut header[1..], 1)?;
    decode_header(&header).and_then(|(kind, session, req, len)| {
        let mut body = vec![0u8; len];
        read_exact_at(r, &mut body, OFF_BODY)?;
        let mut sum = [0u8; 8];
        read_exact_at(r, &mut sum, OFF_BODY + len)?;
        let want = u64::from_le_bytes(sum);
        let mut hashed = fnv1a(&header);
        // continue the running hash over the body without re-buffering
        for &b in &body {
            hashed ^= b as u64;
            hashed = hashed.wrapping_mul(0x1_0000_0000_01b3);
        }
        if hashed != want {
            bail!(
                "frame checksum mismatch at byte offset {} \
                 (stored {want:#018x}, computed {hashed:#018x})",
                OFF_BODY + len
            );
        }
        Ok(Some(Frame { kind, session, req, body }))
    })
}

/// Like [`read_frame_opt`] but a clean between-frame close is also an
/// error — for clients awaiting a reply.
pub fn read_frame(r: &mut dyn Read) -> Result<Frame> {
    match read_frame_opt(r)? {
        Some(f) => Ok(f),
        None => bail!(
            "connection closed before a frame arrived (byte offset 0)"
        ),
    }
}

/// Validate the fixed header, returning (kind, session, req, body_len).
/// Every rejection names the offending byte offset.
fn decode_header(h: &[u8; HEADER_LEN])
                 -> Result<(Kind, u64, u64, usize)> {
    if h[OFF_MAGIC..OFF_MAGIC + 4] != MAGIC {
        bail!(
            "bad frame magic {:02x?} at byte offset {OFF_MAGIC} \
             (expected {MAGIC:02x?} = \"XMGS\")",
            &h[OFF_MAGIC..OFF_MAGIC + 4]
        );
    }
    let ver = u32::from_le_bytes([
        h[OFF_VERSION], h[OFF_VERSION + 1], h[OFF_VERSION + 2],
        h[OFF_VERSION + 3],
    ]);
    if ver != VERSION {
        bail!(
            "unsupported protocol version {ver} at byte offset \
             {OFF_VERSION} (this build speaks {VERSION})"
        );
    }
    let kind = match Kind::from_u8(h[OFF_KIND]) {
        Some(k) => k,
        None => bail!(
            "unknown frame kind {} at byte offset {OFF_KIND}",
            h[OFF_KIND]
        ),
    };
    let mut u64_at = |off: usize| {
        let mut b = [0u8; 8];
        b.copy_from_slice(&h[off..off + 8]);
        u64::from_le_bytes(b)
    };
    let session = u64_at(OFF_SESSION);
    let req = u64_at(OFF_REQ);
    let len = u64_at(OFF_LEN);
    if len > MAX_BODY {
        bail!(
            "frame body length {len} at byte offset {OFF_LEN} exceeds \
             the {MAX_BODY}-byte cap — refusing allocation"
        );
    }
    Ok((kind, session, req, len as usize))
}

// ---------------------------------------------------------------------
// Body codec: length-prefixed fields with bounds-checked reads.
// ---------------------------------------------------------------------

/// Append-only body builder. Counts are u32 length prefixes; scalars
/// are little-endian.
#[derive(Default)]
pub struct BodyWriter {
    buf: Vec<u8>,
}

impl BodyWriter {
    pub fn new() -> BodyWriter {
        BodyWriter { buf: Vec::new() }
    }

    pub fn u32(&mut self, v: u32) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn u64(&mut self, v: u64) -> &mut Self {
        self.buf.extend_from_slice(&v.to_le_bytes());
        self
    }

    pub fn str(&mut self, s: &str) -> &mut Self {
        self.u32(s.len() as u32);
        self.buf.extend_from_slice(s.as_bytes());
        self
    }

    pub fn i32s(&mut self, v: &[i32]) -> &mut Self {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_le_bytes());
        }
        self
    }

    pub fn f32s(&mut self, v: &[f32]) -> &mut Self {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
        self
    }

    pub fn bools(&mut self, v: &[bool]) -> &mut Self {
        self.u32(v.len() as u32);
        for &x in v {
            self.buf.push(x as u8);
        }
        self
    }

    pub fn finish(self) -> Vec<u8> {
        self.buf
    }
}

/// Bounds-checked body reader. Every count field is capped by the
/// bytes actually remaining — a hostile count can make decoding fail,
/// never allocate beyond the frame it arrived in.
pub struct BodyReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> BodyReader<'a> {
    pub fn new(buf: &'a [u8]) -> BodyReader<'a> {
        BodyReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize, what: &str) -> Result<&'a [u8]> {
        let remain = self.buf.len() - self.pos;
        if n > remain {
            bail!(
                "body truncated at offset {}: {what} needs {n} bytes, \
                 {remain} remain",
                self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u32(&mut self, what: &str) -> Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self, what: &str) -> Result<u64> {
        let b = self.take(8, what)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    /// A count prefix for elements of `elem` bytes each, capped by the
    /// remaining body so `vec![0; n]` downstream can never over-allocate.
    fn count(&mut self, elem: usize, what: &str) -> Result<usize> {
        let at = self.pos;
        let n = self.u32(what)? as usize;
        let remain = self.buf.len() - self.pos;
        if n.saturating_mul(elem) > remain {
            bail!(
                "body count {n} at offset {at}: {what} claims \
                 {} bytes but only {remain} remain",
                n.saturating_mul(elem)
            );
        }
        Ok(n)
    }

    pub fn str(&mut self, what: &str) -> Result<String> {
        let n = self.count(1, what)?;
        let b = self.take(n, what)?;
        match std::str::from_utf8(b) {
            Ok(s) => Ok(s.to_string()),
            Err(_) => bail!(
                "body field {what} at offset {} is not valid UTF-8",
                self.pos - n
            ),
        }
    }

    pub fn i32s(&mut self, what: &str) -> Result<Vec<i32>> {
        let n = self.count(4, what)?;
        let b = self.take(n * 4, what)?;
        let mut out = Vec::with_capacity(n);
        for c in b.chunks_exact(4) {
            out.push(i32::from_le_bytes([c[0], c[1], c[2], c[3]]));
        }
        Ok(out)
    }

    pub fn f32s(&mut self, what: &str) -> Result<Vec<f32>> {
        let n = self.count(4, what)?;
        let b = self.take(n * 4, what)?;
        let mut out = Vec::with_capacity(n);
        for c in b.chunks_exact(4) {
            out.push(f32::from_bits(u32::from_le_bytes([
                c[0], c[1], c[2], c[3],
            ])));
        }
        Ok(out)
    }

    pub fn bools(&mut self, what: &str) -> Result<Vec<bool>> {
        let n = self.count(1, what)?;
        let b = self.take(n, what)?;
        Ok(b.iter().map(|&x| x != 0).collect())
    }

    /// Bytes left undecoded (0 for a fully-consumed body).
    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

/// Build an `Error` frame body.
pub fn error_body(code_: u32, msg: &str) -> Vec<u8> {
    let mut w = BodyWriter::new();
    w.u32(code_).str(msg);
    w.finish()
}

/// Decode an `Error` frame body -> (code, message). Tolerant of a
/// truncated message (the code still names the failure class).
pub fn decode_error_body(body: &[u8]) -> (u32, String) {
    let mut r = BodyReader::new(body);
    let c = r.u32("error code").unwrap_or(0);
    let msg = r
        .str("error message")
        .unwrap_or_else(|_| "(unreadable error body)".to_string());
    (c, msg)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(f: &Frame) -> Frame {
        let bytes = encode_frame(f);
        read_frame(&mut &bytes[..]).expect("roundtrip decode")
    }

    #[test]
    fn frame_roundtrips() {
        let mut w = BodyWriter::new();
        w.u32(7).str("hello").i32s(&[1, -2, 3]).f32s(&[0.5, -1.25]);
        w.bools(&[true, false]);
        let f = Frame::new(Kind::Step, 42, 9, w.finish());
        let g = roundtrip(&f);
        assert_eq!(g.kind, Kind::Step);
        assert_eq!(g.session, 42);
        assert_eq!(g.req, 9);
        let mut r = BodyReader::new(&g.body);
        assert_eq!(r.u32("a").unwrap(), 7);
        assert_eq!(r.str("b").unwrap(), "hello");
        assert_eq!(r.i32s("c").unwrap(), vec![1, -2, 3]);
        assert_eq!(r.f32s("d").unwrap(), vec![0.5, -1.25]);
        assert_eq!(r.bools("e").unwrap(), vec![true, false]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn clean_eof_between_frames_is_none() {
        let empty: &[u8] = &[];
        assert!(read_frame_opt(&mut &empty[..]).unwrap().is_none());
    }

    // Fuzz-style corpus: every malformed shape is a structured error
    // naming a byte offset — never a panic, never an allocation driven
    // by attacker-controlled lengths.
    #[test]
    fn corpus_truncation_at_every_header_prefix() {
        let f = Frame::new(Kind::Reset, 1, 2, vec![0u8; 16]);
        let bytes = encode_frame(&f);
        for cut in 1..HEADER_LEN {
            let err = read_frame(&mut &bytes[..cut])
                .expect_err("truncated header must error");
            let msg = format!("{err:#}");
            assert!(
                msg.contains("byte offset"),
                "cut={cut}: error must name an offset, got: {msg}"
            );
        }
    }

    #[test]
    fn corpus_truncated_body_and_checksum() {
        let f = Frame::new(Kind::Reset, 1, 2, vec![7u8; 16]);
        let bytes = encode_frame(&f);
        // mid-body and mid-checksum cuts
        for cut in [HEADER_LEN + 3, HEADER_LEN + 16 + 3] {
            let err = read_frame(&mut &bytes[..cut]).expect_err("cut");
            assert!(format!("{err:#}").contains("byte offset"));
        }
    }

    #[test]
    fn corpus_bad_magic_version_kind() {
        let f = Frame::new(Kind::Hello, 0, 0, Vec::new());
        let good = encode_frame(&f);

        let mut bad = good.clone();
        bad[0] = b'Y';
        let e = read_frame(&mut &bad[..]).expect_err("magic");
        assert!(format!("{e:#}").contains("byte offset 0"));

        let mut bad = good.clone();
        bad[OFF_VERSION] = 99;
        let e = read_frame(&mut &bad[..]).expect_err("version");
        assert!(format!("{e:#}").contains("version"));

        let mut bad = good.clone();
        bad[OFF_KIND] = 0xEE;
        let e = read_frame(&mut &bad[..]).expect_err("kind");
        assert!(format!("{e:#}").contains("unknown frame kind"));
    }

    #[test]
    fn corpus_oversized_length_is_rejected_before_allocation() {
        let f = Frame::new(Kind::Hello, 0, 0, Vec::new());
        let mut bad = encode_frame(&f);
        bad[OFF_LEN..OFF_LEN + 8]
            .copy_from_slice(&u64::MAX.to_le_bytes());
        // If this allocated u64::MAX the test would OOM; a structured
        // error proves the cap fires before the allocation.
        let e = read_frame(&mut &bad[..]).expect_err("oversized len");
        let msg = format!("{e:#}");
        assert!(msg.contains("cap"), "got: {msg}");
        assert!(msg.contains(&format!("{OFF_LEN}")), "got: {msg}");
    }

    #[test]
    fn corpus_checksum_flip_detected() {
        let f = Frame::new(Kind::Step, 3, 4, vec![1, 2, 3, 4]);
        let mut bad = encode_frame(&f);
        let n = bad.len();
        bad[n - 1] ^= 0xFF;
        let e = read_frame(&mut &bad[..]).expect_err("checksum");
        assert!(format!("{e:#}").contains("checksum mismatch"));
        // ... and a body-byte flip trips the same check
        let mut bad2 = encode_frame(&f);
        bad2[OFF_BODY] ^= 0x80;
        let e2 = read_frame(&mut &bad2[..]).expect_err("body flip");
        assert!(format!("{e2:#}").contains("checksum mismatch"));
    }

    #[test]
    fn corpus_hostile_body_counts_cannot_overallocate() {
        // A body claiming 2^31 i32s but carrying 4 bytes: the count
        // check fires with an offset, no allocation happens.
        let mut body = Vec::new();
        body.extend_from_slice(&0x8000_0000u32.to_le_bytes());
        body.extend_from_slice(&[0u8; 4]);
        let mut r = BodyReader::new(&body);
        let e = r.i32s("actions").expect_err("hostile count");
        let msg = format!("{e:#}");
        assert!(msg.contains("offset 0"), "got: {msg}");
    }

    #[test]
    fn error_body_roundtrips() {
        let b = error_body(code::BACKPRESSURE, "queue full");
        let (c, m) = decode_error_body(&b);
        assert_eq!(c, code::BACKPRESSURE);
        assert_eq!(m, "queue full");
        assert_eq!(code::name(c), "backpressure");
    }
}
