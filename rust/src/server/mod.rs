//! L4 service tier: rollout-as-a-service. A persistent multi-tenant
//! environment server (`xmgrid serve`) that owns per-session
//! [`NativePool`](crate::coordinator::NativePool) replicas and serves
//! reset/step batches to many concurrent clients over a framed,
//! checksummed protocol ([`protocol`]) on a unix socket or TCP port —
//! plus a client ([`client::ServerClient`]) that implements
//! [`BatchEnvironment`](crate::env::api::BatchEnvironment), so
//! `xmgrid rollout --backend server:ADDR` is bitwise-identical to the
//! in-process native backend (the client's RNG state rides the wire;
//! the server steps the same kernels).
//!
//! The failure model is the point (see `docs/ARCHITECTURE.md`,
//! "Service layer & failure model"): sessions are fault-isolated
//! (own pool, own threads, own queue), every read/write carries a
//! deadline, full queues answer with explicit backpressure errors,
//! malformed frames get structured rejections naming the byte offset,
//! and SIGTERM / a `Shutdown` frame triggers a graceful drain —
//! in-flight batches complete, new requests are refused, sockets
//! close, and every session thread is joined before [`Server::serve`]
//! returns.

pub mod client;
pub mod protocol;
mod session;

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use crate::benchgen::Benchmark;
use crate::coordinator::metrics::WallTimer;
use anyhow::{bail, Context, Result};
use crate::util::fault::FaultPlan;

pub use client::{request_shutdown, Connection, ServerAddr,
                 ServerClient, SessionSpec};

/// How often the accept loop wakes to notice the drain flag.
const ACCEPT_TICK_MS: u64 = 20;

/// Tunables for one server instance. All deadlines are wall-clock
/// milliseconds; timing inside the server goes through
/// [`WallTimer`] (the lint gate holds `server/` to the same
/// no-raw-wallclock rule as the kernels).
pub struct ServeConfig {
    /// Per-IO deadline: socket writes, and the client's read deadline
    /// for a reply. A stalled peer surfaces as a structured `timeout`
    /// error after this long, never a hung thread.
    pub io_deadline_ms: u64,
    /// How long a session may sit idle (no frames) before it is torn
    /// down with a `timeout` error.
    pub idle_timeout_ms: u64,
    /// Bounded per-session request queue depth; a full queue answers
    /// `backpressure` immediately.
    pub queue_depth: usize,
    /// Injected faults (`XMG_FAULTS` grammar — see `util::fault`).
    pub faults: Arc<FaultPlan>,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            io_deadline_ms: 5_000,
            idle_timeout_ms: 30_000,
            queue_depth: 8,
            faults: Arc::new(FaultPlan::none()),
        }
    }
}

/// What a drained server saw over its lifetime.
#[derive(Clone, Copy, Debug)]
pub struct ServeStats {
    pub sessions: u64,
    pub requests: u64,
    pub uptime_secs: f64,
}

/// A connected byte stream, TCP or unix-domain — the one place the
/// transport dichotomy lives; everything above speaks [`Stream`].
pub(crate) enum Stream {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Stream {
    pub(crate) fn try_clone(&self) -> Result<Stream> {
        Ok(match self {
            Stream::Tcp(s) => {
                Stream::Tcp(s.try_clone().context("cloning tcp stream")?)
            }
            #[cfg(unix)]
            Stream::Unix(s) => Stream::Unix(
                s.try_clone().context("cloning unix stream")?,
            ),
        })
    }

    pub(crate) fn set_read_timeout(&self, d: Option<Duration>)
                                   -> Result<()> {
        match self {
            Stream::Tcp(s) => s.set_read_timeout(d),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_read_timeout(d),
        }
        .context("setting read deadline")
    }

    pub(crate) fn set_write_timeout(&self, d: Option<Duration>)
                                    -> Result<()> {
        match self {
            Stream::Tcp(s) => s.set_write_timeout(d),
            #[cfg(unix)]
            Stream::Unix(s) => s.set_write_timeout(d),
        }
        .context("setting write deadline")
    }

    /// Shut down both halves — the teardown and kill-9-simulation path.
    pub(crate) fn shutdown(&self) -> Result<()> {
        match self {
            Stream::Tcp(s) => s.shutdown(std::net::Shutdown::Both),
            #[cfg(unix)]
            Stream::Unix(s) => {
                s.shutdown(std::net::Shutdown::Both)
            }
        }
        .context("shutting down stream")
    }
}

impl Read for Stream {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Stream {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Stream::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Stream::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Stream::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Stream::Unix(s) => s.flush(),
        }
    }
}

enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener),
}

/// The multi-tenant environment server. `bind` then `serve`; `serve`
/// blocks until a drain (SIGTERM via [`install_signal_drain`], a
/// client `Shutdown` frame, or [`Server::drain_flag`] raised by the
/// embedding test) completes.
pub struct Server {
    listener: Listener,
    cfg: Arc<ServeConfig>,
    drain: Arc<AtomicBool>,
    benchmarks: Arc<Mutex<Vec<(String, Arc<Benchmark>)>>>,
    unix_path: Option<PathBuf>,
}

impl Server {
    pub fn bind_tcp(addr: &str, cfg: ServeConfig) -> Result<Server> {
        let listener = TcpListener::bind(addr)
            .with_context(|| format!("binding tcp {addr}"))?;
        Ok(Server {
            listener: Listener::Tcp(listener),
            cfg: Arc::new(cfg),
            drain: Arc::new(AtomicBool::new(false)),
            benchmarks: Arc::new(Mutex::new(Vec::new())),
            unix_path: None,
        })
    }

    #[cfg(unix)]
    pub fn bind_unix(path: &str, cfg: ServeConfig) -> Result<Server> {
        // A stale socket file from a previous run would make bind fail
        // with AddrInUse; the CLI owns the path, so clear it.
        let _ = std::fs::remove_file(path);
        let listener = UnixListener::bind(path)
            .with_context(|| format!("binding unix socket {path}"))?;
        Ok(Server {
            listener: Listener::Unix(listener),
            cfg: Arc::new(cfg),
            drain: Arc::new(AtomicBool::new(false)),
            benchmarks: Arc::new(Mutex::new(Vec::new())),
            unix_path: Some(PathBuf::from(path)),
        })
    }

    /// The bound address — for tests binding port 0.
    pub fn local_addr(&self) -> Result<String> {
        match &self.listener {
            Listener::Tcp(l) => {
                let a = l.local_addr().context("tcp local addr")?;
                Ok(a.to_string())
            }
            #[cfg(unix)]
            Listener::Unix(_) => match &self.unix_path {
                Some(p) => Ok(p.display().to_string()),
                None => bail!("unix listener with no path"),
            },
        }
    }

    /// Preload a benchmark under `name` so sessions' `Hello` resolves
    /// it without touching the store — how tests serve a synthetic
    /// benchmark.
    pub fn preload(&self, name: &str, bench: Arc<Benchmark>) {
        let mut reg = self
            .benchmarks
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner);
        reg.push((name.to_string(), bench));
    }

    /// The drain flag: store `true` to begin a graceful shutdown from
    /// the embedding thread (tests) — equivalent to SIGTERM.
    pub fn drain_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.drain)
    }

    /// Accept sessions until drained, then join every session thread
    /// and close the listener. Returns lifetime stats; `Ok` is the
    /// graceful-drain exit (the CLI maps it to exit code 0).
    pub fn serve(self) -> Result<ServeStats> {
        match &self.listener {
            Listener::Tcp(l) => l.set_nonblocking(true),
            #[cfg(unix)]
            Listener::Unix(l) => l.set_nonblocking(true),
        }
        .context("setting listener nonblocking")?;

        let timer = WallTimer::start();
        let requests = Arc::new(AtomicU64::new(0));
        let mut next_session: u64 = 0;
        let mut handles: Vec<std::thread::JoinHandle<()>> = Vec::new();

        loop {
            if self.drain.load(Ordering::SeqCst)
                || signal_drain_requested()
            {
                self.drain.store(true, Ordering::SeqCst);
                break;
            }
            let accepted: Option<Stream> = match &self.listener {
                Listener::Tcp(l) => match l.accept() {
                    Ok((s, _)) => {
                        // per-connection sockets block (with deadlines)
                        s.set_nonblocking(false)
                            .context("session socket mode")?;
                        Some(Stream::Tcp(s))
                    }
                    Err(e)
                        if e.kind()
                            == std::io::ErrorKind::WouldBlock =>
                    {
                        None
                    }
                    Err(e) => return Err(e).context("tcp accept"),
                },
                #[cfg(unix)]
                Listener::Unix(l) => match l.accept() {
                    Ok((s, _)) => {
                        s.set_nonblocking(false)
                            .context("session socket mode")?;
                        Some(Stream::Unix(s))
                    }
                    Err(e)
                        if e.kind()
                            == std::io::ErrorKind::WouldBlock =>
                    {
                        None
                    }
                    Err(e) => return Err(e).context("unix accept"),
                },
            };
            match accepted {
                Some(stream) => {
                    let id = next_session;
                    next_session += 1;
                    let shared = session::SessionShared {
                        cfg: Arc::clone(&self.cfg),
                        drain: Arc::clone(&self.drain),
                        benchmarks: Arc::clone(&self.benchmarks),
                        requests_served: Arc::clone(&requests),
                    };
                    handles.push(std::thread::spawn(move || {
                        session::run_session(id, stream, shared)
                    }));
                }
                None => {
                    // Reap finished sessions so a long-lived server
                    // doesn't accumulate handles, then idle briefly.
                    let (done, live): (Vec<_>, Vec<_>) = handles
                        .drain(..)
                        .partition(|h| h.is_finished());
                    for h in done {
                        let _ = h.join();
                    }
                    handles = live;
                    std::thread::sleep(Duration::from_millis(
                        ACCEPT_TICK_MS,
                    ));
                }
            }
        }

        // Drain: stop accepting (loop exited), let sessions finish
        // their in-flight work (they observe the flag within one poll
        // tick), join everything, release the socket.
        for h in handles {
            let _ = h.join();
        }
        if let Some(p) = &self.unix_path {
            let _ = std::fs::remove_file(p);
        }
        Ok(ServeStats {
            sessions: next_session,
            requests: requests.load(Ordering::Relaxed),
            uptime_secs: timer.elapsed_secs(),
        })
    }
}

// --- SIGTERM/SIGINT -> drain, without a libc crate -------------------
//
// std already links libc on unix; declaring `signal(2)` directly keeps
// the zero-dependency rule. The handler only stores to an atomic
// (async-signal-safe); the accept loop polls the flag. Installed only
// by the `xmgrid serve` CLI path — tests drain via Server::drain_flag.

#[cfg(unix)]
static SIGNAL_DRAIN: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
extern "C" fn on_drain_signal(_sig: i32) {
    SIGNAL_DRAIN.store(true, Ordering::SeqCst);
}

/// Route SIGTERM and SIGINT to a graceful drain of every [`Server`]
/// in this process.
#[cfg(unix)]
pub fn install_signal_drain() {
    extern "C" {
        fn signal(signum: i32, handler: usize) -> usize;
    }
    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;
    let h: extern "C" fn(i32) = on_drain_signal;
    unsafe {
        signal(SIGTERM, h as usize);
        signal(SIGINT, h as usize);
    }
}

#[cfg(not(unix))]
pub fn install_signal_drain() {}

fn signal_drain_requested() -> bool {
    #[cfg(unix)]
    {
        SIGNAL_DRAIN.load(Ordering::SeqCst)
    }
    #[cfg(not(unix))]
    {
        false
    }
}
