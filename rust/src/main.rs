//! xmgrid CLI — the L3 launcher.
//!
//! Subcommands:
//!   envs                         list the 38 registered environments
//!   play                         random-policy episode with ASCII render
//!   gen-benchmark                generate + store a benchmark (§3)
//!   rollout                      fused random-policy throughput run
//!   train                        RL² PPO training (Fig. 6/7 harness)
//!   eval                         evaluation protocol on a benchmark
//!   validate                     Rust-oracle vs HLO cross-check
//!   artifacts                    list manifest artifacts

use std::path::PathBuf;

use anyhow::{bail, Context, Result};

use xmgrid::benchgen::store::load_benchmark;
use xmgrid::benchgen::{generate_benchmark, Benchmark, Preset};
use xmgrid::coordinator::metrics::{fmt_sps, CsvLog};
use xmgrid::coordinator::pool::EnvFamily;
use xmgrid::coordinator::{EnvPool, TrainConfig, Trainer};
use xmgrid::env::registry;
use xmgrid::env::state::{reset, step, EnvOptions};
use xmgrid::render::render_grid;
use xmgrid::runtime::Runtime;
use xmgrid::util::args::Args;
use xmgrid::util::rng::Rng;

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts-dir", "artifacts"))
}

fn main() -> Result<()> {
    let args = Args::from_env();
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "envs" => cmd_envs(),
        "play" => cmd_play(&args),
        "gen-benchmark" => cmd_gen_benchmark(&args),
        "rollout" => cmd_rollout(&args),
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "validate" => cmd_validate(&args),
        "artifacts" => cmd_artifacts(&args),
        _ => {
            println!(
                "xmgrid — XLand-MiniGrid reproduction (rust+JAX+Pallas)\n\n\
                 usage: xmgrid <command> [--options]\n\n\
                 commands:\n\
                 \x20 envs                                list environments\n\
                 \x20 play --env NAME [--steps N]         ASCII episode\n\
                 \x20 gen-benchmark --preset P --n N      generate benchmark\n\
                 \x20 rollout --batch B [--chunks N]      throughput run\n\
                 \x20 train --benchmark B --iters N       RL² PPO training\n\
                 \x20 eval --benchmark B                  evaluation\n\
                 \x20 validate                            oracle cross-check\n\
                 \x20 artifacts                           list manifest"
            );
            Ok(())
        }
    }
}

fn cmd_envs() -> Result<()> {
    for name in registry::registered_environments() {
        println!("{name}");
    }
    Ok(())
}

fn cmd_play(args: &Args) -> Result<()> {
    let name = args.str_or("env", "MiniGrid-Empty-8x8");
    let steps = args.usize_or("steps", 30);
    let seed = args.u64_or("seed", 0);
    let mut rng = Rng::new(seed);
    let bp = registry::make(&name, &mut rng);
    let ruleset = bp.ruleset.clone().unwrap_or_else(|| {
        // XLand env: sample a trivial task
        let (mut rs, _) =
            generate_benchmark(&Preset::Trivial.config(), 1);
        rs.pop().unwrap()
    });
    let (mut state, _) = reset(bp.base_grid, ruleset, bp.max_steps,
                               rng.split(), EnvOptions::default());
    println!("{}", render_grid(&state.grid,
                               Some((state.agent_pos, state.agent_dir)),
                               true));
    let mut total = 0.0f32;
    for i in 0..steps {
        let a = rng.below(6) as i32;
        let out = step(&mut state, a, EnvOptions::default());
        total += out.reward;
        if out.trial_done {
            println!("--- trial done at step {i} (reward {:.3})",
                     out.reward);
        }
    }
    println!("{}", render_grid(&state.grid,
                               Some((state.agent_pos, state.agent_dir)),
                               true));
    println!("total reward over {steps} random steps: {total:.3}");
    Ok(())
}

fn cmd_gen_benchmark(args: &Args) -> Result<()> {
    let preset_name = args.str_or("preset", "trivial");
    let n = args.usize_or("n", 1000);
    let preset = Preset::from_name(&preset_name)
        .with_context(|| format!("unknown preset {preset_name}"))?;
    let mut cfg = preset.config();
    cfg.random_seed = args.u64_or("seed", cfg.random_seed);
    let t0 = std::time::Instant::now();
    let (rulesets, stats) = generate_benchmark(&cfg, n);
    let bench = Benchmark {
        name: format!("{preset_name}-{n}"),
        rulesets,
    };
    let dir = xmgrid::benchgen::store::data_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{}.xmg.gz", bench.name));
    let (raw, comp) = bench.save(&path)?;
    let mean_rules: f64 = stats.iter().map(|s| s.num_rules as f64)
        .sum::<f64>() / stats.len() as f64;
    println!(
        "generated {n} unique rulesets in {:.1}s (mean rules {mean_rules:.2}) \
         -> {path:?} ({:.1} KiB raw, {:.1} KiB gz)",
        t0.elapsed().as_secs_f64(), raw as f64 / 1024.0,
        comp as f64 / 1024.0
    );
    Ok(())
}

fn cmd_rollout(args: &Args) -> Result<()> {
    let rt = Runtime::new(&artifacts_dir(args))?;
    let batch = args.usize_or("batch", 1024);
    let chunks = args.usize_or("chunks", 4);
    let rolls = rt.manifest.of_kind("env_rollout");
    let spec = rolls
        .iter()
        .find(|s| s.meta_usize("B").unwrap() == batch)
        .or_else(|| rolls.first())
        .context("no env_rollout artifacts; run `make artifacts`")?;
    let fam = EnvFamily::from_spec(spec)?;
    let t = spec.meta_usize("T")?;
    println!("artifact {} (B={} T={t})", spec.name, fam.b);

    let bench = load_benchmark(&args.str_or("benchmark", "trivial-1k"))?;
    let mut rng = Rng::new(args.u64_or("seed", 0));
    let mut pool = EnvPool::new(&rt, fam, args.usize_or("rooms", 1))?;
    let rulesets = pool.sample_rulesets(&bench, &mut rng);
    pool.reset(&rulesets, &mut rng)?;

    let t0 = std::time::Instant::now();
    let mut total_steps = 0u64;
    for c in 0..chunks {
        let (reward, episodes, trials) = pool.rollout(&rt, t, &mut rng)?;
        total_steps += (fam.b * t) as u64;
        let sps = total_steps as f64 / t0.elapsed().as_secs_f64();
        println!(
            "chunk {c}: steps={} reward={reward:.1} episodes={episodes} \
             trials={trials} cum-sps={}",
            fam.b * t, fmt_sps(sps)
        );
    }
    Ok(())
}

fn pick_train_artifact(rt: &Runtime, batch: usize) -> Result<String> {
    let arts = rt.manifest.of_kind("train_iter");
    let spec = arts
        .iter()
        .find(|s| s.meta_usize("B").unwrap() == batch)
        .or_else(|| {
            arts.iter().max_by_key(|s| s.meta_usize("B").unwrap())
        })
        .context("no train_iter artifacts; run `make artifacts`")?;
    Ok(spec.name.clone())
}

fn cmd_train(args: &Args) -> Result<()> {
    let rt = Runtime::new(&artifacts_dir(args))?;
    let bench = load_benchmark(&args.str_or("benchmark", "trivial-1k"))?;
    let iters = args.usize_or("iters", 50);
    let artifact = match args.get("artifact") {
        Some(a) => a.to_string(),
        None => pick_train_artifact(&rt, args.usize_or("batch", 256))?,
    };
    let rooms = args.usize_or("rooms", 1);
    let mut cfg = TrainConfig::default();
    cfg.train_seed = args.u64_or("seed", cfg.train_seed);
    cfg.task_resample_iters =
        args.usize_or("resample", cfg.task_resample_iters);
    let eval_every = args.usize_or("eval-every", 0);
    let eval_art = rt
        .manifest
        .of_kind("eval_rollout")
        .iter()
        .map(|s| s.name.clone())
        .next();

    println!("training with {artifact} on {} ({} tasks)", bench.name,
             bench.num_rulesets());
    let mut trainer = Trainer::new(&rt, &artifact, rooms, cfg)?;
    trainer.resample_tasks(&bench)?;

    let csv_path = PathBuf::from(
        args.str_or("log", "artifacts/train_log.csv"));
    let mut log = CsvLog::create(&csv_path, &[
        "iter", "env_steps", "loss", "pi_loss", "v_loss", "entropy",
        "approx_kl", "reward_per_step", "trials", "sps",
    ])?;

    let t0 = std::time::Instant::now();
    let mut env_steps = 0u64;
    for i in 1..=iters {
        if i > 1 && (i - 1) % trainer.cfg.task_resample_iters == 0 {
            trainer.resample_tasks(&bench)?;
        }
        let m = trainer.train_iter()?;
        env_steps += m.env_steps;
        let sps = env_steps as f64 / t0.elapsed().as_secs_f64();
        log.row(&[
            i.to_string(), env_steps.to_string(),
            format!("{:.4}", m.total_loss), format!("{:.4}", m.pi_loss),
            format!("{:.4}", m.v_loss), format!("{:.4}", m.entropy),
            format!("{:.5}", m.approx_kl),
            format!("{:.5}", m.reward_sum / m.env_steps as f32),
            m.trials.to_string(), format!("{sps:.0}"),
        ])?;
        if i % 10 == 0 || i == iters {
            println!(
                "iter {i:>4} steps {env_steps:>9} loss {:+.4} ent {:.3} \
                 r/step {:.4} trials {:>5} sps {}",
                m.total_loss, m.entropy,
                m.reward_sum / m.env_steps as f32, m.trials, fmt_sps(sps)
            );
        }
        if eval_every > 0 && i % eval_every == 0 {
            if let Some(ea) = &eval_art {
                let st = trainer.evaluate(&rt, ea, &bench, rooms)?;
                println!(
                    "  eval: return mean {:.3} P20 {:.3} per-trial {:.3} \
                     (tasks {})",
                    st.return_mean, st.return_p20, st.per_trial_mean,
                    st.num_tasks
                );
            }
        }
    }
    println!("log written to {csv_path:?}");
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let rt = Runtime::new(&artifacts_dir(args))?;
    let bench = load_benchmark(&args.str_or("benchmark", "trivial-1k"))?;
    let artifact = pick_train_artifact(&rt, args.usize_or("batch", 256))?;
    let rooms = args.usize_or("rooms", 1);
    let mut trainer =
        Trainer::new(&rt, &artifact, rooms, TrainConfig::default())?;
    trainer.resample_tasks(&bench)?;
    let eval_name = rt
        .manifest
        .of_kind("eval_rollout")
        .iter()
        .map(|s| s.name.clone())
        .next()
        .context("no eval_rollout artifact")?;
    let st = trainer.evaluate(&rt, &eval_name, &bench, rooms)?;
    println!(
        "eval on {}: return mean {:.3} | P20 {:.3} | per-trial mean {:.3} \
         | per-trial P20 {:.3} | trials/task {:.1} | tasks {}",
        bench.name, st.return_mean, st.return_p20, st.per_trial_mean,
        st.per_trial_p20, st.trials_mean, st.num_tasks
    );
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    // thin wrapper over the cross-validation invariants, for manual runs
    let rt = Runtime::new(&artifacts_dir(args))?;
    let steps = rt.manifest.of_kind("env_step");
    if steps.is_empty() {
        bail!("no env_step artifacts in manifest");
    }
    println!("{} env_step artifacts available; run `cargo test --test \
              cross_validation` for the full transition-level check",
             steps.len());
    for s in steps {
        let art = rt.load(&s.name)?;
        println!("  {} compiled ok ({} inputs, {} outputs)", s.name,
                 art.spec.inputs.len(), art.spec.outputs.len());
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let rt = Runtime::new(&artifacts_dir(args))?;
    for a in &rt.manifest.artifacts {
        println!("{:<50} kind={:<12} ins={} outs={}", a.name, a.kind(),
                 a.inputs.len(), a.outputs.len());
    }
    Ok(())
}
