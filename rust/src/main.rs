//! xmgrid CLI — the L3 launcher.
//!
//! Subcommands (see `xmgrid help <cmd>` for per-command options):
//!
//! ```text
//!   envs            list the 38 registered environments
//!   play            random-policy episode with ASCII render
//!   gen-benchmark   generate + store a benchmark (§3)
//!   rollout         sharded random-policy throughput run
//!                   (--backend native|xla|auto|server:ADDR;
//!                   --shards N --overlap on|off: double-buffered
//!                   engine)
//!   serve           rollout-as-a-service environment server
//!                   (--socket PATH | --port P; fault-isolated
//!                   sessions, deadlines, backpressure, drain)
//!   train           RL² PPO training (Fig. 6/7 harness;
//!                   --backend native|xla|auto — native is the pure-Rust
//!                   GRU+PPO stack, zero artifacts; --shards N runs the
//!                   data-parallel shard engine)
//!   eval            evaluation protocol on a benchmark
//!   verify          benchmark store integrity check
//!   lint            determinism & panic-safety static analysis
//!   validate        Rust-oracle vs HLO cross-check
//!   artifacts       list manifest artifacts
//!   help            global or per-command usage
//! ```
//!
//! Every command reading compiled artifacts honours `--artifacts-dir DIR`
//! (default `artifacts`).

use std::path::PathBuf;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use xmgrid::benchgen::store::{data_dir, load_benchmark_with,
                              size_suffix_name};
use xmgrid::benchgen::{generate_benchmark, generate_benchmark_with,
                       verify_file, BenchmarkWriter, Preset, TaskSlice};
use xmgrid::coordinator::metrics::{fmt_sps, CsvLog, ThroughputMeter};
use xmgrid::coordinator::pool::EnvFamily;
use xmgrid::coordinator::{eval_kshot, load_checkpoint, BackendKind,
                          CheckpointPlan, EvalPolicy, KShotConfig,
                          NativeEnvConfig, NativeShardedTrainer,
                          NativeTrainerConfig, Overlap, RolloutEngine,
                          ShardConfig, ShardedTrainer, TrainConfig,
                          Trainer};
use xmgrid::lint;
use xmgrid::nn::{ModelDims, Params};
use xmgrid::util::fault::{FaultPlan, RetryPolicy, FAULTS_ENV};
use xmgrid::util::bench::{json_arg_path, JsonReport};
use xmgrid::env::api::{BatchEnvironment, EnvParams, ObsMode};
use xmgrid::env::registry;
use xmgrid::env::state::{reset, step, EnvOptions};
use xmgrid::render::render_grid;
use xmgrid::runtime::{Manifest, Runtime};
use xmgrid::server::{install_signal_drain, request_shutdown,
                     Connection, ServeConfig, Server, ServerAddr,
                     ServerClient, SessionSpec};
use xmgrid::util::args::Args;
use xmgrid::util::rng::Rng;

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.str_or("artifacts-dir", "artifacts"))
}

/// `--threads N|auto` → worker count (default 1; `auto` = all cores).
/// Drives both native-backend stepping (batch chunked across workers,
/// output bitwise-independent of the count) and first-use benchmark
/// generation.
fn parse_threads(args: &Args) -> Result<usize> {
    match args.get("threads") {
        None => Ok(1),
        Some("auto") => Ok(std::thread::available_parallelism()
            .map(|p| p.get())
            .unwrap_or(1)),
        Some(v) => match v.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(n),
            _ => bail!("--threads must be a positive integer or `auto`, \
                        got {v}"),
        },
    }
}

/// `--shards` / `--overlap` / `--seed` / `--rooms` → engine config.
fn shard_config(args: &Args) -> Result<ShardConfig> {
    let shards = args.usize_or("shards", 1);
    if shards == 0 {
        bail!("--shards must be at least 1");
    }
    Ok(ShardConfig {
        shards,
        overlap: Overlap::from_flag(&args.str_or("overlap", "off"))?,
        seed: args.u64_or("seed", 0),
        rooms: args.usize_or("rooms", 1),
    })
}

/// `--max-retries` / `--retry-backoff-ms` → chunk-worker retry policy
/// (native backend supervision: a panicked chunk worker is respawned
/// and its chunk deterministically replayed up to this many times).
fn retry_policy(args: &Args) -> RetryPolicy {
    let d = RetryPolicy::default();
    RetryPolicy {
        max_retries: args.usize_or("max-retries",
                                   d.max_retries as usize) as u32,
        backoff_ms: args.u64_or("retry-backoff-ms", d.backoff_ms),
    }
}

fn main() -> Result<()> {
    let args = Args::from_env();
    // Validate the fault-injection plan up front: a malformed XMG_FAULTS
    // must be a clean CLI error here, not a panic inside a worker pool.
    FaultPlan::from_env()
        .with_context(|| format!("invalid {FAULTS_ENV}"))?;
    let cmd = args.positional.first().map(|s| s.as_str()).unwrap_or("help");
    match cmd {
        "envs" => cmd_envs(&args),
        "play" => cmd_play(&args),
        "gen-benchmark" => cmd_gen_benchmark(&args),
        "split" => cmd_split(&args),
        "rollout" => cmd_rollout(&args),
        "serve" => cmd_serve(&args),
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "verify" => cmd_verify(&args),
        "lint" => cmd_lint(&args),
        "validate" => cmd_validate(&args),
        "artifacts" => cmd_artifacts(&args),
        "help" => cmd_help(&args),
        other => {
            println!("unknown command `{other}`\n");
            print_global_help();
            Ok(())
        }
    }
}

const GLOBAL_HELP: &str = "\
xmgrid — XLand-MiniGrid reproduction (Rust + JAX + Pallas)

usage: xmgrid <command> [--options]
       xmgrid help <command>        per-command option docs

commands:
  envs [--json]                       list environments (+specs)
  play --env NAME [--steps N]         ASCII episode
  gen-benchmark --preset P --n N      generate benchmark (--threads)
  split --benchmark B [--shuffle S]   deterministic shuffle/filter/
        [--prop P] [--goals IDS]      subset + train/test split, saved
        [--depth LO..HI]              through the benchmark store
  rollout [--backend B] [--shards N]  sharded throughput run
          [--threads T] [--obs M]     (native: chunked stepping pool,
                                      obs wrapper stacks incl. rgb;
                                      server:ADDR steps a remote
                                      serve instance, bitwise equal)
  serve --socket PATH | --port P      rollout-as-a-service environment
        [--deadline-ms D] [--idle-ms  server: fault-isolated sessions,
         I] [--queue-depth Q]         per-request deadlines, bounded
        [--shutdown]                  queues with backpressure replies,
                                      graceful drain on SIGTERM
  train [--backend B] [--shards N]    RL² PPO training (native: pure
        [--obs M] [--overlap M]       Rust GRU+PPO, zero artifacts;
                                      xla: fused train_iter via PJRT)
  eval --benchmark B [--shots K]      k-shot evaluation on a held-out
       [--policy random|greedy|       split (per-trial return curves,
        checkpoint:PATH]              BENCH_eval JSON via --json)
  verify --benchmark B                integrity-check a stored benchmark
                                      (magic, count, per-task decode,
                                      duplicate detection)
  lint [--json] [--rules a,b] [PATH]  determinism & panic-safety
                                      static analysis over the source
                                      tree (hard CI gate; exits 1 on
                                      any violation)
  validate                            oracle cross-check
  artifacts                           list manifest

global options:
  --artifacts-dir DIR   AOT artifact directory (default: artifacts)

fault tolerance:
  Native-backend chunk workers run supervised: a panicking worker is
  respawned and its chunk replayed deterministically (--max-retries,
  --retry-backoff-ms on rollout). train --checkpoint-every N writes
  atomic crash-safe checkpoints; train --resume continues bit for bit.
  XMG_FAULTS (e.g. 'panic@worker=2,step=17', or
  'drop-conn@session=0,req=3' against a serve instance) injects
  deterministic faults for testing — see docs/ARCHITECTURE.md.";

/// Per-command option documentation for `xmgrid help <cmd>`.
fn command_help(cmd: &str) -> Option<&'static str> {
    Some(match cmd {
        "envs" => "\
usage: xmgrid envs [--json]

List the registered environment names (MiniGrid ports + XLand family).

  --json    machine-readable registry: one record per family with grid
            size, room count, step limit, and the ObsSpec/ActionSpec
            derived from the shared EnvParams (segment names + shapes,
            flattened length, action count).",
        "play" => "\
usage: xmgrid play [--env NAME] [--steps N] [--seed S]

Run a random-policy episode in the pure-Rust environment and render the
grid as ASCII before and after.

  --env NAME    environment name from `xmgrid envs`
                (default: MiniGrid-Empty-8x8)
  --steps N     number of random steps (default: 30)
  --seed S      RNG seed (default: 0)",
        "gen-benchmark" => "\
usage: xmgrid gen-benchmark [--preset P] [--n N] [--seed S]
                            [--threads T|auto]

Generate N unique rulesets with the §3 procedural generator and store
them gzip-compressed under the benchmark data dir
($XLAND_MINIGRID_DATA, default artifacts/benchmarks). Generation is
streamed straight into the chunked gzip store and deduplicated on the
exact ruleset encoding, so million-task benchmarks (--n 1000000) run in
a bounded memory footprint and finish in seconds with --threads auto.
The cache name uses the size suffix (--preset medium --n 100000 ->
medium-100k), so other commands load it via --benchmark medium-100k.
A non-default --seed is appended to the name (medium-100k-seed7) so a
custom generation never shadows the canonical benchmark.

  --preset P        trivial | small | medium | high (default: trivial)
  --n N             number of rulesets (default: 1000); errors cleanly
                    if the preset's task space saturates below N
  --seed S          generator seed (default: preset seed)
  --threads T|auto  generation worker threads (default: 1; auto = all
                    cores). Output is identical for every thread count:
                    attempt k's candidate is a pure function of
                    (seed, k) and the dedup merge consumes candidates
                    in ascending k order.",
        "split" => "\
usage: xmgrid split --benchmark NAME [--shuffle S] [--prop P]
                    [--goals IDS] [--depth LO..HI] [--subset LO..HI]
                    [--out PREFIX] [--threads T|auto]

Derive deterministic train/test splits from a stored benchmark and save
them through the chunked-gzip store, loadable by name from any other
command (--benchmark <PREFIX>-train / <PREFIX>-test). Ops apply in a
fixed pipeline — filter by goals, filter by rule depth, subset, shuffle,
split — each a pure function of (store content, arguments): the same
invocation produces byte-identical files on every machine, for every
--threads count, pinned by tests/benchmark_ops.rs.

  --benchmark NAME   source benchmark (generated/cached on first use)
  --shuffle S        Fisher-Yates permutation keyed by seed S before
                     splitting (omit for store order)
  --prop P           train proportion (default: 0.8); test gets the rest
  --goals IDS        keep only goal family ids in the comma list, e.g.
                     --goals 1,3,4 (the Fig. 8 train goals); see
                     docs/ARCHITECTURE.md for the id table
  --depth LO..HI     keep tasks with LO <= rule depth < HI (production-
                     chain depth from init tiles to the goal objects)
  --subset LO..HI    keep slice positions [LO, HI) before shuffling
  --out PREFIX       output name prefix (default: the benchmark name)
  --threads T|auto   first-use generation threads (default: 1)",
        "rollout" => "\
usage: xmgrid rollout [--backend auto|native|xla|server:ADDR]
                      [--batch B]
                      [--chunks N] [--shards K] [--threads T|auto]
                      [--overlap on|off] [--env NAME] [--steps T]
                      [--obs symbolic|dir|rules-goals|rgb]
                      [--benchmark NAME] [--seed S] [--rooms R]
                      [--artifacts-dir DIR]

Random-policy throughput run on the sharded rollout engine. Each shard
is a persistent worker thread owning a full replica and a private RNG
stream; the replica is either an AOT/PJRT executable set (`xla`) or a
pure-Rust SoA VecEnv batch (`native` — no artifacts needed).

  --backend B        native: vectorized SoA kernels, zero artifacts.
                     xla: compiled HLO artifacts through PJRT.
                     server:ADDR: step a running `xmgrid serve`
                     instance over its framed protocol — one session
                     per shard, RNG state shipped in the reset RPC,
                     so chunk/total lines are bitwise-identical to
                     --backend native (ADDR = HOST:PORT or a unix
                     socket path; --deadline-ms caps each RPC).
                     auto (default): xla if a manifest with rollout
                     artifacts exists, else native.
  --batch B          env batch: artifact to pick (xla) or VecEnv size
                     per shard (native) (default: 1024)
  --chunks N         rollout chunks per shard (default: 4)
  --shards K         number of shard replicas (default: 1)
  --threads T|auto   native backend: stepping worker threads per shard
                     replica — the env batch is chunked across a
                     persistent worker pool, bitwise identical to
                     --threads 1 for any T (default: 1; auto = all
                     cores). Also parallelizes first-use benchmark
                     generation.
  --overlap on|off   off: lockstep rounds with a global barrier,
                     bitwise-deterministic per seed. on: double-buffered
                     pipeline — each shard keeps a second trajectory
                     buffer in flight while the host drains the first.
                     Per-shard trajectories are identical in both modes.
                     (default: off)
  --env NAME         native backend: XLand registry family to roll out
                     (default: XLand-MiniGrid-R1-13x13)
  --steps T          native backend: steps per rollout chunk
                     (default: 64; xla takes T from the artifact)
  --obs MODE         native backend: observation wrapper stack each
                     replica steps through (default: symbolic = raw
                     fused fast path). dir appends a one-hot agent
                     direction, rules-goals appends the encoded task
                     (goal [5] + rules [MR,7]), rgb replaces the
                     symbolic view with a rasterized [V*8, V*8, 3]
                     image (the paper's RGBImageObservationWrapper,
                     rendered natively — fig13's cost model). The xla
                     backend supports symbolic only.
  --benchmark NAME   task source (default: trivial-1k, generated and
                     cached on first use)
  --seed S           run seed; shard k derives stream shard_seed(S, k)
                     (default: 0)
  --rooms R          rooms in the base grid layout — xla backend; the
                     native backend takes rooms from --env (default: 1)
  --max-retries N    native backend: times a panicked chunk worker is
                     respawned and its chunk deterministically replayed
                     before the run fails cleanly (default: 2)
  --retry-backoff-ms M  linear backoff between retries: attempt k sleeps
                     k*M ms, capped at 60s (default: 50)",
        "serve" => "\
usage: xmgrid serve --socket PATH | --port P [--host H]
                    [--deadline-ms D] [--idle-ms I] [--queue-depth Q]
                    [--shutdown]

Run the rollout-as-a-service environment server: a persistent process
owning vectorized env pools and serving reset/step batches to any
number of concurrent clients over a length-prefixed framed protocol
(magic + version + checksum, the checkpoint codec's discipline).
`xmgrid rollout --backend server:ADDR` against it is bitwise-identical
to an in-process run: the client ships its RNG state in the reset RPC
and draws actions locally, so the server adds no RNG of its own.

Failure model (pinned by tests/server_faults.rs):
  isolation     every session runs on its own reader+worker thread
                pair with a catch_unwind boundary: a panicking or
                vanishing session is torn down alone, with an
                `internal` error frame; other sessions are unaffected
                bit for bit.
  deadlines     every socket read/write carries --deadline-ms; a
                stalled peer gets a structured `timeout` error frame,
                never a hung thread. A session idle past --idle-ms is
                reaped the same way.
  backpressure  each session's request queue is bounded at
                --queue-depth; a full queue answers `backpressure`
                immediately instead of buffering unboundedly.
  malformed     a corrupt frame (bad magic/version/kind, oversized
                length, checksum mismatch, truncation) is rejected
                with an error naming the byte offset — the server
                never panics, over-allocates, or desyncs on hostile
                input.
  drain         SIGTERM/SIGINT (or a `shutdown` frame via
                `xmgrid serve ... --shutdown`) stops accepting new
                sessions, answers new requests with `draining`,
                completes every in-flight request, then exits 0.

  --socket PATH     bind a unix-domain socket at PATH (removed on
                    drain; stale files are replaced on bind)
  --port P          bind TCP on --host (default 127.0.0.1); port 0
                    picks a free port and prints it
  --host H          TCP bind host (default: 127.0.0.1)
  --deadline-ms D   per-IO deadline, ms (default: 5000)
  --idle-ms I       idle-session reap timeout, ms (default: 30000)
  --queue-depth Q   bounded per-session queue depth (default: 8)
  --shutdown        connect to the given --socket/--port and request
                    a graceful drain instead of serving

XMG_FAULTS accepts server sites for fault-injection testing:
drop-conn@session=S,req=R  stall@session=S,ms=M  torn-frame@session=S
(see `xmgrid help lint` and docs/ARCHITECTURE.md).",
        "train" => "\
usage: xmgrid train [--backend auto|native|xla] [--benchmark NAME]
                    [--iters N] [--batch B] [--steps T] [--env NAME]
                    [--obs symbolic|dir|rules-goals] [--epochs E]
                    [--minibatches M] [--artifact NAME] [--shards K]
                    [--threads T|auto] [--overlap on|off] [--seed S]
                    [--resample I] [--eval-every E] [--rooms R]
                    [--log PATH] [--checkpoint PATH]
                    [--checkpoint-every N] [--resume]
                    [--artifacts-dir DIR]

RL² PPO training. The native backend is the pure-Rust GRU actor-critic
+ PPO stack over the vectorized env pool: zero artifacts, runs on a
fresh checkout, bitwise-reproducible per seed for any --threads. The
xla backend drives fused train_iter artifacts through PJRT. With
--shards > 1 either backend runs one full trainer replica per shard
and all-reduces parameter updates on the host in fixed shard order.
Both write the same checkpoint format, which `eval --policy
checkpoint:PATH` can evaluate directly.

  --backend B        native: pure-Rust GRU+PPO, zero artifacts.
                     xla: compiled train_iter artifacts through PJRT.
                     auto (default): xla if a manifest with train_iter
                     artifacts exists, else native.
  --benchmark NAME   task source (default: trivial-1k)
  --iters N          training iterations (default: 50)
  --batch B          env batch: VecEnv size per shard (native) or the
                     train_iter artifact to pick (xla) (default: 256)
  --steps T          native: rollout window (BPTT length) per iteration
                     (default: 64; xla takes T from the artifact)
  --env NAME         native: XLand registry family to train on
                     (default: XLand-MiniGrid-R1-9x9; xla bakes the
                     family into the artifact)
  --obs MODE         native: symbolic (default) | dir | rules-goals —
                     the wrapper extras feed the trunk input. xla
                     supports symbolic only (other stacks error with a
                     pointer to aot.py).
  --epochs E         native: PPO epochs per iteration (default: 1)
  --minibatches M    native: env-column minibatches per epoch; must
                     divide --batch (default: 1)
  --artifact NAME    xla: explicit train_iter artifact (overrides
                     --batch)
  --shards K         trainer replicas (default: 1)
  --threads T|auto   native: env-stepping workers per shard (output
                     bitwise-identical for any count). Also
                     parallelizes first-use benchmark generation.
                     (default: 1; auto = all cores)
  --overlap on|off   xla: off = lockstep all-reduce every iteration,
                     on = double-buffered pipeline (one iteration of
                     parameter staleness). The native engine is always
                     lockstep. (default: off)
  --seed S           training seed (default: 42); shard k trains with
                     shard_seed(S, k)
  --resample I       resample tasks every I iterations (default: 8)
  --eval-every E     evaluate every E iterations — native: the k-shot
                     harness drives the current master greedily; xla:
                     the §4.2 eval_rollout artifact (default: 0 =
                     never)
  --rooms R          rooms in the base grid layout — xla; the native
                     room count comes from --env (default: 1)
  --log PATH         CSV metrics path
                     (default: artifacts/train_log.csv)
  --checkpoint PATH  crash-safe checkpoint path
                     (default: artifacts/train_ckpt.bin)
  --checkpoint-every N  write an atomic checkpoint (master params, every
                     shard's learner + env state, all RNG streams) every
                     N iterations. Checkpoint boundaries are pipeline
                     sync points, so the cadence is part of the run's
                     schedule: same seed + shards + cadence => same run.
                     (default: 0 = off)
  --resume           restore --checkpoint and continue toward --iters
                     (a total, not an increment), reproducing the
                     uninterrupted run bit for bit; CSV rows append to
                     --log. Missing or torn checkpoints are a clean
                     error.",
        "eval" => "\
usage: xmgrid eval [--benchmark NAME]
                   [--policy random|greedy|checkpoint:PATH|artifact]
                   [--sample] [--shots K] [--batch B] [--env NAME]
                   [--shuffle S] [--prop P] [--split train|test]
                   [--threads T|auto] [--seed S] [--json [PATH]]
                   [--rooms R] [--artifacts-dir DIR]

k-shot evaluation harness: pin one held-out task per env (round-robin
over the split), run the policy for K consecutive trials of that task
(§2.1: trial resets keep the task), and report the per-shot return
curve — mean, P20, solved fraction per trial index. Runs on the native
ParVecEnv batch: no artifacts needed, bitwise deterministic per seed
for any --threads. --json writes fig-schema BENCH_eval_native.json
(one row per shot plus a throughput total, the format
scripts/compare_bench.py diffs).

  --benchmark NAME   task source (default: trivial-1k); point it at a
                     saved `xmgrid split` output to evaluate that split
                     directly
  --policy P         random (default) | greedy (scripted baseline that
                     homes on visible goal objects) |
                     checkpoint:PATH (the learned RL² policy restored
                     from a `train` checkpoint — either backend's; the
                     GRU carry runs through the k-shot loop, so the
                     curve shows within-episode adaptation) | artifact
                     (the legacy §4.2 protocol through the
                     eval_rollout artifact — needs make artifacts +
                     PJRT)
  --sample           checkpoint policy: draw actions from the
                     categorical head instead of greedy argmax
  --shots K          trials recorded per task (default: 5)
  --batch B          env batch; tasks assign round-robin, so B >= the
                     split size covers every task (default: 256)
  --env NAME         XLand registry family to evaluate in
                     (default: XLand-MiniGrid-R1-9x9)
  --shuffle S        shuffle the benchmark with seed S before splitting
  --prop P           train proportion for --split (default: 0.8)
  --split PART       evaluate the train or test part of an in-process
                     shuffle/split instead of the whole benchmark
  --threads T|auto   stepping workers (default: 1; output identical)
  --seed S           harness seed: layouts, env streams, random policy
                     (default: 0)
  --json [PATH]      write BENCH_eval_native.json (or PATH)
  --rooms R          rooms — artifact policy only (default: 1)",
        "verify" => "\
usage: xmgrid verify --benchmark NAME | --file PATH

Integrity-check a stored benchmark end to end: gzip stream, XMG1 magic,
header count vs decoded rulesets, per-task decode (errors name the task
index and byte offset), trailing garbage, and duplicate rulesets (the
store promises unique tasks). Exits non-zero on any defect.

  --benchmark NAME   check <data-dir>/NAME.xmg.gz (the same resolution
                     other commands use; $XLAND_MINIGRID_DATA overrides
                     the data dir). The file must already exist — verify
                     never generates.
  --file PATH        check an explicit store file instead",
        "validate" => "\
usage: xmgrid validate [--artifacts-dir DIR]

Compile-check every env_step artifact in the manifest. The full
transition-level oracle cross-check runs with
`cargo test --test cross_validation -- --ignored`
(the tests are #[ignore]d because they need artifacts + the PJRT
runtime).",
        "artifacts" => "\
usage: xmgrid artifacts [--artifacts-dir DIR]

List every artifact in the manifest with kind and I/O arity.",
        "lint" => LINT_HELP,
        "help" => "\
usage: xmgrid help [command]

Print global usage, or detailed options for one command.",
        _ => return None,
    })
}

fn print_global_help() {
    println!("{GLOBAL_HELP}");
}

fn cmd_help(args: &Args) -> Result<()> {
    match args.positional.get(1).map(|s| s.as_str()) {
        Some(cmd) => match command_help(cmd) {
            Some(text) => {
                println!("{text}");
                Ok(())
            }
            None => {
                println!("no such command `{cmd}`\n");
                print_global_help();
                Ok(())
            }
        },
        None => {
            print_global_help();
            Ok(())
        }
    }
}

fn cmd_envs(args: &Args) -> Result<()> {
    if !args.flag("json") {
        for name in registry::registered_environments() {
            println!("{name}");
        }
        return Ok(());
    }
    // machine-readable registry: name, kind, grid size, step limit, and
    // the family's ObsSpec/ActionSpec (derived from the shared
    // EnvParams — the same single source the engines size buffers from)
    let mut entries = Vec::new();
    for spec in registry::XLAND_ENVS.iter() {
        let params = EnvParams::new(spec.h, spec.w, 1, 1);
        entries.push(format!(
            "{{\"name\":\"{}\",\"kind\":\"xland\",\"h\":{},\"w\":{},\
             \"rooms\":{},\"max_steps\":{},\"obs\":{},\"action\":{}}}",
            spec.name, spec.h, spec.w, spec.rooms,
            xmgrid::env::default_max_steps(spec.h, spec.w),
            params.obs_spec().to_json(),
            params.action_spec().to_json()
        ));
    }
    for name in registry::MINIGRID_ENVS.iter() {
        // blueprint geometry is deterministic given a fixed seed
        let bp = registry::make(name, &mut Rng::new(0));
        let (h, w) = (bp.base_grid.h, bp.base_grid.w);
        let params = EnvParams::new(h, w, 1, 1);
        entries.push(format!(
            "{{\"name\":\"{name}\",\"kind\":\"minigrid\",\"h\":{h},\
             \"w\":{w},\"rooms\":0,\"max_steps\":{},\"obs\":{},\
             \"action\":{}}}",
            bp.max_steps,
            params.obs_spec().to_json(),
            params.action_spec().to_json()
        ));
    }
    println!("{{\"envs\":[{}]}}", entries.join(","));
    Ok(())
}

fn cmd_play(args: &Args) -> Result<()> {
    let name = args.str_or("env", "MiniGrid-Empty-8x8");
    let steps = args.usize_or("steps", 30);
    let seed = args.u64_or("seed", 0);
    let mut rng = Rng::new(seed);
    let bp = registry::make(&name, &mut rng);
    let ruleset = match bp.ruleset.clone() {
        Some(rs) => rs,
        None => {
            // XLand env: sample a trivial task
            let (mut rs, _) =
                generate_benchmark(&Preset::Trivial.config(), 1)?;
            rs.pop().unwrap()
        }
    };
    let (mut state, _) = reset(bp.base_grid, ruleset, bp.max_steps,
                               rng.split(), EnvOptions::default());
    println!("{}", render_grid(&state.grid,
                               Some((state.agent_pos, state.agent_dir)),
                               true));
    let mut total = 0.0f32;
    for i in 0..steps {
        let a = rng.below(6) as i32;
        let out = step(&mut state, a, EnvOptions::default());
        total += out.reward;
        if out.trial_done {
            println!("--- trial done at step {i} (reward {:.3})",
                     out.reward);
        }
    }
    println!("{}", render_grid(&state.grid,
                               Some((state.agent_pos, state.agent_dir)),
                               true));
    println!("total reward over {steps} random steps: {total:.3}");
    Ok(())
}

fn cmd_gen_benchmark(args: &Args) -> Result<()> {
    let preset_name = args.str_or("preset", "trivial");
    let n = args.usize_or("n", 1000);
    if n == 0 {
        bail!("--n must be at least 1");
    }
    let threads = parse_threads(args)?;
    let preset = Preset::from_name(&preset_name)
        .with_context(|| format!("unknown preset {preset_name}"))?;
    let mut cfg = preset.config();
    let default_seed = cfg.random_seed;
    cfg.random_seed = args.u64_or("seed", default_seed);
    let t0 = std::time::Instant::now();
    // Streaming pipeline: rulesets flow generator -> dedup -> gzip store
    // without ever holding the full benchmark in memory, so --n 1000000
    // works in a bounded footprint.
    //
    // Cache naming: only the default-seed benchmark may claim the
    // canonical `<preset>-<size>` name that `--benchmark` resolves and
    // other machines would auto-generate — a custom seed gets its own
    // `-seed<S>` suffix so it can never silently shadow the canonical
    // content.
    let name = if cfg.random_seed == default_seed {
        format!("{preset_name}-{}", size_suffix_name(n))
    } else {
        format!("{preset_name}-{}-seed{}", size_suffix_name(n),
                cfg.random_seed)
    };
    let dir = data_dir();
    std::fs::create_dir_all(&dir)?;
    let path = dir.join(format!("{name}.xmg.gz"));
    let mut writer = BenchmarkWriter::create(&path, n)?;
    let mut rule_sum = 0u64;
    let gen = generate_benchmark_with(&cfg, n, threads, |rs, st| {
        rule_sum += st.num_rules as u64;
        writer.push(&rs)
    });
    let attempts = match gen {
        Ok(a) => a,
        Err(e) => {
            // remove the temp file; a previously cached complete
            // benchmark at the final path stays intact
            writer.discard();
            return Err(e.context(format!(
                "generating benchmark {name}")));
        }
    };
    let (raw, comp) = writer.finish()?;
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "generated {n} unique rulesets in {secs:.1}s \
         ({attempts} attempts, {threads} threads, {:.0} rulesets/s, \
         mean rules {:.2}) -> {path:?} ({:.1} KiB raw, {:.1} KiB gz)",
        n as f64 / secs.max(1e-9), rule_sum as f64 / n as f64,
        raw as f64 / 1024.0, comp as f64 / 1024.0
    );
    Ok(())
}

fn cmd_rollout(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let backend_flag = args.str_or("backend", "auto");
    // `server:ADDR` is handled before BackendKind: the remote backend
    // needs no local benchmark or artifacts — the server owns both.
    if let Some(addr) = backend_flag.strip_prefix("server:") {
        return cmd_rollout_server(args, addr);
    }
    let backend = BackendKind::from_flag(&backend_flag)?;
    let batch = args.usize_or("batch", 1024);
    let chunks = args.usize_or("chunks", 4);
    let threads = parse_threads(args)?;
    let obs_mode = ObsMode::from_flag(&args.str_or("obs", "symbolic"))?;
    let cfg = shard_config(args)?;
    let bench = Arc::new(load_benchmark_with(
        &args.str_or("benchmark", "trivial-1k"), threads)?);

    // Backend selection: an explicit flag wins; `auto` takes the
    // AOT/PJRT path only when a manifest with rollout artifacts exists,
    // and otherwise falls back to the native vectorized engine — so a
    // fresh checkout rolls out with zero build steps. The manifest is
    // loaded once and reused by the xla launch path.
    let manifest = match backend {
        BackendKind::Native => None,
        BackendKind::Xla => Some(Manifest::load(&dir)?),
        BackendKind::Auto => Manifest::load(&dir)
            .ok()
            .filter(|m| !m.of_kind("env_rollout").is_empty()),
    };

    let engine = if let Some(manifest) = manifest {
        if obs_mode != ObsMode::Symbolic {
            bail!(
                "--obs {obs_mode} needs the native backend (the xla \
                 rollout artifacts bake the symbolic spec; image \
                 observations on xla go through the render_rgb \
                 artifacts — see the fig13 bench). Re-run with \
                 --backend native."
            );
        }
        if args.get("env").is_some() || args.get("steps").is_some() {
            println!("note: --env/--steps apply to the native backend \
                      only; the xla family/T come from the artifact");
        }
        if threads > 1 {
            println!("note: --threads chunks the native backend's \
                      stepping; the xla backend parallelizes over \
                      --shards");
        }
        let rolls = manifest.of_kind("env_rollout");
        let spec = rolls
            .iter()
            .find(|s| s.meta_usize("B").unwrap() == batch)
            .or_else(|| rolls.first())
            .context("no env_rollout artifacts; run `make artifacts`")?;
        let fam = EnvFamily::from_spec(spec)?;
        let t = spec.meta_usize("T")?;
        println!(
            "backend xla: artifact {} (B={} T={t}) shards={} overlap={}",
            spec.name, fam.b, cfg.shards, cfg.overlap
        );
        RolloutEngine::launch(dir, spec.name.clone(), bench, cfg)?
    } else {
        if args.get("rooms").is_some() {
            println!("note: --rooms applies to the xla backend only; \
                      the native room count comes from --env");
        }
        let env_name =
            args.str_or("env", "XLand-MiniGrid-R1-13x13");
        let t = args.usize_or("steps", 64);
        let ncfg = NativeEnvConfig::for_env(&env_name, batch, t, &bench)?
            .with_threads(threads)
            .with_retry(retry_policy(args));
        println!(
            "backend native: {env_name} (B={batch} T={t} grid {}x{} \
             rooms {}) shards={} threads={} overlap={} obs={obs_mode}",
            ncfg.params.h, ncfg.params.w, ncfg.rooms, cfg.shards,
            ncfg.threads, cfg.overlap
        );
        RolloutEngine::launch_native_obs(ncfg, bench, cfg, obs_mode)?
    };
    report_rollout(engine, chunks, &cfg)
}

/// The chunk/window/total reporting tail shared by every rollout
/// backend (native, xla, server) — one print path, so backend
/// comparisons diff bitwise on the deterministic fields after
/// stripping the timing columns.
fn report_rollout(engine: RolloutEngine, chunks: usize,
                  cfg: &ShardConfig) -> Result<()> {
    let totals = if cfg.shards == 1 {
        let mut meter = ThroughputMeter::new();
        engine.collect(chunks, |c| {
            meter.add(c.steps);
            println!(
                "chunk {}: steps={} reward={:.1} episodes={} \
                 trials={} shard-secs={:.3} cum-sps={}",
                c.round, c.steps, c.reward_sum, c.episodes, c.trials,
                c.secs, fmt_sps(meter.sps())
            );
        })?
    } else {
        // Windowed reporting: one aggregate line per `shards` chunks.
        engine.collect_windowed(chunks, cfg.shards, |w, win| {
            println!(
                "window {w:>3}: steps={} reward={:.1} episodes={} \
                 trials={} window-sps={}",
                win.steps, win.reward_sum, win.episodes, win.trials,
                fmt_sps(win.sps())
            );
        })?
    };
    println!(
        "total: shards={} overlap={} steps={} elapsed={:.2}s sps={}",
        cfg.shards, cfg.overlap, totals.steps, totals.elapsed,
        fmt_sps(totals.sps())
    );
    Ok(())
}

/// `rollout --backend server:ADDR` — every shard opens its own
/// session against a running `xmgrid serve` instance and steps it
/// through the [`BatchEnvironment`] wire client. RNG state ships in
/// the reset RPC and action draws stay client-side, so the chunk and
/// total lines are bitwise-identical to `--backend native` with the
/// same seed/batch/steps.
fn cmd_rollout_server(args: &Args, addr: &str) -> Result<()> {
    let addr = ServerAddr::parse(addr)?;
    let batch = args.usize_or("batch", 1024);
    let chunks = args.usize_or("chunks", 4);
    let t = args.usize_or("steps", 64);
    let threads = parse_threads(args)?;
    let obs_mode = ObsMode::from_flag(&args.str_or("obs", "symbolic"))?;
    let cfg = shard_config(args)?;
    let deadline_ms = args.u64_or("deadline-ms", 5_000);
    let spec = SessionSpec {
        env: args.str_or("env", "XLand-MiniGrid-R1-13x13"),
        benchmark: args.str_or("benchmark", "trivial-1k"),
        b: batch,
        t,
        threads,
    };
    // Probe on the main thread: an unreachable server or unknown env
    // is a clean CLI error here, not a shard-spawn failure; the hello
    // reply carries the grid family for the engine header.
    let params = {
        let mut conn = Connection::connect(&addr, deadline_ms)
            .with_context(|| format!("probing rollout server {addr}"))?;
        let params = conn.hello(&spec)
            .with_context(|| format!("opening probe session on {addr}"))?;
        conn.bye();
        params
    };
    let family = EnvFamily {
        h: params.h,
        w: params.w,
        mr: params.max_rules,
        mi: params.max_init,
        b: batch,
    };
    println!(
        "backend server ({addr}): {} (B={batch} T={t} grid {}x{}) \
         shards={} threads={} overlap={} obs={obs_mode} \
         deadline={deadline_ms}ms",
        spec.env, params.h, params.w, cfg.shards, threads, cfg.overlap
    );
    let engine = RolloutEngine::launch_batch_envs(
        move |shard, rng| {
            let mut client =
                ServerClient::connect_session(&addr, &spec, deadline_ms)
                    .with_context(|| {
                        format!("opening session for shard {shard}")
                    })?;
            // Mirror the native launch order: reset the raw pool
            // surface first (consuming the shard rng exactly as the
            // in-process reset does), then stack the obs wrappers.
            let mut scratch = vec![0i32; client.obs_len()];
            client.reset(rng, &mut scratch)?;
            Ok(obs_mode.wrap(client))
        },
        batch, t, family, cfg,
    )?;
    report_rollout(engine, chunks, &cfg)
}

/// `xmgrid serve` — bind, install the SIGTERM/SIGINT drain handler,
/// and serve sessions until drained. `--shutdown` flips the command
/// into a client that requests a graceful drain of a running server.
fn cmd_serve(args: &Args) -> Result<()> {
    let deadline_ms = args.u64_or("deadline-ms", 5_000);
    if args.flag("shutdown") {
        let addr = serve_target(args)?;
        request_shutdown(&addr, deadline_ms)
            .with_context(|| format!("requesting drain of {addr}"))?;
        println!("drain requested on {addr}");
        return Ok(());
    }
    let cfg = ServeConfig {
        io_deadline_ms: deadline_ms,
        idle_timeout_ms: args.u64_or("idle-ms", 30_000),
        queue_depth: args.usize_or("queue-depth", 8),
        faults: Arc::new(
            FaultPlan::from_env()
                .with_context(|| format!("invalid {FAULTS_ENV}"))?,
        ),
    };
    if cfg.queue_depth == 0 {
        bail!("--queue-depth must be at least 1");
    }
    let server = match (args.get("socket"), args.get("port")) {
        (Some(path), None) => Server::bind_unix(path, cfg)?,
        (None, Some(port)) => {
            let host = args.str_or("host", "127.0.0.1");
            Server::bind_tcp(&format!("{host}:{port}"), cfg)?
        }
        (Some(_), Some(_)) => {
            bail!("serve takes --socket PATH or --port P, not both")
        }
        (None, None) => {
            bail!("serve needs --socket PATH or --port P \
                   (see `xmgrid help serve`)")
        }
    };
    install_signal_drain();
    println!("serving on {}", server.local_addr()?);
    let stats = server.serve()?;
    println!(
        "drained: sessions={} requests={} uptime={:.2}s",
        stats.sessions, stats.requests, stats.uptime_secs
    );
    Ok(())
}

/// The address a `serve --shutdown` invocation should drain, built
/// from the same `--socket`/`--port`/`--host` flags a serving
/// invocation uses.
fn serve_target(args: &Args) -> Result<ServerAddr> {
    if let Some(path) = args.get("socket") {
        return ServerAddr::parse(&format!("unix:{path}"));
    }
    if let Some(port) = args.get("port") {
        let host = args.str_or("host", "127.0.0.1");
        return ServerAddr::parse(&format!("tcp:{host}:{port}"));
    }
    bail!("serve --shutdown needs the target's --socket or --port")
}

fn pick_train_artifact(manifest: &Manifest, batch: usize)
                       -> Result<String> {
    let arts = manifest.of_kind("train_iter");
    let spec = arts
        .iter()
        .find(|s| s.meta_usize("B").unwrap() == batch)
        .or_else(|| {
            arts.iter().max_by_key(|s| s.meta_usize("B").unwrap())
        })
        .context("no train_iter artifacts; run `make artifacts`")?;
    Ok(spec.name.clone())
}

fn cmd_train(args: &Args) -> Result<()> {
    let obs_mode = ObsMode::from_flag(&args.str_or("obs", "symbolic"))?;
    // Backend selection mirrors `rollout`: an explicit flag wins;
    // `auto` takes the AOT/PJRT path only when a manifest with
    // train_iter artifacts exists, and otherwise falls back to the
    // native training stack — a fresh checkout trains with zero build
    // steps.
    let backend = BackendKind::from_flag(&args.str_or("backend", "auto"))?;
    let use_xla = match backend {
        BackendKind::Native => false,
        BackendKind::Xla => true,
        BackendKind::Auto => Manifest::load(&artifacts_dir(args))
            .ok()
            .map_or(false, |m| !m.of_kind("train_iter").is_empty()),
    };
    if !use_xla {
        return cmd_train_native(args, obs_mode);
    }
    // --obs: the train_iter artifacts bake the symbolic ObsSpec into
    // the compiled policy input; other stacks need re-lowered
    // artifacts, so anything else is an explicit error, not a silent
    // fallback.
    if obs_mode != ObsMode::Symbolic {
        bail!("train --backend xla --obs {obs_mode}: the train_iter \
               artifacts are lowered against the symbolic ObsSpec; \
               re-run python/compile/aot.py with a different obs head, \
               or use --backend native, which trains on \
               --obs symbolic|dir|rules-goals directly");
    }
    let scfg = {
        // train defaults its seed to the Table 6 seed, not 0
        let mut c = shard_config(args)?;
        c.seed = args.u64_or("seed", TrainConfig::default().train_seed);
        c
    };
    // Checkpointing and resume live in the shard-engine path (the
    // checkpoint format captures per-shard replica states); route there
    // even for one shard when either is requested.
    if scfg.shards > 1 || args.flag("resume")
        || args.usize_or("checkpoint-every", 0) > 0
    {
        return cmd_train_sharded(args, scfg);
    }
    let rt = Runtime::new(&artifacts_dir(args))?;
    let bench = load_benchmark_with(
        &args.str_or("benchmark", "trivial-1k"), parse_threads(args)?)?;
    let iters = args.usize_or("iters", 50);
    let artifact = match args.get("artifact") {
        Some(a) => a.to_string(),
        None => {
            pick_train_artifact(&rt.manifest, args.usize_or("batch", 256))?
        }
    };
    let rooms = scfg.rooms;
    let mut cfg = TrainConfig::default();
    cfg.train_seed = scfg.seed;
    cfg.task_resample_iters =
        args.usize_or("resample", cfg.task_resample_iters);
    let eval_every = args.usize_or("eval-every", 0);
    let eval_art = rt
        .manifest
        .of_kind("eval_rollout")
        .iter()
        .map(|s| s.name.clone())
        .next();

    println!("training with {artifact} on {} ({} tasks)", bench.name,
             bench.num_rulesets());
    let mut trainer = Trainer::new(&rt, &artifact, rooms, cfg)?;
    trainer.resample_tasks(&bench)?;

    let csv_path = PathBuf::from(
        args.str_or("log", "artifacts/train_log.csv"));
    let mut log = CsvLog::create(&csv_path, &[
        "iter", "env_steps", "loss", "pi_loss", "v_loss", "entropy",
        "approx_kl", "reward_per_step", "trials", "sps",
    ])?;

    let t0 = std::time::Instant::now();
    let mut env_steps = 0u64;
    for i in 1..=iters {
        if i > 1 && (i - 1) % trainer.cfg.task_resample_iters == 0 {
            trainer.resample_tasks(&bench)?;
        }
        let m = trainer.train_iter()?;
        env_steps += m.env_steps;
        let sps = env_steps as f64 / t0.elapsed().as_secs_f64();
        log.row(&[
            i.to_string(), env_steps.to_string(),
            format!("{:.4}", m.total_loss), format!("{:.4}", m.pi_loss),
            format!("{:.4}", m.v_loss), format!("{:.4}", m.entropy),
            format!("{:.5}", m.approx_kl),
            format!("{:.5}", m.reward_sum / m.env_steps as f32),
            m.trials.to_string(), format!("{sps:.0}"),
        ])?;
        if i % 10 == 0 || i == iters {
            println!(
                "iter {i:>4} steps {env_steps:>9} loss {:+.4} ent {:.3} \
                 r/step {:.4} trials {:>5} sps {}",
                m.total_loss, m.entropy,
                m.reward_sum / m.env_steps as f32, m.trials, fmt_sps(sps)
            );
        }
        if eval_every > 0 && i % eval_every == 0 {
            if let Some(ea) = &eval_art {
                let st = trainer.evaluate(&rt, ea, &bench, rooms)?;
                println!(
                    "  eval: return mean {:.3} P20 {:.3} per-trial {:.3} \
                     (tasks {})",
                    st.return_mean, st.return_p20, st.per_trial_mean,
                    st.num_tasks
                );
            }
        }
    }
    println!("log written to {csv_path:?}");
    Ok(())
}

/// `train --shards K`: the data-parallel shard engine path.
fn cmd_train_sharded(args: &Args, scfg: ShardConfig) -> Result<()> {
    let dir = artifacts_dir(args);
    let manifest = Manifest::load(&dir)?;
    let bench = Arc::new(load_benchmark_with(
        &args.str_or("benchmark", "trivial-1k"), parse_threads(args)?)?);
    let iters = args.usize_or("iters", 50);
    let artifact = match args.get("artifact") {
        Some(a) => a.to_string(),
        None => pick_train_artifact(&manifest, args.usize_or("batch", 256))?,
    };
    // seed flows through scfg.seed; ShardedTrainer::launch derives the
    // per-shard train seeds from it
    let mut cfg = TrainConfig::default();
    cfg.task_resample_iters =
        args.usize_or("resample", cfg.task_resample_iters);
    let eval_every = args.usize_or("eval-every", 0);
    let eval_art = manifest
        .of_kind("eval_rollout")
        .iter()
        .map(|s| s.name.clone())
        .next();

    println!(
        "training with {artifact} on {} ({} tasks) — {} shards, overlap {}",
        bench.name, bench.num_rulesets(), scfg.shards, scfg.overlap
    );
    let mut engine = ShardedTrainer::launch(dir, artifact, bench, scfg,
                                            cfg)?;

    let ckpt_path = PathBuf::from(
        args.str_or("checkpoint", "artifacts/train_ckpt.bin"));
    let resume = args.flag("resume");
    if resume {
        let ckpt = load_checkpoint(&ckpt_path).context(
            "cannot resume (re-run without --resume to start fresh)")?;
        engine.restore(&ckpt)?;
        println!("resumed from {ckpt_path:?} at iteration {}",
                 engine.iters_done);
    }
    let ckpt_every = args.usize_or("checkpoint-every", 0);
    if ckpt_every > 0 {
        engine.checkpoint = Some(CheckpointPlan {
            path: ckpt_path.clone(),
            every: ckpt_every,
            faults: Arc::new(FaultPlan::from_env()?),
        });
        println!("checkpointing to {ckpt_path:?} every {ckpt_every} \
                  iteration(s)");
    }

    let csv_path = PathBuf::from(
        args.str_or("log", "artifacts/train_log.csv"));
    let header = [
        "iter", "env_steps", "loss", "pi_loss", "v_loss", "entropy",
        "approx_kl", "reward_per_step", "trials", "sps",
    ];
    let mut log = if resume {
        CsvLog::append(&csv_path, &header)?
    } else {
        CsvLog::create(&csv_path, &header)?
    };

    let mut meter = ThroughputMeter::new();
    // --iters is the run's total; on resume, only the remainder runs.
    let mut done = engine.iters_done;
    if done >= iters {
        println!("checkpoint already at iteration {done} >= --iters \
                  {iters}; nothing to do");
        return Ok(());
    }
    let base_steps = engine.steps_per_iter() * done as u64;
    while done < iters {
        let n = if eval_every > 0 {
            eval_every.min(iters - done)
        } else {
            iters - done
        };
        engine.train(n, |i, m| {
            meter.add(m.env_steps);
            let sps = meter.sps();
            log.row(&[
                i.to_string(), (base_steps + meter.steps()).to_string(),
                format!("{:.4}", m.total_loss),
                format!("{:.4}", m.pi_loss),
                format!("{:.4}", m.v_loss),
                format!("{:.4}", m.entropy),
                format!("{:.5}", m.approx_kl),
                format!("{:.5}", m.reward_sum / m.env_steps as f32),
                m.trials.to_string(), format!("{sps:.0}"),
            ])
            .with_context(|| format!("writing {csv_path:?}"))?;
            if i % 10 == 0 || i == iters {
                println!(
                    "iter {i:>4} steps {:>9} loss {:+.4} ent {:.3} \
                     r/step {:.4} trials {:>5} sps {}",
                    base_steps + meter.steps(), m.total_loss, m.entropy,
                    m.reward_sum / m.env_steps as f32, m.trials,
                    fmt_sps(sps)
                );
            }
            Ok(())
        })?;
        done += n;
        if eval_every > 0 && done % eval_every == 0 {
            if let Some(ea) = &eval_art {
                let st = engine.evaluate(ea, scfg.rooms)?;
                println!(
                    "  eval: return mean {:.3} P20 {:.3} per-trial {:.3} \
                     (tasks {})",
                    st.return_mean, st.return_p20, st.per_trial_mean,
                    st.num_tasks
                );
            }
        }
    }
    println!("log written to {csv_path:?}");
    Ok(())
}

/// `train --backend native`: the pure-Rust GRU actor-critic + PPO
/// stack over the vectorized native env pool. No artifacts, no PJRT:
/// a fresh checkout trains immediately, bitwise-reproducible per seed
/// for any `--threads`, and writes the same `TrainCheckpoint` format
/// as the xla path.
fn cmd_train_native(args: &Args, obs_mode: ObsMode) -> Result<()> {
    let scfg = {
        // train defaults its seed to the Table 6 seed, not 0
        let mut c = shard_config(args)?;
        c.seed = args.u64_or("seed", TrainConfig::default().train_seed);
        c
    };
    let threads = parse_threads(args)?;
    let bench = Arc::new(load_benchmark_with(
        &args.str_or("benchmark", "trivial-1k"), threads)?);
    let iters = args.usize_or("iters", 50);
    let batch = args.usize_or("batch", 256);
    let t = args.usize_or("steps", 64);
    let env_name = args.str_or("env", "XLand-MiniGrid-R1-9x9");
    if args.get("artifact").is_some() {
        println!("note: --artifact applies to the xla backend only; \
                  the native model shape is built in");
    }
    let mut cfg = TrainConfig::default();
    cfg.task_resample_iters =
        args.usize_or("resample", cfg.task_resample_iters);
    // resolve --resume before building replicas: a missing or torn
    // checkpoint fails fast, before any buffer is allocated
    let ckpt_path = PathBuf::from(
        args.str_or("checkpoint", "artifacts/train_ckpt.bin"));
    let resume = args.flag("resume");
    let resume_ckpt = if resume {
        Some(load_checkpoint(&ckpt_path).context(
            "cannot resume (re-run without --resume to start fresh)")?)
    } else {
        None
    };
    let ncfg = NativeEnvConfig::for_env(&env_name, batch, t, &bench)?
        .with_threads(threads)
        .with_retry(retry_policy(args));
    let eval_ncfg = ncfg.clone();
    let tcfg = NativeTrainerConfig {
        env: ncfg,
        obs: obs_mode,
        model: None,
        epochs: args.usize_or("epochs", 1),
        minibatches: args.usize_or("minibatches", 1),
    };
    println!(
        "backend native: {env_name} on {} ({} tasks) — B={batch} \
         T={t} obs={obs_mode} epochs={} minibatches={} shards={} \
         threads={threads}",
        bench.name, bench.num_rulesets(), tcfg.epochs,
        tcfg.minibatches, scfg.shards
    );
    let tasks: Arc<dyn xmgrid::env::state::TaskSource> = bench.clone();
    let mut engine =
        NativeShardedTrainer::launch(tcfg, tasks, scfg, cfg)?;

    if let Some(ckpt) = &resume_ckpt {
        engine.restore(ckpt)?;
        println!("resumed from {ckpt_path:?} at iteration {}",
                 engine.iters_done);
    }
    let ckpt_every = args.usize_or("checkpoint-every", 0);
    if ckpt_every > 0 {
        engine.checkpoint = Some(CheckpointPlan {
            path: ckpt_path.clone(),
            every: ckpt_every,
            faults: Arc::new(FaultPlan::from_env()?),
        });
        println!("checkpointing to {ckpt_path:?} every {ckpt_every} \
                  iteration(s)");
    }

    let csv_path = PathBuf::from(
        args.str_or("log", "artifacts/train_log.csv"));
    let header = [
        "iter", "env_steps", "loss", "pi_loss", "v_loss", "entropy",
        "approx_kl", "reward_per_step", "trials", "sps",
    ];
    let mut log = if resume {
        CsvLog::append(&csv_path, &header)?
    } else {
        CsvLog::create(&csv_path, &header)?
    };

    let eval_every = args.usize_or("eval-every", 0);
    let mut meter = ThroughputMeter::new();
    // --iters is the run's total; on resume, only the remainder runs.
    let mut done = engine.iters_done;
    if done >= iters {
        println!("checkpoint already at iteration {done} >= --iters \
                  {iters}; nothing to do");
        return Ok(());
    }
    let base_steps = engine.steps_per_iter() * done as u64;
    while done < iters {
        let n = if eval_every > 0 {
            eval_every.min(iters - done)
        } else {
            iters - done
        };
        engine.train(n, |i, m| {
            meter.add(m.env_steps);
            let sps = meter.sps();
            log.row(&[
                i.to_string(), (base_steps + meter.steps()).to_string(),
                format!("{:.4}", m.total_loss),
                format!("{:.4}", m.pi_loss),
                format!("{:.4}", m.v_loss),
                format!("{:.4}", m.entropy),
                format!("{:.5}", m.approx_kl),
                format!("{:.5}", m.reward_sum / m.env_steps as f32),
                m.trials.to_string(), format!("{sps:.0}"),
            ])
            .with_context(|| format!("writing {csv_path:?}"))?;
            if i % 10 == 0 || i == iters {
                println!(
                    "iter {i:>4} steps {:>9} loss {:+.4} ent {:.3} \
                     r/step {:.4} trials {:>5} sps {}",
                    base_steps + meter.steps(), m.total_loss, m.entropy,
                    m.reward_sum / m.env_steps as f32, m.trials,
                    fmt_sps(sps)
                );
            }
            Ok(())
        })?;
        done += n;
        if eval_every > 0 && done % eval_every == 0 {
            // the native eval is the k-shot harness driving the
            // current master parameters greedily (§4.2 protocol)
            let dims = ModelDims::infer(
                &engine.master, eval_ncfg.params.opts.view_size)?;
            let params = Params::from_tensors(dims, &engine.master)?;
            let kcfg = KShotConfig {
                params: eval_ncfg.params,
                rooms: eval_ncfg.rooms,
                b: batch,
                shots: 5,
                threads,
                seed: engine.train_cfg.eval_seed,
            };
            let policy = EvalPolicy::Checkpoint {
                params: Box::new(params),
                sample: false,
            };
            let rep = eval_kshot(&*bench, policy, &kcfg)?;
            let (first, last) = (rep.shots.first(), rep.shots.last());
            println!(
                "  eval: shot-1 return {:.3} | shot-{} return {:.3} \
                 | P20 {:.3} (tasks {})",
                first.map_or(0.0, |s| s.return_mean),
                rep.shots.len(),
                last.map_or(0.0, |s| s.return_mean),
                last.map_or(0.0, |s| s.return_p20),
                rep.tasks
            );
        }
    }
    println!("log written to {csv_path:?}");
    Ok(())
}

/// `"LO..HI"` → `LO..HI` (half-open, usize).
fn parse_range(s: &str) -> Result<std::ops::Range<usize>> {
    let (lo, hi) = s
        .split_once("..")
        .with_context(|| format!("range must be LO..HI, got {s}"))?;
    let lo: usize = lo.parse()
        .with_context(|| format!("bad range start in {s}"))?;
    let hi: usize = hi.parse()
        .with_context(|| format!("bad range end in {s}"))?;
    if hi < lo {
        bail!("empty range {s}");
    }
    Ok(lo..hi)
}

/// Comma-separated goal id list (`1,3,4`).
fn parse_goal_ids(s: &str) -> Result<Vec<i32>> {
    s.split(',')
        .map(|t| {
            t.trim()
                .parse::<i32>()
                .with_context(|| format!("bad goal id `{t}` in {s}"))
        })
        .collect()
}

/// Shared op pipeline of `split`/`eval`: filter by goals, filter by
/// depth, subset, shuffle — fixed order, every stage a pure function
/// of (slice, flag values).
fn apply_slice_ops(mut slice: TaskSlice, args: &Args)
                   -> Result<TaskSlice> {
    if let Some(g) = args.get("goals") {
        slice = slice.filter_goals(&parse_goal_ids(g)?);
    }
    if let Some(d) = args.get("depth") {
        slice = slice.filter_depth(parse_range(d)?);
    }
    if let Some(r) = args.get("subset") {
        slice = slice.subset(parse_range(r)?);
    }
    if let Some(seed) = args.get("shuffle") {
        let seed: u64 = seed.parse()
            .with_context(|| format!("--shuffle needs a u64 seed, \
                                      got {seed}"))?;
        slice = slice.shuffle(seed);
    }
    Ok(slice)
}

fn cmd_split(args: &Args) -> Result<()> {
    let name = args.str_or("benchmark", "trivial-1k");
    let bench = Arc::new(load_benchmark_with(&name,
                                             parse_threads(args)?)?);
    let total = bench.num_rulesets();
    let slice = apply_slice_ops(TaskSlice::full(bench), args)?;
    if slice.is_empty() {
        bail!("the op pipeline selected 0 of {total} tasks — nothing \
               to split");
    }
    let prop = args.f64_or("prop", 0.8);
    if !(0.0..=1.0).contains(&prop) {
        bail!("--prop must be in [0, 1], got {prop}");
    }
    let selected = slice.len();
    let (train, test) = slice.split(prop);
    let prefix = args.str_or("out", &name);
    let dir = data_dir();
    std::fs::create_dir_all(&dir)?;
    for (part, s) in [("train", &train), ("test", &test)] {
        if s.is_empty() {
            println!("{part}: 0 tasks — not saved");
            continue;
        }
        let path = dir.join(format!("{prefix}-{part}.xmg.gz"));
        let (raw, comp) = s.save(&path)?;
        println!(
            "{part}: {} tasks -> {path:?} ({:.1} KiB raw, {:.1} KiB gz)",
            s.len(), raw as f64 / 1024.0, comp as f64 / 1024.0
        );
    }
    println!(
        "selected {selected}/{total} tasks, split {}/{} at prop {prop}; \
         load with --benchmark {prefix}-train / {prefix}-test",
        train.len(), test.len()
    );
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let pol_flag = args.str_or("policy", "random");
    if pol_flag == "artifact" {
        return cmd_eval_artifact(args);
    }
    let name = args.str_or("benchmark", "trivial-1k");
    let bench = Arc::new(load_benchmark_with(&name,
                                             parse_threads(args)?)?);
    let mut slice = apply_slice_ops(TaskSlice::full(bench), args)?;
    if let Some(part) = args.get("split") {
        let prop = args.f64_or("prop", 0.8);
        let (train, test) = slice.split(prop);
        slice = match part {
            "train" => train,
            "test" => test,
            other => bail!("--split must be train | test, got {other}"),
        };
    }
    if slice.is_empty() {
        bail!("the selected split is empty — nothing to evaluate");
    }
    let shots = args.usize_or("shots", 5);
    let batch = args.usize_or("batch", 256);
    let env_name = args.str_or("env", "XLand-MiniGrid-R1-9x9");
    let ncfg = NativeEnvConfig::for_tasks(&env_name, batch, 1, &slice)?;
    let cfg = KShotConfig {
        params: ncfg.params,
        rooms: ncfg.rooms,
        b: batch,
        shots,
        threads: parse_threads(args)?,
        seed: args.u64_or("seed", 0),
    };
    // `--policy checkpoint:PATH` loads a train checkpoint's master
    // parameters (either backend writes the same format) and runs the
    // learned RL² policy through the harness — greedy argmax by
    // default, `--sample` draws from the categorical head.
    let policy = match pol_flag.strip_prefix("checkpoint:") {
        Some(path) => {
            let ckpt = load_checkpoint(&PathBuf::from(path))
                .with_context(|| {
                    format!("loading --policy checkpoint {path}")
                })?;
            let dims = ModelDims::infer(&ckpt.master,
                                        ncfg.params.opts.view_size)?;
            let params = Params::from_tensors(dims, &ckpt.master)?;
            println!(
                "policy checkpoint: {path} (iteration {}, extras {}, \
                 {})",
                ckpt.iters_done, dims.extra,
                if args.flag("sample") { "sampled" } else { "greedy" }
            );
            EvalPolicy::Checkpoint {
                params: Box::new(params),
                sample: args.flag("sample"),
            }
        }
        None => EvalPolicy::from_flag(&pol_flag)?,
    };
    println!(
        "k-shot eval: {} on {} ({} tasks, {} envs, {shots} shots, \
         {} threads, seed {})",
        policy.name(), slice.name, slice.len(), batch, cfg.threads,
        cfg.seed
    );
    let rep = eval_kshot(&slice, policy, &cfg)?;
    for st in &rep.shots {
        println!(
            "  shot {:>2}: return mean {:.4} | P20 {:.4} | solved \
             {:>5.1}% | len {:>6.1}",
            st.shot, st.return_mean, st.return_p20,
            st.solved_frac * 100.0, st.len_mean
        );
    }
    println!(
        "  total: {} env steps in {:.2}s ({} steps/s)",
        rep.total_steps, rep.elapsed_secs, fmt_sps(rep.steps_per_sec())
    );
    if let Some(path) = json_arg_path(args, "eval_native") {
        let mut report = JsonReport::new("eval_native");
        let sps = rep.steps_per_sec();
        for st in &rep.shots {
            report.add_sps_extra(
                &format!("eval-{}-shot{}", rep.policy, st.shot),
                rep.envs,
                st.len_mean.round() as usize,
                sps,
                &format!(
                    "\"shot\":{},\"return_mean\":{:.6},\
                     \"return_p20\":{:.6},\"solved_frac\":{:.6},\
                     \"tasks\":{}",
                    st.shot, st.return_mean, st.return_p20,
                    st.solved_frac, rep.tasks
                ),
            );
        }
        report.add_sps(&format!("eval-{}-total", rep.policy), rep.envs,
                       (rep.total_steps / rep.envs.max(1) as u64)
                           as usize,
                       sps);
        report.metric("shots", shots as f64);
        report.metric("tasks", rep.tasks as f64);
        report.metric(&format!("{}_first_shot_return", rep.policy),
                      rep.shots.first().map_or(0.0, |s| s.return_mean));
        report.metric(&format!("{}_final_shot_return", rep.policy),
                      rep.shots.last().map_or(0.0, |s| s.return_mean));
        report.note(&format!(
            "k-shot eval on {}: one pinned task per env (round-robin), \
             shot j = trial j per §2.1; deterministic per seed for any \
             --threads", slice.name
        ));
        report.write(&path)?;
        println!("wrote {path:?}");
    }
    Ok(())
}

/// The legacy artifact-backed §4.2 protocol (`--policy artifact`).
fn cmd_eval_artifact(args: &Args) -> Result<()> {
    let rt = Runtime::new(&artifacts_dir(args))?;
    let bench = load_benchmark_with(
        &args.str_or("benchmark", "trivial-1k"), parse_threads(args)?)?;
    let artifact =
        pick_train_artifact(&rt.manifest, args.usize_or("batch", 256))?;
    let rooms = args.usize_or("rooms", 1);
    let mut trainer =
        Trainer::new(&rt, &artifact, rooms, TrainConfig::default())?;
    trainer.resample_tasks(&bench)?;
    let eval_name = rt
        .manifest
        .of_kind("eval_rollout")
        .iter()
        .map(|s| s.name.clone())
        .next()
        .context("no eval_rollout artifact")?;
    let st = trainer.evaluate(&rt, &eval_name, &bench, rooms)?;
    println!(
        "eval on {}: return mean {:.3} | P20 {:.3} | per-trial mean {:.3} \
         | per-trial P20 {:.3} | trials/task {:.1} | tasks {}",
        bench.name, st.return_mean, st.return_p20, st.per_trial_mean,
        st.per_trial_p20, st.trials_mean, st.num_tasks
    );
    Ok(())
}

/// `xmgrid verify`: benchmark store integrity check (satellite of the
/// fault-tolerance work — a corrupted task store should fail loudly and
/// diagnosably, not train on garbage).
fn cmd_verify(args: &Args) -> Result<()> {
    let path = match (args.get("file"), args.get("benchmark")) {
        (Some(f), _) => PathBuf::from(f),
        (None, Some(name)) => {
            data_dir().join(format!("{name}.xmg.gz"))
        }
        (None, None) => {
            bail!("verify needs --benchmark NAME or --file PATH \
                   (see `xmgrid help verify`)")
        }
    };
    if !path.exists() {
        bail!("{path:?} does not exist — verify checks an existing \
               store file and never generates one");
    }
    let report = verify_file(&path)?;
    println!(
        "{path:?}: OK — {} unique tasks, {} bytes raw, {} bytes \
         compressed",
        report.tasks, report.raw_bytes, report.compressed_bytes
    );
    Ok(())
}

const LINT_HELP: &str = "\
usage: xmgrid lint [--json] [--rules a,b,c] [paths...]

Token-level static analysis encoding the repo's determinism and
panic-safety invariants. Scans `.rs` files (directories recurse;
`#[cfg(test)]` / `#[test]` regions are exempt) and exits 1 on any
violation — CI runs this as a hard gate.

rules:
  no-std-rng              only util::rng::Rng / stream_seed may produce
                          randomness in env/, benchgen/, coordinator/
  no-hash-iter            no HashMap/HashSet iteration (or DefaultHasher/
                          RandomState) in determinism-critical modules —
                          BTreeMap or collect+sort instead
  no-wallclock-in-kernels Instant::now / SystemTime confined to
                          util/bench.rs, coordinator/metrics.rs
                          (WallTimer) and main.rs — the server tier
                          (server/) times itself through WallTimer
  no-unwrap-in-workers    no .unwrap()/.expect() in the supervised
                          worker / channel paths (shard.rs, workers.rs,
                          rollout.rs, trainer.rs) or anywhere in the
                          service tier (server/)
  float-reduction-order   no f32 accumulation or unordered float folds
                          in coordinator reduction paths
  must-use-result         no discarded Result from fallible engine ops
                          (submit/broadcast/wait/rollout/save/...)
  bad-allow               allow directives must parse, name a known
                          rule, carry a reason, and suppress something

options:
  --json          schema-stable JSON report on stdout (version-pinned;
                  the CI gate validates it)
  --rules a,b,c   run a subset of rules (default: all)
  paths...        files or directories (default: src, or rust/src when
                  run from the repo root)

escape hatch — a reviewed claim, never a bare opt-out:
  // xmglint: allow(rule-id) -- why this site is sound
suppresses matching violations on the same line, or on the next code
line when the directive sits on its own (plain comments may sit
between). Allows that no longer suppress anything are themselves
violations: delete them when the code they excused goes away.";

fn cmd_lint(args: &Args) -> Result<()> {
    let cfg = match args.get("rules") {
        Some(list) => match lint::LintConfig::subset(list) {
            Ok(c) => c,
            Err(e) => bail!("{e}"),
        },
        None => lint::LintConfig::all(),
    };
    let mut paths: Vec<PathBuf> = args.positional[1..]
        .iter()
        .map(PathBuf::from)
        .collect();
    if paths.is_empty() {
        // running from rust/ (CI, cargo run) or from the repo root
        let src = PathBuf::from("src");
        let alt = PathBuf::from("rust/src");
        if src.is_dir() {
            paths.push(src);
        } else if alt.is_dir() {
            paths.push(alt);
        } else {
            bail!("no lint paths given and neither src/ nor rust/src/ \
                   exists here — pass files or directories explicitly");
        }
    }
    let outcome = lint::lint_paths(&paths, &cfg)?;
    if args.flag("json") {
        print!("{}", lint::report::json(&outcome, cfg.enabled()));
    } else {
        print!("{}", lint::report::human(&outcome, cfg.enabled()));
    }
    if !outcome.violations.is_empty() {
        bail!(
            "lint failed: {} violation(s) — see report above",
            outcome.violations.len()
        );
    }
    Ok(())
}

fn cmd_validate(args: &Args) -> Result<()> {
    // thin wrapper over the cross-validation invariants, for manual runs
    let rt = Runtime::new(&artifacts_dir(args))?;
    let steps = rt.manifest.of_kind("env_step");
    if steps.is_empty() {
        bail!("no env_step artifacts in manifest");
    }
    println!("{} env_step artifacts available; run `cargo test --test \
              cross_validation -- --ignored` for the full \
              transition-level check",
             steps.len());
    for s in steps {
        let art = rt.load(&s.name)?;
        println!("  {} compiled ok ({} inputs, {} outputs)", s.name,
                 art.spec.inputs.len(), art.spec.outputs.len());
    }
    Ok(())
}

fn cmd_artifacts(args: &Args) -> Result<()> {
    let rt = Runtime::new(&artifacts_dir(args))?;
    for a in &rt.manifest.artifacts {
        println!("{:<50} kind={:<12} ins={} outs={}", a.name, a.kind(),
                 a.inputs.len(), a.outputs.len());
    }
    Ok(())
}
