//! ASCII renderer: one glyph per tile, ANSI color per color id.

use crate::env::grid::Grid;
use crate::env::observation::Obs;
use crate::env::types::*;

fn glyph(tile: i32) -> char {
    match tile {
        TILE_END_OF_MAP => ' ',
        TILE_UNSEEN => '?',
        TILE_EMPTY => ' ',
        TILE_FLOOR => '.',
        TILE_WALL => '#',
        TILE_BALL => 'o',
        TILE_SQUARE => '□',
        TILE_PYRAMID => '^',
        TILE_GOAL => 'G',
        TILE_KEY => 'k',
        TILE_DOOR_LOCKED => 'L',
        TILE_DOOR_CLOSED => 'D',
        TILE_DOOR_OPEN => 'd',
        TILE_HEX => 'h',
        TILE_STAR => '*',
        _ => '!',
    }
}

fn ansi(color: i32) -> &'static str {
    match color {
        COLOR_RED => "\x1b[31m",
        COLOR_GREEN => "\x1b[32m",
        COLOR_BLUE => "\x1b[34m",
        COLOR_PURPLE => "\x1b[35m",
        COLOR_YELLOW => "\x1b[33m",
        COLOR_GREY => "\x1b[90m",
        COLOR_ORANGE => "\x1b[38;5;208m",
        COLOR_WHITE => "\x1b[97m",
        COLOR_BROWN => "\x1b[38;5;94m",
        COLOR_PINK => "\x1b[38;5;205m",
        _ => "",
    }
}

const RESET: &str = "\x1b[0m";
const AGENT_GLYPHS: [char; 4] = ['▲', '▶', '▼', '◀'];

/// Render the full grid; the agent (if given) overlays its cell.
pub fn render_grid(grid: &Grid, agent: Option<((i32, i32), i32)>,
                   color: bool) -> String {
    let mut out = String::new();
    for r in 0..grid.h {
        for c in 0..grid.w {
            if let Some((pos, dir)) = agent {
                if pos == (r as i32, c as i32) {
                    out.push(AGENT_GLYPHS[(dir.rem_euclid(4)) as usize]);
                    continue;
                }
            }
            let cell = grid.get(r, c);
            if color {
                out.push_str(ansi(cell.color));
                out.push(glyph(cell.tile));
                out.push_str(RESET);
            } else {
                out.push(glyph(cell.tile));
            }
        }
        out.push('\n');
    }
    out
}

/// Render an egocentric observation (agent at bottom-center).
pub fn render_obs(obs: &Obs, color: bool) -> String {
    let mut out = String::new();
    for r in 0..obs.v {
        for c in 0..obs.v {
            if r == obs.v - 1 && c == obs.v / 2 {
                out.push('▲');
                continue;
            }
            let cell = obs.get(r, c);
            if color {
                out.push_str(ansi(cell.color));
                out.push(glyph(cell.tile));
                out.push_str(RESET);
            } else {
                out.push(glyph(cell.tile));
            }
        }
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::observation::observe;

    #[test]
    fn grid_render_dimensions() {
        let g = Grid::empty_room(5, 7);
        let s = render_grid(&g, None, false);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
        assert!(lines.iter().all(|l| l.chars().count() == 7));
        assert!(s.starts_with("#######"));
    }

    #[test]
    fn agent_overlay() {
        let g = Grid::empty_room(5, 5);
        let s = render_grid(&g, Some(((2, 2), 1)), false);
        assert!(s.contains('▶'));
    }

    #[test]
    fn obs_render_marks_agent() {
        let g = Grid::empty_room(9, 9);
        let obs = observe(&g, (4, 4), 0, 5, true);
        let s = render_obs(&obs, false);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 5);
        assert_eq!(lines[4].chars().nth(2), Some('▲'));
    }
}
