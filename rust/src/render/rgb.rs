//! Native RGB rasterizer for symbolic observations — the Rust analogue
//! of `python/compile/xmg/render.py` (the Fig. 13 / App. H image
//! wrapper), so `RgbImageObs` runs with zero artifacts.
//!
//! A symbolic `[V, V, 2]` observation renders to `[V*P, V*P, 3]` with
//! `P` pixels per tile: per cell, a binary-ish tile stencil in the
//! cell's palette color over a dark floor background — the same
//! stencils and palette as the JAX renderer, emitted as `0..=255`
//! integer channels instead of `f32` in `[0, 1]` (a constant scale,
//! not a semantic difference).
//!
//! The rasterizer is a *deterministic pure function* of the symbolic
//! cells (pinned by a property test in `tests/wrapper_parity.rs`):
//! same cells in, same pixels out, no state, no RNG.

use crate::env::types::{NUM_COLORS, NUM_TILES, TILE_BALL,
                        TILE_DOOR_CLOSED, TILE_DOOR_LOCKED,
                        TILE_DOOR_OPEN, TILE_GOAL, TILE_HEX, TILE_KEY,
                        TILE_PYRAMID, TILE_SQUARE, TILE_STAR,
                        TILE_UNSEEN, TILE_WALL};

/// Pixels per tile (matches the `render_rgb_*` artifacts' `P=8`).
pub const TILE_PATCH: usize = 8;

/// RGB per color id (rows index `COLOR_*`; same table as render.py).
const PALETTE: [[u8; 3]; NUM_COLORS] = [
    [0, 0, 0],        // END_OF_MAP
    [40, 40, 40],     // UNSEEN
    [0, 0, 0],        // EMPTY
    [255, 0, 0],      // RED
    [0, 255, 0],      // GREEN
    [0, 0, 255],      // BLUE
    [112, 39, 195],   // PURPLE
    [255, 255, 0],    // YELLOW
    [100, 100, 100],  // GREY
    [20, 20, 20],     // BLACK
    [255, 140, 0],    // ORANGE
    [255, 255, 255],  // WHITE
    [139, 69, 19],    // BROWN
    [255, 105, 180],  // PINK
];

/// Dark floor background (render.py's `floor_bg = 0.12`).
const FLOOR_BG: u8 = 31;

/// Stencil coverage of tile `tile` at centered coordinates
/// `(yc, xc) ∈ [-1, 1]` — the same shape formulas as
/// `render.py::_tile_patches`, returned as a 0..=1 weight.
fn stencil(tile: i32, yc: f32, xc: f32) -> f32 {
    match tile {
        TILE_UNSEEN | TILE_WALL => 1.0,
        TILE_BALL => {
            if yc * yc + xc * xc <= 0.64 { 1.0 } else { 0.0 }
        }
        TILE_SQUARE => {
            if yc.abs() <= 0.7 && xc.abs() <= 0.7 { 1.0 } else { 0.0 }
        }
        TILE_PYRAMID => {
            if yc >= -0.7 && xc.abs() <= 0.7 * (yc + 0.7) / 1.4 {
                1.0
            } else {
                0.0
            }
        }
        TILE_GOAL => 0.6,
        TILE_KEY => {
            let bow = yc * yc + xc * xc <= 0.3 && yc < 0.0;
            let shaft = xc.abs() < 0.18 && (-0.2..=0.8).contains(&yc);
            if bow || shaft { 1.0 } else { 0.0 }
        }
        TILE_DOOR_LOCKED | TILE_DOOR_CLOSED => {
            if yc.abs() > 0.75 || xc.abs() > 0.75 { 1.0 } else { 0.0 }
        }
        TILE_DOOR_OPEN => {
            if xc.abs() > 0.75 { 1.0 } else { 0.0 }
        }
        TILE_HEX => {
            if yc.abs() + xc.abs() * 0.6 <= 0.8 { 1.0 } else { 0.0 }
        }
        TILE_STAR => {
            if (yc.abs() <= 0.25 || xc.abs() <= 0.25)
                && yc.abs() <= 0.8
                && xc.abs() <= 0.8
            {
                1.0
            } else {
                0.0
            }
        }
        // END_OF_MAP, EMPTY, FLOOR: background only
        _ => 0.0,
    }
}

/// Rasterize a flat symbolic observation (`[V, V, 2]` as `i32`
/// tile/color pairs, `cells.len() == v*v*2`) into `out`
/// (`[V*P, V*P, 3]` as `i32` channels in `0..=255`,
/// `out.len() == v*p*v*p*3`). Pixel `(vr*P+py, vc*P+px)` belongs to
/// view cell `(vr, vc)` — the render.py memory layout.
pub fn rasterize_symbolic_into(cells: &[i32], v: usize, p: usize,
                               out: &mut [i32]) {
    assert_eq!(cells.len(), v * v * 2, "symbolic obs buffer size");
    assert_eq!(out.len(), v * p * v * p * 3, "rgb buffer size");
    let half = p as f32 / 2.0;
    for vr in 0..v {
        for vc in 0..v {
            let tile = cells[(vr * v + vc) * 2]
                .clamp(0, NUM_TILES as i32 - 1);
            let color = cells[(vr * v + vc) * 2 + 1]
                .clamp(0, NUM_COLORS as i32 - 1);
            let rgb = PALETTE[color as usize];
            for py in 0..p {
                let yc = (py as f32 - (p as f32 - 1.0) / 2.0) / half;
                for px in 0..p {
                    let xc = (px as f32 - (p as f32 - 1.0) / 2.0) / half;
                    let fg = stencil(tile, yc, xc);
                    let row = vr * p + py;
                    let col = vc * p + px;
                    let o = (row * v * p + col) * 3;
                    for ch in 0..3 {
                        let val = fg * rgb[ch] as f32
                            + (1.0 - fg) * FLOOR_BG as f32;
                        out[o + ch] = val.round() as i32;
                    }
                }
            }
        }
    }
}

/// Allocating convenience form of [`rasterize_symbolic_into`].
pub fn rasterize_symbolic(cells: &[i32], v: usize, p: usize) -> Vec<i32> {
    let mut out = vec![0i32; v * p * v * p * 3];
    rasterize_symbolic_into(cells, v, p, &mut out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::types::{COLOR_GREY, COLOR_RED, TILE_FLOOR};

    fn obs_with_center(tile: i32, color: i32, v: usize) -> Vec<i32> {
        let mut cells = Vec::with_capacity(v * v * 2);
        for _ in 0..v * v {
            cells.push(TILE_FLOOR);
            cells.push(COLOR_GREY);
        }
        let c = (v / 2) * v + v / 2;
        cells[c * 2] = tile;
        cells[c * 2 + 1] = color;
        cells
    }

    #[test]
    fn floor_renders_background_only() {
        let v = 3;
        let img =
            rasterize_symbolic(&obs_with_center(TILE_FLOOR, COLOR_GREY, v),
                               v, TILE_PATCH);
        assert!(img.iter().all(|&x| x == FLOOR_BG as i32));
    }

    #[test]
    fn ball_paints_its_tile_block_red() {
        let v = 3;
        let p = TILE_PATCH;
        let img = rasterize_symbolic(&obs_with_center(TILE_BALL,
                                                      COLOR_RED, v),
                                     v, p);
        // center pixel of the center tile is inside the circle: pure red
        let row = v / 2 * p + p / 2;
        let col = v / 2 * p + p / 2;
        let o = (row * v * p + col) * 3;
        assert_eq!(&img[o..o + 3], &[255, 0, 0]);
        // a corner tile stays background
        assert_eq!(img[0], FLOOR_BG as i32);
    }

    #[test]
    fn wall_fills_its_block() {
        let v = 3;
        let p = TILE_PATCH;
        let img = rasterize_symbolic(&obs_with_center(TILE_WALL,
                                                      COLOR_GREY, v),
                                     v, p);
        let base = v / 2 * p;
        for py in 0..p {
            for px in 0..p {
                let o = ((base + py) * v * p + base + px) * 3;
                assert_eq!(img[o], 100, "grey wall pixel");
            }
        }
    }

    #[test]
    fn deterministic_and_value_range() {
        let v = 5;
        let cells = obs_with_center(TILE_KEY, COLOR_RED, v);
        let a = rasterize_symbolic(&cells, v, TILE_PATCH);
        let b = rasterize_symbolic(&cells, v, TILE_PATCH);
        assert_eq!(a, b);
        assert!(a.iter().all(|&x| (0..=255).contains(&x)));
    }

    #[test]
    fn out_of_range_ids_clamp() {
        let v = 3;
        let mut cells = obs_with_center(TILE_BALL, COLOR_RED, v);
        cells[0] = 999; // bogus tile id
        cells[1] = -7; // bogus color id
        let img = rasterize_symbolic(&cells, v, TILE_PATCH);
        assert!(img.iter().all(|&x| (0..=255).contains(&x)));
    }
}
