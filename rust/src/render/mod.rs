//! ASCII rendering for interactive inspection (`xmgrid play`,
//! examples/quickstart). The RGB rendering path lives in the
//! `render_rgb_*` AOT artifacts (App. H reproduction).

pub mod ascii;

pub use ascii::{render_grid, render_obs};
