//! Rendering: ASCII for interactive inspection (`xmgrid play`,
//! examples/quickstart) and the native RGB rasterizer behind
//! `env::api::RgbImageObs` (App. H reproduction; the `render_rgb_*`
//! AOT artifacts are the device-side twin).

pub mod ascii;
pub mod rgb;

pub use ascii::{render_grid, render_obs};
pub use rgb::{rasterize_symbolic, rasterize_symbolic_into, TILE_PATCH};
