//! xmgrid — reproduction of *XLand-MiniGrid: Scalable Meta-Reinforcement
//! Learning Environments in JAX* (NeurIPS 2024) as a three-layer
//! Rust + JAX + Pallas system.
//!
//! - [`env`] — the full grid-world engine in pure Rust: tiles, rules, goals,
//!   observations, layouts, and the 38-environment registry. Serves as the
//!   cross-validation oracle for the AOT-lowered JAX environment and as the
//!   CPU-loop baseline (EnvPool-style) in the throughput benches.
//!   [`env::api`] is the unified TimeStep `Environment` /
//!   `BatchEnvironment` protocol every stepping surface implements,
//!   with spec-driven observation wrappers (`AutoReset`,
//!   `DirectionObs`, `RulesAndGoalsObs`, `RgbImageObs`).
//! - [`benchgen`] — the procedural benchmark generator (paper §3, Table 4):
//!   goal-rooted production-rule trees, branch pruning, distractors, and the
//!   compressed benchmark store with load/sample/split APIs.
//! - [`runtime`] — PJRT client wrapper: loads `artifacts/*.hlo.txt`
//!   (manifest-driven), compiles once per artifact name, and executes
//!   fused computations so the hot loop crosses the host boundary once
//!   per chunk, not once per step.
//! - [`coordinator`] — the L3 contribution: vectorized env pool, the
//!   persistent double-buffered shard engine standing in for `jax.pmap`
//!   multi-device scaling, the RL² PPO trainer (Anakin-style, single- and
//!   multi-shard), and the evaluation harness (25-trial /
//!   20th-percentile protocol of §4.2).
//! - [`nn`] — the native training stack: dense f32 GRU actor-critic
//!   mirroring the Python reference model, GAE + clipped-PPO loss with
//!   analytic BPTT backward, and Adam, all under a bitwise numeric
//!   contract pinned by committed Python-oracle fixtures. Lets
//!   `xmgrid train --backend native` run RL² end to end with zero
//!   compiled artifacts.
//! - [`server`] — L4 service tier: rollout-as-a-service. A
//!   multi-tenant environment server (`xmgrid serve`) owning
//!   per-session `NativePool` replicas behind a framed, checksummed
//!   wire protocol, with per-session fault isolation, per-request
//!   deadlines, bounded queues with explicit backpressure, and
//!   graceful drain — plus a `BatchEnvironment` client so
//!   `--backend server:ADDR` is bitwise-identical to in-process.
//! - [`render`] — ASCII renderer for interactive inspection.
//! - [`lint`] — the `xmgrid lint` static-analysis pass: token-level
//!   rules that machine-check the determinism and panic-safety
//!   conventions (single seeded RNG, no hasher-order iteration, no
//!   wall-clock in kernels, no `unwrap` in supervised worker paths,
//!   fixed-order float reductions) the engine layers rely on.
//! - [`util`] — offline-friendly substitutes for crates unavailable in this
//!   environment: PRNG, arg parsing, stats, bench harness, property tests.

pub mod benchgen;
pub mod coordinator;
pub mod env;
pub mod lint;
pub mod nn;
pub mod render;
pub mod runtime;
pub mod server;
pub mod util;
