//! Environment registry: all 38 environments of paper Table 7 —
//! 15 XLand-MiniGrid layout/size variants plus 23 ported MiniGrid tasks.
//!
//! Each entry is a builder `(rng) -> EnvBlueprint`: the base grid (walls,
//! doors, any *fixed* task objects), the ruleset (goal + rules + objects
//! randomly placed at each trial start), and the step limit. XLand entries
//! take their ruleset from a benchmark at episode time; MiniGrid ports bake
//! their task into the blueprint.
//!
//! Deviation noted in docs/ARCHITECTURE.md ("Deviations"): agent start position is always randomized
//! (the paper's `Empty` fixes it; `EmptyRandom` matches exactly).

use crate::util::rng::Rng;

use super::goals::Goal;
use super::grid::Grid;
use super::layouts::xland_layout;
use super::state::{default_max_steps, Ruleset};
use super::types::*;

/// Everything needed to start episodes of a registered environment.
#[derive(Clone, Debug)]
pub struct EnvBlueprint {
    pub base_grid: Grid,
    /// `None` for XLand envs (ruleset supplied by a benchmark).
    pub ruleset: Option<Ruleset>,
    pub max_steps: i32,
}

#[derive(Clone, Copy, Debug)]
pub struct EnvSpec {
    pub name: &'static str,
    pub h: usize,
    pub w: usize,
    pub rooms: usize, // 0 = MiniGrid port with custom builder
}

pub const XLAND_ENVS: [EnvSpec; 15] = [
    EnvSpec { name: "XLand-MiniGrid-R1-9x9", h: 9, w: 9, rooms: 1 },
    EnvSpec { name: "XLand-MiniGrid-R1-13x13", h: 13, w: 13, rooms: 1 },
    EnvSpec { name: "XLand-MiniGrid-R1-17x17", h: 17, w: 17, rooms: 1 },
    EnvSpec { name: "XLand-MiniGrid-R2-9x9", h: 9, w: 9, rooms: 2 },
    EnvSpec { name: "XLand-MiniGrid-R2-13x13", h: 13, w: 13, rooms: 2 },
    EnvSpec { name: "XLand-MiniGrid-R2-17x17", h: 17, w: 17, rooms: 2 },
    EnvSpec { name: "XLand-MiniGrid-R4-9x9", h: 9, w: 9, rooms: 4 },
    EnvSpec { name: "XLand-MiniGrid-R4-13x13", h: 13, w: 13, rooms: 4 },
    EnvSpec { name: "XLand-MiniGrid-R4-17x17", h: 17, w: 17, rooms: 4 },
    EnvSpec { name: "XLand-MiniGrid-R6-13x13", h: 13, w: 13, rooms: 6 },
    EnvSpec { name: "XLand-MiniGrid-R6-17x17", h: 17, w: 17, rooms: 6 },
    EnvSpec { name: "XLand-MiniGrid-R6-19x19", h: 19, w: 19, rooms: 6 },
    EnvSpec { name: "XLand-MiniGrid-R9-16x16", h: 16, w: 16, rooms: 9 },
    EnvSpec { name: "XLand-MiniGrid-R9-19x19", h: 19, w: 19, rooms: 9 },
    EnvSpec { name: "XLand-MiniGrid-R9-25x25", h: 25, w: 25, rooms: 9 },
];

pub const MINIGRID_ENVS: [&str; 23] = [
    "MiniGrid-BlockedUnlockPickUp",
    "MiniGrid-DoorKey-5x5",
    "MiniGrid-DoorKey-6x6",
    "MiniGrid-DoorKey-8x8",
    "MiniGrid-DoorKey-16x16",
    "MiniGrid-Empty-5x5",
    "MiniGrid-Empty-6x6",
    "MiniGrid-Empty-8x8",
    "MiniGrid-Empty-16x16",
    "MiniGrid-EmptyRandom-5x5",
    "MiniGrid-EmptyRandom-6x6",
    "MiniGrid-EmptyRandom-8x8",
    "MiniGrid-EmptyRandom-16x16",
    "MiniGrid-FourRooms",
    "MiniGrid-LockedRoom",
    "MiniGrid-MemoryS8",
    "MiniGrid-MemoryS16",
    "MiniGrid-MemoryS32",
    "MiniGrid-MemoryS64",
    "MiniGrid-MemoryS128",
    "MiniGrid-Playground",
    "MiniGrid-Unlock",
    "MiniGrid-UnlockPickUp",
];

/// All registered environment names (38 total, Table 7).
pub fn registered_environments() -> Vec<&'static str> {
    XLAND_ENVS
        .iter()
        .map(|e| e.name)
        .chain(MINIGRID_ENVS.iter().copied())
        .collect()
}

fn goal_green() -> Cell {
    Cell::new(TILE_GOAL, COLOR_GREEN)
}

fn rand_obj_color(rng: &mut Rng) -> i32 {
    GEN_COLORS[rng.below(GEN_COLORS.len())]
}

/// DoorKey-NxN: vertical wall with a locked door; key on the agent side,
/// goal tile in the far corner of the other side.
fn door_key(n: usize, rng: &mut Rng) -> EnvBlueprint {
    let mut g = Grid::empty_room(n, n);
    let wall_c = 1 + rng.below(n.saturating_sub(4).max(1)) + 1; // in [2, n-3]
    for r in 1..n - 1 {
        g.set(r, wall_c, WALL_CELL);
    }
    let color = rand_obj_color(rng);
    let door_r = 1 + rng.below(n - 2);
    g.set(door_r, wall_c, Cell::new(TILE_DOOR_LOCKED, color));
    // key somewhere left of the wall
    let key_r = 1 + rng.below(n - 2);
    let key_c = 1 + rng.below(wall_c - 1);
    g.set(key_r, key_c, Cell::new(TILE_KEY, color));
    g.set(n - 2, n - 2, goal_green());
    EnvBlueprint {
        base_grid: g,
        ruleset: Some(Ruleset {
            goal: Goal::agent_on_tile(goal_green()),
            rules: vec![],
            init_tiles: vec![],
        }),
        max_steps: 10 * (n * n) as i32,
    }
}

/// Empty rooms: goal tile at the bottom-right corner.
fn empty(n: usize) -> EnvBlueprint {
    let mut g = Grid::empty_room(n, n);
    g.set(n - 2, n - 2, goal_green());
    EnvBlueprint {
        base_grid: g,
        ruleset: Some(Ruleset {
            goal: Goal::agent_on_tile(goal_green()),
            rules: vec![],
            init_tiles: vec![],
        }),
        max_steps: 4 * (n * n) as i32,
    }
}

/// FourRooms: 4-room layout, goal tile placed at a random floor cell.
fn four_rooms(rng: &mut Rng) -> EnvBlueprint {
    let mut g = xland_layout(4, 19, 19, rng);
    let free = g.free_cells();
    let p = free[rng.below(free.len())];
    g.set(p / g.w, p % g.w, goal_green());
    EnvBlueprint {
        base_grid: g,
        ruleset: Some(Ruleset {
            goal: Goal::agent_on_tile(goal_green()),
            rules: vec![],
            init_tiles: vec![],
        }),
        max_steps: default_max_steps(19, 19),
    }
}

/// Unlock: locked door + matching key; goal = stand next to the open door.
fn unlock(rng: &mut Rng) -> EnvBlueprint {
    let n = 11;
    let mut g = Grid::empty_room(n, n);
    let wall_c = n / 2;
    for r in 1..n - 1 {
        g.set(r, wall_c, WALL_CELL);
    }
    let color = rand_obj_color(rng);
    let door_r = 1 + rng.below(n - 2);
    g.set(door_r, wall_c, Cell::new(TILE_DOOR_LOCKED, color));
    let key_r = 1 + rng.below(n - 2);
    let key_c = 1 + rng.below(wall_c - 1);
    g.set(key_r, key_c, Cell::new(TILE_KEY, color));
    EnvBlueprint {
        base_grid: g,
        ruleset: Some(Ruleset {
            goal: Goal::agent_near(Cell::new(TILE_DOOR_OPEN, color)),
            rules: vec![],
            init_tiles: vec![],
        }),
        max_steps: 8 * (n * n) as i32,
    }
}

/// UnlockPickUp: box (square) behind a locked door; goal = hold the box.
fn unlock_pickup(rng: &mut Rng, blocked: bool) -> EnvBlueprint {
    let n = 11;
    let mut bp = unlock(rng);
    let g = &mut bp.base_grid;
    // find the door to get its column & color
    let (door_r, door_c, door) = g
        .iter_cells()
        .find(|(_, _, c)| c.tile == TILE_DOOR_LOCKED)
        .map(|(r, c, cell)| (r, c, cell))
        .unwrap();
    if blocked {
        // a ball blocks the door from the key side
        let ball_color = rand_obj_color(rng);
        g.set(door_r, door_c - 1, Cell::new(TILE_BALL, ball_color));
    }
    let box_color = rand_obj_color(rng);
    let box_cell = Cell::new(TILE_SQUARE, box_color);
    // box on the far side of the wall
    let r = 1 + rng.below(n - 2);
    let c = door_c + 1 + rng.below(n - 2 - door_c);
    g.set(r, c, box_cell);
    let _ = door;
    bp.ruleset = Some(Ruleset {
        goal: Goal::agent_hold(box_cell),
        rules: vec![],
        init_tiles: vec![],
    });
    bp
}

/// LockedRoom: three-column layout; the goal room is locked, its key lies
/// in another room.
fn locked_room(rng: &mut Rng) -> EnvBlueprint {
    let n = 19;
    let mut g = xland_layout(9, n, n, rng);
    // lock one door, place its key on a random floor cell
    let doors: Vec<(usize, usize, Cell)> = g
        .iter_cells()
        .filter(|(_, _, c)| c.tile == TILE_DOOR_CLOSED)
        .collect();
    let (dr, dc, dcell) = doors[rng.below(doors.len())];
    g.set(dr, dc, Cell::new(TILE_DOOR_LOCKED, dcell.color));
    let free = g.free_cells();
    let kp = free[rng.below(free.len())];
    g.set(kp / g.w, kp % g.w, Cell::new(TILE_KEY, dcell.color));
    let gp = free[rng.below(free.len())];
    if gp != kp {
        g.set(gp / g.w, gp % g.w, goal_green());
    } else {
        g.set(1, 1, goal_green());
    }
    EnvBlueprint {
        base_grid: g,
        ruleset: Some(Ruleset {
            goal: Goal::agent_on_tile(goal_green()),
            rules: vec![],
            init_tiles: vec![],
        }),
        max_steps: default_max_steps(n, n),
    }
}

/// MemoryS{len}: hint object in the start alcove; two candidate objects at
/// the far end of a corridor; goal = stand next to the one matching the
/// hint.
fn memory(len: usize, rng: &mut Rng) -> EnvBlueprint {
    let h = 7;
    let w = len.max(8);
    let mut g = Grid::filled(h, w, WALL_CELL);
    let mid = h / 2;
    for c in 1..w - 1 {
        g.set(mid, c, FLOOR_CELL); // corridor
    }
    // start alcove (3 rows tall) on the left
    for r in mid - 1..=mid + 1 {
        for c in 1..4 {
            g.set(r, c, FLOOR_CELL);
        }
    }
    // fork at the right end
    g.set(mid - 1, w - 2, FLOOR_CELL);
    g.set(mid + 1, w - 2, FLOOR_CELL);

    let ball = Cell::new(TILE_BALL, COLOR_GREEN);
    let key = Cell::new(TILE_KEY, COLOR_GREEN);
    let (hint, other) = if rng.chance(0.5) { (ball, key) } else { (key, ball) };
    g.set(mid - 1, 1, hint); // visible from the start
    let hint_on_top = rng.chance(0.5);
    let (top, bottom) = if hint_on_top { (hint, other) } else { (other, hint) };
    g.set(mid - 2, w - 2, top);
    g.set(mid + 2, w - 2, bottom);
    EnvBlueprint {
        base_grid: g,
        ruleset: Some(Ruleset {
            goal: Goal::agent_near_dir(
                if hint_on_top { DIR_UP } else { DIR_DOWN }, hint),
            rules: vec![],
            init_tiles: vec![],
        }),
        max_steps: (5 * w) as i32,
    }
}

/// Playground: 9 rooms full of assorted objects and no goal (exploration).
fn playground(rng: &mut Rng) -> EnvBlueprint {
    let n = 19;
    let g = xland_layout(9, n, n, rng);
    let mut init = Vec::new();
    for _ in 0..8 {
        let tile = GEN_TILES[rng.below(GEN_TILES.len() - 1)]; // no goal tiles
        init.push(Cell::new(tile, rand_obj_color(rng)));
    }
    EnvBlueprint {
        base_grid: g,
        ruleset: Some(Ruleset {
            goal: Goal::EMPTY,
            rules: vec![],
            init_tiles: init,
        }),
        max_steps: default_max_steps(n, n),
    }
}

/// Build the blueprint for any registered environment name.
pub fn make(name: &str, rng: &mut Rng) -> EnvBlueprint {
    if let Some(spec) = XLAND_ENVS.iter().find(|e| e.name == name) {
        let base = xland_layout(spec.rooms, spec.h, spec.w, rng);
        return EnvBlueprint {
            base_grid: base,
            ruleset: None,
            max_steps: default_max_steps(spec.h, spec.w),
        };
    }
    match name {
        "MiniGrid-BlockedUnlockPickUp" => unlock_pickup(rng, true),
        "MiniGrid-DoorKey-5x5" => door_key(5, rng),
        "MiniGrid-DoorKey-6x6" => door_key(6, rng),
        "MiniGrid-DoorKey-8x8" => door_key(8, rng),
        "MiniGrid-DoorKey-16x16" => door_key(16, rng),
        "MiniGrid-Empty-5x5" => empty(5),
        "MiniGrid-Empty-6x6" => empty(6),
        "MiniGrid-Empty-8x8" => empty(8),
        "MiniGrid-Empty-16x16" => empty(16),
        "MiniGrid-EmptyRandom-5x5" => empty(5),
        "MiniGrid-EmptyRandom-6x6" => empty(6),
        "MiniGrid-EmptyRandom-8x8" => empty(8),
        "MiniGrid-EmptyRandom-16x16" => empty(16),
        "MiniGrid-FourRooms" => four_rooms(rng),
        "MiniGrid-LockedRoom" => locked_room(rng),
        "MiniGrid-MemoryS8" => memory(8, rng),
        "MiniGrid-MemoryS16" => memory(16, rng),
        "MiniGrid-MemoryS32" => memory(32, rng),
        "MiniGrid-MemoryS64" => memory(64, rng),
        "MiniGrid-MemoryS128" => memory(128, rng),
        "MiniGrid-Playground" => playground(rng),
        "MiniGrid-Unlock" => unlock(rng),
        "MiniGrid-UnlockPickUp" => unlock_pickup(rng, false),
        _ => panic!("unknown environment: {name}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::state::{reset, step, EnvOptions};

    #[test]
    fn registry_all_38() {
        let names = registered_environments();
        assert_eq!(names.len(), 38, "Table 7: 38 registered environments");
        let mut unique: Vec<_> = names.clone();
        unique.sort();
        unique.dedup();
        assert_eq!(unique.len(), 38, "names are unique");
    }

    #[test]
    fn every_env_builds_and_steps() {
        let mut rng = Rng::new(7);
        for name in registered_environments() {
            let bp = make(name, &mut rng);
            let ruleset = bp.ruleset.unwrap_or_else(|| Ruleset {
                goal: Goal::EMPTY,
                rules: vec![],
                init_tiles: vec![],
            });
            let (mut s, obs) = reset(bp.base_grid, ruleset, bp.max_steps,
                                     Rng::new(3), EnvOptions::default());
            assert_eq!(obs.cells.len(), 25, "{name}");
            for a in 0..NUM_ACTIONS as i32 {
                let out = step(&mut s, a, EnvOptions::default());
                assert!(out.reward >= 0.0, "{name}");
            }
        }
    }

    #[test]
    fn door_key_is_solvable_by_scripted_play() {
        // structural check: key color matches the locked door's color
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let bp = door_key(8, &mut rng);
            let door = bp
                .base_grid
                .iter_cells()
                .find(|(_, _, c)| c.tile == TILE_DOOR_LOCKED)
                .unwrap();
            let key = bp
                .base_grid
                .iter_cells()
                .find(|(_, _, c)| c.tile == TILE_KEY)
                .unwrap();
            assert_eq!(door.2.color, key.2.color, "seed {seed}");
            assert_eq!(bp.base_grid.count_tile(TILE_GOAL), 1);
        }
    }

    #[test]
    fn memory_goal_points_at_hint_side() {
        for seed in 0..20 {
            let mut rng = Rng::new(seed);
            let bp = memory(16, &mut rng);
            let g = &bp.base_grid;
            let hint = g.get(2, 1); // mid-1 = 2 with h=7
            let goal = bp.ruleset.as_ref().unwrap().goal;
            let target = Cell::new(goal.0[1], goal.0[2]);
            assert_eq!(hint, target, "goal object equals the hint");
            // hint object present at exactly one fork arm
            let top = g.get(1, g.w - 2);
            let bottom = g.get(5, g.w - 2);
            assert!(top == hint || bottom == hint);
            assert_ne!(top, bottom);
        }
    }

    #[test]
    fn xland_blueprints_have_no_ruleset() {
        let mut rng = Rng::new(1);
        let bp = make("XLand-MiniGrid-R4-13x13", &mut rng);
        assert!(bp.ruleset.is_none());
        assert_eq!(bp.max_steps, 507);
    }

    #[test]
    fn blocked_unlock_pickup_has_blocking_ball() {
        let mut rng = Rng::new(5);
        let bp = make("MiniGrid-BlockedUnlockPickUp", &mut rng);
        assert_eq!(bp.base_grid.count_tile(TILE_BALL), 1);
        assert_eq!(bp.base_grid.count_tile(TILE_DOOR_LOCKED), 1);
        assert_eq!(bp.base_grid.count_tile(TILE_SQUARE), 1);
    }
}
