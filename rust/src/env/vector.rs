//! Vectorized native stepping engine: `VecEnv` holds B environments in
//! flat structure-of-arrays buffers and steps them with allocation-free
//! batch kernels — the NAVIX/Jumanji-style design that makes batched
//! stepping fast on the host, with no AOT artifacts involved.
//!
//! Layout mirrors `python/compile/aot.py`'s STATE_FIELDS: one contiguous
//! grid tensor `[B, H, W]` of [`PackedCell`]s (tile and color packed
//! into one `u16` — half the memory traffic of the `(i32, i32)` pair at
//! large B; unpacked to i32 only at the observation/PJRT boundary), flat
//! arrays for agent pos/dir/pocket/step_count/max_steps, and rulesets
//! encoded into fixed-width tables (`rules [B, MR, 7]`, `goal [B, 5]`,
//! `init [B, MI, 2]`). Per-env reset-derived caches (the base grid's
//! free-cell list, the live rule count) keep the per-step and per-trial
//! kernels free of rescans — see docs/ARCHITECTURE.md "Hot-path
//! anatomy".
//!
//! Semantics are *bitwise identical* to the scalar oracle in
//! [`super::state`]: both run the same generic kernels (`apply_action`,
//! `check_rules`, `check_goal`, the observe kernels over [`CellGrid`])
//! and the same RNG call sequence (`Rng::partial_shuffle` mirrors
//! `Rng::sample_distinct`). `tests/vec_env_equivalence.rs` pins this
//! contract for every registry env family across auto-reset boundaries.

use std::sync::Arc;

use anyhow::Result;

use crate::util::rng::Rng;

use super::api::{ActionSpec, BatchEnvironment, EnvParams, ObsSpec};
use super::goals::{check_goal, Goal};
use super::grid::{CellGrid, Grid};
use super::observation::{observe_flat_into, ObsScratch};
use super::rules::{check_rules, Rule};
use super::state::{apply_action, is_acting_action, Ruleset, TaskSource};
use super::types::*;

/// Borrowed view of one environment's `[H, W]` slice of the batched
/// packed grid tensor — the `CellGrid` the shared kernels run on
/// (packing/unpacking at the accessor boundary, so the kernels stay
/// generic over the storage format).
pub struct GridView<'a> {
    h: usize,
    w: usize,
    cells: &'a mut [PackedCell],
}

impl<'a> GridView<'a> {
    pub fn new(h: usize, w: usize, cells: &'a mut [PackedCell])
               -> GridView<'a> {
        debug_assert_eq!(cells.len(), h * w);
        GridView { h, w, cells }
    }
}

impl CellGrid for GridView<'_> {
    #[inline]
    fn h(&self) -> usize {
        self.h
    }

    #[inline]
    fn w(&self) -> usize {
        self.w
    }

    #[inline]
    fn get_i(&self, r: i32, c: i32) -> Cell {
        if self.in_bounds(r, c) {
            self.cells[r as usize * self.w + c as usize].unpack()
        } else {
            END_OF_MAP_CELL
        }
    }

    #[inline]
    fn set_i(&mut self, r: i32, c: i32, cell: Cell) {
        if self.in_bounds(r, c) {
            self.cells[r as usize * self.w + c as usize] =
                PackedCell::pack(cell);
        }
    }
}

/// Owned copy of every per-env SoA buffer plus the per-env RNG states —
/// the full observable state of a [`VecEnv`] (grids unpacked back to
/// `Cell` at this boundary; the reset-derived caches — free-cell lists,
/// live rule counts — are pure functions of the captured buffers and
/// carry no extra information). The parallel-engine tests compare these
/// across thread counts: equality here means the engines are
/// bitwise-identical, including state no output has surfaced yet.
/// Concatenating per-chunk snapshots in chunk order reconstructs the
/// full-batch snapshot ([`VecEnvSnapshot::append`]).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct VecEnvSnapshot {
    pub base: Vec<Cell>,
    pub grid: Vec<Cell>,
    pub agent_pos: Vec<i32>,
    pub agent_dir: Vec<i32>,
    pub pocket: Vec<Cell>,
    pub rules: Vec<Rule>,
    pub goals: Vec<Goal>,
    pub init: Vec<Cell>,
    pub init_len: Vec<u32>,
    pub step_count: Vec<i32>,
    pub max_steps: Vec<i32>,
    pub rng_states: Vec<[u64; 4]>,
}

impl VecEnvSnapshot {
    /// An empty snapshot to fold chunk snapshots into.
    pub fn empty() -> VecEnvSnapshot {
        VecEnvSnapshot::default()
    }

    /// Append another snapshot's envs after this one's (chunk order).
    pub fn append(&mut self, other: VecEnvSnapshot) {
        self.base.extend(other.base);
        self.grid.extend(other.grid);
        self.agent_pos.extend(other.agent_pos);
        self.agent_dir.extend(other.agent_dir);
        self.pocket.extend(other.pocket);
        self.rules.extend(other.rules);
        self.goals.extend(other.goals);
        self.init.extend(other.init);
        self.init_len.extend(other.init_len);
        self.step_count.extend(other.step_count);
        self.max_steps.extend(other.max_steps);
        self.rng_states.extend(other.rng_states);
    }
}

/// Shape of one `VecEnv` family — an alias of the shared
/// [`EnvParams`] (grid dims, fixed-width table capacities, view
/// options), so the SoA engine and every layer above derive shapes
/// from one struct.
pub type VecEnvConfig = EnvParams;

/// B environments in SoA buffers with allocation-free `reset_all` /
/// `step_all` kernels (in-place trial/episode auto-reset, observations
/// written into a caller-provided `[B, V, V, 2]` i32 buffer).
pub struct VecEnv {
    cfg: VecEnvConfig,
    b: usize,
    /// episode-start grids `[B, H, W]`, packed
    base: Vec<PackedCell>,
    /// live grids `[B, H, W]`, packed
    grid: Vec<PackedCell>,
    /// `[B, 2]` (row, col)
    agent_pos: Vec<i32>,
    /// `[B]`
    agent_dir: Vec<i32>,
    /// `[B, 2]` (tile, color)
    pocket: Vec<Cell>,
    /// `[B, MR, 7]` fixed-width rule table
    rules: Vec<Rule>,
    /// number of live rows in each env's rule table — `check_rules`
    /// runs over exactly this prefix, skipping the inert
    /// `Rule::EMPTY` padding (identical semantics: padding never fires)
    rules_len: Vec<u32>,
    /// `[B, 5]` encoded goals
    goals: Vec<Goal>,
    /// `[B, MI, 2]` init-tile table
    init: Vec<Cell>,
    /// number of live rows in each env's init table
    init_len: Vec<u32>,
    /// `[B]`
    step_count: Vec<i32>,
    /// `[B]`
    max_steps: Vec<i32>,
    /// `[B, H*W]` cached row-major free-cell lists of the base grids
    /// (filled at `reset_env`; `free_len[i]` rows are live). Every
    /// trial placement memcpys this prefix instead of rescanning the
    /// H·W grid — base grids only change when `reset_env` installs one.
    free_base: Vec<u32>,
    /// `[B]` live rows in `free_base`
    free_len: Vec<u32>,
    /// one xoshiro256++ stream per env (the JAX per-env key analogue)
    rngs: Vec<Rng>,
    /// benchmark task distribution for episode auto-reset resampling;
    /// `None` replays each env's current ruleset forever (fixed-task
    /// harnesses like the registry unit tests want exactly that)
    tasks: Option<Arc<dyn TaskSource>>,
    /// whether `reset_all` has installed episode inputs (base grids,
    /// tasks, step limits) — the trait-level `reset` restarts episodes
    /// and needs them present
    seeded: bool,
    // --- reusable scratch: steady-state kernels never allocate ---------
    free_scratch: Vec<u32>,
    obs_scratch: ObsScratch,
}

impl VecEnv {
    pub fn new(cfg: VecEnvConfig, b: usize) -> VecEnv {
        assert!(b > 0, "VecEnv needs at least one env");
        assert!(cfg.h >= 3 && cfg.w >= 3, "grid too small");
        let ghw = cfg.h * cfg.w;
        let zero = Cell::new(0, 0);
        VecEnv {
            cfg,
            b,
            base: vec![PackedCell::ZERO; b * ghw],
            grid: vec![PackedCell::ZERO; b * ghw],
            agent_pos: vec![0; b * 2],
            agent_dir: vec![0; b],
            pocket: vec![POCKET_EMPTY; b],
            rules: vec![Rule::EMPTY; b * cfg.max_rules],
            rules_len: vec![0; b],
            goals: vec![Goal::EMPTY; b],
            init: vec![zero; b * cfg.max_init],
            init_len: vec![0; b],
            step_count: vec![0; b],
            max_steps: vec![0; b],
            free_base: vec![0; b * ghw],
            free_len: vec![0; b],
            rngs: vec![Rng::new(0); b],
            tasks: None,
            seeded: false,
            free_scratch: Vec::with_capacity(ghw),
            obs_scratch: ObsScratch::new(),
        }
    }

    pub fn batch(&self) -> usize {
        self.b
    }

    pub fn config(&self) -> &VecEnvConfig {
        &self.cfg
    }

    /// Length of the caller-provided observation buffer:
    /// `B * V * V * 2` i32s in the PJRT boundary layout (derived from
    /// the family's [`ObsSpec`] via [`EnvParams::obs_len`]).
    pub fn obs_len(&self) -> usize {
        self.b * self.cfg.obs_len()
    }

    /// Install the benchmark task distribution: at every *episode*
    /// auto-reset, the done env draws a fresh task from `tasks` with its
    /// own RNG stream and re-encodes it into the SoA tables (trial
    /// resets keep the task — the §2.1 protocol [`super::state`]'s
    /// `step_with_tasks` defines). Every task must fit the fixed-width
    /// tables this `VecEnv` was built with
    /// ([`VecEnvConfig::validate_task_source`] runs here).
    pub fn set_task_source(&mut self, tasks: Arc<dyn TaskSource>) {
        self.cfg.validate_task_source(tasks.as_ref());
        self.tasks = Some(tasks);
    }

    /// [`VecEnv::set_task_source`] minus the O(num_tasks) capacity
    /// validation — for callers (the chunked parallel engine) that
    /// already validated the source against this exact config once,
    /// instead of once per chunk worker.
    pub fn set_task_source_prevalidated(&mut self,
                                        tasks: Arc<dyn TaskSource>) {
        debug_assert!(tasks.num_tasks() > 0);
        self.tasks = Some(tasks);
    }

    /// Deep copy of every per-env SoA buffer plus the RNG states —
    /// scratch excluded (it carries no state across envs or steps).
    /// Two engines that stepped the same envs are equal here iff they
    /// are bitwise-identical forever after.
    pub fn snapshot(&self) -> VecEnvSnapshot {
        VecEnvSnapshot {
            base: self.base.iter().map(|c| c.unpack()).collect(),
            grid: self.grid.iter().map(|c| c.unpack()).collect(),
            agent_pos: self.agent_pos.clone(),
            agent_dir: self.agent_dir.clone(),
            pocket: self.pocket.clone(),
            rules: self.rules.clone(),
            goals: self.goals.clone(),
            init: self.init.clone(),
            init_len: self.init_len.clone(),
            step_count: self.step_count.clone(),
            max_steps: self.max_steps.clone(),
            rng_states: self.rngs.iter().map(|r| r.state()).collect(),
        }
    }

    /// Install a captured [`VecEnvSnapshot`], the inverse of
    /// [`VecEnv::snapshot`]: afterwards this engine is bitwise-identical
    /// to the one the snapshot was taken from (same buffers, same RNG
    /// positions), so stepping it replays the original run exactly. The
    /// reset-derived caches the snapshot deliberately omits (free-cell
    /// lists, live rule counts) are recomputed here from the captured
    /// buffers. The installed task source is kept — snapshots carry
    /// state, not the task distribution. This is the recovery primitive:
    /// a supervisor restores a respawned chunk worker from the last
    /// synchronization point and replays the logged actions.
    pub fn restore(&mut self, snap: &VecEnvSnapshot) {
        let ghw = self.cfg.h * self.cfg.w;
        let mr = self.cfg.max_rules;
        assert_eq!(snap.base.len(), self.b * ghw, "snapshot batch size");
        assert_eq!(snap.grid.len(), self.b * ghw);
        assert_eq!(snap.rules.len(), self.b * mr);
        assert_eq!(snap.init.len(), self.b * self.cfg.max_init);
        assert_eq!(snap.rng_states.len(), self.b);
        for (dst, &src) in self.base.iter_mut().zip(&snap.base) {
            *dst = PackedCell::pack(src);
        }
        for (dst, &src) in self.grid.iter_mut().zip(&snap.grid) {
            *dst = PackedCell::pack(src);
        }
        self.agent_pos.copy_from_slice(&snap.agent_pos);
        self.agent_dir.copy_from_slice(&snap.agent_dir);
        self.pocket.copy_from_slice(&snap.pocket);
        self.rules.copy_from_slice(&snap.rules);
        self.goals.copy_from_slice(&snap.goals);
        self.init.copy_from_slice(&snap.init);
        self.init_len.copy_from_slice(&snap.init_len);
        self.step_count.copy_from_slice(&snap.step_count);
        self.max_steps.copy_from_slice(&snap.max_steps);
        for (rng, &s) in self.rngs.iter_mut().zip(&snap.rng_states) {
            *rng = Rng::from_state(s);
        }
        // recompute the reset-derived caches exactly as reset_env /
        // encode_task build them: the free-cell list is the base grid's
        // row-major TILE_FLOOR scan, the live rule count is the length
        // of the non-EMPTY prefix (encode packs live rows first and
        // pads with Rule::EMPTY)
        for i in 0..self.b {
            let g0 = i * ghw;
            let mut fl = 0usize;
            for p in 0..ghw {
                if self.base[g0 + p].tile() == TILE_FLOOR {
                    self.free_base[g0 + fl] = p as u32;
                    fl += 1;
                }
            }
            self.free_len[i] = fl as u32;
            let r0 = i * mr;
            let rl = self.rules[r0..r0 + mr]
                .iter()
                .take_while(|r| **r != Rule::EMPTY)
                .count();
            self.rules_len[i] = rl as u32;
        }
        self.seeded = true;
    }

    /// Start a fresh episode in every env slot. Mirrors the scalar
    /// `state::reset` per slot: env `i` consumes `rngs[i]` exactly like
    /// the oracle consumes its reset RNG, then keeps it as its stream.
    pub fn reset_all(&mut self, grids: &[Grid], rulesets: &[&Ruleset],
                     max_steps: &[i32], rngs: &[Rng],
                     obs_out: &mut [i32]) {
        assert_eq!(grids.len(), self.b, "need one base grid per env");
        assert_eq!(rulesets.len(), self.b, "need one ruleset per env");
        assert_eq!(max_steps.len(), self.b);
        assert_eq!(rngs.len(), self.b);
        assert_eq!(obs_out.len(), self.obs_len(), "obs buffer size");
        for i in 0..self.b {
            self.reset_env(i, &grids[i], rulesets[i], max_steps[i],
                           rngs[i].clone());
            self.observe_env(i, obs_out);
        }
    }

    /// One batched transition. `actions[i]` drives env `i`; observations
    /// land in `obs_out` (`[B, V, V, 2]` i32), per-env reward / episode
    /// done / trial done in the remaining buffers. Trial and episode
    /// auto-resets happen in place, exactly like the scalar oracle.
    pub fn step_all(&mut self, actions: &[i32], obs_out: &mut [i32],
                    rewards: &mut [f32], dones: &mut [bool],
                    trial_dones: &mut [bool]) {
        assert_eq!(actions.len(), self.b, "need one action per env");
        assert_eq!(obs_out.len(), self.obs_len(), "obs buffer size");
        assert_eq!(rewards.len(), self.b);
        assert_eq!(dones.len(), self.b);
        assert_eq!(trial_dones.len(), self.b);
        for i in 0..self.b {
            let (reward, done, trial_done) = self.step_env(i, actions[i]);
            rewards[i] = reward;
            dones[i] = done;
            trial_dones[i] = trial_done;
            self.observe_env(i, obs_out);
        }
    }

    // --- per-env kernels ---------------------------------------------------

    fn reset_env(&mut self, i: usize, base: &Grid, ruleset: &Ruleset,
                 max_steps: i32, mut rng: Rng) {
        let (h, w) = (self.cfg.h, self.cfg.w);
        assert_eq!((base.h, base.w), (h, w),
                   "env {i}: base grid {}x{} != family {h}x{w}",
                   base.h, base.w);
        let mr = self.cfg.max_rules;
        let mi = self.cfg.max_init;
        assert!(ruleset.rules.len() <= mr,
                "env {i}: ruleset has {} rules > capacity {mr}",
                ruleset.rules.len());
        assert!(ruleset.init_tiles.len() <= mi,
                "env {i}: ruleset has {} init objects > capacity {mi}",
                ruleset.init_tiles.len());

        self.encode_task(i, ruleset);

        let g0 = i * h * w;
        for (dst, &src) in
            self.base[g0..g0 + h * w].iter_mut().zip(base.cells())
        {
            *dst = PackedCell::pack(src);
        }
        // cache the base grid's row-major free-cell list once per
        // episode-input install; every trial placement copies this
        // prefix instead of rescanning the H·W grid
        let mut fl = 0usize;
        for p in 0..h * w {
            if self.base[g0 + p].tile() == TILE_FLOOR {
                self.free_base[g0 + fl] = p as u32;
                fl += 1;
            }
        }
        self.free_len[i] = fl as u32;
        self.max_steps[i] = max_steps;
        self.pocket[i] = POCKET_EMPTY;
        self.step_count[i] = 0;
        self.place(i, &mut rng);
        self.rngs[i] = rng;
        self.seeded = true;
    }

    fn step_env(&mut self, i: usize, action: i32) -> (f32, bool, bool) {
        let action = action.clamp(0, NUM_ACTIONS as i32 - 1);
        let (h, w) = (self.cfg.h, self.cfg.w);
        let g0 = i * h * w;
        let mr = self.cfg.max_rules;

        let mut pos = (self.agent_pos[i * 2], self.agent_pos[i * 2 + 1]);
        let mut dir = self.agent_dir[i];
        let mut pocket = self.pocket[i];
        let achieved;
        {
            let mut g = GridView::new(h, w, &mut self.grid[g0..g0 + h * w]);
            apply_action(&mut g, &mut pos, &mut dir, &mut pocket, action);
            // rules fire only after acting actions (§2.1); only the
            // rules_len live rows are scanned — the fixed-width padding
            // is inert Rule::EMPTY by construction, so skipping it is
            // semantics-free
            if is_acting_action(action) {
                let rl = self.rules_len[i] as usize;
                check_rules(&mut g, pos, &mut pocket,
                            &self.rules[i * mr..i * mr + rl]);
            }
            achieved = check_goal(&g, pos, pocket, &self.goals[i]);
        }

        let new_step = self.step_count[i] + 1;
        let done = new_step >= self.max_steps[i];
        let reward = if achieved {
            1.0 - 0.9 * new_step as f32 / self.max_steps[i].max(1) as f32
        } else {
            0.0
        };

        self.agent_pos[i * 2] = pos.0;
        self.agent_pos[i * 2 + 1] = pos.1;
        self.agent_dir[i] = dir;
        self.pocket[i] = pocket;

        let trial_done = achieved || done;
        if trial_done {
            // episode boundary: resample the task from the benchmark
            // before re-placing — replaying the same ruleset forever
            // breaks the meta-RL task-distribution protocol. Trial
            // resets keep the task (§2.1). The draw comes from the
            // env's own stream, so chunked parallel stepping stays
            // bitwise-identical to serial. The source is borrowed, not
            // Arc-cloned: `encode_task_into` takes the table columns
            // directly, so no refcount traffic per boundary.
            if done {
                if let Some(ts) = self.tasks.as_deref() {
                    let t = self.rngs[i].below(ts.num_tasks());
                    Self::encode_task_into(
                        self.cfg.max_rules, self.cfg.max_init,
                        &mut self.rules, &mut self.rules_len,
                        &mut self.goals, &mut self.init,
                        &mut self.init_len, i, ts.task(t));
                }
            }
            // same stream discipline as the scalar oracle: split the
            // env's RNG, place from the child stream
            let mut sub = self.rngs[i].split();
            self.place(i, &mut sub);
            self.pocket[i] = POCKET_EMPTY;
        }
        self.step_count[i] = if done { 0 } else { new_step };
        (reward, done, trial_done)
    }

    /// Encode `ruleset` into env `i`'s fixed-width table rows (rules,
    /// goal, init tiles); unused rows are inert padding.
    fn encode_task(&mut self, i: usize, ruleset: &Ruleset) {
        Self::encode_task_into(self.cfg.max_rules, self.cfg.max_init,
                               &mut self.rules, &mut self.rules_len,
                               &mut self.goals, &mut self.init,
                               &mut self.init_len, i, ruleset);
    }

    /// [`VecEnv::encode_task`] over explicitly borrowed table columns,
    /// so episode-boundary call sites can re-encode while the task
    /// source stays borrowed from `self.tasks` — the disjoint field
    /// borrows replace the former per-boundary `Arc` clone.
    #[allow(clippy::too_many_arguments)]
    fn encode_task_into(mr: usize, mi: usize, rules: &mut [Rule],
                        rules_len: &mut [u32], goals: &mut [Goal],
                        init: &mut [Cell], init_len: &mut [u32],
                        i: usize, ruleset: &Ruleset) {
        debug_assert!(ruleset.rules.len() <= mr
                      && ruleset.init_tiles.len() <= mi);
        for j in 0..mr {
            rules[i * mr + j] =
                ruleset.rules.get(j).copied().unwrap_or(Rule::EMPTY);
        }
        rules_len[i] = ruleset.rules.len() as u32;
        goals[i] = ruleset.goal;
        for j in 0..mi {
            init[i * mi + j] = ruleset.init_tiles.get(j).copied()
                .unwrap_or(Cell::new(0, 0));
        }
        init_len[i] = ruleset.init_tiles.len() as u32;
    }

    /// Trial placement for env `i`: restore the base grid, then place
    /// init tiles + agent on distinct random floor cells. Mirrors
    /// `state::place_objects` including its RNG call sequence
    /// (`partial_shuffle` == `sample_distinct`, then `below(4)`), but
    /// works in place on the SoA buffers with reusable scratch. The
    /// candidate list is the cached `free_base` prefix (same row-major
    /// order the scalar `free_cells` scan produces, so the shuffled
    /// draws are bitwise identical) — no O(H·W) rescan per trial.
    fn place(&mut self, i: usize, rng: &mut Rng) {
        let (h, w) = (self.cfg.h, self.cfg.w);
        let g0 = i * h * w;
        let grid = &mut self.grid[g0..g0 + h * w];
        grid.copy_from_slice(&self.base[g0..g0 + h * w]);

        let fl = self.free_len[i] as usize;
        self.free_scratch.clear();
        self.free_scratch
            .extend_from_slice(&self.free_base[g0..g0 + fl]);
        let k = self.init_len[i] as usize;
        assert!(
            fl > k,
            "grid has {fl} free cells but needs {}",
            k + 1
        );
        rng.partial_shuffle(&mut self.free_scratch, k + 1);
        let init = &self.init[i * self.cfg.max_init..];
        for j in 0..k {
            grid[self.free_scratch[j] as usize] =
                PackedCell::pack(init[j]);
        }
        let agent_flat = self.free_scratch[k] as usize;
        self.agent_pos[i * 2] = (agent_flat / w) as i32;
        self.agent_pos[i * 2 + 1] = (agent_flat % w) as i32;
        self.agent_dir[i] = rng.below(4) as i32;
    }

    /// Render env `i`'s observation straight into its `[V, V, 2]` slice
    /// of `obs_out` — one pass, no intermediate `Obs` fill or flatten.
    fn observe_env(&mut self, i: usize, obs_out: &mut [i32]) {
        let (h, w) = (self.cfg.h, self.cfg.w);
        let v = self.cfg.opts.view_size;
        let g0 = i * h * w;
        let pos = (self.agent_pos[i * 2], self.agent_pos[i * 2 + 1]);
        let dir = self.agent_dir[i];
        let gv = GridView::new(h, w, &mut self.grid[g0..g0 + h * w]);
        observe_flat_into(&gv, pos, dir, v,
                          self.cfg.opts.see_through_walls,
                          &mut obs_out[i * v * v * 2
                                       ..(i + 1) * v * v * 2],
                          &mut self.obs_scratch);
    }

    /// Re-render every env's current observation into `obs_out`
    /// (`[B, V, V, 2]` i32) without stepping — the obs-write share of
    /// step time falls out of timing this against `step_all` (the
    /// fig5a `obs_fraction` metric).
    pub fn write_obs_all(&mut self, obs_out: &mut [i32]) {
        assert_eq!(obs_out.len(), self.obs_len(), "obs buffer size");
        for i in 0..self.b {
            self.observe_env(i, obs_out);
        }
    }

    // --- unified-API surface (env::api::BatchEnvironment) ------------------

    /// Start a fresh *episode* in env `i` on its stored base grid,
    /// adopting `rng` as the env's stream: one task draw on the stream
    /// (when a source is installed), then a `split` for placement — the
    /// same episode-boundary RNG discipline as [`VecEnv::step_all`] and
    /// the scalar `ScalarEnv::reset`, so restarts stay bitwise-parallel
    /// across surfaces. `obs_out` is the chunk-local `[B, V, V, 2]`
    /// buffer (env `i`'s slice is written).
    pub fn restart_env_with(&mut self, i: usize, mut rng: Rng,
                            obs_out: &mut [i32]) {
        if let Some(ts) = self.tasks.as_deref() {
            let t = rng.below(ts.num_tasks());
            Self::encode_task_into(self.cfg.max_rules, self.cfg.max_init,
                                   &mut self.rules, &mut self.rules_len,
                                   &mut self.goals, &mut self.init,
                                   &mut self.init_len, i, ts.task(t));
        }
        let mut sub = rng.split();
        self.place(i, &mut sub);
        self.pocket[i] = POCKET_EMPTY;
        self.step_count[i] = 0;
        self.rngs[i] = rng;
        self.observe_env(i, obs_out);
    }

    /// [`VecEnv::restart_env_with`] over the whole batch: env `i`'s
    /// stream is the `i`-th `rng.split()` in env order (the derivation
    /// `ParVecEnv` mirrors chunk by chunk).
    pub fn restart_all(&mut self, rng: &mut Rng, obs_out: &mut [i32]) {
        assert_eq!(obs_out.len(), self.obs_len(), "obs buffer size");
        for i in 0..self.b {
            let r = rng.split();
            self.restart_env_with(i, r, obs_out);
        }
    }

    /// Per-env agent facing directions (the `DirectionObs` input).
    pub fn copy_agent_dirs_into(&self, out: &mut [i32]) {
        assert_eq!(out.len(), self.b, "direction buffer size");
        out.copy_from_slice(&self.agent_dir);
    }

    /// Per-env encoded task rows: goal `[5]` then rules `[MR, 7]`,
    /// env-major (the `RulesAndGoalsObs` input).
    pub fn copy_task_rows_into(&self, out: &mut [i32]) {
        let mr = self.cfg.max_rules;
        let row = GOAL_ENC + mr * RULE_ENC;
        assert_eq!(out.len(), self.b * row, "task row buffer size");
        for i in 0..self.b {
            let dst = &mut out[i * row..(i + 1) * row];
            dst[..GOAL_ENC].copy_from_slice(&self.goals[i].0);
            for j in 0..mr {
                dst[GOAL_ENC + j * RULE_ENC
                    ..GOAL_ENC + (j + 1) * RULE_ENC]
                    .copy_from_slice(&self.rules[i * mr + j].0);
            }
        }
    }
}

/// The serial SoA engine under the unified batch API. `reset` restarts
/// every env on its stored base grid (drawing fresh tasks from the
/// installed source); `step` is exactly [`VecEnv::step_all`].
impl BatchEnvironment for VecEnv {
    fn batch(&self) -> usize {
        self.b
    }

    fn obs_spec(&self) -> ObsSpec {
        self.cfg.obs_spec()
    }

    fn action_spec(&self) -> ActionSpec {
        self.cfg.action_spec()
    }

    fn max_rules(&self) -> usize {
        self.cfg.max_rules
    }

    fn reset(&mut self, rng: &mut Rng, obs_out: &mut [i32]) -> Result<()> {
        anyhow::ensure!(
            self.seeded,
            "VecEnv: no episode inputs installed — seed base grids / \
             tasks / step limits with reset_all once before the \
             trait-level reset restarts episodes"
        );
        self.restart_all(rng, obs_out);
        Ok(())
    }

    fn step(&mut self, actions: &[i32], obs_out: &mut [i32],
            rewards: &mut [f32], dones: &mut [bool],
            trial_dones: &mut [bool]) -> Result<()> {
        self.step_all(actions, obs_out, rewards, dones, trial_dones);
        Ok(())
    }

    fn agent_dirs_into(&self, out: &mut [i32]) {
        self.copy_agent_dirs_into(out)
    }

    fn task_rows_into(&self, out: &mut [i32]) {
        self.copy_task_rows_into(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::state::{reset, step, EnvOptions};

    fn ball_red() -> Cell {
        Cell::new(TILE_BALL, COLOR_RED)
    }

    fn sample_ruleset() -> Ruleset {
        Ruleset {
            goal: Goal::agent_near(ball_red()),
            rules: vec![Rule::agent_near(
                ball_red(),
                Cell::new(TILE_SQUARE, COLOR_BLUE),
            )],
            init_tiles: vec![ball_red()],
        }
    }

    /// Smoke-level bitwise parity on one env family; the full registry
    /// sweep lives in `tests/vec_env_equivalence.rs`.
    #[test]
    fn matches_scalar_oracle_on_simple_family() {
        let opts = EnvOptions::default();
        let b = 3usize;
        let h = 9;
        let w = 9;
        let rs = sample_ruleset();
        let grids: Vec<Grid> =
            (0..b).map(|_| Grid::empty_room(h, w)).collect();
        let max_steps = vec![5i32; b]; // short episodes force auto-resets
        let rngs: Vec<Rng> =
            (0..b).map(|i| Rng::new(100 + i as u64)).collect();

        // scalar oracle
        let mut scalar: Vec<_> = (0..b)
            .map(|i| {
                reset(grids[i].clone(), rs.clone(), max_steps[i],
                      rngs[i].clone(), opts)
            })
            .collect();

        // vectorized
        let cfg = VecEnvConfig {
            h,
            w,
            max_rules: 2,
            max_init: 2,
            opts,
        };
        let mut venv = VecEnv::new(cfg, b);
        let mut obs = vec![0i32; venv.obs_len()];
        let rs_refs: Vec<&Ruleset> = (0..b).map(|_| &rs).collect();
        venv.reset_all(&grids, &rs_refs, &max_steps, &rngs, &mut obs);

        let vv2 = opts.view_size * opts.view_size * 2;
        for i in 0..b {
            assert_eq!(&obs[i * vv2..(i + 1) * vv2],
                       &scalar[i].1.to_flat()[..], "reset obs env {i}");
        }

        let mut rewards = vec![0f32; b];
        let mut dones = vec![false; b];
        let mut trials = vec![false; b];
        let mut act = Rng::new(7);
        for t in 0..24 {
            let actions: Vec<i32> =
                (0..b).map(|_| act.below(6) as i32).collect();
            venv.step_all(&actions, &mut obs, &mut rewards, &mut dones,
                          &mut trials);
            for i in 0..b {
                let out = step(&mut scalar[i].0, actions[i], opts);
                assert_eq!(rewards[i].to_bits(), out.reward.to_bits(),
                           "step {t} env {i}: reward");
                assert_eq!(dones[i], out.done, "step {t} env {i}: done");
                assert_eq!(trials[i], out.trial_done,
                           "step {t} env {i}: trial_done");
                assert_eq!(&obs[i * vv2..(i + 1) * vv2],
                           &out.obs.to_flat()[..],
                           "step {t} env {i}: obs");
            }
        }
    }

    /// Regression: before the task-source fix, episode auto-reset
    /// replayed the same ruleset forever. With a multi-task source the
    /// encoded goal/rule tables must change across episode boundaries.
    #[test]
    fn episode_reset_draws_fresh_task_from_source() {
        let opts = EnvOptions::default();
        let tasks: Vec<Ruleset> = (0..6)
            .map(|k| Ruleset {
                goal: Goal::agent_hold(Cell::new(TILE_BALL, 3 + k)),
                rules: vec![],
                init_tiles: vec![Cell::new(TILE_BALL, 3 + k)],
            })
            .collect();
        let cfg = VecEnvConfig { h: 9, w: 9, max_rules: 1, max_init: 1,
                                 opts };
        let mut venv = VecEnv::new(cfg, 2);
        venv.set_task_source(Arc::new(tasks.clone()));
        let grids = vec![Grid::empty_room(9, 9), Grid::empty_room(9, 9)];
        let refs: Vec<&Ruleset> = vec![&tasks[0], &tasks[0]];
        let rngs = vec![Rng::new(1), Rng::new(2)];
        let mut obs = vec![0i32; venv.obs_len()];
        venv.reset_all(&grids, &refs, &[3, 3], &rngs, &mut obs);

        let mut rewards = vec![0f32; 2];
        let mut dones = vec![false; 2];
        let mut trials = vec![false; 2];
        let mut seen = std::collections::HashSet::new();
        for _ in 0..30 {
            venv.step_all(&[1, 2], &mut obs, &mut rewards, &mut dones,
                          &mut trials);
            seen.insert((venv.snapshot().goals[0], dones[0]));
        }
        let goals_after_reset: std::collections::HashSet<_> =
            seen.iter().map(|&(g, _)| g).collect();
        assert!(goals_after_reset.len() >= 2,
                "10 episode boundaries never changed the task table — \
                 stale-task auto-reset is back");
    }

    /// snapshot → restore into a *fresh* engine → both continue
    /// bitwise-identically (obs, rewards, dones, and final state). This
    /// is the invariant worker recovery stands on: a respawned chunk
    /// restored from the last sync point replays the original run.
    #[test]
    fn restore_resumes_bitwise_identically() {
        let opts = EnvOptions::default();
        let tasks: Vec<Ruleset> = (0..4)
            .map(|k| Ruleset {
                goal: Goal::agent_hold(Cell::new(TILE_BALL, 3 + k)),
                rules: vec![],
                init_tiles: vec![Cell::new(TILE_BALL, 3 + k)],
            })
            .collect();
        let cfg = VecEnvConfig { h: 9, w: 9, max_rules: 1, max_init: 1,
                                 opts };
        let b = 3;
        let mut venv = VecEnv::new(cfg, b);
        venv.set_task_source(Arc::new(tasks.clone()));
        let grids: Vec<Grid> =
            (0..b).map(|_| Grid::empty_room(9, 9)).collect();
        let refs: Vec<&Ruleset> = (0..b).map(|_| &tasks[0]).collect();
        let rngs: Vec<Rng> =
            (0..b).map(|i| Rng::new(40 + i as u64)).collect();
        let mut obs = vec![0i32; venv.obs_len()];
        venv.reset_all(&grids, &refs, &[5, 5, 5], &rngs, &mut obs);

        let mut rewards = vec![0f32; b];
        let mut dones = vec![false; b];
        let mut trials = vec![false; b];
        // advance past several trial/episode boundaries
        for t in 0..17 {
            let a = vec![(t % 6) as i32; b];
            venv.step_all(&a, &mut obs, &mut rewards, &mut dones,
                          &mut trials);
        }
        let snap = venv.snapshot();

        let mut fresh = VecEnv::new(cfg, b);
        fresh.set_task_source(Arc::new(tasks));
        fresh.restore(&snap);
        assert_eq!(fresh.snapshot(), snap, "restore must round-trip");

        let mut obs2 = vec![0i32; fresh.obs_len()];
        let mut rewards2 = vec![0f32; b];
        let mut dones2 = vec![false; b];
        let mut trials2 = vec![false; b];
        for t in 0..23 {
            let a = vec![((t * 5) % 6) as i32; b];
            venv.step_all(&a, &mut obs, &mut rewards, &mut dones,
                          &mut trials);
            fresh.step_all(&a, &mut obs2, &mut rewards2, &mut dones2,
                           &mut trials2);
            assert_eq!(obs, obs2, "step {t}: obs");
            assert_eq!(rewards, rewards2, "step {t}: rewards");
            assert_eq!(dones, dones2, "step {t}: dones");
            assert_eq!(trials, trials2, "step {t}: trial dones");
        }
        assert_eq!(venv.snapshot(), fresh.snapshot());
    }

    #[test]
    fn obs_buffer_layout() {
        let opts = EnvOptions::default();
        let cfg = VecEnvConfig { h: 9, w: 9, max_rules: 1, max_init: 1,
                                 opts };
        let venv = VecEnv::new(cfg, 4);
        assert_eq!(venv.batch(), 4);
        assert_eq!(venv.obs_len(), 4 * 5 * 5 * 2);
        assert_eq!(venv.config().max_rules, 1);
    }

    #[test]
    #[should_panic(expected = "need one action per env")]
    fn action_batch_mismatch_panics() {
        let opts = EnvOptions::default();
        let cfg = VecEnvConfig { h: 9, w: 9, max_rules: 1, max_init: 1,
                                 opts };
        let mut venv = VecEnv::new(cfg, 2);
        let mut obs = vec![0i32; venv.obs_len()];
        let mut rewards = vec![0f32; 2];
        let mut dones = vec![false; 2];
        let mut trials = vec![false; 2];
        venv.step_all(&[0], &mut obs, &mut rewards, &mut dones,
                      &mut trials);
    }
}
