//! Environment state + the `reset`/`step` transition — Rust oracle for
//! `python/compile/xmg/env.py`, with identical semantics:
//!
//! - 6 discrete actions; rules fire after forward/pick/put/toggle only;
//! - reward `1 - 0.9*step/max_steps` on goal;
//! - trial auto-reset on goal, episode auto-reset at `max_steps`.
//!
//! The oracle steps through the same hot-path kernels as the SoA
//! engines (`apply_action`/`check_rules`/`check_goal` over [`CellGrid`],
//! the gather-table + bitmask-occlusion observe kernels of
//! [`super::observation`]), so scalar-vs-batched bitwise parity is a
//! property of shared code, not of two implementations agreeing.

use crate::util::rng::Rng;

use super::goals::{check_goal, Goal};
use super::grid::{CellGrid, Grid};
use super::observation::{observe, observe_into, Obs, ObsScratch};
use super::rules::{check_rules, Rule};
use super::types::*;

/// A task: goal + production rules + objects placed at trial start
/// (paper §2.1 "ruleset").
#[derive(Clone, PartialEq, Debug)]
pub struct Ruleset {
    pub goal: Goal,
    pub rules: Vec<Rule>,
    pub init_tiles: Vec<Cell>,
}

impl Ruleset {
    /// Number of non-empty rules (the Fig. 4 statistic).
    pub fn num_rules(&self) -> usize {
        self.rules.iter().filter(|r| r.id() != RULE_EMPTY).count()
    }
}

/// A bag of tasks an environment resamples from at *episode* auto-reset
/// (the meta-RL task-distribution protocol of §2.1: a new episode is a
/// new task, while trial resets within an episode keep the task). The
/// benchmark store implements this for `Benchmark`; plain ruleset
/// vectors implement it for tests.
///
/// `Send + Sync` is a supertrait so one source can be shared across the
/// parallel stepping workers of `coordinator::workers`.
pub trait TaskSource: Send + Sync {
    fn num_tasks(&self) -> usize;
    fn task(&self, id: usize) -> &Ruleset;
}

impl TaskSource for Vec<Ruleset> {
    fn num_tasks(&self) -> usize {
        self.len()
    }

    fn task(&self, id: usize) -> &Ruleset {
        &self[id]
    }
}

/// Shared sources pass through: `Arc<Benchmark>`, `Arc<TaskSlice>` and
/// `Arc<dyn TaskSource>` are themselves sources, so the coordinator can
/// hold one `Arc` and hand it to engines that take either a borrow or
/// an owned source.
impl<T: TaskSource + ?Sized> TaskSource for std::sync::Arc<T> {
    fn num_tasks(&self) -> usize {
        (**self).num_tasks()
    }

    fn task(&self, id: usize) -> &Ruleset {
        (**self).task(id)
    }
}

#[derive(Clone, Debug)]
pub struct State {
    pub base_grid: Grid,
    pub grid: Grid,
    pub agent_pos: (i32, i32),
    pub agent_dir: i32,
    pub pocket: Cell,
    pub ruleset: Ruleset,
    pub step_count: i32,
    pub max_steps: i32,
    pub rng: Rng,
}

pub struct StepOutput {
    pub obs: Obs,
    pub reward: f32,
    pub done: bool,
    pub trial_done: bool,
}

/// [`StepOutput`] without the observation — returned by the
/// buffer-reusing [`step_with`], which writes the observation into a
/// caller-owned [`Obs`] instead of allocating one per step.
#[derive(Clone, Copy, Debug)]
pub struct StepInfo {
    pub reward: f32,
    pub done: bool,
    pub trial_done: bool,
}

#[derive(Clone, Copy, Debug)]
pub struct EnvOptions {
    pub view_size: usize,
    pub see_through_walls: bool,
}

impl Default for EnvOptions {
    fn default() -> Self {
        EnvOptions { view_size: 5, see_through_walls: true }
    }
}

/// Place init objects + agent on distinct random floor cells. Mirrors the
/// JAX `place_objects` distribution (k+1 distinct uniform floor cells; the
/// object list may contain conceptual padding on the JAX side — here the
/// list is exact).
pub(crate) fn place_objects(rng: &mut Rng, base_grid: &Grid,
                            init_tiles: &[Cell])
                            -> (Grid, (i32, i32), i32) {
    let mut grid = base_grid.clone();
    let free = grid.free_cells();
    assert!(
        free.len() > init_tiles.len(),
        "grid has {} free cells but needs {}",
        free.len(),
        init_tiles.len() + 1
    );
    let chosen = rng.sample_distinct(&free, init_tiles.len() + 1);
    for (cell, &pos) in init_tiles.iter().zip(&chosen) {
        grid.set(pos / grid.w, pos % grid.w, *cell);
    }
    let agent_flat = chosen[init_tiles.len()];
    let agent_pos = ((agent_flat / grid.w) as i32,
                     (agent_flat % grid.w) as i32);
    let agent_dir = rng.below(4) as i32;
    (grid, agent_pos, agent_dir)
}

/// Start a fresh episode.
pub fn reset(base_grid: Grid, ruleset: Ruleset, max_steps: i32,
             mut rng: Rng, opts: EnvOptions) -> (State, Obs) {
    let (grid, agent_pos, agent_dir) =
        place_objects(&mut rng, &base_grid, &ruleset.init_tiles);
    let obs = observe(&grid, agent_pos, agent_dir, opts.view_size,
                      opts.see_through_walls);
    let state = State {
        base_grid,
        grid,
        agent_pos,
        agent_dir,
        pocket: POCKET_EMPTY,
        ruleset,
        step_count: 0,
        max_steps,
        rng,
    };
    (state, obs)
}

/// Paper §2.3 heuristic for the default step limit.
pub fn default_max_steps(h: usize, w: usize) -> i32 {
    (3 * h * w) as i32
}

/// Actions after which production rules fire (§2.1 "acting" actions).
pub fn is_acting_action(action: i32) -> bool {
    matches!(
        action,
        ACTION_FORWARD | ACTION_PICK_UP | ACTION_PUT_DOWN | ACTION_TOGGLE
    )
}

/// Apply one (already clamped) action to a grid/agent/pocket triple.
/// Generic over [`CellGrid`]: this is the single action kernel shared by
/// the scalar oracle and the SoA engine of `env::vector`.
pub fn apply_action<G: CellGrid>(grid: &mut G, agent_pos: &mut (i32, i32),
                                 agent_dir: &mut i32, pocket: &mut Cell,
                                 action: i32) {
    let d = *agent_dir as usize;
    let (fr, fc) = (agent_pos.0 + DIR_DR[d], agent_pos.1 + DIR_DC[d]);
    match action {
        ACTION_FORWARD => {
            if grid.in_bounds(fr, fc)
                && is_walkable(grid.get_i(fr, fc).tile)
            {
                *agent_pos = (fr, fc);
            }
        }
        ACTION_TURN_LEFT => *agent_dir = (*agent_dir + 3) % 4,
        ACTION_TURN_RIGHT => *agent_dir = (*agent_dir + 1) % 4,
        ACTION_PICK_UP => {
            let cell = grid.get_i(fr, fc);
            if grid.in_bounds(fr, fc)
                && pocket.tile == TILE_EMPTY
                && is_pickable(cell.tile)
            {
                *pocket = cell;
                grid.set_i(fr, fc, FLOOR_CELL);
            }
        }
        ACTION_PUT_DOWN => {
            let cell = grid.get_i(fr, fc);
            if grid.in_bounds(fr, fc)
                && pocket.tile != TILE_EMPTY
                && cell.tile == TILE_FLOOR
            {
                grid.set_i(fr, fc, *pocket);
                *pocket = POCKET_EMPTY;
            }
        }
        ACTION_TOGGLE => {
            if grid.in_bounds(fr, fc) {
                let cell = grid.get_i(fr, fc);
                let has_key = pocket.tile == TILE_KEY
                    && pocket.color == cell.color;
                let new_tile = match cell.tile {
                    TILE_DOOR_CLOSED => TILE_DOOR_OPEN,
                    TILE_DOOR_OPEN => TILE_DOOR_CLOSED,
                    TILE_DOOR_LOCKED if has_key => TILE_DOOR_OPEN,
                    t => t,
                };
                grid.set_i(fr, fc, Cell::new(new_tile, cell.color));
            }
        }
        _ => unreachable!(),
    }
}

/// One environment transition, writing the observation into the
/// caller-owned `obs`/`scratch` buffers — the allocation-free hot-loop
/// form of [`step`] (no per-step rule clones or observation `Vec`s).
/// Episode auto-reset replays the same ruleset forever; benchmark-driven
/// runs that must resample a fresh task per episode use
/// [`step_with_tasks`].
pub fn step_with(state: &mut State, action: i32, opts: EnvOptions,
                 obs: &mut Obs, scratch: &mut ObsScratch) -> StepInfo {
    step_with_tasks(state, action, opts, None, obs, scratch)
}

/// [`step_with`] under the benchmark protocol: at an *episode* boundary
/// (`done`) a fresh task is drawn uniformly from `tasks` with the env's
/// own RNG stream and replaces the ruleset before objects are re-placed;
/// trial resets within the episode keep the task (§2.1). With
/// `tasks = None` this is exactly [`step_with`].
///
/// RNG discipline at an episode boundary: one `below(num_tasks)` draw on
/// the env stream, then the usual `split` for placement — the sequence
/// `env::vector::VecEnv` mirrors bitwise.
pub fn step_with_tasks(state: &mut State, action: i32, opts: EnvOptions,
                       tasks: Option<&dyn TaskSource>, obs: &mut Obs,
                       scratch: &mut ObsScratch) -> StepInfo {
    let action = action.clamp(0, NUM_ACTIONS as i32 - 1);
    apply_action(&mut state.grid, &mut state.agent_pos,
                 &mut state.agent_dir, &mut state.pocket, action);

    // rules fire only after acting actions (§2.1); the ruleset is
    // borrowed, not cloned — grid and ruleset are disjoint fields
    if is_acting_action(action) {
        let State { grid, agent_pos, pocket, ruleset, .. } = state;
        check_rules(grid, *agent_pos, pocket, &ruleset.rules);
    }

    let achieved = check_goal(&state.grid, state.agent_pos, state.pocket,
                              &state.ruleset.goal);
    let new_step = state.step_count + 1;
    let done = new_step >= state.max_steps;
    let reward = if achieved {
        1.0 - 0.9 * new_step as f32 / state.max_steps.max(1) as f32
    } else {
        0.0
    };

    let trial_done = achieved || done;
    if trial_done {
        if done {
            // episode boundary: resample the task before re-placing
            // (trial resets keep it — §2.1 benchmark protocol)
            if let Some(ts) = tasks {
                assert!(ts.num_tasks() > 0, "task source is empty");
                let t = state.rng.below(ts.num_tasks());
                state.ruleset = ts.task(t).clone();
            }
        }
        let mut sub = state.rng.split();
        let (grid, pos, dir) =
            place_objects(&mut sub, &state.base_grid,
                          &state.ruleset.init_tiles);
        state.grid = grid;
        state.agent_pos = pos;
        state.agent_dir = dir;
        state.pocket = POCKET_EMPTY;
    }
    state.step_count = if done { 0 } else { new_step };

    observe_into(&state.grid, state.agent_pos, state.agent_dir,
                 opts.view_size, opts.see_through_walls, obs, scratch);
    StepInfo { reward, done, trial_done }
}

/// One environment transition (mutates `state` in place).
pub fn step(state: &mut State, action: i32, opts: EnvOptions) -> StepOutput {
    let mut obs = Obs::empty(opts.view_size);
    let info = step_with(state, action, opts, &mut obs,
                         &mut ObsScratch::new());
    StepOutput {
        obs,
        reward: info.reward,
        done: info.done,
        trial_done: info.trial_done,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ball_red() -> Cell {
        Cell::new(TILE_BALL, COLOR_RED)
    }

    fn simple_state(goal: Goal, rules: Vec<Rule>, init: Vec<Cell>) -> State {
        let base = Grid::empty_room(9, 9);
        let ruleset = Ruleset { goal, rules, init_tiles: init };
        let (state, _) = reset(base, ruleset, 243, Rng::new(1),
                               EnvOptions::default());
        state
    }

    /// Drive the agent to a specific cell/direction (test helper bypassing
    /// pathing).
    fn teleport(state: &mut State, pos: (i32, i32), dir: i32) {
        state.agent_pos = pos;
        state.agent_dir = dir;
    }

    #[test]
    fn forward_moves_onto_floor_only() {
        let mut s = simple_state(Goal::EMPTY, vec![], vec![]);
        teleport(&mut s, (1, 1), 0); // facing up into the wall
        step(&mut s, ACTION_FORWARD, EnvOptions::default());
        assert_eq!(s.agent_pos, (1, 1), "wall blocks");
        teleport(&mut s, (1, 1), 2); // facing down into floor
        step(&mut s, ACTION_FORWARD, EnvOptions::default());
        assert_eq!(s.agent_pos, (2, 1));
    }

    #[test]
    fn turns_cycle_directions() {
        let mut s = simple_state(Goal::EMPTY, vec![], vec![]);
        teleport(&mut s, (4, 4), 0);
        step(&mut s, ACTION_TURN_RIGHT, EnvOptions::default());
        assert_eq!(s.agent_dir, 1);
        step(&mut s, ACTION_TURN_LEFT, EnvOptions::default());
        step(&mut s, ACTION_TURN_LEFT, EnvOptions::default());
        assert_eq!(s.agent_dir, 3);
    }

    #[test]
    fn pick_up_and_put_down_roundtrip() {
        let mut s = simple_state(Goal::EMPTY, vec![], vec![]);
        teleport(&mut s, (4, 4), 1); // facing right
        s.grid.set(4, 5, ball_red());
        step(&mut s, ACTION_PICK_UP, EnvOptions::default());
        assert_eq!(s.pocket, ball_red());
        assert_eq!(s.grid.get(4, 5), FLOOR_CELL);
        // can't pick a second item
        s.grid.set(4, 5, Cell::new(TILE_SQUARE, COLOR_BLUE));
        step(&mut s, ACTION_PICK_UP, EnvOptions::default());
        assert_eq!(s.pocket, ball_red(), "pocket is single-slot");
        // put down on floor
        teleport(&mut s, (4, 4), 2); // facing down (floor)
        step(&mut s, ACTION_PUT_DOWN, EnvOptions::default());
        assert_eq!(s.pocket, POCKET_EMPTY);
        assert_eq!(s.grid.get(5, 4), ball_red());
    }

    #[test]
    fn put_down_blocked_by_occupied_cell() {
        let mut s = simple_state(Goal::EMPTY, vec![], vec![]);
        teleport(&mut s, (4, 4), 1);
        s.pocket = ball_red();
        s.grid.set(4, 5, Cell::new(TILE_SQUARE, COLOR_BLUE));
        step(&mut s, ACTION_PUT_DOWN, EnvOptions::default());
        assert_eq!(s.pocket, ball_red(), "cannot drop onto an object");
    }

    #[test]
    fn toggle_doors_and_keys() {
        let mut s = simple_state(Goal::EMPTY, vec![], vec![]);
        teleport(&mut s, (4, 4), 1);
        s.grid.set(4, 5, Cell::new(TILE_DOOR_CLOSED, COLOR_BLUE));
        step(&mut s, ACTION_TOGGLE, EnvOptions::default());
        assert_eq!(s.grid.get(4, 5).tile, TILE_DOOR_OPEN);
        step(&mut s, ACTION_TOGGLE, EnvOptions::default());
        assert_eq!(s.grid.get(4, 5).tile, TILE_DOOR_CLOSED);

        s.grid.set(4, 5, Cell::new(TILE_DOOR_LOCKED, COLOR_BLUE));
        step(&mut s, ACTION_TOGGLE, EnvOptions::default());
        assert_eq!(s.grid.get(4, 5).tile, TILE_DOOR_LOCKED,
                   "locked without key");
        s.pocket = Cell::new(TILE_KEY, COLOR_RED);
        step(&mut s, ACTION_TOGGLE, EnvOptions::default());
        assert_eq!(s.grid.get(4, 5).tile, TILE_DOOR_LOCKED,
                   "wrong key color");
        s.pocket = Cell::new(TILE_KEY, COLOR_BLUE);
        step(&mut s, ACTION_TOGGLE, EnvOptions::default());
        assert_eq!(s.grid.get(4, 5).tile, TILE_DOOR_OPEN);
    }

    #[test]
    fn goal_gives_scaled_reward_and_trial_reset() {
        let goal = Goal::agent_near(ball_red());
        let mut s = simple_state(goal, vec![], vec![ball_red()]);
        teleport(&mut s, (4, 4), 0);
        s.grid.set(3, 4, ball_red()); // in front; forward triggers check
        let out = step(&mut s, ACTION_TURN_LEFT, EnvOptions::default());
        // goal checked after every action — already adjacent
        assert!(out.trial_done);
        assert!(!out.done);
        let expected = 1.0 - 0.9 * (s.max_steps as f32).recip();
        assert!((out.reward - expected).abs() < 1e-6);
        // trial reset happened: pocket empty, step count continues
        assert_eq!(s.pocket, POCKET_EMPTY);
        assert_eq!(s.step_count, 1);
        // the ball was re-placed somewhere on the grid
        assert_eq!(s.grid.count_tile(TILE_BALL), 1);
    }

    #[test]
    fn episode_reset_resamples_task_trial_reset_keeps_it() {
        // two tasks with distinct goals; episode boundaries must draw
        // from the source, trial boundaries must not
        let tasks: Vec<Ruleset> = vec![
            Ruleset {
                goal: Goal::agent_near(ball_red()),
                rules: vec![],
                init_tiles: vec![ball_red()],
            },
            Ruleset {
                goal: Goal::agent_hold(Cell::new(TILE_KEY, COLOR_BLUE)),
                rules: vec![],
                init_tiles: vec![Cell::new(TILE_KEY, COLOR_BLUE)],
            },
        ];
        let mut s = simple_state(Goal::EMPTY, vec![], vec![ball_red()]);
        s.max_steps = 2;
        let mut obs = Obs::empty(5);
        let mut scratch = ObsScratch::new();
        let opts = EnvOptions::default();
        let mut seen = std::collections::HashSet::new();
        for _ in 0..32 {
            // EMPTY goal never achieves: the only boundaries are
            // episode ends, so every boundary must resample
            let a = step_with_tasks(&mut s, ACTION_TURN_LEFT, opts,
                                    Some(&tasks), &mut obs, &mut scratch);
            assert!(!a.done);
            let b = step_with_tasks(&mut s, ACTION_TURN_LEFT, opts,
                                    Some(&tasks), &mut obs, &mut scratch);
            assert!(b.done && b.trial_done);
            assert!(tasks.contains(&s.ruleset),
                    "episode reset must draw from the task source");
            seen.insert(s.ruleset.goal.0);
        }
        assert_eq!(seen.len(), 2,
                   "32 episode resets must have sampled both tasks");
    }

    #[test]
    fn episode_auto_resets_at_max_steps() {
        let mut s = simple_state(Goal::EMPTY, vec![], vec![ball_red()]);
        s.max_steps = 3;
        let o1 = step(&mut s, ACTION_TURN_LEFT, EnvOptions::default());
        let o2 = step(&mut s, ACTION_TURN_LEFT, EnvOptions::default());
        let o3 = step(&mut s, ACTION_TURN_LEFT, EnvOptions::default());
        assert!(!o1.done && !o2.done && o3.done);
        assert_eq!(s.step_count, 0, "step count reset");
        assert_eq!(s.grid.count_tile(TILE_BALL), 1, "objects re-placed");
    }

    #[test]
    fn rules_fire_after_forward_but_not_after_turn() {
        let rule = Rule::agent_near(ball_red(),
                                    Cell::new(TILE_SQUARE, COLOR_BLUE));
        let mut s = simple_state(Goal::EMPTY, vec![rule], vec![]);
        teleport(&mut s, (4, 4), 0);
        s.grid.set(3, 4, ball_red()); // already adjacent
        step(&mut s, ACTION_TURN_LEFT, EnvOptions::default());
        assert_eq!(s.grid.get(3, 4), ball_red(), "turn must not trigger");
        step(&mut s, ACTION_TURN_RIGHT, EnvOptions::default());
        assert_eq!(s.grid.get(3, 4), ball_red(), "turn must not trigger");
        // put_down with an empty pocket moves nothing but IS an acting
        // action, so rules are evaluated
        step(&mut s, ACTION_PUT_DOWN, EnvOptions::default());
        assert_eq!(s.grid.get(3, 4).tile, TILE_SQUARE);
    }

    #[test]
    fn reset_places_all_objects_and_agent_on_floor() {
        let init = vec![ball_red(), Cell::new(TILE_KEY, COLOR_YELLOW),
                        Cell::new(TILE_SQUARE, COLOR_BLUE)];
        let base = Grid::empty_room(9, 9);
        let ruleset = Ruleset {
            goal: Goal::EMPTY,
            rules: vec![],
            init_tiles: init.clone(),
        };
        for seed in 0..20 {
            let (s, _) = reset(base.clone(), ruleset.clone(), 243,
                               Rng::new(seed), EnvOptions::default());
            for cell in &init {
                assert_eq!(
                    s.grid
                        .iter_cells()
                        .filter(|(_, _, c)| c == cell)
                        .count(),
                    1
                );
            }
            let under_agent =
                s.grid.get_i(s.agent_pos.0, s.agent_pos.1);
            assert_eq!(under_agent.tile, TILE_FLOOR,
                       "agent starts on a floor cell");
        }
    }

    #[test]
    fn observation_matches_view_size() {
        let mut s = simple_state(Goal::EMPTY, vec![], vec![]);
        let opts = EnvOptions { view_size: 7, see_through_walls: true };
        teleport(&mut s, (4, 4), 0);
        let out = step(&mut s, ACTION_TURN_LEFT, opts);
        assert_eq!(out.obs.v, 7);
        assert_eq!(out.obs.cells.len(), 49);
    }

    #[test]
    fn default_max_steps_heuristic() {
        assert_eq!(default_max_steps(9, 9), 243);
        assert_eq!(default_max_steps(13, 13), 507);
    }
}
