//! Room layouts (paper Fig. 14): 1, 2, 4, 6 and 9 rooms with randomized
//! door positions/colors between episodes.
//!
//! Door randomization is owned by L3 (this module), not by the AOT `reset`:
//! the finished base grid is an *input* to the reset executable, keeping the
//! HLO free of data-dependent layout branching (the paper hits the same
//! wall: "layouts ... can not be changed under jit-compilation", App. I).

use crate::util::rng::Rng;

use super::grid::Grid;
use super::types::*;

/// Wall coordinates splitting `len` cells into `parts` rooms.
fn dividers(len: usize, parts: usize) -> Vec<usize> {
    (1..parts).map(|i| i * (len - 1) / parts).collect()
}

/// Build an `room_rows x room_cols` layout with one door per shared wall
/// segment. With `fixed_doors`, doors sit mid-segment (the paper fixes the
/// 6-room layout's doors).
pub fn multi_room(h: usize, w: usize, room_rows: usize, room_cols: usize,
                  rng: &mut Rng, fixed_doors: bool) -> Grid {
    let mut grid = Grid::empty_room(h, w);
    let row_walls = dividers(h, room_rows);
    let col_walls = dividers(w, room_cols);

    for &wr in &row_walls {
        for c in 1..w - 1 {
            grid.set(wr, c, WALL_CELL);
        }
    }
    for &wc in &col_walls {
        for r in 1..h - 1 {
            grid.set(r, wc, WALL_CELL);
        }
    }

    let door = |grid: &mut Grid, r: usize, c: usize, rng: &mut Rng| {
        let color = GEN_COLORS[rng.below(GEN_COLORS.len())];
        grid.set(r, c, Cell::new(TILE_DOOR_CLOSED, color));
    };

    // vertical walls: one door per room-row span
    let row_spans = spans(h, &row_walls);
    let col_spans = spans(w, &col_walls);
    for &wc in &col_walls {
        for span in &row_spans {
            let slots: Vec<usize> = (span.0..span.1)
                .filter(|&r| grid.get(r, wc).tile == TILE_WALL
                        && r > 0 && r < h - 1)
                .collect();
            if slots.is_empty() {
                continue;
            }
            let r = if fixed_doors {
                slots[slots.len() / 2]
            } else {
                slots[rng.below(slots.len())]
            };
            door(&mut grid, r, wc, rng);
        }
    }
    // horizontal walls: one door per room-col span
    for &wr in &row_walls {
        for span in &col_spans {
            let slots: Vec<usize> = (span.0..span.1)
                .filter(|&c| grid.get(wr, c).tile == TILE_WALL
                        && c > 0 && c < w - 1)
                .collect();
            if slots.is_empty() {
                continue;
            }
            let c = if fixed_doors {
                slots[slots.len() / 2]
            } else {
                slots[rng.below(slots.len())]
            };
            door(&mut grid, wr, c, rng);
        }
    }
    grid
}

/// Open intervals between walls (excluding border and wall cells).
fn spans(len: usize, walls: &[usize]) -> Vec<(usize, usize)> {
    let mut edges = vec![0usize];
    edges.extend_from_slice(walls);
    edges.push(len - 1);
    edges.windows(2).map(|p| (p[0] + 1, p[1])).collect()
}

/// XLand layout by room count (1, 2, 4, 6, 9 — Fig. 14).
pub fn xland_layout(rooms: usize, h: usize, w: usize, rng: &mut Rng)
                    -> Grid {
    match rooms {
        1 => Grid::empty_room(h, w),
        2 => multi_room(h, w, 1, 2, rng, false),
        4 => multi_room(h, w, 2, 2, rng, false),
        6 => multi_room(h, w, 2, 3, rng, true),
        9 => multi_room(h, w, 3, 3, rng, false),
        n => panic!("unsupported room count {n}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn door_count(g: &Grid) -> usize {
        g.count_tile(TILE_DOOR_CLOSED) + g.count_tile(TILE_DOOR_OPEN)
            + g.count_tile(TILE_DOOR_LOCKED)
    }

    #[test]
    fn one_room_has_no_doors() {
        let mut rng = Rng::new(0);
        let g = xland_layout(1, 9, 9, &mut rng);
        assert_eq!(door_count(&g), 0);
    }

    #[test]
    fn two_rooms_one_door() {
        let mut rng = Rng::new(0);
        let g = xland_layout(2, 9, 9, &mut rng);
        assert_eq!(door_count(&g), 1);
    }

    #[test]
    fn four_rooms_four_doors() {
        let mut rng = Rng::new(0);
        let g = xland_layout(4, 13, 13, &mut rng);
        assert_eq!(door_count(&g), 4);
    }

    #[test]
    fn six_rooms_seven_doors() {
        // 2x3 rooms: 2 row-spans * 2 col-walls = 4 vertical doors,
        // 3 col-spans * 1 row-wall = 3 horizontal doors
        let mut rng = Rng::new(0);
        let g = xland_layout(6, 13, 13, &mut rng);
        assert_eq!(door_count(&g), 7);
    }

    #[test]
    fn nine_rooms_twelve_doors() {
        let mut rng = Rng::new(0);
        let g = xland_layout(9, 16, 16, &mut rng);
        assert_eq!(door_count(&g), 12);
    }

    #[test]
    fn rooms_are_connected() {
        // flood fill over walkable+door cells must reach every floor cell
        for rooms in [1, 2, 4, 6, 9] {
            let mut rng = Rng::new(42);
            let g = xland_layout(rooms, 13, 13, &mut rng);
            let free = g.free_cells();
            let mut seen = vec![false; g.h * g.w];
            let mut stack = vec![free[0]];
            seen[free[0]] = true;
            while let Some(p) = stack.pop() {
                let (r, c) = ((p / g.w) as i32, (p % g.w) as i32);
                for d in 0..4 {
                    let (nr, nc) = (r + DIR_DR[d], c + DIR_DC[d]);
                    if !g.in_bounds(nr, nc) {
                        continue;
                    }
                    let q = nr as usize * g.w + nc as usize;
                    let t = g.get(nr as usize, nc as usize).tile;
                    if !seen[q]
                        && (t == TILE_FLOOR || t == TILE_DOOR_CLOSED
                            || t == TILE_DOOR_OPEN)
                    {
                        seen[q] = true;
                        stack.push(q);
                    }
                }
            }
            for &p in &free {
                assert!(seen[p], "rooms={rooms}: floor cell {p} unreachable");
            }
        }
    }

    #[test]
    fn door_positions_randomize_between_builds() {
        let g1 = xland_layout(4, 13, 13, &mut Rng::new(1));
        let g2 = xland_layout(4, 13, 13, &mut Rng::new(2));
        assert_ne!(g1, g2, "door placement should vary with the seed");
    }

    #[test]
    fn six_room_doors_are_fixed() {
        let g1 = xland_layout(6, 13, 13, &mut Rng::new(1));
        let g2 = xland_layout(6, 13, 13, &mut Rng::new(2));
        let doors = |g: &Grid| -> Vec<(usize, usize)> {
            g.iter_cells()
                .filter(|(_, _, c)| c.tile == TILE_DOOR_CLOSED)
                .map(|(r, c, _)| (r, c))
                .collect()
        };
        assert_eq!(doors(&g1), doors(&g2), "positions fixed (colors vary)");
    }
}
