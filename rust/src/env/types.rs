//! Shared constants (paper Tables 1-3), mirroring
//! `python/compile/xmg/types.py` value-for-value. Pinned by
//! `rust/tests/id_tables.rs` against the manifest and by python tests.

// --- Table 1a: tiles --------------------------------------------------------
pub const TILE_END_OF_MAP: i32 = 0;
pub const TILE_UNSEEN: i32 = 1;
pub const TILE_EMPTY: i32 = 2;
pub const TILE_FLOOR: i32 = 3;
pub const TILE_WALL: i32 = 4;
pub const TILE_BALL: i32 = 5;
pub const TILE_SQUARE: i32 = 6;
pub const TILE_PYRAMID: i32 = 7;
pub const TILE_GOAL: i32 = 8;
pub const TILE_KEY: i32 = 9;
pub const TILE_DOOR_LOCKED: i32 = 10;
pub const TILE_DOOR_CLOSED: i32 = 11;
pub const TILE_DOOR_OPEN: i32 = 12;
pub const TILE_HEX: i32 = 13;
pub const TILE_STAR: i32 = 14;
pub const NUM_TILES: usize = 15;

// --- Table 1b: colors -------------------------------------------------------
pub const COLOR_END_OF_MAP: i32 = 0;
pub const COLOR_UNSEEN: i32 = 1;
pub const COLOR_EMPTY: i32 = 2;
pub const COLOR_RED: i32 = 3;
pub const COLOR_GREEN: i32 = 4;
pub const COLOR_BLUE: i32 = 5;
pub const COLOR_PURPLE: i32 = 6;
pub const COLOR_YELLOW: i32 = 7;
pub const COLOR_GREY: i32 = 8;
pub const COLOR_BLACK: i32 = 9;
pub const COLOR_ORANGE: i32 = 10;
pub const COLOR_WHITE: i32 = 11;
pub const COLOR_BROWN: i32 = 12;
pub const COLOR_PINK: i32 = 13;
pub const NUM_COLORS: usize = 14;

/// 10 object colors used by the benchmark generator (App. J).
pub const GEN_COLORS: [i32; 10] = [
    COLOR_RED, COLOR_GREEN, COLOR_BLUE, COLOR_PURPLE, COLOR_YELLOW,
    COLOR_GREY, COLOR_WHITE, COLOR_BROWN, COLOR_PINK, COLOR_ORANGE,
];
/// 7 object tiles used by the benchmark generator (App. J).
pub const GEN_TILES: [i32; 7] = [
    TILE_BALL, TILE_SQUARE, TILE_PYRAMID, TILE_KEY, TILE_STAR, TILE_HEX,
    TILE_GOAL,
];

// --- actions ----------------------------------------------------------------
pub const ACTION_FORWARD: i32 = 0;
pub const ACTION_TURN_LEFT: i32 = 1;
pub const ACTION_TURN_RIGHT: i32 = 2;
pub const ACTION_PICK_UP: i32 = 3;
pub const ACTION_PUT_DOWN: i32 = 4;
pub const ACTION_TOGGLE: i32 = 5;
pub const NUM_ACTIONS: usize = 6;

// --- directions: 0=up 1=right 2=down 3=left ---------------------------------
pub const DIR_UP: usize = 0;
pub const DIR_RIGHT: usize = 1;
pub const DIR_DOWN: usize = 2;
pub const DIR_LEFT: usize = 3;
pub const DIR_DR: [i32; 4] = [-1, 0, 1, 0];
pub const DIR_DC: [i32; 4] = [0, 1, 0, -1];

// --- Table 2: goals ---------------------------------------------------------
pub const GOAL_EMPTY: i32 = 0;
pub const GOAL_AGENT_HOLD: i32 = 1;
pub const GOAL_AGENT_ON_TILE: i32 = 2;
pub const GOAL_AGENT_NEAR: i32 = 3;
pub const GOAL_TILE_NEAR: i32 = 4;
pub const GOAL_AGENT_ON_POSITION: i32 = 5;
pub const GOAL_TILE_ON_POSITION: i32 = 6;
pub const GOAL_TILE_NEAR_UP: i32 = 7;
pub const GOAL_TILE_NEAR_RIGHT: i32 = 8;
pub const GOAL_TILE_NEAR_DOWN: i32 = 9;
pub const GOAL_TILE_NEAR_LEFT: i32 = 10;
pub const GOAL_AGENT_NEAR_UP: i32 = 11;
pub const GOAL_AGENT_NEAR_RIGHT: i32 = 12;
pub const GOAL_AGENT_NEAR_DOWN: i32 = 13;
pub const GOAL_AGENT_NEAR_LEFT: i32 = 14;
pub const NUM_GOALS: usize = 15;

// --- Table 3: rules ---------------------------------------------------------
pub const RULE_EMPTY: i32 = 0;
pub const RULE_AGENT_HOLD: i32 = 1;
pub const RULE_AGENT_NEAR: i32 = 2;
pub const RULE_TILE_NEAR: i32 = 3;
pub const RULE_TILE_NEAR_UP: i32 = 4;
pub const RULE_TILE_NEAR_RIGHT: i32 = 5;
pub const RULE_TILE_NEAR_DOWN: i32 = 6;
pub const RULE_TILE_NEAR_LEFT: i32 = 7;
pub const RULE_AGENT_NEAR_UP: i32 = 8;
pub const RULE_AGENT_NEAR_RIGHT: i32 = 9;
pub const RULE_AGENT_NEAR_DOWN: i32 = 10;
pub const RULE_AGENT_NEAR_LEFT: i32 = 11;
pub const NUM_RULES: usize = 12;

/// Encoding widths (paper §2.1).
pub const RULE_ENC: usize = 7; // [id, a_t, a_c, b_t, b_c, c_t, c_c]
pub const GOAL_ENC: usize = 5; // [id, a0, a1, a2, a3]

/// A grid cell / object: (tile id, color id). `repr(C)` so a `[Cell]`
/// slice is bit-identical to the `i32[..., 2]` boundary layout the SoA
/// engine and the PJRT tensors use.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
#[repr(C)]
pub struct Cell {
    pub tile: i32,
    pub color: i32,
}

impl Cell {
    pub const fn new(tile: i32, color: i32) -> Self {
        Cell { tile, color }
    }
}

/// Grid-interior cell packed into 16 bits: `tile` in the low byte,
/// `color` in the high byte. The SoA batch engines store their `[B, H,
/// W]` grid tensors as `PackedCell` — half the memory traffic of the
/// `(i32, i32)` [`Cell`] at large B — and unpack only at the i32
/// PJRT/observation boundary. Lossless for every id the engine can
/// produce (Tables 1-3 ids are < 15; [`PackedCell::pack`] asserts the
/// byte domain so corrupt stores fail loudly instead of truncating).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
#[repr(transparent)]
pub struct PackedCell(u16);

impl PackedCell {
    pub const ZERO: PackedCell = PackedCell(0);

    #[inline]
    pub fn pack(cell: Cell) -> PackedCell {
        assert!(
            (0..256).contains(&cell.tile) && (0..256).contains(&cell.color),
            "cell ({}, {}) outside the u8 id domain",
            cell.tile,
            cell.color
        );
        PackedCell((cell.tile as u16) | ((cell.color as u16) << 8))
    }

    #[inline]
    pub fn tile(self) -> i32 {
        (self.0 & 0xff) as i32
    }

    #[inline]
    pub fn color(self) -> i32 {
        (self.0 >> 8) as i32
    }

    #[inline]
    pub fn unpack(self) -> Cell {
        Cell::new(self.tile(), self.color())
    }
}

pub const FLOOR_CELL: Cell = Cell::new(TILE_FLOOR, COLOR_BLACK);
pub const WALL_CELL: Cell = Cell::new(TILE_WALL, COLOR_GREY);
pub const END_OF_MAP_CELL: Cell = Cell::new(TILE_END_OF_MAP, COLOR_END_OF_MAP);
pub const UNSEEN_CELL: Cell = Cell::new(TILE_UNSEEN, COLOR_UNSEEN);
pub const POCKET_EMPTY: Cell = Cell::new(TILE_EMPTY, COLOR_EMPTY);

pub fn is_pickable(tile: i32) -> bool {
    matches!(
        tile,
        TILE_BALL | TILE_SQUARE | TILE_PYRAMID | TILE_KEY | TILE_HEX
            | TILE_STAR
    )
}

pub fn is_walkable(tile: i32) -> bool {
    matches!(tile, TILE_FLOOR | TILE_GOAL | TILE_DOOR_OPEN)
}

pub fn blocks_sight(tile: i32) -> bool {
    matches!(
        tile,
        TILE_WALL | TILE_DOOR_CLOSED | TILE_DOOR_LOCKED | TILE_END_OF_MAP
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 1 pinned exactly as printed in the paper.
    #[test]
    fn id_tables_match_paper() {
        assert_eq!(TILE_END_OF_MAP, 0);
        assert_eq!(TILE_FLOOR, 3);
        assert_eq!(TILE_WALL, 4);
        assert_eq!(TILE_BALL, 5);
        assert_eq!(TILE_GOAL, 8);
        assert_eq!(TILE_KEY, 9);
        assert_eq!(TILE_DOOR_LOCKED, 10);
        assert_eq!(TILE_STAR, 14);
        assert_eq!(COLOR_RED, 3);
        assert_eq!(COLOR_PINK, 13);
        assert_eq!(NUM_TILES, 15);
        assert_eq!(NUM_COLORS, 14);
    }

    /// Tables 2-3 pinned.
    #[test]
    fn rule_goal_ids_match_paper() {
        assert_eq!(GOAL_TILE_NEAR, 4);
        assert_eq!(GOAL_AGENT_NEAR_LEFT, 14);
        assert_eq!(RULE_TILE_NEAR, 3);
        assert_eq!(RULE_AGENT_NEAR_LEFT, 11);
        assert_eq!(NUM_GOALS, 15);
        assert_eq!(NUM_RULES, 12);
    }

    #[test]
    fn tile_predicates() {
        assert!(is_pickable(TILE_KEY));
        assert!(!is_pickable(TILE_WALL));
        assert!(is_walkable(TILE_DOOR_OPEN));
        assert!(!is_walkable(TILE_DOOR_CLOSED));
        assert!(blocks_sight(TILE_DOOR_LOCKED));
        assert!(!blocks_sight(TILE_FLOOR));
    }

    #[test]
    fn generator_palettes_match_appendix_j() {
        assert_eq!(GEN_COLORS.len(), 10);
        assert_eq!(GEN_TILES.len(), 7);
    }

    #[test]
    fn packed_cell_roundtrip() {
        for tile in 0..NUM_TILES as i32 {
            for color in 0..NUM_COLORS as i32 {
                let cell = Cell::new(tile, color);
                let p = PackedCell::pack(cell);
                assert_eq!(p.unpack(), cell);
                assert_eq!((p.tile(), p.color()), (tile, color));
            }
        }
        // full byte domain, including the corners
        for v in [0, 1, 127, 128, 255] {
            let cell = Cell::new(v, 255 - v);
            assert_eq!(PackedCell::pack(cell).unpack(), cell);
        }
        assert_eq!(PackedCell::ZERO.unpack(), END_OF_MAP_CELL);
    }

    #[test]
    #[should_panic(expected = "outside the u8 id domain")]
    fn packed_cell_rejects_out_of_domain_ids() {
        PackedCell::pack(Cell::new(256, 0));
    }
}
