//! Egocentric partial observations — Rust oracle for
//! `python/compile/xmg/observation.py`.
//!
//! V×V window, agent at bottom-center `(V-1, V/2)` facing up; cells are
//! `(tile, color)` symbol pairs; outside the grid reads END_OF_MAP; with
//! `see_through_walls == false`, a visibility pass marks occluded cells
//! UNSEEN (identical fixed point to the JAX flood fill).
//!
//! # Hot-path kernels (docs/ARCHITECTURE.md "Hot-path anatomy")
//!
//! The per-step kernels here are branch-free where the naive forms
//! branch per cell:
//!
//! - **gather tables**: the view→world offset `(dr, dc)` of every view
//!   cell is a pure function of `(agent_dir, view_size, vr, vc)`; the
//!   per-cell `match agent_dir` of the original kernel is replaced by a
//!   `[4, V, V]` offset table built once per view size and cached in
//!   [`ObsScratch`] ([`reference::gather_offset`] is the generating
//!   formula and the property-test oracle);
//! - **bitmask occlusion**: visibility over the V×V window is one `u64`
//!   (V ≤ 8 ⇒ V² ≤ 64 bits). [`visibility_mask`] propagates light with
//!   four shifts per round (up/down = `>> V`/`<< V`, left/right = `>> 1`
//!   /`<< 1` under column-edge masks) to the same monotone fixed point
//!   as the original O(V²)-sweep flood fill
//!   ([`reference::flood_fill_vis`]), in O(V) word ops;
//! - **direct i32 writes**: [`observe_flat_into`] renders straight into
//!   the caller's `[V, V, 2]` i32 slice — the batch engines' path,
//!   which deletes the intermediate `Obs{Vec<Cell>}` fill plus
//!   `write_flat_into` second pass the old `observe_env` did.
//!
//! Every kernel is pinned bitwise to the [`reference`] implementations
//! by `tests/obs_kernels.rs`, and the engine-level parity suites
//! (`vec_env_equivalence`, `wrapper_parity`, `native_threads`) pin the
//! composition.

use super::grid::{CellGrid, Grid};
use super::types::*;

/// Observation: row-major V×V of cells.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct Obs {
    pub v: usize,
    pub cells: Vec<Cell>,
}

impl Obs {
    /// Empty observation buffer for [`observe_into`] (capacity reserved,
    /// so the first fill is the only allocation).
    pub fn empty(view_size: usize) -> Obs {
        Obs { v: view_size, cells: Vec::with_capacity(view_size * view_size) }
    }

    pub fn get(&self, r: usize, c: usize) -> Cell {
        self.cells[r * self.v + c]
    }

    /// Flatten to the PJRT boundary layout `i32[V, V, 2]`.
    pub fn to_flat(&self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.v * self.v * 2);
        for cell in &self.cells {
            out.push(cell.tile);
            out.push(cell.color);
        }
        out
    }

    /// [`Obs::to_flat`] into a caller-owned slice (`out.len()` must be
    /// `cells.len() * 2`). The batch engines no longer pass through
    /// here — they render with [`observe_flat_into`] — but the scalar
    /// [`TimeStep`](super::api::TimeStep) surface still flattens its
    /// `Obs`.
    pub fn write_flat_into(&self, out: &mut [i32]) {
        assert_eq!(out.len(), self.cells.len() * 2,
                   "flat obs buffer size");
        for (j, cell) in self.cells.iter().enumerate() {
            out[2 * j] = cell.tile;
            out[2 * j + 1] = cell.color;
        }
    }

    pub fn from_flat(v: usize, flat: &[i32]) -> Self {
        assert_eq!(flat.len(), v * v * 2);
        Obs {
            v,
            cells: flat.chunks_exact(2).map(|p| Cell::new(p[0], p[1]))
                .collect(),
        }
    }
}

/// Reusable per-engine scratch for the observe kernels: caches the
/// `[4, V, V]` gather-offset table for the engine's view size, so the
/// steady-state kernels do table lookups only — no per-cell direction
/// branches, no allocation. Occlusion state is a `u64` on the stack and
/// needs no scratch at all.
#[derive(Default)]
pub struct ObsScratch {
    /// flat `[dir][vr][vc] -> (dr, dc)` table, `4 * gather_v²` entries
    gather: Vec<(i32, i32)>,
    /// view size the table was built for (0 = not built yet)
    gather_v: usize,
}

impl ObsScratch {
    pub fn new() -> ObsScratch {
        ObsScratch::default()
    }

    /// Build the gather table for `v` if the cache holds a different
    /// view size (engines have one fixed view size, so this runs once).
    fn ensure_gather(&mut self, v: usize) {
        if self.gather_v == v {
            return;
        }
        self.gather.clear();
        self.gather.reserve(4 * v * v);
        for dir in 0..4i32 {
            for vr in 0..v as i32 {
                for vc in 0..v as i32 {
                    self.gather
                        .push(reference::gather_offset(dir, v as i32, vr,
                                                       vc));
                }
            }
        }
        self.gather_v = v;
    }
}

/// Visibility mask over an `n`×`n` window as a `u64` bitset (bit `r*n +
/// c`; requires `n*n <= 64`). Light starts at the agent cell `(n-1,
/// n/2)` and each round propagates from every visible-and-transparent
/// cell to its four orthogonal neighbors — the same monotone operator
/// as [`reference::flood_fill_vis`], so the fixed points are identical
/// — but one round is four shift-OR word ops instead of an O(n²) sweep,
/// and the fixed point arrives in at most `2n - 1` rounds (the longest
/// shortest path in the window).
pub fn visibility_mask(transparent: u64, n: usize) -> u64 {
    debug_assert!(n >= 1 && n * n <= 64, "bitmask occlusion needs V*V <= 64");
    let cells = n * n;
    let full: u64 = if cells == 64 { u64::MAX } else { (1u64 << cells) - 1 };
    // column-edge masks keep lateral shifts from wrapping across rows
    let mut col0: u64 = 0;
    for r in 0..n {
        col0 |= 1u64 << (r * n);
    }
    let coln = col0 << (n - 1);
    let mut vis: u64 = 1u64 << ((n - 1) * n + n / 2);
    loop {
        let f = vis & transparent;
        let grown = (vis
            | (f >> n)                  // up in the view window
            | (f << n)                  // down
            | ((f & !col0) >> 1)        // left
            | ((f & !coln) << 1))       // right
            & full;
        if grown == vis {
            return vis;
        }
        vis = grown;
    }
}

/// [`observe`] writing into caller-owned buffers: `out.cells` is cleared
/// and refilled (capacity reused). Generic over [`CellGrid`] so the
/// scalar oracle and the SoA engine of `env::vector` share the kernel.
/// Gather offsets come from the `scratch`-cached table; occlusion is the
/// bitmask fixed point of [`visibility_mask`] (views larger than 8×8
/// fall back to the reference flood fill — no engine configures one).
pub fn observe_into<G: CellGrid>(grid: &G, agent_pos: (i32, i32),
                                 agent_dir: i32, view_size: usize,
                                 see_through_walls: bool, out: &mut Obs,
                                 scratch: &mut ObsScratch) {
    let n = view_size * view_size;
    if !see_through_walls && n > 64 {
        // cold fallback outside the bitmask domain (allocates)
        reference::observe_into(grid, agent_pos, agent_dir, view_size,
                                false, out, &mut Vec::new(),
                                &mut Vec::new());
        return;
    }
    out.v = view_size;
    out.cells.clear();
    scratch.ensure_gather(view_size);
    // same arm selection as the reference `match`: 0/1/2 exact, every
    // other value (engines only ever produce 0..4) takes the last arm
    let d = if (0..3).contains(&agent_dir) { agent_dir as usize } else { 3 };
    let offs = &scratch.gather[d * n..(d + 1) * n];
    let (pr, pc) = agent_pos;
    if see_through_walls {
        for &(dr, dc) in offs {
            out.cells.push(grid.get_i(pr + dr, pc + dc));
        }
        return;
    }
    let mut transparent = 0u64;
    for (j, &(dr, dc)) in offs.iter().enumerate() {
        let cell = grid.get_i(pr + dr, pc + dc);
        transparent |= u64::from(!blocks_sight(cell.tile)) << j;
        out.cells.push(cell);
    }
    let full: u64 = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
    let mut hidden = !visibility_mask(transparent, view_size) & full;
    while hidden != 0 {
        out.cells[hidden.trailing_zeros() as usize] = UNSEEN_CELL;
        hidden &= hidden - 1;
    }
}

/// [`observe_into`] rendering straight into a caller-owned `[V, V, 2]`
/// i32 slice — the batch engines' single-pass path (no intermediate
/// `Obs` fill, no flatten second pass). Bitwise-identical values to
/// [`observe_into`] + [`Obs::write_flat_into`], pinned by
/// `tests/obs_kernels.rs`.
pub fn observe_flat_into<G: CellGrid>(grid: &G, agent_pos: (i32, i32),
                                      agent_dir: i32, view_size: usize,
                                      see_through_walls: bool,
                                      out: &mut [i32],
                                      scratch: &mut ObsScratch) {
    let n = view_size * view_size;
    assert_eq!(out.len(), n * 2, "flat obs slice size");
    if !see_through_walls && n > 64 {
        // cold fallback outside the bitmask domain (allocates)
        let mut obs = Obs::empty(view_size);
        reference::observe_into(grid, agent_pos, agent_dir, view_size,
                                false, &mut obs, &mut Vec::new(),
                                &mut Vec::new());
        obs.write_flat_into(out);
        return;
    }
    scratch.ensure_gather(view_size);
    // same arm selection as the reference `match`: 0/1/2 exact, every
    // other value (engines only ever produce 0..4) takes the last arm
    let d = if (0..3).contains(&agent_dir) { agent_dir as usize } else { 3 };
    let offs = &scratch.gather[d * n..(d + 1) * n];
    let (pr, pc) = agent_pos;
    if see_through_walls {
        for (j, &(dr, dc)) in offs.iter().enumerate() {
            let cell = grid.get_i(pr + dr, pc + dc);
            out[2 * j] = cell.tile;
            out[2 * j + 1] = cell.color;
        }
        return;
    }
    let mut transparent = 0u64;
    for (j, &(dr, dc)) in offs.iter().enumerate() {
        let cell = grid.get_i(pr + dr, pc + dc);
        transparent |= u64::from(!blocks_sight(cell.tile)) << j;
        out[2 * j] = cell.tile;
        out[2 * j + 1] = cell.color;
    }
    let full: u64 = if n == 64 { u64::MAX } else { (1u64 << n) - 1 };
    let mut hidden = !visibility_mask(transparent, view_size) & full;
    while hidden != 0 {
        let j = hidden.trailing_zeros() as usize;
        out[2 * j] = TILE_UNSEEN;
        out[2 * j + 1] = COLOR_UNSEEN;
        hidden &= hidden - 1;
    }
}

pub fn observe(grid: &Grid, agent_pos: (i32, i32), agent_dir: i32,
               view_size: usize, see_through_walls: bool) -> Obs {
    let mut obs = Obs::empty(view_size);
    observe_into(grid, agent_pos, agent_dir, view_size, see_through_walls,
                 &mut obs, &mut ObsScratch::new());
    obs
}

/// Pre-optimization observation kernels, kept verbatim as oracles: the
/// property suite (`tests/obs_kernels.rs`) pins the fast kernels above
/// to these bit for bit, and the fig5a bench's legacy-path section
/// measures them as the "before" of the zero-redundancy overhaul. Not
/// `#[cfg(test)]` for exactly that second reason — benches compile
/// without the test cfg.
pub mod reference {
    use crate::env::grid::CellGrid;
    use crate::env::types::*;

    use super::Obs;

    /// View-cell → world offset: the branchy per-cell form the gather
    /// tables are generated from (and checked against).
    pub fn gather_offset(agent_dir: i32, v: i32, vr: i32, vc: i32)
                         -> (i32, i32) {
        let fwd = (v - 1) - vr;
        let lat = vc - v / 2;
        match agent_dir {
            0 => (-fwd, lat),
            1 => (lat, fwd),
            2 => (fwd, -lat),
            _ => (-lat, -fwd),
        }
    }

    /// The original fixed-point visibility flood fill: full O(n²)
    /// sweeps until no cell changes. `vis` is cleared and refilled
    /// (reusable scratch, the pre-optimization calling convention).
    pub fn flood_fill_into(transparent: &[bool], n: usize,
                           vis: &mut Vec<bool>) {
        assert_eq!(transparent.len(), n * n);
        let idx = |r: usize, c: usize| r * n + c;
        vis.clear();
        vis.resize(n * n, false);
        vis[idx(n - 1, n / 2)] = true;
        // flood to fixed point (bounded by cell count)
        loop {
            let mut changed = false;
            for r in 0..n {
                for c in 0..n {
                    if vis[idx(r, c)] {
                        continue;
                    }
                    let mut lit = false;
                    if r > 0 {
                        lit |= vis[idx(r - 1, c)]
                            && transparent[idx(r - 1, c)];
                    }
                    if r + 1 < n {
                        lit |= vis[idx(r + 1, c)]
                            && transparent[idx(r + 1, c)];
                    }
                    if c > 0 {
                        lit |= vis[idx(r, c - 1)]
                            && transparent[idx(r, c - 1)];
                    }
                    if c + 1 < n {
                        lit |= vis[idx(r, c + 1)]
                            && transparent[idx(r, c + 1)];
                    }
                    if lit {
                        vis[idx(r, c)] = true;
                        changed = true;
                    }
                }
            }
            if !changed {
                return;
            }
        }
    }

    /// [`flood_fill_into`] returning a fresh `Vec` (test convenience).
    pub fn flood_fill_vis(transparent: &[bool], n: usize) -> Vec<bool> {
        let mut vis = Vec::new();
        flood_fill_into(transparent, n, &mut vis);
        vis
    }

    /// The pre-optimization `observe_into`: branchy per-cell gather,
    /// then the multi-sweep flood fill over `bool` scratch vectors.
    pub fn observe_into<G: CellGrid>(grid: &G, agent_pos: (i32, i32),
                                     agent_dir: i32, view_size: usize,
                                     see_through_walls: bool,
                                     out: &mut Obs,
                                     transparent: &mut Vec<bool>,
                                     vis: &mut Vec<bool>) {
        let v = view_size as i32;
        out.v = view_size;
        out.cells.clear();
        for vr in 0..v {
            for vc in 0..v {
                let (dr, dc) = gather_offset(agent_dir, v, vr, vc);
                out.cells
                    .push(grid.get_i(agent_pos.0 + dr, agent_pos.1 + dc));
            }
        }
        if !see_through_walls {
            transparent.clear();
            transparent
                .extend(out.cells.iter().map(|c| !blocks_sight(c.tile)));
            flood_fill_into(transparent, view_size, vis);
            for (i, cell) in out.cells.iter_mut().enumerate() {
                if !vis[i] {
                    *cell = UNSEEN_CELL;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ball_red() -> Cell {
        Cell::new(TILE_BALL, COLOR_RED)
    }

    #[test]
    fn agent_cell_is_bottom_center() {
        let mut g = Grid::empty_room(9, 9);
        g.set(4, 4, ball_red()); // agent's own cell shows grid content
        let obs = observe(&g, (4, 4), 0, 5, true);
        assert_eq!(obs.get(4, 2), ball_red());
    }

    #[test]
    fn facing_up_sees_forward() {
        let mut g = Grid::empty_room(9, 9);
        g.set(2, 4, ball_red()); // two cells above agent (4,4)
        let obs = observe(&g, (4, 4), 0, 5, true);
        // forward 2 => view row V-1-2 = 2, center col 2
        assert_eq!(obs.get(2, 2), ball_red());
    }

    #[test]
    fn rotation_consistency() {
        // the object straight ahead must appear at the same view cell for
        // every facing direction
        let mut g = Grid::empty_room(11, 11);
        let center = (5, 5);
        g.set(3, 5, ball_red()); // up
        g.set(5, 7, ball_red()); // right
        g.set(7, 5, ball_red()); // down
        g.set(5, 3, ball_red()); // left
        for dir in 0..4 {
            let obs = observe(&g, center, dir, 5, true);
            assert_eq!(obs.get(2, 2), ball_red(), "dir={dir}");
        }
    }

    #[test]
    fn lateral_orientation() {
        // object to the agent's RIGHT-hand side appears right of center
        let mut g = Grid::empty_room(11, 11);
        g.set(4, 6, ball_red()); // world-east of agent, one fwd one right
        let obs = observe(&g, (5, 5), 0, 5, true); // facing up
        assert_eq!(obs.get(3, 3), ball_red());
        // facing down, the same world cell is on the LEFT, one back —
        // outside the forward view
        let obs = observe(&g, (5, 5), 2, 5, true);
        assert_eq!(obs.get(3, 3), FLOOR_CELL);
    }

    #[test]
    fn out_of_map_cells() {
        let g = Grid::empty_room(9, 9);
        let obs = observe(&g, (1, 1), 0, 5, true); // near top-left corner
        assert_eq!(obs.get(0, 0), END_OF_MAP_CELL);
    }

    #[test]
    fn occlusion_hides_behind_walls() {
        let mut g = Grid::empty_room(11, 11);
        // wall row right in front of the agent
        for c in 0..11 {
            g.set(4, c, WALL_CELL);
        }
        g.set(2, 5, ball_red()); // behind the wall
        let seen = observe(&g, (5, 5), 0, 5, true);
        let occluded = observe(&g, (5, 5), 0, 5, false);
        assert_eq!(seen.get(1, 2), ball_red());
        assert_eq!(occluded.get(1, 2), UNSEEN_CELL);
        // the wall itself is visible
        assert_eq!(occluded.get(3, 2), WALL_CELL);
    }

    #[test]
    fn open_door_lets_light_through() {
        let mut g = Grid::empty_room(11, 11);
        for c in 0..11 {
            g.set(4, c, WALL_CELL);
        }
        g.set(4, 5, Cell::new(TILE_DOOR_OPEN, COLOR_BLUE));
        g.set(3, 5, ball_red());
        let obs = observe(&g, (5, 5), 0, 5, false);
        assert_eq!(obs.get(2, 2), ball_red());
    }

    #[test]
    fn closed_door_blocks_light() {
        let mut g = Grid::empty_room(11, 11);
        for c in 0..11 {
            g.set(4, c, WALL_CELL);
        }
        g.set(4, 5, Cell::new(TILE_DOOR_CLOSED, COLOR_BLUE));
        g.set(3, 5, ball_red());
        let obs = observe(&g, (5, 5), 0, 5, false);
        assert_eq!(obs.get(2, 2), UNSEEN_CELL);
    }

    #[test]
    fn flat_roundtrip() {
        let g = Grid::empty_room(9, 9);
        let obs = observe(&g, (4, 4), 1, 5, true);
        let flat = obs.to_flat();
        assert_eq!(Obs::from_flat(5, &flat), obs);
    }

    #[test]
    fn flat_kernel_matches_obs_kernel() {
        // the one-pass i32 path == the Obs path + flatten (the full
        // randomized sweep lives in tests/obs_kernels.rs)
        let mut g = Grid::empty_room(9, 9);
        g.set(3, 4, ball_red());
        for c in 0..9 {
            g.set(2, c, WALL_CELL);
        }
        let mut scratch = ObsScratch::new();
        for dir in 0..4 {
            for stw in [true, false] {
                let mut obs = Obs::empty(5);
                observe_into(&g, (4, 4), dir, 5, stw, &mut obs,
                             &mut scratch);
                let mut flat = vec![0i32; 5 * 5 * 2];
                observe_flat_into(&g, (4, 4), dir, 5, stw, &mut flat,
                                  &mut scratch);
                assert_eq!(flat, obs.to_flat(), "dir={dir} stw={stw}");
            }
        }
    }

    #[test]
    fn visibility_mask_basics() {
        // everything transparent: the whole window lights up
        let n = 5usize;
        let full = (1u64 << (n * n)) - 1;
        assert_eq!(visibility_mask(full, n), full);
        // nothing transparent: only the agent cell is visible
        let start = 1u64 << ((n - 1) * n + n / 2);
        assert_eq!(visibility_mask(0, n), start);
        // 8x8 uses all 64 bits without overflow
        assert_eq!(visibility_mask(u64::MAX, 8), u64::MAX);
    }
}
