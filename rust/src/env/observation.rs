//! Egocentric partial observations — Rust oracle for
//! `python/compile/xmg/observation.py`.
//!
//! V×V window, agent at bottom-center `(V-1, V/2)` facing up; cells are
//! `(tile, color)` symbol pairs; outside the grid reads END_OF_MAP; with
//! `see_through_walls == false`, a flood-fill visibility pass marks
//! occluded cells UNSEEN (identical fixed-point to the JAX version).

use super::grid::{CellGrid, Grid};
use super::types::*;

/// Observation: row-major V×V of cells.
#[derive(Clone, PartialEq, Eq, Debug)]
pub struct Obs {
    pub v: usize,
    pub cells: Vec<Cell>,
}

impl Obs {
    /// Empty observation buffer for [`observe_into`] (capacity reserved,
    /// so the first fill is the only allocation).
    pub fn empty(view_size: usize) -> Obs {
        Obs { v: view_size, cells: Vec::with_capacity(view_size * view_size) }
    }

    pub fn get(&self, r: usize, c: usize) -> Cell {
        self.cells[r * self.v + c]
    }

    /// Flatten to the PJRT boundary layout `i32[V, V, 2]`.
    pub fn to_flat(&self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.v * self.v * 2);
        for cell in &self.cells {
            out.push(cell.tile);
            out.push(cell.color);
        }
        out
    }

    /// [`Obs::to_flat`] into a caller-owned slice — the allocation-free
    /// form the batch engines and the unified-API surfaces share
    /// (`out.len()` must be `cells.len() * 2`).
    pub fn write_flat_into(&self, out: &mut [i32]) {
        assert_eq!(out.len(), self.cells.len() * 2,
                   "flat obs buffer size");
        for (j, cell) in self.cells.iter().enumerate() {
            out[2 * j] = cell.tile;
            out[2 * j + 1] = cell.color;
        }
    }

    pub fn from_flat(v: usize, flat: &[i32]) -> Self {
        assert_eq!(flat.len(), v * v * 2);
        Obs {
            v,
            cells: flat.chunks_exact(2).map(|p| Cell::new(p[0], p[1]))
                .collect(),
        }
    }
}

/// Reusable occlusion scratch for [`observe_into`]: after warm-up, the
/// flood-fill runs without touching the allocator.
#[derive(Default)]
pub struct ObsScratch {
    transparent: Vec<bool>,
    vis: Vec<bool>,
}

impl ObsScratch {
    pub fn new() -> ObsScratch {
        ObsScratch::default()
    }
}

/// [`observe`] writing into caller-owned buffers: `out.cells` is cleared
/// and refilled (capacity reused), occlusion state lives in `scratch`.
/// Generic over [`CellGrid`] so the scalar oracle and the SoA engine of
/// `env::vector` share the kernel.
pub fn observe_into<G: CellGrid>(grid: &G, agent_pos: (i32, i32),
                                 agent_dir: i32, view_size: usize,
                                 see_through_walls: bool, out: &mut Obs,
                                 scratch: &mut ObsScratch) {
    let v = view_size as i32;
    out.v = view_size;
    out.cells.clear();
    for vr in 0..v {
        for vc in 0..v {
            let fwd = (v - 1) - vr;
            let lat = vc - v / 2;
            let (dr, dc) = match agent_dir {
                0 => (-fwd, lat),
                1 => (lat, fwd),
                2 => (fwd, -lat),
                _ => (-lat, -fwd),
            };
            out.cells.push(grid.get_i(agent_pos.0 + dr, agent_pos.1 + dc));
        }
    }

    if !see_through_walls {
        let n = view_size;
        let idx = |r: usize, c: usize| r * n + c;
        scratch.transparent.clear();
        scratch
            .transparent
            .extend(out.cells.iter().map(|c| !blocks_sight(c.tile)));
        scratch.vis.clear();
        scratch.vis.resize(n * n, false);
        scratch.vis[idx(n - 1, n / 2)] = true;
        // flood to fixed point (bounded by cell count)
        loop {
            let mut changed = false;
            for r in 0..n {
                for c in 0..n {
                    if scratch.vis[idx(r, c)] {
                        continue;
                    }
                    let vis = &scratch.vis;
                    let transparent = &scratch.transparent;
                    let mut lit = false;
                    if r > 0 {
                        lit |= vis[idx(r - 1, c)] && transparent[idx(r - 1, c)];
                    }
                    if r + 1 < n {
                        lit |= vis[idx(r + 1, c)] && transparent[idx(r + 1, c)];
                    }
                    if c > 0 {
                        lit |= vis[idx(r, c - 1)] && transparent[idx(r, c - 1)];
                    }
                    if c + 1 < n {
                        lit |= vis[idx(r, c + 1)] && transparent[idx(r, c + 1)];
                    }
                    if lit {
                        scratch.vis[idx(r, c)] = true;
                        changed = true;
                    }
                }
            }
            if !changed {
                break;
            }
        }
        for (i, cell) in out.cells.iter_mut().enumerate() {
            if !scratch.vis[i] {
                *cell = UNSEEN_CELL;
            }
        }
    }
}

pub fn observe(grid: &Grid, agent_pos: (i32, i32), agent_dir: i32,
               view_size: usize, see_through_walls: bool) -> Obs {
    let mut obs = Obs::empty(view_size);
    observe_into(grid, agent_pos, agent_dir, view_size, see_through_walls,
                 &mut obs, &mut ObsScratch::new());
    obs
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ball_red() -> Cell {
        Cell::new(TILE_BALL, COLOR_RED)
    }

    #[test]
    fn agent_cell_is_bottom_center() {
        let mut g = Grid::empty_room(9, 9);
        g.set(4, 4, ball_red()); // agent's own cell shows grid content
        let obs = observe(&g, (4, 4), 0, 5, true);
        assert_eq!(obs.get(4, 2), ball_red());
    }

    #[test]
    fn facing_up_sees_forward() {
        let mut g = Grid::empty_room(9, 9);
        g.set(2, 4, ball_red()); // two cells above agent (4,4)
        let obs = observe(&g, (4, 4), 0, 5, true);
        // forward 2 => view row V-1-2 = 2, center col 2
        assert_eq!(obs.get(2, 2), ball_red());
    }

    #[test]
    fn rotation_consistency() {
        // the object straight ahead must appear at the same view cell for
        // every facing direction
        let mut g = Grid::empty_room(11, 11);
        let center = (5, 5);
        g.set(3, 5, ball_red()); // up
        g.set(5, 7, ball_red()); // right
        g.set(7, 5, ball_red()); // down
        g.set(5, 3, ball_red()); // left
        for dir in 0..4 {
            let obs = observe(&g, center, dir, 5, true);
            assert_eq!(obs.get(2, 2), ball_red(), "dir={dir}");
        }
    }

    #[test]
    fn lateral_orientation() {
        // object to the agent's RIGHT-hand side appears right of center
        let mut g = Grid::empty_room(11, 11);
        g.set(4, 6, ball_red()); // world-east of agent, one fwd one right
        let obs = observe(&g, (5, 5), 0, 5, true); // facing up
        assert_eq!(obs.get(3, 3), ball_red());
        // facing down, the same world cell is on the LEFT, one back —
        // outside the forward view
        let obs = observe(&g, (5, 5), 2, 5, true);
        assert_eq!(obs.get(3, 3), FLOOR_CELL);
    }

    #[test]
    fn out_of_map_cells() {
        let g = Grid::empty_room(9, 9);
        let obs = observe(&g, (1, 1), 0, 5, true); // near top-left corner
        assert_eq!(obs.get(0, 0), END_OF_MAP_CELL);
    }

    #[test]
    fn occlusion_hides_behind_walls() {
        let mut g = Grid::empty_room(11, 11);
        // wall row right in front of the agent
        for c in 0..11 {
            g.set(4, c, WALL_CELL);
        }
        g.set(2, 5, ball_red()); // behind the wall
        let seen = observe(&g, (5, 5), 0, 5, true);
        let occluded = observe(&g, (5, 5), 0, 5, false);
        assert_eq!(seen.get(1, 2), ball_red());
        assert_eq!(occluded.get(1, 2), UNSEEN_CELL);
        // the wall itself is visible
        assert_eq!(occluded.get(3, 2), WALL_CELL);
    }

    #[test]
    fn open_door_lets_light_through() {
        let mut g = Grid::empty_room(11, 11);
        for c in 0..11 {
            g.set(4, c, WALL_CELL);
        }
        g.set(4, 5, Cell::new(TILE_DOOR_OPEN, COLOR_BLUE));
        g.set(3, 5, ball_red());
        let obs = observe(&g, (5, 5), 0, 5, false);
        assert_eq!(obs.get(2, 2), ball_red());
    }

    #[test]
    fn closed_door_blocks_light() {
        let mut g = Grid::empty_room(11, 11);
        for c in 0..11 {
            g.set(4, c, WALL_CELL);
        }
        g.set(4, 5, Cell::new(TILE_DOOR_CLOSED, COLOR_BLUE));
        g.set(3, 5, ball_red());
        let obs = observe(&g, (5, 5), 0, 5, false);
        assert_eq!(obs.get(2, 2), UNSEEN_CELL);
    }

    #[test]
    fn flat_roundtrip() {
        let g = Grid::empty_room(9, 9);
        let obs = observe(&g, (4, 4), 1, 5, true);
        let flat = obs.to_flat();
        assert_eq!(Obs::from_flat(5, &flat), obs);
    }
}
