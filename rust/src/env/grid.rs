//! Row-major grid of (tile, color) cells; mirrors the JAX `i32[H, W, 2]`
//! representation bit-for-bit via `to_flat`/`from_flat` (the PJRT boundary
//! format used by the cross-validation tests).

use super::types::{Cell, END_OF_MAP_CELL, FLOOR_CELL, TILE_FLOOR, WALL_CELL};

/// Cell-level grid access shared by the owning [`Grid`] and the borrowed
/// SoA views of `env::vector`. The transition kernels (`rules`, `goals`,
/// `observation`, `state::apply_action`) are generic over this trait, so
/// the scalar oracle and the batched engine execute the *same* code —
/// their bitwise equivalence is a test-pinned contract, not a convention.
pub trait CellGrid {
    fn h(&self) -> usize;
    fn w(&self) -> usize;
    /// Signed-index read; END_OF_MAP outside the grid.
    fn get_i(&self, r: i32, c: i32) -> Cell;
    /// Signed-index write; out-of-bounds writes are ignored.
    fn set_i(&mut self, r: i32, c: i32, cell: Cell);

    #[inline]
    fn in_bounds(&self, r: i32, c: i32) -> bool {
        r >= 0 && c >= 0 && (r as usize) < self.h() && (c as usize) < self.w()
    }
}

#[derive(Clone, PartialEq, Eq, Debug)]
pub struct Grid {
    pub h: usize,
    pub w: usize,
    cells: Vec<Cell>,
}

impl CellGrid for Grid {
    #[inline]
    fn h(&self) -> usize {
        self.h
    }

    #[inline]
    fn w(&self) -> usize {
        self.w
    }

    #[inline]
    fn get_i(&self, r: i32, c: i32) -> Cell {
        Grid::get_i(self, r, c)
    }

    #[inline]
    fn set_i(&mut self, r: i32, c: i32, cell: Cell) {
        Grid::set_i(self, r, c, cell)
    }
}

impl Grid {
    pub fn filled(h: usize, w: usize, cell: Cell) -> Self {
        Grid { h, w, cells: vec![cell; h * w] }
    }

    /// Single room: wall border, floor interior.
    pub fn empty_room(h: usize, w: usize) -> Self {
        let mut g = Grid::filled(h, w, FLOOR_CELL);
        for c in 0..w {
            g.set(0, c, WALL_CELL);
            g.set(h - 1, c, WALL_CELL);
        }
        for r in 0..h {
            g.set(r, 0, WALL_CELL);
            g.set(r, w - 1, WALL_CELL);
        }
        g
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> Cell {
        self.cells[r * self.w + c]
    }

    /// Signed-index read; END_OF_MAP outside the grid.
    #[inline]
    pub fn get_i(&self, r: i32, c: i32) -> Cell {
        if r < 0 || c < 0 || r >= self.h as i32 || c >= self.w as i32 {
            END_OF_MAP_CELL
        } else {
            self.get(r as usize, c as usize)
        }
    }

    #[inline]
    pub fn in_bounds(&self, r: i32, c: i32) -> bool {
        r >= 0 && c >= 0 && r < self.h as i32 && c < self.w as i32
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, cell: Cell) {
        self.cells[r * self.w + c] = cell;
    }

    #[inline]
    pub fn set_i(&mut self, r: i32, c: i32, cell: Cell) {
        if self.in_bounds(r, c) {
            self.set(r as usize, c as usize, cell);
        }
    }

    /// Row-major cell storage (the `[H, W, 2]` tensor as `Cell` pairs) —
    /// the memcpy source for `env::vector`'s batched SoA buffers.
    pub fn cells(&self) -> &[Cell] {
        &self.cells
    }

    /// Row-major indices of floor cells (candidate object/agent
    /// positions). The scalar reset path scans here; the SoA engines
    /// cache the same row-major list per env at reset time
    /// (`VecEnv::free_base`) so trial placements never rescan — both
    /// orders are identical, which keeps the placement RNG draws
    /// bitwise-parallel across surfaces.
    pub fn free_cells(&self) -> Vec<usize> {
        self.cells
            .iter()
            .enumerate()
            .filter(|(_, c)| c.tile == TILE_FLOOR)
            .map(|(i, _)| i)
            .collect()
    }

    pub fn count_tile(&self, tile: i32) -> usize {
        self.cells.iter().filter(|c| c.tile == tile).count()
    }

    /// Flatten to the PJRT boundary layout `i32[H, W, 2]` (row-major,
    /// innermost = [tile, color]).
    pub fn to_flat(&self) -> Vec<i32> {
        let mut out = Vec::with_capacity(self.h * self.w * 2);
        for cell in &self.cells {
            out.push(cell.tile);
            out.push(cell.color);
        }
        out
    }

    pub fn from_flat(h: usize, w: usize, flat: &[i32]) -> Self {
        assert_eq!(flat.len(), h * w * 2, "flat grid size mismatch");
        let cells = flat
            .chunks_exact(2)
            .map(|p| Cell::new(p[0], p[1]))
            .collect();
        Grid { h, w, cells }
    }

    pub fn iter_cells(&self) -> impl Iterator<Item = (usize, usize, Cell)> + '_ {
        let w = self.w;
        self.cells
            .iter()
            .enumerate()
            .map(move |(i, &c)| (i / w, i % w, c))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::types::*;

    #[test]
    fn empty_room_structure() {
        let g = Grid::empty_room(5, 7);
        assert_eq!(g.get(0, 0).tile, TILE_WALL);
        assert_eq!(g.get(4, 6).tile, TILE_WALL);
        assert_eq!(g.get(2, 3).tile, TILE_FLOOR);
        assert_eq!(g.count_tile(TILE_WALL), 2 * 7 + 2 * 3);
        assert_eq!(g.free_cells().len(), 3 * 5);
    }

    #[test]
    fn out_of_bounds_reads_end_of_map() {
        let g = Grid::empty_room(4, 4);
        assert_eq!(g.get_i(-1, 0), END_OF_MAP_CELL);
        assert_eq!(g.get_i(0, 4), END_OF_MAP_CELL);
        assert_eq!(g.get_i(1, 1).tile, TILE_FLOOR);
    }

    #[test]
    fn flat_roundtrip() {
        let mut g = Grid::empty_room(4, 5);
        g.set(2, 2, Cell::new(TILE_BALL, COLOR_RED));
        let flat = g.to_flat();
        assert_eq!(flat.len(), 4 * 5 * 2);
        let g2 = Grid::from_flat(4, 5, &flat);
        assert_eq!(g, g2);
    }

    #[test]
    fn free_cells_row_major() {
        let g = Grid::empty_room(3, 3);
        assert_eq!(g.free_cells(), vec![4]); // only the center
    }
}
