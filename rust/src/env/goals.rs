//! Goals (paper Table 2) — pure condition checks, the Rust oracle for
//! `python/compile/xmg/goals.py`.

use super::grid::CellGrid;
use super::types::*;

/// Encoded goal `[id, a0, a1, a2, a3]`.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub struct Goal(pub [i32; GOAL_ENC]);

impl Goal {
    pub const EMPTY: Goal = Goal([0; GOAL_ENC]);

    pub fn id(&self) -> i32 {
        self.0[0]
    }

    pub fn agent_hold(a: Cell) -> Goal {
        Goal([GOAL_AGENT_HOLD, a.tile, a.color, 0, 0])
    }
    pub fn agent_on_tile(a: Cell) -> Goal {
        Goal([GOAL_AGENT_ON_TILE, a.tile, a.color, 0, 0])
    }
    pub fn agent_near(a: Cell) -> Goal {
        Goal([GOAL_AGENT_NEAR, a.tile, a.color, 0, 0])
    }
    pub fn tile_near(a: Cell, b: Cell) -> Goal {
        Goal([GOAL_TILE_NEAR, a.tile, a.color, b.tile, b.color])
    }
    pub fn agent_on_position(r: i32, c: i32) -> Goal {
        Goal([GOAL_AGENT_ON_POSITION, r, c, 0, 0])
    }
    pub fn tile_on_position(a: Cell, r: i32, c: i32) -> Goal {
        Goal([GOAL_TILE_ON_POSITION, a.tile, a.color, r, c])
    }
    pub fn tile_near_dir(dir: usize, a: Cell, b: Cell) -> Goal {
        Goal([GOAL_TILE_NEAR_UP + dir as i32, a.tile, a.color, b.tile,
              b.color])
    }
    pub fn agent_near_dir(dir: usize, a: Cell) -> Goal {
        Goal([GOAL_AGENT_NEAR_UP + dir as i32, a.tile, a.color, 0, 0])
    }

    /// Objects the goal requires on the grid / in pocket (generator input).
    pub fn required_objects(&self) -> Vec<Cell> {
        let a = Cell::new(self.0[1], self.0[2]);
        let b = Cell::new(self.0[3], self.0[4]);
        match self.id() {
            GOAL_EMPTY | GOAL_AGENT_ON_POSITION => vec![],
            GOAL_TILE_NEAR | GOAL_TILE_NEAR_UP | GOAL_TILE_NEAR_RIGHT
            | GOAL_TILE_NEAR_DOWN | GOAL_TILE_NEAR_LEFT => vec![a, b],
            _ => vec![a],
        }
    }
}

fn agent_near_any<G: CellGrid>(grid: &G, agent_pos: (i32, i32), a: Cell,
                               dirs: &[usize]) -> bool {
    dirs.iter().any(|&d| {
        let r = agent_pos.0 + DIR_DR[d];
        let c = agent_pos.1 + DIR_DC[d];
        grid.in_bounds(r, c) && grid.get_i(r, c) == a
    })
}

fn tile_near_any<G: CellGrid>(grid: &G, a: Cell, b: Cell,
                              dirs: &[usize]) -> bool {
    for r in 0..grid.h() as i32 {
        for c in 0..grid.w() as i32 {
            if grid.get_i(r, c) != a {
                continue;
            }
            for &d in dirs {
                if grid.get_i(r + DIR_DR[d], c + DIR_DC[d]) == b {
                    return true;
                }
            }
        }
    }
    false
}

const ALL_DIRS: [usize; 4] = [DIR_UP, DIR_RIGHT, DIR_DOWN, DIR_LEFT];

/// Evaluate an encoded goal. Generic over [`CellGrid`] so the scalar
/// oracle and the SoA engine of `env::vector` run the identical kernel.
pub fn check_goal<G: CellGrid>(grid: &G, agent_pos: (i32, i32), pocket: Cell,
                               goal: &Goal) -> bool {
    let a = Cell::new(goal.0[1], goal.0[2]);
    let b = Cell::new(goal.0[3], goal.0[4]);
    match goal.id() {
        GOAL_EMPTY => false,
        GOAL_AGENT_HOLD => pocket == a,
        GOAL_AGENT_ON_TILE => grid.get_i(agent_pos.0, agent_pos.1) == a,
        GOAL_AGENT_NEAR => agent_near_any(grid, agent_pos, a, &ALL_DIRS),
        GOAL_TILE_NEAR => tile_near_any(grid, a, b, &ALL_DIRS),
        GOAL_AGENT_ON_POSITION => {
            agent_pos.0 == goal.0[1] && agent_pos.1 == goal.0[2]
        }
        GOAL_TILE_ON_POSITION => grid.get_i(goal.0[3], goal.0[4]) == a,
        GOAL_TILE_NEAR_UP => tile_near_any(grid, a, b, &[DIR_UP]),
        GOAL_TILE_NEAR_RIGHT => tile_near_any(grid, a, b, &[DIR_RIGHT]),
        GOAL_TILE_NEAR_DOWN => tile_near_any(grid, a, b, &[DIR_DOWN]),
        GOAL_TILE_NEAR_LEFT => tile_near_any(grid, a, b, &[DIR_LEFT]),
        GOAL_AGENT_NEAR_UP => {
            agent_near_any(grid, agent_pos, a, &[DIR_UP])
        }
        GOAL_AGENT_NEAR_RIGHT => {
            agent_near_any(grid, agent_pos, a, &[DIR_RIGHT])
        }
        GOAL_AGENT_NEAR_DOWN => {
            agent_near_any(grid, agent_pos, a, &[DIR_DOWN])
        }
        GOAL_AGENT_NEAR_LEFT => {
            agent_near_any(grid, agent_pos, a, &[DIR_LEFT])
        }
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::grid::Grid;

    fn ball_red() -> Cell {
        Cell::new(TILE_BALL, COLOR_RED)
    }
    fn sq_blue() -> Cell {
        Cell::new(TILE_SQUARE, COLOR_BLUE)
    }

    #[test]
    fn empty_goal_always_false() {
        let g = Grid::empty_room(5, 5);
        assert!(!check_goal(&g, (2, 2), POCKET_EMPTY, &Goal::EMPTY));
    }

    #[test]
    fn agent_hold_goal() {
        let g = Grid::empty_room(5, 5);
        let goal = Goal::agent_hold(ball_red());
        assert!(check_goal(&g, (2, 2), ball_red(), &goal));
        assert!(!check_goal(&g, (2, 2), sq_blue(), &goal));
        assert!(!check_goal(&g, (2, 2), POCKET_EMPTY, &goal));
    }

    #[test]
    fn agent_on_tile_goal() {
        let mut g = Grid::empty_room(5, 5);
        g.set(2, 2, Cell::new(TILE_GOAL, COLOR_GREEN));
        let goal = Goal::agent_on_tile(Cell::new(TILE_GOAL, COLOR_GREEN));
        assert!(check_goal(&g, (2, 2), POCKET_EMPTY, &goal));
        assert!(!check_goal(&g, (1, 2), POCKET_EMPTY, &goal));
    }

    #[test]
    fn agent_near_goal_all_directions() {
        let mut g = Grid::empty_room(5, 5);
        g.set(3, 2, ball_red()); // below agent (2,2)
        let goal = Goal::agent_near(ball_red());
        assert!(check_goal(&g, (2, 2), POCKET_EMPTY, &goal));
        assert!(!check_goal(&g, (1, 1), POCKET_EMPTY, &goal));
    }

    #[test]
    fn tile_near_goal() {
        let mut g = Grid::empty_room(6, 6);
        g.set(2, 2, ball_red());
        g.set(2, 3, sq_blue());
        assert!(check_goal(&g, (4, 4), POCKET_EMPTY,
                           &Goal::tile_near(ball_red(), sq_blue())));
        // symmetric: also true with operands swapped
        assert!(check_goal(&g, (4, 4), POCKET_EMPTY,
                           &Goal::tile_near(sq_blue(), ball_red())));
    }

    #[test]
    fn tile_near_directional_goals() {
        let mut g = Grid::empty_room(6, 6);
        g.set(3, 2, ball_red());
        g.set(2, 2, sq_blue()); // b above a
        let up = Goal::tile_near_dir(DIR_UP, ball_red(), sq_blue());
        let down = Goal::tile_near_dir(DIR_DOWN, ball_red(), sq_blue());
        assert!(check_goal(&g, (5, 5), POCKET_EMPTY, &up));
        assert!(!check_goal(&g, (5, 5), POCKET_EMPTY, &down));
    }

    #[test]
    fn position_goals() {
        let mut g = Grid::empty_room(6, 6);
        assert!(check_goal(&g, (3, 4), POCKET_EMPTY,
                           &Goal::agent_on_position(3, 4)));
        assert!(!check_goal(&g, (4, 3), POCKET_EMPTY,
                            &Goal::agent_on_position(3, 4)));
        g.set(1, 2, ball_red());
        assert!(check_goal(&g, (3, 3), POCKET_EMPTY,
                           &Goal::tile_on_position(ball_red(), 1, 2)));
        assert!(!check_goal(&g, (3, 3), POCKET_EMPTY,
                            &Goal::tile_on_position(ball_red(), 2, 1)));
    }

    #[test]
    fn agent_near_directional_goals() {
        let mut g = Grid::empty_room(5, 5);
        g.set(2, 3, ball_red()); // right of agent (2,2)
        assert!(check_goal(&g, (2, 2), POCKET_EMPTY,
                           &Goal::agent_near_dir(DIR_RIGHT, ball_red())));
        assert!(!check_goal(&g, (2, 2), POCKET_EMPTY,
                            &Goal::agent_near_dir(DIR_LEFT, ball_red())));
    }

    #[test]
    fn color_must_match() {
        let mut g = Grid::empty_room(5, 5);
        g.set(2, 3, Cell::new(TILE_BALL, COLOR_GREEN));
        let goal = Goal::agent_near(ball_red());
        assert!(!check_goal(&g, (2, 2), POCKET_EMPTY, &goal));
    }

    #[test]
    fn required_objects_arity() {
        assert_eq!(Goal::EMPTY.required_objects().len(), 0);
        assert_eq!(Goal::agent_hold(ball_red()).required_objects().len(), 1);
        assert_eq!(
            Goal::tile_near(ball_red(), sq_blue())
                .required_objects()
                .len(),
            2
        );
    }
}
