//! Pure-Rust XLand-MiniGrid engine: the cross-validation oracle for the
//! AOT-lowered JAX environment, the CPU-loop baseline for the throughput
//! benches (the comparison every hardware-accelerated-env paper makes
//! against EnvPool-style stepping), and — via [`vector`] — the native
//! vectorized backend: SoA batch kernels stepping B envs per call with
//! no AOT artifacts, sharing the exact transition code with the scalar
//! oracle through the [`grid::CellGrid`] trait.

pub mod api;
pub mod goals;
pub mod grid;
pub mod layouts;
pub mod observation;
pub mod registry;
pub mod rules;
pub mod state;
pub mod types;
pub mod vector;

pub use api::{ActionSpec, AutoReset, BatchEnvironment, DirectionObs,
              EnvParams, Environment, ObsMode, ObsSegment, ObsSpec,
              RgbImageObs, RolloutBufs, RulesAndGoalsObs, ScalarEnv,
              SingleEnv, StepType, TimeStep};
pub use goals::Goal;
pub use grid::{CellGrid, Grid};
pub use observation::{Obs, ObsScratch};
pub use rules::Rule;
pub use state::{default_max_steps, reset, step, step_with,
                step_with_tasks, EnvOptions, Ruleset, State, StepInfo,
                StepOutput, TaskSource};
pub use types::{Cell, PackedCell};
pub use vector::{VecEnv, VecEnvConfig, VecEnvSnapshot};
