//! Pure-Rust XLand-MiniGrid engine: the cross-validation oracle for the
//! AOT-lowered JAX environment and the CPU-loop baseline for the throughput
//! benches (the comparison every hardware-accelerated-env paper makes
//! against EnvPool-style stepping).

pub mod goals;
pub mod grid;
pub mod layouts;
pub mod observation;
pub mod registry;
pub mod rules;
pub mod state;
pub mod types;

pub use goals::Goal;
pub use grid::Grid;
pub use observation::Obs;
pub use rules::Rule;
pub use state::{default_max_steps, reset, step, EnvOptions, Ruleset, State,
                StepOutput};
pub use types::Cell;
