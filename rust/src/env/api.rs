//! Unified TimeStep environment API: the single protocol every stepping
//! surface of the reproduction speaks (the gymnax/Jumanji-style seam the
//! paper's own interface is built on).
//!
//! The pieces, bottom-up:
//!
//! - [`EnvParams`] — the one shared description of an env family's shape
//!   (grid dims, fixed-width task-table capacities, view options).
//!   `env::vector::VecEnvConfig` is an alias of it and
//!   `coordinator::NativeEnvConfig` embeds it, so observation lengths and
//!   table capacities are derived in exactly one place.
//! - [`ObsSpec`] / [`ActionSpec`] — machine-readable I/O contracts. An
//!   observation is a flat per-env `i32` record made of named
//!   [`ObsSegment`]s; wrappers extend or transform the segment list and
//!   the spec always matches the bytes an engine actually writes.
//! - [`TimeStep`] / [`StepType`] — the dm_env-style scalar step record
//!   returned by the [`Environment`] trait.
//! - [`Environment`] (scalar) and [`BatchEnvironment`] (batched,
//!   allocation-free, observations written into caller buffers) — the
//!   traits all four stepping surfaces implement: the scalar oracle
//!   ([`ScalarEnv`]), the serial SoA engine (`env::vector::VecEnv`), the
//!   chunked parallel engine (`coordinator::ParVecEnv` /
//!   `coordinator::NativePool`) and the AOT/PJRT pool
//!   (`coordinator::EnvPool`).
//! - The wrapper stack — [`AutoReset`], [`DirectionObs`],
//!   [`RulesAndGoalsObs`], [`RgbImageObs`] — composable over any
//!   `BatchEnvironment`; [`ObsMode`] maps the CLI `--obs` flag onto a
//!   stack.
//! - [`rollout_batch`] — the backend-generic random-policy rollout
//!   driver used by wrapped engine replicas and the fig13 bench.
//!
//! Task distributions are first-class: scalar and batch envs alike carry
//! an optional [`TaskSource`] installed at construction, and every
//! *episode* reset draws a fresh task from it (§2.1 protocol).

use std::sync::Arc;

use anyhow::{ensure, Result};

use crate::util::rng::Rng;

use super::grid::Grid;
use super::observation::{observe_into, Obs, ObsScratch};
use super::state::{self, place_objects, EnvOptions, Ruleset, State,
                   TaskSource};
use super::types::{GOAL_ENC, NUM_ACTIONS, POCKET_EMPTY, RULE_ENC};

// ---------------------------------------------------------------------------
// Shared env params
// ---------------------------------------------------------------------------

/// Shape of one environment family: grid dims, fixed-width task-table
/// capacities and view options — the single source both `VecEnvConfig`
/// (an alias of this type) and `NativeEnvConfig` (which embeds it) are
/// derived from, replacing the former per-layer copies of `(H, W, MR,
/// MI)`.
#[derive(Clone, Copy, Debug)]
pub struct EnvParams {
    pub h: usize,
    pub w: usize,
    /// rule-table rows per env (zero rows are inert padding)
    pub max_rules: usize,
    /// init-tile rows per env
    pub max_init: usize,
    pub opts: EnvOptions,
}

impl EnvParams {
    /// Params for an `h`×`w` family with table capacities and default
    /// view options.
    pub fn new(h: usize, w: usize, max_rules: usize, max_init: usize)
               -> EnvParams {
        EnvParams {
            h,
            w,
            max_rules: max_rules.max(1),
            max_init: max_init.max(1),
            opts: EnvOptions::default(),
        }
    }

    /// The scalar-level view options (derived, not duplicated).
    pub fn options(&self) -> EnvOptions {
        self.opts
    }

    /// The family's raw (unwrapped) observation spec: one symbolic
    /// `[V, V, 2]` segment. Every obs-length in the crate funnels
    /// through here.
    pub fn obs_spec(&self) -> ObsSpec {
        ObsSpec::symbolic(self.opts.view_size)
    }

    /// Per-env symbolic observation length `V * V * 2`
    /// (= `self.obs_spec().len()`, allocation-free for hot asserts).
    pub fn obs_len(&self) -> usize {
        self.opts.view_size * self.opts.view_size * 2
    }

    pub fn action_spec(&self) -> ActionSpec {
        ActionSpec::default()
    }

    /// Per-env encoded-task row length: goal `[5]` + rules `[MR, 7]` —
    /// the layout of [`BatchEnvironment::task_rows_into`] and of the
    /// [`RulesAndGoalsObs`] observation segment.
    pub fn task_row_len(&self) -> usize {
        GOAL_ENC + self.max_rules * RULE_ENC
    }

    /// Assert every task in `tasks` fits this family's fixed-width
    /// tables. O(num_tasks) — run once per source, not per chunk.
    pub fn validate_task_source(&self, tasks: &dyn TaskSource) {
        let n = tasks.num_tasks();
        assert!(n > 0, "task source is empty");
        for id in 0..n {
            let t = tasks.task(id);
            assert!(t.rules.len() <= self.max_rules,
                    "task {id}: {} rules > capacity {}",
                    t.rules.len(), self.max_rules);
            assert!(t.init_tiles.len() <= self.max_init,
                    "task {id}: {} init objects > capacity {}",
                    t.init_tiles.len(), self.max_init);
        }
    }
}

// ---------------------------------------------------------------------------
// Specs
// ---------------------------------------------------------------------------

/// One named, shaped slice of a flat per-env observation record.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ObsSegment {
    pub name: &'static str,
    pub shape: Vec<usize>,
}

impl ObsSegment {
    pub fn new(name: &'static str, shape: &[usize]) -> ObsSegment {
        ObsSegment { name, shape: shape.to_vec() }
    }

    /// Flattened element count.
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Observation contract: the flat per-env `i32` record is the
/// concatenation of these segments, in order. Engines write exactly
/// `len()` values per env; wrappers rewrite the segment list alongside
/// the bytes, so the spec can never drift from the data.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ObsSpec {
    pub segments: Vec<ObsSegment>,
}

impl ObsSpec {
    /// The raw engine observation: egocentric symbolic `[V, V, 2]`.
    pub fn symbolic(view_size: usize) -> ObsSpec {
        ObsSpec {
            segments: vec![ObsSegment::new("symbolic",
                                           &[view_size, view_size, 2])],
        }
    }

    /// Per-env flattened length.
    pub fn len(&self) -> usize {
        self.segments.iter().map(|s| s.len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Append a segment (the `DirectionObs`/`RulesAndGoalsObs` shape).
    pub fn with_segment(mut self, seg: ObsSegment) -> ObsSpec {
        self.segments.push(seg);
        self
    }

    /// Replace the leading segment (the `RgbImageObs` shape).
    pub fn with_first_replaced(mut self, seg: ObsSegment) -> ObsSpec {
        assert!(!self.segments.is_empty(), "spec has no segments");
        self.segments[0] = seg;
        self
    }

    /// Machine-readable form for `xmgrid envs --json`.
    pub fn to_json(&self) -> String {
        let segs: Vec<String> = self
            .segments
            .iter()
            .map(|s| {
                let dims: Vec<String> =
                    s.shape.iter().map(|d| d.to_string()).collect();
                format!("{{\"name\":\"{}\",\"shape\":[{}]}}", s.name,
                        dims.join(","))
            })
            .collect();
        format!("{{\"segments\":[{}],\"len\":{}}}", segs.join(","),
                self.len())
    }
}

/// Discrete action contract (6 actions, paper §2.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ActionSpec {
    pub num_actions: usize,
}

impl Default for ActionSpec {
    fn default() -> Self {
        ActionSpec { num_actions: NUM_ACTIONS }
    }
}

impl ActionSpec {
    pub fn to_json(&self) -> String {
        format!("{{\"num_actions\":{}}}", self.num_actions)
    }
}

// ---------------------------------------------------------------------------
// TimeStep
// ---------------------------------------------------------------------------

/// Position of a transition within an episode (dm_env convention).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepType {
    /// Episode start (produced by `reset`).
    First,
    /// Ordinary transition.
    Mid,
    /// Episode boundary: the env auto-reset in place, so `obs` already
    /// belongs to the *next* episode (the standard batched auto-reset
    /// quirk; `reward`/`discount` belong to the finished episode).
    Last,
}

/// One scalar environment transition under the unified API.
#[derive(Clone, Debug)]
pub struct TimeStep {
    /// Flat per-env observation, laid out per the env's [`ObsSpec`].
    pub obs: Vec<i32>,
    pub reward: f32,
    /// `0.0` at an episode boundary, `1.0` otherwise.
    pub discount: f32,
    pub step_type: StepType,
    /// Trial boundary within the episode (meta-RL §2.1): goal achieved
    /// or episode end; objects were re-placed, the task kept unless the
    /// episode also ended.
    pub trial_done: bool,
}

impl TimeStep {
    pub fn is_first(&self) -> bool {
        self.step_type == StepType::First
    }

    pub fn is_last(&self) -> bool {
        self.step_type == StepType::Last
    }
}

// ---------------------------------------------------------------------------
// Traits
// ---------------------------------------------------------------------------

/// Scalar environment protocol: `reset`/`step` returning a [`TimeStep`],
/// with spec accessors and the auxiliary state the observation wrappers
/// need. [`ScalarEnv`] is the oracle implementation; [`SingleEnv`] lifts
/// any `Environment` into the batch API as a batch of one.
pub trait Environment {
    fn obs_spec(&self) -> ObsSpec;

    fn action_spec(&self) -> ActionSpec {
        ActionSpec::default()
    }

    /// Rule-table capacity of the encoded-task row
    /// (see [`EnvParams::task_row_len`]).
    fn max_rules(&self) -> usize;

    /// Start a fresh episode: draw a task from the installed
    /// [`TaskSource`] (if any), re-place objects, adopt `rng` as the
    /// env's stream. RNG discipline matches the batch engines' episode
    /// reset (`below(num_tasks)` on the stream, then a `split` for
    /// placement) so scalar and batched resets stay bitwise-parallel.
    fn reset(&mut self, rng: Rng) -> TimeStep;

    /// One transition with in-place trial/episode auto-reset.
    fn step(&mut self, action: i32) -> TimeStep;

    /// Agent facing direction (0..4) — [`DirectionObs`] input.
    fn agent_dir(&self) -> i32;

    /// Encoded current task: goal `[5]` then rules `[MR, 7]` —
    /// [`RulesAndGoalsObs`] input. `out.len()` must equal
    /// `GOAL_ENC + max_rules() * RULE_ENC`.
    fn task_rows_into(&self, out: &mut [i32]);
}

/// Batched environment protocol: B envs stepped per call,
/// allocation-free, observations written into a caller-provided flat
/// `i32` buffer of `batch() * obs_spec().len()` values (env-major).
///
/// Auto-reset semantics are the engines' own (trial reset keeps the
/// task, episode reset draws a fresh one from the constructor-installed
/// [`TaskSource`]); [`AutoReset`] makes the resulting step types and
/// discounts explicit.
pub trait BatchEnvironment {
    fn batch(&self) -> usize;

    fn obs_spec(&self) -> ObsSpec;

    fn action_spec(&self) -> ActionSpec {
        ActionSpec::default()
    }

    /// Total caller-buffer length: `batch() * obs_spec().len()`.
    fn obs_len(&self) -> usize {
        self.batch() * self.obs_spec().len()
    }

    /// Rule-table capacity of the per-env encoded-task rows.
    fn max_rules(&self) -> usize;

    /// Start fresh episodes in every slot (tasks drawn from the
    /// installed source, per-env streams split off `rng` in env order)
    /// and write the first observations into `obs_out`.
    fn reset(&mut self, rng: &mut Rng, obs_out: &mut [i32]) -> Result<()>;

    /// One batched transition; observations land in `obs_out`, per-env
    /// reward / episode-done / trial-done flags in the remaining
    /// buffers. Trial and episode auto-resets happen in place.
    fn step(&mut self, actions: &[i32], obs_out: &mut [i32],
            rewards: &mut [f32], dones: &mut [bool],
            trial_dones: &mut [bool]) -> Result<()>;

    /// Per-env agent facing direction (0..4), `out.len() == batch()`.
    fn agent_dirs_into(&self, out: &mut [i32]);

    /// Per-env encoded task rows (goal `[5]` + rules `[MR, 7]`,
    /// env-major); `out.len() == batch() * (GOAL_ENC + max_rules()*RULE_ENC)`.
    fn task_rows_into(&self, out: &mut [i32]);
}

/// Forwarding impl so heterogeneous engines behind `Box<dyn
/// BatchEnvironment>` plug into the generic wrappers.
impl<E: BatchEnvironment + ?Sized> BatchEnvironment for Box<E> {
    fn batch(&self) -> usize {
        (**self).batch()
    }

    fn obs_spec(&self) -> ObsSpec {
        (**self).obs_spec()
    }

    fn action_spec(&self) -> ActionSpec {
        (**self).action_spec()
    }

    fn max_rules(&self) -> usize {
        (**self).max_rules()
    }

    fn reset(&mut self, rng: &mut Rng, obs_out: &mut [i32]) -> Result<()> {
        (**self).reset(rng, obs_out)
    }

    fn step(&mut self, actions: &[i32], obs_out: &mut [i32],
            rewards: &mut [f32], dones: &mut [bool],
            trial_dones: &mut [bool]) -> Result<()> {
        (**self).step(actions, obs_out, rewards, dones, trial_dones)
    }

    fn agent_dirs_into(&self, out: &mut [i32]) {
        (**self).agent_dirs_into(out)
    }

    fn task_rows_into(&self, out: &mut [i32]) {
        (**self).task_rows_into(out)
    }
}

/// Forwarding impl so short-lived wrapper stacks can borrow an engine
/// (`DirectionObs::new(&mut venv)`) instead of consuming it.
impl<E: BatchEnvironment + ?Sized> BatchEnvironment for &mut E {
    fn batch(&self) -> usize {
        (**self).batch()
    }

    fn obs_spec(&self) -> ObsSpec {
        (**self).obs_spec()
    }

    fn action_spec(&self) -> ActionSpec {
        (**self).action_spec()
    }

    fn max_rules(&self) -> usize {
        (**self).max_rules()
    }

    fn reset(&mut self, rng: &mut Rng, obs_out: &mut [i32]) -> Result<()> {
        (**self).reset(rng, obs_out)
    }

    fn step(&mut self, actions: &[i32], obs_out: &mut [i32],
            rewards: &mut [f32], dones: &mut [bool],
            trial_dones: &mut [bool]) -> Result<()> {
        (**self).step(actions, obs_out, rewards, dones, trial_dones)
    }

    fn agent_dirs_into(&self, out: &mut [i32]) {
        (**self).agent_dirs_into(out)
    }

    fn task_rows_into(&self, out: &mut [i32]) {
        (**self).task_rows_into(out)
    }
}

// ---------------------------------------------------------------------------
// Scalar oracle surface
// ---------------------------------------------------------------------------

/// The scalar oracle behind the [`Environment`] trait: one `State`
/// driven by `state::step_with_tasks`, with the task source as a
/// first-class constructor input. Bitwise-identical to one slot of the
/// SoA engines (both run the same kernels and RNG sequences).
pub struct ScalarEnv {
    params: EnvParams,
    tasks: Option<Arc<dyn TaskSource>>,
    state: State,
    obs: Obs,
    scratch: ObsScratch,
}

impl ScalarEnv {
    /// Build and reset the env (mirrors `state::reset`): `rng` is
    /// consumed for placement exactly like the oracle's reset stream,
    /// then kept as the env's stream.
    pub fn new(params: EnvParams, base_grid: Grid, ruleset: Ruleset,
               max_steps: i32, rng: Rng) -> ScalarEnv {
        let (state, obs) = state::reset(base_grid, ruleset, max_steps,
                                        rng, params.opts);
        ScalarEnv {
            params,
            tasks: None,
            state,
            obs,
            scratch: ObsScratch::new(),
        }
    }

    /// Install the episode-reset task distribution (§2.1 protocol):
    /// every episode boundary draws a fresh task; trial resets keep it.
    pub fn with_task_source(mut self, tasks: Arc<dyn TaskSource>)
                            -> ScalarEnv {
        self.params.validate_task_source(tasks.as_ref());
        self.tasks = Some(tasks);
        self
    }

    pub fn params(&self) -> &EnvParams {
        &self.params
    }

    pub fn state(&self) -> &State {
        &self.state
    }

    /// Current observation in the flat spec layout.
    fn obs_flat(&self) -> Vec<i32> {
        let mut out = vec![0i32; self.obs.cells.len() * 2];
        self.obs.write_flat_into(&mut out);
        out
    }
}

impl Environment for ScalarEnv {
    fn obs_spec(&self) -> ObsSpec {
        self.params.obs_spec()
    }

    fn max_rules(&self) -> usize {
        self.params.max_rules
    }

    fn reset(&mut self, mut rng: Rng) -> TimeStep {
        // episode-boundary RNG discipline (matches VecEnv::restart):
        // one task draw on the env stream, then a split for placement.
        // The source is borrowed, not Arc-cloned (same episode-boundary
        // rule as the batch engines).
        if let Some(ts) = self.tasks.as_deref() {
            let t = rng.below(ts.num_tasks());
            self.state.ruleset = ts.task(t).clone();
        }
        let mut sub = rng.split();
        let (grid, pos, dir) = place_objects(
            &mut sub, &self.state.base_grid, &self.state.ruleset.init_tiles);
        self.state.grid = grid;
        self.state.agent_pos = pos;
        self.state.agent_dir = dir;
        self.state.pocket = POCKET_EMPTY;
        self.state.step_count = 0;
        self.state.rng = rng;
        observe_into(&self.state.grid, self.state.agent_pos,
                     self.state.agent_dir, self.params.opts.view_size,
                     self.params.opts.see_through_walls, &mut self.obs,
                     &mut self.scratch);
        TimeStep {
            obs: self.obs_flat(),
            reward: 0.0,
            discount: 1.0,
            step_type: StepType::First,
            trial_done: false,
        }
    }

    fn step(&mut self, action: i32) -> TimeStep {
        let info = state::step_with_tasks(
            &mut self.state, action, self.params.opts,
            self.tasks.as_deref(), &mut self.obs, &mut self.scratch);
        TimeStep {
            obs: self.obs_flat(),
            reward: info.reward,
            discount: if info.done { 0.0 } else { 1.0 },
            step_type: if info.done { StepType::Last } else { StepType::Mid },
            trial_done: info.trial_done,
        }
    }

    fn agent_dir(&self) -> i32 {
        self.state.agent_dir
    }

    fn task_rows_into(&self, out: &mut [i32]) {
        write_task_row(&self.state.ruleset, self.params.max_rules, out);
    }
}

/// Encode one ruleset as a goal `[5]` + rules `[MR, 7]` row.
pub(crate) fn write_task_row(rs: &Ruleset, max_rules: usize,
                             out: &mut [i32]) {
    assert_eq!(out.len(), GOAL_ENC + max_rules * RULE_ENC,
               "task row buffer size");
    out[..GOAL_ENC].copy_from_slice(&rs.goal.0);
    for j in 0..max_rules {
        let dst = &mut out[GOAL_ENC + j * RULE_ENC
                           ..GOAL_ENC + (j + 1) * RULE_ENC];
        match rs.rules.get(j) {
            Some(r) => dst.copy_from_slice(&r.0),
            None => dst.fill(0),
        }
    }
}

/// Lift any scalar [`Environment`] into the batch API as a batch of
/// one — the bridge the wrapper-stack parity tests drive (wrapped
/// scalar vs wrapped `VecEnv`, row for row).
pub struct SingleEnv<E: Environment> {
    env: E,
}

impl<E: Environment> SingleEnv<E> {
    pub fn new(env: E) -> SingleEnv<E> {
        SingleEnv { env }
    }

    pub fn inner(&self) -> &E {
        &self.env
    }
}

impl<E: Environment> BatchEnvironment for SingleEnv<E> {
    fn batch(&self) -> usize {
        1
    }

    fn obs_spec(&self) -> ObsSpec {
        self.env.obs_spec()
    }

    fn action_spec(&self) -> ActionSpec {
        self.env.action_spec()
    }

    fn max_rules(&self) -> usize {
        self.env.max_rules()
    }

    fn reset(&mut self, rng: &mut Rng, obs_out: &mut [i32]) -> Result<()> {
        ensure!(obs_out.len() == self.obs_len(), "obs buffer size");
        // same per-env stream derivation as the batch engines: one
        // split off the caller's rng per env slot
        let ts = self.env.reset(rng.split());
        obs_out.copy_from_slice(&ts.obs);
        Ok(())
    }

    fn step(&mut self, actions: &[i32], obs_out: &mut [i32],
            rewards: &mut [f32], dones: &mut [bool],
            trial_dones: &mut [bool]) -> Result<()> {
        ensure!(actions.len() == 1, "need one action per env");
        ensure!(obs_out.len() == self.obs_len(), "obs buffer size");
        let ts = self.env.step(actions[0]);
        obs_out.copy_from_slice(&ts.obs);
        rewards[0] = ts.reward;
        dones[0] = ts.is_last();
        trial_dones[0] = ts.trial_done;
        Ok(())
    }

    fn agent_dirs_into(&self, out: &mut [i32]) {
        out[0] = self.env.agent_dir();
    }

    fn task_rows_into(&self, out: &mut [i32]) {
        self.env.task_rows_into(out);
    }
}

// ---------------------------------------------------------------------------
// Wrappers
// ---------------------------------------------------------------------------

/// Explicit auto-reset semantics over any [`BatchEnvironment`]. The
/// engines already auto-reset in place (trial reset keeps the task,
/// episode reset draws a fresh one); this wrapper surfaces the
/// resulting [`StepType`]s and discounts per env instead of leaving
/// them implicit in the `dones` flags.
pub struct AutoReset<E: BatchEnvironment> {
    inner: E,
    step_types: Vec<StepType>,
    discounts: Vec<f32>,
}

impl<E: BatchEnvironment> AutoReset<E> {
    pub fn new(inner: E) -> AutoReset<E> {
        let b = inner.batch();
        AutoReset {
            inner,
            step_types: vec![StepType::First; b],
            discounts: vec![1.0; b],
        }
    }

    /// Step types of the latest transition (all `First` after a reset).
    pub fn step_types(&self) -> &[StepType] {
        &self.step_types
    }

    /// Discounts of the latest transition (`0.0` where `done`).
    pub fn discounts(&self) -> &[f32] {
        &self.discounts
    }
}

impl<E: BatchEnvironment> BatchEnvironment for AutoReset<E> {
    fn batch(&self) -> usize {
        self.inner.batch()
    }

    fn obs_spec(&self) -> ObsSpec {
        self.inner.obs_spec()
    }

    fn action_spec(&self) -> ActionSpec {
        self.inner.action_spec()
    }

    fn max_rules(&self) -> usize {
        self.inner.max_rules()
    }

    fn reset(&mut self, rng: &mut Rng, obs_out: &mut [i32]) -> Result<()> {
        self.inner.reset(rng, obs_out)?;
        self.step_types.fill(StepType::First);
        self.discounts.fill(1.0);
        Ok(())
    }

    fn step(&mut self, actions: &[i32], obs_out: &mut [i32],
            rewards: &mut [f32], dones: &mut [bool],
            trial_dones: &mut [bool]) -> Result<()> {
        self.inner.step(actions, obs_out, rewards, dones, trial_dones)?;
        for i in 0..self.step_types.len() {
            self.step_types[i] =
                if dones[i] { StepType::Last } else { StepType::Mid };
            self.discounts[i] = if dones[i] { 0.0 } else { 1.0 };
        }
        Ok(())
    }

    fn agent_dirs_into(&self, out: &mut [i32]) {
        self.inner.agent_dirs_into(out)
    }

    fn task_rows_into(&self, out: &mut [i32]) {
        self.inner.task_rows_into(out)
    }
}

/// Appends a one-hot agent-direction segment (`[4]`) to every env's
/// observation record.
pub struct DirectionObs<E: BatchEnvironment> {
    inner: E,
    inner_len: usize,
    inner_buf: Vec<i32>,
    dirs: Vec<i32>,
}

impl<E: BatchEnvironment> DirectionObs<E> {
    pub fn new(inner: E) -> DirectionObs<E> {
        let b = inner.batch();
        let inner_len = inner.obs_spec().len();
        DirectionObs {
            inner_buf: vec![0; b * inner_len],
            dirs: vec![0; b],
            inner,
            inner_len,
        }
    }

    fn compose(&mut self, obs_out: &mut [i32]) {
        let b = self.dirs.len();
        let out_len = self.inner_len + 4;
        self.inner.agent_dirs_into(&mut self.dirs);
        for i in 0..b {
            let src = &self.inner_buf[i * self.inner_len
                                      ..(i + 1) * self.inner_len];
            let dst = &mut obs_out[i * out_len..(i + 1) * out_len];
            dst[..self.inner_len].copy_from_slice(src);
            let one_hot = &mut dst[self.inner_len..];
            one_hot.fill(0);
            one_hot[self.dirs[i].rem_euclid(4) as usize] = 1;
        }
    }
}

impl<E: BatchEnvironment> BatchEnvironment for DirectionObs<E> {
    fn batch(&self) -> usize {
        self.inner.batch()
    }

    fn obs_spec(&self) -> ObsSpec {
        self.inner
            .obs_spec()
            .with_segment(ObsSegment::new("direction", &[4]))
    }

    fn action_spec(&self) -> ActionSpec {
        self.inner.action_spec()
    }

    fn max_rules(&self) -> usize {
        self.inner.max_rules()
    }

    fn reset(&mut self, rng: &mut Rng, obs_out: &mut [i32]) -> Result<()> {
        ensure!(obs_out.len() == self.obs_len(), "obs buffer size");
        self.inner.reset(rng, &mut self.inner_buf)?;
        self.compose(obs_out);
        Ok(())
    }

    fn step(&mut self, actions: &[i32], obs_out: &mut [i32],
            rewards: &mut [f32], dones: &mut [bool],
            trial_dones: &mut [bool]) -> Result<()> {
        ensure!(obs_out.len() == self.obs_len(), "obs buffer size");
        self.inner.step(actions, &mut self.inner_buf, rewards, dones,
                        trial_dones)?;
        self.compose(obs_out);
        Ok(())
    }

    fn agent_dirs_into(&self, out: &mut [i32]) {
        self.inner.agent_dirs_into(out)
    }

    fn task_rows_into(&self, out: &mut [i32]) {
        self.inner.task_rows_into(out)
    }
}

/// Appends the encoded current task — goal `[5]` + rules `[MR, 7]` — to
/// every env's observation record (the paper's RulesAndGoals wrapper).
pub struct RulesAndGoalsObs<E: BatchEnvironment> {
    inner: E,
    inner_len: usize,
    row_len: usize,
    inner_buf: Vec<i32>,
    rows: Vec<i32>,
}

impl<E: BatchEnvironment> RulesAndGoalsObs<E> {
    pub fn new(inner: E) -> RulesAndGoalsObs<E> {
        let b = inner.batch();
        let inner_len = inner.obs_spec().len();
        let row_len = GOAL_ENC + inner.max_rules() * RULE_ENC;
        RulesAndGoalsObs {
            inner_buf: vec![0; b * inner_len],
            rows: vec![0; b * row_len],
            inner,
            inner_len,
            row_len,
        }
    }

    fn compose(&mut self, obs_out: &mut [i32]) {
        let b = self.inner.batch();
        let out_len = self.inner_len + self.row_len;
        self.inner.task_rows_into(&mut self.rows);
        for i in 0..b {
            let src = &self.inner_buf[i * self.inner_len
                                      ..(i + 1) * self.inner_len];
            let row = &self.rows[i * self.row_len..(i + 1) * self.row_len];
            let dst = &mut obs_out[i * out_len..(i + 1) * out_len];
            dst[..self.inner_len].copy_from_slice(src);
            dst[self.inner_len..].copy_from_slice(row);
        }
    }
}

impl<E: BatchEnvironment> BatchEnvironment for RulesAndGoalsObs<E> {
    fn batch(&self) -> usize {
        self.inner.batch()
    }

    fn obs_spec(&self) -> ObsSpec {
        let mr = self.inner.max_rules();
        self.inner
            .obs_spec()
            .with_segment(ObsSegment::new("goal", &[GOAL_ENC]))
            .with_segment(ObsSegment::new("rules", &[mr, RULE_ENC]))
    }

    fn action_spec(&self) -> ActionSpec {
        self.inner.action_spec()
    }

    fn max_rules(&self) -> usize {
        self.inner.max_rules()
    }

    fn reset(&mut self, rng: &mut Rng, obs_out: &mut [i32]) -> Result<()> {
        ensure!(obs_out.len() == self.obs_len(), "obs buffer size");
        self.inner.reset(rng, &mut self.inner_buf)?;
        self.compose(obs_out);
        Ok(())
    }

    fn step(&mut self, actions: &[i32], obs_out: &mut [i32],
            rewards: &mut [f32], dones: &mut [bool],
            trial_dones: &mut [bool]) -> Result<()> {
        ensure!(obs_out.len() == self.obs_len(), "obs buffer size");
        self.inner.step(actions, &mut self.inner_buf, rewards, dones,
                        trial_dones)?;
        self.compose(obs_out);
        Ok(())
    }

    fn agent_dirs_into(&self, out: &mut [i32]) {
        self.inner.agent_dirs_into(out)
    }

    fn task_rows_into(&self, out: &mut [i32]) {
        self.inner.task_rows_into(out)
    }
}

/// The obs spec's goal+rules segment is wrong on a `RulesAndGoalsObs`
/// stacked on itself — composition rule: append-style wrappers compose
/// freely, but stack each at most once (asserted here).
fn assert_no_segment(spec: &ObsSpec, name: &str) {
    assert!(spec.segments.iter().all(|s| s.name != name),
            "wrapper stack already contains a `{name}` segment");
}

/// Replaces the leading symbolic segment with a rasterized RGB image
/// `[V*P, V*P, 3]` (values 0..=255 in i32 slots), a deterministic pure
/// function of the symbolic cells — the native analogue of the paper's
/// RGBImageObservationWrapper, rendered by `render::rgb` at `P` pixels
/// per tile. Appended segments from inner wrappers are passed through
/// unchanged, so `RgbImageObs(DirectionObs(env))` composes; stacking a
/// second `RgbImageObs` is rejected (no symbolic segment remains).
pub struct RgbImageObs<E: BatchEnvironment> {
    inner: E,
    inner_len: usize,
    sym_len: usize,
    rgb_len: usize,
    v: usize,
    patch: usize,
    inner_buf: Vec<i32>,
}

impl<E: BatchEnvironment> RgbImageObs<E> {
    pub fn new(inner: E) -> RgbImageObs<E> {
        RgbImageObs::with_patch(inner, crate::render::TILE_PATCH)
    }

    pub fn with_patch(inner: E, patch: usize) -> RgbImageObs<E> {
        let b = inner.batch();
        let spec = inner.obs_spec();
        let first = spec.segments.first().expect("empty obs spec");
        assert_eq!(first.name, "symbolic",
                   "RgbImageObs needs a leading symbolic segment, found \
                    `{}`", first.name);
        let v = first.shape[0];
        let sym_len = first.len();
        let rgb_len = v * patch * v * patch * 3;
        RgbImageObs {
            inner_len: spec.len(),
            inner_buf: vec![0; b * spec.len()],
            sym_len,
            rgb_len,
            v,
            patch,
            inner,
        }
    }

    fn compose(&mut self, obs_out: &mut [i32]) {
        let b = self.inner.batch();
        let out_len = self.rgb_len + (self.inner_len - self.sym_len);
        for i in 0..b {
            let src = &self.inner_buf[i * self.inner_len
                                      ..(i + 1) * self.inner_len];
            let dst = &mut obs_out[i * out_len..(i + 1) * out_len];
            crate::render::rasterize_symbolic_into(
                &src[..self.sym_len], self.v, self.patch,
                &mut dst[..self.rgb_len]);
            dst[self.rgb_len..].copy_from_slice(&src[self.sym_len..]);
        }
    }
}

impl<E: BatchEnvironment> BatchEnvironment for RgbImageObs<E> {
    fn batch(&self) -> usize {
        self.inner.batch()
    }

    fn obs_spec(&self) -> ObsSpec {
        let spec = self.inner.obs_spec();
        assert_no_segment(&spec, "rgb");
        spec.with_first_replaced(ObsSegment::new(
            "rgb", &[self.v * self.patch, self.v * self.patch, 3]))
    }

    fn action_spec(&self) -> ActionSpec {
        self.inner.action_spec()
    }

    fn max_rules(&self) -> usize {
        self.inner.max_rules()
    }

    fn reset(&mut self, rng: &mut Rng, obs_out: &mut [i32]) -> Result<()> {
        ensure!(obs_out.len() == self.obs_len(), "obs buffer size");
        self.inner.reset(rng, &mut self.inner_buf)?;
        self.compose(obs_out);
        Ok(())
    }

    fn step(&mut self, actions: &[i32], obs_out: &mut [i32],
            rewards: &mut [f32], dones: &mut [bool],
            trial_dones: &mut [bool]) -> Result<()> {
        ensure!(obs_out.len() == self.obs_len(), "obs buffer size");
        self.inner.step(actions, &mut self.inner_buf, rewards, dones,
                        trial_dones)?;
        self.compose(obs_out);
        Ok(())
    }

    fn agent_dirs_into(&self, out: &mut [i32]) {
        self.inner.agent_dirs_into(out)
    }

    fn task_rows_into(&self, out: &mut [i32]) {
        self.inner.task_rows_into(out)
    }
}

// ---------------------------------------------------------------------------
// Obs-mode selection (`--obs`) and the generic rollout driver
// ---------------------------------------------------------------------------

/// Which observation wrapper stack a rollout/train run steps through
/// (`xmgrid rollout --obs symbolic|dir|rules-goals|rgb`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ObsMode {
    /// Raw engine observation (no wrapper; fused fast path).
    #[default]
    Symbolic,
    /// `DirectionObs` appended.
    Direction,
    /// `RulesAndGoalsObs` appended.
    RulesGoals,
    /// `RgbImageObs` replacing the symbolic segment.
    Rgb,
}

impl ObsMode {
    pub fn from_flag(s: &str) -> Result<ObsMode> {
        match s {
            "symbolic" => Ok(ObsMode::Symbolic),
            "dir" => Ok(ObsMode::Direction),
            "rules-goals" => Ok(ObsMode::RulesGoals),
            "rgb" => Ok(ObsMode::Rgb),
            other => anyhow::bail!(
                "--obs must be `symbolic`, `dir`, `rules-goals` or \
                 `rgb`, got {other}"
            ),
        }
    }

    /// Build the wrapper stack over `env` as a trait object.
    pub fn wrap<E: BatchEnvironment + 'static>(self, env: E)
                                               -> Box<dyn BatchEnvironment> {
        match self {
            ObsMode::Symbolic => Box::new(env),
            ObsMode::Direction => Box::new(DirectionObs::new(env)),
            ObsMode::RulesGoals => Box::new(RulesAndGoalsObs::new(env)),
            ObsMode::Rgb => Box::new(RgbImageObs::new(env)),
        }
    }
}

/// `Display` writes the CLI flag spelling back out.
impl std::fmt::Display for ObsMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            ObsMode::Symbolic => "symbolic",
            ObsMode::Direction => "dir",
            ObsMode::RulesGoals => "rules-goals",
            ObsMode::Rgb => "rgb",
        })
    }
}

/// Reusable I/O buffers for [`rollout_batch`], sized once per env.
pub struct RolloutBufs {
    pub obs: Vec<i32>,
    pub actions: Vec<i32>,
    pub rewards: Vec<f32>,
    pub dones: Vec<bool>,
    pub trial_dones: Vec<bool>,
    reward_acc: Vec<f64>,
}

impl RolloutBufs {
    pub fn for_env(env: &dyn BatchEnvironment) -> RolloutBufs {
        let b = env.batch();
        RolloutBufs {
            obs: vec![0; env.obs_len()],
            actions: vec![0; b],
            rewards: vec![0.0; b],
            dones: vec![false; b],
            trial_dones: vec![false; b],
            reward_acc: vec![0.0; b],
        }
    }
}

/// Random-policy rollout through any [`BatchEnvironment`] — the driver
/// wrapped engine replicas and the fig13 bench share. `t` steps per
/// env; actions drawn from `rng` in serial order (step-major,
/// env-minor, matching the fused engines); returns
/// `(reward_sum, episodes_done, trials_done)` with the reward reduction
/// performed env-major (per-env `f64` sums folded in ascending env
/// order), so the aggregates match the fused path bit for bit.
pub fn rollout_batch(env: &mut dyn BatchEnvironment, t: usize,
                     rng: &mut Rng, bufs: &mut RolloutBufs)
                     -> Result<(f64, u64, u64)> {
    let b = env.batch();
    ensure!(bufs.actions.len() == b && bufs.obs.len() == env.obs_len(),
            "rollout buffers sized for a different env");
    let na = env.action_spec().num_actions;
    bufs.reward_acc.iter_mut().for_each(|x| *x = 0.0);
    let mut episodes = 0u64;
    let mut trials = 0u64;
    for _ in 0..t {
        for a in bufs.actions.iter_mut() {
            *a = rng.below(na) as i32;
        }
        env.step(&bufs.actions, &mut bufs.obs, &mut bufs.rewards,
                 &mut bufs.dones, &mut bufs.trial_dones)?;
        for (acc, &r) in bufs.reward_acc.iter_mut().zip(&bufs.rewards) {
            *acc += r as f64;
        }
        episodes += bufs.dones.iter().filter(|&&d| d).count() as u64;
        trials += bufs.trial_dones.iter().filter(|&&d| d).count() as u64;
    }
    let mut reward_sum = 0.0f64;
    for &x in &bufs.reward_acc {
        reward_sum += x;
    }
    Ok((reward_sum, episodes, trials))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::types::*;
    use crate::env::Goal;

    fn ball_red() -> Cell {
        Cell::new(TILE_BALL, COLOR_RED)
    }

    fn sample_ruleset() -> Ruleset {
        Ruleset {
            goal: Goal::agent_near(ball_red()),
            rules: vec![],
            init_tiles: vec![ball_red()],
        }
    }

    fn scalar_env(max_steps: i32) -> ScalarEnv {
        ScalarEnv::new(EnvParams::new(9, 9, 1, 1), Grid::empty_room(9, 9),
                       sample_ruleset(), max_steps, Rng::new(3))
    }

    #[test]
    fn spec_lengths_compose() {
        let spec = ObsSpec::symbolic(5);
        assert_eq!(spec.len(), 50);
        let spec = spec.with_segment(ObsSegment::new("direction", &[4]));
        assert_eq!(spec.len(), 54);
        assert_eq!(spec.segments.len(), 2);
        let json = spec.to_json();
        assert!(json.contains("\"name\":\"symbolic\""));
        assert!(json.contains("\"shape\":[5,5,2]"));
        assert!(json.contains("\"len\":54"));
        assert_eq!(ActionSpec::default().num_actions, 6);
    }

    #[test]
    fn env_params_single_source_of_shape() {
        let p = EnvParams::new(13, 13, 9, 12);
        assert_eq!(p.obs_len(), p.obs_spec().len());
        assert_eq!(p.task_row_len(), 5 + 9 * 7);
        assert_eq!(p.options().view_size, 5);
    }

    #[test]
    fn scalar_env_timestep_protocol() {
        let mut env = scalar_env(3);
        let first = env.reset(Rng::new(11));
        assert!(first.is_first());
        assert_eq!(first.obs.len(), env.obs_spec().len());
        let mut saw_last = false;
        for _ in 0..6 {
            let ts = env.step(ACTION_TURN_LEFT);
            assert_eq!(ts.obs.len(), env.obs_spec().len());
            if ts.is_last() {
                assert_eq!(ts.discount, 0.0);
                assert!(ts.trial_done);
                saw_last = true;
            } else {
                assert_eq!(ts.discount, 1.0);
            }
        }
        assert!(saw_last, "max_steps=3 must hit episode boundaries");
    }

    #[test]
    fn scalar_env_resamples_tasks_on_reset() {
        let tasks: Vec<Ruleset> = (0..5)
            .map(|k| Ruleset {
                goal: Goal::agent_hold(Cell::new(TILE_BALL, 3 + k)),
                rules: vec![],
                init_tiles: vec![Cell::new(TILE_BALL, 3 + k)],
            })
            .collect();
        let mut env = scalar_env(100).with_task_source(Arc::new(tasks));
        let mut rng = Rng::new(2);
        let mut seen = std::collections::HashSet::new();
        for _ in 0..24 {
            env.reset(rng.split());
            seen.insert(env.state().ruleset.goal.0);
        }
        assert!(seen.len() >= 2, "resets must draw from the task source");
    }

    #[test]
    fn single_env_bridges_scalar_to_batch() {
        let mut env = SingleEnv::new(scalar_env(50));
        assert_eq!(env.batch(), 1);
        let mut obs = vec![0i32; env.obs_len()];
        let mut rng = Rng::new(9);
        env.reset(&mut rng, &mut obs).unwrap();
        let mut rewards = [0f32];
        let mut dones = [false];
        let mut trials = [false];
        env.step(&[ACTION_FORWARD], &mut obs, &mut rewards, &mut dones,
                 &mut trials)
            .unwrap();
        let mut dirs = [0i32];
        env.agent_dirs_into(&mut dirs);
        assert!((0..4).contains(&dirs[0]));
        let mut row = vec![0i32; GOAL_ENC + env.max_rules() * RULE_ENC];
        env.task_rows_into(&mut row);
        assert_eq!(row[0], GOAL_AGENT_NEAR);
    }

    #[test]
    fn direction_obs_appends_one_hot() {
        let mut env = DirectionObs::new(SingleEnv::new(scalar_env(50)));
        assert_eq!(env.obs_spec().len(), 50 + 4);
        let mut obs = vec![0i32; env.obs_len()];
        let mut rng = Rng::new(4);
        env.reset(&mut rng, &mut obs).unwrap();
        let one_hot = &obs[50..];
        assert_eq!(one_hot.iter().sum::<i32>(), 1);
        let mut dirs = [0i32];
        env.agent_dirs_into(&mut dirs);
        assert_eq!(one_hot[dirs[0] as usize], 1);
    }

    #[test]
    fn rules_goals_obs_appends_task_row() {
        let mut env =
            RulesAndGoalsObs::new(SingleEnv::new(scalar_env(50)));
        let row_len = GOAL_ENC + env.max_rules() * RULE_ENC;
        assert_eq!(env.obs_spec().len(), 50 + row_len);
        let mut obs = vec![0i32; env.obs_len()];
        let mut rng = Rng::new(4);
        env.reset(&mut rng, &mut obs).unwrap();
        assert_eq!(obs[50], GOAL_AGENT_NEAR, "goal id leads the row");
    }

    #[test]
    fn rgb_obs_replaces_symbolic_segment() {
        let mut env = RgbImageObs::new(SingleEnv::new(scalar_env(50)));
        let spec = env.obs_spec();
        assert_eq!(spec.segments[0].name, "rgb");
        let p = crate::render::TILE_PATCH;
        assert_eq!(spec.len(), 5 * p * 5 * p * 3);
        let mut obs = vec![0i32; env.obs_len()];
        let mut rng = Rng::new(4);
        env.reset(&mut rng, &mut obs).unwrap();
        assert!(obs.iter().all(|&x| (0..=255).contains(&x)));
        assert!(obs.iter().any(|&x| x > 0), "image is not all black");
    }

    #[test]
    fn auto_reset_marks_step_types() {
        let mut env = AutoReset::new(SingleEnv::new(scalar_env(2)));
        let mut obs = vec![0i32; env.obs_len()];
        let mut rng = Rng::new(4);
        env.reset(&mut rng, &mut obs).unwrap();
        assert_eq!(env.step_types(), &[StepType::First]);
        let mut rewards = [0f32];
        let mut dones = [false];
        let mut trials = [false];
        env.step(&[ACTION_TURN_LEFT], &mut obs, &mut rewards, &mut dones,
                 &mut trials)
            .unwrap();
        assert_eq!(env.step_types(), &[StepType::Mid]);
        assert_eq!(env.discounts(), &[1.0]);
        env.step(&[ACTION_TURN_LEFT], &mut obs, &mut rewards, &mut dones,
                 &mut trials)
            .unwrap();
        assert_eq!(env.step_types(), &[StepType::Last]);
        assert_eq!(env.discounts(), &[0.0]);
    }

    #[test]
    fn rollout_batch_counts_and_obs_mode_flags() {
        let mut env = SingleEnv::new(scalar_env(4));
        let mut bufs = RolloutBufs::for_env(&env);
        let mut rng = Rng::new(8);
        let (_, episodes, trials) =
            rollout_batch(&mut env, 8, &mut rng, &mut bufs).unwrap();
        assert_eq!(episodes, 2, "max_steps=4 over 8 steps = 2 episodes");
        assert!(trials >= 2);

        assert_eq!(ObsMode::from_flag("rgb").unwrap(), ObsMode::Rgb);
        assert_eq!(ObsMode::from_flag("dir").unwrap(),
                   ObsMode::Direction);
        assert!(ObsMode::from_flag("pixels").is_err());
        assert_eq!(ObsMode::RulesGoals.to_string(), "rules-goals");
        assert_eq!(ObsMode::default(), ObsMode::Symbolic);
    }
}
