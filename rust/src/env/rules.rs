//! Production rules (paper Table 3) — the Rust oracle for
//! `python/compile/xmg/rules.py`.
//!
//! Determinism contract shared with the JAX side: candidate directions are
//! scanned up, right, down, left; cells row-major; the first match fires;
//! each rule fires at most once per check; rules apply sequentially in
//! ruleset order.

use super::grid::CellGrid;
use super::types::*;

/// Encoded rule `[id, a_tile, a_col, b_tile, b_col, c_tile, c_col]`.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash)]
pub struct Rule(pub [i32; RULE_ENC]);

impl Rule {
    pub const EMPTY: Rule = Rule([0; RULE_ENC]);

    pub fn id(&self) -> i32 {
        self.0[0]
    }
    pub fn a(&self) -> Cell {
        Cell::new(self.0[1], self.0[2])
    }
    pub fn b(&self) -> Cell {
        Cell::new(self.0[3], self.0[4])
    }
    pub fn c(&self) -> Cell {
        Cell::new(self.0[5], self.0[6])
    }

    pub fn agent_hold(a: Cell, c: Cell) -> Rule {
        Rule([RULE_AGENT_HOLD, a.tile, a.color, 0, 0, c.tile, c.color])
    }
    pub fn agent_near(a: Cell, c: Cell) -> Rule {
        Rule([RULE_AGENT_NEAR, a.tile, a.color, 0, 0, c.tile, c.color])
    }
    pub fn tile_near(a: Cell, b: Cell, c: Cell) -> Rule {
        Rule([RULE_TILE_NEAR, a.tile, a.color, b.tile, b.color, c.tile,
              c.color])
    }
    pub fn tile_near_dir(dir: usize, a: Cell, b: Cell, c: Cell) -> Rule {
        let id = RULE_TILE_NEAR_UP + dir as i32;
        Rule([id, a.tile, a.color, b.tile, b.color, c.tile, c.color])
    }
    pub fn agent_near_dir(dir: usize, a: Cell, c: Cell) -> Rule {
        let id = RULE_AGENT_NEAR_UP + dir as i32;
        Rule([id, a.tile, a.color, 0, 0, c.tile, c.color])
    }

    /// Input objects consumed by this rule (for the generator/solver).
    pub fn inputs(&self) -> Vec<Cell> {
        match self.id() {
            RULE_EMPTY => vec![],
            RULE_AGENT_HOLD | RULE_AGENT_NEAR | RULE_AGENT_NEAR_UP
            | RULE_AGENT_NEAR_RIGHT | RULE_AGENT_NEAR_DOWN
            | RULE_AGENT_NEAR_LEFT => vec![self.a()],
            _ => vec![self.a(), self.b()],
        }
    }
}

const ALL_DIRS: [usize; 4] = [DIR_UP, DIR_RIGHT, DIR_DOWN, DIR_LEFT];

/// Production that lands on the grid; producing FLOOR means disappearance
/// (App. J: "the disappearance production rule was emulated by setting the
/// production tile to the black floor").
fn production(rule: &Rule) -> Cell {
    rule.c()
}

fn apply_tile_near<G: CellGrid>(grid: &mut G, rule: &Rule, dirs: &[usize]) {
    let (a, b, c) = (rule.a(), rule.b(), production(rule));
    for &d in dirs {
        for r in 0..grid.h() as i32 {
            for col in 0..grid.w() as i32 {
                if grid.get_i(r, col) != a {
                    continue;
                }
                let (br, bc) = (r + DIR_DR[d], col + DIR_DC[d]);
                if grid.get_i(br, bc) == b {
                    // b's cell is cleared first, then a's becomes c —
                    // same order as the JAX scatter (handles a == b).
                    grid.set_i(br, bc, FLOOR_CELL);
                    grid.set_i(r, col, c);
                    return;
                }
            }
        }
    }
}

fn apply_agent_near<G: CellGrid>(grid: &mut G, agent_pos: (i32, i32),
                                 rule: &Rule, dirs: &[usize]) {
    let (a, c) = (rule.a(), production(rule));
    for &d in dirs {
        let r = agent_pos.0 + DIR_DR[d];
        let col = agent_pos.1 + DIR_DC[d];
        if grid.in_bounds(r, col) && grid.get_i(r, col) == a {
            grid.set_i(r, col, c);
            return;
        }
    }
}

/// Apply one encoded rule; mutates grid/pocket like the JAX `check_rule`.
/// Generic over [`CellGrid`] so the scalar oracle and the SoA engine of
/// `env::vector` run the identical kernel.
pub fn check_rule<G: CellGrid>(grid: &mut G, agent_pos: (i32, i32),
                               pocket: &mut Cell, rule: &Rule) {
    match rule.id() {
        RULE_EMPTY => {}
        RULE_AGENT_HOLD => {
            if *pocket == rule.a() {
                let c = production(rule);
                *pocket = if c.tile == TILE_FLOOR { POCKET_EMPTY } else { c };
            }
        }
        RULE_AGENT_NEAR => apply_agent_near(grid, agent_pos, rule, &ALL_DIRS),
        RULE_TILE_NEAR => apply_tile_near(grid, rule, &ALL_DIRS),
        RULE_TILE_NEAR_UP => apply_tile_near(grid, rule, &[DIR_UP]),
        RULE_TILE_NEAR_RIGHT => apply_tile_near(grid, rule, &[DIR_RIGHT]),
        RULE_TILE_NEAR_DOWN => apply_tile_near(grid, rule, &[DIR_DOWN]),
        RULE_TILE_NEAR_LEFT => apply_tile_near(grid, rule, &[DIR_LEFT]),
        RULE_AGENT_NEAR_UP => {
            apply_agent_near(grid, agent_pos, rule, &[DIR_UP])
        }
        RULE_AGENT_NEAR_RIGHT => {
            apply_agent_near(grid, agent_pos, rule, &[DIR_RIGHT])
        }
        RULE_AGENT_NEAR_DOWN => {
            apply_agent_near(grid, agent_pos, rule, &[DIR_DOWN])
        }
        RULE_AGENT_NEAR_LEFT => {
            apply_agent_near(grid, agent_pos, rule, &[DIR_LEFT])
        }
        _ => {} // unknown ids are inert, like the clipped lax.switch
    }
}

/// Apply a full ruleset sequentially. Padding `RULE_EMPTY` rows are
/// inert, so callers may pass an entire fixed-width rule table — or,
/// like the SoA engines, only the live `rules_len` prefix of one: the
/// two are semantically identical, and skipping the padding is the
/// cheaper call (docs/ARCHITECTURE.md "Hot-path anatomy").
pub fn check_rules<G: CellGrid>(grid: &mut G, agent_pos: (i32, i32),
                                pocket: &mut Cell, rules: &[Rule]) {
    for rule in rules {
        check_rule(grid, agent_pos, pocket, rule);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::grid::Grid;

    fn ball_red() -> Cell {
        Cell::new(TILE_BALL, COLOR_RED)
    }
    fn sq_blue() -> Cell {
        Cell::new(TILE_SQUARE, COLOR_BLUE)
    }
    fn pyr_green() -> Cell {
        Cell::new(TILE_PYRAMID, COLOR_GREEN)
    }

    #[test]
    fn tile_near_fires_on_adjacency() {
        let mut g = Grid::empty_room(7, 7);
        g.set(3, 3, ball_red());
        g.set(3, 4, sq_blue());
        let rule = Rule::tile_near(ball_red(), sq_blue(), pyr_green());
        let mut pocket = POCKET_EMPTY;
        check_rule(&mut g, (1, 1), &mut pocket, &rule);
        assert_eq!(g.get(3, 3), pyr_green()); // a replaced by c
        assert_eq!(g.get(3, 4), FLOOR_CELL); // b removed
    }

    #[test]
    fn tile_near_ignores_non_adjacent() {
        let mut g = Grid::empty_room(7, 7);
        g.set(1, 1, ball_red());
        g.set(5, 5, sq_blue());
        let rule = Rule::tile_near(ball_red(), sq_blue(), pyr_green());
        let mut pocket = POCKET_EMPTY;
        check_rule(&mut g, (3, 3), &mut pocket, &rule);
        assert_eq!(g.get(1, 1), ball_red());
        assert_eq!(g.get(5, 5), sq_blue());
    }

    #[test]
    fn tile_near_direction_priority_up_first() {
        // b both above and to the right of a: the up-direction match wins
        let mut g = Grid::empty_room(7, 7);
        g.set(3, 3, ball_red());
        g.set(2, 3, sq_blue()); // above
        g.set(3, 4, sq_blue()); // right
        let rule = Rule::tile_near(ball_red(), sq_blue(), pyr_green());
        let mut pocket = POCKET_EMPTY;
        check_rule(&mut g, (1, 1), &mut pocket, &rule);
        assert_eq!(g.get(2, 3), FLOOR_CELL, "up neighbor consumed");
        assert_eq!(g.get(3, 4), sq_blue(), "right neighbor untouched");
        assert_eq!(g.get(3, 3), pyr_green());
    }

    #[test]
    fn tile_near_fires_once_per_check() {
        let mut g = Grid::empty_room(9, 9);
        g.set(1, 1, ball_red());
        g.set(1, 2, sq_blue());
        g.set(5, 5, ball_red());
        g.set(5, 6, sq_blue());
        let rule = Rule::tile_near(ball_red(), sq_blue(), pyr_green());
        let mut pocket = POCKET_EMPTY;
        check_rule(&mut g, (3, 3), &mut pocket, &rule);
        // only the row-major-first pair fired
        assert_eq!(g.get(1, 1), pyr_green());
        assert_eq!(g.get(5, 5), ball_red());
        assert_eq!(g.get(5, 6), sq_blue());
    }

    #[test]
    fn directional_tile_near_up_only() {
        // TileNearUp: b one tile ABOVE a
        let mut g = Grid::empty_room(7, 7);
        g.set(3, 3, ball_red());
        g.set(3, 4, sq_blue()); // right, should NOT fire
        let rule = Rule::tile_near_dir(DIR_UP, ball_red(), sq_blue(),
                                       pyr_green());
        let mut pocket = POCKET_EMPTY;
        check_rule(&mut g, (1, 1), &mut pocket, &rule);
        assert_eq!(g.get(3, 3), ball_red());

        g.set(2, 3, sq_blue()); // above, should fire
        check_rule(&mut g, (1, 1), &mut pocket, &rule);
        assert_eq!(g.get(3, 3), pyr_green());
        assert_eq!(g.get(2, 3), FLOOR_CELL);
    }

    #[test]
    fn agent_hold_transforms_pocket() {
        let mut g = Grid::empty_room(5, 5);
        let rule = Rule::agent_hold(ball_red(), sq_blue());
        let mut pocket = ball_red();
        check_rule(&mut g, (2, 2), &mut pocket, &rule);
        assert_eq!(pocket, sq_blue());
    }

    #[test]
    fn agent_hold_disappearance_empties_pocket() {
        let mut g = Grid::empty_room(5, 5);
        let rule = Rule::agent_hold(ball_red(), FLOOR_CELL);
        let mut pocket = ball_red();
        check_rule(&mut g, (2, 2), &mut pocket, &rule);
        assert_eq!(pocket, POCKET_EMPTY);
    }

    #[test]
    fn agent_near_replaces_neighbor() {
        let mut g = Grid::empty_room(5, 5);
        g.set(1, 2, ball_red()); // above agent at (2,2)
        let rule = Rule::agent_near(ball_red(), sq_blue());
        let mut pocket = POCKET_EMPTY;
        check_rule(&mut g, (2, 2), &mut pocket, &rule);
        assert_eq!(g.get(1, 2), sq_blue());
    }

    #[test]
    fn agent_near_dir_respects_direction() {
        let mut g = Grid::empty_room(5, 5);
        g.set(2, 3, ball_red()); // right of agent
        let up_rule = Rule::agent_near_dir(DIR_UP, ball_red(), sq_blue());
        let mut pocket = POCKET_EMPTY;
        check_rule(&mut g, (2, 2), &mut pocket, &up_rule);
        assert_eq!(g.get(2, 3), ball_red(), "up rule must not fire");
        let right_rule =
            Rule::agent_near_dir(DIR_RIGHT, ball_red(), sq_blue());
        check_rule(&mut g, (2, 2), &mut pocket, &right_rule);
        assert_eq!(g.get(2, 3), sq_blue());
    }

    #[test]
    fn rules_apply_sequentially_chained() {
        // rule1 produces the input of rule2 — both fire in one check
        let mut g = Grid::empty_room(7, 7);
        g.set(3, 3, ball_red());
        g.set(3, 4, sq_blue());
        g.set(2, 3, pyr_green());
        let star = Cell::new(TILE_STAR, COLOR_YELLOW);
        let hexa = Cell::new(TILE_HEX, COLOR_PINK);
        let rules = [
            Rule::tile_near(ball_red(), sq_blue(), star.clone()),
            Rule::tile_near(star.clone(), pyr_green(), hexa.clone()),
        ];
        let mut pocket = POCKET_EMPTY;
        check_rules(&mut g, (5, 5), &mut pocket, &rules);
        assert_eq!(g.get(3, 3), hexa);
        assert_eq!(g.get(3, 4), FLOOR_CELL);
        assert_eq!(g.get(2, 3), FLOOR_CELL);
    }

    #[test]
    fn empty_rule_inert() {
        let mut g = Grid::empty_room(5, 5);
        let before = g.clone();
        let mut pocket = ball_red();
        check_rule(&mut g, (2, 2), &mut pocket, &Rule::EMPTY);
        assert_eq!(g, before);
        assert_eq!(pocket, ball_red());
    }

    #[test]
    fn rule_inputs_arity() {
        assert_eq!(Rule::EMPTY.inputs().len(), 0);
        assert_eq!(Rule::agent_hold(ball_red(), sq_blue()).inputs().len(), 1);
        assert_eq!(
            Rule::tile_near(ball_red(), sq_blue(), pyr_green())
                .inputs()
                .len(),
            2
        );
    }
}
