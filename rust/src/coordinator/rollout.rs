//! Double-buffered sharded rollout engine.
//!
//! Replaces the fork-join `run_sharded` collection loop with persistent
//! shard workers (see [`ShardPool`]): each shard owns a full replica —
//! PJRT client, compiled rollout executable, device-resident env-state
//! buffers, and a private RNG stream — and is driven over a channel of
//! rollout jobs.
//!
//! The engine is **backend-generic**: a replica is anything that can
//! produce rollout chunks. Two backends exist — the AOT/PJRT replica
//! (`ShardReplica`, `--backend xla`) and the native vectorized replica
//! (`NativeReplica`, `--backend native`: a [`NativePool`]-owned
//! `ParVecEnv` batch per shard — itself chunked over `--threads`
//! stepping workers, bitwise-independent of the thread count — no
//! artifacts). Both run under the same overlap disciplines and the
//! same `(seed, shard)` RNG streams.
//!
//! With overlap **off**, collection is a lockstep collective per round
//! (dispatch to all shards, barrier, consume in shard order) — bitwise
//! identical across runs for a fixed seed.
//!
//! With overlap **on**, each shard keeps up to two rounds in flight (the
//! double buffer): while the consumer drains the stats of trajectory
//! buffer *t*, the shard is already stepping buffer *t+1*. There is no
//! global barrier, so a slow shard never stalls the others, and host-side
//! consumption overlaps device stepping. Per-shard trajectories are
//! *identical* in both modes — a shard's RNG advances only with its own
//! jobs, in submission order — only the order in which the consumer
//! observes finished chunks changes.

use std::path::PathBuf;
use std::sync::mpsc::channel;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::benchgen::Benchmark;
use crate::env::api::{rollout_batch, BatchEnvironment, ObsMode,
                      RolloutBufs};
use crate::env::state::TaskSource;
use crate::runtime::{Manifest, Runtime};
use crate::util::fault::FaultPlan;
use crate::util::rng::Rng;

use super::config::{Overlap, ShardConfig};
use super::metrics::WallTimer;
use super::native::{NativeEnvConfig, NativePool};
use super::pool::{EnvFamily, EnvPool};
use super::shard::{panic_message, ShardPool};

/// Rounds in flight per shard with overlap on: the double buffer.
pub const PIPELINE_DEPTH: usize = 2;

/// Derive shard `i`'s seed from the run seed. Shard 0 keeps the run seed
/// itself (so a one-shard engine reproduces the unsharded path bitwise);
/// higher shards are decorrelated by [`crate::util::rng::stream_seed`]'s
/// golden-ratio spread. The mapping depends only on `(seed, shard)`,
/// never on scheduling — that is what keeps overlap modes
/// trajectory-identical.
pub fn shard_seed(seed: u64, shard: usize) -> u64 {
    crate::util::rng::stream_seed(seed, shard as u64)
}

/// [`shard_seed`] as a ready-made RNG stream.
pub fn shard_rng(seed: u64, shard: usize) -> Rng {
    Rng::new(shard_seed(seed, shard))
}

/// One finished rollout chunk (a trajectory buffer's aggregate stats).
/// The env-state tensors themselves stay shard-resident; only these
/// aggregates cross the channel to the consumer.
#[derive(Clone, Copy, Debug)]
pub struct ChunkStats {
    pub shard: usize,
    pub round: usize,
    pub steps: u64,
    pub reward_sum: f64,
    pub episodes: u64,
    pub trials: u64,
    /// seconds the shard spent executing this chunk
    pub secs: f64,
}

/// Totals over one `collect` call.
#[derive(Clone, Copy, Debug, Default)]
pub struct RolloutTotals {
    pub steps: u64,
    pub reward_sum: f64,
    pub episodes: u64,
    pub trials: u64,
    /// wall-clock seconds for the whole collection
    pub elapsed: f64,
}

impl RolloutTotals {
    pub fn absorb(&mut self, c: &ChunkStats) {
        self.steps += c.steps;
        self.reward_sum += c.reward_sum;
        self.episodes += c.episodes;
        self.trials += c.trials;
    }

    pub fn sps(&self) -> f64 {
        if self.elapsed > 0.0 {
            self.steps as f64 / self.elapsed
        } else {
            0.0
        }
    }
}

/// One rollout replica's unit of work. Implemented by both backends so
/// the engine's collect machinery (lockstep and double-buffered alike)
/// is generic over where the stepping actually happens.
trait RolloutReplica: 'static {
    fn rollout_chunk(&mut self, round: usize) -> Result<ChunkStats>;
}

/// `panic@shard=K,round=R` fault site, shared by both backends: a
/// shard-level injected panic exercises the engine's coarse failure
/// path (clean error from `collect`, never a hang or abort) as opposed
/// to the chunk-level faults `ParVecEnv` recovers from internally.
fn maybe_shard_fault(faults: &FaultPlan, shard: usize, round: usize) {
    if !faults.is_empty()
        && faults.shard_round_panic(shard, round as u64)
    {
        panic!("injected fault: shard {shard} at round {round}");
    }
}

/// Per-shard AOT/PJRT replica state, constructed inside the shard thread.
struct ShardReplica {
    shard: usize,
    rt: Runtime,
    pool: EnvPool,
    rng: Rng,
    t: usize,
    faults: Arc<FaultPlan>,
}

impl RolloutReplica for ShardReplica {
    fn rollout_chunk(&mut self, round: usize) -> Result<ChunkStats> {
        maybe_shard_fault(&self.faults, self.shard, round);
        let t0 = WallTimer::start();
        let (reward_sum, episodes, trials) =
            self.pool.rollout(&self.rt, self.t, &mut self.rng)?;
        Ok(ChunkStats {
            shard: self.shard,
            round,
            steps: (self.pool.family.b * self.t) as u64,
            reward_sum,
            episodes,
            trials,
            secs: t0.elapsed_secs(),
        })
    }
}

/// How a native replica steps its envs: the fused symbolic fast path
/// (whole-T rollout shipped worker-side), or per-step through an
/// `--obs` wrapper stack (observations actually composed every step —
/// that cost is the point of the fig13-style measurements).
enum NativeStepper {
    Fused(NativePool),
    Wrapped {
        env: Box<dyn BatchEnvironment>,
        bufs: RolloutBufs,
    },
}

/// Per-shard native vectorized replica: a `VecEnv` batch stepped by the
/// SoA kernels on the shard's own thread — no PJRT, no artifacts.
struct NativeReplica {
    shard: usize,
    stepper: NativeStepper,
    rng: Rng,
    b: usize,
    t: usize,
    faults: Arc<FaultPlan>,
}

impl RolloutReplica for NativeReplica {
    fn rollout_chunk(&mut self, round: usize) -> Result<ChunkStats> {
        maybe_shard_fault(&self.faults, self.shard, round);
        let t0 = WallTimer::start();
        let (reward_sum, episodes, trials) = match &mut self.stepper {
            NativeStepper::Fused(pool) => {
                pool.rollout(self.t, &mut self.rng)?
            }
            NativeStepper::Wrapped { env, bufs } => {
                rollout_batch(env.as_mut(), self.t, &mut self.rng, bufs)?
            }
        };
        Ok(ChunkStats {
            shard: self.shard,
            round,
            steps: (self.b * self.t) as u64,
            reward_sum,
            episodes,
            trials,
            secs: t0.elapsed_secs(),
        })
    }
}

/// The engine's shard pool, one variant per backend.
enum EnginePool {
    Xla(ShardPool<ShardReplica>),
    Native(ShardPool<NativeReplica>),
}

/// Persistent sharded rollout engine (random-policy collection).
pub struct RolloutEngine {
    pool: EnginePool,
    pub family: EnvFamily,
    /// steps per fused rollout call
    pub t: usize,
    pub cfg: ShardConfig,
}

impl RolloutEngine {
    /// Spin up `cfg.shards` replica threads around one `env_rollout`
    /// artifact. Each shard loads its own PJRT client + executables from
    /// `artifacts_dir`, samples rulesets from `bench` with its private
    /// stream, resets, and pre-compiles the rollout executable so the
    /// first timed chunk measures stepping, not compilation.
    pub fn launch(artifacts_dir: PathBuf, artifact: String,
                  bench: Arc<Benchmark>, cfg: ShardConfig)
                  -> Result<RolloutEngine> {
        // Family / T come from the manifest (cheap text parse — no PJRT
        // client on the main thread; replicas own the clients).
        let manifest = Manifest::load(&artifacts_dir)?;
        let spec = manifest.find(&artifact)?;
        let family = EnvFamily::from_spec(spec)?;
        let t = spec.meta_usize("T")?;

        let seed = cfg.seed;
        let rooms = cfg.rooms;
        let name = artifact.clone();
        let faults = Arc::new(FaultPlan::from_env()?);
        let pool = ShardPool::spawn(cfg.shards, move |i| {
            let faults = faults.clone();
            let rt = Runtime::new(&artifacts_dir)?;
            rt.preload(&[name.as_str()])?;
            let mut rng = shard_rng(seed, i);
            let mut pool = EnvPool::new(&rt, family, rooms)?;
            let rulesets = pool.sample_rulesets(&bench, &mut rng);
            pool.reset(&rulesets, &mut rng)
                .with_context(|| format!("resetting shard {i}"))?;
            // §2.1 task resampling for the xla backend: the benchmark
            // becomes the pool's task source and done envs' ruleset
            // rows are re-encoded host-side between fused chunks
            // (ROADMAP open item; see coordinator::pool module docs)
            let tasks: Arc<dyn TaskSource> = bench.clone();
            pool.set_task_source(tasks, rng.split());
            Ok(ShardReplica { shard: i, rt, pool, rng, t, faults })
        })?;
        Ok(RolloutEngine { pool: EnginePool::Xla(pool), family, t, cfg })
    }

    /// Spin up `cfg.shards` *native vectorized* replicas — no manifest,
    /// no artifacts, no PJRT. Each shard owns a `ParVecEnv` of `ncfg.b`
    /// envs chunked over `ncfg.threads` stepping workers, samples
    /// rulesets from `bench` with the same `shard_rng(seed, i)` streams
    /// as the AOT path, resets, and steps the SoA kernels.
    pub fn launch_native(ncfg: NativeEnvConfig, bench: Arc<Benchmark>,
                         cfg: ShardConfig) -> Result<RolloutEngine> {
        RolloutEngine::launch_native_obs(ncfg, bench, cfg,
                                         ObsMode::Symbolic)
    }

    /// [`RolloutEngine::launch_native`] with an `--obs` wrapper stack:
    /// `symbolic` keeps the fused fast path; any other mode steps each
    /// replica through the wrapper per step, composing the full
    /// observation record (direction one-hots, goal+rule rows, or the
    /// rasterized RGB image) every transition.
    pub fn launch_native_obs(ncfg: NativeEnvConfig, bench: Arc<Benchmark>,
                             cfg: ShardConfig, obs: ObsMode)
                             -> Result<RolloutEngine> {
        let seed = cfg.seed;
        let faults = Arc::new(FaultPlan::from_env()?);
        let pool = ShardPool::spawn(cfg.shards, move |i| {
            let faults = faults.clone();
            let mut rng = shard_rng(seed, i);
            let mut pool = NativePool::with_tasks(ncfg, bench.clone());
            pool.reset(&bench, &mut rng)
                .with_context(|| format!("resetting native shard {i}"))?;
            let stepper = match obs {
                ObsMode::Symbolic => NativeStepper::Fused(pool),
                mode => {
                    let env = mode.wrap(pool);
                    let bufs = RolloutBufs::for_env(env.as_ref());
                    NativeStepper::Wrapped { env, bufs }
                }
            };
            Ok(NativeReplica {
                shard: i,
                stepper,
                rng,
                b: ncfg.b,
                t: ncfg.t,
                faults,
            })
        })?;
        let family = EnvFamily {
            h: ncfg.params.h,
            w: ncfg.params.w,
            mr: ncfg.params.max_rules,
            mi: ncfg.params.max_init,
            b: ncfg.b,
        };
        Ok(RolloutEngine {
            pool: EnginePool::Native(pool),
            family,
            t: ncfg.t,
            cfg,
        })
    }

    /// Spin up `cfg.shards` replicas over *caller-supplied*
    /// [`BatchEnvironment`]s — the `--backend server:ADDR` hook, and
    /// the generic seam for any future remote/exotic engine. `make`
    /// runs on each shard's own thread with the shard's canonical
    /// `shard_rng(seed, i)` stream; it must return an already-reset
    /// environment (consuming rng state exactly as the native reset
    /// would, so the downstream action draws stay bitwise-aligned
    /// with the in-process backends). Chunks then step through
    /// `rollout_batch` like any wrapped native replica: same shard
    /// topology, same overlap pipeline, same ChunkStats.
    pub fn launch_batch_envs<F>(make: F, b: usize, t: usize,
                                family: EnvFamily, cfg: ShardConfig)
                                -> Result<RolloutEngine>
    where
        F: Fn(usize, &mut Rng) -> Result<Box<dyn BatchEnvironment>>
            + Send
            + Sync
            + 'static,
    {
        let seed = cfg.seed;
        let faults = Arc::new(FaultPlan::from_env()?);
        let pool = ShardPool::spawn(cfg.shards, move |i| {
            let faults = faults.clone();
            let mut rng = shard_rng(seed, i);
            let env = make(i, &mut rng)
                .with_context(|| format!("building shard {i} env"))?;
            let bufs = RolloutBufs::for_env(env.as_ref());
            Ok(NativeReplica {
                shard: i,
                stepper: NativeStepper::Wrapped { env, bufs },
                rng,
                b,
                t,
                faults,
            })
        })?;
        Ok(RolloutEngine {
            pool: EnginePool::Native(pool),
            family,
            t,
            cfg,
        })
    }

    pub fn shards(&self) -> usize {
        match &self.pool {
            EnginePool::Xla(p) => p.shards(),
            EnginePool::Native(p) => p.shards(),
        }
    }

    /// Collect `rounds` rollout chunks *per shard*, invoking `consume`
    /// for every finished chunk, and return the totals.
    ///
    /// Overlap off: lockstep rounds, chunks consumed in (round, shard)
    /// order. Overlap on: double-buffered free-running pipeline, chunks
    /// consumed in completion order.
    pub fn collect<C>(&self, rounds: usize, mut consume: C)
                      -> Result<RolloutTotals>
    where
        C: FnMut(&ChunkStats),
    {
        match &self.pool {
            EnginePool::Xla(p) => {
                collect_over(p, self.cfg.overlap, rounds, &mut consume)
            }
            EnginePool::Native(p) => {
                collect_over(p, self.cfg.overlap, rounds, &mut consume)
            }
        }
    }

    /// `collect` with windowed progress reporting: chunk stats
    /// accumulate into a window aggregate; every `window` chunks the
    /// completed window is reported (aggregate steps/sec over that
    /// window) and a fresh one starts.
    pub fn collect_windowed<R>(&self, rounds: usize, window: usize,
                               mut report: R) -> Result<RolloutTotals>
    where
        R: FnMut(usize, &RolloutTotals),
    {
        let mut acc = RolloutTotals::default();
        let mut in_window = 0usize;
        let mut windows = 0usize;
        let t_window = WallTimer::start();
        let mut last_report = 0.0f64;
        let totals = self.collect(rounds, |c| {
            acc.absorb(c);
            in_window += 1;
            if in_window == window {
                let now = t_window.elapsed_secs();
                acc.elapsed = now - last_report;
                last_report = now;
                report(windows, &std::mem::take(&mut acc));
                in_window = 0;
                windows += 1;
            }
        })?;
        if in_window > 0 {
            let now = t_window.elapsed_secs();
            acc.elapsed = now - last_report;
            report(windows, &acc);
        }
        Ok(totals)
    }
}

/// Backend-generic collection loop: the lockstep collective (overlap
/// off) and the depth-2 double-buffered pipeline (overlap on), over any
/// `RolloutReplica` pool. This is the single implementation both the
/// AOT and native backends run, so the overlap determinism contract is
/// shared by construction.
fn collect_over<W, C>(pool: &ShardPool<W>, overlap: Overlap,
                      rounds: usize, consume: &mut C)
                      -> Result<RolloutTotals>
where
    W: RolloutReplica,
    C: FnMut(&ChunkStats),
{
    let t0 = WallTimer::start();
    let mut totals = RolloutTotals::default();
    match overlap {
        Overlap::Off => {
            for round in 0..rounds {
                // broadcast errors cleanly if a shard worker died (the
                // panic cause is reported once by the pool teardown);
                // a replica-level Err rides inside the per-shard result
                let stats = pool
                    .broadcast(move |_, w| w.rollout_chunk(round))
                    .with_context(|| {
                        format!("rollout collection round {round}")
                    })?;
                for s in stats {
                    let s = s?;
                    totals.absorb(&s);
                    consume(&s);
                }
            }
        }
        Overlap::On => {
            let shards = pool.shards();
            let (res_tx, res_rx) = channel::<Result<ChunkStats>>();
            let mut next_round = vec![0usize; shards];
            let dispatch = |shard: usize, round: usize| {
                let tx = res_tx.clone();
                // a failed submit means the worker already died; its
                // earlier panic Err is in flight on res_rx, so dropping
                // this job is safe — the consumer errors out below
                let _ = pool.submit(shard, move |w| {
                    // Every dispatched job sends exactly once, even
                    // if the chunk panics — otherwise the consumer
                    // below would wait forever for a message from a
                    // dead worker (it holds a sender itself, so the
                    // channel never closes).
                    let r = std::panic::catch_unwind(
                        std::panic::AssertUnwindSafe(|| {
                            w.rollout_chunk(round)
                        }),
                    );
                    match r {
                        Ok(res) => {
                            let _ = tx.send(res);
                        }
                        Err(p) => {
                            let _ = tx.send(Err(anyhow::anyhow!(
                                "shard {shard} panicked during rollout \
                                 round {round}: {}",
                                panic_message(p.as_ref())
                            )));
                            std::panic::resume_unwind(p);
                        }
                    }
                });
            };
            for shard in 0..shards {
                for _ in 0..PIPELINE_DEPTH.min(rounds) {
                    dispatch(shard, next_round[shard]);
                    next_round[shard] += 1;
                }
            }
            for _ in 0..shards * rounds {
                let s = res_rx
                    .recv()
                    .context("rollout result channel closed: every \
                              shard sender dropped mid-collection")??;
                // Refill this shard's pipeline before consuming, so
                // the shard steps buffer t+1 while we drain buffer t.
                if next_round[s.shard] < rounds {
                    dispatch(s.shard, next_round[s.shard]);
                    next_round[s.shard] += 1;
                }
                totals.absorb(&s);
                consume(&s);
            }
        }
    }
    totals.elapsed = t0.elapsed_secs();
    Ok(totals)
}
