//! Native RL² PPO training: the pure-Rust analogue of the fused
//! `train_iter` artifact, driving the [`crate::nn`] GRU actor-critic
//! over a [`NativePool`] batch. `xmgrid train --backend native` runs
//! this on a fresh checkout — no HLO artifacts, no PJRT.
//!
//! One [`NativeTrainer::train_iter`] is: a T-step on-policy rollout
//! with the RL² carry (hidden state + prev-action/prev-reward, reset
//! at episode boundaries per paper §2.1), GAE over the window, then
//! `epochs × minibatches` clipped-PPO updates with BPTT through the
//! GRU. Everything is serial and fixed-order on the learner side, so a
//! run is bitwise-reproducible for a fixed seed at any `--threads`
//! count (the thread pool only steps envs, under the
//! [`super::workers`] equivalence contract).
//!
//! [`NativeShardedTrainer`] mirrors [`super::trainer::ShardedTrainer`]
//! on the host thread: per-iteration basis broadcast, per-shard delta,
//! fixed-order averaging into the master, periodic atomic
//! checkpoints via the shared [`TrainCheckpoint`] codec. Replicas run
//! serially in ascending shard order (the native stack has no device
//! axis to hide latency on), which keeps the reduction order — and
//! therefore the master parameters — identical to a one-shard-at-a-
//! time replay.

use std::sync::Arc;

use anyhow::{bail, ensure, Context, Result};

use crate::env::api::{BatchEnvironment, ObsMode};
use crate::env::state::TaskSource;
use crate::nn::loss::gae;
use crate::nn::math::categorical;
use crate::nn::model::{network_step, StepScratch, NUM_PARAMS};
use crate::nn::{ppo_update, Adam, MiniBatch, ModelDims, Params,
                UpdateBufs};
use crate::runtime::Tensor;
use crate::util::rng::Rng;

use super::checkpoint::{decode_env_snapshot, encode_env_snapshot,
                        save_checkpoint, TrainCheckpoint, TrainerState};
use super::config::{ShardConfig, TrainConfig};
use super::metrics::reduce_iter_metrics;
use super::native::{NativeEnvConfig, NativePool};
use super::rollout::shard_seed;
use super::shard::{add_params, average_param_tensors, sub_params};
use super::trainer::{CheckpointPlan, IterMetrics};

/// Shape of one native training replica: the vectorized env family
/// plus the learner knobs the artifact metadata would otherwise carry.
#[derive(Clone, Debug)]
pub struct NativeTrainerConfig {
    /// env family: batch `b`, rollout window `t`, stepping threads
    pub env: NativeEnvConfig,
    /// observation layout (`--obs symbolic|dir|rules-goals`); the
    /// wrapper extras enter the trunk input as raw values
    pub obs: ObsMode,
    /// model hyper-shape; `None` → the reference dims
    /// ([`ModelDims::reference`]) for this env's view/extra widths
    pub model: Option<ModelDims>,
    /// PPO epochs per iteration (the XLA `train_update` is 1)
    pub epochs: usize,
    /// env-column minibatches per epoch (must divide the batch)
    pub minibatches: usize,
}

/// Wrapper-extra layout, resolved once at construction so the rollout
/// hot loop never re-matches on [`ObsMode`] (and the unsupported `rgb`
/// arm is rejected before any buffer exists).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum ExtraKind {
    None,
    /// 4-wide facing-direction one-hot (`DirectionObs` semantics)
    Direction,
    /// encoded goal+rules task row (`RulesAndGoalsObs` semantics)
    TaskRow,
}

/// Fill `dst` (`[B, obs_len]`) with model-ready observation rows: the
/// raw symbolic cells followed by the wrapper extras, matching the
/// corresponding `ObsMode` wrapper bit for bit.
fn assemble_rows(pool: &NativePool, kind: ExtraKind, dm: &ModelDims,
                 cur_obs: &[i32], dir_buf: &mut [i32],
                 task_buf: &mut [i32], dst: &mut [i32]) {
    let b = pool.cfg.b;
    let vv2 = dm.v * dm.v * 2;
    let ol = dm.obs_len();
    debug_assert_eq!(cur_obs.len(), b * vv2);
    debug_assert_eq!(dst.len(), b * ol);
    match kind {
        ExtraKind::None => dst.copy_from_slice(cur_obs),
        ExtraKind::Direction => {
            pool.agent_dirs_into(dir_buf);
            for i in 0..b {
                let row = &mut dst[i * ol..(i + 1) * ol];
                row[..vv2]
                    .copy_from_slice(&cur_obs[i * vv2..(i + 1) * vv2]);
                for x in row[vv2..].iter_mut() {
                    *x = 0;
                }
                row[vv2 + dir_buf[i].rem_euclid(4) as usize] = 1;
            }
        }
        ExtraKind::TaskRow => {
            let rl = dm.extra;
            pool.task_rows_into(task_buf);
            for i in 0..b {
                let row = &mut dst[i * ol..(i + 1) * ol];
                row[..vv2]
                    .copy_from_slice(&cur_obs[i * vv2..(i + 1) * vv2]);
                row[vv2..]
                    .copy_from_slice(&task_buf[i * rl..(i + 1) * rl]);
            }
        }
    }
}

/// Checked f32-tensor view for checkpoint restoration.
fn want_f32<'a>(t: &'a Tensor, what: &str, n: usize)
                -> Result<&'a [f32]> {
    match t {
        Tensor::F32(v) if v.len() == n => Ok(v),
        Tensor::F32(v) => bail!(
            "checkpoint {what} has {} values, expected {n}", v.len()),
        other => bail!("checkpoint {what} is {:?}, expected f32",
                       other.dtype()),
    }
}

/// Checked i32-tensor view for checkpoint restoration.
fn want_i32<'a>(t: &'a Tensor, what: &str, n: usize)
                -> Result<&'a [i32]> {
    match t {
        Tensor::I32(v) if v.len() == n => Ok(v),
        Tensor::I32(v) => bail!(
            "checkpoint {what} has {} values, expected {n}", v.len()),
        other => bail!("checkpoint {what} is {:?}, expected i32",
                       other.dtype()),
    }
}

/// One native training replica: envs, model, optimizer, RL² carry and
/// all rollout/update buffers (allocated once; the iteration hot path
/// allocates nothing).
pub struct NativeTrainer {
    pub cfg: TrainConfig,
    pub dims: ModelDims,
    pool: NativePool,
    tasks: Arc<dyn TaskSource>,
    extra_kind: ExtraKind,
    t_len: usize,
    b: usize,
    epochs: usize,
    minibatches: usize,
    pub params: Params,
    adam: Adam,
    pub rng: Rng,
    pub iter: usize,
    ready: bool,
    // --- RL² carry (between iterations) ---
    prev_a: Vec<i32>,
    prev_r: Vec<f32>,
    done_prev: Vec<i32>,
    h: Vec<f32>,
    /// latest raw symbolic observations `[B, V, V, 2]`
    cur_obs: Vec<i32>,
    // --- rollout storage, flat `[T, B]` ---
    obs_seq: Vec<i32>,
    prev_a_seq: Vec<i32>,
    prev_r_seq: Vec<f32>,
    done_seq: Vec<i32>,
    actions_seq: Vec<i32>,
    logp_seq: Vec<f32>,
    rewards_seq: Vec<f32>,
    done_post: Vec<i32>,
    values_seq: Vec<f32>,
    adv: Vec<f32>,
    targets: Vec<f32>,
    /// hidden carry at the window start (minibatch `h0` source)
    h_start: Vec<f32>,
    // --- per-step staging ---
    logits: Vec<f32>,
    values_step: Vec<f32>,
    h_next: Vec<f32>,
    h_discard: Vec<f32>,
    last_rows: Vec<i32>,
    last_value: Vec<f32>,
    scratch: StepScratch,
    lp_scratch: Vec<f32>,
    dir_buf: Vec<i32>,
    task_buf: Vec<i32>,
    step_obs: Vec<i32>,
    rewards: Vec<f32>,
    dones: Vec<bool>,
    trial_dones: Vec<bool>,
    reward_acc: Vec<f64>,
    // --- update machinery ---
    perm: Vec<usize>,
    mb: MiniBatch,
    bufs: UpdateBufs,
}

impl NativeTrainer {
    /// Build a replica. Parameters are initialized from a stream split
    /// off the trainer RNG (so the whole run is a function of
    /// `cfg.train_seed` alone); under [`NativeShardedTrainer`] the
    /// first basis broadcast replaces them with the shard-0 master.
    pub fn new(tcfg: NativeTrainerConfig, tasks: Arc<dyn TaskSource>,
               cfg: TrainConfig) -> Result<NativeTrainer> {
        let env = tcfg.env;
        let (b, t_len) = (env.b, env.t);
        ensure!(b > 0 && t_len > 0,
                "native training needs batch and steps >= 1");
        ensure!(tcfg.epochs >= 1, "--epochs must be >= 1");
        ensure!(
            tcfg.minibatches >= 1 && b % tcfg.minibatches == 0,
            "--minibatches ({}) must divide the env batch ({b})",
            tcfg.minibatches
        );
        let extra_kind = match tcfg.obs {
            ObsMode::Symbolic => ExtraKind::None,
            ObsMode::Direction => ExtraKind::Direction,
            ObsMode::RulesGoals => ExtraKind::TaskRow,
            ObsMode::Rgb => bail!(
                "--backend native trains on symbolic observation \
                 layouts (--obs symbolic|dir|rules-goals); rgb is a \
                 render-only surface"
            ),
        };
        let extra = match extra_kind {
            ExtraKind::None => 0,
            ExtraKind::Direction => 4,
            ExtraKind::TaskRow => env.params.task_row_len(),
        };
        let v = env.params.opts.view_size;
        let dims = tcfg
            .model
            .unwrap_or_else(|| ModelDims::reference(v, extra));
        ensure!(dims.v == v, "model view size {} != env view {v}",
                dims.v);
        ensure!(
            dims.extra == extra,
            "model extra width {} != the {extra} values --obs {} \
             appends",
            dims.extra,
            tcfg.obs
        );
        let pool = NativePool::with_task_source(env, tasks.clone());
        let na = pool.action_spec().num_actions;
        ensure!(dims.a == na,
                "model emits {} action logits, env has {na}", dims.a);

        let mut rng = Rng::new(cfg.train_seed);
        let params = {
            let mut prng = rng.split();
            Params::init(dims, &mut prng)
        };
        let (ol, hh, a) = (dims.obs_len(), dims.h, dims.a);
        let vv2 = dims.v * dims.v * 2;
        let n = t_len * b;
        let bm = b / tcfg.minibatches;
        let nm = t_len * bm;
        let task_len = if extra_kind == ExtraKind::TaskRow {
            b * extra
        } else {
            0
        };
        Ok(NativeTrainer {
            cfg,
            dims,
            pool,
            tasks,
            extra_kind,
            t_len,
            b,
            epochs: tcfg.epochs,
            minibatches: tcfg.minibatches,
            adam: Adam::new(&dims),
            params,
            rng,
            iter: 0,
            ready: false,
            prev_a: vec![0; b],
            prev_r: vec![0.0; b],
            done_prev: vec![1; b],
            h: vec![0.0; b * hh],
            cur_obs: vec![0; b * vv2],
            obs_seq: vec![0; n * ol],
            prev_a_seq: vec![0; n],
            prev_r_seq: vec![0.0; n],
            done_seq: vec![0; n],
            actions_seq: vec![0; n],
            logp_seq: vec![0.0; n],
            rewards_seq: vec![0.0; n],
            done_post: vec![0; n],
            values_seq: vec![0.0; n],
            adv: vec![0.0; n],
            targets: vec![0.0; n],
            h_start: vec![0.0; b * hh],
            logits: vec![0.0; b * a],
            values_step: vec![0.0; b],
            h_next: vec![0.0; b * hh],
            h_discard: vec![0.0; b * hh],
            last_rows: vec![0; b * ol],
            last_value: vec![0.0; b],
            scratch: StepScratch::new(&dims),
            lp_scratch: vec![0.0; a],
            dir_buf: vec![0; b],
            task_buf: vec![0; task_len],
            step_obs: vec![0; b * vv2],
            rewards: vec![0.0; b],
            dones: vec![false; b],
            trial_dones: vec![false; b],
            reward_acc: vec![0.0; b],
            perm: (0..b).collect(),
            mb: MiniBatch {
                t_len,
                bm,
                obs: vec![0; nm * ol],
                prev_a: vec![0; nm],
                prev_r: vec![0.0; nm],
                done: vec![0; nm],
                actions: vec![0; nm],
                old_logp: vec![0.0; nm],
                adv: vec![0.0; nm],
                targets: vec![0.0; nm],
                h0: vec![0.0; bm * hh],
            },
            bufs: UpdateBufs::new(dims, t_len, bm),
        })
    }

    /// Overwrite the policy/value parameters (the broadcast half of
    /// the all-reduce). Adam moments stay local, like the XLA path.
    pub fn set_params(&mut self, basis: &[Tensor]) -> Result<()> {
        self.params = Params::from_tensors(self.dims, basis)?;
        Ok(())
    }

    /// Sample fresh tasks for every env, reset the pool, and zero the
    /// RL² carry (episode start: `done_prev = 1` resets the hidden
    /// state inside the first `network_step`).
    pub fn resample_tasks(&mut self) -> Result<()> {
        let tasks = self.tasks.clone();
        let mut rng = self.rng.split();
        self.pool.reset_from(&tasks, &mut rng)?;
        self.cur_obs.copy_from_slice(self.pool.obs());
        for x in self.prev_a.iter_mut() {
            *x = 0;
        }
        for x in self.prev_r.iter_mut() {
            *x = 0.0;
        }
        for x in self.done_prev.iter_mut() {
            *x = 1;
        }
        for x in self.h.iter_mut() {
            *x = 0.0;
        }
        self.ready = true;
        Ok(())
    }

    /// One PPO iteration: collect `T × B` on-policy steps, GAE, then
    /// `epochs × minibatches` optimizer steps. Metrics are averaged
    /// over the updates (f64, fixed dispatch order).
    pub fn train_iter(&mut self) -> Result<IterMetrics> {
        ensure!(self.ready, "call resample_tasks before train_iter");
        let dm = self.dims;
        let (t_len, b) = (self.t_len, self.b);
        let (ol, a, hh) = (dm.obs_len(), dm.a, dm.h);
        self.h_start.copy_from_slice(&self.h);
        for x in self.reward_acc.iter_mut() {
            *x = 0.0;
        }
        let (mut episodes, mut trials) = (0i64, 0i64);

        // --- rollout ---
        for t in 0..t_len {
            let lo = t * b;
            self.prev_a_seq[lo..lo + b].copy_from_slice(&self.prev_a);
            self.prev_r_seq[lo..lo + b].copy_from_slice(&self.prev_r);
            self.done_seq[lo..lo + b].copy_from_slice(&self.done_prev);
            assemble_rows(&self.pool, self.extra_kind, &dm,
                          &self.cur_obs, &mut self.dir_buf,
                          &mut self.task_buf,
                          &mut self.obs_seq[lo * ol..(lo + b) * ol]);
            network_step(&self.params,
                         &self.obs_seq[lo * ol..(lo + b) * ol],
                         &self.prev_a, &self.prev_r, &self.done_prev,
                         &self.h, &mut self.logits,
                         &mut self.values_step, &mut self.h_next,
                         &mut self.scratch, None);
            self.values_seq[lo..lo + b]
                .copy_from_slice(&self.values_step);
            // serial env-order sampling: exactly one rng draw per env
            for i in 0..b {
                let act = categorical(&mut self.rng,
                                      &self.logits[i * a..(i + 1) * a],
                                      &mut self.lp_scratch);
                self.actions_seq[lo + i] = act as i32;
                self.logp_seq[lo + i] = self.lp_scratch[act];
            }
            std::mem::swap(&mut self.h, &mut self.h_next);
            self.pool.step(&self.actions_seq[lo..lo + b],
                           &mut self.step_obs, &mut self.rewards,
                           &mut self.dones, &mut self.trial_dones)?;
            for i in 0..b {
                let r = self.rewards[i];
                self.reward_acc[i] += r as f64;
                let d = self.dones[i];
                if d {
                    episodes += 1;
                }
                if self.trial_dones[i] {
                    trials += 1;
                }
                self.prev_a[i] = self.actions_seq[lo + i];
                self.prev_r[i] = r;
                self.done_prev[i] = d as i32;
                self.rewards_seq[lo + i] = r;
                self.done_post[lo + i] = d as i32;
            }
            self.cur_obs.copy_from_slice(&self.step_obs);
        }

        // --- bootstrap value + GAE (episode dones gate the carry) ---
        assemble_rows(&self.pool, self.extra_kind, &dm, &self.cur_obs,
                      &mut self.dir_buf, &mut self.task_buf,
                      &mut self.last_rows);
        network_step(&self.params, &self.last_rows, &self.prev_a,
                     &self.prev_r, &self.done_prev, &self.h,
                     &mut self.logits, &mut self.values_step,
                     &mut self.h_discard, &mut self.scratch, None);
        self.last_value.copy_from_slice(&self.values_step);
        gae(&self.rewards_seq, &self.values_seq, &self.done_post,
            &self.last_value, self.cfg.gamma, self.cfg.gae_lambda,
            t_len, b, &mut self.adv, &mut self.targets);

        // --- PPO epochs over env-column minibatches ---
        let hpv = self.cfg.hp_vector();
        let mut hp = [0.0f32; 8];
        hp.copy_from_slice(&hpv);
        let bm = b / self.minibatches;
        let mut acc = [0.0f64; 8];
        let mut updates = 0usize;
        for _ in 0..self.epochs {
            for (i, p) in self.perm.iter_mut().enumerate() {
                *p = i;
            }
            // fixed permutation from the private learner stream —
            // independent of thread count
            self.rng.shuffle(&mut self.perm);
            for g in 0..self.minibatches {
                let envs = &self.perm[g * bm..(g + 1) * bm];
                for t in 0..t_len {
                    for (j, &e) in envs.iter().enumerate() {
                        let src = t * b + e;
                        let dst = t * bm + j;
                        self.mb.obs[dst * ol..(dst + 1) * ol]
                            .copy_from_slice(
                                &self.obs_seq
                                    [src * ol..(src + 1) * ol]);
                        self.mb.prev_a[dst] = self.prev_a_seq[src];
                        self.mb.prev_r[dst] = self.prev_r_seq[src];
                        self.mb.done[dst] = self.done_seq[src];
                        self.mb.actions[dst] = self.actions_seq[src];
                        self.mb.old_logp[dst] = self.logp_seq[src];
                        self.mb.adv[dst] = self.adv[src];
                        self.mb.targets[dst] = self.targets[src];
                    }
                }
                for (j, &e) in envs.iter().enumerate() {
                    self.mb.h0[j * hh..(j + 1) * hh].copy_from_slice(
                        &self.h_start[e * hh..(e + 1) * hh]);
                }
                let s = ppo_update(&mut self.params, &mut self.adam,
                                   &self.mb, &hp, &mut self.bufs);
                acc[0] += s.loss.total as f64;
                acc[1] += s.loss.pi_loss as f64;
                acc[2] += s.loss.v_loss as f64;
                acc[3] += s.loss.entropy as f64;
                acc[4] += s.loss.approx_kl as f64;
                acc[5] += s.loss.clip_frac as f64;
                acc[6] += s.grad_norm as f64;
                acc[7] += s.loss.adv_std as f64;
                updates += 1;
            }
        }

        let nu = updates as f64;
        let mut reward_sum = 0.0f64; // env-major fixed-order reduce
        for &x in self.reward_acc.iter() {
            reward_sum += x;
        }
        self.iter += 1;
        Ok(IterMetrics {
            total_loss: (acc[0] / nu) as f32,
            pi_loss: (acc[1] / nu) as f32,
            v_loss: (acc[2] / nu) as f32,
            entropy: (acc[3] / nu) as f32,
            approx_kl: (acc[4] / nu) as f32,
            clip_frac: (acc[5] / nu) as f32,
            grad_norm: (acc[6] / nu) as f32,
            adv_std: (acc[7] / nu) as f32,
            reward_sum: reward_sum as f32,
            trials,
            episodes,
            env_steps: (t_len * b) as u64,
        })
    }

    /// Capture everything the next [`train_iter`](Self::train_iter)
    /// depends on — same [`TrainerState`] container as the XLA path
    /// (env state via the snapshot codec), so the checkpoint file
    /// format is shared.
    pub fn state_snapshot(&mut self) -> Result<TrainerState> {
        let snap = self.pool.snapshot()?;
        Ok(TrainerState {
            params: self.params.to_tensors(),
            m: self
                .adam
                .m
                .iter()
                .map(|v| Tensor::F32(v.clone()))
                .collect(),
            v: self
                .adam
                .v
                .iter()
                .map(|v| Tensor::F32(v.clone()))
                .collect(),
            t: Tensor::I32(vec![self.adam.t as i32]),
            env_state: encode_env_snapshot(&snap),
            last_obs: Tensor::I32(self.cur_obs.clone()),
            obs: Tensor::I32(self.cur_obs.clone()),
            prev_a: Tensor::I32(self.prev_a.clone()),
            prev_r: Tensor::F32(self.prev_r.clone()),
            done_prev: Tensor::I32(self.done_prev.clone()),
            h: Tensor::F32(self.h.clone()),
            rng: self.rng.state(),
            task_rng: None,
            iter: self.iter as u64,
        })
    }

    /// Restore a [`state_snapshot`](Self::state_snapshot); the resumed
    /// replica continues bit-for-bit. Shape mismatches are clean
    /// errors, never a silently-wrong resume.
    pub fn restore_state(&mut self, s: &TrainerState) -> Result<()> {
        self.params = Params::from_tensors(self.dims, &s.params)
            .context("checkpoint params do not fit this model")?;
        ensure!(s.m.len() == NUM_PARAMS && s.v.len() == NUM_PARAMS,
                "checkpoint has {}/{} moment tensors, expected {}",
                s.m.len(), s.v.len(), NUM_PARAMS);
        for i in 0..NUM_PARAMS {
            let n = self.dims.param_len(i);
            self.adam.m[i]
                .copy_from_slice(want_f32(&s.m[i], "adam m", n)?);
            self.adam.v[i]
                .copy_from_slice(want_f32(&s.v[i], "adam v", n)?);
        }
        self.adam.t = want_i32(&s.t, "adam t", 1)?[0] as i64;
        let snap = decode_env_snapshot(&s.env_state)
            .context("decoding checkpoint env state")?;
        self.pool.restore(&snap)?;
        let b = self.b;
        let vv2 = self.dims.v * self.dims.v * 2;
        self.cur_obs
            .copy_from_slice(want_i32(&s.obs, "obs", b * vv2)?);
        self.prev_a
            .copy_from_slice(want_i32(&s.prev_a, "prev_a", b)?);
        self.prev_r
            .copy_from_slice(want_f32(&s.prev_r, "prev_r", b)?);
        self.done_prev
            .copy_from_slice(want_i32(&s.done_prev, "done_prev", b)?);
        self.h.copy_from_slice(want_f32(&s.h, "h", b * self.dims.h)?);
        self.rng = Rng::from_state(s.rng);
        self.iter = s.iter as usize;
        self.ready = true;
        Ok(())
    }
}

/// Data-parallel native training: one [`NativeTrainer`] replica per
/// shard, run serially in ascending shard order each iteration, with
/// the same basis-broadcast / delta-average / master-fold reduction
/// (and the same [`TrainCheckpoint`] on-disk format) as the XLA
/// [`super::trainer::ShardedTrainer`]. Overlap has no effect here —
/// there is no device axis to pipeline against — so every iteration
/// is the lockstep collective.
pub struct NativeShardedTrainer {
    replicas: Vec<NativeTrainer>,
    pub cfg: ShardConfig,
    pub train_cfg: TrainConfig,
    /// host-side master parameters (averaged across shards)
    pub master: Vec<Tensor>,
    pub t_len: usize,
    pub b: usize,
    /// iterations completed (reduced into the master)
    pub iters_done: usize,
    /// optional periodic crash-safe checkpointing
    pub checkpoint: Option<CheckpointPlan>,
}

impl NativeShardedTrainer {
    /// Spin up `cfg.shards` replicas. `cfg.seed` is the single run
    /// seed: shard `i` trains with `shard_seed(cfg.seed, i)` (any
    /// `train_cfg.train_seed` is overwritten so the two knobs cannot
    /// drift apart); the master starts from shard 0's deterministic
    /// parameter init and every replica receives it at the first basis
    /// broadcast.
    pub fn launch(tcfg: NativeTrainerConfig, tasks: Arc<dyn TaskSource>,
                  cfg: ShardConfig, mut train_cfg: TrainConfig)
                  -> Result<NativeShardedTrainer> {
        ensure!(cfg.shards >= 1, "--shards must be >= 1");
        train_cfg.train_seed = cfg.seed;
        let mut replicas = Vec::with_capacity(cfg.shards);
        for i in 0..cfg.shards {
            let mut tc = train_cfg;
            tc.train_seed = shard_seed(cfg.seed, i);
            let mut tr = NativeTrainer::new(tcfg.clone(),
                                            tasks.clone(), tc)
                .with_context(|| format!("building native shard {i}"))?;
            tr.resample_tasks()
                .with_context(|| format!("initial resample, shard {i}"))?;
            replicas.push(tr);
        }
        let master = replicas[0].params.to_tensors();
        Ok(NativeShardedTrainer {
            replicas,
            cfg,
            train_cfg,
            master,
            t_len: tcfg.env.t,
            b: tcfg.env.b,
            iters_done: 0,
            checkpoint: None,
        })
    }

    pub fn shards(&self) -> usize {
        self.replicas.len()
    }

    /// Environment steps contributed per iteration across all shards.
    pub fn steps_per_iter(&self) -> u64 {
        (self.t_len * self.b * self.replicas.len()) as u64
    }

    /// Restore a saved [`TrainCheckpoint`]: master parameters, reduced
    /// iteration count, and every replica's full state. Must be
    /// launched with the same shard count the checkpoint was written
    /// with.
    pub fn restore(&mut self, ckpt: &TrainCheckpoint) -> Result<()> {
        ensure!(
            ckpt.shards.len() == self.replicas.len(),
            "checkpoint holds {} shard states but the trainer is \
             running {} shards — resume with --shards {}",
            ckpt.shards.len(),
            self.replicas.len(),
            ckpt.shards.len()
        );
        ensure!(
            ckpt.master.len() == self.master.len(),
            "checkpoint has {} master tensors, expected {}",
            ckpt.master.len(),
            self.master.len()
        );
        for (s, st) in ckpt.shards.iter().enumerate() {
            self.replicas[s]
                .restore_state(st)
                .with_context(|| format!("restoring shard {s}"))?;
        }
        self.master = ckpt.master.clone();
        self.iters_done = ckpt.iters_done as usize;
        Ok(())
    }

    /// Snapshot every replica into an in-memory [`TrainCheckpoint`]
    /// for the current `iters_done`.
    pub fn snapshot(&mut self) -> Result<TrainCheckpoint> {
        let mut shards = Vec::with_capacity(self.replicas.len());
        for (s, r) in self.replicas.iter_mut().enumerate() {
            shards.push(r.state_snapshot().with_context(|| {
                format!("snapshotting shard {s}")
            })?);
        }
        Ok(TrainCheckpoint {
            iters_done: self.iters_done as u64,
            master: self.master.clone(),
            shards,
        })
    }

    /// Run `iters` training iterations, calling `consume(iter,
    /// metrics)` with the cross-shard reduced metrics after each
    /// iteration is folded into the master. A `consume` error aborts
    /// training and is returned.
    pub fn train<C>(&mut self, iters: usize, mut consume: C)
                    -> Result<()>
    where
        C: FnMut(usize, &IterMetrics) -> Result<()>,
    {
        let resample_every = self.train_cfg.task_resample_iters.max(1);
        let every = match &self.checkpoint {
            Some(p) if p.every > 0 => Some(p.every),
            _ => None,
        };
        let first = self.iters_done + 1;
        let last = self.iters_done + iters;
        for t in first..=last {
            let resample = t > 1 && (t - 1) % resample_every == 0;
            let basis = self.master.clone();
            let mut deltas = Vec::with_capacity(self.replicas.len());
            let mut metrics = Vec::with_capacity(self.replicas.len());
            // serial, ascending shard order — the reduction order
            // (and thus the master) is the determinism contract
            for (s, r) in self.replicas.iter_mut().enumerate() {
                r.set_params(&basis)
                    .with_context(|| format!("broadcast, shard {s}"))?;
                if resample {
                    r.resample_tasks().with_context(|| {
                        format!("resampling tasks, shard {s}")
                    })?;
                }
                let m = r.train_iter().with_context(|| {
                    format!("training iteration {t}, shard {s}")
                })?;
                deltas.push(sub_params(&r.params.to_tensors(), &basis));
                metrics.push(m);
            }
            let mean_delta = average_param_tensors(deltas);
            add_params(&mut self.master, &mean_delta);
            self.iters_done = t;
            if let Some(e) = every {
                if t % e == 0 {
                    self.write_checkpoint()?;
                }
            }
            let reduced = reduce_iter_metrics(&metrics);
            consume(t, &reduced)?;
        }
        Ok(())
    }

    /// Write an atomic checkpoint for the current `iters_done`.
    fn write_checkpoint(&mut self) -> Result<()> {
        let Some(plan) = &self.checkpoint else {
            return Ok(());
        };
        let (path, faults) = (plan.path.clone(), plan.faults.clone());
        let ckpt = self.snapshot()?;
        save_checkpoint(&path, &ckpt, &faults).with_context(|| {
            format!("checkpointing at iteration {}", self.iters_done)
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchgen::{generate_benchmark, Benchmark, Preset};

    fn tiny_bench() -> Arc<Benchmark> {
        let (rulesets, _) =
            generate_benchmark(&Preset::Trivial.config(), 8).unwrap();
        Arc::new(Benchmark { name: "t".into(), rulesets })
    }

    fn tiny_dims() -> ModelDims {
        ModelDims { v: 5, e: 2, ae: 3, d: 8, h: 6, a: 6, extra: 0 }
    }

    fn tiny_cfg(threads: usize, bench: &Arc<Benchmark>)
                -> NativeTrainerConfig {
        let env = NativeEnvConfig::for_env("XLand-MiniGrid-R1-9x9", 4,
                                           3, bench)
            .unwrap()
            .with_threads(threads);
        NativeTrainerConfig {
            env,
            obs: ObsMode::Symbolic,
            model: Some(tiny_dims()),
            epochs: 2,
            minibatches: 2,
        }
    }

    fn param_bits(p: &Params) -> Vec<u32> {
        p.t.iter()
            .flat_map(|v| v.iter().map(|x| x.to_bits()))
            .collect()
    }

    fn tensor_bits(ts: &[Tensor]) -> Vec<u32> {
        ts.iter()
            .flat_map(|t| t.as_f32().iter().map(|x| x.to_bits()))
            .collect()
    }

    #[test]
    fn train_iter_is_deterministic_and_thread_invariant() {
        let run = |threads: usize| {
            let bench = tiny_bench();
            let tasks: Arc<dyn TaskSource> = bench.clone();
            let mut tr = NativeTrainer::new(tiny_cfg(threads, &bench),
                                            tasks,
                                            TrainConfig::default())
                .unwrap();
            tr.resample_tasks().unwrap();
            let m1 = tr.train_iter().unwrap();
            let m2 = tr.train_iter().unwrap();
            assert!(m1.total_loss.is_finite());
            assert_eq!(m1.env_steps, 4 * 3);
            (param_bits(&tr.params), m1.total_loss.to_bits(),
             m2.total_loss.to_bits(), m2.reward_sum.to_bits())
        };
        let a = run(1);
        assert_eq!(a, run(1), "fixed seed reproduces bitwise");
        assert_eq!(a, run(2), "thread count is invisible");
    }

    #[test]
    fn obs_modes_change_the_input_width() {
        let bench = tiny_bench();
        let tasks: Arc<dyn TaskSource> = bench.clone();
        let mut cfg = tiny_cfg(1, &bench);
        cfg.obs = ObsMode::Direction;
        cfg.model = None; // reference dims with extra=4
        let tr = NativeTrainer::new(cfg.clone(), tasks.clone(),
                                    TrainConfig::default())
            .unwrap();
        assert_eq!(tr.dims.extra, 4);
        cfg.obs = ObsMode::RulesGoals;
        let tr = NativeTrainer::new(cfg.clone(), tasks.clone(),
                                    TrainConfig::default())
            .unwrap();
        assert_eq!(tr.dims.extra,
                   cfg.env.params.task_row_len());
        cfg.obs = ObsMode::Rgb;
        assert!(NativeTrainer::new(cfg, tasks, TrainConfig::default())
            .is_err());
    }

    #[test]
    fn training_with_dir_obs_runs() {
        let bench = tiny_bench();
        let tasks: Arc<dyn TaskSource> = bench.clone();
        let mut cfg = tiny_cfg(1, &bench);
        cfg.obs = ObsMode::Direction;
        cfg.model = Some(ModelDims { extra: 4, ..tiny_dims() });
        let mut tr =
            NativeTrainer::new(cfg, tasks, TrainConfig::default())
                .unwrap();
        tr.resample_tasks().unwrap();
        let m = tr.train_iter().unwrap();
        assert!(m.total_loss.is_finite());
    }

    #[test]
    fn sharded_snapshot_resumes_bitwise() {
        let scfg = ShardConfig { shards: 2, seed: 7,
                                 ..Default::default() };
        let build = || {
            let bench = tiny_bench();
            let tasks: Arc<dyn TaskSource> = bench.clone();
            NativeShardedTrainer::launch(tiny_cfg(1, &bench), tasks,
                                         scfg,
                                         TrainConfig::default())
                .unwrap()
        };
        let mut a = build();
        a.train(1, |_, _| Ok(())).unwrap();
        let ckpt = a.snapshot().unwrap();
        let mut rows_a = Vec::new();
        a.train(2, |t, m| {
            rows_a.push((t, m.total_loss.to_bits(),
                         m.reward_sum.to_bits()));
            Ok(())
        })
        .unwrap();

        let mut b = build();
        b.restore(&ckpt).unwrap();
        assert_eq!(b.iters_done, 1);
        let mut rows_b = Vec::new();
        b.train(2, |t, m| {
            rows_b.push((t, m.total_loss.to_bits(),
                         m.reward_sum.to_bits()));
            Ok(())
        })
        .unwrap();
        assert_eq!(rows_a, rows_b, "resumed metrics identical");
        assert_eq!(tensor_bits(&a.master), tensor_bits(&b.master),
                   "resumed master identical");
    }

    #[test]
    fn restore_rejects_wrong_shard_count() {
        let bench = tiny_bench();
        let tasks: Arc<dyn TaskSource> = bench.clone();
        let scfg = ShardConfig { shards: 2, seed: 7,
                                 ..Default::default() };
        let mut a = NativeShardedTrainer::launch(tiny_cfg(1, &bench),
                                                 tasks.clone(), scfg,
                                                 TrainConfig::default())
            .unwrap();
        let ckpt = a.snapshot().unwrap();
        let one = ShardConfig { shards: 1, seed: 7,
                                ..Default::default() };
        let mut b = NativeShardedTrainer::launch(tiny_cfg(1, &bench),
                                                 tasks, one,
                                                 TrainConfig::default())
            .unwrap();
        let err = b.restore(&ckpt).unwrap_err().to_string();
        assert!(err.contains("--shards 2"), "{err}");
    }
}
