//! Native vectorized backend: drives [`ParVecEnv`] batches from the
//! coordinator with the same shard/RNG discipline as the AOT-backed
//! [`super::pool::EnvPool`] — but with zero artifacts and zero PJRT.
//! This is what makes `xmgrid rollout --backend native` work on a fresh
//! checkout: any registry XLand env family rolls out at full speed with
//! no artifact build step, chunked across `threads` stepping workers
//! per replica (bitwise-identical to serial for any thread count — see
//! [`super::workers`]).

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::benchgen::Benchmark;
use crate::env::api::{ActionSpec, BatchEnvironment, EnvParams, ObsSpec};
use crate::env::layouts::xland_layout;
use crate::env::registry::XLAND_ENVS;
use crate::env::state::{default_max_steps, Ruleset, TaskSource};
use crate::env::Grid;
use crate::util::fault::RetryPolicy;
use crate::util::rng::Rng;

use crate::env::vector::VecEnvSnapshot;

use super::workers::ParVecEnv;

/// Shape of a native vectorized env family: the shared [`EnvParams`]
/// (grid dims, table capacities, view options — the same struct
/// `VecEnvConfig` aliases) plus the layout/batch/schedule knobs the
/// coordinator adds. The artifact-free analogue of
/// [`super::pool::EnvFamily`].
#[derive(Clone, Copy, Debug)]
pub struct NativeEnvConfig {
    /// shared env-shape params (single source for H/W/MR/MI/view)
    pub params: EnvParams,
    pub rooms: usize,
    /// env batch per replica
    pub b: usize,
    /// steps per rollout chunk (the fused-T analogue)
    pub t: usize,
    /// stepping worker threads per replica (`--threads`); the batch is
    /// chunked across them, output bitwise-independent of the count
    pub threads: usize,
    /// supervised-recovery policy for worker panics (`--max-retries` /
    /// `--retry-backoff-ms`); recovery replays deterministically, so it
    /// never changes results — only how many worker deaths are survived
    pub retry: RetryPolicy,
}

impl NativeEnvConfig {
    /// Derive the family from a registry XLand env name plus the
    /// benchmark that will supply tasks (its max rule / init-tile counts
    /// size the fixed-width tables). One stepping thread by default;
    /// see [`NativeEnvConfig::with_threads`].
    pub fn for_env(name: &str, b: usize, t: usize, bench: &Benchmark)
                   -> Result<NativeEnvConfig> {
        NativeEnvConfig::for_tasks(name, b, t, bench)
    }

    /// [`NativeEnvConfig::for_env`] over any [`TaskSource`] — a whole
    /// benchmark or a derived `TaskSlice` split: the rule / init-tile
    /// table capacities are sized to the maxima of exactly the tasks
    /// the pool will draw.
    pub fn for_tasks(name: &str, b: usize, t: usize,
                     tasks: &dyn TaskSource) -> Result<NativeEnvConfig> {
        let spec = match XLAND_ENVS.iter().find(|e| e.name == name) {
            Some(s) => s,
            None => bail!(
                "--backend native rolls out XLand registry families; \
                 `{name}` is not one (see `xmgrid envs`)"
            ),
        };
        if b == 0 || t == 0 {
            bail!("native backend needs batch and steps >= 1");
        }
        let (mut mr, mut mi) = (0usize, 0usize);
        for i in 0..tasks.num_tasks() {
            let rs = tasks.task(i);
            mr = mr.max(rs.rules.len());
            mi = mi.max(rs.init_tiles.len());
        }
        Ok(NativeEnvConfig {
            params: EnvParams::new(spec.h, spec.w, mr, mi),
            rooms: spec.rooms,
            b,
            t,
            threads: 1,
            retry: RetryPolicy::default(),
        })
    }

    /// Chunk the batch across `threads` persistent stepping workers
    /// (clamped to at least 1; `ParVecEnv` further clamps to the batch).
    pub fn with_threads(mut self, threads: usize) -> NativeEnvConfig {
        self.threads = threads.max(1);
        self
    }

    /// Override the supervised worker-recovery policy.
    pub fn with_retry(mut self, retry: RetryPolicy) -> NativeEnvConfig {
        self.retry = retry;
        self
    }
}

/// Host-side analogue of [`super::pool::EnvPool`]: owns a [`ParVecEnv`]
/// batch plus the rollout I/O buffers, and drives the random-policy
/// rollout used by the throughput benches and `xmgrid rollout
/// --backend native`. Data buffers (obs, per-chunk staging, action
/// scratch) are allocated once and recycled; the rollout hot loop
/// costs only the per-chunk job dispatch, never per-step allocation.
///
/// Also one of the four surfaces of the unified
/// [`BatchEnvironment`] API: construct with
/// [`NativePool::with_tasks`] and the trait's `reset` re-layouts and
/// resamples from the installed benchmark.
pub struct NativePool {
    pub cfg: NativeEnvConfig,
    venv: ParVecEnv,
    obs: Vec<i32>,
    /// task source installed at construction (`with_tasks` /
    /// `with_task_source`) — what the trait-level `reset` draws from
    tasks: Option<Arc<dyn TaskSource>>,
}

impl NativePool {
    pub fn new(cfg: NativeEnvConfig) -> NativePool {
        let venv =
            ParVecEnv::with_retry(cfg.params, cfg.b, cfg.threads,
                                  cfg.retry);
        let obs_len = venv.obs_len();
        NativePool { cfg, venv, obs: vec![0; obs_len], tasks: None }
    }

    /// [`NativePool::new`] with the benchmark task distribution as a
    /// first-class constructor input, enabling the self-contained
    /// [`BatchEnvironment::reset`].
    pub fn with_tasks(cfg: NativeEnvConfig, bench: Arc<Benchmark>)
                      -> NativePool {
        NativePool::with_task_source(cfg, bench)
    }

    /// [`NativePool::with_tasks`] over any shared [`TaskSource`] — in
    /// particular a `TaskSlice` split, which installs a held-out task
    /// pool without materializing a second benchmark.
    pub fn with_task_source(cfg: NativeEnvConfig,
                            tasks: Arc<dyn TaskSource>) -> NativePool {
        let mut pool = NativePool::new(cfg);
        pool.tasks = Some(tasks);
        pool
    }

    /// Latest observations, `[B, V, V, 2]` i32.
    pub fn obs(&self) -> &[i32] {
        &self.obs
    }

    /// Mirror of `EnvPool::reset`: per env, a fresh base grid with
    /// re-randomized doors, a ruleset sampled from the benchmark, the
    /// default step limit, and a private RNG stream split off `rng` —
    /// everything a function of the caller's stream only. The benchmark
    /// is also installed as the episode-reset task source, so every
    /// episode draws a fresh task (the §2.1 protocol) instead of
    /// replaying the reset-time ruleset forever.
    pub fn reset(&mut self, bench: &Arc<Benchmark>, rng: &mut Rng)
                 -> Result<()> {
        let tasks: Arc<dyn TaskSource> = bench.clone();
        self.reset_from(&tasks, rng)
    }

    /// [`NativePool::reset`] over any shared [`TaskSource`] (the RNG
    /// draw sequence is identical, so a whole-benchmark source
    /// reproduces the historical `reset` bit for bit).
    pub fn reset_from(&mut self, tasks: &Arc<dyn TaskSource>,
                      rng: &mut Rng) -> Result<()> {
        let b = self.cfg.b;
        let (h, w) = (self.cfg.params.h, self.cfg.params.w);
        let n = tasks.num_tasks();
        assert!(n > 0, "empty task source");
        let rulesets: Vec<&Ruleset> =
            (0..b).map(|_| tasks.task(rng.below(n))).collect();
        let grids: Vec<Grid> = (0..b)
            .map(|_| xland_layout(self.cfg.rooms, h, w, rng))
            .collect();
        let max_steps = vec![default_max_steps(h, w); b];
        let rngs: Vec<Rng> = (0..b).map(|_| rng.split()).collect();
        self.venv.reset_all(&grids, &rulesets, &max_steps, &rngs,
                            &mut self.obs)?;
        self.venv.set_task_source(tasks.clone())
    }

    /// Full-batch env snapshot (chunk snapshots concatenated in global
    /// env order) — what the native trainer checkpoints.
    pub fn snapshot(&mut self) -> Result<VecEnvSnapshot> {
        self.venv.snapshot()
    }

    /// Install a full-batch snapshot (inverse of
    /// [`NativePool::snapshot`]) and re-install the constructor task
    /// source so episode auto-resets keep drawing tasks. Refreshes the
    /// `obs()` cache to the restored state.
    pub fn restore(&mut self, snap: &VecEnvSnapshot) -> Result<()> {
        self.venv.restore(snap)?;
        if let Some(ts) = self.tasks.clone() {
            self.venv.set_task_source(ts)?;
        }
        self.venv.copy_obs_into(&mut self.obs);
        Ok(())
    }

    /// One random-policy rollout chunk of `t` steps; returns
    /// (reward_sum, episodes_done, trials_done) aggregated over the
    /// batch — the same aggregates as `EnvPool::rollout`, reduced
    /// env-major so the value is identical for every thread count.
    pub fn rollout(&mut self, t: usize, rng: &mut Rng)
                   -> Result<(f64, u64, u64)> {
        let totals = self.venv.rollout(t, rng)?;
        self.venv.copy_obs_into(&mut self.obs);
        Ok(totals)
    }
}

/// The `ParVecEnv`-backed pool under the unified batch API (the
/// "parallel native" surface). The trait `reset` requires the
/// benchmark installed via [`NativePool::with_tasks`] and reproduces
/// the inherent [`NativePool::reset`] bit for bit.
impl BatchEnvironment for NativePool {
    fn batch(&self) -> usize {
        self.cfg.b
    }

    fn obs_spec(&self) -> ObsSpec {
        self.cfg.params.obs_spec()
    }

    fn action_spec(&self) -> ActionSpec {
        self.cfg.params.action_spec()
    }

    fn max_rules(&self) -> usize {
        self.cfg.params.max_rules
    }

    fn reset(&mut self, rng: &mut Rng, obs_out: &mut [i32]) -> Result<()> {
        anyhow::ensure!(obs_out.len() == self.venv.obs_len(),
                        "obs buffer size");
        let tasks = self
            .tasks
            .clone()
            .context("NativePool: no task source installed; construct \
                      with NativePool::with_tasks")?;
        self.reset_from(&tasks, rng)?;
        obs_out.copy_from_slice(&self.obs);
        Ok(())
    }

    fn step(&mut self, actions: &[i32], obs_out: &mut [i32],
            rewards: &mut [f32], dones: &mut [bool],
            trial_dones: &mut [bool]) -> Result<()> {
        // observations go to the caller's buffer only — the `obs()`
        // cache tracks the inherent reset/rollout paths, and syncing it
        // here would tax every wrapped step with a dead B*V*V*2 memcpy
        self.venv.step_all(actions, obs_out, rewards, dones, trial_dones)
    }

    fn agent_dirs_into(&self, out: &mut [i32]) {
        self.venv.copy_agent_dirs_into(out)
    }

    fn task_rows_into(&self, out: &mut [i32]) {
        self.venv.copy_task_rows_into(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchgen::{generate_benchmark, Preset};

    fn tiny_bench() -> Arc<Benchmark> {
        let (rulesets, _) =
            generate_benchmark(&Preset::Trivial.config(), 8).unwrap();
        Arc::new(Benchmark { name: "t".into(), rulesets })
    }

    #[test]
    fn family_from_registry_env() {
        let bench = tiny_bench();
        let cfg = NativeEnvConfig::for_env("XLand-MiniGrid-R4-13x13", 16,
                                           8, &bench)
            .unwrap();
        assert_eq!((cfg.params.h, cfg.params.w, cfg.rooms), (13, 13, 4));
        assert!(cfg.params.max_rules >= 1 && cfg.params.max_init >= 1);
        assert_eq!(cfg.threads, 1);
        assert_eq!(cfg.with_threads(0).threads, 1);
        assert_eq!(cfg.with_threads(4).threads, 4);
        assert!(NativeEnvConfig::for_env("MiniGrid-Empty-8x8", 16, 8,
                                         &bench)
            .is_err());
    }

    #[test]
    fn rollout_is_deterministic_per_seed() {
        let bench = tiny_bench();
        let cfg = NativeEnvConfig::for_env("XLand-MiniGrid-R1-9x9", 8, 4,
                                           &bench)
            .unwrap();
        let run = |threads: usize| {
            let mut pool = NativePool::new(cfg.with_threads(threads));
            let mut rng = Rng::new(9);
            pool.reset(&bench, &mut rng).unwrap();
            let totals = pool.rollout(4, &mut rng).unwrap();
            (totals.0.to_bits(), totals.1, totals.2,
             pool.obs().to_vec())
        };
        assert_eq!(run(1), run(1));
        // chunked across workers == serial, bitwise
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn rollout_counts_trials_and_episodes() {
        let bench = tiny_bench();
        let cfg = NativeEnvConfig::for_env("XLand-MiniGrid-R1-9x9", 16,
                                           8, &bench)
            .unwrap();
        let mut pool = NativePool::new(cfg);
        let mut rng = Rng::new(1);
        pool.reset(&bench, &mut rng).unwrap();
        // 9x9 default max_steps = 243: no episode boundary in 8 steps
        let (_, episodes, trials) = pool.rollout(8, &mut rng).unwrap();
        assert_eq!(episodes, 0);
        // trials only end on goal achievement here, which random play
        // may or may not hit — just check the aggregate is sane
        assert!(trials <= 16 * 8);
    }

    /// A derived `TaskSlice` split installs directly as the pool's
    /// task source, and the rollout stays bitwise thread-invariant.
    #[test]
    fn slice_installs_as_task_pool() {
        use crate::benchgen::TaskSlice;
        let bench = tiny_bench();
        let slice = Arc::new(
            TaskSlice::full(bench).shuffle(5).subset(0..4));
        let cfg = NativeEnvConfig::for_tasks("XLand-MiniGrid-R1-9x9", 8,
                                             4, slice.as_ref())
            .unwrap();
        let run = |threads: usize| {
            let src: Arc<dyn TaskSource> = slice.clone();
            let mut pool = NativePool::with_task_source(
                cfg.with_threads(threads), src.clone());
            let mut rng = Rng::new(11);
            pool.reset_from(&src, &mut rng).unwrap();
            let totals = pool.rollout(6, &mut rng).unwrap();
            (totals.0.to_bits(), totals.1, totals.2,
             pool.obs().to_vec())
        };
        assert_eq!(run(1), run(2), "split pool thread-invariant");
    }

    /// The trait surface reproduces the inherent pool bitwise: same
    /// reset (via the installed benchmark), same stepping.
    #[test]
    fn trait_surface_matches_inherent_pool() {
        let bench = tiny_bench();
        let cfg = NativeEnvConfig::for_env("XLand-MiniGrid-R1-9x9", 4, 4,
                                           &bench)
            .unwrap();
        let mut a = NativePool::new(cfg);
        let mut b = NativePool::with_tasks(cfg, bench.clone());
        let mut rng_a = Rng::new(3);
        let mut rng_b = Rng::new(3);
        a.reset(&bench, &mut rng_a).unwrap();
        let mut obs_b = vec![0i32; 4 * a.cfg.params.obs_len()];
        BatchEnvironment::reset(&mut b, &mut rng_b, &mut obs_b).unwrap();
        assert_eq!(a.obs(), &obs_b[..], "trait reset == inherent reset");

        let actions = [0i32, 1, 2, 3];
        let mut obs_a = vec![0i32; obs_b.len()];
        let (mut rw, mut dn, mut tr) =
            (vec![0f32; 4], vec![false; 4], vec![false; 4]);
        // step the inherent pool's engine through the trait on `a` too
        BatchEnvironment::step(&mut a, &actions, &mut obs_a, &mut rw,
                               &mut dn, &mut tr)
            .unwrap();
        let (mut rw2, mut dn2, mut tr2) =
            (vec![0f32; 4], vec![false; 4], vec![false; 4]);
        BatchEnvironment::step(&mut b, &actions, &mut obs_b, &mut rw2,
                               &mut dn2, &mut tr2)
            .unwrap();
        assert_eq!(obs_a, obs_b);
        assert_eq!(rw, rw2);

        let mut dirs = vec![0i32; 4];
        b.agent_dirs_into(&mut dirs);
        assert!(dirs.iter().all(|d| (0..4).contains(d)));
        let row = b.cfg.params.task_row_len();
        let mut rows = vec![0i32; 4 * row];
        b.task_rows_into(&mut rows);
        assert!(rows.iter().any(|&x| x != 0), "encoded tasks present");
    }
}
