//! Native vectorized backend: drives [`ParVecEnv`] batches from the
//! coordinator with the same shard/RNG discipline as the AOT-backed
//! [`super::pool::EnvPool`] — but with zero artifacts and zero PJRT.
//! This is what makes `xmgrid rollout --backend native` work on a fresh
//! checkout: any registry XLand env family rolls out at full speed with
//! no artifact build step, chunked across `threads` stepping workers
//! per replica (bitwise-identical to serial for any thread count — see
//! [`super::workers`]).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::benchgen::Benchmark;
use crate::env::layouts::xland_layout;
use crate::env::registry::XLAND_ENVS;
use crate::env::state::{default_max_steps, EnvOptions, Ruleset,
                        TaskSource};
use crate::env::vector::VecEnvConfig;
use crate::env::Grid;
use crate::util::rng::Rng;

use super::workers::ParVecEnv;

/// Shape of a native vectorized env family — the artifact-free analogue
/// of [`super::pool::EnvFamily`] plus the fused step count `T` and the
/// stepping-worker count.
#[derive(Clone, Copy, Debug)]
pub struct NativeEnvConfig {
    pub h: usize,
    pub w: usize,
    pub rooms: usize,
    /// rule-table capacity (max rules over the task source)
    pub mr: usize,
    /// init-tile capacity (max init objects over the task source)
    pub mi: usize,
    /// env batch per replica
    pub b: usize,
    /// steps per rollout chunk (the fused-T analogue)
    pub t: usize,
    /// stepping worker threads per replica (`--threads`); the batch is
    /// chunked across them, output bitwise-independent of the count
    pub threads: usize,
}

impl NativeEnvConfig {
    /// Derive the family from a registry XLand env name plus the
    /// benchmark that will supply tasks (its max rule / init-tile counts
    /// size the fixed-width tables). One stepping thread by default;
    /// see [`NativeEnvConfig::with_threads`].
    pub fn for_env(name: &str, b: usize, t: usize, bench: &Benchmark)
                   -> Result<NativeEnvConfig> {
        let spec = match XLAND_ENVS.iter().find(|e| e.name == name) {
            Some(s) => s,
            None => bail!(
                "--backend native rolls out XLand registry families; \
                 `{name}` is not one (see `xmgrid envs`)"
            ),
        };
        if b == 0 || t == 0 {
            bail!("native backend needs batch and steps >= 1");
        }
        let mr = bench
            .rulesets
            .iter()
            .map(|r| r.rules.len())
            .max()
            .unwrap_or(0)
            .max(1);
        let mi = bench
            .rulesets
            .iter()
            .map(|r| r.init_tiles.len())
            .max()
            .unwrap_or(0)
            .max(1);
        Ok(NativeEnvConfig {
            h: spec.h,
            w: spec.w,
            rooms: spec.rooms,
            mr,
            mi,
            b,
            t,
            threads: 1,
        })
    }

    /// Chunk the batch across `threads` persistent stepping workers
    /// (clamped to at least 1; `ParVecEnv` further clamps to the batch).
    pub fn with_threads(mut self, threads: usize) -> NativeEnvConfig {
        self.threads = threads.max(1);
        self
    }
}

/// Host-side analogue of [`super::pool::EnvPool`]: owns a [`ParVecEnv`]
/// batch plus the rollout I/O buffers, and drives the random-policy
/// rollout used by the throughput benches and `xmgrid rollout
/// --backend native`. Data buffers (obs, per-chunk staging, action
/// scratch) are allocated once and recycled; the rollout hot loop
/// costs only the per-chunk job dispatch, never per-step allocation.
pub struct NativePool {
    pub cfg: NativeEnvConfig,
    venv: ParVecEnv,
    obs: Vec<i32>,
}

impl NativePool {
    pub fn new(cfg: NativeEnvConfig) -> NativePool {
        let venv = ParVecEnv::new(
            VecEnvConfig {
                h: cfg.h,
                w: cfg.w,
                max_rules: cfg.mr,
                max_init: cfg.mi,
                opts: EnvOptions::default(),
            },
            cfg.b,
            cfg.threads,
        );
        let obs_len = venv.obs_len();
        NativePool { cfg, venv, obs: vec![0; obs_len] }
    }

    /// Latest observations, `[B, V, V, 2]` i32.
    pub fn obs(&self) -> &[i32] {
        &self.obs
    }

    /// Mirror of `EnvPool::reset`: per env, a fresh base grid with
    /// re-randomized doors, a ruleset sampled from the benchmark, the
    /// default step limit, and a private RNG stream split off `rng` —
    /// everything a function of the caller's stream only. The benchmark
    /// is also installed as the episode-reset task source, so every
    /// episode draws a fresh task (the §2.1 protocol) instead of
    /// replaying the reset-time ruleset forever.
    pub fn reset(&mut self, bench: &Arc<Benchmark>, rng: &mut Rng) {
        let b = self.cfg.b;
        let rulesets: Vec<&Ruleset> =
            (0..b).map(|_| bench.sample_ruleset(rng)).collect();
        let grids: Vec<Grid> = (0..b)
            .map(|_| xland_layout(self.cfg.rooms, self.cfg.h, self.cfg.w,
                                  rng))
            .collect();
        let max_steps =
            vec![default_max_steps(self.cfg.h, self.cfg.w); b];
        let rngs: Vec<Rng> = (0..b).map(|_| rng.split()).collect();
        self.venv.reset_all(&grids, &rulesets, &max_steps, &rngs,
                            &mut self.obs);
        let tasks: Arc<dyn TaskSource> = bench.clone();
        self.venv.set_task_source(tasks);
    }

    /// One random-policy rollout chunk of `t` steps; returns
    /// (reward_sum, episodes_done, trials_done) aggregated over the
    /// batch — the same aggregates as `EnvPool::rollout`, reduced
    /// env-major so the value is identical for every thread count.
    pub fn rollout(&mut self, t: usize, rng: &mut Rng)
                   -> (f64, u64, u64) {
        let totals = self.venv.rollout(t, rng);
        self.venv.copy_obs_into(&mut self.obs);
        totals
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchgen::{generate_benchmark, Preset};

    fn tiny_bench() -> Arc<Benchmark> {
        let (rulesets, _) =
            generate_benchmark(&Preset::Trivial.config(), 8).unwrap();
        Arc::new(Benchmark { name: "t".into(), rulesets })
    }

    #[test]
    fn family_from_registry_env() {
        let bench = tiny_bench();
        let cfg = NativeEnvConfig::for_env("XLand-MiniGrid-R4-13x13", 16,
                                           8, &bench)
            .unwrap();
        assert_eq!((cfg.h, cfg.w, cfg.rooms), (13, 13, 4));
        assert!(cfg.mr >= 1 && cfg.mi >= 1);
        assert_eq!(cfg.threads, 1);
        assert_eq!(cfg.with_threads(0).threads, 1);
        assert_eq!(cfg.with_threads(4).threads, 4);
        assert!(NativeEnvConfig::for_env("MiniGrid-Empty-8x8", 16, 8,
                                         &bench)
            .is_err());
    }

    #[test]
    fn rollout_is_deterministic_per_seed() {
        let bench = tiny_bench();
        let cfg = NativeEnvConfig::for_env("XLand-MiniGrid-R1-9x9", 8, 4,
                                           &bench)
            .unwrap();
        let run = |threads: usize| {
            let mut pool = NativePool::new(cfg.with_threads(threads));
            let mut rng = Rng::new(9);
            pool.reset(&bench, &mut rng);
            let totals = pool.rollout(4, &mut rng);
            (totals.0.to_bits(), totals.1, totals.2,
             pool.obs().to_vec())
        };
        assert_eq!(run(1), run(1));
        // chunked across workers == serial, bitwise
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn rollout_counts_trials_and_episodes() {
        let bench = tiny_bench();
        let cfg = NativeEnvConfig::for_env("XLand-MiniGrid-R1-9x9", 16,
                                           8, &bench)
            .unwrap();
        let mut pool = NativePool::new(cfg);
        let mut rng = Rng::new(1);
        pool.reset(&bench, &mut rng);
        // 9x9 default max_steps = 243: no episode boundary in 8 steps
        let (_, episodes, trials) = pool.rollout(8, &mut rng);
        assert_eq!(episodes, 0);
        // trials only end on goal achievement here, which random play
        // may or may not hit — just check the aggregate is sane
        assert!(trials <= 16 * 8);
    }
}
