//! Native vectorized backend: drives [`VecEnv`] batches from the
//! coordinator with the same shard/RNG discipline as the AOT-backed
//! [`super::pool::EnvPool`] — but with zero artifacts and zero PJRT.
//! This is what makes `xmgrid rollout --backend native` work on a fresh
//! checkout: any registry XLand env family rolls out at full speed with
//! no artifact build step.

use anyhow::{bail, Result};

use crate::benchgen::Benchmark;
use crate::env::layouts::xland_layout;
use crate::env::registry::XLAND_ENVS;
use crate::env::state::{default_max_steps, EnvOptions, Ruleset};
use crate::env::types::NUM_ACTIONS;
use crate::env::vector::{VecEnv, VecEnvConfig};
use crate::env::Grid;
use crate::util::rng::Rng;

/// Shape of a native vectorized env family — the artifact-free analogue
/// of [`super::pool::EnvFamily`] plus the fused step count `T`.
#[derive(Clone, Copy, Debug)]
pub struct NativeEnvConfig {
    pub h: usize,
    pub w: usize,
    pub rooms: usize,
    /// rule-table capacity (max rules over the task source)
    pub mr: usize,
    /// init-tile capacity (max init objects over the task source)
    pub mi: usize,
    /// env batch per replica
    pub b: usize,
    /// steps per rollout chunk (the fused-T analogue)
    pub t: usize,
}

impl NativeEnvConfig {
    /// Derive the family from a registry XLand env name plus the
    /// benchmark that will supply tasks (its max rule / init-tile counts
    /// size the fixed-width tables).
    pub fn for_env(name: &str, b: usize, t: usize, bench: &Benchmark)
                   -> Result<NativeEnvConfig> {
        let spec = match XLAND_ENVS.iter().find(|e| e.name == name) {
            Some(s) => s,
            None => bail!(
                "--backend native rolls out XLand registry families; \
                 `{name}` is not one (see `xmgrid envs`)"
            ),
        };
        if b == 0 || t == 0 {
            bail!("native backend needs batch and steps >= 1");
        }
        let mr = bench
            .rulesets
            .iter()
            .map(|r| r.rules.len())
            .max()
            .unwrap_or(0)
            .max(1);
        let mi = bench
            .rulesets
            .iter()
            .map(|r| r.init_tiles.len())
            .max()
            .unwrap_or(0)
            .max(1);
        Ok(NativeEnvConfig {
            h: spec.h,
            w: spec.w,
            rooms: spec.rooms,
            mr,
            mi,
            b,
            t,
        })
    }
}

/// Host-side analogue of [`super::pool::EnvPool`]: owns a [`VecEnv`]
/// batch plus the rollout I/O buffers, and drives the random-policy
/// rollout used by the throughput benches and `xmgrid rollout
/// --backend native`. All buffers are allocated once here; the rollout
/// loop itself never allocates.
pub struct NativePool {
    pub cfg: NativeEnvConfig,
    venv: VecEnv,
    actions: Vec<i32>,
    obs: Vec<i32>,
    rewards: Vec<f32>,
    dones: Vec<bool>,
    trial_dones: Vec<bool>,
}

impl NativePool {
    pub fn new(cfg: NativeEnvConfig) -> NativePool {
        let venv = VecEnv::new(
            VecEnvConfig {
                h: cfg.h,
                w: cfg.w,
                max_rules: cfg.mr,
                max_init: cfg.mi,
                opts: EnvOptions::default(),
            },
            cfg.b,
        );
        let obs_len = venv.obs_len();
        NativePool {
            cfg,
            venv,
            actions: vec![0; cfg.b],
            obs: vec![0; obs_len],
            rewards: vec![0.0; cfg.b],
            dones: vec![false; cfg.b],
            trial_dones: vec![false; cfg.b],
        }
    }

    /// Latest observations, `[B, V, V, 2]` i32.
    pub fn obs(&self) -> &[i32] {
        &self.obs
    }

    /// Mirror of `EnvPool::reset`: per env, a fresh base grid with
    /// re-randomized doors, a ruleset sampled from the benchmark, the
    /// default step limit, and a private RNG stream split off `rng` —
    /// everything a function of the caller's stream only.
    pub fn reset(&mut self, bench: &Benchmark, rng: &mut Rng) {
        let b = self.cfg.b;
        let rulesets: Vec<&Ruleset> =
            (0..b).map(|_| bench.sample_ruleset(rng)).collect();
        let grids: Vec<Grid> = (0..b)
            .map(|_| xland_layout(self.cfg.rooms, self.cfg.h, self.cfg.w,
                                  rng))
            .collect();
        let max_steps =
            vec![default_max_steps(self.cfg.h, self.cfg.w); b];
        let rngs: Vec<Rng> = (0..b).map(|_| rng.split()).collect();
        self.venv.reset_all(&grids, &rulesets, &max_steps, &rngs,
                            &mut self.obs);
    }

    /// One random-policy rollout chunk of `t` steps; returns
    /// (reward_sum, episodes_done, trials_done) aggregated over the
    /// batch — the same aggregates as `EnvPool::rollout`.
    pub fn rollout(&mut self, t: usize, rng: &mut Rng)
                   -> (f64, u64, u64) {
        let mut reward_sum = 0.0f64;
        let mut episodes = 0u64;
        let mut trials = 0u64;
        for _ in 0..t {
            for a in self.actions.iter_mut() {
                *a = rng.below(NUM_ACTIONS) as i32;
            }
            self.venv.step_all(&self.actions, &mut self.obs,
                               &mut self.rewards, &mut self.dones,
                               &mut self.trial_dones);
            reward_sum +=
                self.rewards.iter().map(|&x| x as f64).sum::<f64>();
            episodes += self.dones.iter().filter(|&&d| d).count() as u64;
            trials +=
                self.trial_dones.iter().filter(|&&d| d).count() as u64;
        }
        (reward_sum, episodes, trials)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchgen::{generate_benchmark, Preset};

    fn tiny_bench() -> Benchmark {
        let (rulesets, _) =
            generate_benchmark(&Preset::Trivial.config(), 8);
        Benchmark { name: "t".into(), rulesets }
    }

    #[test]
    fn family_from_registry_env() {
        let bench = tiny_bench();
        let cfg = NativeEnvConfig::for_env("XLand-MiniGrid-R4-13x13", 16,
                                           8, &bench)
            .unwrap();
        assert_eq!((cfg.h, cfg.w, cfg.rooms), (13, 13, 4));
        assert!(cfg.mr >= 1 && cfg.mi >= 1);
        assert!(NativeEnvConfig::for_env("MiniGrid-Empty-8x8", 16, 8,
                                         &bench)
            .is_err());
    }

    #[test]
    fn rollout_is_deterministic_per_seed() {
        let bench = tiny_bench();
        let cfg = NativeEnvConfig::for_env("XLand-MiniGrid-R1-9x9", 8, 4,
                                           &bench)
            .unwrap();
        let run = || {
            let mut pool = NativePool::new(cfg);
            let mut rng = Rng::new(9);
            pool.reset(&bench, &mut rng);
            let totals = pool.rollout(4, &mut rng);
            (totals, pool.obs().to_vec())
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn rollout_counts_trials_and_episodes() {
        let bench = tiny_bench();
        let cfg = NativeEnvConfig::for_env("XLand-MiniGrid-R1-9x9", 16,
                                           8, &bench)
            .unwrap();
        let mut pool = NativePool::new(cfg);
        let mut rng = Rng::new(1);
        pool.reset(&bench, &mut rng);
        // 9x9 default max_steps = 243: no episode boundary in 8 steps
        let (_, episodes, trials) = pool.rollout(8, &mut rng);
        assert_eq!(episodes, 0);
        // trials only end on goal achievement here, which random play
        // may or may not hit — just check the aggregate is sane
        assert!(trials <= 16 * 8);
    }
}
