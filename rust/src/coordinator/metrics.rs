//! Metrics sinks: CSV rows (plottable) + human-readable console lines,
//! plus the cross-shard metric reduction and a throughput meter for the
//! engines. No serde offline — plain formatting.

use std::io::Write;
use std::path::Path;
use std::time::Instant;

use anyhow::{Context, Result};

use super::trainer::IterMetrics;

/// Reduce per-shard iteration metrics to one row: losses and ratios are
/// averaged, counters (steps, trials, episodes, reward) are summed.
pub fn reduce_iter_metrics(shard_metrics: &[IterMetrics]) -> IterMetrics {
    assert!(!shard_metrics.is_empty());
    let n = shard_metrics.len() as f32;
    let mut out = IterMetrics::default();
    for m in shard_metrics {
        out.total_loss += m.total_loss;
        out.pi_loss += m.pi_loss;
        out.v_loss += m.v_loss;
        out.entropy += m.entropy;
        out.approx_kl += m.approx_kl;
        out.clip_frac += m.clip_frac;
        out.grad_norm += m.grad_norm;
        out.adv_std += m.adv_std;
        out.reward_sum += m.reward_sum;
        out.trials += m.trials;
        out.episodes += m.episodes;
        out.env_steps += m.env_steps;
    }
    out.total_loss /= n;
    out.pi_loss /= n;
    out.v_loss /= n;
    out.entropy /= n;
    out.approx_kl /= n;
    out.clip_frac /= n;
    out.grad_norm /= n;
    out.adv_std /= n;
    out
}

/// The engines' one sanctioned wall-clock handle.
///
/// Timing chunks and windows is measurement, not computation: nothing
/// the engines produce (trajectories, reductions, checkpoints) may
/// depend on it. Funneling every coordinator-side `Instant::now` read
/// through this type keeps that auditable — `xmgrid lint`'s
/// `no-wallclock-in-kernels` rule confines raw `Instant`/`SystemTime`
/// access to this module, `util/bench.rs` and the CLI surface, so a
/// wall-clock read leaking into a kernel or reduction path fails the
/// gate instead of skewing bench rows or breaking replay determinism.
pub struct WallTimer {
    t0: Instant,
}

impl WallTimer {
    pub fn start() -> WallTimer {
        WallTimer { t0: Instant::now() }
    }

    /// Seconds since `start()`. Strictly for reporting (`ChunkStats`
    /// secs, window sps) — never feed this back into engine state.
    pub fn elapsed_secs(&self) -> f64 {
        self.t0.elapsed().as_secs_f64()
    }
}

/// Cumulative steps/second meter for the engines' console reporting.
pub struct ThroughputMeter {
    t0: Instant,
    steps: u64,
}

impl Default for ThroughputMeter {
    fn default() -> Self {
        Self::new()
    }
}

impl ThroughputMeter {
    pub fn new() -> ThroughputMeter {
        ThroughputMeter { t0: Instant::now(), steps: 0 }
    }

    pub fn add(&mut self, steps: u64) {
        self.steps += steps;
    }

    pub fn steps(&self) -> u64 {
        self.steps
    }

    /// Cumulative steps per second since construction.
    pub fn sps(&self) -> f64 {
        let secs = self.t0.elapsed().as_secs_f64();
        if secs > 0.0 { self.steps as f64 / secs } else { 0.0 }
    }
}

/// Append-only CSV writer with a fixed header.
pub struct CsvLog {
    file: std::fs::File,
}

impl CsvLog {
    pub fn create(path: &Path, header: &[&str]) -> Result<CsvLog> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::fs::File::create(path)
            .with_context(|| format!("creating {path:?}"))?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvLog { file })
    }

    /// Open for appending — used by `train --resume` so the interrupted
    /// run's rows survive. Writes the header only when the file is new
    /// or empty.
    pub fn append(path: &Path, header: &[&str]) -> Result<CsvLog> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(path)
            .with_context(|| format!("opening {path:?} for append"))?;
        let empty = file
            .metadata()
            .map(|m| m.len() == 0)
            .unwrap_or(true);
        if empty {
            writeln!(file, "{}", header.join(","))?;
        }
        Ok(CsvLog { file })
    }

    pub fn row(&mut self, values: &[String]) -> Result<()> {
        writeln!(self.file, "{}", values.join(","))?;
        Ok(())
    }

    pub fn row_f64(&mut self, values: &[f64]) -> Result<()> {
        let strs: Vec<String> =
            values.iter().map(|v| format!("{v}")).collect();
        self.row(&strs)
    }
}

/// Format steps/second human-readably (e.g. "1.25M").
pub fn fmt_sps(sps: f64) -> String {
    if sps >= 1e6 {
        format!("{:.2}M", sps / 1e6)
    } else if sps >= 1e3 {
        format!("{:.1}k", sps / 1e3)
    } else {
        format!("{sps:.0}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_writes_rows() {
        let dir = std::env::temp_dir().join(format!(
            "xmg_csv_{}", std::process::id()));
        let path = dir.join("m.csv");
        {
            let mut log =
                CsvLog::create(&path, &["iter", "loss"]).unwrap();
            log.row(&["1".into(), "0.5".into()]).unwrap();
            log.row_f64(&[2.0, 0.25]).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "iter,loss\n1,0.5\n2,0.25\n");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sps_formatting() {
        assert_eq!(fmt_sps(1_250_000.0), "1.25M");
        assert_eq!(fmt_sps(32_100.0), "32.1k");
        assert_eq!(fmt_sps(321.0), "321");
    }

    #[test]
    fn iter_metrics_reduction() {
        let a = IterMetrics {
            total_loss: 1.0,
            entropy: 0.5,
            reward_sum: 2.0,
            trials: 3,
            episodes: 1,
            env_steps: 100,
            ..Default::default()
        };
        let b = IterMetrics {
            total_loss: 3.0,
            entropy: 1.5,
            reward_sum: 4.0,
            trials: 5,
            episodes: 1,
            env_steps: 100,
            ..Default::default()
        };
        let r = reduce_iter_metrics(&[a, b]);
        assert_eq!(r.total_loss, 2.0);
        assert_eq!(r.entropy, 1.0);
        assert_eq!(r.reward_sum, 6.0);
        assert_eq!(r.trials, 8);
        assert_eq!(r.env_steps, 200);
    }

    #[test]
    fn throughput_meter_accumulates() {
        let mut m = ThroughputMeter::new();
        m.add(50);
        m.add(50);
        assert_eq!(m.steps(), 100);
        assert!(m.sps() >= 0.0);
    }
}
