//! Metrics sinks: CSV rows (plottable) + human-readable console lines.
//! No serde offline — plain formatting.

use std::io::Write;
use std::path::Path;

use anyhow::{Context, Result};

/// Append-only CSV writer with a fixed header.
pub struct CsvLog {
    file: std::fs::File,
}

impl CsvLog {
    pub fn create(path: &Path, header: &[&str]) -> Result<CsvLog> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut file = std::fs::File::create(path)
            .with_context(|| format!("creating {path:?}"))?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvLog { file })
    }

    pub fn row(&mut self, values: &[String]) -> Result<()> {
        writeln!(self.file, "{}", values.join(","))?;
        Ok(())
    }

    pub fn row_f64(&mut self, values: &[f64]) -> Result<()> {
        let strs: Vec<String> =
            values.iter().map(|v| format!("{v}")).collect();
        self.row(&strs)
    }
}

/// Format steps/second human-readably (e.g. "1.25M").
pub fn fmt_sps(sps: f64) -> String {
    if sps >= 1e6 {
        format!("{:.2}M", sps / 1e6)
    } else if sps >= 1e3 {
        format!("{:.1}k", sps / 1e3)
    } else {
        format!("{sps:.0}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_writes_rows() {
        let dir = std::env::temp_dir().join(format!(
            "xmg_csv_{}", std::process::id()));
        let path = dir.join("m.csv");
        {
            let mut log =
                CsvLog::create(&path, &["iter", "loss"]).unwrap();
            log.row(&["1".into(), "0.5".into()]).unwrap();
            log.row_f64(&[2.0, 0.25]).unwrap();
        }
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "iter,loss\n1,0.5\n2,0.25\n");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn sps_formatting() {
        assert_eq!(fmt_sps(1_250_000.0), "1.25M");
        assert_eq!(fmt_sps(32_100.0), "32.1k");
        assert_eq!(fmt_sps(321.0), "321");
    }
}
