//! Parallel vectorized stepping: [`ParVecEnv`] chunks one `VecEnv`
//! batch across a pool of persistent worker threads and drives them
//! through the same `reset_all`/`step_all` surface as the serial
//! engine — saturating every core while staying **bitwise identical**
//! to serial execution for any thread count.
//!
//! # Determinism argument
//!
//! Envs are independent: every RNG draw a step makes comes from the
//! stepped env's own stream (placement splits, episode task draws), and
//! every buffer a step touches is private to that env's SoA rows. Chunk
//! worker `c` owns envs `[lo_c, hi_c)` outright — a real sub-`VecEnv`
//! over contiguous ranges, not a view — so parallel execution is the
//! *same computation* as serial, merely partitioned. The only cross-env
//! arithmetic is the rollout reward reduction, which is performed
//! env-major (each env accumulates its own `f64` sum over time, then
//! the sums are folded in ascending env order on the coordinator
//! thread), so even that float reduction is independent of chunking.
//! `tests/native_threads.rs` pins all of this across thread counts
//! {1, 2, 8}, down to the internal SoA buffers and RNG states.
//!
//! # Thread model
//!
//! Workers are spawned once ([`ShardPool`]) and live as long as the
//! `ParVecEnv`; each call ships the chunk's I/O staging buffers to its
//! worker (owned, recycled — no steady-state allocation) and collects
//! them back in chunk order. For rollout chunks the whole `T`-step loop
//! runs worker-side off one dispatch, so synchronization cost is per
//! chunk, not per step. Each chunk's `VecEnv` carries its own packed
//! grids, gather-table cache and free-cell lists (docs/ARCHITECTURE.md
//! "Hot-path anatomy"), so the zero-redundancy per-step kernels run
//! unchanged inside every worker.
//!
//! # Failure model (docs/ARCHITECTURE.md "Failure model & recovery")
//!
//! Every chunk job runs under `catch_unwind` inside its worker thread
//! ([`ShardPool`]); a panic retires the worker and surfaces as a channel
//! error, never a process abort. The coordinator then *supervises*: it
//! respawns the worker ([`ShardPool::respawn`]), deterministically
//! rebuilds the chunk's state by replaying the engine's input log (the
//! last reset/snapshot base plus every action batch, restart stream and
//! task-source install since — all pure data the coordinator already
//! owned), and re-dispatches the failed job, under a bounded
//! [`RetryPolicy`]. Because the replay re-runs the *same computation*
//! on the *same inputs*, a faulted-then-recovered run is bitwise
//! identical to an unfaulted one — `tests/fault_tolerance.rs` pins
//! this across thread counts and fault sites. The log is compacted to a
//! per-chunk [`VecEnv::snapshot`] base every `COMPACT_AFTER_STEPS`
//! logged steps, so replay cost and log memory stay bounded.

use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::env::api::{ActionSpec, BatchEnvironment, ObsSpec};
use crate::env::state::{Ruleset, TaskSource};
use crate::env::types::{GOAL_ENC, NUM_ACTIONS, RULE_ENC};
use crate::env::vector::{VecEnv, VecEnvConfig, VecEnvSnapshot};
use crate::env::Grid;
use crate::util::fault::{FaultPlan, RetryPolicy};
use crate::util::rng::Rng;

use super::shard::{ShardPool, Ticket};

/// Replay from the base is compacted into a fresh snapshot base once the
/// log exceeds this many steps, bounding recovery time and log memory.
const COMPACT_AFTER_STEPS: usize = 1024;

/// One worker's owned slice of the batch.
struct ChunkEnv {
    venv: VecEnv,
    /// chunk index — the `worker=` coordinate of the fault grammar
    worker: usize,
    faults: Arc<FaultPlan>,
}

impl ChunkEnv {
    /// Fault-injection site: consulted once per env-batch step with the
    /// *global* step index, identical on first execution and on replay,
    /// so a one-shot fault fires at the same logical point for any
    /// thread count and a `count=*` fault keeps a worker down through
    /// every retry.
    #[inline]
    fn maybe_fault(&self, step: u64) {
        if !self.faults.is_empty()
            && self.faults.chunk_step_panic(self.worker, step)
        {
            panic!(
                "injected fault: worker {} at step {}",
                self.worker, step
            );
        }
    }
}

/// Recyclable I/O staging for one chunk: shipped into the worker job,
/// filled there, shipped back, and stored for the next call. Lost with
/// the worker on a panic (the job owned it); the supervisor reallocates.
struct ChunkBufs {
    actions: Vec<i32>,
    obs: Vec<i32>,
    rewards: Vec<f32>,
    dones: Vec<bool>,
    trials: Vec<bool>,
    /// per-env `f64` reward accumulators for fused rollout chunks
    reward_acc: Vec<f64>,
}

/// Full-batch base of the replay log: the last full synchronization
/// point every chunk's state is a pure function of.
enum ReplayBase {
    /// construction state — a fresh `VecEnv::new`
    Unseeded,
    /// the inputs of the last `reset_all` (full-batch clones)
    Reset {
        grids: Vec<Grid>,
        rulesets: Vec<Ruleset>,
        max_steps: Vec<i32>,
        rngs: Vec<Rng>,
    },
    /// compacted per-chunk snapshots, chunk order
    Snapshots(Vec<VecEnvSnapshot>),
}

/// One logged engine input since the base, in execution order.
enum ReplayEvent {
    /// a `step_all`/`rollout` action slab, step-major `[t, B]` global
    /// layout, tagged with its starting global step index so replays
    /// consult the fault plan at the original coordinates
    Steps { start: u64, t: usize, actions: Vec<i32> },
    /// pre-split per-env restart streams, global env order
    Restart(Vec<Rng>),
    /// task-source install (order relative to steps matters: draws
    /// after this point come from the new source)
    SetTasks(Arc<dyn TaskSource>),
}

/// The deterministic input log: `base`, then `base_tasks` (the source
/// in effect at the base), then `events` in order, reproduces every
/// chunk's state exactly.
struct ReplayLog {
    base: ReplayBase,
    base_tasks: Option<Arc<dyn TaskSource>>,
    events: Vec<ReplayEvent>,
    logged_steps: usize,
}

impl ReplayLog {
    fn new() -> ReplayLog {
        ReplayLog {
            base: ReplayBase::Unseeded,
            base_tasks: None,
            events: Vec::new(),
            logged_steps: 0,
        }
    }

    /// The task source in effect after the full log ran.
    fn effective_tasks(&self) -> Option<Arc<dyn TaskSource>> {
        for ev in self.events.iter().rev() {
            if let ReplayEvent::SetTasks(ts) = ev {
                return Some(ts.clone());
            }
        }
        self.base_tasks.clone()
    }
}

/// Chunk-sliced copy of base + events, shipped into a replay job.
enum ChunkBase {
    Unseeded,
    Reset {
        grids: Vec<Grid>,
        rulesets: Vec<Ruleset>,
        max_steps: Vec<i32>,
        rngs: Vec<Rng>,
    },
    Snapshot(VecEnvSnapshot),
}

enum ChunkEvent {
    Steps { start: u64, t: usize, actions: Vec<i32> },
    Restart(Vec<Rng>),
    SetTasks(Arc<dyn TaskSource>),
}

/// A supervised chunk job: returns the chunk's staging buffers plus the
/// op-specific output.
type ChunkJob<R> = Box<dyn FnOnce(&mut ChunkEnv) -> (ChunkBufs, R) + Send>;

/// `B` envs chunked over `threads` persistent workers, with the serial
/// [`VecEnv`] API plus a fused [`ParVecEnv::rollout`]. `threads == 1`
/// runs the identical machinery with a single worker.
///
/// All state-advancing operations return `Result`: a worker panic is
/// recovered by respawn + deterministic replay under the configured
/// [`RetryPolicy`], and only after retries are exhausted does the
/// operation surface a clean error naming the worker and step.
pub struct ParVecEnv {
    cfg: VecEnvConfig,
    b: usize,
    /// per-chunk `[lo, hi)` env ranges, ascending and contiguous
    ranges: Vec<(usize, usize)>,
    pool: ShardPool<ChunkEnv>,
    bufs: Vec<Option<ChunkBufs>>,
    /// reusable `[T, B]` action staging for fused rollouts — the
    /// rollout hot path allocates nothing per chunk
    act_scratch: Vec<i32>,
    /// whether `reset_all` has installed episode inputs (guards the
    /// trait-level episode restart)
    seeded: bool,
    /// deterministic input log for replay-based recovery
    log: ReplayLog,
    /// global step index of the next env-batch step (fault coordinates)
    steps_done: u64,
    retry: RetryPolicy,
    faults: Arc<FaultPlan>,
}

impl ParVecEnv {
    /// Chunk `b` envs over `threads` workers (clamped to `[1, b]`);
    /// chunk sizes differ by at most one env. Reads the ambient fault
    /// plan from `XMG_FAULTS` (pre-validate it with
    /// [`FaultPlan::from_env`] for a clean CLI error; a malformed value
    /// here panics rather than silently running unfaulted) and uses the
    /// default [`RetryPolicy`].
    pub fn new(cfg: VecEnvConfig, b: usize, threads: usize) -> ParVecEnv {
        Self::with_retry(cfg, b, threads, RetryPolicy::default())
    }

    /// [`ParVecEnv::new`] with an explicit recovery policy (the
    /// `--max-retries` / `--retry-backoff-ms` CLI knobs); the fault
    /// plan still comes from the ambient `XMG_FAULTS`.
    pub fn with_retry(cfg: VecEnvConfig, b: usize, threads: usize,
                      retry: RetryPolicy) -> ParVecEnv {
        let faults = FaultPlan::from_env().unwrap_or_else(|e| {
            panic!("malformed {}: {e:#}", crate::util::fault::FAULTS_ENV)
        });
        Self::with_faults(cfg, b, threads, Arc::new(faults), retry)
    }

    /// [`ParVecEnv::new`] with an explicit fault plan and retry policy
    /// (the fault-tolerance tests inject through this constructor).
    pub fn with_faults(cfg: VecEnvConfig, b: usize, threads: usize,
                       faults: Arc<FaultPlan>, retry: RetryPolicy)
                       -> ParVecEnv {
        assert!(b > 0, "ParVecEnv needs at least one env");
        let threads = threads.max(1).min(b);
        let (base, extra) = (b / threads, b % threads);
        let mut ranges = Vec::with_capacity(threads);
        let mut lo = 0usize;
        for c in 0..threads {
            let len = base + usize::from(c < extra);
            ranges.push((lo, lo + len));
            lo += len;
        }
        let spawn_ranges = ranges.clone();
        let spawn_faults = faults.clone();
        let pool = ShardPool::spawn(threads, move |c| {
            let (lo, hi) = spawn_ranges[c];
            Ok(ChunkEnv {
                venv: VecEnv::new(cfg, hi - lo),
                worker: c,
                faults: spawn_faults.clone(),
            })
        })
        // the spawn closure above never returns Err — `ShardPool::spawn`
        // is fallible only through the closure it is given
        // xmglint: allow(no-unwrap-in-workers) -- spawn closure is Ok-only
        .expect("spawning vec-env chunk workers");
        let vv2 = cfg.opts.view_size * cfg.opts.view_size * 2;
        let bufs = ranges
            .iter()
            .map(|&(lo, hi)| {
                let cb = hi - lo;
                Some(ChunkBufs {
                    actions: Vec::with_capacity(cb),
                    obs: vec![0; cb * vv2],
                    rewards: vec![0.0; cb],
                    dones: vec![false; cb],
                    trials: vec![false; cb],
                    reward_acc: vec![0.0; cb],
                })
            })
            .collect();
        ParVecEnv {
            cfg,
            b,
            ranges,
            pool,
            bufs,
            act_scratch: Vec::new(),
            seeded: false,
            log: ReplayLog::new(),
            steps_done: 0,
            retry,
            faults,
        }
    }

    pub fn batch(&self) -> usize {
        self.b
    }

    pub fn threads(&self) -> usize {
        self.ranges.len()
    }

    pub fn config(&self) -> &VecEnvConfig {
        &self.cfg
    }

    /// Global step index of the next env-batch step — the `step=`
    /// coordinate of the fault grammar, and part of error messages.
    pub fn steps_done(&self) -> u64 {
        self.steps_done
    }

    /// `B * V * V * 2`, as in [`VecEnv::obs_len`].
    pub fn obs_len(&self) -> usize {
        self.b * self.vv2()
    }

    fn vv2(&self) -> usize {
        self.cfg.opts.view_size * self.cfg.opts.view_size * 2
    }

    fn alloc_bufs(&self, c: usize) -> ChunkBufs {
        let (lo, hi) = self.ranges[c];
        let cb = hi - lo;
        let vv2 = self.vv2();
        ChunkBufs {
            actions: Vec::with_capacity(cb),
            obs: vec![0; cb * vv2],
            rewards: vec![0.0; cb],
            dones: vec![false; cb],
            trials: vec![false; cb],
            reward_acc: vec![0.0; cb],
        }
    }

    fn take_bufs(&mut self, c: usize) -> ChunkBufs {
        match self.bufs[c].take() {
            Some(b) => b,
            None => self.alloc_bufs(c),
        }
    }

    /// Chunk `c`'s staging buffers, which must be at rest. A slot is
    /// `None` only while a `run_op` dispatch owns it, and every such
    /// window restores the slot before returning (success, recovery,
    /// or bail), so `None` here is a coordinator sequencing bug — an
    /// error, not a panic, to keep the supervised pool recoverable.
    fn bufs_ref(&self, c: usize) -> Result<&ChunkBufs> {
        self.bufs[c].as_ref().ok_or_else(|| {
            anyhow!("chunk {c} staging buffers still in flight — \
                     coordinator sequencing bug")
        })
    }

    // --- supervised dispatch ----------------------------------------------

    /// Run one operation across every chunk with supervision: dispatch
    /// all chunks, await them in chunk order, and for any chunk whose
    /// worker died, respawn + replay the input log + re-dispatch the
    /// same job (built fresh by `make_job`), up to `retry.max_retries`
    /// recovery rounds with linear backoff. Chunks that succeeded keep
    /// their advanced state — recovery replays exactly the failed
    /// chunk's envs, so the batch stays consistent. Returns per-chunk
    /// outputs in chunk order, or a clean error naming the worker after
    /// retries are exhausted.
    fn run_op<R, J>(&mut self, label: &str, make_job: J) -> Result<Vec<R>>
    where
        R: Send + 'static,
        J: Fn(usize, ChunkBufs) -> ChunkJob<R>,
    {
        let n = self.ranges.len();
        let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
        let mut tickets: Vec<Option<Ticket<(ChunkBufs, R)>>> =
            Vec::with_capacity(n);
        for c in 0..n {
            let bufs = self.take_bufs(c);
            tickets.push(self.pool.call(c, make_job(c, bufs)).ok());
        }
        let mut failed: Vec<usize> = Vec::new();
        for (c, t) in tickets.into_iter().enumerate() {
            match t.map(Ticket::wait) {
                Some(Ok((bufs, r))) => {
                    self.bufs[c] = Some(bufs);
                    results[c] = Some(r);
                }
                _ => failed.push(c),
            }
        }
        let mut attempt = 0u32;
        while !failed.is_empty() {
            attempt += 1;
            if attempt > self.retry.max_retries {
                let c = failed[0];
                // respawn once more purely to harvest the authoritative
                // cause (the join inside makes the dying thread's record
                // visible) and leave the pool teardown-safe
                let cause = match self.pool.respawn(c) {
                    Ok(e) => format!(": {e}"),
                    Err(_) => String::new(),
                };
                let (lo, hi) = self.ranges[c];
                for &f in &failed {
                    if self.bufs[f].is_none() {
                        self.bufs[f] = Some(self.alloc_bufs(f));
                    }
                }
                bail!(
                    "chunk worker {c} (envs {lo}..{hi}) failed \
                     `{label}` at global step {} after {} retries{cause}",
                    self.steps_done,
                    self.retry.max_retries
                );
            }
            self.retry.sleep(attempt);
            let mut still = Vec::new();
            for &c in &failed {
                if self.recover_chunk(c).is_err() {
                    still.push(c);
                    continue;
                }
                let bufs = self.take_bufs(c);
                let ok = match self.pool.call(c, make_job(c, bufs)) {
                    Ok(t) => match t.wait() {
                        Ok((bufs, r)) => {
                            self.bufs[c] = Some(bufs);
                            results[c] = Some(r);
                            true
                        }
                        Err(_) => false,
                    },
                    Err(_) => false,
                };
                if !ok {
                    still.push(c);
                }
            }
            failed = still;
        }
        results
            .into_iter()
            .enumerate()
            .map(|(c, r)| {
                // the retry loop only exits with `failed` empty, so
                // every slot is filled; a hole is a recovery bug
                r.ok_or_else(|| {
                    anyhow!("chunk {c} has no `{label}` result after \
                             recovery — supervision bug")
                })
            })
            .collect()
    }

    /// Respawn chunk worker `c` and deterministically rebuild its state:
    /// install the base (reset inputs or snapshot) and re-run every
    /// logged event, consulting the fault plan at the original global
    /// step coordinates. On success the chunk's staging buffers are
    /// rebuilt too (current observations re-rendered), so recovery is
    /// invisible to `copy_obs_into`.
    fn recover_chunk(&mut self, c: usize) -> Result<()> {
        let cause = self.pool.respawn(c)?;
        eprintln!(
            "xmgrid: recovering chunk worker {c}: {cause} \
             (replaying {} logged steps)",
            self.log.logged_steps
        );
        let (lo, hi) = self.ranges[c];
        let cb = hi - lo;
        let cfg = self.cfg;
        let vv2 = self.vv2();
        let base = match &self.log.base {
            ReplayBase::Unseeded => ChunkBase::Unseeded,
            ReplayBase::Reset { grids, rulesets, max_steps, rngs } => {
                ChunkBase::Reset {
                    grids: grids[lo..hi].to_vec(),
                    rulesets: rulesets[lo..hi].to_vec(),
                    max_steps: max_steps[lo..hi].to_vec(),
                    rngs: rngs[lo..hi].to_vec(),
                }
            }
            ReplayBase::Snapshots(s) => ChunkBase::Snapshot(s[c].clone()),
        };
        let base_tasks = self.log.base_tasks.clone();
        let b = self.b;
        let events: Vec<ChunkEvent> = self
            .log
            .events
            .iter()
            .map(|ev| match ev {
                ReplayEvent::Steps { start, t, actions } => {
                    let mut a = Vec::with_capacity(*t * cb);
                    for step in 0..*t {
                        a.extend_from_slice(
                            &actions[step * b + lo..step * b + hi],
                        );
                    }
                    ChunkEvent::Steps { start: *start, t: *t, actions: a }
                }
                ReplayEvent::Restart(rngs) => {
                    ChunkEvent::Restart(rngs[lo..hi].to_vec())
                }
                ReplayEvent::SetTasks(ts) => {
                    ChunkEvent::SetTasks(ts.clone())
                }
            })
            .collect();
        let ticket = self.pool.call(c, move |w: &mut ChunkEnv| {
            w.venv = VecEnv::new(cfg, cb);
            if let Some(ts) = base_tasks {
                w.venv.set_task_source_prevalidated(ts);
            }
            let mut bufs = ChunkBufs {
                actions: Vec::with_capacity(cb),
                obs: vec![0; cb * vv2],
                rewards: vec![0.0; cb],
                dones: vec![false; cb],
                trials: vec![false; cb],
                reward_acc: vec![0.0; cb],
            };
            match base {
                ChunkBase::Unseeded => {}
                ChunkBase::Reset { grids, rulesets, max_steps, rngs } => {
                    let refs: Vec<&Ruleset> = rulesets.iter().collect();
                    w.venv.reset_all(&grids, &refs, &max_steps, &rngs,
                                     &mut bufs.obs);
                }
                ChunkBase::Snapshot(snap) => w.venv.restore(&snap),
            }
            for ev in events {
                match ev {
                    ChunkEvent::Steps { start, t, actions } => {
                        for step in 0..t {
                            w.maybe_fault(start + step as u64);
                            let a = &actions[step * cb..(step + 1) * cb];
                            let ChunkBufs {
                                obs, rewards, dones, trials, ..
                            } = &mut bufs;
                            w.venv.step_all(a, obs, rewards, dones,
                                            trials);
                        }
                    }
                    ChunkEvent::Restart(rngs) => {
                        for (j, r) in rngs.into_iter().enumerate() {
                            w.venv.restart_env_with(j, r, &mut bufs.obs);
                        }
                    }
                    ChunkEvent::SetTasks(ts) => {
                        w.venv.set_task_source_prevalidated(ts);
                    }
                }
            }
            // re-render current observations so the recovered staging
            // buffers equal the survivors' (snapshot bases carry no obs)
            w.venv.write_obs_all(&mut bufs.obs);
            bufs
        })?;
        let bufs = ticket.wait().map_err(|_| {
            anyhow!("chunk worker {c} died again during replay")
        })?;
        self.bufs[c] = Some(bufs);
        Ok(())
    }

    /// Compact the replay log into fresh per-chunk snapshot bases once
    /// it exceeds [`COMPACT_AFTER_STEPS`], bounding replay time and log
    /// memory. Runs at a synchronization point (all chunks idle and
    /// consistent), itself supervised.
    fn maybe_compact(&mut self) -> Result<()> {
        if self.log.logged_steps <= COMPACT_AFTER_STEPS {
            return Ok(());
        }
        let snaps = self.run_op("snapshot-compact", |_, bufs| {
            Box::new(move |w: &mut ChunkEnv| (bufs, w.venv.snapshot()))
        })?;
        self.log.base_tasks = self.log.effective_tasks();
        self.log.base = ReplayBase::Snapshots(snaps);
        self.log.events.clear();
        self.log.logged_steps = 0;
        Ok(())
    }

    // --- public engine surface --------------------------------------------

    /// Install the episode-reset task distribution on every chunk
    /// (see [`VecEnv::set_task_source`]). The O(num_tasks) capacity
    /// validation runs once here, not once per chunk worker.
    pub fn set_task_source(&mut self, tasks: Arc<dyn TaskSource>)
                           -> Result<()> {
        self.cfg.validate_task_source(tasks.as_ref());
        let install = tasks.clone();
        self.run_op("set_task_source", move |_, bufs| {
            let ts = install.clone();
            Box::new(move |w: &mut ChunkEnv| {
                w.venv.set_task_source_prevalidated(ts);
                (bufs, ())
            })
        })?;
        self.log.events.push(ReplayEvent::SetTasks(tasks));
        Ok(())
    }

    /// Parallel [`VecEnv::reset_all`]: inputs are split by chunk and
    /// cloned into the workers (reset is the cold path), observations
    /// land in `obs_out` in global env order.
    pub fn reset_all(&mut self, grids: &[Grid], rulesets: &[&Ruleset],
                     max_steps: &[i32], rngs: &[Rng],
                     obs_out: &mut [i32]) -> Result<()> {
        assert_eq!(grids.len(), self.b, "need one base grid per env");
        assert_eq!(rulesets.len(), self.b, "need one ruleset per env");
        assert_eq!(max_steps.len(), self.b);
        assert_eq!(rngs.len(), self.b);
        assert_eq!(obs_out.len(), self.obs_len(), "obs buffer size");
        let vv2 = self.vv2();
        let owned_rulesets: Vec<Ruleset> =
            rulesets.iter().map(|&r| r.clone()).collect();
        let ranges = self.ranges.clone();
        {
            let grids = &grids;
            let owned = &owned_rulesets;
            let max_steps = &max_steps;
            let rngs = &rngs;
            let ranges = &ranges;
            self.run_op("reset_all", move |c, bufs| {
                let (lo, hi) = ranges[c];
                let g: Vec<Grid> = grids[lo..hi].to_vec();
                let rs: Vec<Ruleset> = owned[lo..hi].to_vec();
                let ms: Vec<i32> = max_steps[lo..hi].to_vec();
                let rg: Vec<Rng> = rngs[lo..hi].to_vec();
                Box::new(move |w: &mut ChunkEnv| {
                    let mut bufs = bufs;
                    let refs: Vec<&Ruleset> = rs.iter().collect();
                    w.venv.reset_all(&g, &refs, &ms, &rg, &mut bufs.obs);
                    (bufs, ())
                })
            })?;
        }
        for (c, &(lo, hi)) in self.ranges.iter().enumerate() {
            let bufs = self.bufs_ref(c)?;
            obs_out[lo * vv2..hi * vv2].copy_from_slice(&bufs.obs);
        }
        // a reset is a full synchronization point: everything before it
        // is dead state, so the log restarts here (tasks carry over)
        self.log.base_tasks = self.log.effective_tasks();
        self.log.base = ReplayBase::Reset {
            grids: grids.to_vec(),
            rulesets: owned_rulesets,
            max_steps: max_steps.to_vec(),
            rngs: rngs.to_vec(),
        };
        self.log.events.clear();
        self.log.logged_steps = 0;
        self.seeded = true;
        Ok(())
    }

    /// Parallel [`VecEnv::step_all`]: one dispatch per chunk, outputs
    /// merged back into the caller's buffers in global env order —
    /// bitwise identical to the serial engine for any thread count.
    pub fn step_all(&mut self, actions: &[i32], obs_out: &mut [i32],
                    rewards: &mut [f32], dones: &mut [bool],
                    trial_dones: &mut [bool]) -> Result<()> {
        assert_eq!(actions.len(), self.b, "need one action per env");
        assert_eq!(obs_out.len(), self.obs_len(), "obs buffer size");
        assert_eq!(rewards.len(), self.b);
        assert_eq!(dones.len(), self.b);
        assert_eq!(trial_dones.len(), self.b);
        let vv2 = self.vv2();
        let step_idx = self.steps_done;
        let ranges = self.ranges.clone();
        {
            let actions = &actions;
            let ranges = &ranges;
            self.run_op("step_all", move |c, mut bufs| {
                let (lo, hi) = ranges[c];
                bufs.actions.clear();
                bufs.actions.extend_from_slice(&actions[lo..hi]);
                Box::new(move |w: &mut ChunkEnv| {
                    w.maybe_fault(step_idx);
                    let mut bufs = bufs;
                    let ChunkBufs {
                        actions, obs, rewards, dones, trials, ..
                    } = &mut bufs;
                    w.venv.step_all(actions, obs, rewards, dones, trials);
                    (bufs, ())
                })
            })?;
        }
        for (c, &(lo, hi)) in self.ranges.iter().enumerate() {
            let bufs = self.bufs_ref(c)?;
            obs_out[lo * vv2..hi * vv2].copy_from_slice(&bufs.obs);
            rewards[lo..hi].copy_from_slice(&bufs.rewards);
            dones[lo..hi].copy_from_slice(&bufs.dones);
            trial_dones[lo..hi].copy_from_slice(&bufs.trials);
        }
        self.log.events.push(ReplayEvent::Steps {
            start: step_idx,
            t: 1,
            actions: actions.to_vec(),
        });
        self.log.logged_steps += 1;
        self.steps_done += 1;
        self.maybe_compact()
    }

    /// Fused random-policy rollout: `t` steps per env with actions drawn
    /// from `rng` in the serial order (step-major, env-minor), the whole
    /// `t`-step loop running worker-side off a single dispatch per
    /// chunk. Returns `(reward_sum, episodes_done, trials_done)`.
    ///
    /// The reward reduction is env-major — env `i` accumulates its own
    /// `f64` sum over the `t` steps, and the per-env sums are folded in
    /// ascending env order here — so the result is bit-identical for
    /// every thread count.
    pub fn rollout(&mut self, t: usize, rng: &mut Rng)
                   -> Result<(f64, u64, u64)> {
        let b = self.b;
        self.act_scratch.resize(t * b, 0);
        for a in self.act_scratch.iter_mut() {
            *a = rng.below(NUM_ACTIONS) as i32;
        }
        let start = self.steps_done;
        // step-major per-chunk slabs, rebuilt fresh for any re-dispatch
        // (the original slab rode into the dead worker)
        let mut slabs: Vec<Vec<i32>> =
            Vec::with_capacity(self.ranges.len());
        for &(lo, hi) in &self.ranges {
            let mut v = Vec::with_capacity(t * (hi - lo));
            for step in 0..t {
                v.extend_from_slice(
                    &self.act_scratch[step * b + lo..step * b + hi],
                );
            }
            slabs.push(v);
        }
        let ranges = self.ranges.clone();
        let per_chunk: Vec<(u64, u64)> = {
            let slabs = &slabs;
            let ranges = &ranges;
            self.run_op("rollout", move |c, mut bufs| {
                let (lo, hi) = ranges[c];
                let cb = hi - lo;
                bufs.actions.clear();
                bufs.actions.extend_from_slice(&slabs[c]);
                Box::new(move |w: &mut ChunkEnv| {
                    let mut bufs = bufs;
                    bufs.reward_acc.iter_mut().for_each(|x| *x = 0.0);
                    let mut episodes = 0u64;
                    let mut trials = 0u64;
                    for step in 0..t {
                        w.maybe_fault(start + step as u64);
                        let ChunkBufs {
                            actions, obs, rewards, dones, trials: tr,
                            reward_acc,
                        } = &mut bufs;
                        let a = &actions[step * cb..(step + 1) * cb];
                        w.venv.step_all(a, obs, rewards, dones, tr);
                        for (acc, &r) in
                            reward_acc.iter_mut().zip(&*rewards)
                        {
                            *acc += r as f64;
                        }
                        episodes +=
                            dones.iter().filter(|&&d| d).count() as u64;
                        trials +=
                            tr.iter().filter(|&&d| d).count() as u64;
                    }
                    (bufs, (episodes, trials))
                })
            })?
        };
        let mut reward_sum = 0.0f64;
        let mut episodes = 0u64;
        let mut trials = 0u64;
        for (c, (ep, tr)) in per_chunk.into_iter().enumerate() {
            for &x in &self.bufs_ref(c)?.reward_acc {
                reward_sum += x;
            }
            episodes += ep;
            trials += tr;
        }
        self.log.events.push(ReplayEvent::Steps {
            start,
            t,
            actions: self.act_scratch.clone(),
        });
        self.log.logged_steps += t;
        self.steps_done += t as u64;
        self.maybe_compact()?;
        Ok((reward_sum, episodes, trials))
    }

    /// Copy the most recent observations (from the last `reset_all`,
    /// `step_all` or `rollout`) into `out`, global env order.
    pub fn copy_obs_into(&self, out: &mut [i32]) {
        assert_eq!(out.len(), self.obs_len(), "obs buffer size");
        let vv2 = self.vv2();
        for (c, &(lo, hi)) in self.ranges.iter().enumerate() {
            // the `&self` signature cannot surface `bufs_ref`'s error;
            // buffers are always at rest between operations, so this
            // only fires on the same sequencing bug `bufs_ref` guards
            // xmglint: allow(no-unwrap-in-workers) -- infallible &self getter
            let bufs = self.bufs[c].as_ref().expect("bufs in flight");
            out[lo * vv2..hi * vv2].copy_from_slice(&bufs.obs);
        }
    }

    /// Full-batch snapshot: per-chunk snapshots concatenated in chunk
    /// (= global env) order. Equal across thread counts iff the engines
    /// are bitwise-identical.
    pub fn snapshot(&mut self) -> Result<VecEnvSnapshot> {
        let chunks = self.run_op("snapshot", |_, bufs| {
            Box::new(move |w: &mut ChunkEnv| (bufs, w.venv.snapshot()))
        })?;
        let mut out = VecEnvSnapshot::empty();
        for s in chunks {
            out.append(s);
        }
        Ok(out)
    }

    /// Install a full-batch snapshot — the inverse of
    /// [`ParVecEnv::snapshot`] and the trainer's resume primitive. The
    /// global snapshot is sliced per chunk along the fixed per-env
    /// strides, each chunk engine is restored in place, and staging
    /// observations are re-rendered so `copy_obs_into` reflects the
    /// restored state. Like `reset_all`, a restore is a full
    /// synchronization point: the replay log restarts here with the
    /// per-chunk snapshots as base (tasks carry over), so worker
    /// recovery replays from the restored state, not the dead past.
    pub fn restore(&mut self, snap: &VecEnvSnapshot) -> Result<()> {
        let ghw = self.cfg.h * self.cfg.w;
        let (mr, mi) = (self.cfg.max_rules, self.cfg.max_init);
        if snap.rng_states.len() != self.b
            || snap.base.len() != self.b * ghw
            || snap.rules.len() != self.b * mr
        {
            bail!(
                "snapshot shape mismatch: {} envs (want {}), {} base \
                 cells (want {}), {} rules (want {})",
                snap.rng_states.len(),
                self.b,
                snap.base.len(),
                self.b * ghw,
                snap.rules.len(),
                self.b * mr
            );
        }
        let per_chunk: Vec<VecEnvSnapshot> = self
            .ranges
            .iter()
            .map(|&(lo, hi)| VecEnvSnapshot {
                base: snap.base[lo * ghw..hi * ghw].to_vec(),
                grid: snap.grid[lo * ghw..hi * ghw].to_vec(),
                agent_pos: snap.agent_pos[lo * 2..hi * 2].to_vec(),
                agent_dir: snap.agent_dir[lo..hi].to_vec(),
                pocket: snap.pocket[lo..hi].to_vec(),
                rules: snap.rules[lo * mr..hi * mr].to_vec(),
                goals: snap.goals[lo..hi].to_vec(),
                init: snap.init[lo * mi..hi * mi].to_vec(),
                init_len: snap.init_len[lo..hi].to_vec(),
                step_count: snap.step_count[lo..hi].to_vec(),
                max_steps: snap.max_steps[lo..hi].to_vec(),
                rng_states: snap.rng_states[lo..hi].to_vec(),
            })
            .collect();
        {
            let per = &per_chunk;
            self.run_op("restore", move |c, bufs| {
                let s = per[c].clone();
                Box::new(move |w: &mut ChunkEnv| {
                    let mut bufs = bufs;
                    w.venv.restore(&s);
                    w.venv.write_obs_all(&mut bufs.obs);
                    (bufs, ())
                })
            })?;
        }
        self.log.base_tasks = self.log.effective_tasks();
        self.log.base = ReplayBase::Snapshots(per_chunk);
        self.log.events.clear();
        self.log.logged_steps = 0;
        self.seeded = true;
        Ok(())
    }

    // --- unified-API surface (env::api::BatchEnvironment) ------------------

    /// Parallel [`VecEnv::restart_all`]: per-env streams are split off
    /// `rng` in *global* env order on the coordinator thread, then
    /// shipped to the chunk workers — bitwise identical to the serial
    /// engine for any thread count.
    pub fn restart_all(&mut self, rng: &mut Rng, obs_out: &mut [i32])
                       -> Result<()> {
        assert_eq!(obs_out.len(), self.obs_len(), "obs buffer size");
        let vv2 = self.vv2();
        let rngs: Vec<Rng> = (0..self.b).map(|_| rng.split()).collect();
        let ranges = self.ranges.clone();
        {
            let rngs = &rngs;
            let ranges = &ranges;
            self.run_op("restart_all", move |c, bufs| {
                let (lo, hi) = ranges[c];
                let rg: Vec<Rng> = rngs[lo..hi].to_vec();
                Box::new(move |w: &mut ChunkEnv| {
                    let mut bufs = bufs;
                    for (j, r) in rg.into_iter().enumerate() {
                        w.venv.restart_env_with(j, r, &mut bufs.obs);
                    }
                    (bufs, ())
                })
            })?;
        }
        for (c, &(lo, hi)) in self.ranges.iter().enumerate() {
            let bufs = self.bufs_ref(c)?;
            obs_out[lo * vv2..hi * vv2].copy_from_slice(&bufs.obs);
        }
        self.log.events.push(ReplayEvent::Restart(rngs));
        Ok(())
    }

    /// Per-env agent facing directions, global env order (one
    /// synchronous broadcast round-trip).
    pub fn copy_agent_dirs_into(&self, out: &mut [i32]) {
        assert_eq!(out.len(), self.b, "direction buffer size");
        let chunks = self
            .pool
            .broadcast(|_, w: &mut ChunkEnv| {
                let mut v = vec![0i32; w.venv.batch()];
                w.venv.copy_agent_dirs_into(&mut v);
                v
            })
            // pinned by the BatchEnvironment trait to an infallible
            // `&self` signature; workers can only be dead if a prior
            // fallible op already returned Err, which callers propagate
            // xmglint: allow(no-unwrap-in-workers) -- trait-pinned &self
            .expect("chunk workers dead — a prior operation failed \
                     and its error was ignored");
        for (c, chunk) in chunks.into_iter().enumerate() {
            let (lo, hi) = self.ranges[c];
            out[lo..hi].copy_from_slice(&chunk);
        }
    }

    /// Per-env encoded task rows (goal `[5]` + rules `[MR, 7]`), global
    /// env order (one synchronous broadcast round-trip).
    pub fn copy_task_rows_into(&self, out: &mut [i32]) {
        let row = GOAL_ENC + self.cfg.max_rules * RULE_ENC;
        assert_eq!(out.len(), self.b * row, "task row buffer size");
        let chunks = self
            .pool
            .broadcast(|_, w: &mut ChunkEnv| {
                let mr = w.venv.config().max_rules;
                let mut v =
                    vec![0i32; w.venv.batch() * (GOAL_ENC + mr * RULE_ENC)];
                w.venv.copy_task_rows_into(&mut v);
                v
            })
            // same contract as `copy_agent_dirs_into` directly above
            // xmglint: allow(no-unwrap-in-workers) -- trait-pinned &self
            .expect("chunk workers dead — a prior operation failed \
                     and its error was ignored");
        for (c, chunk) in chunks.into_iter().enumerate() {
            let (lo, hi) = self.ranges[c];
            out[lo * row..hi * row].copy_from_slice(&chunk);
        }
    }
}

/// The chunked parallel engine under the unified batch API — the same
/// contract as the serial [`VecEnv`] impl, thread-count invariant by
/// the determinism argument above.
impl BatchEnvironment for ParVecEnv {
    fn batch(&self) -> usize {
        self.b
    }

    fn obs_spec(&self) -> ObsSpec {
        self.cfg.obs_spec()
    }

    fn action_spec(&self) -> ActionSpec {
        self.cfg.action_spec()
    }

    fn max_rules(&self) -> usize {
        self.cfg.max_rules
    }

    fn reset(&mut self, rng: &mut Rng, obs_out: &mut [i32]) -> Result<()> {
        anyhow::ensure!(
            self.seeded,
            "ParVecEnv: no episode inputs installed — seed base grids / \
             tasks / step limits with reset_all once before the \
             trait-level reset restarts episodes"
        );
        self.restart_all(rng, obs_out)
    }

    fn step(&mut self, actions: &[i32], obs_out: &mut [i32],
            rewards: &mut [f32], dones: &mut [bool],
            trial_dones: &mut [bool]) -> Result<()> {
        self.step_all(actions, obs_out, rewards, dones, trial_dones)
    }

    fn agent_dirs_into(&self, out: &mut [i32]) {
        self.copy_agent_dirs_into(out)
    }

    fn task_rows_into(&self, out: &mut [i32]) {
        self.copy_task_rows_into(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::state::EnvOptions;
    use crate::env::types::{Cell, COLOR_RED, TILE_BALL};
    use crate::env::Goal;

    fn simple_ruleset() -> Ruleset {
        Ruleset {
            goal: Goal::agent_near(Cell::new(TILE_BALL, COLOR_RED)),
            rules: vec![],
            init_tiles: vec![Cell::new(TILE_BALL, COLOR_RED)],
        }
    }

    fn reset_inputs(b: usize)
                    -> (Vec<Grid>, Ruleset, Vec<i32>, Vec<Rng>) {
        let grids = (0..b).map(|_| Grid::empty_room(9, 9)).collect();
        let rs = simple_ruleset();
        let maxs = vec![5i32; b];
        let rngs = (0..b).map(|i| Rng::new(300 + i as u64)).collect();
        (grids, rs, maxs, rngs)
    }

    /// Chunked parallel stepping must be bitwise identical to the plain
    /// serial `VecEnv` — outputs and internal state. (The full
    /// registry/thread-count matrix lives in `tests/native_threads.rs`.)
    #[test]
    fn parallel_matches_serial_vecenv() {
        let opts = EnvOptions::default();
        let cfg = VecEnvConfig { h: 9, w: 9, max_rules: 1, max_init: 1,
                                 opts };
        let b = 5usize; // odd on purpose: uneven chunks
        let (grids, rs, maxs, rngs) = reset_inputs(b);
        let refs: Vec<&Ruleset> = (0..b).map(|_| &rs).collect();

        let mut serial = VecEnv::new(cfg, b);
        let mut par = ParVecEnv::new(cfg, b, 3);
        let mut obs_s = vec![0i32; serial.obs_len()];
        let mut obs_p = vec![0i32; par.obs_len()];
        serial.reset_all(&grids, &refs, &maxs, &rngs, &mut obs_s);
        par.reset_all(&grids, &refs, &maxs, &rngs, &mut obs_p).unwrap();
        assert_eq!(obs_s, obs_p, "reset obs");

        let mut rw_s = vec![0f32; b];
        let mut dn_s = vec![false; b];
        let mut tr_s = vec![false; b];
        let (mut rw_p, mut dn_p, mut tr_p) =
            (rw_s.clone(), dn_s.clone(), tr_s.clone());
        let mut act = Rng::new(4);
        for t in 0..20 {
            let actions: Vec<i32> =
                (0..b).map(|_| act.below(6) as i32).collect();
            serial.step_all(&actions, &mut obs_s, &mut rw_s, &mut dn_s,
                            &mut tr_s);
            par.step_all(&actions, &mut obs_p, &mut rw_p, &mut dn_p,
                         &mut tr_p)
                .unwrap();
            assert_eq!(obs_s, obs_p, "step {t}: obs");
            assert_eq!(rw_s.iter().map(|r| r.to_bits()).collect::<Vec<_>>(),
                       rw_p.iter().map(|r| r.to_bits()).collect::<Vec<_>>(),
                       "step {t}: rewards");
            assert_eq!(dn_s, dn_p, "step {t}: dones");
            assert_eq!(tr_s, tr_p, "step {t}: trials");
        }
        assert_eq!(serial.snapshot(), par.snapshot().unwrap(),
                   "internal SoA buffers and RNG states");
    }

    /// The fused rollout's aggregates, final observations and internal
    /// state must be identical for every thread count.
    #[test]
    fn fused_rollout_thread_invariant() {
        let opts = EnvOptions::default();
        let cfg = VecEnvConfig { h: 9, w: 9, max_rules: 1, max_init: 1,
                                 opts };
        let b = 8usize;
        let run = |threads: usize| {
            let (grids, rs, maxs, rngs) = reset_inputs(b);
            let refs: Vec<&Ruleset> = (0..b).map(|_| &rs).collect();
            let mut par = ParVecEnv::new(cfg, b, threads);
            let mut obs = vec![0i32; par.obs_len()];
            par.reset_all(&grids, &refs, &maxs, &rngs, &mut obs)
                .unwrap();
            let mut rng = Rng::new(77);
            let totals = par.rollout(12, &mut rng).unwrap();
            par.copy_obs_into(&mut obs);
            (totals.0.to_bits(), totals.1, totals.2, obs,
             par.snapshot().unwrap())
        };
        let one = run(1);
        assert_eq!(one, run(2));
        assert_eq!(one, run(8));
    }

    #[test]
    fn threads_clamped_to_batch() {
        let opts = EnvOptions::default();
        let cfg = VecEnvConfig { h: 9, w: 9, max_rules: 1, max_init: 1,
                                 opts };
        let par = ParVecEnv::new(cfg, 2, 16);
        assert_eq!(par.threads(), 2);
        assert_eq!(par.batch(), 2);
        assert_eq!(par.obs_len(), 2 * 5 * 5 * 2);
    }

    /// An injected worker panic mid-step recovers via respawn + replay
    /// and the run stays bitwise-identical to an unfaulted one. (The
    /// full site × thread-count matrix is `tests/fault_tolerance.rs`.)
    #[test]
    fn injected_panic_recovers_bitwise() {
        let opts = EnvOptions::default();
        let cfg = VecEnvConfig { h: 9, w: 9, max_rules: 1, max_init: 1,
                                 opts };
        let b = 6usize;
        let run = |faults: Arc<FaultPlan>| {
            let (grids, rs, maxs, rngs) = reset_inputs(b);
            let refs: Vec<&Ruleset> = (0..b).map(|_| &rs).collect();
            let mut par = ParVecEnv::with_faults(
                cfg, b, 2, faults, RetryPolicy {
                    max_retries: 2,
                    backoff_ms: 0,
                });
            let mut obs = vec![0i32; par.obs_len()];
            par.reset_all(&grids, &refs, &maxs, &rngs, &mut obs)
                .unwrap();
            let mut rng = Rng::new(9);
            let totals = par.rollout(10, &mut rng).unwrap();
            (totals.0.to_bits(), totals.1, totals.2,
             par.snapshot().unwrap())
        };
        let clean = run(Arc::new(FaultPlan::none()));
        let faulted = run(Arc::new(
            FaultPlan::parse("panic@worker=1,step=4").unwrap(),
        ));
        assert_eq!(clean, faulted);
    }

    /// A permanently-broken worker (`count=*`) exhausts retries and
    /// surfaces a clean error naming the worker — no hang, no abort.
    #[test]
    fn retries_exhausted_errors_cleanly() {
        let opts = EnvOptions::default();
        let cfg = VecEnvConfig { h: 9, w: 9, max_rules: 1, max_init: 1,
                                 opts };
        let b = 4usize;
        let (grids, rs, maxs, rngs) = reset_inputs(b);
        let refs: Vec<&Ruleset> = (0..b).map(|_| &rs).collect();
        let faults = Arc::new(
            FaultPlan::parse("panic@worker=0,step=2,count=*").unwrap(),
        );
        let mut par = ParVecEnv::with_faults(
            cfg, b, 2, faults, RetryPolicy {
                max_retries: 1,
                backoff_ms: 0,
            });
        let mut obs = vec![0i32; par.obs_len()];
        par.reset_all(&grids, &refs, &maxs, &rngs, &mut obs).unwrap();
        let mut rng = Rng::new(9);
        let err = par.rollout(8, &mut rng).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("chunk worker 0"), "{msg}");
        assert!(msg.contains("rollout"), "{msg}");
    }
}
