//! Parallel vectorized stepping: [`ParVecEnv`] chunks one `VecEnv`
//! batch across a pool of persistent worker threads and drives them
//! through the same `reset_all`/`step_all` surface as the serial
//! engine — saturating every core while staying **bitwise identical**
//! to serial execution for any thread count.
//!
//! # Determinism argument
//!
//! Envs are independent: every RNG draw a step makes comes from the
//! stepped env's own stream (placement splits, episode task draws), and
//! every buffer a step touches is private to that env's SoA rows. Chunk
//! worker `c` owns envs `[lo_c, hi_c)` outright — a real sub-`VecEnv`
//! over contiguous ranges, not a view — so parallel execution is the
//! *same computation* as serial, merely partitioned. The only cross-env
//! arithmetic is the rollout reward reduction, which is performed
//! env-major (each env accumulates its own `f64` sum over time, then
//! the sums are folded in ascending env order on the coordinator
//! thread), so even that float reduction is independent of chunking.
//! `tests/native_threads.rs` pins all of this across thread counts
//! {1, 2, 8}, down to the internal SoA buffers and RNG states.
//!
//! # Thread model
//!
//! Workers are spawned once ([`ShardPool`]) and live as long as the
//! `ParVecEnv`; each call ships the chunk's I/O staging buffers to its
//! worker (owned, recycled — no steady-state allocation) and collects
//! them back in chunk order. For rollout chunks the whole `T`-step loop
//! runs worker-side off one dispatch, so synchronization cost is per
//! chunk, not per step. Each chunk's `VecEnv` carries its own packed
//! grids, gather-table cache and free-cell lists (docs/ARCHITECTURE.md
//! "Hot-path anatomy"), so the zero-redundancy per-step kernels run
//! unchanged inside every worker.

use std::sync::Arc;

use anyhow::Result;

use crate::env::api::{ActionSpec, BatchEnvironment, ObsSpec};
use crate::env::state::{Ruleset, TaskSource};
use crate::env::types::{GOAL_ENC, NUM_ACTIONS, RULE_ENC};
use crate::env::vector::{VecEnv, VecEnvConfig, VecEnvSnapshot};
use crate::env::Grid;
use crate::util::rng::Rng;

use super::shard::ShardPool;

/// One worker's owned slice of the batch.
struct ChunkEnv {
    venv: VecEnv,
}

/// Recyclable I/O staging for one chunk: shipped into the worker job,
/// filled there, shipped back, and stored for the next call.
struct ChunkBufs {
    actions: Vec<i32>,
    obs: Vec<i32>,
    rewards: Vec<f32>,
    dones: Vec<bool>,
    trials: Vec<bool>,
    /// per-env `f64` reward accumulators for fused rollout chunks
    reward_acc: Vec<f64>,
}

/// `B` envs chunked over `threads` persistent workers, with the serial
/// [`VecEnv`] API plus a fused [`ParVecEnv::rollout`]. `threads == 1`
/// runs the identical machinery with a single worker.
pub struct ParVecEnv {
    cfg: VecEnvConfig,
    b: usize,
    /// per-chunk `[lo, hi)` env ranges, ascending and contiguous
    ranges: Vec<(usize, usize)>,
    pool: ShardPool<ChunkEnv>,
    bufs: Vec<Option<ChunkBufs>>,
    /// reusable `[T, B]` action staging for fused rollouts — the
    /// rollout hot path allocates nothing per chunk
    act_scratch: Vec<i32>,
    /// whether `reset_all` has installed episode inputs (guards the
    /// trait-level episode restart)
    seeded: bool,
}

impl ParVecEnv {
    /// Chunk `b` envs over `threads` workers (clamped to `[1, b]`);
    /// chunk sizes differ by at most one env.
    pub fn new(cfg: VecEnvConfig, b: usize, threads: usize) -> ParVecEnv {
        assert!(b > 0, "ParVecEnv needs at least one env");
        let threads = threads.max(1).min(b);
        let (base, extra) = (b / threads, b % threads);
        let mut ranges = Vec::with_capacity(threads);
        let mut lo = 0usize;
        for c in 0..threads {
            let len = base + usize::from(c < extra);
            ranges.push((lo, lo + len));
            lo += len;
        }
        let spawn_ranges = ranges.clone();
        let pool = ShardPool::spawn(threads, move |c| {
            let (lo, hi) = spawn_ranges[c];
            Ok(ChunkEnv { venv: VecEnv::new(cfg, hi - lo) })
        })
        .expect("spawning vec-env chunk workers");
        let vv2 = cfg.opts.view_size * cfg.opts.view_size * 2;
        let bufs = ranges
            .iter()
            .map(|&(lo, hi)| {
                let cb = hi - lo;
                Some(ChunkBufs {
                    actions: Vec::with_capacity(cb),
                    obs: vec![0; cb * vv2],
                    rewards: vec![0.0; cb],
                    dones: vec![false; cb],
                    trials: vec![false; cb],
                    reward_acc: vec![0.0; cb],
                })
            })
            .collect();
        ParVecEnv { cfg, b, ranges, pool, bufs,
                    act_scratch: Vec::new(), seeded: false }
    }

    pub fn batch(&self) -> usize {
        self.b
    }

    pub fn threads(&self) -> usize {
        self.ranges.len()
    }

    pub fn config(&self) -> &VecEnvConfig {
        &self.cfg
    }

    /// `B * V * V * 2`, as in [`VecEnv::obs_len`].
    pub fn obs_len(&self) -> usize {
        self.b * self.vv2()
    }

    fn vv2(&self) -> usize {
        self.cfg.opts.view_size * self.cfg.opts.view_size * 2
    }

    /// Install the episode-reset task distribution on every chunk
    /// (see [`VecEnv::set_task_source`]). The O(num_tasks) capacity
    /// validation runs once here, not once per chunk worker.
    pub fn set_task_source(&mut self, tasks: Arc<dyn TaskSource>) {
        self.cfg.validate_task_source(tasks.as_ref());
        self.pool.broadcast(move |_, w: &mut ChunkEnv| {
            w.venv.set_task_source_prevalidated(tasks.clone());
        });
    }

    /// Parallel [`VecEnv::reset_all`]: inputs are split by chunk and
    /// cloned into the workers (reset is the cold path), observations
    /// land in `obs_out` in global env order.
    pub fn reset_all(&mut self, grids: &[Grid], rulesets: &[&Ruleset],
                     max_steps: &[i32], rngs: &[Rng],
                     obs_out: &mut [i32]) {
        assert_eq!(grids.len(), self.b, "need one base grid per env");
        assert_eq!(rulesets.len(), self.b, "need one ruleset per env");
        assert_eq!(max_steps.len(), self.b);
        assert_eq!(rngs.len(), self.b);
        assert_eq!(obs_out.len(), self.obs_len(), "obs buffer size");
        let vv2 = self.vv2();
        let mut tickets = Vec::with_capacity(self.ranges.len());
        for (c, &(lo, hi)) in self.ranges.iter().enumerate() {
            let bufs = self.bufs[c].take().expect("chunk bufs in flight");
            let g: Vec<Grid> = grids[lo..hi].to_vec();
            let rs: Vec<Ruleset> =
                rulesets[lo..hi].iter().map(|&r| r.clone()).collect();
            let ms: Vec<i32> = max_steps[lo..hi].to_vec();
            let rg: Vec<Rng> = rngs[lo..hi].to_vec();
            tickets.push(self.pool.call(c, move |w| {
                let mut bufs = bufs;
                let refs: Vec<&Ruleset> = rs.iter().collect();
                w.venv.reset_all(&g, &refs, &ms, &rg, &mut bufs.obs);
                bufs
            }));
        }
        for (c, ticket) in tickets.into_iter().enumerate() {
            let bufs = ticket.wait();
            let (lo, hi) = self.ranges[c];
            obs_out[lo * vv2..hi * vv2].copy_from_slice(&bufs.obs);
            self.bufs[c] = Some(bufs);
        }
        self.seeded = true;
    }

    /// Parallel [`VecEnv::step_all`]: one dispatch per chunk, outputs
    /// merged back into the caller's buffers in global env order —
    /// bitwise identical to the serial engine for any thread count.
    pub fn step_all(&mut self, actions: &[i32], obs_out: &mut [i32],
                    rewards: &mut [f32], dones: &mut [bool],
                    trial_dones: &mut [bool]) {
        assert_eq!(actions.len(), self.b, "need one action per env");
        assert_eq!(obs_out.len(), self.obs_len(), "obs buffer size");
        assert_eq!(rewards.len(), self.b);
        assert_eq!(dones.len(), self.b);
        assert_eq!(trial_dones.len(), self.b);
        let vv2 = self.vv2();
        let mut tickets = Vec::with_capacity(self.ranges.len());
        for (c, &(lo, hi)) in self.ranges.iter().enumerate() {
            let mut bufs =
                self.bufs[c].take().expect("chunk bufs in flight");
            bufs.actions.clear();
            bufs.actions.extend_from_slice(&actions[lo..hi]);
            tickets.push(self.pool.call(c, move |w| {
                let mut bufs = bufs;
                let ChunkBufs {
                    actions, obs, rewards, dones, trials, ..
                } = &mut bufs;
                w.venv.step_all(actions, obs, rewards, dones, trials);
                bufs
            }));
        }
        for (c, ticket) in tickets.into_iter().enumerate() {
            let bufs = ticket.wait();
            let (lo, hi) = self.ranges[c];
            obs_out[lo * vv2..hi * vv2].copy_from_slice(&bufs.obs);
            rewards[lo..hi].copy_from_slice(&bufs.rewards);
            dones[lo..hi].copy_from_slice(&bufs.dones);
            trial_dones[lo..hi].copy_from_slice(&bufs.trials);
            self.bufs[c] = Some(bufs);
        }
    }

    /// Fused random-policy rollout: `t` steps per env with actions drawn
    /// from `rng` in the serial order (step-major, env-minor), the whole
    /// `t`-step loop running worker-side off a single dispatch per
    /// chunk. Returns `(reward_sum, episodes_done, trials_done)`.
    ///
    /// The reward reduction is env-major — env `i` accumulates its own
    /// `f64` sum over the `t` steps, and the per-env sums are folded in
    /// ascending env order here — so the result is bit-identical for
    /// every thread count.
    pub fn rollout(&mut self, t: usize, rng: &mut Rng)
                   -> (f64, u64, u64) {
        let b = self.b;
        self.act_scratch.resize(t * b, 0);
        for a in self.act_scratch.iter_mut() {
            *a = rng.below(NUM_ACTIONS) as i32;
        }
        let acts = &self.act_scratch;
        let mut tickets = Vec::with_capacity(self.ranges.len());
        for (c, &(lo, hi)) in self.ranges.iter().enumerate() {
            let cb = hi - lo;
            let mut bufs =
                self.bufs[c].take().expect("chunk bufs in flight");
            bufs.actions.clear();
            for step in 0..t {
                bufs.actions
                    .extend_from_slice(&acts[step * b + lo..step * b + hi]);
            }
            tickets.push(self.pool.call(c, move |w| {
                let mut bufs = bufs;
                bufs.reward_acc.iter_mut().for_each(|x| *x = 0.0);
                let mut episodes = 0u64;
                let mut trials = 0u64;
                for step in 0..t {
                    let ChunkBufs {
                        actions, obs, rewards, dones, trials: tr,
                        reward_acc,
                    } = &mut bufs;
                    let a = &actions[step * cb..(step + 1) * cb];
                    w.venv.step_all(a, obs, rewards, dones, tr);
                    for (acc, &r) in reward_acc.iter_mut().zip(&*rewards)
                    {
                        *acc += r as f64;
                    }
                    episodes +=
                        dones.iter().filter(|&&d| d).count() as u64;
                    trials += tr.iter().filter(|&&d| d).count() as u64;
                }
                (bufs, episodes, trials)
            }));
        }
        let mut reward_sum = 0.0f64;
        let mut episodes = 0u64;
        let mut trials = 0u64;
        for (c, ticket) in tickets.into_iter().enumerate() {
            let (bufs, ep, tr) = ticket.wait();
            for &x in &bufs.reward_acc {
                reward_sum += x;
            }
            episodes += ep;
            trials += tr;
            self.bufs[c] = Some(bufs);
        }
        (reward_sum, episodes, trials)
    }

    /// Copy the most recent observations (from the last `reset_all`,
    /// `step_all` or `rollout`) into `out`, global env order.
    pub fn copy_obs_into(&self, out: &mut [i32]) {
        assert_eq!(out.len(), self.obs_len(), "obs buffer size");
        let vv2 = self.vv2();
        for (c, &(lo, hi)) in self.ranges.iter().enumerate() {
            let bufs =
                self.bufs[c].as_ref().expect("chunk bufs in flight");
            out[lo * vv2..hi * vv2].copy_from_slice(&bufs.obs);
        }
    }

    /// Full-batch snapshot: per-chunk snapshots concatenated in chunk
    /// (= global env) order. Equal across thread counts iff the engines
    /// are bitwise-identical.
    pub fn snapshot(&self) -> VecEnvSnapshot {
        let chunks = self.pool.broadcast(|_, w: &mut ChunkEnv| {
            w.venv.snapshot()
        });
        let mut out = VecEnvSnapshot::empty();
        for s in chunks {
            out.append(s);
        }
        out
    }

    // --- unified-API surface (env::api::BatchEnvironment) ------------------

    /// Parallel [`VecEnv::restart_all`]: per-env streams are split off
    /// `rng` in *global* env order on the coordinator thread, then
    /// shipped to the chunk workers — bitwise identical to the serial
    /// engine for any thread count.
    pub fn restart_all(&mut self, rng: &mut Rng, obs_out: &mut [i32]) {
        assert_eq!(obs_out.len(), self.obs_len(), "obs buffer size");
        let vv2 = self.vv2();
        let rngs: Vec<Rng> = (0..self.b).map(|_| rng.split()).collect();
        let mut tickets = Vec::with_capacity(self.ranges.len());
        for (c, &(lo, hi)) in self.ranges.iter().enumerate() {
            let bufs = self.bufs[c].take().expect("chunk bufs in flight");
            let rg: Vec<Rng> = rngs[lo..hi].to_vec();
            tickets.push(self.pool.call(c, move |w| {
                let mut bufs = bufs;
                for (j, r) in rg.into_iter().enumerate() {
                    w.venv.restart_env_with(j, r, &mut bufs.obs);
                }
                bufs
            }));
        }
        for (c, ticket) in tickets.into_iter().enumerate() {
            let bufs = ticket.wait();
            let (lo, hi) = self.ranges[c];
            obs_out[lo * vv2..hi * vv2].copy_from_slice(&bufs.obs);
            self.bufs[c] = Some(bufs);
        }
    }

    /// Per-env agent facing directions, global env order (one
    /// synchronous broadcast round-trip).
    pub fn copy_agent_dirs_into(&self, out: &mut [i32]) {
        assert_eq!(out.len(), self.b, "direction buffer size");
        let chunks = self.pool.broadcast(|_, w: &mut ChunkEnv| {
            let mut v = vec![0i32; w.venv.batch()];
            w.venv.copy_agent_dirs_into(&mut v);
            v
        });
        for (c, chunk) in chunks.into_iter().enumerate() {
            let (lo, hi) = self.ranges[c];
            out[lo..hi].copy_from_slice(&chunk);
        }
    }

    /// Per-env encoded task rows (goal `[5]` + rules `[MR, 7]`), global
    /// env order (one synchronous broadcast round-trip).
    pub fn copy_task_rows_into(&self, out: &mut [i32]) {
        let row = GOAL_ENC + self.cfg.max_rules * RULE_ENC;
        assert_eq!(out.len(), self.b * row, "task row buffer size");
        let chunks = self.pool.broadcast(|_, w: &mut ChunkEnv| {
            let mr = w.venv.config().max_rules;
            let mut v =
                vec![0i32; w.venv.batch() * (GOAL_ENC + mr * RULE_ENC)];
            w.venv.copy_task_rows_into(&mut v);
            v
        });
        for (c, chunk) in chunks.into_iter().enumerate() {
            let (lo, hi) = self.ranges[c];
            out[lo * row..hi * row].copy_from_slice(&chunk);
        }
    }
}

/// The chunked parallel engine under the unified batch API — the same
/// contract as the serial [`VecEnv`] impl, thread-count invariant by
/// the determinism argument above.
impl BatchEnvironment for ParVecEnv {
    fn batch(&self) -> usize {
        self.b
    }

    fn obs_spec(&self) -> ObsSpec {
        self.cfg.obs_spec()
    }

    fn action_spec(&self) -> ActionSpec {
        self.cfg.action_spec()
    }

    fn max_rules(&self) -> usize {
        self.cfg.max_rules
    }

    fn reset(&mut self, rng: &mut Rng, obs_out: &mut [i32]) -> Result<()> {
        anyhow::ensure!(
            self.seeded,
            "ParVecEnv: no episode inputs installed — seed base grids / \
             tasks / step limits with reset_all once before the \
             trait-level reset restarts episodes"
        );
        self.restart_all(rng, obs_out);
        Ok(())
    }

    fn step(&mut self, actions: &[i32], obs_out: &mut [i32],
            rewards: &mut [f32], dones: &mut [bool],
            trial_dones: &mut [bool]) -> Result<()> {
        self.step_all(actions, obs_out, rewards, dones, trial_dones);
        Ok(())
    }

    fn agent_dirs_into(&self, out: &mut [i32]) {
        self.copy_agent_dirs_into(out)
    }

    fn task_rows_into(&self, out: &mut [i32]) {
        self.copy_task_rows_into(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::env::state::EnvOptions;
    use crate::env::types::{Cell, COLOR_RED, TILE_BALL};
    use crate::env::Goal;

    fn simple_ruleset() -> Ruleset {
        Ruleset {
            goal: Goal::agent_near(Cell::new(TILE_BALL, COLOR_RED)),
            rules: vec![],
            init_tiles: vec![Cell::new(TILE_BALL, COLOR_RED)],
        }
    }

    fn reset_inputs(b: usize)
                    -> (Vec<Grid>, Ruleset, Vec<i32>, Vec<Rng>) {
        let grids = (0..b).map(|_| Grid::empty_room(9, 9)).collect();
        let rs = simple_ruleset();
        let maxs = vec![5i32; b];
        let rngs = (0..b).map(|i| Rng::new(300 + i as u64)).collect();
        (grids, rs, maxs, rngs)
    }

    /// Chunked parallel stepping must be bitwise identical to the plain
    /// serial `VecEnv` — outputs and internal state. (The full
    /// registry/thread-count matrix lives in `tests/native_threads.rs`.)
    #[test]
    fn parallel_matches_serial_vecenv() {
        let opts = EnvOptions::default();
        let cfg = VecEnvConfig { h: 9, w: 9, max_rules: 1, max_init: 1,
                                 opts };
        let b = 5usize; // odd on purpose: uneven chunks
        let (grids, rs, maxs, rngs) = reset_inputs(b);
        let refs: Vec<&Ruleset> = (0..b).map(|_| &rs).collect();

        let mut serial = VecEnv::new(cfg, b);
        let mut par = ParVecEnv::new(cfg, b, 3);
        let mut obs_s = vec![0i32; serial.obs_len()];
        let mut obs_p = vec![0i32; par.obs_len()];
        serial.reset_all(&grids, &refs, &maxs, &rngs, &mut obs_s);
        par.reset_all(&grids, &refs, &maxs, &rngs, &mut obs_p);
        assert_eq!(obs_s, obs_p, "reset obs");

        let mut rw_s = vec![0f32; b];
        let mut dn_s = vec![false; b];
        let mut tr_s = vec![false; b];
        let (mut rw_p, mut dn_p, mut tr_p) =
            (rw_s.clone(), dn_s.clone(), tr_s.clone());
        let mut act = Rng::new(4);
        for t in 0..20 {
            let actions: Vec<i32> =
                (0..b).map(|_| act.below(6) as i32).collect();
            serial.step_all(&actions, &mut obs_s, &mut rw_s, &mut dn_s,
                            &mut tr_s);
            par.step_all(&actions, &mut obs_p, &mut rw_p, &mut dn_p,
                         &mut tr_p);
            assert_eq!(obs_s, obs_p, "step {t}: obs");
            assert_eq!(rw_s.iter().map(|r| r.to_bits()).collect::<Vec<_>>(),
                       rw_p.iter().map(|r| r.to_bits()).collect::<Vec<_>>(),
                       "step {t}: rewards");
            assert_eq!(dn_s, dn_p, "step {t}: dones");
            assert_eq!(tr_s, tr_p, "step {t}: trials");
        }
        assert_eq!(serial.snapshot(), par.snapshot(),
                   "internal SoA buffers and RNG states");
    }

    /// The fused rollout's aggregates, final observations and internal
    /// state must be identical for every thread count.
    #[test]
    fn fused_rollout_thread_invariant() {
        let opts = EnvOptions::default();
        let cfg = VecEnvConfig { h: 9, w: 9, max_rules: 1, max_init: 1,
                                 opts };
        let b = 8usize;
        let run = |threads: usize| {
            let (grids, rs, maxs, rngs) = reset_inputs(b);
            let refs: Vec<&Ruleset> = (0..b).map(|_| &rs).collect();
            let mut par = ParVecEnv::new(cfg, b, threads);
            let mut obs = vec![0i32; par.obs_len()];
            par.reset_all(&grids, &refs, &maxs, &rngs, &mut obs);
            let mut rng = Rng::new(77);
            let totals = par.rollout(12, &mut rng);
            par.copy_obs_into(&mut obs);
            (totals.0.to_bits(), totals.1, totals.2, obs,
             par.snapshot())
        };
        let one = run(1);
        assert_eq!(one, run(2));
        assert_eq!(one, run(8));
    }

    #[test]
    fn threads_clamped_to_batch() {
        let opts = EnvOptions::default();
        let cfg = VecEnvConfig { h: 9, w: 9, max_rules: 1, max_init: 1,
                                 opts };
        let par = ParVecEnv::new(cfg, 2, 16);
        assert_eq!(par.threads(), 2);
        assert_eq!(par.batch(), 2);
        assert_eq!(par.obs_len(), 2 * 5 * 5 * 2);
    }
}
