//! L3 coordinator: vectorized env pool, RL² PPO training orchestration
//! (Anakin-style — the whole collect+update iteration is one fused HLO
//! call), the §4.2 evaluation protocol, and the shard pool standing in for
//! `jax.pmap` multi-device scaling.

pub mod config;
pub mod metrics;
pub mod pool;
pub mod shard;
pub mod trainer;

pub use config::TrainConfig;
pub use pool::EnvPool;
pub use trainer::{EvalStats, Trainer};
