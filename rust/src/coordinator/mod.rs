//! L3 coordinator: vectorized env pool, RL² PPO training orchestration
//! (Anakin-style — the whole collect+update iteration is one fused HLO
//! call), the §4.2 evaluation protocol, and the persistent shard engine
//! standing in for `jax.pmap` multi-device scaling. The rollout engine
//! is backend-generic: `--backend xla` drives AOT executables through
//! PJRT, `--backend native` drives the pure-Rust SoA `VecEnv` kernels
//! (see [`native`]) — same shard topology, same RNG streams, zero
//! artifacts.
//!
//! The execution model is a pipelined producer/consumer system: long-lived
//! shard worker threads (one PJRT replica each, driven over channels of
//! jobs — [`shard::ShardPool`]) produce trajectory buffers that the host
//! consumes, double-buffered when overlap is on. See `docs/ARCHITECTURE.md`
//! for the threading model.

pub mod checkpoint;
pub mod config;
pub mod eval;
pub mod metrics;
pub mod native;
pub mod native_trainer;
pub mod pool;
pub mod rollout;
pub mod shard;
pub mod trainer;
pub mod workers;

pub use checkpoint::{load_checkpoint, save_checkpoint, TrainCheckpoint,
                     TrainerState};
pub use config::{BackendKind, Overlap, ShardConfig, TrainConfig};
pub use eval::{eval_kshot, EvalPolicy, KShotConfig, KShotReport,
               ShotStats};
pub use native::{NativeEnvConfig, NativePool};
pub use native_trainer::{NativeShardedTrainer, NativeTrainer,
                         NativeTrainerConfig};
pub use pool::EnvPool;
pub use rollout::RolloutEngine;
pub use shard::ShardPool;
pub use trainer::{CheckpointPlan, EvalStats, ShardedTrainer, Trainer};
pub use workers::ParVecEnv;
