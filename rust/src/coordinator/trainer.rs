//! RL² PPO trainer: drives `train_iter` artifacts (collect + update fused
//! into one HLO call), handles task resampling between iterations, and
//! implements the §4.2 evaluation protocol (N tasks × trials, mean and
//! 20th percentile).
//!
//! [`ShardedTrainer`] scales the single-replica [`Trainer`] across the
//! shard engine: one full trainer replica per shard thread, fixed-order
//! averaging of per-iteration parameter updates on the host (the pmap
//! all-reduce), and — with overlap on — a double-buffered pipeline that
//! lets shards compute iteration *t+1* while the host reduces and logs
//! iteration *t*.

use std::collections::VecDeque;
use std::path::PathBuf;

use anyhow::{bail, ensure, Context, Result};
use std::sync::Arc;

use crate::benchgen::Benchmark;
use crate::runtime::state::NUM_STATE_FIELDS;
use crate::runtime::{Artifact, Manifest, Runtime, Tensor};
use crate::util::fault::FaultPlan;
use crate::util::rng::Rng;
use crate::util::stats::{mean, percentile};

use super::checkpoint::{save_checkpoint, TrainCheckpoint, TrainerState};
use super::config::{ShardConfig, TrainConfig};
use super::pool::{EnvFamily, EnvPool};
use super::rollout::{shard_seed, PIPELINE_DEPTH};
use super::shard::{add_params, average_param_tensors, sub_params,
                   ShardPool, Ticket};

pub const NUM_PARAMS: usize = 11;
const NUM_METRICS: usize = 8;

/// One iteration's training metrics (from the train_update HLO).
#[derive(Clone, Copy, Debug, Default)]
pub struct IterMetrics {
    pub total_loss: f32,
    pub pi_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub approx_kl: f32,
    pub clip_frac: f32,
    pub grad_norm: f32,
    pub adv_std: f32,
    pub reward_sum: f32,
    pub trials: i64,
    pub episodes: i64,
    pub env_steps: u64,
}

/// Evaluation summary over tasks (paper reports mean + 20th percentile).
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalStats {
    pub return_mean: f64,
    pub return_p20: f64,
    pub per_trial_mean: f64,
    pub per_trial_p20: f64,
    pub trials_mean: f64,
    pub num_tasks: usize,
}

pub struct Trainer {
    pub family: EnvFamily,
    pub t_len: usize,
    train_art: Arc<Artifact>,
    pool: EnvPool,
    pub cfg: TrainConfig,
    // learner state (host copies; device round-trip once per iteration)
    pub params: Vec<Tensor>,
    m: Vec<Tensor>,
    v: Vec<Tensor>,
    t: Tensor,
    // RL² carry
    obs: Tensor,
    prev_a: Tensor,
    prev_r: Tensor,
    done_prev: Tensor,
    h: Tensor,
    hidden_dim: usize,
    pub rng: Rng,
    pub iter: usize,
}

impl Trainer {
    /// Build a trainer around a `train_iter_*` artifact name.
    pub fn new(rt: &Runtime, artifact: &str, rooms: usize,
               cfg: TrainConfig) -> Result<Trainer> {
        let train_art = rt.load(artifact)?;
        let spec = &train_art.spec;
        if spec.kind() != "train_iter" {
            bail!("{artifact} is not a train_iter artifact");
        }
        let family = EnvFamily::from_spec(spec)?;
        let t_len = spec.meta_usize("T")?;
        let hidden_dim = spec.meta_usize("H_DIM")?;
        let pool = EnvPool::new(rt, family, rooms)?;
        let params = rt.load_params_init()?;
        let m: Vec<Tensor> = params
            .iter()
            .map(|p| Tensor::F32(vec![0.0; p.len()]))
            .collect();
        let v = m.clone();
        let b = family.b;
        Ok(Trainer {
            family,
            t_len,
            train_art,
            pool,
            cfg,
            params,
            m,
            v,
            t: Tensor::I32(vec![0]),
            obs: Tensor::I32(vec![]),
            prev_a: Tensor::I32(vec![0; b]),
            prev_r: Tensor::F32(vec![0.0; b]),
            done_prev: Tensor::I32(vec![1; b]),
            h: Tensor::F32(vec![0.0; b * hidden_dim]),
            hidden_dim,
            rng: Rng::new(cfg.train_seed),
            iter: 0,
        })
    }

    /// Overwrite the policy/value parameters (the broadcast half of the
    /// shard engine's all-reduce). Adam moments stay local to this
    /// replica — only parameters cross the shard boundary, like the
    /// paper's pmap all-reduce of the learner state's gradient half.
    pub fn set_params(&mut self, params: Vec<Tensor>) {
        debug_assert_eq!(params.len(), self.params.len());
        self.params = params;
    }

    /// Sample fresh tasks for every env and reset (called at start and
    /// every `task_resample_iters` iterations).
    pub fn resample_tasks(&mut self, bench: &Benchmark) -> Result<()> {
        let rulesets = {
            let mut rng = self.rng.split();
            self.pool.sample_rulesets(bench, &mut rng)
        };
        let mut rng = self.rng.split();
        self.pool.reset(&rulesets, &mut rng)?;
        self.obs = self.pool.last_obs.clone();
        let b = self.family.b;
        self.prev_a = Tensor::I32(vec![0; b]);
        self.prev_r = Tensor::F32(vec![0.0; b]);
        self.done_prev = Tensor::I32(vec![1; b]); // episode start: reset h
        self.h = Tensor::F32(vec![0.0; b * self.hidden_dim]);
        Ok(())
    }

    /// One fused PPO iteration (collect T×B steps + minibatch updates).
    pub fn train_iter(&mut self) -> Result<IterMetrics> {
        if self.obs.is_empty() {
            bail!("call resample_tasks before train_iter");
        }
        let mut inputs = Vec::with_capacity(3 * NUM_PARAMS + 20);
        inputs.extend(self.params.iter().cloned());
        inputs.extend(self.m.iter().cloned());
        inputs.extend(self.v.iter().cloned());
        inputs.push(self.t.clone());
        inputs.extend(self.pool.state.iter().cloned());
        inputs.push(self.obs.clone());
        inputs.push(self.prev_a.clone());
        inputs.push(self.prev_r.clone());
        inputs.push(self.done_prev.clone());
        inputs.push(self.h.clone());
        inputs.push(Tensor::U32(vec![self.rng.next_u32(),
                                     self.rng.next_u32()]));
        inputs.push(Tensor::F32(self.cfg.hp_vector()));

        let out = self.train_art.execute(&inputs)?;
        let mut it = out.into_iter();
        self.params = (&mut it).take(NUM_PARAMS).collect();
        self.m = (&mut it).take(NUM_PARAMS).collect();
        self.v = (&mut it).take(NUM_PARAMS).collect();
        self.t = it.next().context("missing t")?;
        self.pool.state = (&mut it).take(NUM_STATE_FIELDS).collect();
        self.obs = it.next().context("missing obs")?;
        self.prev_a = it.next().context("missing prev_a")?;
        self.prev_r = it.next().context("missing prev_r")?;
        self.done_prev = it.next().context("missing done_prev")?;
        self.h = it.next().context("missing h")?;
        let metrics = it.next().context("missing metrics")?;
        let reward_sum = it.next().context("missing reward_sum")?;
        let trials = it.next().context("missing trials")?;
        let episodes = it.next().context("missing episodes")?;

        let ms = metrics.as_f32();
        if ms.len() != NUM_METRICS {
            bail!("metrics vector has {} entries", ms.len());
        }
        self.iter += 1;
        Ok(IterMetrics {
            total_loss: ms[0],
            pi_loss: ms[1],
            v_loss: ms[2],
            entropy: ms[3],
            approx_kl: ms[4],
            clip_frac: ms[5],
            grad_norm: ms[6],
            adv_std: ms[7],
            reward_sum: reward_sum.scalar_f32(),
            trials: trials.scalar_i32() as i64,
            episodes: episodes.scalar_i32() as i64,
            env_steps: (self.t_len * self.family.b) as u64,
        })
    }

    /// Capture everything the next `train_iter` depends on, so a restored
    /// replica continues bit-for-bit where this one left off.
    pub fn state_snapshot(&self) -> TrainerState {
        TrainerState {
            params: self.params.clone(),
            m: self.m.clone(),
            v: self.v.clone(),
            t: self.t.clone(),
            env_state: self.pool.state.clone(),
            last_obs: self.pool.last_obs.clone(),
            obs: self.obs.clone(),
            prev_a: self.prev_a.clone(),
            prev_r: self.prev_r.clone(),
            done_prev: self.done_prev.clone(),
            h: self.h.clone(),
            rng: self.rng.state(),
            task_rng: self.pool.task_rng_state(),
            iter: self.iter as u64,
        }
    }

    /// Restore a [`state_snapshot`](Self::state_snapshot). The trainer
    /// must have been built from the same artifact (same parameter and
    /// env-state shapes) — mismatches are a clean error, never a
    /// silently-wrong resume.
    pub fn restore_state(&mut self, s: &TrainerState) -> Result<()> {
        ensure!(
            s.params.len() == self.params.len(),
            "checkpoint has {} parameter tensors, this artifact has {} \
             — was it written by a different model?",
            s.params.len(),
            self.params.len()
        );
        ensure!(
            s.env_state.len() == self.pool.state.len(),
            "checkpoint has {} env-state tensors, expected {}",
            s.env_state.len(),
            self.pool.state.len()
        );
        self.params = s.params.clone();
        self.m = s.m.clone();
        self.v = s.v.clone();
        self.t = s.t.clone();
        self.pool.state = s.env_state.clone();
        self.pool.last_obs = s.last_obs.clone();
        self.obs = s.obs.clone();
        self.prev_a = s.prev_a.clone();
        self.prev_r = s.prev_r.clone();
        self.done_prev = s.done_prev.clone();
        self.h = s.h.clone();
        self.rng = Rng::from_state(s.rng);
        if let Some(tr) = s.task_rng {
            self.pool.restore_task_rng(tr)?;
        }
        self.iter = s.iter as usize;
        Ok(())
    }

    /// §4.2 evaluation: roll the current policy over `eval_art`'s batch of
    /// held-out tasks and report mean / 20th-percentile return.
    pub fn evaluate(&mut self, rt: &Runtime, eval_artifact: &str,
                    bench: &Benchmark, rooms: usize) -> Result<EvalStats> {
        let eval_art = rt.load(eval_artifact)?;
        let spec = &eval_art.spec;
        if spec.kind() != "eval_rollout" {
            bail!("{eval_artifact} is not an eval_rollout artifact");
        }
        let family = EnvFamily::from_spec(spec)?;
        if family.h != self.family.h || family.w != self.family.w {
            bail!("eval artifact grid differs from training grid");
        }
        let mut pool = EnvPool::new(rt, family, rooms)?;
        let mut rng = Rng::new(self.cfg.eval_seed);
        let rulesets = pool.sample_rulesets(bench, &mut rng.split());
        pool.reset(&rulesets, &mut rng)?;

        let b = family.b;
        let mut inputs = Vec::new();
        inputs.extend(self.params.iter().cloned());
        inputs.extend(pool.state.iter().cloned());
        inputs.push(pool.last_obs.clone());
        inputs.push(Tensor::I32(vec![0; b]));
        inputs.push(Tensor::F32(vec![0.0; b]));
        inputs.push(Tensor::I32(vec![1; b]));
        inputs.push(Tensor::F32(vec![0.0; b * self.hidden_dim]));
        inputs.push(Tensor::U32(vec![rng.next_u32(), rng.next_u32()]));

        let out = eval_art.execute(&inputs)?;
        let n = out.len();
        let acc_r = out[n - 3].as_f32();
        let acc_goals = out[n - 2].as_i32();
        let acc_eps = out[n - 1].as_i32();

        let returns: Vec<f64> = acc_r.iter().map(|&x| x as f64).collect();
        let per_trial: Vec<f64> = acc_r
            .iter()
            .zip(acc_goals.iter().zip(acc_eps))
            .map(|(&r, (&g, &e))| r as f64 / ((g + e).max(1)) as f64)
            .collect();
        let trials: Vec<f64> = acc_goals
            .iter()
            .zip(acc_eps)
            .map(|(&g, &e)| (g + e) as f64)
            .collect();
        Ok(EvalStats {
            return_mean: mean(&returns),
            return_p20: percentile(&returns, 20.0),
            per_trial_mean: mean(&per_trial),
            per_trial_p20: percentile(&per_trial, 20.0),
            trials_mean: mean(&trials),
            num_tasks: b,
        })
    }
}

/// One shard's contribution to a training iteration: the local parameter
/// update (delta) it computed, plus its metrics.
type ShardIterOut = Result<(Vec<Tensor>, IterMetrics)>;

/// A full trainer replica living on one shard thread.
struct TrainerReplica {
    rt: Runtime,
    trainer: Trainer,
    bench: Arc<Benchmark>,
}

impl TrainerReplica {
    /// Run one fused PPO iteration from the broadcast `basis` parameters
    /// and return the local update `params_after - basis`.
    fn shard_iter(&mut self, basis: Arc<Vec<Tensor>>, resample: bool)
                  -> ShardIterOut {
        self.trainer.set_params((*basis).clone());
        if resample {
            self.trainer.resample_tasks(&self.bench)?;
        }
        let m = self.trainer.train_iter()?;
        Ok((sub_params(&self.trainer.params, &basis), m))
    }
}

/// Data-parallel RL² PPO across the shard engine.
///
/// Every shard thread owns a full [`Trainer`] replica (its own PJRT
/// client, `train_iter` executable, env states and Adam moments). The
/// host thread holds the *master* parameters and drives iterations:
///
/// 1. broadcast the master parameters as the iteration's basis,
/// 2. each shard runs one fused collect+update and returns its local
///    parameter delta,
/// 3. the host averages the deltas in ascending shard order (f32
///    addition is not associative — the fixed order is the determinism
///    contract) and folds the mean into the master.
///
/// With overlap **off** this is the classic lockstep pmap step: one
/// iteration in flight, every shard starts from the freshly averaged
/// master, bitwise reproducible for a fixed seed.
///
/// With overlap **on** the pipeline keeps [`PIPELINE_DEPTH`] iterations
/// in flight: shards compute iteration *t+1* (from the master as of
/// *t-1* — one iteration of staleness) while the host reduces and logs
/// iteration *t*. All updates are still applied exactly once; they are
/// merely computed at a one-iteration-stale basis, the usual
/// stale-synchronous data-parallel trade.
/// Periodic crash-safe checkpointing for [`ShardedTrainer::train`].
///
/// When set, a [`TrainCheckpoint`] is written atomically to `path` every
/// `every` iterations. Checkpoint boundaries are *synchronization
/// points*: with overlap on, the pipeline never dispatches past an
/// unwritten boundary, so the snapshot observes a quiescent, fully
/// reduced state. This means the cadence is part of the run's schedule —
/// the determinism contract is "same seed, same shards, same cadence ⇒
/// same run", and `--resume` reproduces the interrupted schedule
/// exactly.
pub struct CheckpointPlan {
    /// final checkpoint path (written via tmp + rename)
    pub path: PathBuf,
    /// checkpoint every N iterations (0 disables)
    pub every: usize,
    /// fault-injection plan (drives `torn-checkpoint@iter=I`)
    pub faults: Arc<FaultPlan>,
}

pub struct ShardedTrainer {
    pool: ShardPool<TrainerReplica>,
    pub cfg: ShardConfig,
    pub train_cfg: TrainConfig,
    /// host-side master parameters (averaged across shards)
    pub master: Vec<Tensor>,
    pub family: EnvFamily,
    pub t_len: usize,
    /// iterations completed (reduced into the master)
    pub iters_done: usize,
    /// optional periodic crash-safe checkpointing
    pub checkpoint: Option<CheckpointPlan>,
}

impl ShardedTrainer {
    /// Spin up `cfg.shards` trainer replicas around one `train_iter`
    /// artifact. `cfg.seed` is the single run seed: shard `i` trains
    /// with `shard_seed(cfg.seed, i)` (any `train_cfg.train_seed` is
    /// overwritten so the two knobs cannot drift apart) and samples its
    /// tasks from `bench` with that private stream; all replicas start
    /// from the same `params_init.bin` master copy.
    pub fn launch(artifacts_dir: PathBuf, artifact: String,
                  bench: Arc<Benchmark>, cfg: ShardConfig,
                  mut train_cfg: TrainConfig) -> Result<ShardedTrainer> {
        train_cfg.train_seed = cfg.seed;
        let manifest = Manifest::load(&artifacts_dir)?;
        let spec = manifest.find(&artifact)?;
        if spec.kind() != "train_iter" {
            bail!("{artifact} is not a train_iter artifact");
        }
        let family = EnvFamily::from_spec(spec)?;
        let t_len = spec.meta_usize("T")?;
        let master =
            crate::runtime::load_params_init_from(&artifacts_dir,
                                                  &manifest)?;
        let rooms = cfg.rooms;
        let pool = ShardPool::spawn(cfg.shards, move |i| {
            let rt = Runtime::new(&artifacts_dir)?;
            let mut tc = train_cfg;
            tc.train_seed = shard_seed(cfg.seed, i);
            let mut trainer = Trainer::new(&rt, &artifact, rooms, tc)?;
            trainer
                .resample_tasks(&bench)
                .with_context(|| format!("initial resample, shard {i}"))?;
            Ok(TrainerReplica { rt, trainer, bench: bench.clone() })
        })?;
        Ok(ShardedTrainer {
            pool,
            cfg,
            train_cfg,
            master,
            family,
            t_len,
            iters_done: 0,
            checkpoint: None,
        })
    }

    /// Restore a previously saved [`TrainCheckpoint`]: master parameters,
    /// reduced iteration count, and every shard replica's full state. The
    /// trainer must have been launched with the same artifact and shard
    /// count the checkpoint was written with.
    pub fn restore(&mut self, ckpt: &TrainCheckpoint) -> Result<()> {
        ensure!(
            ckpt.shards.len() == self.shards(),
            "checkpoint holds {} shard states but the trainer is running \
             {} shards — resume with --shards {}",
            ckpt.shards.len(),
            self.shards(),
            ckpt.shards.len()
        );
        ensure!(
            ckpt.master.len() == self.master.len(),
            "checkpoint has {} master tensors, this artifact has {}",
            ckpt.master.len(),
            self.master.len()
        );
        let tickets: Vec<Ticket<Result<()>>> = ckpt
            .shards
            .iter()
            .enumerate()
            .map(|(s, st)| {
                let st = st.clone();
                self.pool.call(s, move |w| w.trainer.restore_state(&st))
            })
            .collect::<Result<Vec<_>>>()?;
        for (s, ticket) in tickets.into_iter().enumerate() {
            ticket
                .wait()
                .and_then(|r| r)
                .with_context(|| format!("restoring shard {s}"))?;
        }
        self.master = ckpt.master.clone();
        self.iters_done = ckpt.iters_done as usize;
        Ok(())
    }

    pub fn shards(&self) -> usize {
        self.pool.shards()
    }

    /// Environment steps contributed per iteration across all shards.
    pub fn steps_per_iter(&self) -> u64 {
        (self.t_len * self.family.b * self.shards()) as u64
    }

    /// Run `iters` training iterations, calling `consume(iter, metrics)`
    /// with the cross-shard reduced metrics as each iteration's results
    /// are folded into the master parameters. A `consume` error aborts
    /// training immediately (in-flight pipelined iterations are
    /// discarded) and is returned to the caller.
    pub fn train<C>(&mut self, iters: usize, mut consume: C) -> Result<()>
    where
        C: FnMut(usize, &IterMetrics) -> Result<()>,
    {
        let depth = if self.cfg.overlap.is_on() { PIPELINE_DEPTH } else { 1 };
        let shards = self.shards();
        let resample_every = self.train_cfg.task_resample_iters.max(1);
        let every = match &self.checkpoint {
            Some(p) if p.every > 0 => Some(p.every),
            _ => None,
        };
        let first = self.iters_done + 1;
        let last = self.iters_done + iters;
        // Last iteration already captured on disk (or implicitly captured
        // by being in the past when training started). The pipeline never
        // dispatches past an unwritten checkpoint boundary — see below.
        let mut ckpt_done = self.iters_done;
        let mut inflight: VecDeque<(usize, Vec<Ticket<ShardIterOut>>)> =
            VecDeque::new();
        let mut next = first;
        while next <= last || !inflight.is_empty() {
            // Keep the pipeline full: with depth 2 the dispatch of t+1
            // happens before t is reduced, so shards never idle on the
            // host's averaging / logging.
            //
            // Checkpoint barrier: iteration `next` may be dispatched only
            // once the latest checkpoint boundary strictly before it has
            // been written. Boundaries are therefore quiescent points —
            // when boundary t is reduced, no t+1 work has touched any
            // replica, so the snapshot is exactly "the run after t". The
            // cadence deterministically shapes the overlap schedule;
            // resuming reproduces that same schedule bit for bit.
            while next <= last && inflight.len() < depth {
                if let Some(e) = every {
                    let boundary = (next - 1) / e * e;
                    if boundary > ckpt_done {
                        break;
                    }
                }
                let basis = Arc::new(self.master.clone());
                let resample = next > 1 && (next - 1) % resample_every == 0;
                let tickets: Vec<Ticket<ShardIterOut>> = (0..shards)
                    .map(|s| {
                        let basis = basis.clone();
                        self.pool.call(s, move |w| {
                            w.shard_iter(basis, resample)
                        })
                    })
                    .collect::<Result<Vec<_>>>()
                    .with_context(|| {
                        format!("dispatching training iteration {next}")
                    })?;
                inflight.push_back((next, tickets));
                next += 1;
            }
            // the dispatch loop above always leaves >= 1 iteration in
            // flight while the outer condition holds; an empty queue here
            // is a scheduler bug, not a worker failure -- surface it as a
            // clean error instead of poisoning the supervised pool
            let Some((t, tickets)) = inflight.pop_front() else {
                bail!("training pipeline stalled: no iteration in flight");
            };
            let mut deltas = Vec::with_capacity(shards);
            let mut metrics = Vec::with_capacity(shards);
            for ticket in tickets {
                let (d, m) = ticket
                    .wait()
                    .and_then(|r| r)
                    .with_context(|| format!("training iteration {t}"))?;
                deltas.push(d);
                metrics.push(m);
            }
            // Fixed-order all-reduce: mean of the shard deltas, shard 0
            // first, folded into the master.
            let mean_delta = average_param_tensors(deltas);
            add_params(&mut self.master, &mean_delta);
            self.iters_done = t;
            if let Some(e) = every {
                if t % e == 0 {
                    self.write_checkpoint()?;
                    ckpt_done = t;
                }
            }
            let reduced = super::metrics::reduce_iter_metrics(&metrics);
            consume(t, &reduced)?;
        }
        Ok(())
    }

    /// Snapshot every replica and write an atomic checkpoint for the
    /// current `iters_done`. Callers must guarantee quiescence (no
    /// in-flight iterations past `iters_done`) — `train`'s barrier rule
    /// does.
    fn write_checkpoint(&self) -> Result<()> {
        let Some(plan) = &self.checkpoint else { return Ok(()) };
        let tickets: Vec<Ticket<TrainerState>> = (0..self.shards())
            .map(|s| self.pool.call(s, |w| w.trainer.state_snapshot()))
            .collect::<Result<Vec<_>>>()
            .context("dispatching checkpoint snapshots")?;
        let shards = tickets
            .into_iter()
            .enumerate()
            .map(|(s, t)| {
                t.wait()
                    .with_context(|| format!("snapshotting shard {s}"))
            })
            .collect::<Result<Vec<_>>>()?;
        let ckpt = TrainCheckpoint {
            iters_done: self.iters_done as u64,
            master: self.master.clone(),
            shards,
        };
        save_checkpoint(&plan.path, &ckpt, &plan.faults).with_context(
            || format!("checkpointing at iteration {}", self.iters_done),
        )
    }

    /// §4.2 evaluation of the *master* parameters, run on shard 0's
    /// replica (its queue guarantees this happens after any previously
    /// dispatched iterations).
    pub fn evaluate(&self, eval_artifact: &str, rooms: usize)
                    -> Result<EvalStats> {
        let master = Arc::new(self.master.clone());
        let name = eval_artifact.to_string();
        self.pool
            .call(0, move |w| {
                w.trainer.set_params((*master).clone());
                let bench = w.bench.clone();
                w.trainer.evaluate(&w.rt, &name, &bench, rooms)
            })
            .context("dispatching evaluation")?
            .wait()
            .and_then(|r| r)
    }
}
