//! Training configuration — paper Table 6 (RL² hyperparameters), with the
//! compute-scale knobs (num_envs, total steps) sized for the CPU testbed.

/// PPO/RL² hyperparameters. The first eight map onto the runtime `hp[8]`
/// vector consumed by the `train_iter` artifacts.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrainConfig {
    pub lr: f32,
    pub clip_eps: f32,
    pub gamma: f32,
    pub gae_lambda: f32,
    pub ent_coef: f32,
    pub vf_coef: f32,
    pub max_grad_norm: f32,
    /// resample tasks (rulesets) every this many train iterations
    pub task_resample_iters: usize,
    pub eval_seed: u64,
    pub train_seed: u64,
}

impl Default for TrainConfig {
    /// Table 6 values where they are hyperparameters (lr, clip, gamma,
    /// lambda, coefs, grad norm, seeds).
    fn default() -> Self {
        TrainConfig {
            lr: 1e-3,
            clip_eps: 0.2,
            gamma: 0.99,
            gae_lambda: 0.95,
            ent_coef: 0.01,
            vf_coef: 0.5,
            max_grad_norm: 0.5,
            task_resample_iters: 8,
            eval_seed: 42,
            train_seed: 42,
        }
    }
}

impl TrainConfig {
    /// The runtime hyperparameter vector (see model.HP_LEN).
    pub fn hp_vector(&self) -> Vec<f32> {
        vec![self.lr, self.clip_eps, self.gamma, self.gae_lambda,
             self.ent_coef, self.vf_coef, self.max_grad_norm, 0.0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 6 pinned.
    #[test]
    fn table6_defaults() {
        let c = TrainConfig::default();
        assert_eq!(c.lr, 1e-3);
        assert_eq!(c.clip_eps, 0.2);
        assert_eq!(c.gamma, 0.99);
        assert_eq!(c.gae_lambda, 0.95);
        assert_eq!(c.ent_coef, 0.01);
        assert_eq!(c.vf_coef, 0.5);
        assert_eq!(c.max_grad_norm, 0.5);
        assert_eq!(c.eval_seed, 42);
        assert_eq!(c.train_seed, 42);
    }

    #[test]
    fn hp_vector_layout() {
        let hp = TrainConfig::default().hp_vector();
        assert_eq!(hp.len(), 8);
        assert_eq!(hp[0], 1e-3);
        assert_eq!(hp[6], 0.5);
    }
}
