//! Training configuration — paper Table 6 (RL² hyperparameters), with the
//! compute-scale knobs (num_envs, total steps) sized for the CPU testbed,
//! plus the shard-engine execution knobs (`--shards` / `--overlap`).

use anyhow::{bail, Result};

/// Whether the shard engine pipelines collection against consumption.
///
/// `Off` is the lockstep mode: every round is a collective with a global
/// barrier and fixed-order reduction — bitwise reproducible for a fixed
/// seed. `On` enables the double-buffered pipeline: shards keep a second
/// trajectory buffer in flight while the consumer drains the first, and
/// the trainer applies averaged updates with one iteration of staleness.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Overlap {
    #[default]
    Off,
    On,
}

impl Overlap {
    /// Parse a `--overlap on|off` CLI value.
    pub fn from_flag(s: &str) -> Result<Overlap> {
        match s {
            "on" => Ok(Overlap::On),
            "off" => Ok(Overlap::Off),
            other => bail!("--overlap must be `on` or `off`, got {other}"),
        }
    }

    pub fn is_on(self) -> bool {
        self == Overlap::On
    }
}

impl std::fmt::Display for Overlap {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Overlap::On => "on",
            Overlap::Off => "off",
        })
    }
}

/// Which execution backend drives the rollout engine's shard replicas
/// (`xmgrid rollout --backend auto|native|xla`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum BackendKind {
    /// AOT artifacts through PJRT when a manifest with rollout
    /// artifacts is present, otherwise the native vectorized engine.
    #[default]
    Auto,
    /// Pure-Rust SoA `VecEnv` kernels — no artifacts, no PJRT.
    Native,
    /// Compiled HLO artifacts through the PJRT runtime.
    Xla,
}

impl BackendKind {
    /// Parse a `--backend auto|native|xla` CLI value.
    pub fn from_flag(s: &str) -> Result<BackendKind> {
        match s {
            "auto" => Ok(BackendKind::Auto),
            "native" => Ok(BackendKind::Native),
            "xla" => Ok(BackendKind::Xla),
            other => bail!(
                "--backend must be `auto`, `native` or `xla`, got {other}"
            ),
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            BackendKind::Auto => "auto",
            BackendKind::Native => "native",
            BackendKind::Xla => "xla",
        })
    }
}

/// Execution shape of the shard engine, shared by `rollout` and `train`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ShardConfig {
    /// number of shard replicas (pmap stand-in axis)
    pub shards: usize,
    /// double-buffered pipelining on/off
    pub overlap: Overlap,
    /// run seed; each shard derives a private stream from it
    pub seed: u64,
    /// rooms for base-grid construction on reset
    pub rooms: usize,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            shards: 1,
            overlap: Overlap::Off,
            seed: 0,
            rooms: 1,
        }
    }
}

/// PPO/RL² hyperparameters. The first eight map onto the runtime `hp[8]`
/// vector consumed by the `train_iter` artifacts.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrainConfig {
    pub lr: f32,
    pub clip_eps: f32,
    pub gamma: f32,
    pub gae_lambda: f32,
    pub ent_coef: f32,
    pub vf_coef: f32,
    pub max_grad_norm: f32,
    /// resample tasks (rulesets) every this many train iterations
    pub task_resample_iters: usize,
    pub eval_seed: u64,
    pub train_seed: u64,
}

impl Default for TrainConfig {
    /// Table 6 values where they are hyperparameters (lr, clip, gamma,
    /// lambda, coefs, grad norm, seeds).
    fn default() -> Self {
        TrainConfig {
            lr: 1e-3,
            clip_eps: 0.2,
            gamma: 0.99,
            gae_lambda: 0.95,
            ent_coef: 0.01,
            vf_coef: 0.5,
            max_grad_norm: 0.5,
            task_resample_iters: 8,
            eval_seed: 42,
            train_seed: 42,
        }
    }
}

impl TrainConfig {
    /// The runtime hyperparameter vector (see model.HP_LEN).
    pub fn hp_vector(&self) -> Vec<f32> {
        vec![self.lr, self.clip_eps, self.gamma, self.gae_lambda,
             self.ent_coef, self.vf_coef, self.max_grad_norm, 0.0]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Table 6 pinned.
    #[test]
    fn table6_defaults() {
        let c = TrainConfig::default();
        assert_eq!(c.lr, 1e-3);
        assert_eq!(c.clip_eps, 0.2);
        assert_eq!(c.gamma, 0.99);
        assert_eq!(c.gae_lambda, 0.95);
        assert_eq!(c.ent_coef, 0.01);
        assert_eq!(c.vf_coef, 0.5);
        assert_eq!(c.max_grad_norm, 0.5);
        assert_eq!(c.eval_seed, 42);
        assert_eq!(c.train_seed, 42);
    }

    #[test]
    fn hp_vector_layout() {
        let hp = TrainConfig::default().hp_vector();
        assert_eq!(hp.len(), 8);
        assert_eq!(hp[0], 1e-3);
        assert_eq!(hp[6], 0.5);
    }

    #[test]
    fn overlap_flag_parsing() {
        assert_eq!(Overlap::from_flag("on").unwrap(), Overlap::On);
        assert_eq!(Overlap::from_flag("off").unwrap(), Overlap::Off);
        assert!(Overlap::from_flag("maybe").is_err());
        assert_eq!(Overlap::On.to_string(), "on");
        assert!(!ShardConfig::default().overlap.is_on());
    }

    #[test]
    fn backend_flag_parsing() {
        assert_eq!(BackendKind::from_flag("auto").unwrap(),
                   BackendKind::Auto);
        assert_eq!(BackendKind::from_flag("native").unwrap(),
                   BackendKind::Native);
        assert_eq!(BackendKind::from_flag("xla").unwrap(),
                   BackendKind::Xla);
        assert!(BackendKind::from_flag("tpu").is_err());
        assert_eq!(BackendKind::default(), BackendKind::Auto);
        assert_eq!(BackendKind::Native.to_string(), "native");
    }
}
