//! Crash-safe training checkpoints.
//!
//! A checkpoint captures *everything* the training loop's future depends
//! on — master parameters, per-shard learner state (params + Adam
//! moments + step counter), the device-resident env state tensors, the
//! RL² carry, and every RNG stream position — so `xmgrid train --resume`
//! reproduces the uninterrupted run **bit for bit** (the fused HLO
//! iteration is a pure function of these inputs).
//!
//! # File format
//!
//! ```text
//! magic   "XMGC"          4 bytes
//! version u32 LE          (currently 1)
//! len     u64 LE          body length in bytes
//! body    [u8; len]       serialized TrainCheckpoint (see encode_*)
//! check   u64 LE          FNV-1a 64 of body
//! ```
//!
//! The explicit length and trailing checksum make *torn* writes
//! (truncation) and silent corruption detectable on load — a damaged
//! checkpoint is a clean error naming the file and the defect, never a
//! garbage resume.
//!
//! # Atomicity
//!
//! [`save_checkpoint`] streams to a process-unique `.tmp-<pid>` sibling
//! and `rename`s onto the final path (the same discipline as
//! `BenchmarkWriter`), so a crash mid-write leaves the previous
//! checkpoint intact. The `torn-checkpoint@iter=I` fault
//! ([`crate::util::fault::FaultPlan`]) deliberately bypasses this and
//! writes a truncated file at the final path, so the detection path is
//! provable in tests and CI.

use std::path::Path;

use anyhow::{bail, ensure, Context, Result};

use crate::env::goals::Goal;
use crate::env::rules::Rule;
use crate::env::types::{Cell, GOAL_ENC, RULE_ENC};
use crate::env::vector::VecEnvSnapshot;
use crate::runtime::Tensor;
use crate::util::fault::FaultPlan;

const MAGIC: &[u8; 4] = b"XMGC";
const VERSION: u32 = 1;

/// One trainer replica's complete resumable state (the host copies of
/// everything [`super::trainer::Trainer`] threads through the fused
/// iteration).
#[derive(Clone, Debug, PartialEq)]
pub struct TrainerState {
    pub params: Vec<Tensor>,
    /// Adam first moments
    pub m: Vec<Tensor>,
    /// Adam second moments
    pub v: Vec<Tensor>,
    /// Adam step counter tensor
    pub t: Tensor,
    /// device-resident env state tensors (aot.STATE_FIELDS order)
    pub env_state: Vec<Tensor>,
    /// pool's latest observation (re-read at task resample)
    pub last_obs: Tensor,
    // RL² carry
    pub obs: Tensor,
    pub prev_a: Tensor,
    pub prev_r: Tensor,
    pub done_prev: Tensor,
    pub h: Tensor,
    /// trainer RNG stream position
    pub rng: [u64; 4],
    /// env pool's task-draw stream, when a source is installed
    pub task_rng: Option<[u64; 4]>,
    /// iterations this replica has completed
    pub iter: u64,
}

/// A full training-run checkpoint: the host master parameters plus one
/// [`TrainerState`] per shard, tagged with the reduced iteration count.
#[derive(Clone, Debug, PartialEq)]
pub struct TrainCheckpoint {
    /// iterations reduced into the master when this was taken
    pub iters_done: u64,
    /// host-side master parameters
    pub master: Vec<Tensor>,
    /// per-shard replica states, shard order
    pub shards: Vec<TrainerState>,
}

// --- env snapshot <-> tensors ---------------------------------------------

fn cells_to_i32(cells: &[Cell]) -> Vec<i32> {
    let mut out = Vec::with_capacity(cells.len() * 2);
    for c in cells {
        out.push(c.tile);
        out.push(c.color);
    }
    out
}

fn i32_to_cells(v: &[i32]) -> Result<Vec<Cell>> {
    ensure!(v.len() % 2 == 0, "odd cell-pair tensor length {}", v.len());
    Ok(v.chunks_exact(2)
        .map(|p| Cell { tile: p[0], color: p[1] })
        .collect())
}

/// Encode a [`VecEnvSnapshot`] as 12 tensors in a fixed order — the
/// native trainer's `env_state` representation (the analogue of the
/// XLA trainer's `aot.STATE_FIELDS` device tensors). Cells flatten to
/// `(tile, color)` i32 pairs; each RNG state becomes 8 u32 words
/// (lo, hi per u64 lane).
pub fn encode_env_snapshot(s: &VecEnvSnapshot) -> Vec<Tensor> {
    let mut rng_words = Vec::with_capacity(s.rng_states.len() * 8);
    for st in &s.rng_states {
        for &lane in st {
            rng_words.push(lane as u32);
            rng_words.push((lane >> 32) as u32);
        }
    }
    vec![
        Tensor::I32(cells_to_i32(&s.base)),
        Tensor::I32(cells_to_i32(&s.grid)),
        Tensor::I32(s.agent_pos.clone()),
        Tensor::I32(s.agent_dir.clone()),
        Tensor::I32(cells_to_i32(&s.pocket)),
        Tensor::I32(s.rules.iter().flat_map(|r| r.0).collect()),
        Tensor::I32(s.goals.iter().flat_map(|g| g.0).collect()),
        Tensor::I32(cells_to_i32(&s.init)),
        Tensor::U32(s.init_len.clone()),
        Tensor::I32(s.step_count.clone()),
        Tensor::I32(s.max_steps.clone()),
        Tensor::U32(rng_words),
    ]
}

fn want_i32(t: &Tensor, what: &str) -> Result<Vec<i32>> {
    match t {
        Tensor::I32(v) => Ok(v.clone()),
        other => bail!("env-state field `{what}`: expected an I32 \
                        tensor, found {other:?}"),
    }
}

fn want_u32(t: &Tensor, what: &str) -> Result<Vec<u32>> {
    match t {
        Tensor::U32(v) => Ok(v.clone()),
        other => bail!("env-state field `{what}`: expected a U32 \
                        tensor, found {other:?}"),
    }
}

/// Decode the inverse of [`encode_env_snapshot`]. Structural defects
/// (wrong tensor count, wrong dtype, non-divisible lengths) are clean
/// errors — a corrupt resume must never panic.
pub fn decode_env_snapshot(ts: &[Tensor]) -> Result<VecEnvSnapshot> {
    ensure!(ts.len() == 12,
            "env-state tensor count {} (expected 12)", ts.len());
    let rules_flat = want_i32(&ts[5], "rules")?;
    ensure!(rules_flat.len() % RULE_ENC == 0,
            "rules tensor length {} not a multiple of {RULE_ENC}",
            rules_flat.len());
    let goals_flat = want_i32(&ts[6], "goals")?;
    ensure!(goals_flat.len() % GOAL_ENC == 0,
            "goals tensor length {} not a multiple of {GOAL_ENC}",
            goals_flat.len());
    let rng_words = want_u32(&ts[11], "rng_states")?;
    ensure!(rng_words.len() % 8 == 0,
            "rng tensor length {} not a multiple of 8", rng_words.len());
    let mut rng_states = Vec::with_capacity(rng_words.len() / 8);
    for w in rng_words.chunks_exact(8) {
        let mut st = [0u64; 4];
        for (lane, p) in st.iter_mut().zip(w.chunks_exact(2)) {
            *lane = p[0] as u64 | ((p[1] as u64) << 32);
        }
        rng_states.push(st);
    }
    Ok(VecEnvSnapshot {
        base: i32_to_cells(&want_i32(&ts[0], "base")?)?,
        grid: i32_to_cells(&want_i32(&ts[1], "grid")?)?,
        agent_pos: want_i32(&ts[2], "agent_pos")?,
        agent_dir: want_i32(&ts[3], "agent_dir")?,
        pocket: i32_to_cells(&want_i32(&ts[4], "pocket")?)?,
        rules: rules_flat
            .chunks_exact(RULE_ENC)
            .map(|c| {
                let mut r = [0i32; RULE_ENC];
                r.copy_from_slice(c);
                Rule(r)
            })
            .collect(),
        goals: goals_flat
            .chunks_exact(GOAL_ENC)
            .map(|c| {
                let mut g = [0i32; GOAL_ENC];
                g.copy_from_slice(c);
                Goal(g)
            })
            .collect(),
        init: i32_to_cells(&want_i32(&ts[7], "init")?)?,
        init_len: want_u32(&ts[8], "init_len")?,
        step_count: want_i32(&ts[9], "step_count")?,
        max_steps: want_i32(&ts[10], "max_steps")?,
        rng_states,
    })
}

// --- primitive encoding ---------------------------------------------------

fn put_u32(out: &mut Vec<u8>, x: u32) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, x: u64) {
    out.extend_from_slice(&x.to_le_bytes());
}

fn put_tensor(out: &mut Vec<u8>, t: &Tensor) {
    match t {
        Tensor::I32(v) => {
            out.push(0);
            put_u64(out, v.len() as u64);
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Tensor::U32(v) => {
            out.push(1);
            put_u64(out, v.len() as u64);
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        Tensor::F32(v) => {
            out.push(2);
            put_u64(out, v.len() as u64);
            for x in v {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
    }
}

fn put_tensors(out: &mut Vec<u8>, ts: &[Tensor]) {
    put_u64(out, ts.len() as u64);
    for t in ts {
        put_tensor(out, t);
    }
}

fn put_rng(out: &mut Vec<u8>, s: &[u64; 4]) {
    for &x in s {
        put_u64(out, x);
    }
}

/// Bounded little-endian reader over the checkpoint body; every read is
/// length-checked so truncation surfaces as an error, never a panic.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.pos + n <= self.buf.len(),
            "truncated checkpoint body (wanted {} bytes at offset {}, \
             have {})",
            n,
            self.pos,
            self.buf.len()
        );
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes(b.try_into().unwrap()))
    }

    /// A u64 that is about to size an allocation: bound it by the bytes
    /// actually remaining so a corrupt length can't OOM the process.
    fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u64()?;
        let cap = (self.buf.len() - self.pos) / elem_bytes.max(1) + 1;
        ensure!(n as usize <= cap,
                "corrupt checkpoint: implausible element count {n}");
        Ok(n as usize)
    }

    fn tensor(&mut self) -> Result<Tensor> {
        let tag = self.u8()?;
        let n = self.count(4)?;
        let raw = self.take(n * 4)?;
        let mut chunks = raw.chunks_exact(4);
        Ok(match tag {
            0 => Tensor::I32(
                chunks
                    .by_ref()
                    .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
            1 => Tensor::U32(
                chunks
                    .by_ref()
                    .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
            2 => Tensor::F32(
                chunks
                    .by_ref()
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect(),
            ),
            other => bail!("corrupt checkpoint: unknown tensor tag {other}"),
        })
    }

    fn tensors(&mut self) -> Result<Vec<Tensor>> {
        // 9 = tag + u64 len, the minimum encoded tensor size
        let n = self.count(9)?;
        (0..n).map(|_| self.tensor()).collect()
    }

    fn rng(&mut self) -> Result<[u64; 4]> {
        Ok([self.u64()?, self.u64()?, self.u64()?, self.u64()?])
    }
}

fn put_trainer_state(out: &mut Vec<u8>, s: &TrainerState) {
    put_tensors(out, &s.params);
    put_tensors(out, &s.m);
    put_tensors(out, &s.v);
    put_tensor(out, &s.t);
    put_tensors(out, &s.env_state);
    put_tensor(out, &s.last_obs);
    put_tensor(out, &s.obs);
    put_tensor(out, &s.prev_a);
    put_tensor(out, &s.prev_r);
    put_tensor(out, &s.done_prev);
    put_tensor(out, &s.h);
    put_rng(out, &s.rng);
    match &s.task_rng {
        Some(r) => {
            out.push(1);
            put_rng(out, r);
        }
        None => out.push(0),
    }
    put_u64(out, s.iter);
}

fn read_trainer_state(r: &mut Reader) -> Result<TrainerState> {
    Ok(TrainerState {
        params: r.tensors()?,
        m: r.tensors()?,
        v: r.tensors()?,
        t: r.tensor()?,
        env_state: r.tensors()?,
        last_obs: r.tensor()?,
        obs: r.tensor()?,
        prev_a: r.tensor()?,
        prev_r: r.tensor()?,
        done_prev: r.tensor()?,
        h: r.tensor()?,
        rng: r.rng()?,
        task_rng: match r.u8()? {
            0 => None,
            1 => Some(r.rng()?),
            other => bail!(
                "corrupt checkpoint: bad task-rng tag {other}"
            ),
        },
        iter: r.u64()?,
    })
}

/// FNV-1a 64 — tiny, dependency-free, and plenty to catch torn writes
/// and bit rot (this is an integrity check, not an authenticity one).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1_0000_0000_01b3);
    }
    h
}

/// Serialize a checkpoint to its on-disk byte image (header + body +
/// checksum).
pub fn encode_checkpoint(ckpt: &TrainCheckpoint) -> Vec<u8> {
    let mut body = Vec::new();
    put_u64(&mut body, ckpt.iters_done);
    put_tensors(&mut body, &ckpt.master);
    put_u64(&mut body, ckpt.shards.len() as u64);
    for s in &ckpt.shards {
        put_trainer_state(&mut body, s);
    }
    let mut out = Vec::with_capacity(body.len() + 24);
    out.extend_from_slice(MAGIC);
    put_u32(&mut out, VERSION);
    put_u64(&mut out, body.len() as u64);
    out.extend_from_slice(&body);
    put_u64(&mut out, fnv1a(&body));
    out
}

/// Parse an on-disk byte image. Every defect — wrong magic, truncation
/// anywhere, checksum mismatch, corrupt structure — is a descriptive
/// error.
pub fn decode_checkpoint(bytes: &[u8]) -> Result<TrainCheckpoint> {
    ensure!(bytes.len() >= 16, "file too short to be a checkpoint \
                                ({} bytes)", bytes.len());
    ensure!(&bytes[..4] == MAGIC,
            "not a checkpoint file (bad magic; expected \"XMGC\")");
    let version = u32::from_le_bytes(bytes[4..8].try_into().unwrap());
    ensure!(version == VERSION,
            "checkpoint version {version} unsupported (expected \
             {VERSION})");
    let len64 = u64::from_le_bytes(bytes[8..16].try_into().unwrap());
    let have = bytes.len().saturating_sub(24);
    ensure!(
        len64 <= have as u64,
        "torn checkpoint: header promises a {len64}-byte body but only \
         {have} bytes follow (interrupted write?)"
    );
    let len = len64 as usize;
    let body = &bytes[16..16 + len];
    let stored =
        u64::from_le_bytes(bytes[16 + len..24 + len].try_into().unwrap());
    let actual = fnv1a(body);
    ensure!(stored == actual,
            "checkpoint checksum mismatch (stored {stored:#018x}, \
             computed {actual:#018x}) — the file is corrupt");
    let mut r = Reader { buf: body, pos: 0 };
    let iters_done = r.u64()?;
    let master = r.tensors()?;
    let nshards = r.count(1)?;
    let shards = (0..nshards)
        .map(|_| read_trainer_state(&mut r))
        .collect::<Result<Vec<_>>>()?;
    ensure!(r.pos == body.len(),
            "corrupt checkpoint: {} trailing bytes after the last \
             shard state", body.len() - r.pos);
    Ok(TrainCheckpoint { iters_done, master, shards })
}

/// Atomically write `ckpt` to `path`: stream to a `.tmp-<pid>` sibling,
/// then rename onto the final path, so a crash mid-write can never
/// destroy the previous checkpoint.
///
/// If `faults` schedules `torn-checkpoint@iter=<ckpt.iters_done>`, the
/// file is instead written *truncated at the final path* — simulating
/// exactly the torn write the atomic rename protects against — so tests
/// and CI can prove `--resume` detects the damage.
pub fn save_checkpoint(path: &Path, ckpt: &TrainCheckpoint,
                       faults: &FaultPlan) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)
                .with_context(|| format!("creating {dir:?}"))?;
        }
    }
    let bytes = encode_checkpoint(ckpt);
    if faults.torn_checkpoint(ckpt.iters_done) {
        let cut = bytes.len() / 2;
        std::fs::write(path, &bytes[..cut])
            .with_context(|| format!("writing torn checkpoint {path:?}"))?;
        eprintln!(
            "xmgrid: injected torn checkpoint at iteration {} \
             ({} of {} bytes)",
            ckpt.iters_done, cut, bytes.len()
        );
        return Ok(());
    }
    let mut tmp = path.to_path_buf();
    let mut name = tmp
        .file_name()
        .map(|n| n.to_string_lossy().into_owned())
        .unwrap_or_else(|| "checkpoint".into());
    name.push_str(&format!(".tmp-{}", std::process::id()));
    tmp.set_file_name(name);
    std::fs::write(&tmp, &bytes)
        .with_context(|| format!("writing {tmp:?}"))?;
    std::fs::rename(&tmp, path).with_context(|| {
        format!("renaming {tmp:?} into place at {path:?}")
    })?;
    Ok(())
}

/// Load and validate a checkpoint file.
pub fn load_checkpoint(path: &Path) -> Result<TrainCheckpoint> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading checkpoint {path:?}"))?;
    decode_checkpoint(&bytes)
        .with_context(|| format!("loading checkpoint {path:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn sample() -> TrainCheckpoint {
        let ts = TrainerState {
            params: vec![Tensor::F32(vec![0.5, -1.25]),
                         Tensor::F32(vec![3.0])],
            m: vec![Tensor::F32(vec![0.0, 0.0]), Tensor::F32(vec![0.0])],
            v: vec![Tensor::F32(vec![1.0, 2.0]), Tensor::F32(vec![4.0])],
            t: Tensor::I32(vec![7]),
            env_state: vec![Tensor::I32(vec![1, 2, 3]),
                            Tensor::U32(vec![9, 8])],
            last_obs: Tensor::I32(vec![5; 8]),
            obs: Tensor::I32(vec![5; 8]),
            prev_a: Tensor::I32(vec![0, 1]),
            prev_r: Tensor::F32(vec![0.25, 0.0]),
            done_prev: Tensor::I32(vec![1, 0]),
            h: Tensor::F32(vec![0.125; 4]),
            rng: [1, 2, 3, 4],
            task_rng: Some([5, 6, 7, 8]),
            iter: 12,
        };
        let mut other = ts.clone();
        other.task_rng = None;
        other.rng = [9, 9, 9, 9];
        TrainCheckpoint {
            iters_done: 12,
            master: vec![Tensor::F32(vec![0.5, -1.25]),
                         Tensor::F32(vec![3.0])],
            shards: vec![ts, other],
        }
    }

    fn tmp_path(tag: &str) -> PathBuf {
        std::env::temp_dir().join(format!(
            "xmgrid_ckpt_test_{}_{tag}.bin",
            std::process::id()
        ))
    }

    #[test]
    fn env_snapshot_codec_round_trips() {
        let snap = VecEnvSnapshot {
            base: vec![Cell { tile: 1, color: 2 }; 6],
            grid: vec![Cell { tile: 3, color: 0 }; 6],
            agent_pos: vec![1, 2, 3, 4],
            agent_dir: vec![0, 3],
            pocket: vec![Cell { tile: 0, color: 0 },
                         Cell { tile: 5, color: 7 }],
            rules: vec![Rule([1, 2, 3, 4, 5, 6, 7]); 4],
            goals: vec![Goal([9, 8, 7, 6, 5]); 2],
            init: vec![Cell { tile: 2, color: 2 }; 4],
            init_len: vec![1, 2],
            step_count: vec![10, 20],
            max_steps: vec![243, 243],
            rng_states: vec![[u64::MAX, 1, 2, 3], [4, 5, 6, 7]],
        };
        let ts = encode_env_snapshot(&snap);
        assert_eq!(ts.len(), 12);
        assert_eq!(decode_env_snapshot(&ts).unwrap(), snap);
        // wrong tensor count is a clean error
        assert!(decode_env_snapshot(&ts[..11]).is_err());
        // dtype mismatch is a clean error
        let mut bad = ts.clone();
        bad[8] = Tensor::I32(vec![1, 2]);
        assert!(decode_env_snapshot(&bad).is_err());
    }

    #[test]
    fn round_trips_bitwise() {
        let ckpt = sample();
        let bytes = encode_checkpoint(&ckpt);
        let back = decode_checkpoint(&bytes).unwrap();
        assert_eq!(ckpt, back);
    }

    #[test]
    fn save_load_via_disk_and_no_tmp_left() {
        let path = tmp_path("disk");
        let ckpt = sample();
        save_checkpoint(&path, &ckpt, &FaultPlan::none()).unwrap();
        assert_eq!(load_checkpoint(&path).unwrap(), ckpt);
        let dir = path.parent().unwrap();
        let leftovers = std::fs::read_dir(dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| {
                e.file_name().to_string_lossy().contains(
                    &format!("xmgrid_ckpt_test_{}_disk",
                             std::process::id()),
                ) && e.file_name().to_string_lossy().contains(".tmp-")
            })
            .count();
        assert_eq!(leftovers, 0, "tmp file leaked past the rename");
        std::fs::remove_file(&path).unwrap();
    }

    /// Truncation at *every* prefix length must be a clean error — no
    /// panic, no bogus success.
    #[test]
    fn any_truncation_is_detected() {
        let bytes = encode_checkpoint(&sample());
        for cut in 0..bytes.len() {
            match decode_checkpoint(&bytes[..cut]) {
                Err(_) => {}
                Ok(_) => panic!("truncation to {cut} bytes decoded"),
            }
        }
    }

    #[test]
    fn corruption_is_detected() {
        let mut bytes = encode_checkpoint(&sample());
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x40;
        let err = decode_checkpoint(&bytes).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("checksum") || msg.contains("corrupt"),
                "{msg}");
    }

    #[test]
    fn bad_magic_is_rejected() {
        let mut bytes = encode_checkpoint(&sample());
        bytes[0] = b'Z';
        let msg =
            format!("{:#}", decode_checkpoint(&bytes).unwrap_err());
        assert!(msg.contains("magic"), "{msg}");
    }

    /// The torn-checkpoint fault writes a half file at the final path,
    /// and loading it reports a torn/truncated checkpoint.
    #[test]
    fn torn_fault_produces_detectable_damage() {
        let path = tmp_path("torn");
        let ckpt = sample();
        let faults = FaultPlan::parse("torn-checkpoint@iter=12").unwrap();
        save_checkpoint(&path, &ckpt, &faults).unwrap();
        let msg = format!("{:#}", load_checkpoint(&path).unwrap_err());
        assert!(msg.contains("torn") || msg.contains("truncated"),
                "{msg}");
        // the fault budget is consumed: the next save is clean
        save_checkpoint(&path, &ckpt, &faults).unwrap();
        assert_eq!(load_checkpoint(&path).unwrap(), ckpt);
        std::fs::remove_file(&path).unwrap();
    }
}
