//! Vectorized environment pool: owns the batched state tensors for one
//! artifact family (H, W, MR, MI, B) and drives reset / random-policy
//! rollout executables.

use anyhow::{Context, Result};
use std::sync::Arc;

use crate::benchgen::Benchmark;
use crate::env::grid::Grid;
use crate::env::layouts::xland_layout;
use crate::env::state::{default_max_steps, Ruleset};
use crate::runtime::state::{reset_inputs, NUM_STATE_FIELDS};
use crate::runtime::{Artifact, Runtime, Tensor};
use crate::util::rng::Rng;

/// Shape family of compiled env artifacts.
#[derive(Clone, Copy, Debug)]
pub struct EnvFamily {
    pub h: usize,
    pub w: usize,
    pub mr: usize,
    pub mi: usize,
    pub b: usize,
}

impl EnvFamily {
    pub fn reset_name(&self) -> String {
        format!("env_reset_g{}x{}_r{}_b{}", self.h, self.w, self.mr, self.b)
    }

    pub fn rollout_name(&self, t: usize) -> String {
        format!("env_rollout_g{}x{}_r{}_b{}_t{t}", self.h, self.w, self.mr,
                self.b)
    }

    pub fn step_name(&self) -> String {
        format!("env_step_g{}x{}_r{}_b{}", self.h, self.w, self.mr, self.b)
    }

    /// Read the family from an artifact's metadata.
    pub fn from_spec(spec: &crate::runtime::ArtifactSpec) -> Result<Self> {
        Ok(EnvFamily {
            h: spec.meta_usize("H")?,
            w: spec.meta_usize("W")?,
            mr: spec.meta_usize("MR")?,
            mi: spec.meta_usize("MI")?,
            b: spec.meta_usize("B")?,
        })
    }
}

/// Batched environment pool driving AOT executables.
pub struct EnvPool {
    pub family: EnvFamily,
    reset_art: Arc<Artifact>,
    /// 11 state tensors (aot.STATE_FIELDS order)
    pub state: Vec<Tensor>,
    /// observation from the latest reset/step
    pub last_obs: Tensor,
    /// number of rooms for base-grid construction (XLand layouts)
    pub rooms: usize,
}

impl EnvPool {
    pub fn new(rt: &Runtime, family: EnvFamily, rooms: usize)
               -> Result<EnvPool> {
        let reset_art = rt.load(&family.reset_name())?;
        Ok(EnvPool {
            family,
            reset_art,
            state: Vec::new(),
            last_obs: Tensor::I32(vec![]),
            rooms,
        })
    }

    /// Sample one ruleset per env slot from the benchmark.
    pub fn sample_rulesets<'b>(&self, bench: &'b Benchmark, rng: &mut Rng)
                               -> Vec<&'b Ruleset> {
        (0..self.family.b).map(|_| bench.sample_ruleset(rng)).collect()
    }

    /// Reset every env with the given rulesets (fresh base grids with
    /// re-randomized doors — L3 owns door randomization; docs/ARCHITECTURE.md, "Deviations").
    pub fn reset(&mut self, rulesets: &[&Ruleset], rng: &mut Rng)
                 -> Result<()> {
        let f = self.family;
        let grids: Vec<Grid> = (0..f.b)
            .map(|_| xland_layout(self.rooms, f.h, f.w, rng))
            .collect();
        let max_steps = vec![default_max_steps(f.h, f.w); f.b];
        let seeds: Vec<[u32; 2]> =
            (0..f.b).map(|_| [rng.next_u32(), rng.next_u32()]).collect();
        let inputs = reset_inputs(&grids, rulesets, &max_steps, &seeds,
                                  f.mr, f.mi)?;
        let mut out = self.reset_art.execute(&inputs)?;
        self.last_obs = out
            .pop()
            .context("reset artifact returned no outputs")?;
        out.truncate(NUM_STATE_FIELDS);
        self.state = out;
        Ok(())
    }

    /// Run one fused random-policy rollout of `t` steps; returns
    /// (reward_sum, episodes_done, trials_done) aggregated over the batch.
    pub fn rollout(&mut self, rt: &Runtime, t: usize, rng: &mut Rng)
                   -> Result<(f64, u64, u64)> {
        let art = rt.load(&self.family.rollout_name(t))?;
        let mut inputs = self.state.clone();
        inputs.push(Tensor::U32(vec![rng.next_u32(), rng.next_u32()]));
        let mut out = art.execute(&inputs)?;
        // Buffer handoff: the returned state tensors replace ours by
        // move, not copy — at B=1024 the state block is megabytes and
        // this runs once per chunk on the engine's hot path.
        let rest = out.split_off(NUM_STATE_FIELDS);
        self.state = out;
        let reward_sum: f64 =
            rest[0].as_f32().iter().map(|&x| x as f64).sum();
        let episodes: u64 =
            rest[1].as_i32().iter().map(|&x| x as u64).sum();
        let trials: u64 = rest[2].as_i32().iter().map(|&x| x as u64).sum();
        Ok((reward_sum, episodes, trials))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_names() {
        let f = EnvFamily { h: 9, w: 9, mr: 3, mi: 6, b: 8 };
        assert_eq!(f.reset_name(), "env_reset_g9x9_r3_b8");
        assert_eq!(f.rollout_name(8), "env_rollout_g9x9_r3_b8_t8");
        assert_eq!(f.step_name(), "env_step_g9x9_r3_b8");
    }
}
