//! Vectorized environment pool: owns the batched state tensors for one
//! artifact family (H, W, MR, MI, B) and drives reset / random-policy
//! rollout executables.
//!
//! With a [`TaskSource`] installed ([`EnvPool::set_task_source`]), the
//! pool closes the xla side of the §2.1 task-resampling protocol: the
//! compiled kernels carry the ruleset tables as device state and replay
//! them at every episode auto-reset, so the pool performs a *full
//! host-side episode restart* for done envs — fresh task drawn from the
//! source, new ruleset rows re-encoded, objects re-placed on the base
//! grid and the cached observation refreshed, so the new episode's
//! goal/rules and its placed objects always belong to the same task.
//! This runs exactly per step on the `env_step` path, and between fused
//! chunks on the `env_rollout` path (episode boundaries *inside* a
//! chunk keep the previous task until the chunk ends, where the current
//! episode is restarted under the fresh task; chunk-boundary
//! granularity is the host-side limit of the AOT design and is
//! documented in ARCHITECTURE.md).

use anyhow::{ensure, Context, Result};
use std::sync::Arc;

use crate::benchgen::Benchmark;
use crate::env::api::{ActionSpec, BatchEnvironment, ObsSpec};
use crate::env::grid::Grid;
use crate::env::layouts::xland_layout;
use crate::env::observation::observe;
use crate::env::state::{default_max_steps, place_objects, EnvOptions,
                        Ruleset, TaskSource};
use crate::env::types::{GOAL_ENC, POCKET_EMPTY, RULE_ENC};
use crate::runtime::state::{encode_ruleset, reset_inputs,
                            NUM_STATE_FIELDS};
use crate::runtime::{Artifact, Runtime, Tensor};
use crate::util::rng::Rng;

/// Shape family of compiled env artifacts.
#[derive(Clone, Copy, Debug)]
pub struct EnvFamily {
    pub h: usize,
    pub w: usize,
    pub mr: usize,
    pub mi: usize,
    pub b: usize,
}

impl EnvFamily {
    pub fn reset_name(&self) -> String {
        format!("env_reset_g{}x{}_r{}_b{}", self.h, self.w, self.mr, self.b)
    }

    pub fn rollout_name(&self, t: usize) -> String {
        format!("env_rollout_g{}x{}_r{}_b{}_t{t}", self.h, self.w, self.mr,
                self.b)
    }

    pub fn step_name(&self) -> String {
        format!("env_step_g{}x{}_r{}_b{}", self.h, self.w, self.mr, self.b)
    }

    /// Read the family from an artifact's metadata.
    pub fn from_spec(spec: &crate::runtime::ArtifactSpec) -> Result<Self> {
        Ok(EnvFamily {
            h: spec.meta_usize("H")?,
            w: spec.meta_usize("W")?,
            mr: spec.meta_usize("MR")?,
            mi: spec.meta_usize("MI")?,
            b: spec.meta_usize("B")?,
        })
    }
}

/// Index of each host-rewritten field in the 11 state tensors
/// (aot.STATE_FIELDS order).
const STATE_BASE: usize = 0;
const STATE_GRID: usize = 1;
const STATE_POS: usize = 2;
const STATE_DIR: usize = 3;
const STATE_POCKET: usize = 4;
const STATE_RULES: usize = 5;
const STATE_GOAL: usize = 6;
const STATE_INIT: usize = 7;
const STATE_STEP: usize = 8;

/// Batched environment pool driving AOT executables.
pub struct EnvPool {
    pub family: EnvFamily,
    reset_art: Arc<Artifact>,
    /// single-step executable, loaded on demand
    /// ([`EnvPool::load_step_artifact`]) for the per-step trait path
    step_art: Option<Arc<Artifact>>,
    /// 11 state tensors (aot.STATE_FIELDS order)
    pub state: Vec<Tensor>,
    /// observation from the latest reset/step
    pub last_obs: Tensor,
    /// number of rooms for base-grid construction (XLand layouts)
    pub rooms: usize,
    /// §2.1 task distribution + its private draw stream (host-side
    /// re-encode of done envs' rows; see module docs)
    tasks: Option<(Arc<dyn TaskSource>, Rng)>,
}

impl EnvPool {
    pub fn new(rt: &Runtime, family: EnvFamily, rooms: usize)
               -> Result<EnvPool> {
        let reset_art = rt.load(&family.reset_name())?;
        Ok(EnvPool {
            family,
            reset_art,
            step_art: None,
            state: Vec::new(),
            last_obs: Tensor::I32(vec![]),
            rooms,
            tasks: None,
        })
    }

    /// Install the episode-reset task distribution. `rng` is the
    /// private stream task draws come from (one `below(num_tasks)` per
    /// done env, ascending env order — deterministic and independent of
    /// the rollout action stream). Every task is validated against the
    /// artifact's MR/MI capacities here, so an oversized task fails at
    /// launch instead of mid-run at its first draw.
    pub fn set_task_source(&mut self, tasks: Arc<dyn TaskSource>,
                           rng: Rng) {
        let f = self.family;
        crate::env::api::EnvParams::new(f.h, f.w, f.mr, f.mi)
            .validate_task_source(tasks.as_ref());
        self.tasks = Some((tasks, rng));
    }

    /// Capture the task-draw stream's state for checkpointing (`None`
    /// when no task source is installed). The source itself is not
    /// serialized — a resumed run re-installs the same benchmark and
    /// only the stream position needs restoring.
    pub fn task_rng_state(&self) -> Option<[u64; 4]> {
        self.tasks.as_ref().map(|(_, r)| r.state())
    }

    /// Restore a task-draw stream captured by
    /// [`EnvPool::task_rng_state`]. Requires a task source to already be
    /// installed (checkpoints store the stream, not the distribution).
    pub fn restore_task_rng(&mut self, s: [u64; 4]) -> Result<()> {
        match self.tasks.as_mut() {
            Some((_, r)) => {
                *r = Rng::from_state(s);
                Ok(())
            }
            None => anyhow::bail!(
                "restoring a task-draw stream, but no task source is \
                 installed — install the benchmark first"
            ),
        }
    }

    /// Load the family's `env_step` artifact so the pool can serve the
    /// per-step [`BatchEnvironment::step`] path.
    pub fn load_step_artifact(&mut self, rt: &Runtime) -> Result<()> {
        self.step_art = Some(rt.load(&self.family.step_name())?);
        Ok(())
    }

    /// Sample one ruleset per env slot from the benchmark.
    pub fn sample_rulesets<'b>(&self, bench: &'b Benchmark, rng: &mut Rng)
                               -> Vec<&'b Ruleset> {
        (0..self.family.b).map(|_| bench.sample_ruleset(rng)).collect()
    }

    /// Reset every env with the given rulesets (fresh base grids with
    /// re-randomized doors — L3 owns door randomization; docs/ARCHITECTURE.md, "Deviations").
    pub fn reset(&mut self, rulesets: &[&Ruleset], rng: &mut Rng)
                 -> Result<()> {
        let f = self.family;
        let grids: Vec<Grid> = (0..f.b)
            .map(|_| xland_layout(self.rooms, f.h, f.w, rng))
            .collect();
        let max_steps = vec![default_max_steps(f.h, f.w); f.b];
        let seeds: Vec<[u32; 2]> =
            (0..f.b).map(|_| [rng.next_u32(), rng.next_u32()]).collect();
        let inputs = reset_inputs(&grids, rulesets, &max_steps, &seeds,
                                  f.mr, f.mi)?;
        let mut out = self.reset_art.execute(&inputs)?;
        self.last_obs = out
            .pop()
            .context("reset artifact returned no outputs")?;
        out.truncate(NUM_STATE_FIELDS);
        self.state = out;
        Ok(())
    }

    /// Host-side episode restart for env `i` under `task`: re-encode
    /// the ruleset rows, restore the base grid and place the new task's
    /// objects + agent with the resample stream (the same
    /// `place_objects` the oracle reset runs), clear pocket and step
    /// count, and refresh the env's cached observation row — so the new
    /// episode's goal/rules and its placed objects belong to one task.
    fn restart_env_host(&mut self, i: usize, task: &Ruleset,
                        rng: &mut Rng) -> Result<()> {
        let f = self.family;
        let (rules, goal, init) = encode_ruleset(task, f.mr, f.mi)?;
        let rw = rules.len();
        self.state[STATE_RULES].as_i32_mut()[i * rw..(i + 1) * rw]
            .copy_from_slice(&rules);
        let gw = goal.len();
        self.state[STATE_GOAL].as_i32_mut()[i * gw..(i + 1) * gw]
            .copy_from_slice(&goal);
        let iw = init.len();
        self.state[STATE_INIT].as_i32_mut()[i * iw..(i + 1) * iw]
            .copy_from_slice(&init);

        let ghw = f.h * f.w * 2;
        let base = Grid::from_flat(
            f.h, f.w,
            &self.state[STATE_BASE].as_i32()[i * ghw..(i + 1) * ghw]);
        let (grid, pos, dir) = place_objects(rng, &base,
                                             &task.init_tiles);
        self.state[STATE_GRID].as_i32_mut()[i * ghw..(i + 1) * ghw]
            .copy_from_slice(&grid.to_flat());
        self.state[STATE_POS].as_i32_mut()[i * 2] = pos.0;
        self.state[STATE_POS].as_i32_mut()[i * 2 + 1] = pos.1;
        self.state[STATE_DIR].as_i32_mut()[i] = dir;
        self.state[STATE_POCKET].as_i32_mut()[i * 2] =
            POCKET_EMPTY.tile;
        self.state[STATE_POCKET].as_i32_mut()[i * 2 + 1] =
            POCKET_EMPTY.color;
        self.state[STATE_STEP].as_i32_mut()[i] = 0;

        let opts = EnvOptions::default();
        let obs = observe(&grid, pos, dir, opts.view_size,
                          opts.see_through_walls);
        let v2 = opts.view_size * opts.view_size * 2;
        obs.write_flat_into(
            &mut self.last_obs.as_i32_mut()[i * v2..(i + 1) * v2]);
        Ok(())
    }

    /// Restart every env whose done flag is set (the compiled
    /// auto-reset replayed its device-resident table) under a fresh
    /// task. Draws come from the installed task-source stream in
    /// ascending env order; without a source this is a no-op.
    fn resample_done_tasks<I>(&mut self, done: I) -> Result<()>
    where
        I: IntoIterator<Item = bool>,
    {
        let Some((tasks, mut rng)) = self.tasks.take() else {
            return Ok(());
        };
        let f = self.family;
        let flags: Vec<bool> = done.into_iter().collect();
        let mut err = None;
        if flags.len() != f.b {
            err = Some(anyhow::anyhow!(
                "done flags have {} entries, batch is {}",
                flags.len(), f.b));
        } else {
            let n = tasks.num_tasks();
            for (i, &d) in flags.iter().enumerate() {
                if !d {
                    continue;
                }
                let t = rng.below(n);
                if let Err(e) =
                    self.restart_env_host(i, tasks.task(t), &mut rng)
                {
                    err = Some(e);
                    break;
                }
            }
        }
        self.tasks = Some((tasks, rng));
        match err {
            Some(e) => Err(e),
            None => Ok(()),
        }
    }

    /// Run one fused random-policy rollout of `t` steps; returns
    /// (reward_sum, episodes_done, trials_done) aggregated over the batch.
    pub fn rollout(&mut self, rt: &Runtime, t: usize, rng: &mut Rng)
                   -> Result<(f64, u64, u64)> {
        let art = rt.load(&self.family.rollout_name(t))?;
        let mut inputs = self.state.clone();
        inputs.push(Tensor::U32(vec![rng.next_u32(), rng.next_u32()]));
        let mut out = art.execute(&inputs)?;
        // Buffer handoff: the returned state tensors replace ours by
        // move, not copy — at B=1024 the state block is megabytes and
        // this runs once per chunk on the engine's hot path.
        let rest = out.split_off(NUM_STATE_FIELDS);
        self.state = out;
        let reward_sum: f64 =
            rest[0].as_f32().iter().map(|&x| x as f64).sum();
        let episodes: u64 =
            rest[1].as_i32().iter().map(|&x| x as u64).sum();
        let trials: u64 = rest[2].as_i32().iter().map(|&x| x as u64).sum();
        // §2.1 task resampling, host-side: envs that crossed an episode
        // boundary inside the chunk get fresh ruleset rows before the
        // next chunk runs (chunk-boundary granularity; module docs).
        let done: Vec<bool> =
            rest[1].as_i32().iter().map(|&c| c > 0).collect();
        self.resample_done_tasks(done)?;
        Ok((reward_sum, episodes, trials))
    }
}

/// The AOT/PJRT pool under the unified batch API: `reset` samples tasks
/// from the installed source and drives the `env_reset` executable;
/// `step` drives `env_step` ([`EnvPool::load_step_artifact`] first) and
/// re-encodes fresh tasks into done envs *exactly* at their episode
/// boundary — on this path the adapter has per-step done flags, so the
/// protocol granularity matches the native engines.
impl BatchEnvironment for EnvPool {
    fn batch(&self) -> usize {
        self.family.b
    }

    fn obs_spec(&self) -> ObsSpec {
        // artifacts are lowered at the default view size (aot.VIEW_SIZE)
        ObsSpec::symbolic(EnvOptions::default().view_size)
    }

    fn action_spec(&self) -> ActionSpec {
        ActionSpec::default()
    }

    fn max_rules(&self) -> usize {
        self.family.mr
    }

    fn reset(&mut self, rng: &mut Rng, obs_out: &mut [i32]) -> Result<()> {
        let tasks = self
            .tasks
            .as_ref()
            .map(|(t, _)| t.clone())
            .context("EnvPool: no task source installed; call \
                      set_task_source first")?;
        let n = tasks.num_tasks();
        let rulesets: Vec<&Ruleset> = (0..self.family.b)
            .map(|_| tasks.task(rng.below(n)))
            .collect();
        EnvPool::reset(self, &rulesets, rng)?;
        ensure!(obs_out.len() == self.last_obs.len(), "obs buffer size");
        obs_out.copy_from_slice(self.last_obs.as_i32());
        Ok(())
    }

    fn step(&mut self, actions: &[i32], obs_out: &mut [i32],
            rewards: &mut [f32], dones: &mut [bool],
            trial_dones: &mut [bool]) -> Result<()> {
        let art = self
            .step_art
            .clone()
            .context("EnvPool: env_step artifact not loaded; call \
                      load_step_artifact first")?;
        let b = self.family.b;
        ensure!(actions.len() == b, "need one action per env");
        ensure!(obs_out.len() == self.obs_len(), "obs buffer size");
        ensure!(rewards.len() == b && dones.len() == b
                    && trial_dones.len() == b,
                "per-env output buffers must have batch length");
        ensure!(!self.state.is_empty(), "EnvPool: reset before stepping");
        // move the state block into the input list instead of cloning
        // it (megabytes at B=1024; same discipline as `rollout`). On an
        // execute error the pool is left un-reset and the next step
        // fails fast on the emptiness check above.
        let mut inputs = std::mem::take(&mut self.state);
        inputs.push(Tensor::I32(actions.to_vec()));
        let mut out = art.execute(&inputs)?;
        // outputs: 11 state fields + obs + reward + done + trial_done
        ensure!(out.len() >= NUM_STATE_FIELDS + 4,
                "env_step returned {} outputs", out.len());
        let rest = out.split_off(NUM_STATE_FIELDS);
        self.state = out;
        obs_out.copy_from_slice(rest[0].as_i32());
        rewards.copy_from_slice(rest[1].as_f32());
        for (d, &x) in dones.iter_mut().zip(rest[2].as_i32()) {
            *d = x != 0;
        }
        for (d, &x) in trial_dones.iter_mut().zip(rest[3].as_i32()) {
            *d = x != 0;
        }
        self.last_obs = rest.into_iter().next().expect("obs output");
        // per-step path: exact episode-boundary task resampling — done
        // envs restart host-side under a fresh task, and the caller's
        // obs rows are refreshed to the restarted episodes' views
        let done: Vec<bool> = dones.to_vec();
        self.resample_done_tasks(done)?;
        if self.tasks.is_some() {
            let v2 = self.obs_len() / b;
            let obs = self.last_obs.as_i32();
            for (i, &d) in dones.iter().enumerate() {
                if d {
                    obs_out[i * v2..(i + 1) * v2]
                        .copy_from_slice(&obs[i * v2..(i + 1) * v2]);
                }
            }
        }
        Ok(())
    }

    fn agent_dirs_into(&self, out: &mut [i32]) {
        out.copy_from_slice(self.state[STATE_DIR].as_i32());
    }

    fn task_rows_into(&self, out: &mut [i32]) {
        let f = self.family;
        let rw = f.mr * RULE_ENC;
        let row = GOAL_ENC + rw;
        let goals = self.state[STATE_GOAL].as_i32();
        let rules = self.state[STATE_RULES].as_i32();
        for i in 0..f.b {
            let dst = &mut out[i * row..(i + 1) * row];
            dst[..GOAL_ENC].copy_from_slice(
                &goals[i * GOAL_ENC..(i + 1) * GOAL_ENC]);
            dst[GOAL_ENC..].copy_from_slice(
                &rules[i * rw..(i + 1) * rw]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn family_names() {
        let f = EnvFamily { h: 9, w: 9, mr: 3, mi: 6, b: 8 };
        assert_eq!(f.reset_name(), "env_reset_g9x9_r3_b8");
        assert_eq!(f.rollout_name(8), "env_rollout_g9x9_r3_b8_t8");
        assert_eq!(f.step_name(), "env_step_g9x9_r3_b8");
    }
}
