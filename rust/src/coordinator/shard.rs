//! Shard engine — the `jax.pmap` stand-in (see `docs/ARCHITECTURE.md`,
//! "Shard engine" section).
//!
//! Each shard is a *persistent* host thread owning its own PJRT client,
//! compiled executables and env-state buffers (exactly a pmap replica's
//! footprint). Because the `xla` crate's handles are not `Send`, all shard
//! state is constructed inside the shard's thread by an init closure and
//! never leaves it; the main thread talks to shards exclusively over
//! channels of `FnOnce` jobs.
//!
//! Two layers build on [`ShardPool`]:
//!
//! - [`crate::coordinator::rollout::RolloutEngine`] — double-buffered
//!   random-policy collection (Fig. 5d/e scaling axis).
//! - [`crate::coordinator::trainer::ShardedTrainer`] — data-parallel RL²
//!   PPO with fixed-order parameter averaging (the pmap all-reduce).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use anyhow::{anyhow, Context, Result};

use crate::runtime::Tensor;

/// A unit of work shipped to one shard thread. The worker state `W` stays
/// on its thread; only the closure (and its captures) cross.
type Job<W> = Box<dyn FnOnce(&mut W) + Send + 'static>;

/// Best-effort text of a panic payload (the `&str`/`String` forms cover
/// every `panic!`/`assert!` in this crate).
pub fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Structured record of one worker death: which worker, which job (index
/// in that worker's since-(re)spawn submission order), and the panic or
/// error message. Recorded by the dying worker thread itself and read by
/// the supervisor after joining the thread (join is the happens-before
/// edge), so the cause is never lost to a racing channel close.
#[derive(Debug, Clone)]
pub struct WorkerError {
    pub worker: usize,
    pub job: u64,
    pub message: String,
}

impl std::fmt::Display for WorkerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "worker {} panicked in job {}: {}", self.worker,
               self.job, self.message)
    }
}

impl std::error::Error for WorkerError {}

/// Per-shard slot for the last death cause.
type CauseSlots = Arc<Vec<Mutex<Option<WorkerError>>>>;

/// Pool of persistent shard worker threads, each owning a worker state `W`
/// built in-thread by the init closure (so `W` need not be `Send` — PJRT
/// clients and executables are not).
///
/// Jobs are executed strictly in submission order per shard, which is what
/// the double-buffered engines rely on for deterministic per-shard RNG
/// streams: a shard's trajectory depends only on its own job sequence,
/// never on cross-shard scheduling.
///
/// Failure model: every job body runs under `catch_unwind`. A panicking
/// job records a [`WorkerError`] in the shard's cause slot and retires
/// the thread (its state `W` may be poisoned mid-update, so it is never
/// reused); pending [`Ticket`]s and later submissions observe the closed
/// channel and return errors instead of aborting the process. A
/// supervisor holding `&mut` may then [`ShardPool::respawn`] the shard —
/// rebuilding `W` with the original init closure — and replay from its
/// own last synchronization point.
pub struct ShardPool<W> {
    txs: Vec<Sender<Job<W>>>,
    handles: Vec<Option<JoinHandle<()>>>,
    init: Arc<dyn Fn(usize) -> Result<W> + Send + Sync>,
    causes: CauseSlots,
}

impl<W: 'static> ShardPool<W> {
    /// Spawn `n` shard threads. `init(shard_index)` runs *inside* each
    /// thread to build its worker state; if any shard fails to initialise,
    /// the pool is torn down and the first error is returned.
    pub fn spawn<F>(n: usize, init: F) -> Result<ShardPool<W>>
    where
        F: Fn(usize) -> Result<W> + Send + Sync + 'static,
    {
        assert!(n > 0, "shard pool needs at least one shard");
        let init: Arc<dyn Fn(usize) -> Result<W> + Send + Sync> =
            Arc::new(init);
        let causes: CauseSlots =
            Arc::new((0..n).map(|_| Mutex::new(None)).collect());
        let mut txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        let mut readies = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, handle, ready) =
                spawn_worker(i, init.clone(), causes.clone())?;
            txs.push(tx);
            handles.push(Some(handle));
            readies.push(ready);
        }
        let pool = ShardPool { txs, handles, init, causes };
        // Inits run concurrently (one PJRT client each); collect their
        // verdicts afterwards. A worker that panics inside init drops
        // its ready sender without sending, so recv() errors instead of
        // hanging.
        for (i, ready) in readies.into_iter().enumerate() {
            ready
                .recv()
                .map_err(|_| anyhow!("shard {i} died during init"))?
                .with_context(|| format!("initialising shard {i}"))?;
        }
        Ok(pool)
    }

    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// Enqueue `f` on one shard without waiting for a result. Errors if
    /// the shard thread has died (a previous job panicked) — see
    /// [`ShardPool::respawn`] for recovery.
    pub fn submit<F>(&self, shard: usize, f: F) -> Result<()>
    where
        F: FnOnce(&mut W) + Send + 'static,
    {
        self.txs[shard].send(Box::new(f)).map_err(|_| {
            anyhow!("shard {shard} worker is dead (a prior job panicked)")
        })
    }

    /// Enqueue `f` on one shard and return a [`Ticket`] for its result.
    pub fn call<R, F>(&self, shard: usize, f: F) -> Result<Ticket<R>>
    where
        R: Send + 'static,
        F: FnOnce(&mut W) -> R + Send + 'static,
    {
        let (tx, rx) = channel();
        self.submit(shard, move |w| {
            let _ = tx.send(f(w));
        })?;
        Ok(Ticket { rx, shard })
    }

    /// Lockstep collective: run `f(shard_index, worker)` on every shard
    /// concurrently, wait for all, and return results in shard order.
    /// All shards are dispatched before any is awaited; on worker death
    /// the surviving shards still finish their jobs, and the first
    /// error is returned.
    pub fn broadcast<R, F>(&self, f: F) -> Result<Vec<R>>
    where
        R: Send + 'static,
        F: Fn(usize, &mut W) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let tickets: Vec<Result<Ticket<R>>> = (0..self.shards())
            .map(|i| {
                let f = f.clone();
                self.call(i, move |w| f(i, w))
            })
            .collect();
        let mut out = Vec::with_capacity(self.shards());
        let mut first_err = None;
        for t in tickets {
            match t.and_then(|t| t.wait()) {
                Ok(r) => out.push(r),
                Err(e) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
            }
        }
        match first_err {
            None => Ok(out),
            Some(e) => Err(e),
        }
    }

    /// Take the recorded death cause for `shard`, if any. Joins the dead
    /// handle first so the read is ordered after the dying thread's
    /// write. Consuming reads: each cause is surfaced at most once.
    pub fn take_cause(&mut self, shard: usize) -> Option<WorkerError> {
        if let Some(h) = self.handles[shard].take() {
            if h.is_finished() {
                let _ = h.join();
            } else {
                // still alive — put it back untouched
                self.handles[shard] = Some(h);
                return None;
            }
        }
        self.causes[shard].lock().ok()?.take()
    }

    /// Replace a dead shard worker with a fresh one built by the
    /// original init closure, and return the recorded cause of death.
    /// The supervisor that calls this owns replay: the new worker's `W`
    /// is a *fresh init-state*, not the dead worker's state — callers
    /// must re-establish it deterministically (snapshot restore + replay
    /// of logged inputs) before resuming.
    pub fn respawn(&mut self, shard: usize) -> Result<WorkerError> {
        let (tx, handle, ready) =
            spawn_worker(shard, self.init.clone(), self.causes.clone())?;
        // Swap the job channel first: dropping the old sender closes the
        // old worker's queue (so even a still-alive worker exits its
        // loop), making the following join deadlock-free. The join is
        // the happens-before edge that makes the dying thread's
        // cause-slot write visible — and guarantees the old worker can
        // no longer race the new one on the slot.
        drop(std::mem::replace(&mut self.txs[shard], tx));
        if let Some(h) = self.handles[shard].replace(handle) {
            let _ = h.join();
        }
        let cause = self.causes[shard]
            .lock()
            .map(|mut g| g.take())
            .unwrap_or(None)
            .unwrap_or_else(|| WorkerError {
                worker: shard,
                job: 0,
                message: "worker exited without a recorded cause".into(),
            });
        ready
            .recv()
            .map_err(|_| anyhow!("shard {shard} died during respawn init"))?
            .with_context(|| format!("re-initialising shard {shard}"))?;
        Ok(cause)
    }
}

/// Spawn one worker thread: init in-thread (verdict over the returned
/// ready channel), then run jobs in order, each under `catch_unwind`. A
/// panicking job records its [`WorkerError`] in `causes[i]` and retires
/// the thread.
fn spawn_worker<W: 'static>(
    i: usize,
    init: Arc<dyn Fn(usize) -> Result<W> + Send + Sync>,
    causes: CauseSlots,
) -> Result<(Sender<Job<W>>, JoinHandle<()>, Receiver<Result<()>>)> {
    let (tx, rx) = channel::<Job<W>>();
    let (ready_tx, ready_rx) = channel::<Result<()>>();
    let handle = std::thread::Builder::new()
        .name(format!("xmgrid-shard-{i}"))
        .spawn(move || {
            let mut w = match catch_unwind(AssertUnwindSafe(|| init(i))) {
                Ok(Ok(w)) => {
                    let _ = ready_tx.send(Ok(()));
                    w
                }
                Ok(Err(e)) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
                Err(p) => {
                    let _ = ready_tx.send(Err(anyhow!(
                        "init panicked: {}",
                        panic_message(p.as_ref())
                    )));
                    return;
                }
            };
            drop(ready_tx);
            let mut job_idx: u64 = 0;
            while let Ok(job) = rx.recv() {
                if let Err(p) =
                    catch_unwind(AssertUnwindSafe(|| job(&mut w)))
                {
                    if let Ok(mut slot) = causes[i].lock() {
                        *slot = Some(WorkerError {
                            worker: i,
                            job: job_idx,
                            message: panic_message(p.as_ref()),
                        });
                    }
                    // W may be poisoned mid-update: retire the thread
                    // (and drop W) instead of running more jobs on it.
                    return;
                }
                job_idx += 1;
            }
        })
        .context("spawning shard thread")?;
    Ok((tx, handle, ready_rx))
}

impl<W> Drop for ShardPool<W> {
    fn drop(&mut self) {
        // Closing the job channels ends each worker loop; queued jobs
        // still run to completion before the thread exits. Dead shards'
        // channels drain silently — teardown must never turn one worker
        // panic into a second panic mid-unwind.
        self.txs.clear();
        for h in self.handles.drain(..) {
            if let Some(h) = h {
                let _ = h.join();
            }
        }
        // Surface the first unconsumed death cause exactly once (causes
        // already taken by a supervisor via respawn()/take_cause() were
        // reported there and stay silent here).
        let mut first: Option<WorkerError> = None;
        for slot in self.causes.iter() {
            if let Ok(mut g) = slot.lock() {
                if let Some(e) = g.take() {
                    first.get_or_insert(e);
                }
            }
        }
        if let Some(e) = first {
            eprintln!("xmgrid: shard {e}");
        }
    }
}

/// Receipt for an in-flight shard job.
pub struct Ticket<R> {
    rx: Receiver<R>,
    shard: usize,
}

impl<R> Ticket<R> {
    /// Block until the job completes. Errors if the shard thread died
    /// before sending (i.e. the job itself panicked) — the pool's
    /// [`ShardPool::respawn`]/[`ShardPool::take_cause`] then yields the
    /// authoritative [`WorkerError`].
    pub fn wait(self) -> Result<R> {
        let shard = self.shard;
        self.rx.recv().map_err(|_| {
            anyhow!(
                "shard {shard} worker died before returning a result \
                 (job panicked)"
            )
        })
    }
}

/// Run `f(shard_index)` on `n` scoped threads and collect the results in
/// shard order. The original fork-join primitive, superseded on the hot
/// paths by the persistent [`ShardPool`]; retained as the simple
/// borrow-friendly escape hatch (scoped threads may capture non-`'static`
/// state, which pool jobs cannot). A panicking thread surfaces as an
/// `Err` naming the shard — never a coordinator abort.
pub fn run_sharded<F, R>(n: usize, f: F) -> Result<Vec<R>>
where
    F: Fn(usize) -> R + Send + Sync,
    R: Send,
{
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let f = &f;
                scope.spawn(move || {
                    catch_unwind(AssertUnwindSafe(|| f(i)))
                })
            })
            .collect();
        handles
            .into_iter()
            .enumerate()
            .map(|(i, h)| match h.join() {
                Ok(Ok(r)) => Ok(r),
                Ok(Err(p)) => Err(anyhow!(
                    "shard {i} panicked: {}",
                    panic_message(p.as_ref())
                )),
                Err(p) => Err(anyhow!(
                    "shard {i} panicked: {}",
                    panic_message(p.as_ref())
                )),
            })
            .collect()
    })
}

/// Data-parallel parameter averaging across shard parameter sets (the
/// all-reduce a pmap training step performs). Arithmetic mean, in place on
/// the first set, returned.
///
/// The reduction order is *fixed*: shard 0's parameters are the
/// accumulator and shards 1..n are added in ascending index order. f32
/// addition is not associative, so this ordering is part of the engine's
/// determinism contract — overlap-off runs must be bitwise reproducible
/// regardless of which shard finished first (see the reduction-order
/// regression test in `tests/shard_engine.rs`).
pub fn average_params(shard_params: Vec<Vec<Vec<f32>>>)
                      -> Vec<Vec<f32>> {
    assert!(!shard_params.is_empty());
    let n = shard_params.len() as f32;
    let mut shards = shard_params.into_iter();
    // non-empty was just asserted, so the accumulator always exists
    let mut acc = shards.next().unwrap_or_default();
    for other in shards {
        for (a, o) in acc.iter_mut().zip(&other) {
            for (x, y) in a.iter_mut().zip(o) {
                *x += *y;
            }
        }
    }
    for a in acc.iter_mut() {
        for x in a.iter_mut() {
            *x /= n;
        }
    }
    acc
}

/// [`average_params`] lifted to the runtime's `Tensor` parameter lists
/// (all-f32), as held by the trainer.
pub fn average_param_tensors(shard_params: Vec<Vec<Tensor>>)
                             -> Vec<Tensor> {
    let raw: Vec<Vec<Vec<f32>>> = shard_params
        .into_iter()
        .map(|ps| {
            ps.into_iter()
                .map(|t| match t {
                    // move, don't copy: this runs on the per-iteration
                    // all-reduce hot path and the tensors are owned
                    Tensor::F32(v) => v,
                    _ => panic!("parameters must be f32 tensors"),
                })
                .collect()
        })
        .collect();
    average_params(raw).into_iter().map(Tensor::F32).collect()
}

/// Element-wise `after - before` over two parameter lists: the local
/// update one fused train iteration applied on a shard.
pub fn sub_params(after: &[Tensor], before: &[Tensor]) -> Vec<Tensor> {
    after
        .iter()
        .zip(before)
        .map(|(a, b)| {
            Tensor::F32(
                a.as_f32()
                    .iter()
                    .zip(b.as_f32())
                    .map(|(x, y)| x - y)
                    .collect(),
            )
        })
        .collect()
}

/// Add a (mean) delta into the master parameters in place.
pub fn add_params(master: &mut [Tensor], delta: &[Tensor]) {
    for (m, d) in master.iter_mut().zip(delta) {
        match m {
            Tensor::F32(mv) => {
                for (x, y) in mv.iter_mut().zip(d.as_f32()) {
                    *x += *y;
                }
            }
            _ => panic!("parameters must be f32 tensors"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_run_and_collect_in_order() {
        let out = run_sharded(4, |i| i * 10).unwrap();
        assert_eq!(out, vec![0, 10, 20, 30]);
    }

    #[test]
    fn run_sharded_propagates_panic_as_error() {
        let r = run_sharded(3, |i| {
            if i == 1 {
                panic!("shard {i} exploded");
            }
            i
        });
        let msg = format!("{:#}", r.unwrap_err());
        assert!(msg.contains("shard 1"), "{msg}");
        assert!(msg.contains("exploded"), "{msg}");
    }

    #[test]
    fn shards_actually_parallel() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let peak = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        run_sharded(4, |_| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(30));
            live.fetch_sub(1, Ordering::SeqCst);
        })
        .unwrap();
        assert!(peak.load(Ordering::SeqCst) >= 2, "threads overlapped");
    }

    #[test]
    fn param_averaging() {
        let shards = vec![
            vec![vec![1.0, 2.0]],
            vec![vec![3.0, 6.0]],
        ];
        let avg = average_params(shards);
        assert_eq!(avg, vec![vec![2.0, 4.0]]);
    }

    #[test]
    fn pool_broadcast_collects_in_shard_order() {
        let pool = ShardPool::spawn(4, |i| Ok(i * 100)).unwrap();
        let out = pool.broadcast(|i, w| *w + i).unwrap();
        assert_eq!(out, vec![0, 101, 202, 303]);
    }

    #[test]
    fn pool_jobs_run_in_submission_order_per_shard() {
        let pool = ShardPool::spawn(1, |_| Ok(Vec::<usize>::new())).unwrap();
        for k in 0..16 {
            pool.submit(0, move |log| log.push(k)).unwrap();
        }
        let log = pool.call(0, |log| log.clone()).unwrap().wait().unwrap();
        assert_eq!(log, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn pool_worker_state_persists_across_calls() {
        let pool = ShardPool::spawn(2, |_| Ok(0u64)).unwrap();
        for _ in 0..5 {
            pool.broadcast(|_, w| *w += 1).unwrap();
        }
        let counts = pool.broadcast(|_, w| *w).unwrap();
        assert_eq!(counts, vec![5, 5]);
    }

    #[test]
    fn pool_init_failure_surfaces() {
        let r = ShardPool::<u8>::spawn(3, |i| {
            if i == 1 {
                anyhow::bail!("shard 1 refuses");
            }
            Ok(0)
        });
        assert!(r.is_err());
    }

    /// A panicking job is isolated: the ticket and later submissions
    /// error (no abort), sibling shards keep working, and teardown is
    /// clean — one panic never becomes a second panic in Drop.
    #[test]
    fn pool_job_panic_is_isolated() {
        let pool = ShardPool::spawn(2, |_| Ok(7u64)).unwrap();
        let t = pool
            .call(0, |_: &mut u64| -> u64 { panic!("chunk kaboom") })
            .unwrap();
        assert!(t.wait().is_err());
        // dead shard rejects new work with an error, not a panic. The
        // ticket fails as soon as the panic unwinds; the channel closes
        // when the thread retires moments later — poll for it.
        while pool.submit(0, |_| {}).is_ok() {
            std::thread::sleep(std::time::Duration::from_millis(1));
        }
        // sibling shard is unaffected
        let v = pool.call(1, |w| *w).unwrap().wait().unwrap();
        assert_eq!(v, 7);
        drop(pool); // must not panic while unwinding channels/handles
    }

    /// respawn() rebuilds the dead worker from the init closure and
    /// reports the recorded cause (worker id + panic message) exactly
    /// once.
    #[test]
    fn pool_respawn_recovers_and_reports_cause() {
        let mut pool = ShardPool::spawn(2, |i| Ok(i as u64)).unwrap();
        pool.broadcast(|_, w| *w += 10).unwrap();
        let t = pool
            .call(1, |_: &mut u64| -> u64 { panic!("injected fault") })
            .unwrap();
        assert!(t.wait().is_err());
        let cause = pool.respawn(1).unwrap();
        assert_eq!(cause.worker, 1);
        assert!(cause.message.contains("injected fault"), "{cause}");
        // the respawned worker is fresh init-state (1), not 11 — replay
        // is the supervisor's job
        let v = pool.call(1, |w| *w).unwrap().wait().unwrap();
        assert_eq!(v, 1);
        // cause was consumed: nothing left to take
        assert!(pool.take_cause(1).is_none());
        // shard 0 kept its state across the sibling's death
        let v0 = pool.call(0, |w| *w).unwrap().wait().unwrap();
        assert_eq!(v0, 10);
    }

    /// broadcast() over a pool with one dead shard returns an error
    /// while surviving shards still ran their jobs.
    #[test]
    fn pool_broadcast_with_dead_shard_errors_cleanly() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let pool = ShardPool::spawn(3, |_| Ok(0u8)).unwrap();
        let t = pool
            .call(1, |_: &mut u8| panic!("dead"))
            .unwrap();
        assert!(t.wait().is_err());
        let ran = Arc::new(AtomicUsize::new(0));
        let ran2 = ran.clone();
        let r = pool.broadcast(move |_, _| {
            ran2.fetch_add(1, Ordering::SeqCst);
        });
        assert!(r.is_err());
        assert_eq!(ran.load(Ordering::SeqCst), 2, "survivors ran");
    }

    #[test]
    fn param_tensor_helpers() {
        let a = vec![Tensor::F32(vec![2.0, 4.0])];
        let b = vec![Tensor::F32(vec![1.0, 1.0])];
        let d = sub_params(&a, &b);
        assert_eq!(d[0].as_f32(), &[1.0, 3.0]);
        let mut m = vec![Tensor::F32(vec![10.0, 10.0])];
        add_params(&mut m, &d);
        assert_eq!(m[0].as_f32(), &[11.0, 13.0]);
        let avg = average_param_tensors(vec![a, b]);
        assert_eq!(avg[0].as_f32(), &[1.5, 2.5]);
    }
}
