//! Shard engine — the `jax.pmap` stand-in (see `docs/ARCHITECTURE.md`,
//! "Shard engine" section).
//!
//! Each shard is a *persistent* host thread owning its own PJRT client,
//! compiled executables and env-state buffers (exactly a pmap replica's
//! footprint). Because the `xla` crate's handles are not `Send`, all shard
//! state is constructed inside the shard's thread by an init closure and
//! never leaves it; the main thread talks to shards exclusively over
//! channels of `FnOnce` jobs.
//!
//! Two layers build on [`ShardPool`]:
//!
//! - [`crate::coordinator::rollout::RolloutEngine`] — double-buffered
//!   random-policy collection (Fig. 5d/e scaling axis).
//! - [`crate::coordinator::trainer::ShardedTrainer`] — data-parallel RL²
//!   PPO with fixed-order parameter averaging (the pmap all-reduce).

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;

use anyhow::{Context, Result};

use crate::runtime::Tensor;

/// A unit of work shipped to one shard thread. The worker state `W` stays
/// on its thread; only the closure (and its captures) cross.
type Job<W> = Box<dyn FnOnce(&mut W) + Send + 'static>;

/// Pool of persistent shard worker threads, each owning a worker state `W`
/// built in-thread by the init closure (so `W` need not be `Send` — PJRT
/// clients and executables are not).
///
/// Jobs are executed strictly in submission order per shard, which is what
/// the double-buffered engines rely on for deterministic per-shard RNG
/// streams: a shard's trajectory depends only on its own job sequence,
/// never on cross-shard scheduling.
pub struct ShardPool<W> {
    txs: Vec<Sender<Job<W>>>,
    handles: Vec<JoinHandle<()>>,
}

impl<W: 'static> ShardPool<W> {
    /// Spawn `n` shard threads. `init(shard_index)` runs *inside* each
    /// thread to build its worker state; if any shard fails to initialise,
    /// the pool is torn down and the first error is returned.
    pub fn spawn<F>(n: usize, init: F) -> Result<ShardPool<W>>
    where
        F: Fn(usize) -> Result<W> + Send + Sync + 'static,
    {
        assert!(n > 0, "shard pool needs at least one shard");
        let init = Arc::new(init);
        let (ready_tx, ready_rx) = channel::<(usize, Result<()>)>();
        let mut txs = Vec::with_capacity(n);
        let mut handles = Vec::with_capacity(n);
        for i in 0..n {
            let (tx, rx) = channel::<Job<W>>();
            let init = init.clone();
            let ready = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("xmgrid-shard-{i}"))
                .spawn(move || {
                    let mut w = match init(i) {
                        Ok(w) => {
                            let _ = ready.send((i, Ok(())));
                            w
                        }
                        Err(e) => {
                            let _ = ready.send((i, Err(e)));
                            return;
                        }
                    };
                    // Drop the ready sender now: if a *sibling* shard
                    // panics during init (sending nothing), the channel
                    // must close once the survivors are done with it,
                    // so spawn() fails loudly instead of hanging.
                    drop(ready);
                    while let Ok(job) = rx.recv() {
                        job(&mut w);
                    }
                })
                .expect("spawning shard thread");
            txs.push(tx);
            handles.push(handle);
        }
        drop(ready_tx);
        let pool = ShardPool { txs, handles };
        for _ in 0..n {
            let (i, r) =
                ready_rx.recv().expect("shard init channel closed");
            r.with_context(|| format!("initialising shard {i}"))?;
        }
        Ok(pool)
    }

    pub fn shards(&self) -> usize {
        self.txs.len()
    }

    /// Enqueue `f` on one shard without waiting for a result. Panics if
    /// the shard thread has died (a previous job panicked).
    pub fn submit<F>(&self, shard: usize, f: F)
    where
        F: FnOnce(&mut W) + Send + 'static,
    {
        self.txs[shard]
            .send(Box::new(f))
            .expect("shard thread has exited");
    }

    /// Enqueue `f` on one shard and return a [`Ticket`] for its result.
    pub fn call<R, F>(&self, shard: usize, f: F) -> Ticket<R>
    where
        R: Send + 'static,
        F: FnOnce(&mut W) -> R + Send + 'static,
    {
        let (tx, rx) = channel();
        self.submit(shard, move |w| {
            let _ = tx.send(f(w));
        });
        Ticket { rx }
    }

    /// Lockstep collective: run `f(shard_index, worker)` on every shard
    /// concurrently, wait for all, and return results in shard order.
    pub fn broadcast<R, F>(&self, f: F) -> Vec<R>
    where
        R: Send + 'static,
        F: Fn(usize, &mut W) -> R + Send + Sync + 'static,
    {
        let f = Arc::new(f);
        let tickets: Vec<Ticket<R>> = (0..self.shards())
            .map(|i| {
                let f = f.clone();
                self.call(i, move |w| f(i, w))
            })
            .collect();
        tickets.into_iter().map(|t| t.wait()).collect()
    }
}

impl<W> Drop for ShardPool<W> {
    fn drop(&mut self) {
        // Closing the job channels ends each worker loop; queued jobs
        // still run to completion before the thread exits.
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Receipt for an in-flight shard job.
pub struct Ticket<R> {
    rx: Receiver<R>,
}

impl<R> Ticket<R> {
    /// Block until the job completes. Panics if the shard thread died
    /// before sending (i.e. the job itself panicked).
    pub fn wait(self) -> R {
        self.rx
            .recv()
            .expect("shard dropped its result (worker panicked)")
    }
}

/// Run `f(shard_index)` on `n` scoped threads and collect the results in
/// shard order. The original fork-join primitive, superseded on the hot
/// paths by the persistent [`ShardPool`]; retained as the simple
/// borrow-friendly escape hatch (scoped threads may capture non-`'static`
/// state, which pool jobs cannot).
pub fn run_sharded<F, R>(n: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Send + Sync,
    R: Send,
{
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let f = &f;
                scope.spawn(move || f(i))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Data-parallel parameter averaging across shard parameter sets (the
/// all-reduce a pmap training step performs). Arithmetic mean, in place on
/// the first set, returned.
///
/// The reduction order is *fixed*: shard 0's parameters are the
/// accumulator and shards 1..n are added in ascending index order. f32
/// addition is not associative, so this ordering is part of the engine's
/// determinism contract — overlap-off runs must be bitwise reproducible
/// regardless of which shard finished first (see the reduction-order
/// regression test in `tests/shard_engine.rs`).
pub fn average_params(mut shard_params: Vec<Vec<Vec<f32>>>)
                      -> Vec<Vec<f32>> {
    assert!(!shard_params.is_empty());
    let n = shard_params.len() as f32;
    let rest = shard_params.split_off(1);
    let mut acc = shard_params.pop().unwrap();
    for other in &rest {
        for (a, o) in acc.iter_mut().zip(other) {
            for (x, y) in a.iter_mut().zip(o) {
                *x += *y;
            }
        }
    }
    for a in acc.iter_mut() {
        for x in a.iter_mut() {
            *x /= n;
        }
    }
    acc
}

/// [`average_params`] lifted to the runtime's `Tensor` parameter lists
/// (all-f32), as held by the trainer.
pub fn average_param_tensors(shard_params: Vec<Vec<Tensor>>)
                             -> Vec<Tensor> {
    let raw: Vec<Vec<Vec<f32>>> = shard_params
        .into_iter()
        .map(|ps| {
            ps.into_iter()
                .map(|t| match t {
                    // move, don't copy: this runs on the per-iteration
                    // all-reduce hot path and the tensors are owned
                    Tensor::F32(v) => v,
                    _ => panic!("parameters must be f32 tensors"),
                })
                .collect()
        })
        .collect();
    average_params(raw).into_iter().map(Tensor::F32).collect()
}

/// Element-wise `after - before` over two parameter lists: the local
/// update one fused train iteration applied on a shard.
pub fn sub_params(after: &[Tensor], before: &[Tensor]) -> Vec<Tensor> {
    after
        .iter()
        .zip(before)
        .map(|(a, b)| {
            Tensor::F32(
                a.as_f32()
                    .iter()
                    .zip(b.as_f32())
                    .map(|(x, y)| x - y)
                    .collect(),
            )
        })
        .collect()
}

/// Add a (mean) delta into the master parameters in place.
pub fn add_params(master: &mut [Tensor], delta: &[Tensor]) {
    for (m, d) in master.iter_mut().zip(delta) {
        match m {
            Tensor::F32(mv) => {
                for (x, y) in mv.iter_mut().zip(d.as_f32()) {
                    *x += *y;
                }
            }
            _ => panic!("parameters must be f32 tensors"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_run_and_collect_in_order() {
        let out = run_sharded(4, |i| i * 10);
        assert_eq!(out, vec![0, 10, 20, 30]);
    }

    #[test]
    fn shards_actually_parallel() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let peak = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        run_sharded(4, |_| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(30));
            live.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) >= 2, "threads overlapped");
    }

    #[test]
    fn param_averaging() {
        let shards = vec![
            vec![vec![1.0, 2.0]],
            vec![vec![3.0, 6.0]],
        ];
        let avg = average_params(shards);
        assert_eq!(avg, vec![vec![2.0, 4.0]]);
    }

    #[test]
    fn pool_broadcast_collects_in_shard_order() {
        let pool = ShardPool::spawn(4, |i| Ok(i * 100)).unwrap();
        let out = pool.broadcast(|i, w| *w + i);
        assert_eq!(out, vec![0, 101, 202, 303]);
    }

    #[test]
    fn pool_jobs_run_in_submission_order_per_shard() {
        let pool = ShardPool::spawn(1, |_| Ok(Vec::<usize>::new())).unwrap();
        for k in 0..16 {
            pool.submit(0, move |log| log.push(k));
        }
        let log = pool.call(0, |log| log.clone()).wait();
        assert_eq!(log, (0..16).collect::<Vec<_>>());
    }

    #[test]
    fn pool_worker_state_persists_across_calls() {
        let pool = ShardPool::spawn(2, |_| Ok(0u64)).unwrap();
        for _ in 0..5 {
            pool.broadcast(|_, w| *w += 1);
        }
        let counts = pool.broadcast(|_, w| *w);
        assert_eq!(counts, vec![5, 5]);
    }

    #[test]
    fn pool_init_failure_surfaces() {
        let r = ShardPool::<u8>::spawn(3, |i| {
            if i == 1 {
                anyhow::bail!("shard 1 refuses");
            }
            Ok(0)
        });
        assert!(r.is_err());
    }

    #[test]
    fn param_tensor_helpers() {
        let a = vec![Tensor::F32(vec![2.0, 4.0])];
        let b = vec![Tensor::F32(vec![1.0, 1.0])];
        let d = sub_params(&a, &b);
        assert_eq!(d[0].as_f32(), &[1.0, 3.0]);
        let mut m = vec![Tensor::F32(vec![10.0, 10.0])];
        add_params(&mut m, &d);
        assert_eq!(m[0].as_f32(), &[11.0, 13.0]);
        let avg = average_param_tensors(vec![a, b]);
        assert_eq!(avg[0].as_f32(), &[1.5, 2.5]);
    }
}
