//! Shard pool — the `jax.pmap` stand-in (DESIGN.md §Hardware-Adaptation).
//!
//! Each shard is a host thread owning its *own* PJRT client, compiled
//! executables and env-state buffers (exactly a pmap replica's footprint).
//! Shards synchronize per call like a collective step. Since the `xla`
//! crate's handles are not `Send`, all shard state is constructed inside
//! the shard's thread.

/// Run `f(shard_index)` on `n` threads and collect the results in shard
/// order. Panics propagate.
pub fn run_sharded<F, R>(n: usize, f: F) -> Vec<R>
where
    F: Fn(usize) -> R + Send + Sync,
    R: Send,
{
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..n)
            .map(|i| {
                let f = &f;
                scope.spawn(move || f(i))
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Data-parallel gradient averaging across shard parameter sets (the
/// all-reduce a pmap training step performs). Arithmetic mean, in place on
/// the first set, returned.
pub fn average_params(mut shard_params: Vec<Vec<Vec<f32>>>)
                      -> Vec<Vec<f32>> {
    assert!(!shard_params.is_empty());
    let n = shard_params.len() as f32;
    let mut acc = shard_params.swap_remove(0);
    for other in &shard_params {
        for (a, o) in acc.iter_mut().zip(other) {
            for (x, y) in a.iter_mut().zip(o) {
                *x += *y;
            }
        }
    }
    for a in acc.iter_mut() {
        for x in a.iter_mut() {
            *x /= n;
        }
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shards_run_and_collect_in_order() {
        let out = run_sharded(4, |i| i * 10);
        assert_eq!(out, vec![0, 10, 20, 30]);
    }

    #[test]
    fn shards_actually_parallel() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let peak = AtomicUsize::new(0);
        let live = AtomicUsize::new(0);
        run_sharded(4, |_| {
            let now = live.fetch_add(1, Ordering::SeqCst) + 1;
            peak.fetch_max(now, Ordering::SeqCst);
            std::thread::sleep(std::time::Duration::from_millis(30));
            live.fetch_sub(1, Ordering::SeqCst);
        });
        assert!(peak.load(Ordering::SeqCst) >= 2, "threads overlapped");
    }

    #[test]
    fn param_averaging() {
        let shards = vec![
            vec![vec![1.0, 2.0]],
            vec![vec![3.0, 6.0]],
        ];
        let avg = average_params(shards);
        assert_eq!(avg, vec![vec![2.0, 4.0]]);
    }
}
