//! k-shot evaluation harness (`xmgrid eval`): run a policy over a
//! held-out task split and report the per-trial (shot 1..k) return
//! curve — the paper's §2.1 trial protocol turned into a measurement.
//!
//! # k-shot definition
//!
//! An episode in XLand-MiniGrid is a sequence of *trials* of the same
//! task: a trial ends when the goal is reached or the step limit
//! expires, and the trial reset re-places objects but keeps the task
//! (§2.1). The harness pins one task per env (round-robin over the
//! split) and records the return of each env's first `k` trials —
//! shot `j` is trial `j`, so a policy that adapts within an episode
//! shows a rising curve, while memoryless baselines (random, the
//! greedy script) stay flat. No task source is installed on the env
//! batch: episode auto-reset without a source replays the env's
//! current task (`env::vector`), which is exactly the pinned-task
//! protocol.
//!
//! # Determinism
//!
//! Everything derives from the config seed: layouts, per-env streams
//! and the random policy's action stream are drawn coordinator-side in
//! fixed env order, and stepping runs on [`ParVecEnv`], whose outputs
//! are bitwise thread-invariant. Same seed + same split ⇒ same curve,
//! for any `--threads`.

use anyhow::{bail, ensure, Result};

use crate::env::api::EnvParams;
use crate::env::goals::Goal;
use crate::env::layouts::xland_layout;
use crate::env::state::{default_max_steps, Ruleset, TaskSource};
use crate::env::types::*;
use crate::env::Grid;
use crate::nn::math::categorical;
use crate::nn::model::{network_step, StepScratch};
use crate::nn::Params;
use crate::util::rng::Rng;

use super::metrics::WallTimer;
use super::workers::ParVecEnv;

/// Policies the harness runs. `Random` samples uniform actions;
/// `Greedy` is a deterministic script that turns toward the nearest
/// visible goal object and picks it up when the goal asks for
/// possession (a floor for learned policies to clear, not a solver);
/// `Checkpoint` is a learned RL² policy restored from a train
/// checkpoint (`--policy checkpoint:PATH`).
#[derive(Clone, Debug, PartialEq)]
pub enum EvalPolicy {
    Random,
    Greedy,
    /// The native GRU actor-critic, run with its hidden state,
    /// previous action and previous reward carried through the k-shot
    /// loop exactly as in training: trial resets keep the carry (the
    /// policy adapts across shots, §2.1), episode resets clear it via
    /// the done mask inside [`network_step`].
    Checkpoint {
        params: Box<Params>,
        /// sample the categorical head instead of taking the argmax
        sample: bool,
    },
}

impl EvalPolicy {
    pub fn from_flag(s: &str) -> Result<EvalPolicy> {
        match s {
            "random" => Ok(EvalPolicy::Random),
            "greedy" => Ok(EvalPolicy::Greedy),
            other => anyhow::bail!(
                "--policy must be random | greedy | artifact | \
                 checkpoint:PATH, got {other}"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EvalPolicy::Random => "random",
            EvalPolicy::Greedy => "greedy",
            EvalPolicy::Checkpoint { .. } => "checkpoint",
        }
    }
}

/// Where the checkpointed model's observation extras come from —
/// resolved from `ModelDims::extra` against the env shape, mirroring
/// the trainer's `--obs` stacks (0 = symbolic, 4 = dir one-hot,
/// task_row_len = rules-goals).
enum ExtraSrc {
    None,
    Direction,
    TaskRow(usize),
}

/// Carry + scratch for the checkpoint policy: one batched RL² network
/// step per harness step, mirroring the native trainer's rollout loop.
struct NetState {
    params: Params,
    sample: bool,
    extra: ExtraSrc,
    rows: Vec<i32>,
    dir_buf: Vec<i32>,
    task_buf: Vec<i32>,
    h: Vec<f32>,
    h_next: Vec<f32>,
    prev_a: Vec<i32>,
    prev_r: Vec<f32>,
    done_prev: Vec<i32>,
    logits: Vec<f32>,
    values: Vec<f32>,
    scratch: StepScratch,
    lp: Vec<f32>,
}

impl NetState {
    fn new(params: Params, sample: bool, ep: &EnvParams, b: usize)
           -> Result<NetState> {
        let dm = params.dims;
        ensure!(
            dm.v == ep.opts.view_size,
            "checkpoint was trained on a {0}x{0} view; this env family \
             observes {1}x{1}",
            dm.v, ep.opts.view_size
        );
        ensure!(
            dm.a == NUM_ACTIONS,
            "checkpoint head has {} actions, the env has {NUM_ACTIONS}",
            dm.a
        );
        let extra = match dm.extra {
            0 => ExtraSrc::None,
            4 => ExtraSrc::Direction,
            x if x == ep.task_row_len() => ExtraSrc::TaskRow(x),
            x => bail!(
                "checkpoint expects {x} observation extras; this env \
                 shape provides 0 (symbolic), 4 (dir) or {} \
                 (rules-goals)",
                ep.task_row_len()
            ),
        };
        Ok(NetState {
            sample,
            extra,
            rows: vec![0; b * dm.obs_len()],
            dir_buf: vec![0; b],
            task_buf: vec![0; b * ep.task_row_len()],
            h: vec![0.0; b * dm.h],
            h_next: vec![0.0; b * dm.h],
            prev_a: vec![0; b],
            prev_r: vec![0.0; b],
            // a fresh episode starts done: the mask zeroes the carry
            done_prev: vec![1; b],
            logits: vec![0.0; b * dm.a],
            values: vec![0.0; b],
            scratch: StepScratch::new(&dm),
            lp: vec![0.0; dm.a],
            params,
        })
    }

    /// Assemble observation rows, run one network step and pick the
    /// batch's actions — argmax (first maximum) or one categorical
    /// draw per env in ascending env order from `act_rng`.
    fn act(&mut self, venv: &ParVecEnv, obs: &[i32],
           act_rng: &mut Rng, actions: &mut [i32]) {
        let dm = self.params.dims;
        let (ol, vv2, a) = (dm.obs_len(), dm.v * dm.v * 2, dm.a);
        let b = actions.len();
        match self.extra {
            ExtraSrc::None => self.rows.copy_from_slice(obs),
            ExtraSrc::Direction => {
                venv.copy_agent_dirs_into(&mut self.dir_buf);
                for i in 0..b {
                    let row = &mut self.rows[i * ol..(i + 1) * ol];
                    row[..vv2]
                        .copy_from_slice(&obs[i * vv2..(i + 1) * vv2]);
                    for x in row[vv2..].iter_mut() {
                        *x = 0;
                    }
                    let d = self.dir_buf[i].rem_euclid(4) as usize;
                    row[vv2 + d] = 1;
                }
            }
            ExtraSrc::TaskRow(rl) => {
                venv.copy_task_rows_into(&mut self.task_buf);
                for i in 0..b {
                    let row = &mut self.rows[i * ol..(i + 1) * ol];
                    row[..vv2]
                        .copy_from_slice(&obs[i * vv2..(i + 1) * vv2]);
                    row[vv2..].copy_from_slice(
                        &self.task_buf[i * rl..(i + 1) * rl]);
                }
            }
        }
        network_step(&self.params, &self.rows, &self.prev_a,
                     &self.prev_r, &self.done_prev, &self.h,
                     &mut self.logits, &mut self.values,
                     &mut self.h_next, &mut self.scratch, None);
        std::mem::swap(&mut self.h, &mut self.h_next);
        for i in 0..b {
            let row = &self.logits[i * a..(i + 1) * a];
            actions[i] = if self.sample {
                categorical(act_rng, row, &mut self.lp) as i32
            } else {
                let mut best = 0usize;
                for j in 1..a {
                    if row[j] > row[best] {
                        best = j;
                    }
                }
                best as i32
            };
        }
    }

    /// Advance the RL² carry with the step's outcome (episode dones
    /// gate the reset inside the next `network_step`, as in training).
    fn observe(&mut self, actions: &[i32], rewards: &[f32],
               dones: &[bool]) {
        for i in 0..actions.len() {
            self.prev_a[i] = actions[i];
            self.prev_r[i] = rewards[i];
            self.done_prev[i] = dones[i] as i32;
        }
    }
}

/// Shape of one k-shot evaluation run.
#[derive(Clone, Copy, Debug)]
pub struct KShotConfig {
    /// env family shape (grid dims + table capacities sized to the
    /// split, e.g. via `NativeEnvConfig::for_tasks`)
    pub params: EnvParams,
    /// rooms in the base grid layout (from the registry family)
    pub rooms: usize,
    /// env batch; split tasks are assigned round-robin (env `i` gets
    /// task `i % num_tasks`), so `b >= num_tasks` covers every task
    pub b: usize,
    /// trials recorded per env (the `k` of k-shot)
    pub shots: usize,
    /// stepping worker threads (bitwise-invariant, any count)
    pub threads: usize,
    pub seed: u64,
}

/// Aggregates of one shot index across the env batch.
#[derive(Clone, Copy, Debug)]
pub struct ShotStats {
    /// 1-based trial index
    pub shot: usize,
    pub return_mean: f64,
    /// 20th-percentile return (the §4.2 robustness figure)
    pub return_p20: f64,
    /// fraction of envs whose trial ended on goal achievement
    pub solved_frac: f64,
    /// mean trial length in steps
    pub len_mean: f64,
}

/// Result of [`eval_kshot`]: the per-shot curve plus throughput.
#[derive(Clone, Debug)]
pub struct KShotReport {
    pub policy: &'static str,
    pub shots: Vec<ShotStats>,
    pub envs: usize,
    /// distinct tasks of the split actually pinned (min(b, num_tasks))
    pub tasks: usize,
    /// total env steps executed (batch * loop steps)
    pub total_steps: u64,
    pub elapsed_secs: f64,
}

impl KShotReport {
    pub fn steps_per_sec(&self) -> f64 {
        self.total_steps as f64 / self.elapsed_secs.max(1e-9)
    }
}

/// 20th percentile of `xs` (lower-index convention on the sorted
/// values, matching the §4.2 evaluation protocol's P20).
fn p20(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    s[(s.len() - 1) / 5]
}

/// Run `policy` for `cfg.shots` trials per env over `tasks` (one task
/// pinned per env, round-robin) and aggregate the per-shot return
/// curve. Deterministic per `(tasks, cfg.seed)` for any thread count.
pub fn eval_kshot(tasks: &dyn TaskSource, policy: EvalPolicy,
                  cfg: &KShotConfig) -> Result<KShotReport> {
    let n = tasks.num_tasks();
    ensure!(n > 0, "k-shot eval needs a non-empty task split");
    ensure!(cfg.b > 0 && cfg.shots > 0, "need batch and shots >= 1");
    let b = cfg.b;
    let (h, w) = (cfg.params.h, cfg.params.w);
    let max_steps = default_max_steps(h, w);

    // all randomness flows from the config seed in fixed env order
    let mut rng = Rng::new(cfg.seed);
    let rulesets: Vec<&Ruleset> = (0..b).map(|i| tasks.task(i % n)).collect();
    let grids: Vec<Grid> = (0..b)
        .map(|_| xland_layout(cfg.rooms, h, w, &mut rng))
        .collect();
    let limits = vec![max_steps; b];
    let rngs: Vec<Rng> = (0..b).map(|_| rng.split()).collect();
    let mut act_rng = rng.split();

    let mut venv = ParVecEnv::new(cfg.params, b, cfg.threads);
    let mut obs = vec![0i32; venv.obs_len()];
    venv.reset_all(&grids, &rulesets, &limits, &rngs, &mut obs)?;
    // NOTE: no set_task_source — auto-reset replays the pinned task

    // one dispatch before the loop: the learned policy's carry state
    // lives in `Actor::Net`, the baselines stay allocation-free
    enum Actor {
        Random,
        Greedy,
        Net(Box<NetState>),
    }
    let mut actor = match &policy {
        EvalPolicy::Random => Actor::Random,
        EvalPolicy::Greedy => Actor::Greedy,
        EvalPolicy::Checkpoint { params, sample } => Actor::Net(
            Box::new(NetState::new((**params).clone(), *sample,
                                   &cfg.params, b)?),
        ),
    };

    let goals: Vec<Goal> = rulesets.iter().map(|r| r.goal).collect();
    let v = cfg.params.opts.view_size;
    let mut actions = vec![0i32; b];
    let mut rewards = vec![0f32; b];
    let mut dones = vec![false; b];
    let mut trial_dones = vec![false; b];

    // per-env shot accumulators
    let mut shot_returns = vec![vec![0f64; b]; cfg.shots];
    let mut shot_solved = vec![vec![false; b]; cfg.shots];
    let mut shot_lens = vec![vec![0u32; b]; cfg.shots];
    let mut cur_return = vec![0f64; b];
    let mut cur_len = vec![0u32; b];
    let mut shot_idx = vec![0usize; b];
    let mut pending = b;

    // every episode of max_steps steps ends >= 1 trial, so this cap
    // guarantees completion even for a policy that never scores
    let step_cap = cfg.shots * max_steps as usize + 1;
    let t0 = WallTimer::start();
    let mut steps_run = 0u64;
    for _ in 0..step_cap {
        if pending == 0 {
            break;
        }
        match &mut actor {
            Actor::Random => {
                for a in actions.iter_mut() {
                    *a = act_rng.below(NUM_ACTIONS) as i32;
                }
            }
            Actor::Greedy => {
                for i in 0..b {
                    let view = &obs[i * v * v * 2..(i + 1) * v * v * 2];
                    actions[i] = greedy_action(view, v, &goals[i]);
                }
            }
            Actor::Net(n) => {
                n.act(&venv, &obs, &mut act_rng, &mut actions);
            }
        }
        venv.step_all(&actions, &mut obs, &mut rewards, &mut dones,
                      &mut trial_dones)?;
        if let Actor::Net(n) = &mut actor {
            n.observe(&actions, &rewards, &dones);
        }
        steps_run += b as u64;
        for i in 0..b {
            if shot_idx[i] >= cfg.shots {
                continue;
            }
            cur_return[i] += rewards[i] as f64;
            cur_len[i] += 1;
            if trial_dones[i] {
                let s = shot_idx[i];
                shot_returns[s][i] = cur_return[i];
                shot_solved[s][i] = rewards[i] > 0.0;
                shot_lens[s][i] = cur_len[i];
                cur_return[i] = 0.0;
                cur_len[i] = 0;
                shot_idx[i] += 1;
                if shot_idx[i] == cfg.shots {
                    pending -= 1;
                }
            }
        }
    }
    let elapsed = t0.elapsed_secs();
    ensure!(pending == 0,
            "k-shot harness did not complete within the step cap \
             ({pending} envs short) — this is a bug, the cap covers \
             shots * max_steps");

    // env-major f64 reductions in ascending order: deterministic
    let shots = (0..cfg.shots)
        .map(|s| {
            let rets = &shot_returns[s];
            let mean = rets.iter().sum::<f64>() / b as f64;
            let solved =
                shot_solved[s].iter().filter(|&&x| x).count() as f64
                    / b as f64;
            let len_mean = shot_lens[s].iter().map(|&x| x as f64)
                .sum::<f64>() / b as f64;
            ShotStats {
                shot: s + 1,
                return_mean: mean,
                return_p20: p20(rets),
                solved_frac: solved,
                len_mean,
            }
        })
        .collect();
    Ok(KShotReport {
        policy: policy.name(),
        shots,
        envs: b,
        tasks: n.min(b),
        total_steps: steps_run,
        elapsed_secs: elapsed,
    })
}

/// The greedy script: egocentric V×V view, agent at bottom-center
/// `(V-1, V/2)` facing up. Scan for the closest visible cell matching
/// one of the goal's required objects; pick it up when directly ahead
/// and the goal wants possession, otherwise turn/step toward it; with
/// no target in sight, walk forward when the cell ahead is passable and
/// turn right at obstacles. Pure function of (view, goal) — fully
/// deterministic.
fn greedy_action(view: &[i32], v: usize, goal: &Goal) -> i32 {
    let want = goal.required_objects();
    let (ar, ac) = (v as i32 - 1, v as i32 / 2);
    let mut best: Option<(i32, i32, i32)> = None; // (dist, dr, dc)
    if !want.is_empty() {
        for r in 0..v as i32 {
            for c in 0..v as i32 {
                if (r, c) == (ar, ac) {
                    continue;
                }
                let t = view[((r * v as i32 + c) * 2) as usize];
                let col = view[((r * v as i32 + c) * 2 + 1) as usize];
                if !want.iter().any(|o| o.tile == t && o.color == col) {
                    continue;
                }
                let (dr, dc) = (r - ar, c - ac);
                let dist = dr.abs() + dc.abs();
                if best.map_or(true, |(d, _, _)| dist < d) {
                    best = Some((dist, dr, dc));
                }
            }
        }
    }
    if let Some((dist, dr, dc)) = best {
        if dist == 1 && dr == -1 && goal.id() == GOAL_AGENT_HOLD {
            return ACTION_PICK_UP;
        }
        if dc < 0 {
            return ACTION_TURN_LEFT;
        }
        if dc > 0 {
            return ACTION_TURN_RIGHT;
        }
        if dr < -1 {
            return ACTION_FORWARD;
        }
        // adjacent ahead but not a possession goal: the near-goal
        // checks fire on adjacency by themselves; nudge forward (a
        // blocked move is a no-op step)
        return ACTION_FORWARD;
    }
    // wander: forward over passable terrain, else turn right
    let ahead_t = view[(((ar - 1) * v as i32 + ac) * 2) as usize];
    let passable = matches!(ahead_t,
                            TILE_FLOOR | TILE_GOAL | TILE_DOOR_OPEN);
    if passable {
        ACTION_FORWARD
    } else {
        ACTION_TURN_RIGHT
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchgen::config::Preset;
    use crate::benchgen::generator::generate_benchmark_par;
    use crate::benchgen::{Benchmark, TaskSlice};
    use crate::coordinator::NativeEnvConfig;
    use std::sync::Arc;

    fn split() -> TaskSlice {
        let (rulesets, _) =
            generate_benchmark_par(&Preset::Trivial.config(), 16, 1)
                .unwrap();
        let b = Arc::new(Benchmark { name: "ev".into(), rulesets });
        TaskSlice::full(b).shuffle(3).split(0.5).1
    }

    fn cfg(tasks: &dyn TaskSource, b: usize, threads: usize)
           -> KShotConfig {
        let ncfg = NativeEnvConfig::for_tasks("XLand-MiniGrid-R1-9x9",
                                              b, 1, tasks)
            .unwrap();
        KShotConfig {
            params: ncfg.params,
            rooms: ncfg.rooms,
            b,
            shots: 3,
            threads,
            seed: 17,
        }
    }

    #[test]
    fn curve_shape_and_finiteness() {
        let s = split();
        for policy in [EvalPolicy::Random, EvalPolicy::Greedy] {
            let rep =
                eval_kshot(&s, policy, &cfg(&s, 8, 1)).unwrap();
            assert_eq!(rep.shots.len(), 3);
            for (j, st) in rep.shots.iter().enumerate() {
                assert_eq!(st.shot, j + 1, "monotone 1-based shots");
                assert!(st.return_mean.is_finite());
                assert!(st.return_p20 <= st.return_mean + 1e-12);
                assert!((0.0..=1.0).contains(&st.solved_frac));
                assert!(st.len_mean >= 1.0);
            }
            assert!(rep.total_steps > 0);
            assert_eq!(rep.envs, 8);
            assert_eq!(rep.tasks, 8);
        }
    }

    #[test]
    fn deterministic_across_threads() {
        let s = split();
        let run = |threads: usize| {
            let rep = eval_kshot(&s, EvalPolicy::Random,
                                 &cfg(&s, 8, threads))
                .unwrap();
            rep.shots
                .iter()
                .map(|st| (st.return_mean.to_bits(),
                           st.return_p20.to_bits(),
                           st.solved_frac.to_bits(),
                           st.len_mean.to_bits()))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(2));
        assert_eq!(run(1), run(4));
    }

    fn tiny_policy(extra: usize) -> EvalPolicy {
        let dims = crate::nn::ModelDims {
            v: 5, e: 2, ae: 3, d: 8, h: 6, a: 6, extra,
        };
        let mut rng = Rng::new(11);
        EvalPolicy::Checkpoint {
            params: Box::new(Params::init(dims, &mut rng)),
            sample: false,
        }
    }

    #[test]
    fn checkpoint_policy_runs_all_obs_widths() {
        let s = split();
        let c = cfg(&s, 8, 1);
        let task_row = c.params.task_row_len();
        for extra in [0usize, 4, task_row] {
            let rep = eval_kshot(&s, tiny_policy(extra), &c).unwrap();
            assert_eq!(rep.policy, "checkpoint");
            assert_eq!(rep.shots.len(), 3);
            assert!(rep.shots.iter().all(|st| st.return_mean.is_finite()));
        }
        // a width no wrapper stack produces is a clean error
        assert!(eval_kshot(&s, tiny_policy(3), &c).is_err());
    }

    #[test]
    fn checkpoint_policy_deterministic_across_threads() {
        let s = split();
        let run = |threads: usize, sample: bool| {
            let mut p = tiny_policy(4);
            if let EvalPolicy::Checkpoint { sample: sm, .. } = &mut p {
                *sm = sample;
            }
            let rep = eval_kshot(&s, p, &cfg(&s, 8, threads)).unwrap();
            rep.shots
                .iter()
                .map(|st| (st.return_mean.to_bits(),
                           st.len_mean.to_bits()))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(1, false), run(4, false));
        assert_eq!(run(1, true), run(4, true));
    }

    #[test]
    fn p20_convention() {
        assert_eq!(p20(&[]), 0.0);
        assert_eq!(p20(&[5.0]), 5.0);
        let xs: Vec<f64> = (1..=10).map(|x| x as f64).collect();
        assert_eq!(p20(&xs), 2.0); // index (10-1)/5 = 1 of sorted
    }
}
