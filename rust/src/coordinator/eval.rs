//! k-shot evaluation harness (`xmgrid eval`): run a policy over a
//! held-out task split and report the per-trial (shot 1..k) return
//! curve — the paper's §2.1 trial protocol turned into a measurement.
//!
//! # k-shot definition
//!
//! An episode in XLand-MiniGrid is a sequence of *trials* of the same
//! task: a trial ends when the goal is reached or the step limit
//! expires, and the trial reset re-places objects but keeps the task
//! (§2.1). The harness pins one task per env (round-robin over the
//! split) and records the return of each env's first `k` trials —
//! shot `j` is trial `j`, so a policy that adapts within an episode
//! shows a rising curve, while memoryless baselines (random, the
//! greedy script) stay flat. No task source is installed on the env
//! batch: episode auto-reset without a source replays the env's
//! current task (`env::vector`), which is exactly the pinned-task
//! protocol.
//!
//! # Determinism
//!
//! Everything derives from the config seed: layouts, per-env streams
//! and the random policy's action stream are drawn coordinator-side in
//! fixed env order, and stepping runs on [`ParVecEnv`], whose outputs
//! are bitwise thread-invariant. Same seed + same split ⇒ same curve,
//! for any `--threads`.

use anyhow::{ensure, Result};

use crate::env::api::EnvParams;
use crate::env::goals::Goal;
use crate::env::layouts::xland_layout;
use crate::env::state::{default_max_steps, Ruleset, TaskSource};
use crate::env::types::*;
use crate::env::Grid;
use crate::util::rng::Rng;

use super::metrics::WallTimer;
use super::workers::ParVecEnv;

/// Baseline policies the harness ships. `Random` samples uniform
/// actions; `Greedy` is a deterministic script that turns toward the
/// nearest visible goal object and picks it up when the goal asks for
/// possession (a floor for learned policies to clear, not a solver).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EvalPolicy {
    Random,
    Greedy,
}

impl EvalPolicy {
    pub fn from_flag(s: &str) -> Result<EvalPolicy> {
        match s {
            "random" => Ok(EvalPolicy::Random),
            "greedy" => Ok(EvalPolicy::Greedy),
            other => anyhow::bail!(
                "--policy must be random | greedy | artifact, got {other}"
            ),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            EvalPolicy::Random => "random",
            EvalPolicy::Greedy => "greedy",
        }
    }
}

/// Shape of one k-shot evaluation run.
#[derive(Clone, Copy, Debug)]
pub struct KShotConfig {
    /// env family shape (grid dims + table capacities sized to the
    /// split, e.g. via `NativeEnvConfig::for_tasks`)
    pub params: EnvParams,
    /// rooms in the base grid layout (from the registry family)
    pub rooms: usize,
    /// env batch; split tasks are assigned round-robin (env `i` gets
    /// task `i % num_tasks`), so `b >= num_tasks` covers every task
    pub b: usize,
    /// trials recorded per env (the `k` of k-shot)
    pub shots: usize,
    /// stepping worker threads (bitwise-invariant, any count)
    pub threads: usize,
    pub seed: u64,
}

/// Aggregates of one shot index across the env batch.
#[derive(Clone, Copy, Debug)]
pub struct ShotStats {
    /// 1-based trial index
    pub shot: usize,
    pub return_mean: f64,
    /// 20th-percentile return (the §4.2 robustness figure)
    pub return_p20: f64,
    /// fraction of envs whose trial ended on goal achievement
    pub solved_frac: f64,
    /// mean trial length in steps
    pub len_mean: f64,
}

/// Result of [`eval_kshot`]: the per-shot curve plus throughput.
#[derive(Clone, Debug)]
pub struct KShotReport {
    pub policy: &'static str,
    pub shots: Vec<ShotStats>,
    pub envs: usize,
    /// distinct tasks of the split actually pinned (min(b, num_tasks))
    pub tasks: usize,
    /// total env steps executed (batch * loop steps)
    pub total_steps: u64,
    pub elapsed_secs: f64,
}

impl KShotReport {
    pub fn steps_per_sec(&self) -> f64 {
        self.total_steps as f64 / self.elapsed_secs.max(1e-9)
    }
}

/// 20th percentile of `xs` (lower-index convention on the sorted
/// values, matching the §4.2 evaluation protocol's P20).
fn p20(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.partial_cmp(b).unwrap());
    s[(s.len() - 1) / 5]
}

/// Run `policy` for `cfg.shots` trials per env over `tasks` (one task
/// pinned per env, round-robin) and aggregate the per-shot return
/// curve. Deterministic per `(tasks, cfg.seed)` for any thread count.
pub fn eval_kshot(tasks: &dyn TaskSource, policy: EvalPolicy,
                  cfg: &KShotConfig) -> Result<KShotReport> {
    let n = tasks.num_tasks();
    ensure!(n > 0, "k-shot eval needs a non-empty task split");
    ensure!(cfg.b > 0 && cfg.shots > 0, "need batch and shots >= 1");
    let b = cfg.b;
    let (h, w) = (cfg.params.h, cfg.params.w);
    let max_steps = default_max_steps(h, w);

    // all randomness flows from the config seed in fixed env order
    let mut rng = Rng::new(cfg.seed);
    let rulesets: Vec<&Ruleset> = (0..b).map(|i| tasks.task(i % n)).collect();
    let grids: Vec<Grid> = (0..b)
        .map(|_| xland_layout(cfg.rooms, h, w, &mut rng))
        .collect();
    let limits = vec![max_steps; b];
    let rngs: Vec<Rng> = (0..b).map(|_| rng.split()).collect();
    let mut act_rng = rng.split();

    let mut venv = ParVecEnv::new(cfg.params, b, cfg.threads);
    let mut obs = vec![0i32; venv.obs_len()];
    venv.reset_all(&grids, &rulesets, &limits, &rngs, &mut obs)?;
    // NOTE: no set_task_source — auto-reset replays the pinned task

    let goals: Vec<Goal> = rulesets.iter().map(|r| r.goal).collect();
    let v = cfg.params.opts.view_size;
    let mut actions = vec![0i32; b];
    let mut rewards = vec![0f32; b];
    let mut dones = vec![false; b];
    let mut trial_dones = vec![false; b];

    // per-env shot accumulators
    let mut shot_returns = vec![vec![0f64; b]; cfg.shots];
    let mut shot_solved = vec![vec![false; b]; cfg.shots];
    let mut shot_lens = vec![vec![0u32; b]; cfg.shots];
    let mut cur_return = vec![0f64; b];
    let mut cur_len = vec![0u32; b];
    let mut shot_idx = vec![0usize; b];
    let mut pending = b;

    // every episode of max_steps steps ends >= 1 trial, so this cap
    // guarantees completion even for a policy that never scores
    let step_cap = cfg.shots * max_steps as usize + 1;
    let t0 = WallTimer::start();
    let mut steps_run = 0u64;
    for _ in 0..step_cap {
        if pending == 0 {
            break;
        }
        match policy {
            EvalPolicy::Random => {
                for a in actions.iter_mut() {
                    *a = act_rng.below(NUM_ACTIONS) as i32;
                }
            }
            EvalPolicy::Greedy => {
                for i in 0..b {
                    let view = &obs[i * v * v * 2..(i + 1) * v * v * 2];
                    actions[i] = greedy_action(view, v, &goals[i]);
                }
            }
        }
        venv.step_all(&actions, &mut obs, &mut rewards, &mut dones,
                      &mut trial_dones)?;
        steps_run += b as u64;
        for i in 0..b {
            if shot_idx[i] >= cfg.shots {
                continue;
            }
            cur_return[i] += rewards[i] as f64;
            cur_len[i] += 1;
            if trial_dones[i] {
                let s = shot_idx[i];
                shot_returns[s][i] = cur_return[i];
                shot_solved[s][i] = rewards[i] > 0.0;
                shot_lens[s][i] = cur_len[i];
                cur_return[i] = 0.0;
                cur_len[i] = 0;
                shot_idx[i] += 1;
                if shot_idx[i] == cfg.shots {
                    pending -= 1;
                }
            }
        }
    }
    let elapsed = t0.elapsed_secs();
    ensure!(pending == 0,
            "k-shot harness did not complete within the step cap \
             ({pending} envs short) — this is a bug, the cap covers \
             shots * max_steps");

    // env-major f64 reductions in ascending order: deterministic
    let shots = (0..cfg.shots)
        .map(|s| {
            let rets = &shot_returns[s];
            let mean = rets.iter().sum::<f64>() / b as f64;
            let solved =
                shot_solved[s].iter().filter(|&&x| x).count() as f64
                    / b as f64;
            let len_mean = shot_lens[s].iter().map(|&x| x as f64)
                .sum::<f64>() / b as f64;
            ShotStats {
                shot: s + 1,
                return_mean: mean,
                return_p20: p20(rets),
                solved_frac: solved,
                len_mean,
            }
        })
        .collect();
    Ok(KShotReport {
        policy: policy.name(),
        shots,
        envs: b,
        tasks: n.min(b),
        total_steps: steps_run,
        elapsed_secs: elapsed,
    })
}

/// The greedy script: egocentric V×V view, agent at bottom-center
/// `(V-1, V/2)` facing up. Scan for the closest visible cell matching
/// one of the goal's required objects; pick it up when directly ahead
/// and the goal wants possession, otherwise turn/step toward it; with
/// no target in sight, walk forward when the cell ahead is passable and
/// turn right at obstacles. Pure function of (view, goal) — fully
/// deterministic.
fn greedy_action(view: &[i32], v: usize, goal: &Goal) -> i32 {
    let want = goal.required_objects();
    let (ar, ac) = (v as i32 - 1, v as i32 / 2);
    let mut best: Option<(i32, i32, i32)> = None; // (dist, dr, dc)
    if !want.is_empty() {
        for r in 0..v as i32 {
            for c in 0..v as i32 {
                if (r, c) == (ar, ac) {
                    continue;
                }
                let t = view[((r * v as i32 + c) * 2) as usize];
                let col = view[((r * v as i32 + c) * 2 + 1) as usize];
                if !want.iter().any(|o| o.tile == t && o.color == col) {
                    continue;
                }
                let (dr, dc) = (r - ar, c - ac);
                let dist = dr.abs() + dc.abs();
                if best.map_or(true, |(d, _, _)| dist < d) {
                    best = Some((dist, dr, dc));
                }
            }
        }
    }
    if let Some((dist, dr, dc)) = best {
        if dist == 1 && dr == -1 && goal.id() == GOAL_AGENT_HOLD {
            return ACTION_PICK_UP;
        }
        if dc < 0 {
            return ACTION_TURN_LEFT;
        }
        if dc > 0 {
            return ACTION_TURN_RIGHT;
        }
        if dr < -1 {
            return ACTION_FORWARD;
        }
        // adjacent ahead but not a possession goal: the near-goal
        // checks fire on adjacency by themselves; nudge forward (a
        // blocked move is a no-op step)
        return ACTION_FORWARD;
    }
    // wander: forward over passable terrain, else turn right
    let ahead_t = view[(((ar - 1) * v as i32 + ac) * 2) as usize];
    let passable = matches!(ahead_t,
                            TILE_FLOOR | TILE_GOAL | TILE_DOOR_OPEN);
    if passable {
        ACTION_FORWARD
    } else {
        ACTION_TURN_RIGHT
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::benchgen::config::Preset;
    use crate::benchgen::generator::generate_benchmark_par;
    use crate::benchgen::{Benchmark, TaskSlice};
    use crate::coordinator::NativeEnvConfig;
    use std::sync::Arc;

    fn split() -> TaskSlice {
        let (rulesets, _) =
            generate_benchmark_par(&Preset::Trivial.config(), 16, 1)
                .unwrap();
        let b = Arc::new(Benchmark { name: "ev".into(), rulesets });
        TaskSlice::full(b).shuffle(3).split(0.5).1
    }

    fn cfg(tasks: &dyn TaskSource, b: usize, threads: usize)
           -> KShotConfig {
        let ncfg = NativeEnvConfig::for_tasks("XLand-MiniGrid-R1-9x9",
                                              b, 1, tasks)
            .unwrap();
        KShotConfig {
            params: ncfg.params,
            rooms: ncfg.rooms,
            b,
            shots: 3,
            threads,
            seed: 17,
        }
    }

    #[test]
    fn curve_shape_and_finiteness() {
        let s = split();
        for policy in [EvalPolicy::Random, EvalPolicy::Greedy] {
            let rep =
                eval_kshot(&s, policy, &cfg(&s, 8, 1)).unwrap();
            assert_eq!(rep.shots.len(), 3);
            for (j, st) in rep.shots.iter().enumerate() {
                assert_eq!(st.shot, j + 1, "monotone 1-based shots");
                assert!(st.return_mean.is_finite());
                assert!(st.return_p20 <= st.return_mean + 1e-12);
                assert!((0.0..=1.0).contains(&st.solved_frac));
                assert!(st.len_mean >= 1.0);
            }
            assert!(rep.total_steps > 0);
            assert_eq!(rep.envs, 8);
            assert_eq!(rep.tasks, 8);
        }
    }

    #[test]
    fn deterministic_across_threads() {
        let s = split();
        let run = |threads: usize| {
            let rep = eval_kshot(&s, EvalPolicy::Random,
                                 &cfg(&s, 8, threads))
                .unwrap();
            rep.shots
                .iter()
                .map(|st| (st.return_mean.to_bits(),
                           st.return_p20.to_bits(),
                           st.solved_frac.to_bits(),
                           st.len_mean.to_bits()))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(1), run(2));
        assert_eq!(run(1), run(4));
    }

    #[test]
    fn p20_convention() {
        assert_eq!(p20(&[]), 0.0);
        assert_eq!(p20(&[5.0]), 5.0);
        let xs: Vec<f64> = (1..=10).map(|x| x as f64).collect();
        assert_eq!(p20(&xs), 2.0); // index (10-1)/5 = 1 of sorted
    }
}
