//! Lint output: human-readable lines for the terminal and a
//! schema-stable JSON document for the CI gate. JSON is hand-rolled
//! (no serde offline — same discipline as `util::bench::JsonReport`),
//! with a fixed key order and entries sorted by `(file, line, rule)`,
//! so byte-level diffs of two runs are meaningful.
//!
//! Schema (`version` bumps on any breaking change):
//!
//! ```json
//! {
//!   "tool": "xmglint",
//!   "version": 1,
//!   "rules": ["no-std-rng", …],
//!   "violations": [{"file": …, "line": …, "rule": …, "message": …}],
//!   "allows":     [{"file": …, "line": …, "rule": …, "reason": …}],
//!   "summary": {"files": N, "violations": N, "allows": N}
//! }
//! ```

use super::rules::AllowRecord;
use super::{Outcome, Violation};

/// JSON schema version — the CI validator pins this.
pub const JSON_VERSION: u32 = 1;

fn esc(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

/// Sort for stable output. Callers sort once, centrally, so the human
/// and JSON reports always agree on order.
pub fn sort_violations(violations: &mut [Violation]) {
    violations.sort_by(|a, b| {
        (a.file.as_str(), a.line, a.rule)
            .cmp(&(b.file.as_str(), b.line, b.rule))
    });
}

pub fn sort_allows(allows: &mut [AllowRecord]) {
    allows.sort_by(|a, b| {
        (a.file.as_str(), a.line).cmp(&(b.file.as_str(), b.line))
    });
}

/// `path:line: [rule] message` lines plus a one-line summary — the
/// shape compilers and editors already know how to jump through.
pub fn human(outcome: &Outcome, enabled: &[&str]) -> String {
    let mut out = String::new();
    for v in &outcome.violations {
        out.push_str(&format!(
            "{}:{}: [{}] {}\n",
            v.file,
            v.line,
            v.rule,
            v.message.replace('\n', " ")
        ));
    }
    if !outcome.violations.is_empty() {
        out.push('\n');
    }
    out.push_str(&format!(
        "xmglint: {} file(s), {} rule(s): {} violation(s), {} \
         allow(s)\n",
        outcome.files,
        enabled.len(),
        outcome.violations.len(),
        outcome.allows.len()
    ));
    out
}

pub fn json(outcome: &Outcome, enabled: &[&str]) -> String {
    let mut out = String::new();
    out.push_str("{\n");
    out.push_str("  \"tool\": \"xmglint\",\n");
    out.push_str(&format!("  \"version\": {JSON_VERSION},\n"));
    let rules: Vec<String> =
        enabled.iter().map(|r| format!("\"{}\"", esc(r))).collect();
    out.push_str(&format!("  \"rules\": [{}],\n", rules.join(", ")));
    out.push_str("  \"violations\": [");
    for (i, v) in outcome.violations.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"file\": \"{}\", \"line\": {}, \"rule\": \
             \"{}\", \"message\": \"{}\"}}",
            esc(&v.file),
            v.line,
            esc(v.rule),
            esc(&v.message)
        ));
    }
    if !outcome.violations.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str("],\n");
    out.push_str("  \"allows\": [");
    for (i, a) in outcome.allows.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n    {{\"file\": \"{}\", \"line\": {}, \"rule\": \
             \"{}\", \"reason\": \"{}\"}}",
            esc(&a.file),
            a.line,
            esc(a.rule),
            esc(&a.reason)
        ));
    }
    if !outcome.allows.is_empty() {
        out.push_str("\n  ");
    }
    out.push_str("],\n");
    out.push_str(&format!(
        "  \"summary\": {{\"files\": {}, \"violations\": {}, \
         \"allows\": {}}}\n",
        outcome.files,
        outcome.violations.len(),
        outcome.allows.len()
    ));
    out.push_str("}\n");
    out
}
