//! `xmgrid lint` — the in-repo determinism & panic-safety static
//! analysis pass.
//!
//! XLand-MiniGrid inherits reproducibility from JAX's purity
//! discipline; this native Rust engine gets no such help from its
//! substrate, so the invariants that make `--threads` bitwise-
//! invariant and workers panic-safe (single seeded RNG, no
//! hasher-order iteration, wall-clock confined to measurement, no
//! `unwrap` in supervised paths, fixed-order f64 reductions) are
//! conventions — exactly the kind of thing that regresses silently
//! and surfaces three PRs later as a thread-count-dependent parity
//! failure. This module turns those conventions into machine-checked
//! rules, run token-level over the source tree with zero new
//! dependencies, and wired as a hard CI gate.
//!
//! Layering:
//!
//! - [`scan`] — the lexer: tokens + test-region marking + directives;
//! - [`rules`] — rule registry, `--rules` config, allow directives;
//! - [`checks`] — the per-rule checkers (path-scoped token patterns);
//! - [`report`] — human and schema-stable JSON output.
//!
//! The library surface ([`lint_source`], [`lint_paths`]) exists so
//! `tests/lint_suite.rs` can pin each rule against fixture snippets
//! without spawning processes.

pub mod checks;
pub mod report;
pub mod rules;
pub mod scan;

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

pub use rules::{AllowRecord, LintConfig, RULES};

/// One finding: `file` is the path relative to the crate's `src/`
/// root (the coordinate system the rule scoping is defined in).
#[derive(Debug)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

/// A full lint run over a set of files.
pub struct Outcome {
    pub violations: Vec<Violation>,
    pub allows: Vec<AllowRecord>,
    pub files: usize,
}

/// Lint one in-memory source file. `name` plays the role of the
/// src-relative path for rule scoping (e.g. pass
/// `"coordinator/workers.rs"` to exercise the worker rules).
pub fn lint_source(
    name: &str,
    text: &str,
    cfg: &LintConfig,
) -> (Vec<Violation>, Vec<AllowRecord>) {
    let scanned = scan::scan(text);
    let raw = checks::check(name, &scanned, cfg);
    let (allows, mut bad) = rules::parse_allows(name, &scanned, cfg);
    let (mut kept, records) =
        rules::apply_allows(name, &scanned, allows, raw, cfg);
    kept.append(&mut bad);
    (kept, records)
}

/// Lint `.rs` files on disk: each path may be a file or a directory
/// (walked recursively, sorted for deterministic order). Returns the
/// aggregate outcome, violations and allows sorted by (file, line).
pub fn lint_paths(paths: &[PathBuf], cfg: &LintConfig) -> Result<Outcome> {
    let mut files: Vec<PathBuf> = Vec::new();
    for p in paths {
        if p.is_dir() {
            collect_rs(p, &mut files)
                .with_context(|| format!("walking {}", p.display()))?;
        } else if p.is_file() {
            files.push(p.clone());
        } else {
            bail!("lint path {} does not exist", p.display());
        }
    }
    files.sort();
    files.dedup();
    let mut violations = Vec::new();
    let mut allows = Vec::new();
    for f in &files {
        let text = std::fs::read_to_string(f)
            .with_context(|| format!("reading {}", f.display()))?;
        let rel = src_relative(f);
        let (mut v, mut a) = lint_source(&rel, &text, cfg);
        violations.append(&mut v);
        allows.append(&mut a);
    }
    report::sort_violations(&mut violations);
    report::sort_allows(&mut allows);
    Ok(Outcome { violations, allows, files: files.len() })
}

fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Rule scoping runs on paths relative to the crate's `src/` root
/// with `/` separators: strip everything up to and including the last
/// `src` component. A path with no `src` component (fixtures, odd
/// layouts) is used as-is, so scoped rules simply see an unscoped
/// name.
fn src_relative(path: &Path) -> String {
    let comps: Vec<String> = path
        .components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect();
    let after_src = comps
        .iter()
        .rposition(|c| c == "src")
        .map(|i| i + 1)
        .unwrap_or(0);
    comps[after_src..].join("/")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn src_relative_strips_through_src() {
        assert_eq!(
            src_relative(Path::new("rust/src/coordinator/shard.rs")),
            "coordinator/shard.rs"
        );
        assert_eq!(
            src_relative(Path::new("/a/b/src/main.rs")),
            "main.rs"
        );
        assert_eq!(
            src_relative(Path::new("fixture.rs")),
            "fixture.rs"
        );
    }

    #[test]
    fn scanner_skips_strings_comments_and_range_dots() {
        let cfg = LintConfig::all();
        let text = r#"
fn f(m: &std::collections::HashMap<u32, u32>) -> u32 {
    // thread_rng mentioned in a comment is fine
    let s = "Instant::now inside a string is fine";
    let _ = s;
    let mut acc = 0;
    for i in 0..m.len() {
        acc += i as u32;
    }
    acc
}
"#;
        let (v, _) = lint_source("coordinator/x.rs", text, &cfg);
        assert!(v.is_empty(), "false positives: {v:?}");
    }

    #[test]
    fn test_regions_are_exempt() {
        let cfg = LintConfig::all();
        let text = r#"
#[cfg(test)]
mod tests {
    #[test]
    fn t() {
        let mut m = std::collections::HashMap::new();
        m.insert(1, 2);
        for (k, v) in m.iter() {
            let _ = (k, v);
        }
    }
}
"#;
        let (v, _) = lint_source("coordinator/x.rs", text, &cfg);
        assert!(v.is_empty(), "test region not exempt: {v:?}");
    }
}
