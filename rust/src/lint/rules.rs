//! Rule registry and the allow-directive machinery.
//!
//! Every rule the linter knows is declared here with a stable id (the
//! same id appears in `--rules`, in `--json` output, and in allow
//! directives) and a one-line summary for `xmgrid help lint`.
//!
//! # Allow directives
//!
//! A violation is suppressed by an inline escape hatch:
//!
//! ```text
//! // xmglint: allow(rule-id) -- why this site is sound
//! ```
//!
//! The reason after `--` is mandatory — an allow is a reviewed claim
//! ("this expect cannot fire because …"), not an opt-out. A directive
//! covers its own line when it trails code, otherwise the next line of
//! code below it (intervening plain comments are fine, so a directive
//! can sit under a longer explanation block). Only plain `//` comments
//! carry directives — doc comments that mention the syntax are
//! documentation. Malformed directives,
//! unknown rule ids, missing reasons, and allows that suppress nothing
//! are themselves violations of the meta-rule [`BAD_ALLOW`] — an allow
//! that outlives the code it excused must be deleted, not inherited.

use super::scan::Scan;
use super::Violation;

pub struct RuleInfo {
    pub id: &'static str,
    pub summary: &'static str,
}

/// Meta-rule id: defects in the allow directives themselves.
pub const BAD_ALLOW: &str = "bad-allow";

/// The registry, in canonical (reporting) order. The documented rule
/// table in docs/ARCHITECTURE.md and the CI gate's expected rule list
/// mirror this — change them together.
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "no-std-rng",
        summary: "only util::rng may produce randomness in env/, \
                  benchgen/, coordinator/",
    },
    RuleInfo {
        id: "no-hash-iter",
        summary: "no HashMap/HashSet iteration (or random hashers) in \
                  determinism-critical modules",
    },
    RuleInfo {
        id: "no-wallclock-in-kernels",
        summary: "Instant::now/SystemTime confined to util/bench.rs, \
                  coordinator/metrics.rs and the CLI",
    },
    RuleInfo {
        id: "no-unwrap-in-workers",
        summary: "no .unwrap()/.expect() in supervised worker / \
                  channel paths",
    },
    RuleInfo {
        id: "float-reduction-order",
        summary: "no f32 accumulation or unordered float folds in \
                  coordinator reduction paths",
    },
    RuleInfo {
        id: "must-use-result",
        summary: "no discarded Result from fallible engine ops \
                  (submit/broadcast/wait/rollout/…)",
    },
    RuleInfo {
        id: BAD_ALLOW,
        summary: "xmglint allow directives must parse, name a known \
                  rule, carry a reason, and suppress something",
    },
];

pub fn is_rule(id: &str) -> bool {
    RULES.iter().any(|r| r.id == id)
}

/// Canonical static id for a rule name (so `Violation.rule` can stay
/// `&'static str` even when the name arrived from a directive).
pub fn canonical_id(id: &str) -> Option<&'static str> {
    RULES.iter().find(|r| r.id == id).map(|r| r.id)
}

/// Which rules run. Built from `--rules a,b,c` or [`LintConfig::all`].
pub struct LintConfig {
    enabled: Vec<&'static str>,
}

impl LintConfig {
    pub fn all() -> LintConfig {
        LintConfig {
            enabled: RULES.iter().map(|r| r.id).collect(),
        }
    }

    /// Parse a `--rules` list. Unknown ids are an error, not a silent
    /// no-op — a typo in a CI invocation must fail loudly.
    pub fn subset(list: &str) -> Result<LintConfig, String> {
        let mut enabled = Vec::new();
        for raw in list.split(',') {
            let id = raw.trim();
            if id.is_empty() {
                continue;
            }
            match canonical_id(id) {
                Some(s) => {
                    if !enabled.contains(&s) {
                        enabled.push(s);
                    }
                }
                None => {
                    return Err(format!(
                        "unknown lint rule `{id}` (known: {})",
                        RULES
                            .iter()
                            .map(|r| r.id)
                            .collect::<Vec<_>>()
                            .join(", ")
                    ))
                }
            }
        }
        // report in canonical order regardless of flag order
        let enabled = RULES
            .iter()
            .map(|r| r.id)
            .filter(|id| enabled.contains(id))
            .collect();
        Ok(LintConfig { enabled })
    }

    pub fn on(&self, id: &str) -> bool {
        self.enabled.iter().any(|r| *r == id)
    }

    pub fn enabled(&self) -> &[&'static str] {
        &self.enabled
    }
}

/// A parsed, well-formed allow directive.
pub struct Allow {
    /// Line of the directive comment itself.
    pub line: usize,
    pub rule: &'static str,
    pub reason: String,
}

/// An allow that actually suppressed a violation — surfaced in the
/// report so the escape hatches stay auditable.
pub struct AllowRecord {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub reason: String,
}

/// Parse every directive in a scan. Well-formed allows come back as
/// [`Allow`]; everything malformed becomes a [`BAD_ALLOW`] violation
/// immediately.
pub fn parse_allows(
    file: &str,
    scan: &Scan,
    cfg: &LintConfig,
) -> (Vec<Allow>, Vec<Violation>) {
    let mut allows = Vec::new();
    let mut bad = Vec::new();
    let mut push_bad = |line: usize, message: String| {
        if cfg.on(BAD_ALLOW) {
            bad.push(Violation {
                file: file.to_string(),
                line,
                rule: BAD_ALLOW,
                message,
            });
        }
    };
    for d in &scan.directives {
        let text = d.text.trim();
        let inner = match text
            .strip_prefix("allow(")
            .and_then(|rest| rest.split_once(')'))
        {
            Some((rule, tail)) => Some((rule.trim(), tail.trim())),
            None => None,
        };
        let Some((rule_name, tail)) = inner else {
            push_bad(
                d.line,
                format!(
                    "malformed directive `xmglint: {text}` (expected \
                     `allow(rule) -- reason`)"
                ),
            );
            continue;
        };
        let Some(rule) = canonical_id(rule_name) else {
            push_bad(
                d.line,
                format!("allow names unknown rule `{rule_name}`"),
            );
            continue;
        };
        let reason = match tail.strip_prefix("--") {
            Some(r) => r.trim(),
            None => "",
        };
        if reason.is_empty() {
            push_bad(
                d.line,
                format!(
                    "allow({rule}) has no reason — write \
                     `allow({rule}) -- why this site is sound`"
                ),
            );
            continue;
        }
        allows.push(Allow {
            line: d.line,
            rule,
            reason: reason.to_string(),
        });
    }
    (allows, bad)
}

/// Apply allows to a file's violations: a directive suppresses
/// matching-rule violations on its own line (trailing-comment form) or
/// on the next code line below it. Used allows are returned for the
/// report; unused allows for *enabled* rules become [`BAD_ALLOW`]
/// violations (for disabled rules the linter cannot tell, so it stays
/// quiet).
pub fn apply_allows(
    file: &str,
    scan: &Scan,
    allows: Vec<Allow>,
    violations: Vec<Violation>,
    cfg: &LintConfig,
) -> (Vec<Violation>, Vec<AllowRecord>) {
    let mut kept: Vec<Violation> = Vec::new();
    let mut suppressed = vec![false; allows.len()];
    // target code line per allow: own line if it holds tokens,
    // otherwise the first code line below the directive
    let targets: Vec<Option<usize>> = allows
        .iter()
        .map(|a| {
            let own = scan.toks.iter().any(|t| t.line == a.line);
            if own {
                Some(a.line)
            } else {
                scan.next_code_line(a.line)
            }
        })
        .collect();
    for v in violations {
        let mut hit = false;
        for (k, a) in allows.iter().enumerate() {
            if a.rule == v.rule && targets[k] == Some(v.line) {
                suppressed[k] = true;
                hit = true;
            }
        }
        if !hit {
            kept.push(v);
        }
    }
    let mut records = Vec::new();
    for (k, a) in allows.into_iter().enumerate() {
        if suppressed[k] {
            records.push(AllowRecord {
                file: file.to_string(),
                line: a.line,
                rule: a.rule,
                reason: a.reason,
            });
        } else if cfg.on(a.rule) && cfg.on(BAD_ALLOW) {
            kept.push(Violation {
                file: file.to_string(),
                line: a.line,
                rule: BAD_ALLOW,
                message: format!(
                    "allow({}) suppresses nothing — delete it or move \
                     it next to the violating line",
                    a.rule
                ),
            });
        }
    }
    (kept, records)
}
