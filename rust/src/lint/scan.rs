//! Token scanner for the lint pass: a lightweight Rust lexer that is
//! exactly strong enough to support token-level rules — comments
//! (line, nested block), string literals (plain, byte, raw with any
//! `#` arity), char-vs-lifetime disambiguation, numeric literals
//! (without swallowing range dots: `0..n` is three tokens, not a
//! float), identifiers, and single-character punctuation. No parse
//! tree: the rule checkers in [`super::checks`] pattern-match short
//! token windows instead, which is what keeps the whole subsystem
//! dependency-free (same vendored-offline discipline as the rest of
//! the workspace).
//!
//! Two source-level facts ride along with the token stream because
//! every rule needs them:
//!
//! - **test regions** — tokens inside a `#[cfg(test)]`-gated item or a
//!   `#[test]` fn are marked, and every rule skips them (tests may
//!   unwrap, time, and iterate hash maps freely);
//! - **directives** — `// xmglint: …` comments, collected with their
//!   line numbers for the allow machinery in [`super::rules`].

/// Token classes. `Str`/`Char` carry no text (their content is
/// irrelevant to every rule — what matters is that the scanner does
/// not lex *inside* them).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Kind {
    Ident,
    Punct,
    Str,
    Char,
    Num,
    Lifetime,
}

#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: Kind,
    pub text: String,
    pub line: usize,
}

impl Tok {
    pub fn is(&self, text: &str) -> bool {
        self.text == text
    }

    pub fn ident(&self, text: &str) -> bool {
        self.kind == Kind::Ident && self.text == text
    }
}

/// A `// xmglint: …` comment: line number plus the directive text
/// after the marker, trimmed.
#[derive(Debug, Clone)]
pub struct Directive {
    pub line: usize,
    pub text: String,
}

/// One scanned source file: token stream, per-token test-region flags,
/// and the lint directives found in comments.
pub struct Scan {
    pub toks: Vec<Tok>,
    pub in_test: Vec<bool>,
    pub directives: Vec<Directive>,
}

impl Scan {
    /// Line number of the first token strictly after `line`, if any.
    /// This is what a standalone directive comment covers: comment
    /// lines produce no tokens, so a directive stacked under further
    /// explanation comments still lands on the code line below.
    pub fn next_code_line(&self, line: usize) -> Option<usize> {
        self.toks
            .iter()
            .map(|t| t.line)
            .filter(|&l| l > line)
            .min()
    }
}

const DIRECTIVE_MARKER: &str = "xmglint:";

pub fn scan(src: &str) -> Scan {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut toks: Vec<Tok> = Vec::new();
    let mut directives: Vec<Directive> = Vec::new();
    let mut i = 0usize;
    let mut line = 1usize;
    while i < n {
        let c = cs[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        // line comment (also doc comments, which start the same way)
        if c == '/' && i + 1 < n && cs[i + 1] == '/' {
            let start = i + 2;
            let mut j = start;
            while j < n && cs[j] != '\n' {
                j += 1;
            }
            // directives live in plain `//` comments only: a doc
            // comment (`///`, `//!`) that *mentions* the syntax is
            // documentation, not an annotation
            let doc = start < n && (cs[start] == '/' || cs[start] == '!');
            if !doc {
                let comment: String = cs[start..j].iter().collect();
                if let Some(pos) = comment.find(DIRECTIVE_MARKER) {
                    let text = comment[pos + DIRECTIVE_MARKER.len()..]
                        .trim()
                        .to_string();
                    directives.push(Directive { line, text });
                }
            }
            i = j;
            continue;
        }
        // block comment, nested
        if c == '/' && i + 1 < n && cs[i + 1] == '*' {
            let mut depth = 1usize;
            i += 2;
            while i < n && depth > 0 {
                if cs[i] == '/' && i + 1 < n && cs[i + 1] == '*' {
                    depth += 1;
                    i += 2;
                } else if cs[i] == '*' && i + 1 < n && cs[i + 1] == '/' {
                    depth -= 1;
                    i += 2;
                } else {
                    if cs[i] == '\n' {
                        line += 1;
                    }
                    i += 1;
                }
            }
            continue;
        }
        // raw strings: r"…", r#"…"#, br"…", br#"…"# (any # arity)
        if c == 'r' || (c == 'b' && i + 1 < n && cs[i + 1] == 'r') {
            let mut j = i + if c == 'b' { 2 } else { 1 };
            let mut hashes = 0usize;
            while j < n && cs[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && cs[j] == '"' {
                j += 1;
                // closes at `"` followed by `hashes` × `#`
                'raw: while j < n {
                    if cs[j] == '\n' {
                        line += 1;
                    } else if cs[j] == '"' {
                        let mut k = 0usize;
                        while k < hashes && j + 1 + k < n
                            && cs[j + 1 + k] == '#'
                        {
                            k += 1;
                        }
                        if k == hashes {
                            j += 1 + hashes;
                            break 'raw;
                        }
                    }
                    j += 1;
                }
                toks.push(Tok {
                    kind: Kind::Str,
                    text: String::new(),
                    line,
                });
                i = j;
                continue;
            }
            // not a raw string — fall through to the ident rule, which
            // will consume `r…`/`b…` as an ordinary identifier
        }
        // plain and byte strings
        if c == '"' || (c == 'b' && i + 1 < n && cs[i + 1] == '"') {
            if c == 'b' {
                i += 1;
            }
            i += 1;
            let start_line = line;
            while i < n {
                if cs[i] == '\\' {
                    i += 2;
                    continue;
                }
                if cs[i] == '\n' {
                    line += 1;
                }
                if cs[i] == '"' {
                    i += 1;
                    break;
                }
                i += 1;
            }
            toks.push(Tok {
                kind: Kind::Str,
                text: String::new(),
                line: start_line,
            });
            continue;
        }
        if c == '\'' {
            // lifetime ('a, 'static) unless it closes as a char ('a')
            let alpha_next = i + 1 < n
                && (cs[i + 1].is_alphabetic() || cs[i + 1] == '_');
            let closes = i + 2 < n && cs[i + 2] == '\'';
            if alpha_next && !closes {
                let mut j = i + 1;
                while j < n && (cs[j].is_alphanumeric() || cs[j] == '_') {
                    j += 1;
                }
                toks.push(Tok {
                    kind: Kind::Lifetime,
                    text: cs[i..j].iter().collect(),
                    line,
                });
                i = j;
                continue;
            }
            // char literal, escapes included
            i += 1;
            while i < n {
                if cs[i] == '\\' {
                    i += 2;
                    continue;
                }
                if cs[i] == '\'' {
                    i += 1;
                    break;
                }
                i += 1;
            }
            toks.push(Tok {
                kind: Kind::Char,
                text: String::new(),
                line,
            });
            continue;
        }
        if c.is_alphabetic() || c == '_' {
            let mut j = i;
            while j < n && (cs[j].is_alphanumeric() || cs[j] == '_') {
                j += 1;
            }
            toks.push(Tok {
                kind: Kind::Ident,
                text: cs[i..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let mut j = i;
            while j < n {
                let ch = cs[j];
                if ch.is_alphanumeric() || ch == '_' {
                    j += 1;
                    continue;
                }
                // `1.5` continues the number; `0..n` does not (the dot
                // must be followed by a digit to be a decimal point)
                if ch == '.' && j + 1 < n && cs[j + 1].is_ascii_digit() {
                    j += 1;
                    continue;
                }
                // exponent sign: 1e-5, 2.5E+3
                if (ch == '+' || ch == '-')
                    && j > i
                    && (cs[j - 1] == 'e' || cs[j - 1] == 'E')
                {
                    j += 1;
                    continue;
                }
                break;
            }
            toks.push(Tok {
                kind: Kind::Num,
                text: cs[i..j].iter().collect(),
                line,
            });
            i = j;
            continue;
        }
        toks.push(Tok {
            kind: Kind::Punct,
            text: c.to_string(),
            line,
        });
        i += 1;
    }
    let in_test = mark_tests(&toks);
    Scan { toks, in_test, directives }
}

/// Mark every token inside a `#[cfg(test)]`-gated item or a `#[test]`
/// fn: find attributes containing the ident `test`, then extend the
/// region over any further attributes and through the attributed
/// item's `{…}` body (brace-matched) or to its terminating `;`.
fn mark_tests(toks: &[Tok]) -> Vec<bool> {
    let mut in_test = vec![false; toks.len()];
    let mut i = 0usize;
    while i < toks.len() {
        let attr_start = toks[i].is("#")
            && i + 1 < toks.len()
            && toks[i + 1].is("[");
        if !attr_start {
            i += 1;
            continue;
        }
        // scan the attribute group for the ident `test`
        let mut j = i + 2;
        let mut depth = 1usize;
        let mut has_test = false;
        while j < toks.len() && depth > 0 {
            let t = &toks[j];
            if t.is("[") {
                depth += 1;
            } else if t.is("]") {
                depth -= 1;
            } else if t.ident("test") {
                has_test = true;
            }
            j += 1;
        }
        if !has_test {
            i = j;
            continue;
        }
        // skip any further attributes on the same item
        let mut k = j;
        while k + 1 < toks.len() && toks[k].is("#") && toks[k + 1].is("[")
        {
            let mut d = 1usize;
            k += 2;
            while k < toks.len() && d > 0 {
                if toks[k].is("[") {
                    d += 1;
                } else if toks[k].is("]") {
                    d -= 1;
                }
                k += 1;
            }
        }
        // item body: brace-matched block, or a `;`-terminated item
        let mut m = k;
        while m < toks.len() && !toks[m].is("{") && !toks[m].is(";") {
            m += 1;
        }
        let end = if m < toks.len() && toks[m].is("{") {
            let mut d = 1usize;
            let mut e = m + 1;
            while e < toks.len() && d > 0 {
                if toks[e].is("{") {
                    d += 1;
                } else if toks[e].is("}") {
                    d -= 1;
                }
                e += 1;
            }
            e
        } else {
            (m + 1).min(toks.len())
        };
        for flag in in_test.iter_mut().take(end).skip(i) {
            *flag = true;
        }
        i = end;
    }
    in_test
}
