//! The rule checkers: each one pattern-matches short windows of the
//! token stream from [`super::scan`] against the invariant it guards.
//! Test-region tokens are skipped everywhere — the invariants bind the
//! engine, not its tests.
//!
//! Path scoping is part of each rule (a wall-clock read is fine in the
//! bench harness, fatal in a kernel), so checkers receive the file's
//! path relative to the crate's `src/` root with `/` separators.

use super::rules::LintConfig;
use super::scan::{Kind, Scan, Tok};
use super::Violation;

/// Determinism-critical module roots: everything the bitwise
/// `--threads`-invariance contract covers.
const DET_DIRS: [&str; 4] =
    ["env/", "benchgen/", "coordinator/", "nn/"];

/// Files sanctioned to read the wall clock: the bench harness, the
/// metrics sink (via `WallTimer`), and the CLI binary.
const WALLCLOCK_ALLOWED: [&str; 3] =
    ["util/bench.rs", "coordinator/metrics.rs", "main.rs"];

/// Supervised worker / channel paths: a panic here defeats the
/// catch_unwind + respawn recovery machinery. The whole service tier
/// (`server/`, see [`in_worker_path`]) is scoped in too — a session
/// thread's panic must surface as a structured Error frame, never an
/// unwrap-abort that skips the teardown protocol.
const WORKER_FILES: [&str; 5] = [
    "coordinator/shard.rs",
    "coordinator/workers.rs",
    "coordinator/rollout.rs",
    "coordinator/trainer.rs",
    "coordinator/native_trainer.rs",
];

/// Is `rel` in the no-unwrap supervised scope? The coordinator list is
/// exact files; the serve tier is a whole-directory prefix so new
/// server modules are covered by default.
fn in_worker_path(rel: &str) -> bool {
    WORKER_FILES.contains(&rel) || rel.starts_with("server/")
}

/// Identifiers that mean "randomness not derived from the config
/// seed": the rand-crate entry points and OS entropy.
const RNG_BANNED: [&str; 7] = [
    "thread_rng",
    "from_entropy",
    "OsRng",
    "StdRng",
    "SmallRng",
    "getrandom",
    "rand",
];

/// Randomized-hasher types (the PR 3 DefaultHasher collision bug
/// class) — banned outright in determinism-critical modules.
const HASH_RANDOM: [&str; 2] = ["DefaultHasher", "RandomState"];

/// Methods that iterate a hash container in hasher order.
const HASH_ITER_METHODS: [&str; 8] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "into_iter",
    "drain",
    "retain",
];

/// Fallible engine ops whose `Result` must never be discarded. Only
/// names that return `Result` on *every* stepping/coordination surface
/// belong here — a token scanner cannot resolve receiver types, so an
/// ambiguous name (e.g. `step_all`, `Result` on `ParVecEnv` but `()`
/// on `VecEnv`) would false-positive. The compiler-native
/// `unused_must_use` deny in `[workspace.lints]` covers the rest.
const MUST_USE_METHODS: [&str; 9] = [
    "submit",
    "broadcast",
    "respawn",
    "wait",
    "rollout",
    "train_iter",
    "resample_tasks",
    "save",
    "finish",
];

/// Statement heads that exempt a `…;` run from the must-use check:
/// bindings, control flow, items, and the assert/log macros.
const STMT_HEADS: [&str; 27] = [
    "let", "return", "break", "continue", "if", "match", "while",
    "for", "loop", "else", "fn", "pub", "use", "mod", "impl",
    "struct", "enum", "trait", "const", "static", "type", "unsafe",
    "where", "assert", "assert_eq", "assert_ne", "panic",
];

/// Macro-call heads likewise exempt (side-effecting by design).
const STMT_MACRO_HEADS: [&str; 7] = [
    "println", "eprintln", "print", "eprint", "write", "writeln",
    "debug_assert",
];

fn in_det_dir(rel: &str) -> bool {
    DET_DIRS.iter().any(|d| rel.starts_with(d))
}

/// Run every enabled rule over one scanned file. `rel` is the path
/// relative to `src/`.
pub fn check(rel: &str, scan: &Scan, cfg: &LintConfig) -> Vec<Violation> {
    let mut out = Vec::new();
    {
        let mut viol = |line: usize, rule: &'static str, msg: String| {
            out.push(Violation {
                file: rel.to_string(),
                line,
                rule,
                message: msg,
            });
        };
        let toks = &scan.toks;
        let live = |k: usize| !scan.in_test[k];

        if cfg.on("no-std-rng") && in_det_dir(rel) {
            for (k, t) in toks.iter().enumerate() {
                if !live(k) || t.kind != Kind::Ident {
                    continue;
                }
                if RNG_BANNED.contains(&t.text.as_str()) {
                    viol(
                        t.line,
                        "no-std-rng",
                        format!(
                            "`{}` — derive randomness from the config \
                             seed via util::rng::Rng / stream_seed",
                            t.text
                        ),
                    );
                }
            }
        }

        if cfg.on("no-hash-iter") && in_det_dir(rel) {
            check_hash_iter(rel, scan, &mut viol);
        }

        if cfg.on("no-wallclock-in-kernels")
            && !WALLCLOCK_ALLOWED.contains(&rel)
        {
            for (k, t) in toks.iter().enumerate() {
                if !live(k) || t.kind != Kind::Ident {
                    continue;
                }
                let instant_now = t.text == "Instant"
                    && matches_seq(toks, k + 1, &[":", ":", "now"]);
                if instant_now {
                    viol(
                        t.line,
                        "no-wallclock-in-kernels",
                        "`Instant::now` — time through \
                         coordinator::metrics::WallTimer or move the \
                         measurement into util/bench.rs"
                            .to_string(),
                    );
                } else if t.text == "SystemTime" || t.text == "UNIX_EPOCH"
                {
                    viol(
                        t.line,
                        "no-wallclock-in-kernels",
                        format!("`{}` — wall-clock reads are confined \
                                 to the bench/CLI surface", t.text),
                    );
                }
            }
        }

        if cfg.on("no-unwrap-in-workers") && in_worker_path(rel) {
            for (k, t) in toks.iter().enumerate() {
                if !live(k) || t.kind != Kind::Ident {
                    continue;
                }
                if (t.text == "unwrap" || t.text == "expect")
                    && k > 0
                    && toks[k - 1].is(".")
                    && k + 1 < toks.len()
                    && toks[k + 1].is("(")
                {
                    viol(
                        t.line,
                        "no-unwrap-in-workers",
                        format!(
                            ".{}() in a supervised worker path — \
                             return the error so recovery can replay \
                             the chunk",
                            t.text
                        ),
                    );
                }
            }
        }

        if cfg.on("float-reduction-order")
            && (rel.starts_with("coordinator/")
                || rel.starts_with("nn/"))
        {
            check_float_reduction(scan, &mut viol);
        }

        if cfg.on("must-use-result") {
            check_must_use(scan, &mut viol);
        }
    }
    out
}

/// `toks[at..]` equals the given texts, in order.
fn matches_seq(toks: &[Tok], at: usize, texts: &[&str]) -> bool {
    texts
        .iter()
        .enumerate()
        .all(|(j, s)| at + j < toks.len() && toks[at + j].is(s))
}

/// no-hash-iter: flag randomized hashers outright, then track
/// `let`-bindings whose initializer mentions HashMap/HashSet and flag
/// hasher-order iteration over those bindings (`name.iter()` et al.,
/// `for x in [&[mut]] name {`). Sorted iteration (collect + sort, or
/// BTreeMap) never trips this.
fn check_hash_iter<F>(rel: &str, scan: &Scan, viol: &mut F)
where
    F: FnMut(usize, &'static str, String),
{
    let toks = &scan.toks;
    for (k, t) in toks.iter().enumerate() {
        if scan.in_test[k] || t.kind != Kind::Ident {
            continue;
        }
        if HASH_RANDOM.contains(&t.text.as_str()) {
            viol(
                t.line,
                "no-hash-iter",
                format!(
                    "`{}` is seeded per-process — use a deterministic \
                     key order (BTreeMap, or collect + sort)",
                    t.text
                ),
            );
        }
    }
    // pass 1: hash-typed let bindings (scan to `;`/`=`-statement end
    // at bracket depth 0, recording whether HashMap/HashSet occurs)
    let mut hashy: Vec<String> = Vec::new();
    let mut k = 0usize;
    while k < toks.len() {
        if scan.in_test[k] {
            k += 1;
            continue;
        }
        if toks[k].ident("let") {
            let mut j = k + 1;
            if j < toks.len() && toks[j].ident("mut") {
                j += 1;
            }
            if j < toks.len() && toks[j].kind == Kind::Ident {
                let name = toks[j].text.clone();
                let mut depth = 0usize;
                let mut hash_init = false;
                let mut e = j + 1;
                while e < toks.len() {
                    let tt = &toks[e];
                    if tt.is("(") || tt.is("[") || tt.is("{") {
                        depth += 1;
                    } else if tt.is(")") || tt.is("]") || tt.is("}") {
                        if depth == 0 {
                            break;
                        }
                        depth -= 1;
                    } else if tt.is(";") && depth == 0 {
                        break;
                    } else if tt.kind == Kind::Ident
                        && (tt.text == "HashMap" || tt.text == "HashSet")
                    {
                        hash_init = true;
                    }
                    e += 1;
                }
                if hash_init && !hashy.contains(&name) {
                    hashy.push(name);
                }
                k = e;
                continue;
            }
        }
        k += 1;
    }
    // pass 2: iteration over those bindings
    for (k, t) in toks.iter().enumerate() {
        if scan.in_test[k] || t.kind != Kind::Ident {
            continue;
        }
        if !hashy.contains(&t.text) {
            continue;
        }
        // name.iter() / name.drain() / …
        if k + 3 < toks.len()
            && toks[k + 1].is(".")
            && toks[k + 2].kind == Kind::Ident
            && HASH_ITER_METHODS.contains(&toks[k + 2].text.as_str())
            && toks[k + 3].is("(")
        {
            viol(
                t.line,
                "no-hash-iter",
                format!(
                    "{}.{}() iterates in hasher order in {rel} — \
                     collect + sort, or use a BTreeMap",
                    t.text, toks[k + 2].text
                ),
            );
        }
        // for x in [&[mut]] name {
        if k >= 1 && k + 1 < toks.len() && toks[k + 1].is("{") {
            let mut b = k as isize - 1;
            while b >= 0
                && (toks[b as usize].is("&")
                    || toks[b as usize].ident("mut"))
            {
                b -= 1;
            }
            if b >= 0 && toks[b as usize].ident("in") {
                viol(
                    t.line,
                    "no-hash-iter",
                    format!(
                        "`for _ in {}` iterates in hasher order — \
                         collect + sort, or use a BTreeMap",
                        t.text
                    ),
                );
            }
        }
    }
}

/// float-reduction-order: `.sum::<f32>()`, `fold(0.0f32, …)`-style
/// folds with an f32-suffixed init, and rayon parallel iteration — all
/// order-sensitive float reductions the fixed-order f64 contract
/// (ascending env-major, shard 0 accumulator) exists to forbid.
fn check_float_reduction<F>(scan: &Scan, viol: &mut F)
where
    F: FnMut(usize, &'static str, String),
{
    let toks = &scan.toks;
    for (k, t) in toks.iter().enumerate() {
        if scan.in_test[k] || t.kind != Kind::Ident {
            continue;
        }
        if t.text == "sum"
            && matches_seq(toks, k + 1, &[":", ":", "<", "f32"])
        {
            viol(
                t.line,
                "float-reduction-order",
                ".sum::<f32>() — accumulate in f64, in a fixed order"
                    .to_string(),
            );
        }
        if t.text == "fold"
            && k + 2 < toks.len()
            && toks[k + 1].is("(")
            && toks[k + 2].kind == Kind::Num
            && toks[k + 2].text.ends_with("f32")
        {
            viol(
                t.line,
                "float-reduction-order",
                "fold with an f32 accumulator — use f64 and a fixed \
                 reduction order"
                    .to_string(),
            );
        }
        if t.text == "par_iter"
            || t.text == "par_iter_mut"
            || t.text == "rayon"
        {
            viol(
                t.line,
                "float-reduction-order",
                format!(
                    "`{}` — unordered parallel reduction breaks the \
                     bitwise --threads contract",
                    t.text
                ),
            );
        }
    }
}

/// must-use-result: a `;`-terminated statement whose head is a plain
/// identifier (not a binding/control-flow/macro head), which calls one
/// of [`MUST_USE_METHODS`] and contains no `?`, discards a `Result`.
/// Tail expressions (runs ending at `}`) return their value and are
/// exempt by construction.
fn check_must_use<F>(scan: &Scan, viol: &mut F)
where
    F: FnMut(usize, &'static str, String),
{
    let toks = &scan.toks;
    let mut start = 0usize;
    for k in 0..toks.len() {
        let t = &toks[k];
        let boundary = t.kind == Kind::Punct
            && (t.is("{") || t.is("}") || t.is(";"));
        if !boundary {
            continue;
        }
        let run = &toks[start..k];
        if t.is(";") && !run.is_empty() && !scan.in_test[start] {
            let head = &run[0];
            // any macro statement (`name!(…)`) is side-effecting by
            // design — bail!/ensure!/log macros — and exempt
            let is_macro =
                run.len() > 1 && run[1].is("!");
            let head_exempt = head.kind != Kind::Ident
                || is_macro
                || STMT_HEADS.contains(&head.text.as_str())
                || STMT_MACRO_HEADS.contains(&head.text.as_str());
            if !head_exempt {
                let has_try = run.iter().any(|x| x.is("?"));
                let mut called: Option<&str> = None;
                for m in 0..run.len().saturating_sub(2) {
                    if run[m].is(".")
                        && run[m + 1].kind == Kind::Ident
                        && MUST_USE_METHODS
                            .contains(&run[m + 1].text.as_str())
                        && run[m + 2].is("(")
                    {
                        called = Some(&run[m + 1].text);
                    }
                }
                if let Some(name) = called {
                    if !has_try {
                        viol(
                            head.line,
                            "must-use-result",
                            format!(
                                "Result of .{name}() is discarded — \
                                 `?` it or handle the error"
                            ),
                        );
                    }
                }
            }
        }
        start = k + 1;
    }
}
