//! xoshiro256++ PRNG (the offline environment has no `rand` crate).
//!
//! Not cryptographic; used for layout/door randomization, benchmark
//! generation and action sampling in random-policy benches. Streams are
//! split with splitmix64, mirroring the "key" discipline of the JAX side.

#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Seed of the `k`-th decorrelated stream derived from `seed`: stream 0
/// keeps the seed itself; higher streams are spread by a golden-ratio
/// multiple, which [`Rng::new`]'s splitmix init diffuses into an
/// independent sequence. The single definition behind both the shard
/// engine's `shard_seed` and the benchmark generator's per-attempt
/// streams — the mapping depends only on `(seed, k)`, never on
/// scheduling or thread count.
pub fn stream_seed(seed: u64, k: u64) -> u64 {
    seed ^ 0x9E37_79B9_7F4A_7C15u64.wrapping_mul(k)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut x = seed;
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = splitmix64(&mut x);
        }
        Rng { s }
    }

    /// The `k`-th decorrelated stream derived from `seed` — see
    /// [`stream_seed`]. Used for both the engine's per-shard streams
    /// and the benchmark generator's per-attempt streams.
    pub fn stream(seed: u64, k: u64) -> Rng {
        Rng::new(stream_seed(seed, k))
    }

    /// The raw xoshiro256++ state. Two streams with equal state are
    /// bitwise-identical forever — the equivalence tests use this to
    /// assert that parallel and serial engines leave every per-env
    /// stream in exactly the same position.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a stream from a captured [`Rng::state`]. The inverse of
    /// `state()`: the restored stream continues bitwise-identically from
    /// the capture point. Used by snapshot/restore (worker recovery) and
    /// training checkpoints.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Derive an independent stream (JAX `random.split` analogue).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64() ^ 0xA5A5_5A5A_DEAD_BEEF)
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform integer in `[0, n)` (modulo bias negligible at n << 2^64).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform f64 in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli draw.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Partial Fisher-Yates: after the call, `pool[..k]` holds `k`
    /// distinct uniformly-sampled elements. Returns the clamped `k`.
    ///
    /// In-place, allocation-free form of [`Rng::sample_distinct`] — the
    /// RNG call sequence (`below(len)`, `below(len-1)`, ...) is shared
    /// between both and is part of the scalar/vectorized equivalence
    /// contract of `env::vector`.
    pub fn partial_shuffle<T>(&mut self, pool: &mut [T], k: usize) -> usize {
        let k = k.min(pool.len());
        for i in 0..k {
            let j = i + self.below(pool.len() - i);
            pool.swap(i, j);
        }
        k
    }

    /// Sample `k` distinct elements from `items` (partial Fisher-Yates).
    pub fn sample_distinct<T: Copy>(&mut self, items: &[T], k: usize) -> Vec<T> {
        let mut pool: Vec<T> = items.to_vec();
        let k = self.partial_shuffle(&mut pool, k);
        pool.truncate(k);
        pool
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            let j = self.below(i + 1);
            items.swap(i, j);
        }
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.below(items.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut a = Rng::new(7);
        let mut b = a.split();
        let xs: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let ys: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xs, ys);
    }

    #[test]
    fn from_state_resumes_stream() {
        let mut a = Rng::new(13);
        for _ in 0..5 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn sample_distinct_no_dups() {
        let mut r = Rng::new(11);
        let items: Vec<usize> = (0..50).collect();
        let s = r.sample_distinct(&items, 20);
        assert_eq!(s.len(), 20);
        let mut t = s.clone();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 20);
    }

    #[test]
    fn partial_shuffle_matches_sample_distinct() {
        // same seed -> identical RNG call sequence -> identical prefix
        let items: Vec<usize> = (0..30).collect();
        let sampled = Rng::new(21).sample_distinct(&items, 12);
        let mut pool = items.clone();
        let k = Rng::new(21).partial_shuffle(&mut pool, 12);
        assert_eq!(k, 12);
        assert_eq!(&pool[..12], &sampled[..]);
    }

    #[test]
    fn uniformity_rough() {
        let mut r = Rng::new(5);
        let mut counts = [0usize; 8];
        for _ in 0..80_000 {
            counts[r.below(8)] += 1;
        }
        for c in counts {
            assert!((8_000..12_000).contains(&c), "count {c}");
        }
    }
}
