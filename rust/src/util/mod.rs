//! Self-contained utilities replacing crates that are unavailable offline
//! (rand, clap, criterion, proptest, serde_json).

pub mod args;
pub mod bench;
pub mod fault;
pub mod rng;
pub mod stats;

/// Lightweight property-test driver: runs `f` against `n` seeded RNGs and
/// reports the failing seed, so failures reproduce deterministically.
pub fn property_test<F: Fn(&mut rng::Rng)>(name: &str, n: u64, f: F) {
    for seed in 0..n {
        let mut rng = rng::Rng::new(0xC0FFEE ^ seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
            || f(&mut rng),
        ));
        if let Err(e) = result {
            eprintln!("property `{name}` failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}
