//! Deterministic fault-injection harness.
//!
//! A [`FaultPlan`] is a small parsed schedule of failures that the worker
//! pools and the checkpoint writer consult at well-defined sites. It lets
//! the fault-tolerance tests (and CI) *prove* the recovery paths — a
//! worker panic at a named step, a torn checkpoint at a named iteration —
//! instead of hoping they work.
//!
//! Grammar (`;`-separated entries, parsed from the `XMG_FAULTS` env var
//! or an explicit string):
//!
//! ```text
//! panic@worker=W,step=S[,count=N|*]     chunk worker W panics when it
//!                                       executes global step index S
//! panic@shard=K,round=R[,count=N|*]     shard worker K panics in
//!                                       collection round R
//! torn-checkpoint@iter=I                checkpoint at iteration I is
//!                                       written torn (truncated, at the
//!                                       final path) instead of atomically
//! drop-conn@session=S,req=R[,count=N|*] the serve tier hard-drops
//!                                       session S's socket when it is
//!                                       about to serve request R (the
//!                                       kill-9 shape)
//! stall@session=S,ms=M[,count=N|*]      session S's worker sleeps M ms
//!                                       before serving a request (trips
//!                                       client deadlines)
//! torn-frame@session=S[,count=N|*]      session S's next reply is
//!                                       written half-length, then the
//!                                       stream is cut
//! ```
//!
//! Every entry carries a *consumption budget* (default 1): once it has
//! fired `count` times it goes inert. One-shot semantics are what make
//! recovery testable — the supervisor's deterministic replay of the same
//! step must NOT re-trigger the same fault, while `count=*` (infinite)
//! expresses "this worker is permanently broken" for retries-exhausted
//! tests.
//!
//! Matching is by deterministic coordinates (worker id + global step
//! index, shard id + round, iteration) so a plan fires at the same
//! logical point for any thread count and any interleaving.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

/// Environment variable holding the fault plan for CLI runs.
pub const FAULTS_ENV: &str = "XMG_FAULTS";

const INFINITE: u64 = u64::MAX;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Site {
    /// `panic@worker=W,step=S` — chunk worker W at global env-step S.
    ChunkStep { worker: usize, step: u64 },
    /// `panic@shard=K,round=R` — shard worker K at collection round R.
    ShardRound { shard: usize, round: u64 },
    /// `torn-checkpoint@iter=I` — checkpoint write at iteration I.
    TornCheckpoint { iter: u64 },
    /// `drop-conn@session=S,req=R` — serve tier drops session S's
    /// socket at request R.
    ServerDropConn { session: u64, req: u64 },
    /// `stall@session=S,ms=M` — session S's worker sleeps M ms before
    /// serving a request.
    ServerStall { session: u64, ms: u64 },
    /// `torn-frame@session=S` — session S's next reply is truncated.
    ServerTornFrame { session: u64 },
}

#[derive(Debug)]
struct Entry {
    site: Site,
    /// Remaining firings; decremented atomically so concurrent workers
    /// racing on the same entry consume it exactly `count` times.
    remaining: AtomicU64,
}

/// A parsed, consumable schedule of injected failures. Shared across
/// worker threads behind an `Arc`; an empty plan is free to consult.
#[derive(Debug, Default)]
pub struct FaultPlan {
    entries: Vec<Entry>,
}

impl FaultPlan {
    /// The empty plan: nothing ever fires.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Parse a plan string (the `XMG_FAULTS` grammar above).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut entries = Vec::new();
        for raw in spec.split(';') {
            let part = raw.trim();
            if part.is_empty() {
                continue;
            }
            entries.push(
                parse_entry(part)
                    .with_context(|| format!("fault entry `{part}`"))?,
            );
        }
        Ok(FaultPlan { entries })
    }

    /// Read the plan from `XMG_FAULTS`; unset or empty means no faults.
    /// A malformed value is an error (silently ignoring a typo'd fault
    /// plan would make a failing injection test look like a pass).
    pub fn from_env() -> Result<FaultPlan> {
        match std::env::var(FAULTS_ENV) {
            Ok(spec) if !spec.trim().is_empty() => FaultPlan::parse(&spec)
                .with_context(|| format!("parsing ${FAULTS_ENV}")),
            _ => Ok(FaultPlan::none()),
        }
    }

    /// Should chunk worker `worker` panic while executing global step
    /// index `step`? Consumes one firing on a hit.
    pub fn chunk_step_panic(&self, worker: usize, step: u64) -> bool {
        self.fire(Site::ChunkStep { worker, step })
    }

    /// Should shard worker `shard` panic in collection round `round`?
    pub fn shard_round_panic(&self, shard: usize, round: u64) -> bool {
        self.fire(Site::ShardRound { shard, round })
    }

    /// Should the checkpoint at iteration `iter` be written torn?
    pub fn torn_checkpoint(&self, iter: u64) -> bool {
        self.fire(Site::TornCheckpoint { iter })
    }

    /// Should the serve tier hard-drop `session`'s socket at request
    /// `req` (the kill-9 shape)? Consumes one firing on a hit.
    pub fn server_drop_conn(&self, session: u64, req: u64) -> bool {
        self.fire(Site::ServerDropConn { session, req })
    }

    /// Milliseconds `session`'s worker should stall before serving its
    /// next request, if a matching entry has budget left.
    pub fn server_stall_ms(&self, session: u64) -> Option<u64> {
        for e in &self.entries {
            if let Site::ServerStall { session: s, ms } = e.site {
                if s == session && consume(e) {
                    return Some(ms);
                }
            }
        }
        None
    }

    /// Should `session`'s next reply frame be written torn (truncated,
    /// then the stream cut)?
    pub fn server_torn_frame(&self, session: u64) -> bool {
        self.fire(Site::ServerTornFrame { session })
    }

    fn fire(&self, site: Site) -> bool {
        for e in &self.entries {
            if e.site == site && consume(e) {
                return true;
            }
        }
        false
    }
}

/// Decrement-if-positive on the entry's budget; INFINITE never
/// decrements. Atomic so concurrent workers racing on the same entry
/// consume it exactly `count` times.
fn consume(e: &Entry) -> bool {
    loop {
        let cur = e.remaining.load(Ordering::Relaxed);
        if cur == 0 {
            return false;
        }
        if cur == INFINITE {
            return true;
        }
        if e.remaining
            .compare_exchange(cur, cur - 1, Ordering::Relaxed,
                              Ordering::Relaxed)
            .is_ok()
        {
            return true;
        }
    }
}

fn parse_entry(part: &str) -> Result<Entry> {
    let (kind, rest) = part
        .split_once('@')
        .context("expected `<kind>@<key>=<val>,...`")?;
    let mut keys: Vec<(&str, &str)> = Vec::new();
    let mut count = 1u64;
    for kv in rest.split(',') {
        let (k, v) = kv
            .trim()
            .split_once('=')
            .with_context(|| format!("expected `key=value`, got `{kv}`"))?;
        let (k, v) = (k.trim(), v.trim());
        if k == "count" {
            count = if v == "*" {
                INFINITE
            } else {
                parse_u64(v).context("count")?
            };
        } else {
            keys.push((k, v));
        }
    }
    keys.sort_by_key(|&(k, _)| k);
    let site = match kind.trim() {
        "panic" => match keys.as_slice() {
            [("step", s), ("worker", w)] => Site::ChunkStep {
                worker: parse_u64(w).context("worker")? as usize,
                step: parse_u64(s).context("step")?,
            },
            [("round", r), ("shard", k)] => Site::ShardRound {
                shard: parse_u64(k).context("shard")? as usize,
                round: parse_u64(r).context("round")?,
            },
            _ => bail!(
                "panic@ needs `worker=W,step=S` or `shard=K,round=R`"
            ),
        },
        "torn-checkpoint" => match keys.as_slice() {
            [("iter", i)] => Site::TornCheckpoint {
                iter: parse_u64(i).context("iter")?,
            },
            _ => bail!("torn-checkpoint@ needs `iter=I`"),
        },
        "drop-conn" => match keys.as_slice() {
            [("req", r), ("session", s)] => Site::ServerDropConn {
                session: parse_u64(s).context("session")?,
                req: parse_u64(r).context("req")?,
            },
            _ => bail!("drop-conn@ needs `session=S,req=R`"),
        },
        "stall" => match keys.as_slice() {
            [("ms", m), ("session", s)] => Site::ServerStall {
                session: parse_u64(s).context("session")?,
                ms: parse_u64(m).context("ms")?,
            },
            _ => bail!("stall@ needs `session=S,ms=M`"),
        },
        "torn-frame" => match keys.as_slice() {
            [("session", s)] => Site::ServerTornFrame {
                session: parse_u64(s).context("session")?,
            },
            _ => bail!("torn-frame@ needs `session=S`"),
        },
        other => bail!(
            "unknown fault kind `{other}` (expected `panic`, \
             `torn-checkpoint`, `drop-conn`, `stall`, or `torn-frame`)"
        ),
    };
    if count == 0 {
        bail!("count=0 would never fire");
    }
    Ok(Entry { site, remaining: AtomicU64::new(count) })
}

fn parse_u64(v: &str) -> Result<u64> {
    v.parse::<u64>()
        .with_context(|| format!("`{v}` is not a non-negative integer"))
}

/// Bounded retry-with-backoff policy for supervised worker recovery.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Respawn attempts per failed job before giving up (0 = fail on
    /// the first worker death).
    pub max_retries: u32,
    /// Sleep before the k-th respawn: `backoff_ms * k` (linear).
    pub backoff_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 2, backoff_ms: 50 }
    }
}

/// Ceiling on a single backoff sleep. Linear backoff with a huge
/// `backoff_ms` (or many attempts) must degrade to a bounded wait, not
/// an effectively-infinite sleep that looks like a hung worker.
pub const MAX_BACKOFF_MS: u64 = 60_000;

impl RetryPolicy {
    /// The backoff for the `attempt`-th retry (1-based):
    /// `min(backoff_ms * attempt, MAX_BACKOFF_MS)`, overflow-safe.
    /// Attempt 0 (no retry yet) is always 0.
    pub fn backoff_for(&self, attempt: u32) -> u64 {
        self.backoff_ms
            .saturating_mul(attempt as u64)
            .min(MAX_BACKOFF_MS)
    }

    /// Sleep for the `attempt`-th retry (1-based). No-op at 0 backoff.
    pub fn sleep(&self, attempt: u32) {
        let ms = self.backoff_for(attempt);
        if ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_worker_step_panic() {
        let p = FaultPlan::parse("panic@worker=2,step=17").unwrap();
        assert!(!p.is_empty());
        assert!(!p.chunk_step_panic(1, 17));
        assert!(!p.chunk_step_panic(2, 16));
        assert!(p.chunk_step_panic(2, 17));
        // one-shot: a deterministic replay of the same step is clean
        assert!(!p.chunk_step_panic(2, 17));
    }

    #[test]
    fn parses_multi_entry_and_shard_round() {
        let p = FaultPlan::parse(
            "panic@worker=0,step=3; panic@shard=1,round=2;\
             torn-checkpoint@iter=4",
        )
        .unwrap();
        assert!(p.chunk_step_panic(0, 3));
        assert!(p.shard_round_panic(1, 2));
        assert!(!p.shard_round_panic(1, 3));
        assert!(p.torn_checkpoint(4));
        assert!(!p.torn_checkpoint(4));
    }

    #[test]
    fn count_budget_and_infinite() {
        let p = FaultPlan::parse("panic@worker=1,step=5,count=2").unwrap();
        assert!(p.chunk_step_panic(1, 5));
        assert!(p.chunk_step_panic(1, 5));
        assert!(!p.chunk_step_panic(1, 5));

        let q = FaultPlan::parse("panic@worker=1,step=5,count=*").unwrap();
        for _ in 0..10 {
            assert!(q.chunk_step_panic(1, 5));
        }
    }

    #[test]
    fn rejects_malformed_entries() {
        for bad in [
            "panic@worker=2",
            "panic@step=17,worker=2,extra=1",
            "explode@worker=1,step=2",
            "torn-checkpoint@step=3",
            "panic@worker=x,step=1",
            "panic@worker=1,step=2,count=0",
            "panic",
            "drop-conn@session=1",
            "drop-conn@req=2",
            "stall@session=1",
            "stall@ms=10",
            "torn-frame@req=1",
            "torn-frame@session=x",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted `{bad}`");
        }
    }

    #[test]
    fn server_faults_parse_and_consume() {
        let p = FaultPlan::parse(
            "drop-conn@session=1,req=3; stall@session=0,ms=250;\
             torn-frame@session=2,count=2",
        )
        .unwrap();
        // wrong coordinates never fire
        assert!(!p.server_drop_conn(0, 3));
        assert!(!p.server_drop_conn(1, 2));
        assert!(p.server_drop_conn(1, 3));
        assert!(!p.server_drop_conn(1, 3), "one-shot budget");

        assert_eq!(p.server_stall_ms(1), None);
        assert_eq!(p.server_stall_ms(0), Some(250));
        assert_eq!(p.server_stall_ms(0), None, "budget consumed");

        assert!(p.server_torn_frame(2));
        assert!(p.server_torn_frame(2));
        assert!(!p.server_torn_frame(2), "count=2 exhausted");
        assert!(!p.server_torn_frame(1));
    }

    // --- RetryPolicy edges (the PR 10 hardening satellite) -----------

    #[test]
    fn retry_zero_retries_means_no_backoff_path() {
        // max_retries=0 -> run_op bails before any sleep; the policy
        // itself must still be well-defined for attempt 0 and 1.
        let p = RetryPolicy { max_retries: 0, backoff_ms: 50 };
        assert_eq!(p.backoff_for(0), 0);
        assert_eq!(p.backoff_for(1), 50);
    }

    #[test]
    fn retry_backoff_overflow_saturates_to_cap() {
        // backoff_ms near u64::MAX must neither overflow nor sleep
        // "forever": the product saturates, then the cap clamps it.
        let p = RetryPolicy { max_retries: 2, backoff_ms: u64::MAX };
        assert_eq!(p.backoff_for(1), MAX_BACKOFF_MS);
        assert_eq!(p.backoff_for(u32::MAX), MAX_BACKOFF_MS);
        // ...and a sane config is untouched by the cap
        let q = RetryPolicy { max_retries: 2, backoff_ms: 50 };
        assert_eq!(q.backoff_for(3), 150);
    }

    #[test]
    fn retry_no_sleep_configured_is_truly_free() {
        // backoff_ms=0: every attempt's backoff is 0, so sleep() is a
        // no-op — pinned so a future refactor can't introduce a
        // minimum sleep.
        let p = RetryPolicy { max_retries: 3, backoff_ms: 0 };
        for attempt in 0..5 {
            assert_eq!(p.backoff_for(attempt), 0);
        }
        p.sleep(4); // must return immediately, not panic
    }

    #[test]
    fn empty_plan_never_fires() {
        let p = FaultPlan::none();
        assert!(p.is_empty());
        assert!(!p.chunk_step_panic(0, 0));
        assert!(!p.shard_round_panic(0, 0));
        assert!(!p.torn_checkpoint(0));
    }
}
