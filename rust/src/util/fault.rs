//! Deterministic fault-injection harness.
//!
//! A [`FaultPlan`] is a small parsed schedule of failures that the worker
//! pools and the checkpoint writer consult at well-defined sites. It lets
//! the fault-tolerance tests (and CI) *prove* the recovery paths — a
//! worker panic at a named step, a torn checkpoint at a named iteration —
//! instead of hoping they work.
//!
//! Grammar (`;`-separated entries, parsed from the `XMG_FAULTS` env var
//! or an explicit string):
//!
//! ```text
//! panic@worker=W,step=S[,count=N|*]     chunk worker W panics when it
//!                                       executes global step index S
//! panic@shard=K,round=R[,count=N|*]     shard worker K panics in
//!                                       collection round R
//! torn-checkpoint@iter=I                checkpoint at iteration I is
//!                                       written torn (truncated, at the
//!                                       final path) instead of atomically
//! ```
//!
//! Every entry carries a *consumption budget* (default 1): once it has
//! fired `count` times it goes inert. One-shot semantics are what make
//! recovery testable — the supervisor's deterministic replay of the same
//! step must NOT re-trigger the same fault, while `count=*` (infinite)
//! expresses "this worker is permanently broken" for retries-exhausted
//! tests.
//!
//! Matching is by deterministic coordinates (worker id + global step
//! index, shard id + round, iteration) so a plan fires at the same
//! logical point for any thread count and any interleaving.

use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Context, Result};

/// Environment variable holding the fault plan for CLI runs.
pub const FAULTS_ENV: &str = "XMG_FAULTS";

const INFINITE: u64 = u64::MAX;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Site {
    /// `panic@worker=W,step=S` — chunk worker W at global env-step S.
    ChunkStep { worker: usize, step: u64 },
    /// `panic@shard=K,round=R` — shard worker K at collection round R.
    ShardRound { shard: usize, round: u64 },
    /// `torn-checkpoint@iter=I` — checkpoint write at iteration I.
    TornCheckpoint { iter: u64 },
}

#[derive(Debug)]
struct Entry {
    site: Site,
    /// Remaining firings; decremented atomically so concurrent workers
    /// racing on the same entry consume it exactly `count` times.
    remaining: AtomicU64,
}

/// A parsed, consumable schedule of injected failures. Shared across
/// worker threads behind an `Arc`; an empty plan is free to consult.
#[derive(Debug, Default)]
pub struct FaultPlan {
    entries: Vec<Entry>,
}

impl FaultPlan {
    /// The empty plan: nothing ever fires.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Parse a plan string (the `XMG_FAULTS` grammar above).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut entries = Vec::new();
        for raw in spec.split(';') {
            let part = raw.trim();
            if part.is_empty() {
                continue;
            }
            entries.push(
                parse_entry(part)
                    .with_context(|| format!("fault entry `{part}`"))?,
            );
        }
        Ok(FaultPlan { entries })
    }

    /// Read the plan from `XMG_FAULTS`; unset or empty means no faults.
    /// A malformed value is an error (silently ignoring a typo'd fault
    /// plan would make a failing injection test look like a pass).
    pub fn from_env() -> Result<FaultPlan> {
        match std::env::var(FAULTS_ENV) {
            Ok(spec) if !spec.trim().is_empty() => FaultPlan::parse(&spec)
                .with_context(|| format!("parsing ${FAULTS_ENV}")),
            _ => Ok(FaultPlan::none()),
        }
    }

    /// Should chunk worker `worker` panic while executing global step
    /// index `step`? Consumes one firing on a hit.
    pub fn chunk_step_panic(&self, worker: usize, step: u64) -> bool {
        self.fire(Site::ChunkStep { worker, step })
    }

    /// Should shard worker `shard` panic in collection round `round`?
    pub fn shard_round_panic(&self, shard: usize, round: u64) -> bool {
        self.fire(Site::ShardRound { shard, round })
    }

    /// Should the checkpoint at iteration `iter` be written torn?
    pub fn torn_checkpoint(&self, iter: u64) -> bool {
        self.fire(Site::TornCheckpoint { iter })
    }

    fn fire(&self, site: Site) -> bool {
        for e in &self.entries {
            if e.site != site {
                continue;
            }
            // Decrement-if-positive; INFINITE never decrements.
            loop {
                let cur = e.remaining.load(Ordering::Relaxed);
                if cur == 0 {
                    break;
                }
                if cur == INFINITE {
                    return true;
                }
                if e.remaining
                    .compare_exchange(
                        cur,
                        cur - 1,
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    )
                    .is_ok()
                {
                    return true;
                }
            }
        }
        false
    }
}

fn parse_entry(part: &str) -> Result<Entry> {
    let (kind, rest) = part
        .split_once('@')
        .context("expected `<kind>@<key>=<val>,...`")?;
    let mut keys: Vec<(&str, &str)> = Vec::new();
    let mut count = 1u64;
    for kv in rest.split(',') {
        let (k, v) = kv
            .trim()
            .split_once('=')
            .with_context(|| format!("expected `key=value`, got `{kv}`"))?;
        let (k, v) = (k.trim(), v.trim());
        if k == "count" {
            count = if v == "*" {
                INFINITE
            } else {
                parse_u64(v).context("count")?
            };
        } else {
            keys.push((k, v));
        }
    }
    keys.sort_by_key(|&(k, _)| k);
    let site = match kind.trim() {
        "panic" => match keys.as_slice() {
            [("step", s), ("worker", w)] => Site::ChunkStep {
                worker: parse_u64(w).context("worker")? as usize,
                step: parse_u64(s).context("step")?,
            },
            [("round", r), ("shard", k)] => Site::ShardRound {
                shard: parse_u64(k).context("shard")? as usize,
                round: parse_u64(r).context("round")?,
            },
            _ => bail!(
                "panic@ needs `worker=W,step=S` or `shard=K,round=R`"
            ),
        },
        "torn-checkpoint" => match keys.as_slice() {
            [("iter", i)] => Site::TornCheckpoint {
                iter: parse_u64(i).context("iter")?,
            },
            _ => bail!("torn-checkpoint@ needs `iter=I`"),
        },
        other => bail!(
            "unknown fault kind `{other}` \
             (expected `panic` or `torn-checkpoint`)"
        ),
    };
    if count == 0 {
        bail!("count=0 would never fire");
    }
    Ok(Entry { site, remaining: AtomicU64::new(count) })
}

fn parse_u64(v: &str) -> Result<u64> {
    v.parse::<u64>()
        .with_context(|| format!("`{v}` is not a non-negative integer"))
}

/// Bounded retry-with-backoff policy for supervised worker recovery.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    /// Respawn attempts per failed job before giving up (0 = fail on
    /// the first worker death).
    pub max_retries: u32,
    /// Sleep before the k-th respawn: `backoff_ms * k` (linear).
    pub backoff_ms: u64,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        RetryPolicy { max_retries: 2, backoff_ms: 50 }
    }
}

impl RetryPolicy {
    /// Sleep for the `attempt`-th retry (1-based). No-op at 0 backoff.
    pub fn sleep(&self, attempt: u32) {
        let ms = self.backoff_ms.saturating_mul(attempt as u64);
        if ms > 0 {
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_worker_step_panic() {
        let p = FaultPlan::parse("panic@worker=2,step=17").unwrap();
        assert!(!p.is_empty());
        assert!(!p.chunk_step_panic(1, 17));
        assert!(!p.chunk_step_panic(2, 16));
        assert!(p.chunk_step_panic(2, 17));
        // one-shot: a deterministic replay of the same step is clean
        assert!(!p.chunk_step_panic(2, 17));
    }

    #[test]
    fn parses_multi_entry_and_shard_round() {
        let p = FaultPlan::parse(
            "panic@worker=0,step=3; panic@shard=1,round=2;\
             torn-checkpoint@iter=4",
        )
        .unwrap();
        assert!(p.chunk_step_panic(0, 3));
        assert!(p.shard_round_panic(1, 2));
        assert!(!p.shard_round_panic(1, 3));
        assert!(p.torn_checkpoint(4));
        assert!(!p.torn_checkpoint(4));
    }

    #[test]
    fn count_budget_and_infinite() {
        let p = FaultPlan::parse("panic@worker=1,step=5,count=2").unwrap();
        assert!(p.chunk_step_panic(1, 5));
        assert!(p.chunk_step_panic(1, 5));
        assert!(!p.chunk_step_panic(1, 5));

        let q = FaultPlan::parse("panic@worker=1,step=5,count=*").unwrap();
        for _ in 0..10 {
            assert!(q.chunk_step_panic(1, 5));
        }
    }

    #[test]
    fn rejects_malformed_entries() {
        for bad in [
            "panic@worker=2",
            "panic@step=17,worker=2,extra=1",
            "explode@worker=1,step=2",
            "torn-checkpoint@step=3",
            "panic@worker=x,step=1",
            "panic@worker=1,step=2,count=0",
            "panic",
        ] {
            assert!(FaultPlan::parse(bad).is_err(), "accepted `{bad}`");
        }
    }

    #[test]
    fn empty_plan_never_fires() {
        let p = FaultPlan::none();
        assert!(p.is_empty());
        assert!(!p.chunk_step_panic(0, 0));
        assert!(!p.shard_round_panic(0, 0));
        assert!(!p.torn_checkpoint(0));
    }
}
