//! Minimal CLI argument parser (no `clap` offline): `--key value`,
//! `--flag`, and positional arguments.

use std::collections::HashMap;

#[derive(Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    opts: HashMap<String, String>,
    flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Self {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                // --key=value or --key value or --flag
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.opts.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(name.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Self {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Self::parse(&argv)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --{name}: {v}")))
            .unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --{name}: {v}")))
            .unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|v| v.parse().unwrap_or_else(|_| panic!("bad --{name}: {v}")))
            .unwrap_or(default)
    }

    /// Comma-separated usize list, e.g. `--batches 1,16,256`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            Some(v) => v
                .split(',')
                .map(|x| x.trim().parse().expect("bad list item"))
                .collect(),
            None => default.to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(items: &[&str]) -> Vec<String> {
        items.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_key_value_pairs() {
        let a = Args::parse(&sv(&["--batch", "64", "--name", "x"]));
        assert_eq!(a.usize_or("batch", 0), 64);
        assert_eq!(a.str_or("name", ""), "x");
    }

    #[test]
    fn parses_equals_form() {
        let a = Args::parse(&sv(&["--batch=128"]));
        assert_eq!(a.usize_or("batch", 0), 128);
    }

    #[test]
    fn parses_flags_and_positional() {
        let a = Args::parse(&sv(&["train", "--fast", "--n", "3"]));
        assert!(a.flag("fast"));
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.usize_or("n", 0), 3);
    }

    #[test]
    fn trailing_flag() {
        let a = Args::parse(&sv(&["--quick"]));
        assert!(a.flag("quick"));
    }

    #[test]
    fn list_parsing() {
        let a = Args::parse(&sv(&["--batches", "1,2,8"]));
        assert_eq!(a.usize_list_or("batches", &[]), vec![1, 2, 8]);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&[]);
        assert_eq!(a.usize_or("missing", 42), 42);
        assert_eq!(a.f64_or("missing", 0.5), 0.5);
        assert!(!a.flag("missing"));
    }
}
