//! Statistics helpers for the evaluation protocol (§4.2: return mean and
//! 20th percentile over evaluation tasks) and for bench reporting.

/// Percentile with linear interpolation (numpy 'linear' method), so the
/// "20th percentile" matches the paper's evaluation metric.
pub fn percentile(values: &[f64], p: f64) -> f64 {
    assert!(!values.is_empty(), "percentile of empty slice");
    let mut v = values.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() as f64 - 1.0);
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

pub fn mean(values: &[f64]) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    values.iter().sum::<f64>() / values.len() as f64
}

pub fn std_dev(values: &[f64]) -> f64 {
    if values.len() < 2 {
        return 0.0;
    }
    let m = mean(values);
    let var = values.iter().map(|x| (x - m) * (x - m)).sum::<f64>()
        / (values.len() - 1) as f64;
    var.sqrt()
}

pub fn min(values: &[f64]) -> f64 {
    values.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(values: &[f64]) -> f64 {
    values.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Histogram over integer values (used by the Fig. 4 rule-count
/// distribution bench).
pub fn int_histogram(values: &[usize]) -> Vec<(usize, usize)> {
    let mut counts = std::collections::BTreeMap::new();
    for &v in values {
        *counts.entry(v).or_insert(0usize) += 1;
    }
    counts.into_iter().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn percentile_linear_interp() {
        let v = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&v, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&v, 100.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&v, 50.0) - 2.5).abs() < 1e-12);
        // numpy.percentile([1,2,3,4], 20) == 1.6
        assert!((percentile(&v, 20.0) - 1.6).abs() < 1e-12);
    }

    #[test]
    fn percentile_single() {
        assert_eq!(percentile(&[7.0], 20.0), 7.0);
    }

    #[test]
    fn mean_std() {
        let v = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&v) - 5.0).abs() < 1e-12);
        assert!((std_dev(&v) - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn histogram_counts() {
        let h = int_histogram(&[1, 1, 2, 5, 5, 5]);
        assert_eq!(h, vec![(1, 2), (2, 1), (5, 3)]);
    }
}
