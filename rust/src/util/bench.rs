//! Bench harness (no `criterion` offline): warmup + repeated timed runs,
//! reporting the *minimum* across repeats — the paper's own protocol
//! ("taking the minimum value among multiple repeats", §4.1). Results
//! can be serialized to `BENCH_<name>.json` files ([`JsonReport`]) so
//! the repo's perf trajectory is machine-readable across PRs.

use std::path::{Path, PathBuf};
use std::time::Instant;

use super::args::Args;

pub struct BenchResult {
    pub name: String,
    /// seconds per invocation, minimum over repeats
    pub min_secs: f64,
    pub mean_secs: f64,
    pub repeats: usize,
}

/// Minimal JSON string escaping (quotes and backslashes; labels here are
/// ASCII identifiers, control characters do not occur).
fn json_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

/// A finite f64 as a JSON number (JSON has no NaN/Infinity literals).
fn json_num(x: f64) -> String {
    if x.is_finite() {
        format!("{x}")
    } else {
        "null".to_string()
    }
}

impl BenchResult {
    /// steps/second given `work` units per invocation.
    pub fn throughput(&self, work: usize) -> f64 {
        work as f64 / self.min_secs
    }

    /// Machine-readable record (no serde offline — hand-rolled JSON).
    pub fn to_json(&self) -> String {
        format!(
            "{{\"name\":\"{}\",\"min_secs\":{},\"mean_secs\":{},\
             \"repeats\":{}}}",
            json_escape(&self.name),
            json_num(self.min_secs),
            json_num(self.mean_secs),
            self.repeats
        )
    }
}

/// Accumulates bench rows and writes one `BENCH_<name>.json` file — the
/// perf-trajectory format the CI smoke run validates and the repo tracks
/// across PRs.
pub struct JsonReport {
    bench: String,
    rows: Vec<String>,
    metrics: Vec<(String, f64)>,
    note: Option<String>,
}

impl JsonReport {
    pub fn new(bench: &str) -> JsonReport {
        JsonReport {
            bench: bench.to_string(),
            rows: Vec::new(),
            metrics: Vec::new(),
            note: None,
        }
    }

    /// One timed row: `envs * steps` work units per invocation.
    /// `steps_per_sec` duplicates `sps` under the explicit name the
    /// perf-trajectory tooling (CI regression diff) keys on; `sps`
    /// stays for older readers of the committed files.
    pub fn add(&mut self, label: &str, envs: usize, steps: usize,
               r: &BenchResult) {
        let sps = r.throughput(envs * steps);
        self.rows.push(format!(
            "{{\"label\":\"{}\",\"envs\":{envs},\"steps\":{steps},\
             \"sps\":{},\"steps_per_sec\":{},\"min_secs\":{},\
             \"mean_secs\":{},\"repeats\":{}}}",
            json_escape(label),
            json_num(sps),
            json_num(sps),
            json_num(r.min_secs),
            json_num(r.mean_secs),
            r.repeats
        ));
    }

    /// A row measured externally (e.g. by an engine's own wall clock)
    /// where only the steps/second figure is known.
    pub fn add_sps(&mut self, label: &str, envs: usize, steps: usize,
                   sps: f64) {
        self.rows.push(format!(
            "{{\"label\":\"{}\",\"envs\":{envs},\"steps\":{steps},\
             \"sps\":{},\"steps_per_sec\":{}}}",
            json_escape(label),
            json_num(sps),
            json_num(sps)
        ));
    }

    /// [`JsonReport::add_sps`] with extra schema fields appended
    /// verbatim after the standard keys (e.g. the eval harness's
    /// per-shot `"shot":1,"return_mean":0.42` columns). `extra` must be
    /// a comma-separated list of JSON key:value pairs without braces;
    /// the standard keys stay first so label-keyed tooling
    /// (scripts/compare_bench.py) reads these rows unchanged.
    pub fn add_sps_extra(&mut self, label: &str, envs: usize,
                         steps: usize, sps: f64, extra: &str) {
        self.rows.push(format!(
            "{{\"label\":\"{}\",\"envs\":{envs},\"steps\":{steps},\
             \"sps\":{},\"steps_per_sec\":{},{extra}}}",
            json_escape(label),
            json_num(sps),
            json_num(sps)
        ));
    }

    /// A named summary figure (speedups, ratios).
    pub fn metric(&mut self, key: &str, value: f64) {
        self.metrics.push((key.to_string(), value));
    }

    pub fn note(&mut self, note: &str) {
        self.note = Some(note.to_string());
    }

    pub fn to_json(&self) -> String {
        let metrics: Vec<String> = self
            .metrics
            .iter()
            .map(|(k, v)| format!("\"{}\":{}", json_escape(k), json_num(*v)))
            .collect();
        let note = match &self.note {
            Some(n) => format!(",\"note\":\"{}\"", json_escape(n)),
            None => String::new(),
        };
        format!(
            "{{\"bench\":\"{}\",\"rows\":[{}],\"metrics\":{{{}}}{}}}\n",
            json_escape(&self.bench),
            self.rows.join(","),
            metrics.join(","),
            note
        )
    }

    pub fn write(&self, path: &Path) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// Read a `usize` bench knob from the environment (the `XMG_*`
/// variables the CI smoke runs use to cap batch/steps/threads).
pub fn env_usize(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(default)
}

/// Resolve the `--json [PATH]` bench flag: an explicit path wins; the
/// bare flag means `BENCH_<name>.json` in the working directory; absent
/// means no JSON output.
pub fn json_arg_path(args: &Args, name: &str) -> Option<PathBuf> {
    if let Some(p) = args.get("json") {
        return Some(PathBuf::from(p));
    }
    if args.flag("json") {
        return Some(PathBuf::from(format!("BENCH_{name}.json")));
    }
    None
}

/// Time `f` (which performs one full invocation of the workload).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, repeats: usize,
                         mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(repeats);
    for _ in 0..repeats {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let min = times.iter().copied().fold(f64::INFINITY, f64::min);
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    BenchResult {
        name: name.to_string(),
        min_secs: min,
        mean_secs: mean,
        repeats,
    }
}

/// Pretty-print a steps-per-second table row (log-log figures in the paper
/// become rows here; plotting is left to the reader's tooling).
pub fn report_sps(label: &str, envs: usize, steps: usize, r: &BenchResult) {
    let sps = (envs * steps) as f64 / r.min_secs;
    println!(
        "{label:<40} envs={envs:<6} steps={steps:<6} \
         min={:>9.4}s  sps={sps:>12.0}",
        r.min_secs
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_times() {
        let mut count = 0;
        let r = bench("noop", 2, 5, || {
            count += 1;
        });
        assert_eq!(count, 7); // warmup + repeats
        assert_eq!(r.repeats, 5);
        assert!(r.min_secs >= 0.0);
        assert!(r.mean_secs >= r.min_secs);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            min_secs: 0.5,
            mean_secs: 0.5,
            repeats: 1,
        };
        assert_eq!(r.throughput(100), 200.0);
    }

    #[test]
    fn bench_result_json() {
        let r = BenchResult {
            name: "native-vec".into(),
            min_secs: 0.25,
            mean_secs: 0.5,
            repeats: 3,
        };
        assert_eq!(
            r.to_json(),
            "{\"name\":\"native-vec\",\"min_secs\":0.25,\
             \"mean_secs\":0.5,\"repeats\":3}"
        );
    }

    #[test]
    fn json_report_shape() {
        let mut rep = JsonReport::new("fig5a_native");
        let r = BenchResult {
            name: "n".into(),
            min_secs: 0.5,
            mean_secs: 0.5,
            repeats: 2,
        };
        rep.add("native-vec-b16", 16, 64, &r);
        rep.add_sps("engine", 8, 32, 1000.0);
        rep.metric("native_vs_scalar_b1024", 6.5);
        rep.note("a \"quoted\" note");
        let text = rep.to_json();
        assert!(text.starts_with("{\"bench\":\"fig5a_native\""));
        assert!(text.contains("\"label\":\"native-vec-b16\""));
        assert!(text.contains("\"sps\":2048")); // 16*64/0.5
        assert!(text.contains("\"steps_per_sec\":2048"));
        // the external-sps row carries the explicit name too
        assert!(text.contains("\"label\":\"engine\",\"envs\":8,\
                               \"steps\":32,\"sps\":1000,\
                               \"steps_per_sec\":1000"));
        assert!(text.contains("\"native_vs_scalar_b1024\":6.5"));
        rep.add_sps_extra("eval-random-shot1", 8, 32, 500.0,
                          "\"shot\":1,\"return_mean\":0.25");
        let text = rep.to_json();
        assert!(text.contains("\"label\":\"eval-random-shot1\",\
                               \"envs\":8,\"steps\":32,\"sps\":500,\
                               \"steps_per_sec\":500,\"shot\":1,\
                               \"return_mean\":0.25"));
        assert!(text.contains("\\\"quoted\\\""));
        assert!(text.ends_with("}\n"));
    }

    #[test]
    fn json_path_resolution() {
        use crate::util::args::Args;
        let argv: Vec<String> =
            vec!["--json".into(), "out.json".into()];
        let a = Args::parse(&argv);
        assert_eq!(json_arg_path(&a, "x").unwrap(),
                   PathBuf::from("out.json"));
        let argv: Vec<String> = vec!["--json".into()];
        let a = Args::parse(&argv);
        assert_eq!(json_arg_path(&a, "fig5a_native").unwrap(),
                   PathBuf::from("BENCH_fig5a_native.json"));
        let a = Args::parse(&[]);
        assert!(json_arg_path(&a, "x").is_none());
    }
}
