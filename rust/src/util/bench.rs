//! Bench harness (no `criterion` offline): warmup + repeated timed runs,
//! reporting the *minimum* across repeats — the paper's own protocol
//! ("taking the minimum value among multiple repeats", §4.1).

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    /// seconds per invocation, minimum over repeats
    pub min_secs: f64,
    pub mean_secs: f64,
    pub repeats: usize,
}

impl BenchResult {
    /// steps/second given `work` units per invocation.
    pub fn throughput(&self, work: usize) -> f64 {
        work as f64 / self.min_secs
    }
}

/// Time `f` (which performs one full invocation of the workload).
pub fn bench<F: FnMut()>(name: &str, warmup: usize, repeats: usize,
                         mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(repeats);
    for _ in 0..repeats {
        let t0 = Instant::now();
        f();
        times.push(t0.elapsed().as_secs_f64());
    }
    let min = times.iter().copied().fold(f64::INFINITY, f64::min);
    let mean = times.iter().sum::<f64>() / times.len() as f64;
    BenchResult {
        name: name.to_string(),
        min_secs: min,
        mean_secs: mean,
        repeats,
    }
}

/// Pretty-print a steps-per-second table row (log-log figures in the paper
/// become rows here; plotting is left to the reader's tooling).
pub fn report_sps(label: &str, envs: usize, steps: usize, r: &BenchResult) {
    let sps = (envs * steps) as f64 / r.min_secs;
    println!(
        "{label:<40} envs={envs:<6} steps={steps:<6} \
         min={:>9.4}s  sps={sps:>12.0}",
        r.min_secs
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_times() {
        let mut count = 0;
        let r = bench("noop", 2, 5, || {
            count += 1;
        });
        assert_eq!(count, 7); // warmup + repeats
        assert_eq!(r.repeats, 5);
        assert!(r.min_secs >= 0.0);
        assert!(r.mean_secs >= r.min_secs);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            min_secs: 0.5,
            mean_secs: 0.5,
            repeats: 1,
        };
        assert_eq!(r.throughput(100), 200.0);
    }
}
