//! The native numeric contract: every op the `nn` stack uses, defined
//! once so the Rust kernels and the committed Python oracle fixtures
//! (`python/tools/gen_nn_fixtures.py`) agree bit for bit.
//!
//! Contract (mirrored exactly by the generator):
//!
//! * dot products accumulate in f64 sequentially over the contraction
//!   index (ascending) and round to f32 once; the f64 product of two
//!   f32 operands is exact, so the result depends only on the
//!   summation order, which is fixed;
//! * elementwise `+ - * /` are plain f32 IEEE ops (single rounding);
//! * transcendentals evaluate in f64 via the platform libm on the
//!   widened f32 input and round to f32 once — `f64::{exp, tanh, ln}`
//!   and CPython's `math` module resolve to the same libm calls on
//!   linux-gnu, so the fixture bits match;
//! * batch reductions (loss means, normalizations) accumulate in f64
//!   in a fixed documented order and round once at the end.
//!
//! Everything here is serial on the coordinator thread: thread-count
//! invariance of training comes for free because the only parallel
//! component (env stepping) is bitwise thread-invariant already.

use crate::util::rng::Rng;

/// `f32(exp(x as f64))` — single rounding through libm.
#[inline]
pub fn exp_f32(x: f32) -> f32 {
    (x as f64).exp() as f32
}

/// `f32(tanh(x as f64))` — single rounding through libm.
#[inline]
pub fn tanh_f32(x: f32) -> f32 {
    (x as f64).tanh() as f32
}

/// Logistic sigmoid, all-f64 inner evaluation, single rounding.
#[inline]
pub fn sigmoid_f32(x: f32) -> f32 {
    (1.0f64 / (1.0 + (-(x as f64)).exp())) as f32
}

/// `out[j] = f32(Σ_k f64(x[k] · w[k·n_out + j])) (+ bias[j], f32 add)`
/// for row-major `w` of shape `[n_in, n_out]` — the `x @ w` of the
/// reference model. The f64 accumulator runs over `k` ascending.
pub fn matvec(x: &[f32], w: &[f32], n_in: usize, n_out: usize,
              bias: Option<&[f32]>, out: &mut [f32]) {
    debug_assert_eq!(x.len(), n_in);
    debug_assert_eq!(w.len(), n_in * n_out);
    debug_assert_eq!(out.len(), n_out);
    for (j, o) in out.iter_mut().enumerate() {
        let mut acc = 0.0f64;
        for k in 0..n_in {
            acc += x[k] as f64 * w[k * n_out + j] as f64;
        }
        let mut v = acc as f32;
        if let Some(b) = bias {
            v += b[j];
        }
        *o = v;
    }
}

/// Contract log-softmax of one logits row: `m = max` (f32 compare),
/// `d_i = f32(x_i - m)`, `s = Σ exp(d_i)` (f64, ascending),
/// `logp_i = f32(d_i - ln s)`.
pub fn log_softmax(logits: &[f32], out: &mut [f32]) {
    debug_assert_eq!(logits.len(), out.len());
    let mut m = f32::NEG_INFINITY;
    for &x in logits {
        if x > m {
            m = x;
        }
    }
    let mut s = 0.0f64;
    for (o, &x) in out.iter_mut().zip(logits) {
        let d = x - m;
        *o = d; // stash d_i; finalized below
        s += (d as f64).exp();
    }
    let ls = s.ln();
    for o in out.iter_mut() {
        *o = (*o as f64 - ls) as f32;
    }
}

/// One categorical draw from a logits row: softmax probabilities in
/// f64 (from the contract log-probs), exactly one `rng.f64()` per
/// draw, CDF walk in action order. Serial per env in env order — the
/// sampling sequence is part of the determinism contract.
pub fn categorical(rng: &mut Rng, logits: &[f32], scratch: &mut [f32])
                   -> usize {
    debug_assert_eq!(scratch.len(), logits.len());
    log_softmax(logits, scratch);
    let mut total = 0.0f64;
    for &lp in scratch.iter() {
        total += (lp as f64).exp();
    }
    let u = rng.f64() * total;
    let mut acc = 0.0f64;
    for (a, &lp) in scratch.iter().enumerate() {
        acc += (lp as f64).exp();
        if u < acc {
            return a;
        }
    }
    logits.len() - 1
}

/// Standard-normal draw via Box-Muller on two `rng.f64()` uniforms.
/// Only used for parameter init (the JAX side seeds its own params;
/// there is no cross-language init parity to keep — just determinism
/// per seed).
pub fn normal_f64(rng: &mut Rng) -> f64 {
    let u1 = 1.0 - rng.f64(); // (0, 1]: keeps ln finite
    let u2 = rng.f64();
    (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matvec_is_f64_sequential() {
        // 2x2 identity-ish check plus a catastrophic-cancellation case
        // that distinguishes f64 accumulation from f32
        let x = [1.0e8f32, 1.0, -1.0e8];
        let w = [1.0f32, 1.0, 1.0]; // [3, 1]
        let mut out = [0.0f32];
        matvec(&x, &w, 3, 1, None, &mut out);
        assert_eq!(out[0], 1.0, "f64 accumulator preserves the 1.0");
    }

    #[test]
    fn log_softmax_normalizes() {
        let logits = [0.5f32, -1.0, 2.0, 0.0];
        let mut lp = [0.0f32; 4];
        log_softmax(&logits, &mut lp);
        let total: f64 = lp.iter().map(|&x| (x as f64).exp()).sum();
        assert!((total - 1.0).abs() < 1e-6, "probs sum to 1: {total}");
        assert!(lp.iter().all(|&x| x < 0.0));
    }

    #[test]
    fn categorical_is_deterministic_and_in_range() {
        let logits = [0.1f32, 3.0, -2.0, 0.5];
        let mut s = [0.0f32; 4];
        let mut a = Rng::new(5);
        let mut b = Rng::new(5);
        for _ in 0..64 {
            let x = categorical(&mut a, &logits, &mut s);
            let y = categorical(&mut b, &logits, &mut s);
            assert_eq!(x, y);
            assert!(x < 4);
        }
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut rng = Rng::new(11);
        let n = 20_000;
        let (mut s1, mut s2) = (0.0f64, 0.0f64);
        for _ in 0..n {
            let x = normal_f64(&mut rng);
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }
}
