//! GAE and the clipped-PPO loss, forward + analytic gradient at the
//! (logits, value) level — the reference `model.py` math under the
//! [`super::math`] numeric contract. Batch reductions accumulate in
//! f64 in flat `[T, B]` order (t-major) and round to f32 once;
//! per-element gradients stay f64 (they feed the f64 accumulators of
//! [`super::model::Grads`]).

/// Reverse-scan generalized advantage estimation (contract f32 ops).
/// All arrays are flat `[T, B]`; `dones[i] != 0` means the episode
/// ended *after* step i (the bootstrap mask). Writes advantages and
/// value targets (`adv + values`).
#[allow(clippy::too_many_arguments)]
pub fn gae(rewards: &[f32], values: &[f32], dones: &[i32],
           last_value: &[f32], gamma: f32, lam: f32, t_len: usize,
           b: usize, adv: &mut [f32], targets: &mut [f32]) {
    debug_assert_eq!(rewards.len(), t_len * b);
    debug_assert_eq!(last_value.len(), b);
    debug_assert_eq!(adv.len(), t_len * b);
    let gl = gamma * lam;
    for e in 0..b {
        let mut a_next = 0.0f32;
        let mut v_next = last_value[e];
        for t in (0..t_len).rev() {
            let i = t * b + e;
            let nonterm = 1.0f32 - if dones[i] != 0 { 1.0 } else { 0.0 };
            let t1 = gamma * v_next;
            let t2 = t1 * nonterm;
            let t3 = rewards[i] + t2;
            let delta = t3 - values[i];
            let u1 = gl * nonterm;
            let u2 = u1 * a_next;
            a_next = delta + u2;
            adv[i] = a_next;
            targets[i] = a_next + values[i];
            v_next = values[i];
        }
    }
}

/// Scalar loss statistics of one PPO minibatch update (f32, contract
/// rounding; the reference `metrics` vector minus grad-norm, which the
/// optimizer step reports).
#[derive(Clone, Copy, Debug, Default)]
pub struct LossStats {
    pub total: f32,
    pub pi_loss: f32,
    pub v_loss: f32,
    pub entropy: f32,
    pub approx_kl: f32,
    pub clip_frac: f32,
    pub adv_std: f32,
}

/// Inputs of [`ppo_loss_grads`] that come from the rollout (flat
/// `[T, Bm]` minibatch views).
pub struct LossBatch<'a> {
    pub actions: &'a [i32],
    pub old_logp: &'a [f32],
    pub adv: &'a [f32],
    pub targets: &'a [f32],
}

/// Clipped-PPO loss forward + gradient wrt logits and values.
///
/// `logits` is flat `[N, A]`, `values`/`dvalues` `[N]`, `dlogits`
/// `[N, A]` (overwritten). Advantages are normalized over the
/// minibatch with f64 mean/std (population). `hp` is the 8-float
/// hyperparameter vector (`clip_eps = hp[1]`, `ent_coef = hp[4]`,
/// `vf_coef = hp[5]`). `scratch` must hold `A` floats.
#[allow(clippy::too_many_arguments)]
pub fn ppo_loss_grads(logits: &[f32], values: &[f32], lb: &LossBatch,
                      hp: &[f32; 8], a_dim: usize, scratch: &mut [f32],
                      dlogits: &mut [f64], dvalues: &mut [f64])
                      -> LossStats {
    let n = values.len();
    debug_assert_eq!(logits.len(), n * a_dim);
    debug_assert_eq!(dlogits.len(), n * a_dim);
    let n_f = n as f64;
    let clip_eps = hp[1];
    let (ent_coef, vf_coef) = (hp[4] as f64, hp[5] as f64);

    // advantage normalization: f64 mean/std over the minibatch
    let mut s = 0.0f64;
    for &a in lb.adv {
        s += a as f64;
    }
    let mean = s / n_f;
    let mut s2 = 0.0f64;
    for &a in lb.adv {
        let d = a as f64 - mean;
        s2 += d * d;
    }
    let std = (s2 / n_f).sqrt();

    let lo = 1.0f32 - clip_eps;
    let hi = 1.0f32 + clip_eps;
    let (mut sum_pi, mut sum_v, mut sum_ent, mut sum_kl) =
        (0.0f64, 0.0f64, 0.0f64, 0.0f64);
    let mut n_clip = 0usize;
    for i in 0..n {
        let row = &logits[i * a_dim..(i + 1) * a_dim];
        super::math::log_softmax(row, scratch);
        let act = lb.actions[i] as usize;
        let lp = scratch[act];
        let dl = lp - lb.old_logp[i];
        let ratio = super::math::exp_f32(dl);
        let a_n = ((lb.adv[i] as f64 - mean) / (std + 1e-8)) as f32;
        let pg1 = ratio * a_n;
        let rc = ratio.max(lo).min(hi);
        let pg2 = rc * a_n;
        let pg_min = if pg1 <= pg2 { pg1 } else { pg2 };
        sum_pi += pg_min as f64;
        let rf = ratio as f64;
        sum_kl += (rf - 1.0) - rf.ln();
        if (ratio - 1.0).abs() > clip_eps {
            n_clip += 1;
        }
        // d min(pg1, pg2) / d logp (dratio/dlogp = ratio); the clip
        // branch passes gradient only inside [lo, hi]
        let dmin_dlogp = if pg1 <= pg2 {
            a_n as f64 * rf
        } else if ratio >= lo && ratio <= hi {
            a_n as f64 * rf
        } else {
            0.0
        };
        let dlp = -(1.0 / n_f) * dmin_dlogp;
        let mut ent_i = 0.0f64;
        for &lp_a in scratch.iter() {
            let p_a = (lp_a as f64).exp();
            ent_i -= p_a * lp_a as f64;
        }
        sum_ent += ent_i;
        for j in 0..a_dim {
            let p_j = (scratch[j] as f64).exp();
            let ind = if j == act { 1.0f64 } else { 0.0 };
            let mut d_z = dlp * (ind - p_j);
            d_z += ent_coef / n_f * p_j * (scratch[j] as f64 + ent_i);
            dlogits[i * a_dim + j] = d_z;
        }
        let e = values[i] - lb.targets[i];
        sum_v += e as f64 * e as f64;
        dvalues[i] = vf_coef / n_f * e as f64;
    }
    let pi_loss = (-(sum_pi / n_f)) as f32;
    let v_loss = (0.5 * sum_v / n_f) as f32;
    let entropy = (sum_ent / n_f) as f32;
    LossStats {
        total: (pi_loss as f64 + vf_coef * v_loss as f64
                - ent_coef * entropy as f64) as f32,
        pi_loss,
        v_loss,
        entropy,
        approx_kl: (sum_kl / n_f) as f32,
        clip_frac: (n_clip as f64 / n_f) as f32,
        adv_std: std as f32,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gae_matches_hand_rollout() {
        // single env, no terminals: classic telescoped recursion
        let rewards = [1.0f32, 0.0, 0.5];
        let values = [0.2f32, 0.3, 0.1];
        let dones = [0i32, 0, 0];
        let last_value = [0.4f32];
        let (mut adv, mut tg) = ([0.0f32; 3], [0.0f32; 3]);
        gae(&rewards, &values, &dones, &last_value, 0.9, 0.8, 3, 1,
            &mut adv, &mut tg);
        let d2 = 0.5 + 0.9 * 0.4 - 0.1;
        let a2 = d2;
        let d1 = 0.0 + 0.9 * 0.1 - 0.3;
        let a1 = d1 + 0.9 * 0.8 * a2;
        let d0 = 1.0 + 0.9 * 0.3 - 0.2;
        let a0 = d0 + 0.9 * 0.8 * a1;
        assert!((adv[0] - a0).abs() < 1e-5, "{} vs {a0}", adv[0]);
        assert!((adv[1] - a1).abs() < 1e-5);
        assert!((adv[2] - a2).abs() < 1e-5);
        assert_eq!(tg[2], adv[2] + values[2]);
    }

    #[test]
    fn gae_terminal_cuts_bootstrap() {
        let rewards = [0.0f32, 1.0];
        let values = [0.5f32, 0.5];
        let dones = [1i32, 0]; // terminal after step 0
        let last_value = [9.0f32];
        let (mut adv, mut tg) = ([0.0f32; 2], [0.0f32; 2]);
        gae(&rewards, &values, &dones, &last_value, 0.99, 0.95, 2, 1,
            &mut adv, &mut tg);
        // step 0 sees neither v(step 1) nor adv(step 1)
        assert!((adv[0] - (0.0 - 0.5)).abs() < 1e-6, "{}", adv[0]);
    }

    #[test]
    fn loss_grad_signs_point_downhill() {
        // one element, strong positive advantage on the taken action:
        // the policy gradient must push that logit up (negative grad)
        let logits = [0.0f32, 0.0, 0.0];
        let values = [0.0f32];
        let lb = LossBatch {
            actions: &[1],
            old_logp: &[-1.0986f32], // log(1/3)
            adv: &[2.0f32],
            targets: &[1.0f32],
        };
        let hp = [1e-3f32, 0.2, 0.99, 0.95, 0.0, 0.5, 0.5, 0.0];
        let mut scratch = [0.0f32; 3];
        let mut dlogits = [0.0f64; 3];
        let mut dvalues = [0.0f64; 1];
        let stats = ppo_loss_grads(&logits, &values, &lb, &hp, 3,
                                   &mut scratch, &mut dlogits,
                                   &mut dvalues);
        // NB: single-element minibatch → normalized adv is 0/1e-8 ≈ 0,
        // so use dvalue for the sign check instead
        assert!(dvalues[0] < 0.0, "value below target: push up");
        assert!(stats.v_loss > 0.0);
        assert!(stats.total.is_finite());
    }
}
