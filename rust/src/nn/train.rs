//! The PPO update: T-step forward over a minibatch of env columns
//! (sequences kept intact — BPTT needs them), loss + analytic
//! backward, global-norm clip, Adam. One [`ppo_update`] call is one
//! optimizer step; the reference XLA `train_update` is exactly one
//! such call over the whole batch (1 epoch × 1 minibatch), which is
//! the native CLI default too.

use super::loss::{ppo_loss_grads, LossBatch, LossStats};
use super::model::{backward_step, network_step, CacheSlices, Grads,
                   ModelDims, Params, StepScratch, NUM_PARAMS};

/// Per-step forward activations for a whole `[T, Bm]` window,
/// allocated once and reused across epochs/minibatches of equal
/// shape.
pub struct SeqCache {
    t_len: usize,
    bm: usize,
    dims: ModelDims,
    x: Vec<f32>,
    h_in: Vec<f32>,
    r: Vec<f32>,
    z: Vec<f32>,
    n: Vec<f32>,
    ghn: Vec<f32>,
    pa: Vec<i32>,
    nd: Vec<f32>,
    h_out: Vec<f32>,
}

impl SeqCache {
    pub fn new(dims: ModelDims, t_len: usize, bm: usize) -> SeqCache {
        let (h, ri) = (dims.h, dims.rl2_in());
        SeqCache {
            t_len,
            bm,
            dims,
            x: vec![0.0; t_len * bm * ri],
            h_in: vec![0.0; t_len * bm * h],
            r: vec![0.0; t_len * bm * h],
            z: vec![0.0; t_len * bm * h],
            n: vec![0.0; t_len * bm * h],
            ghn: vec![0.0; t_len * bm * h],
            pa: vec![0; t_len * bm],
            nd: vec![0.0; t_len * bm],
            h_out: vec![0.0; t_len * bm * h],
        }
    }

    /// Mutable step-`t` view (all buffers sliced to `[Bm, dim]`).
    fn at(&mut self, t: usize) -> CacheSlices<'_> {
        debug_assert!(t < self.t_len);
        let (h, ri, bm) = (self.dims.h, self.dims.rl2_in(), self.bm);
        CacheSlices {
            x: &mut self.x[t * bm * ri..(t + 1) * bm * ri],
            h_in: &mut self.h_in[t * bm * h..(t + 1) * bm * h],
            r: &mut self.r[t * bm * h..(t + 1) * bm * h],
            z: &mut self.z[t * bm * h..(t + 1) * bm * h],
            n: &mut self.n[t * bm * h..(t + 1) * bm * h],
            ghn: &mut self.ghn[t * bm * h..(t + 1) * bm * h],
            pa: &mut self.pa[t * bm..(t + 1) * bm],
            nd: &mut self.nd[t * bm..(t + 1) * bm],
            h_out: &mut self.h_out[t * bm * h..(t + 1) * bm * h],
        }
    }
}

/// One minibatch of rollout columns, flat `[T, Bm]` arrays (plus
/// `h0 [Bm, H]`). The trainer gathers these from the `[T, B]` rollout
/// by env index, preserving each env's full T-step sequence.
pub struct MiniBatch {
    pub t_len: usize,
    pub bm: usize,
    pub obs: Vec<i32>,
    pub prev_a: Vec<i32>,
    pub prev_r: Vec<f32>,
    pub done: Vec<i32>,
    pub actions: Vec<i32>,
    pub old_logp: Vec<f32>,
    pub adv: Vec<f32>,
    pub targets: Vec<f32>,
    pub h0: Vec<f32>,
}

/// Reusable buffers of [`ppo_update`] for a fixed `[T, Bm]` shape.
pub struct UpdateBufs {
    cache: SeqCache,
    logits: Vec<f32>,
    values: Vec<f32>,
    dlogits: Vec<f64>,
    dvalues: Vec<f64>,
    grads: Grads,
    scratch: StepScratch,
    lp_scratch: Vec<f32>,
    h: Vec<f32>,
    h_next: Vec<f32>,
}

impl UpdateBufs {
    pub fn new(dims: ModelDims, t_len: usize, bm: usize) -> UpdateBufs {
        let n = t_len * bm;
        UpdateBufs {
            cache: SeqCache::new(dims, t_len, bm),
            logits: vec![0.0; n * dims.a],
            values: vec![0.0; n],
            dlogits: vec![0.0; n * dims.a],
            dvalues: vec![0.0; n],
            grads: Grads::zeros(&dims),
            scratch: StepScratch::new(&dims),
            lp_scratch: vec![0.0; dims.a],
            h: vec![0.0; bm * dims.h],
            h_next: vec![0.0; bm * dims.h],
        }
    }
}

/// Adam optimizer state (f32 moments, like the reference). The
/// update math runs in f64 per element and rounds each stored value
/// once — the contract the `adam` fixtures pin.
#[derive(Clone, Debug, PartialEq)]
pub struct Adam {
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
    pub t: i64,
}

impl Adam {
    pub fn new(dims: &ModelDims) -> Adam {
        Adam {
            m: (0..NUM_PARAMS)
                .map(|i| vec![0.0; dims.param_len(i)])
                .collect(),
            v: (0..NUM_PARAMS)
                .map(|i| vec![0.0; dims.param_len(i)])
                .collect(),
            t: 0,
        }
    }

    /// Global-norm-clipped Adam step (β₁ 0.9, β₂ 0.999, ε 1e-8).
    /// Returns the pre-clip global gradient norm.
    pub fn step(&mut self, params: &mut Params, grads: &Grads,
                lr: f32, max_norm: f32) -> f64 {
        self.t += 1;
        let mut acc = 0.0f64;
        for g in &grads.g {
            for &x in g {
                acc += x * x;
            }
        }
        let gn = acc.sqrt();
        let scale = (max_norm as f64 / (gn + 1e-8)).min(1.0);
        let bc1 = 1.0 - 0.9f64.powf(self.t as f64);
        let bc2 = 1.0 - 0.999f64.powf(self.t as f64);
        let lr = lr as f64;
        for idx in 0..NUM_PARAMS {
            let p = &mut params.t[idx];
            let g = &grads.g[idx];
            let (m, v) = (&mut self.m[idx], &mut self.v[idx]);
            for k in 0..p.len() {
                let gk = g[k] * scale;
                let mk = (0.9 * m[k] as f64 + 0.1 * gk) as f32;
                let vk =
                    (0.999 * v[k] as f64 + 0.001 * gk * gk) as f32;
                m[k] = mk;
                v[k] = vk;
                let mh = mk as f64 / bc1;
                let vh = vk as f64 / bc2;
                p[k] = (p[k] as f64 - lr * mh / (vh.sqrt() + 1e-8))
                    as f32;
            }
        }
        gn
    }
}

/// Loss stats plus the optimizer-side scalars of one update.
#[derive(Clone, Copy, Debug, Default)]
pub struct UpdateStats {
    pub loss: LossStats,
    pub grad_norm: f32,
}

/// Forward the policy over the minibatch window, recording caches.
fn forward_sequence(params: &Params, mb: &MiniBatch,
                    bufs: &mut UpdateBufs) {
    let dm = params.dims;
    let (t_len, bm) = (mb.t_len, mb.bm);
    let (ol, a, h) = (dm.obs_len(), dm.a, dm.h);
    bufs.h.copy_from_slice(&mb.h0);
    for t in 0..t_len {
        let lo = t * bm;
        let mut cs = bufs.cache.at(t);
        network_step(
            params,
            &mb.obs[lo * ol..(lo + bm) * ol],
            &mb.prev_a[lo..lo + bm],
            &mb.prev_r[lo..lo + bm],
            &mb.done[lo..lo + bm],
            &bufs.h,
            &mut bufs.logits[lo * a..(lo + bm) * a],
            &mut bufs.values[lo..lo + bm],
            &mut bufs.h_next[..bm * h],
            &mut bufs.scratch,
            Some(&mut cs),
        );
        std::mem::swap(&mut bufs.h, &mut bufs.h_next);
    }
}

/// One PPO optimizer step over one minibatch: forward (with caches),
/// clipped loss + gradient at the head, BPTT through the GRU window
/// (t descending), global-norm clip, Adam. Deterministic and serial;
/// bitwise-pinned end to end by the `ppo_update` oracle fixture.
pub fn ppo_update(params: &mut Params, adam: &mut Adam,
                  mb: &MiniBatch, hp: &[f32; 8],
                  bufs: &mut UpdateBufs) -> UpdateStats {
    let dm = params.dims;
    let (t_len, bm) = (mb.t_len, mb.bm);
    forward_sequence(params, mb, bufs);
    let lb = LossBatch {
        actions: &mb.actions,
        old_logp: &mb.old_logp,
        adv: &mb.adv,
        targets: &mb.targets,
    };
    let loss = ppo_loss_grads(&bufs.logits, &bufs.values, &lb, hp,
                              dm.a, &mut bufs.lp_scratch,
                              &mut bufs.dlogits, &mut bufs.dvalues);
    bufs.grads.clear();
    let mut dh = vec![0.0f64; bm * dm.h];
    let (ol, a) = (dm.obs_len(), dm.a);
    for t in (0..t_len).rev() {
        let lo = t * bm;
        let cs = bufs.cache.at(t);
        backward_step(
            params,
            &cs,
            &mb.obs[lo * ol..(lo + bm) * ol],
            &bufs.dlogits[lo * a..(lo + bm) * a],
            &bufs.dvalues[lo..lo + bm],
            &mut dh,
            &mut bufs.grads,
            &mut bufs.scratch,
        );
    }
    let gn = adam.step(params, &bufs.grads, hp[0], hp[6]);
    UpdateStats { loss, grad_norm: gn as f32 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny_dims() -> ModelDims {
        ModelDims { v: 5, e: 2, ae: 3, d: 6, h: 4, a: 6, extra: 0 }
    }

    fn tiny_mb(dm: &ModelDims, seed: u64) -> MiniBatch {
        let (t_len, bm) = (3usize, 2usize);
        let n = t_len * bm;
        let mut rng = Rng::new(seed);
        let obs: Vec<i32> = (0..n * dm.obs_len())
            .map(|_| rng.below(15) as i32)
            .collect();
        let actions: Vec<i32> =
            (0..n).map(|_| rng.below(dm.a) as i32).collect();
        MiniBatch {
            t_len,
            bm,
            obs,
            prev_a: vec![0; n],
            prev_r: vec![0.0; n],
            done: (0..n).map(|i| (i % 4 == 0) as i32).collect(),
            actions,
            old_logp: (0..n)
                .map(|_| -(rng.f64() as f32) - 0.2)
                .collect(),
            adv: (0..n)
                .map(|_| rng.f64() as f32 - 0.5)
                .collect(),
            targets: (0..n)
                .map(|_| rng.f64() as f32)
                .collect(),
            h0: vec![0.0; bm * dm.h],
        }
    }

    #[test]
    fn update_is_deterministic_and_moves_params() {
        let dm = tiny_dims();
        let mb = tiny_mb(&dm, 9);
        let hp = [1e-2f32, 0.2, 0.99, 0.95, 0.01, 0.5, 0.5, 0.0];
        let run = || {
            let mut rng = Rng::new(1);
            let mut p = Params::init(dm, &mut rng);
            let before = p.t.clone();
            let mut adam = Adam::new(&dm);
            let mut bufs = UpdateBufs::new(dm, mb.t_len, mb.bm);
            let s = ppo_update(&mut p, &mut adam, &mb, &hp, &mut bufs);
            (p, adam, s, before)
        };
        let (p1, a1, s1, before) = run();
        let (p2, a2, s2, _) = run();
        assert_eq!(p1, p2, "update bitwise-deterministic");
        assert_eq!(a1, a2);
        assert_eq!(s1.loss.total.to_bits(), s2.loss.total.to_bits());
        assert!(s1.loss.total.is_finite());
        assert!(s1.grad_norm > 0.0);
        assert_ne!(p1.t, before, "params moved");
        assert_eq!(a1.t, 1);
    }

    #[test]
    fn grad_norm_clip_bounds_the_step() {
        let dm = tiny_dims();
        let mb = tiny_mb(&dm, 11);
        // huge lr + tiny max_norm: post-clip effective gradient norm
        // is <= max_norm, so m-updates stay small
        let hp = [1e-3f32, 0.2, 0.99, 0.95, 0.01, 0.5, 1e-6, 0.0];
        let mut rng = Rng::new(2);
        let mut p = Params::init(dm, &mut rng);
        let mut adam = Adam::new(&dm);
        let mut bufs = UpdateBufs::new(dm, mb.t_len, mb.bm);
        let s = ppo_update(&mut p, &mut adam, &mb, &hp, &mut bufs);
        assert!(s.grad_norm > 1e-6, "reported norm is pre-clip");
        let m_norm: f64 = adam
            .m
            .iter()
            .flat_map(|v| v.iter())
            .map(|&x| x as f64 * x as f64)
            .sum::<f64>()
            .sqrt();
        // m = 0.1 * clipped grad; clipped grad norm <= 1e-6
        assert!(m_norm <= 0.1 * 1e-6 * 1.01, "m_norm {m_norm}");
    }
}
